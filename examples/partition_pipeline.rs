//! §IV-D1 scenario: split Qwen3-4B (BS=8) across an RTX 3060M and an RTX
//! 5070 with pipeline parallelism, choosing the cut with PM2Lat, then
//! validate the plan by simulating 100 requests.
//!
//! ```bash
//! make artifacts && cargo run --release --example partition_pipeline
//! ```

use pm2lat::apps::partition;
use pm2lat::gpusim::Gpu;
use pm2lat::models::zoo;
use pm2lat::ops::DType;
use pm2lat::pm2lat::Pm2Lat;
use pm2lat::profiler::ProfileSpec;

fn main() {
    let cfg = zoo::qwen3_4b();
    let (batch, seq) = (8, 512);
    println!("partitioning {} (BS={batch}, seq={seq}) across rtx3060m + rtx5070", cfg.name);

    // Fit PM2Lat on both target devices.
    let mut d1 = Gpu::by_name("rtx3060m").unwrap();
    let mut d2 = Gpu::by_name("rtx5070").unwrap();
    let spec = ProfileSpec::experiment();
    let pl1 = Pm2Lat::build_dtypes(&mut d1, &spec, &[DType::Bf16], false);
    let pl2 = Pm2Lat::build_dtypes(&mut d2, &spec, &[DType::Bf16], false);
    d1.reset();
    d2.reset();

    // Evaluate every feasible cut; print the frontier.
    println!("\ncut  stage1(3060M)  stage2(5070)  bottleneck");
    let mut best: Option<partition::Plan> = None;
    for cut in 1..cfg.layers {
        if !partition::cut_fits(&cfg, cut, batch, seq, &d1, &d2) {
            continue;
        }
        let t1 = cfg.block_range_trace(batch, seq, 0, cut, false);
        let t2 = cfg.block_range_trace(batch, seq, cut, cfg.layers, true);
        let s1 = pl1.predict_trace(&d1, &t1).unwrap();
        let s2 = pl2.predict_trace(&d2, &t2).unwrap() + partition::transfer_s(&cfg, batch, seq);
        let plan = partition::Plan { cut, stage1_s: s1, stage2_s: s2 };
        println!(
            "{cut:>3}  {:>10.0} ms  {:>10.0} ms  {:>8.0} ms",
            s1 * 1e3,
            s2 * 1e3,
            plan.bottleneck_s() * 1e3
        );
        if best.map(|b| plan.bottleneck_s() < b.bottleneck_s()).unwrap_or(true) {
            best = Some(plan);
        }
    }
    let plan = best.expect("a feasible cut");
    println!("\nchosen cut: after block {}", plan.cut);

    // Validate: measure the chosen cut and simulate 100 requests.
    let measured =
        partition::measure_cut(&cfg, plan.cut, batch, seq, &mut d1, &mut d2, 5).unwrap();
    println!(
        "measured stages: {:.0} ms / {:.0} ms (bottleneck {:.0} ms, predicted {:.0} ms)",
        measured.stage1_s * 1e3,
        measured.stage2_s * 1e3,
        measured.bottleneck_s() * 1e3,
        plan.bottleneck_s() * 1e3
    );
    println!(
        "100 requests complete in {:.1} s",
        partition::pipeline_completion_s(&measured, 100)
    );
}
