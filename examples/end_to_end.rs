//! End-to-end driver: exercises the full three-layer system on a real
//! small workload and reports the paper's headline metric.
//!
//! Pipeline: simulated devices → PM2Lat collection (profiler) → NeuSight
//! dataset + **MLP training through the AOT Pallas/JAX artifacts on PJRT**
//! → per-layer + model-level evaluation → headline: PM2Lat error vs
//! NeuSight error, and the NAS-preprocessing speed ratio.
//!
//! ```bash
//! make artifacts && cargo run --release --example end_to_end
//! ```

use pm2lat::experiments::{apps_exp, tables, Lab, Scale};
use pm2lat::runtime::Runtime;

fn main() {
    let runtime = Runtime::open_default().expect("run `make artifacts` first");
    println!("== end-to-end: build lab (PM2Lat fits + NeuSight PJRT training) ==");
    let scale = Scale { per_cell: 60, ns_per_device: 100, ns_epochs: 30, model_reps: 5, custom_per_kind: 20 };
    let mut lab = Lab::build(&runtime, scale, false).expect("lab build");
    for (dt, ns) in &lab.neusight {
        if let Some(r) = &ns.report {
            println!(
                "NeuSight[{dt}] trained via PJRT: loss {:.4} → {:.4} over {} epochs",
                r.first_loss, r.final_loss, r.epochs
            );
        }
    }

    println!("\n== per-layer evaluation (Table II, reduced scale) ==");
    let t2 = tables::table2(&mut lab).expect("table2");
    println!("{}", t2.markdown);

    // Headline: mean error over all finite cells.
    let pl_mean = mean_err(&t2.records, true);
    let ns_mean = mean_err(&t2.records, false);
    println!(
        "HEADLINE per-layer: PM2Lat {:.1}% vs NeuSight {:.1}% mean relative error ({:.0}x)",
        pl_mean,
        ns_mean,
        ns_mean / pl_mean
    );

    println!("\n== NAS preprocessing speed (§IV-D2) ==");
    let nas = apps_exp::nas_speed_experiment(&mut lab, 500).expect("nas");
    println!("{nas}");

    assert!(pl_mean < ns_mean, "PM2Lat must beat the baseline");
    println!("end_to_end OK");
}

fn mean_err(records: &[tables::SampleRecord], pl: bool) -> f64 {
    let vals: Vec<f64> = records
        .iter()
        .map(|r| if pl { r.pl_err } else { r.ns_err })
        .filter(|v| v.is_finite())
        .collect();
    pm2lat::util::stats::mean(&vals)
}
