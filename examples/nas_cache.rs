//! §IV-D2 scenario: NAS preprocessing — precompute a latency cache for a
//! large MatMul configuration space through the coordinator's batched
//! prediction service, and report per-prediction cost.
//!
//! ```bash
//! make artifacts && cargo run --release --example nas_cache
//! ```

use std::time::Instant;

use pm2lat::apps::nas::{self, LatencyCache, SpeedReport};
use pm2lat::coordinator::{Coordinator, PredictorKind, Request};
use pm2lat::gpusim::Gpu;
use pm2lat::ops::{DType, Op};
use pm2lat::pm2lat::Pm2Lat;
use pm2lat::profiler::ProfileSpec;
use pm2lat::runtime::Runtime;

fn main() {
    let runtime = Runtime::open_default().expect("run `make artifacts` first");
    let mut gpu = Gpu::by_name("a100").unwrap();
    let pl = Pm2Lat::build_dtypes(&mut gpu, &ProfileSpec::experiment(), &[DType::F32], false);
    gpu.reset();

    // Route through the coordinator (batched PM2Lat path).
    let mut coord = Coordinator::new(&runtime);
    coord.register_device(gpu, pl).unwrap();

    let n = 4096;
    let configs = nas::sample_configs(n, DType::F32, 7);
    println!("NAS space ≈ {:.0}M configs; sampling {n}", nas::space_size() as f64 / 1e6);

    let requests: Vec<Request> = configs
        .iter()
        .map(|g| Request {
            device: "a100".into(),
            op: Op::Gemm(*g),
            kind: PredictorKind::Pm2LatBatched,
        })
        .collect();
    let t0 = Instant::now();
    let results = coord.submit(&requests).unwrap();
    let elapsed = t0.elapsed().as_secs_f64();

    let mut cache = LatencyCache::default();
    for (g, r) in configs.iter().zip(&results) {
        if let Some(lat) = r {
            cache.insert(g, *lat);
        }
    }
    let report = SpeedReport::from_run(n, elapsed);
    println!(
        "cached {} predictions in {:.3} s → {:.4} ms/prediction",
        cache.len(),
        report.total_s,
        report.ms_per_prediction
    );
    println!(
        "extrapolated to the full 400M-config space: {:.1} hours (paper: PM2Lat ≈ 5 h, NeuSight ≈ 30 days)",
        report.full_space_hours
    );
    println!("coordinator metrics: {}", coord.metrics.summary());

    // Demonstrate the cache in use: instant lookups at NAS-search time.
    let t0 = Instant::now();
    let mut hits = 0;
    for g in &configs {
        if cache.get(g).is_some() {
            hits += 1;
        }
    }
    println!(
        "cache lookups: {hits}/{n} hits in {:.1} µs total",
        t0.elapsed().as_secs_f64() * 1e6
    );
}
