//! §IV-D2 scenario: NAS preprocessing — precompute a latency cache for a
//! large MatMul configuration space through the coordinator's batched
//! prediction service, and report per-prediction cost. A second pass shows
//! the service's own LRU serving repeat configurations at cache speed.
//!
//! ```bash
//! make artifacts && cargo run --release --example nas_cache
//! ```

use std::time::Instant;

use pm2lat::apps::nas::{self, LatencyCache};
use pm2lat::gpusim::Gpu;
use pm2lat::ops::DType;
use pm2lat::pm2lat::Pm2Lat;
use pm2lat::profiler::ProfileSpec;
use pm2lat::runtime::Runtime;

fn main() {
    let runtime = Runtime::open_default().expect("run `make artifacts` first");
    let mut gpu = Gpu::by_name("a100").unwrap();
    let pl = Pm2Lat::build_dtypes(&mut gpu, &ProfileSpec::experiment(), &[DType::F32], false);
    gpu.reset();

    // Route through the coordinator (batched PM2Lat path + its LRU).
    let mut coord = pm2lat::coordinator::Coordinator::new(&runtime);
    coord.register_device(gpu, pl).unwrap();

    let n = 4096;
    let configs = nas::sample_configs(n, DType::F32, 7);
    println!("NAS space ≈ {:.0}M configs; sampling {n}", nas::space_size() as f64 / 1e6);

    let mut cache = LatencyCache::default();
    let report = nas::preprocess_service(&coord, "a100", &configs, &mut cache).expect("submit");
    println!(
        "cached {} predictions in {:.3} s → {:.4} ms/prediction",
        cache.len(),
        report.total_s,
        report.ms_per_prediction
    );
    println!(
        "extrapolated to the full 400M-config space: {:.1} hours (paper: PM2Lat ≈ 5 h, NeuSight ≈ 30 days)",
        report.full_space_hours
    );

    // Preprocessing round 2: every op now hits the coordinator's LRU —
    // bit-identical values at cache throughput. Hit counting uses the
    // delta over this pass (the cumulative rate would include round 1's
    // unavoidable misses).
    let hits_before = coord.metrics.cache_hits.load(std::sync::atomic::Ordering::Relaxed);
    let mut warm = LatencyCache::default();
    let warm_report = nas::preprocess_service(&coord, "a100", &configs, &mut warm).expect("submit");
    let warm_hits = coord.metrics.cache_hits.load(std::sync::atomic::Ordering::Relaxed) - hits_before;
    println!(
        "warm pass: {:.4} ms/prediction ({warm_hits}/{n} served from the service LRU)",
        warm_report.ms_per_prediction
    );
    println!("coordinator metrics: {}", coord.metrics.summary());

    // Demonstrate the cache in use: instant lookups at NAS-search time.
    let t0 = Instant::now();
    let mut hits = 0;
    for g in &configs {
        if cache.get(g).is_some() {
            hits += 1;
        }
    }
    println!(
        "cache lookups: {hits}/{n} hits in {:.1} µs total",
        t0.elapsed().as_secs_f64() * 1e6
    );
}
