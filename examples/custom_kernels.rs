//! §IV-C scenario: PM2Lat on custom computation-intensive kernels —
//! Triton MatMul (autotuned), Triton vector kernels, FlashAttention-2 and
//! CUTLASS attention — including the architecture gates (no FA2 on
//! Turing, no attention kernels on Blackwell).
//!
//! ```bash
//! make artifacts && cargo run --release --example custom_kernels
//! ```

use pm2lat::gpusim::{custom, Gpu};
use pm2lat::ops::{CustomOp, DType, Op};
use pm2lat::pm2lat::custom_model;
use pm2lat::profiler::{self, ProfileSpec};
use pm2lat::util::stats::signed_rel_err_pct;

fn main() {
    let dtype = DType::F32;
    for device in ["rtx3060m", "t4", "a100", "rtx5070"] {
        let mut gpu = Gpu::by_name(device).unwrap();
        println!("\n=== {device} ===");
        let model = custom_model::collect(&mut gpu, dtype, &ProfileSpec::experiment());
        gpu.reset();
        let ops = [
            CustomOp::TritonMM { m: 1024, n: 2048, k: 4096, dtype },
            CustomOp::TritonVec { elems: 1 << 22, dtype },
            CustomOp::FlashAttn { batch: 4, heads: 16, kv_heads: 16, q_len: 1024, kv_len: 1024, head_dim: 64, dtype, causal: true },
            CustomOp::CutlassAttn { batch: 4, heads: 16, kv_heads: 16, q_len: 1024, kv_len: 1024, head_dim: 64, dtype, causal: true },
            // One decode step over a 1024-token KV cache: the KV-bound
            // regime of autoregressive generation.
            CustomOp::FlashAttn { batch: 4, heads: 16, kv_heads: 16, q_len: 1, kv_len: 1024, head_dim: 64, dtype, causal: true },
            // The same step with a grouped (GQA, 4 kv heads) cache:
            // 4x less KV traffic, visibly cheaper.
            CustomOp::FlashAttn { batch: 4, heads: 16, kv_heads: 4, q_len: 1, kv_len: 1024, head_dim: 64, dtype, causal: true },
        ];
        for op in ops {
            if !custom::supported(&gpu.spec, &op) {
                println!("  {:10} unsupported on this architecture (-)", op.name());
                continue;
            }
            let pred = model.predict(&gpu, &op);
            let truth = profiler::measure(&mut gpu, &Op::Custom(op), &ProfileSpec::experiment())
                .unwrap()
                .mean_s;
            match pred {
                Some(p) => println!(
                    "  {:10} predicted {:>8.3} ms | measured {:>8.3} ms | {:+.1}%",
                    op.name(),
                    p * 1e3,
                    truth * 1e3,
                    signed_rel_err_pct(p, truth)
                ),
                None => println!("  {:10} no profile", op.name()),
            }
        }
        // TruthCFG variant for Triton MM.
        let op = CustomOp::TritonMM { m: 1024, n: 2048, k: 4096, dtype };
        if custom::supported(&gpu.spec, &op) {
            if let Some(p) = model.predict_truth_cfg(&gpu, &op) {
                println!("  TritonMM (TruthCFG) predicted {:.3} ms", p * 1e3);
            }
        }
    }
}
