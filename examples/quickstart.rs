//! Quickstart: profile a device once, then predict GEMM / utility-layer /
//! whole-model latencies and check them against measurements.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use pm2lat::gpusim::Gpu;
use pm2lat::models::{runner, zoo};
use pm2lat::ops::{DType, GemmOp, Op, UtilKind, UtilOp};
use pm2lat::pm2lat::Pm2Lat;
use pm2lat::profiler::{self, ProfileSpec};
use pm2lat::util::stats::signed_rel_err_pct;

fn main() {
    // 1. Pick a (simulated) target device and run PM2Lat's one-time
    //    data-collection + fitting pass on it.
    let mut gpu = Gpu::by_name("a100").expect("device");
    println!("profiling {} (one-time, per-device)...", gpu.spec.name);
    let pl = Pm2Lat::build_dtypes(&mut gpu, &ProfileSpec::experiment(), &[DType::F32], false);
    gpu.reset();

    // 2. Predict individual layers and compare to fresh measurements.
    let ops = [
        ("Linear 512x4096x1024", Op::Gemm(GemmOp::linear(512, 4096, 1024, DType::F32))),
        ("MatMul 2048^3", Op::Gemm(GemmOp::mm(2048, 2048, 2048, DType::F32))),
        ("BMM 32x256x256x64", Op::Gemm(GemmOp::bmm(32, 256, 256, 64, DType::F32))),
        ("SoftMax 8192x1024", Op::Util(UtilOp::new(UtilKind::Softmax, 8192, 1024, DType::F32))),
        ("GeLU 4096x4096", Op::Util(UtilOp::new(UtilKind::Gelu, 4096, 4096, DType::F32))),
    ];
    println!("\nper-layer predictions on {}:", gpu.spec.name);
    for (name, op) in &ops {
        let pred = pl.predict(&gpu, op).expect("supported");
        let truth = profiler::measure(&mut gpu, op, &ProfileSpec::experiment())
            .expect("measure")
            .mean_s;
        println!(
            "  {name:24} predicted {:>9.3} ms | measured {:>9.3} ms | {:+.1}%",
            pred * 1e3,
            truth * 1e3,
            signed_rel_err_pct(pred, truth)
        );
    }

    // 3. Whole model: GPT-2 Large prefill at batch 8.
    let cfg = zoo::gpt2_large();
    let trace = cfg.trace(8, 512);
    let pred = pl.predict_trace(&gpu, &trace).expect("supported");
    gpu.reset();
    let run = runner::run_model(&mut gpu, &cfg, 8, 512, 5, 25).expect("run");
    println!(
        "\n{} BS=8 seq=512: predicted {:.1} ms | measured {:.1} ms | {:+.1}%",
        cfg.name,
        pred * 1e3,
        run.mean_s * 1e3,
        signed_rel_err_pct(pred, run.mean_s)
    );
}
