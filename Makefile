# PM2Lat build / CI entrypoints.
#
#   make artifacts   — AOT-lower the L1/L2 Pallas+JAX kernels to HLO text
#                      (required once before any Rust target that opens
#                      the PJRT runtime).
#   make lint        — formatting + clippy-as-errors; skips gracefully in
#                      toolchain-less containers so CI plumbing still runs.
#   make doc         — rustdoc for the crate (no deps); same graceful
#                      no-toolchain skip as lint.
#   make ci          — tier-1 verification in one command: lint, docs,
#                      release build, full test suite, serve-sim smoke.
#   make serve-sim-smoke — fast serving-simulator end-to-end check
#                      (tiny trace, quick profile; graceful no-cargo skip).
#   make serve-sim-tp-smoke — same smoke on a tensor-parallel placement
#                      (--tp 2: rank-graph rewrite + priced collectives).
#   make serve-sim-prefix-smoke — the smoke with copy-on-write prefix
#                      sharing on; fails if the prefix index never hits.
#   make serve-sim-spec-smoke — the smoke under speculative decoding
#                      (k=4, α=0.8, auto-draft); fails if no draft token
#                      is ever accepted or tokens/s does not beat the
#                      non-speculative baseline on the same trace.
#   make bench-serving — the serving-capacity sweep on the fast setting.
#   make bench-json  — the same sweep, writing the hot-path measurements
#                      (iterations/s cold vs memoized, sweep wall-clock)
#                      to BENCH_serving.json for CI trend lines, then
#                      appending the speculative k × α crossover lanes.

PYTHON ?= python3

.PHONY: artifacts ci lint doc fmt clippy build test bench-fast bench-serving bench-json serve-sim-smoke serve-sim-tp-smoke serve-sim-prefix-smoke serve-sim-spec-smoke

# aot.py uses package-relative imports — must run as a module from python/.
artifacts:
	cd python && $(PYTHON) -m compile.aot --out-dir ../artifacts

ci: lint doc test serve-sim-smoke serve-sim-tp-smoke serve-sim-prefix-smoke serve-sim-spec-smoke bench-json

# Graceful no-toolchain path: some dev containers ship without cargo, and
# lint is the one stage that may safely no-op there (skipping style checks
# loses nothing; skipping build/test would fake a green CI). `make ci`
# still hard-fails without cargo at the build/test stages, by design.
lint:
	@if command -v cargo >/dev/null 2>&1; then \
		cargo fmt --check && cargo clippy --all-targets -- -D warnings; \
	else \
		echo "lint: cargo not found — skipping (toolchain-less container)"; \
	fi

# Docs are load-bearing (README/ARCHITECTURE link into rustdoc): build
# them in CI, with the same graceful skip as lint when cargo is absent
# (skipping doc generation loses nothing; build/test still hard-fail).
doc:
	@if command -v cargo >/dev/null 2>&1; then \
		cargo doc --no-deps; \
	else \
		echo "doc: cargo not found — skipping (toolchain-less container)"; \
	fi

fmt:
	cargo fmt --check

clippy:
	cargo clippy --all-targets -- -D warnings

build:
	cargo build --release

test: build
	cargo test -q

bench-fast:
	PM2LAT_BENCH_FAST=1 cargo bench

bench-serving:
	PM2LAT_BENCH_FAST=1 cargo bench --bench serving_capacity

# Measured, not asserted: the serving bench's hot-path lane writes its
# numbers (cold vs memoized iterations/s, serial vs parallel sweep
# wall-clock, cache hit rate) to BENCH_serving.json. Bit-for-bit equality
# between fast and cold paths is asserted inside the bench itself. Same
# graceful no-cargo skip as lint/doc.
bench-json:
	@if command -v cargo >/dev/null 2>&1; then \
		PM2LAT_BENCH_FAST=1 PM2LAT_BENCH_JSON=BENCH_serving.json cargo bench --bench serving_capacity && \
		PM2LAT_BENCH_FAST=1 PM2LAT_BENCH_JSON=BENCH_serving.json cargo bench --bench spec_decode; \
	else \
		echo "bench-json: cargo not found — skipping (toolchain-less container)"; \
	fi

# End-to-end serving-simulator smoke: drives `pm2lat serve-sim --smoke`
# (tiny Poisson trace, quick profile, sweep + SLO search) as an execution
# check on top of the unit suite. Same graceful no-cargo skip as lint/doc
# — in a toolchain-less container `make ci` already hard-fails at the
# build/test stages, so skipping here fakes nothing.
serve-sim-smoke:
	@if command -v cargo >/dev/null 2>&1; then \
		cargo run --release --quiet -- serve-sim --smoke; \
	else \
		echo "serve-sim-smoke: cargo not found — skipping (toolchain-less container)"; \
	fi

# The same smoke over a 2-way tensor-parallel placement: every iteration
# graph is rewritten by TensorParallelPass and the SLO curves come out
# cluster-level, so this exercises the placement path end to end.
serve-sim-tp-smoke:
	@if command -v cargo >/dev/null 2>&1; then \
		cargo run --release --quiet -- serve-sim --tp 2 --smoke; \
	else \
		echo "serve-sim-tp-smoke: cargo not found — skipping (toolchain-less container)"; \
	fi

# The smoke with the copy-on-write prefix pager engaged: the CLI prepends
# a shared template to every synthetic prompt, and under --smoke the run
# itself errors if the prefix index never produces a hit — so a silently
# dead sharing path (index never consulted, blocks never deduped) fails
# CI instead of just printing zeros.
serve-sim-prefix-smoke:
	@if command -v cargo >/dev/null 2>&1; then \
		cargo run --release --quiet -- serve-sim --prefix-share --smoke; \
	else \
		echo "serve-sim-prefix-smoke: cargo not found — skipping (toolchain-less container)"; \
	fi

# The smoke under speculative decoding: k=4 speculated tokens at a
# uniform 0.8 acceptance, the draft defaulting to an auto-shrunk copy of
# the target. Under --smoke the run itself errors if no draft token is
# ever accepted (dead acceptance path) or if speculative tokens/s fails
# to strictly beat the non-speculative replay of the same trace — so a
# speculation path that silently stops paying fails CI.
serve-sim-spec-smoke:
	@if command -v cargo >/dev/null 2>&1; then \
		cargo run --release --quiet -- serve-sim --spec-k 4 --accept 0.8 --smoke; \
	else \
		echo "serve-sim-spec-smoke: cargo not found — skipping (toolchain-less container)"; \
	fi
