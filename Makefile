# PM2Lat build / CI entrypoints.
#
#   make artifacts   — AOT-lower the L1/L2 Pallas+JAX kernels to HLO text
#                      (required once before any Rust target that opens
#                      the PJRT runtime).
#   make lint        — formatting + clippy-as-errors; skips gracefully in
#                      toolchain-less containers so CI plumbing still runs.
#   make doc         — rustdoc for the crate (no deps), warnings as errors
#                      (broken intra-doc links fail); same graceful
#                      no-toolchain skip as lint.
#   make doc-check   — prose/code drift check: every --flag mentioned in
#                      README/docs must exist in the CLI, every relative
#                      markdown link must resolve. Pure grep — runs even
#                      in toolchain-less containers.
#   make ci          — tier-1 verification in one command: lint, docs,
#                      doc-check, release build, full test suite,
#                      serve-sim smokes, trace smoke.
#   make serve-sim-smoke — fast serving-simulator end-to-end check
#                      (tiny trace, quick profile; graceful no-cargo skip).
#   make serve-sim-tp-smoke — same smoke on a tensor-parallel placement
#                      (--tp 2: rank-graph rewrite + priced collectives).
#   make serve-sim-prefix-smoke — the smoke with copy-on-write prefix
#                      sharing on; fails if the prefix index never hits.
#   make serve-sim-spec-smoke — the smoke under speculative decoding
#                      (k=4, α=0.8, auto-draft); fails if no draft token
#                      is ever accepted or tokens/s does not beat the
#                      non-speculative baseline on the same trace.
#   make trace-smoke — the smoke with --trace-out: fails if the Chrome
#                      trace is empty or invalid JSON (the run itself
#                      already errors if the span count diverges from the
#                      reported iteration count).
#   make bench-serving — the serving-capacity sweep on the fast setting.
#   make bench-json  — the same sweep, writing the hot-path measurements
#                      (iterations/s cold vs memoized, sweep wall-clock)
#                      to BENCH_serving.json for CI trend lines, then
#                      appending the speculative k × α crossover lanes.

PYTHON ?= python3

.PHONY: artifacts ci lint doc doc-check fmt clippy build test bench-fast bench-serving bench-json serve-sim-smoke serve-sim-tp-smoke serve-sim-prefix-smoke serve-sim-spec-smoke trace-smoke

# aot.py uses package-relative imports — must run as a module from python/.
artifacts:
	cd python && $(PYTHON) -m compile.aot --out-dir ../artifacts

ci: lint doc doc-check test serve-sim-smoke serve-sim-tp-smoke serve-sim-prefix-smoke serve-sim-spec-smoke trace-smoke bench-json

# Graceful no-toolchain path: some dev containers ship without cargo, and
# lint is the one stage that may safely no-op there (skipping style checks
# loses nothing; skipping build/test would fake a green CI). `make ci`
# still hard-fails without cargo at the build/test stages, by design.
lint:
	@if command -v cargo >/dev/null 2>&1; then \
		cargo fmt --check && cargo clippy --all-targets -- -D warnings; \
	else \
		echo "lint: cargo not found — skipping (toolchain-less container)"; \
	fi

# Docs are load-bearing (README/ARCHITECTURE link into rustdoc): build
# them in CI with rustdoc warnings promoted to errors, so a broken
# intra-doc link or a malformed doc attribute fails the lane instead of
# scrolling by. Same graceful skip as lint when cargo is absent (skipping
# doc generation loses nothing; build/test still hard-fail).
doc:
	@if command -v cargo >/dev/null 2>&1; then \
		RUSTDOCFLAGS="-D warnings" cargo doc --no-deps; \
	else \
		echo "doc: cargo not found — skipping (toolchain-less container)"; \
	fi

# Prose drifts faster than code: doc-check greps README.md and docs/*.md
# for CLI flags and relative links and verifies both against the tree.
# Deliberately toolchain-free so it runs (and fails) even in containers
# without cargo — stale docs are exactly the regression this lane exists
# to catch.
doc-check:
	@sh scripts/doc_check.sh

fmt:
	cargo fmt --check

clippy:
	cargo clippy --all-targets -- -D warnings

build:
	cargo build --release

test: build
	cargo test -q

bench-fast:
	PM2LAT_BENCH_FAST=1 cargo bench

bench-serving:
	PM2LAT_BENCH_FAST=1 cargo bench --bench serving_capacity

# Measured, not asserted: the serving bench's hot-path lane writes its
# numbers (cold vs memoized iterations/s, serial vs parallel sweep
# wall-clock, cache hit rate) to BENCH_serving.json. Bit-for-bit equality
# between fast and cold paths is asserted inside the bench itself. Same
# graceful no-cargo skip as lint/doc.
bench-json:
	@if command -v cargo >/dev/null 2>&1; then \
		PM2LAT_BENCH_FAST=1 PM2LAT_BENCH_JSON=BENCH_serving.json cargo bench --bench serving_capacity && \
		PM2LAT_BENCH_FAST=1 PM2LAT_BENCH_JSON=BENCH_serving.json cargo bench --bench spec_decode; \
	else \
		echo "bench-json: cargo not found — skipping (toolchain-less container)"; \
	fi

# End-to-end serving-simulator smoke: drives `pm2lat serve-sim --smoke`
# (tiny Poisson trace, quick profile, sweep + SLO search) as an execution
# check on top of the unit suite. Same graceful no-cargo skip as lint/doc
# — in a toolchain-less container `make ci` already hard-fails at the
# build/test stages, so skipping here fakes nothing.
serve-sim-smoke:
	@if command -v cargo >/dev/null 2>&1; then \
		cargo run --release --quiet -- serve-sim --smoke; \
	else \
		echo "serve-sim-smoke: cargo not found — skipping (toolchain-less container)"; \
	fi

# The same smoke over a 2-way tensor-parallel placement: every iteration
# graph is rewritten by TensorParallelPass and the SLO curves come out
# cluster-level, so this exercises the placement path end to end.
serve-sim-tp-smoke:
	@if command -v cargo >/dev/null 2>&1; then \
		cargo run --release --quiet -- serve-sim --tp 2 --smoke; \
	else \
		echo "serve-sim-tp-smoke: cargo not found — skipping (toolchain-less container)"; \
	fi

# The smoke with the copy-on-write prefix pager engaged: the CLI prepends
# a shared template to every synthetic prompt, and under --smoke the run
# itself errors if the prefix index never produces a hit — so a silently
# dead sharing path (index never consulted, blocks never deduped) fails
# CI instead of just printing zeros.
serve-sim-prefix-smoke:
	@if command -v cargo >/dev/null 2>&1; then \
		cargo run --release --quiet -- serve-sim --prefix-share --smoke; \
	else \
		echo "serve-sim-prefix-smoke: cargo not found — skipping (toolchain-less container)"; \
	fi

# The smoke under speculative decoding: k=4 speculated tokens at a
# uniform 0.8 acceptance, the draft defaulting to an auto-shrunk copy of
# the target. Under --smoke the run itself errors if no draft token is
# ever accepted (dead acceptance path) or if speculative tokens/s fails
# to strictly beat the non-speculative replay of the same trace — so a
# speculation path that silently stops paying fails CI.
serve-sim-spec-smoke:
	@if command -v cargo >/dev/null 2>&1; then \
		cargo run --release --quiet -- serve-sim --spec-k 4 --accept 0.8 --smoke; \
	else \
		echo "serve-sim-spec-smoke: cargo not found — skipping (toolchain-less container)"; \
	fi

# The smoke with the observability layer on: record the replay, write the
# Chrome trace, then prove the artifact is real — valid JSON, a non-empty
# traceEvents array, and at least one B/E span pair. The binary itself
# already hard-errors when the recorded span count diverges from the
# reported iteration count, so this lane focuses on the exported file.
trace-smoke:
	@if command -v cargo >/dev/null 2>&1; then \
		out=$$(mktemp /tmp/pm2lat-trace.XXXXXX.json) && \
		cargo run --release --quiet -- serve-sim --smoke --trace-out $$out && \
		$(PYTHON) -c "import json,sys; \
ev = json.load(open(sys.argv[1]))['traceEvents']; \
assert ev, 'empty traceEvents'; \
assert any(e.get('ph') == 'B' for e in ev), 'no spans in trace'; \
print('trace-smoke: %d events OK' % len(ev))" $$out; \
		st=$$?; rm -f $$out; exit $$st; \
	else \
		echo "trace-smoke: cargo not found — skipping (toolchain-less container)"; \
	fi
