# PM2Lat build / CI entrypoints.
#
#   make artifacts   — AOT-lower the L1/L2 Pallas+JAX kernels to HLO text
#                      (required once before any Rust target that opens
#                      the PJRT runtime).
#   make ci          — tier-1 verification in one command: formatting,
#                      clippy as errors, release build, full test suite.

PYTHON ?= python3

.PHONY: artifacts ci fmt clippy build test bench-fast

# aot.py uses package-relative imports — must run as a module from python/.
artifacts:
	cd python && $(PYTHON) -m compile.aot --out-dir ../artifacts

ci: fmt clippy test

fmt:
	cargo fmt --check

clippy:
	cargo clippy --all-targets -- -D warnings

build:
	cargo build --release

test: build
	cargo test -q

bench-fast:
	PM2LAT_BENCH_FAST=1 cargo bench
