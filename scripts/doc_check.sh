#!/bin/sh
# doc_check.sh — the prose/code drift gate behind `make doc-check`.
#
# Two checks, both pure grep so the lane runs even in containers without
# a Rust toolchain:
#
#   1. Every `--flag` mentioned in README.md or docs/*.md must exist in
#      the CLI (rust/src/main.rs) or be a known build-tool flag — stale
#      flag references are the fastest way docs rot.
#   2. Every relative markdown link must resolve to a file in the tree
#      (http/mailto/#anchor links are skipped).
#
# Exit non-zero with one line per violation.
set -eu
cd "$(dirname "$0")/.."

fail=0
docs="README.md"
for f in docs/*.md; do
  docs="$docs $f"
done

# Build-tool flags (cargo, python, perfetto) that legitimately appear in
# prose but are not pm2lat CLI surface.
whitelist=" --release --quiet --check --all-targets --no-deps --bench --out-dir --help --version --locked --offline "

for f in $docs; do
  [ -f "$f" ] || continue

  # --- stale CLI flags ---
  # A live flag shows up in main.rs either spelled out (`--trace-out` in
  # the usage header) or as the quoted name the parser reads
  # (`args.opt("trace-out")`).
  # The delimiter class before `--` keeps heading-anchor slugs
  # (#section--subtitle) from reading as flags.
  for flag in $(grep -oE -- '(^|[[:space:]`"(=|])--[a-z][a-z0-9-]*' "$f" \
      | sed 's/^[^-]*//' | sort -u); do
    case "$whitelist" in
      *" $flag "*) continue ;;
    esac
    bare=${flag#--}
    if ! grep -qF -- "$flag" rust/src/main.rs && \
       ! grep -qF -- "\"$bare\"" rust/src/main.rs; then
      echo "doc-check: $f mentions $flag, which rust/src/main.rs does not define" >&2
      fail=1
    fi
  done

  # --- broken relative links ---
  dir=$(dirname "$f")
  for link in $(grep -oE '\]\([^)]+\)' "$f" | sed -e 's/^](//' -e 's/)$//' | sort -u); do
    case "$link" in
      http://* | https://* | mailto:* | \#*) continue ;;
    esac
    target=${link%%#*}
    [ -n "$target" ] || continue
    if [ ! -e "$dir/$target" ] && [ ! -e "$target" ]; then
      echo "doc-check: $f links to $link but no such file exists" >&2
      fail=1
    fi
  done
done

if [ "$fail" -ne 0 ]; then
  echo "doc-check: FAILED" >&2
  exit 1
fi
echo "doc-check: OK"
