"""Kernel-vs-oracle correctness: the CORE L1 signal.

Every Pallas kernel must match its pure-jnp oracle to float32 tolerance
across a sweep of shapes and value distributions (hypothesis when
available, a fixed grid otherwise).
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile import model
from compile.kernels import batch_predict as bp
from compile.kernels import lstsq as lsq
from compile.kernels import mlp as mlpk
from compile.kernels import ref

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    HAVE_HYPOTHESIS = False

RNG = np.random.default_rng(42)


def _mlp_inputs(b, f=model.FEATURE_DIM, h=model.HIDDEN_DIM, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(b, f)).astype(np.float32)
    w1 = rng.normal(scale=0.3, size=(f, h)).astype(np.float32)
    b1 = rng.normal(scale=0.1, size=(h,)).astype(np.float32)
    w2 = rng.normal(scale=0.1, size=(h, h)).astype(np.float32)
    b2 = rng.normal(scale=0.1, size=(h,)).astype(np.float32)
    w3 = rng.normal(scale=0.3, size=(h, 1)).astype(np.float32)
    b3 = rng.normal(scale=0.1, size=(1,)).astype(np.float32)
    return x, w1, b1, w2, b2, w3, b3


class TestMlpKernel:
    @pytest.mark.parametrize("b", [128, 256, 1024])
    def test_matches_ref(self, b):
        args = _mlp_inputs(b, seed=b)
        got = mlpk.mlp_forward(*args)
        want = ref.mlp_forward_ref(*args)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)

    def test_output_in_unit_interval(self):
        args = _mlp_inputs(256, seed=7)
        out = np.asarray(mlpk.mlp_forward(*args))
        assert np.all(out > 0.0) and np.all(out < 1.0)

    def test_rejects_unaligned_batch(self):
        args = _mlp_inputs(128)
        bad = (np.zeros((100, model.FEATURE_DIM), np.float32),) + args[1:]
        with pytest.raises(AssertionError):
            mlpk.mlp_forward(*bad)

    @pytest.mark.parametrize("f", [8, 16, 32])
    def test_feature_dims(self, f):
        args = _mlp_inputs(128, f=f, seed=f)
        got = mlpk.mlp_forward(*args)
        want = ref.mlp_forward_ref(*args)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)

    def test_deterministic(self):
        args = _mlp_inputs(128, seed=3)
        a = np.asarray(mlpk.mlp_forward(*args))
        b = np.asarray(mlpk.mlp_forward(*args))
        np.testing.assert_array_equal(a, b)

    if HAVE_HYPOTHESIS:

        @settings(max_examples=20, deadline=None)
        @given(
            b_mult=st.integers(min_value=1, max_value=6),
            f=st.integers(min_value=4, max_value=48),
            seed=st.integers(min_value=0, max_value=2**31 - 1),
        )
        def test_hypothesis_sweep(self, b_mult, f, seed):
            args = _mlp_inputs(128 * b_mult, f=f, seed=seed)
            got = mlpk.mlp_forward(*args)
            want = ref.mlp_forward_ref(*args)
            np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def _predict_inputs(b, nk=bp.MAX_KERNELS, seed=0):
    rng = np.random.default_rng(seed)
    # Monotone-ish saturating throughput rows, like real kernels.
    base = rng.uniform(0.5, 4.0, size=(nk, 1)).astype(np.float32)
    ramp = 1.0 / (1.0 + 64.0 / (2.0 ** np.arange(ref.N_K_POINTS))[None, :])
    table = (base * (0.2 + ramp)).astype(np.float32)
    base_dur = rng.uniform(1e-5, 1e-2, size=(nk,)).astype(np.float32)
    k_vals = rng.uniform(1.0, 10000.0, size=(b,)).astype(np.float32)
    kids = rng.integers(0, nk, size=(b,), dtype=np.int32)
    scale = rng.uniform(0.1, 8.0, size=(b,)).astype(np.float32)
    return table, base_dur, k_vals, kids, scale


class TestBatchPredictKernel:
    @pytest.mark.parametrize("b", [1024, 4096])
    def test_matches_ref(self, b):
        args = _predict_inputs(b, seed=b)
        got = bp.batch_predict(*args)
        want = ref.batch_predict_ref(*args)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-9)

    def test_exact_grid_points(self):
        """At K exactly on the grid, prediction must hit the table value."""
        table, base_dur, _, _, _ = _predict_inputs(1024, seed=1)
        k_grid = 32.0 * 2.0 ** np.arange(ref.N_K_POINTS - 1)
        k_vals = np.tile(k_grid, 128).astype(np.float32)
        kids = np.repeat(np.arange(128, dtype=np.int32), 8)
        scale = np.ones(1024, np.float32)
        got = np.asarray(bp.batch_predict(table, base_dur, k_vals, kids, scale))
        thr = table[kids, np.log2(k_vals / 32.0).astype(int)]
        org_thr = table[kids, -1]
        want = base_dur[kids] * (k_vals / 8192.0) * (org_thr / thr)
        np.testing.assert_allclose(got, want, rtol=1e-4)

    def test_k_clamped_above_grid(self):
        """K > 8192 behaves as K = 8192 × linear duration extension? No —
        the kernel clamps K to the grid for interpolation; Eq. 1's K factor
        uses the clamped K too, matching ref."""
        args = list(_predict_inputs(1024, seed=2))
        args[2] = np.full(1024, 20000.0, np.float32)
        got = bp.batch_predict(*args)
        want = ref.batch_predict_ref(*args)
        np.testing.assert_allclose(got, want, rtol=1e-4)

    def test_monotone_in_scale(self):
        args = list(_predict_inputs(1024, seed=3))
        lo = np.asarray(bp.batch_predict(*args))
        args[4] = args[4] * 2.0
        hi = np.asarray(bp.batch_predict(*args))
        assert np.all(hi > lo)

    if HAVE_HYPOTHESIS:

        @settings(max_examples=20, deadline=None)
        @given(seed=st.integers(min_value=0, max_value=2**31 - 1))
        def test_hypothesis_sweep(self, seed):
            args = _predict_inputs(1024, seed=seed)
            got = bp.batch_predict(*args)
            want = ref.batch_predict_ref(*args)
            np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-9)


class TestLstsqKernel:
    @pytest.mark.parametrize("n,p", [(256, 4), (1024, 8), (4096, 8)])
    def test_recovers_coefficients(self, n, p):
        rng = np.random.default_rng(n + p)
        x = rng.normal(size=(n, p)).astype(np.float32)
        true_c = rng.normal(size=(p,)).astype(np.float32)
        y = x @ true_c
        got = np.asarray(lsq.lstsq(jnp.asarray(x), jnp.asarray(y)))
        np.testing.assert_allclose(got, true_c, rtol=1e-3, atol=1e-3)

    def test_matches_ref(self):
        rng = np.random.default_rng(0)
        x = rng.normal(size=(1024, 8)).astype(np.float32)
        y = rng.normal(size=(1024,)).astype(np.float32)
        got = np.asarray(lsq.lstsq(jnp.asarray(x), jnp.asarray(y)))
        want = np.asarray(ref.lstsq_ref(jnp.asarray(x), jnp.asarray(y)))
        np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-4)

    def test_gram_matches_dense(self):
        rng = np.random.default_rng(5)
        x = rng.normal(size=(512, 8)).astype(np.float32)
        y = rng.normal(size=(512,)).astype(np.float32)
        xtx, xty = lsq.gram(jnp.asarray(x), jnp.asarray(y))
        np.testing.assert_allclose(np.asarray(xtx), x.T @ x, rtol=1e-4, atol=1e-3)
        np.testing.assert_allclose(np.asarray(xty), x.T @ y, rtol=1e-4, atol=1e-3)

    def test_zero_padding_invariance(self):
        """Zero rows contribute nothing: padded fit == unpadded fit."""
        rng = np.random.default_rng(6)
        x = rng.normal(size=(512, 8)).astype(np.float32)
        y = rng.normal(size=(512,)).astype(np.float32)
        xp = np.zeros((1024, 8), np.float32)
        yp = np.zeros((1024,), np.float32)
        xp[:512], yp[:512] = x, y
        a = np.asarray(lsq.lstsq(jnp.asarray(x), jnp.asarray(y)))
        b = np.asarray(lsq.lstsq(jnp.asarray(xp), jnp.asarray(yp)))
        np.testing.assert_allclose(a, b, rtol=1e-3, atol=1e-4)
