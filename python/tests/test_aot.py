"""AOT pipeline consistency: manifest shapes, artifact determinism, and
the DESIGN.md §Perf VMEM estimates."""

import json
import os

import jax
import pytest

from compile import aot, model
from compile.kernels import batch_predict as bp
from compile.kernels import mlp as mlpk


class TestVmemEstimates:
    """Static VMEM footprints quoted in EXPERIMENTS.md §Perf."""

    def test_mlp_fits_vmem(self):
        bytes_ = mlpk.vmem_bytes()
        assert bytes_ < 16 * 1024 * 1024, "must fit a 16MB VMEM"
        # And the quoted order of magnitude (~230 KB).
        assert 100_000 < bytes_ < 400_000

    def test_batch_predict_fits_vmem(self):
        bytes_ = bp.vmem_bytes()
        assert bytes_ < 16 * 1024 * 1024
        assert 20_000 < bytes_ < 100_000

    def test_footprint_scales_with_tile(self):
        assert mlpk.vmem_bytes(batch_tile=256) > mlpk.vmem_bytes(batch_tile=128)
        assert bp.vmem_bytes(tile=2048) > bp.vmem_bytes(tile=1024)


class TestAotDeterminism:
    def test_hlo_text_is_deterministic(self):
        name, fn, specs = aot.entries()[0]
        a = aot.to_hlo_text(jax.jit(fn).lower(*specs))
        b = aot.to_hlo_text(jax.jit(fn).lower(*specs))
        assert a == b, name

    def test_params_init_deterministic(self):
        a = model.init_params(seed=0)
        b = model.init_params(seed=0)
        for x, y in zip(a, b):
            assert (x == y).all()
        c = model.init_params(seed=1)
        assert not (a[0] == c[0]).all()


@pytest.mark.skipif(
    not os.path.exists(os.path.join(os.path.dirname(__file__), "../../artifacts/manifest.json")),
    reason="run `make artifacts` first",
)
class TestManifestConsistency:
    def _manifest(self):
        path = os.path.join(os.path.dirname(__file__), "../../artifacts/manifest.json")
        with open(path) as fh:
            return json.load(fh)

    def test_manifest_matches_entries(self):
        m = self._manifest()
        names = {e[0] for e in aot.entries()}
        assert set(m["artifacts"].keys()) == names

    def test_manifest_dims_match_model(self):
        m = self._manifest()
        assert m["feature_dim"] == model.FEATURE_DIM
        assert m["hidden_dim"] == model.HIDDEN_DIM
        assert m["max_kernels"] == bp.MAX_KERNELS

    def test_every_artifact_file_exists_and_is_hlo(self):
        m = self._manifest()
        base = os.path.join(os.path.dirname(__file__), "../../artifacts")
        for name, entry in m["artifacts"].items():
            path = os.path.join(base, entry["file"])
            assert os.path.exists(path), name
            with open(path) as fh:
                assert fh.read(9) == "HloModule", name
