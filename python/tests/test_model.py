"""L2 graph tests: train step learns, shapes are stable, AOT entries lower."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile import aot, model
from compile.kernels import ref


def _synthetic_batch(b, seed=0):
    """Features + ground-truth latency from a hidden utilization function."""
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(b, model.FEATURE_DIM)).astype(np.float32)
    true_util = 1.0 / (1.0 + np.exp(-(0.8 * x[:, 0] - 0.5 * x[:, 1])))
    true_util = np.clip(true_util, 0.05, 0.99).astype(np.float32)
    scale = rng.uniform(1e-4, 1e-2, size=(b,)).astype(np.float32)
    y_lat = scale / true_util
    return x, scale, y_lat


class TestTrainStep:
    def _init_state(self):
        params = model.init_params(seed=0)
        zeros = tuple(jnp.zeros_like(p) for p in params)
        return params, zeros, zeros, jnp.asarray(0.0, jnp.float32)

    def test_loss_decreases(self):
        params, m, v, step = self._init_state()
        x, scale, y = _synthetic_batch(512, seed=1)
        lr = jnp.asarray(3e-3, jnp.float32)
        first_loss = None
        for i in range(60):
            out = model.neusight_train_step(
                *params, *m, *v, step, x, scale, y, lr
            )
            params, m, v, step, loss = (
                tuple(out[0:6]), tuple(out[6:12]), tuple(out[12:18]),
                out[18], out[19],
            )
            if first_loss is None:
                first_loss = float(loss)
        assert float(loss) < first_loss * 0.7, (first_loss, float(loss))

    def test_step_counter_increments(self):
        params, m, v, step = self._init_state()
        x, scale, y = _synthetic_batch(512, seed=2)
        out = model.neusight_train_step(
            *params, *m, *v, step, x, scale, y, jnp.float32(1e-3)
        )
        assert float(out[18]) == 1.0

    def test_param_shapes_preserved(self):
        params, m, v, step = self._init_state()
        x, scale, y = _synthetic_batch(512, seed=3)
        out = model.neusight_train_step(
            *params, *m, *v, step, x, scale, y, jnp.float32(1e-3)
        )
        for p, s in zip(out[0:6], model.PARAM_SHAPES):
            assert p.shape == s

    def test_loss_is_finite_on_extreme_targets(self):
        params, m, v, step = self._init_state()
        x, scale, y = _synthetic_batch(512, seed=4)
        y = y * 1e6  # wildly mis-scaled targets must not produce NaN
        out = model.neusight_train_step(
            *params, *m, *v, step, x, scale, y, jnp.float32(1e-3)
        )
        assert np.isfinite(float(out[19]))


class TestLatencyHead:
    def test_latency_inverse_in_util(self):
        util = jnp.asarray([[0.25], [0.5], [1.0]], jnp.float32)
        scale = jnp.asarray([1.0, 1.0, 1.0], jnp.float32)
        lat = model._latency_from_util(util, scale)
        np.testing.assert_allclose(lat, [4.0, 2.0, 1.0], rtol=1e-6)

    def test_smape_symmetric(self):
        a = jnp.asarray([1.0, 2.0], jnp.float32)
        b = jnp.asarray([2.0, 1.0], jnp.float32)
        assert float(model._smape(a, b)) == pytest.approx(
            float(model._smape(b, a))
        )

    def test_smape_zero_on_exact(self):
        a = jnp.asarray([3.0, 5.0], jnp.float32)
        assert float(model._smape(a, a)) == 0.0


class TestAotEntries:
    """Each AOT entry must lower to non-trivial HLO text."""

    @pytest.mark.parametrize("name,fn,specs", aot.entries(),
                             ids=[e[0] for e in aot.entries()])
    def test_lowers_to_hlo_text(self, name, fn, specs):
        lowered = jax.jit(fn).lower(*specs)
        text = aot.to_hlo_text(lowered)
        assert text.startswith("HloModule"), name
        assert "ROOT" in text and len(text) > 200

    def test_entry_names_unique(self):
        names = [e[0] for e in aot.entries()]
        assert len(names) == len(set(names))

    def test_infer_entry_executes(self):
        params = model.init_params(seed=0)
        x = jnp.zeros((128, model.FEATURE_DIM), jnp.float32)
        (out,) = model.neusight_infer(x, *params)
        assert out.shape == (128, 1)
        # Zero input → sigmoid of the bias path; must be strictly in (0,1).
        assert 0.0 < float(out[0, 0]) < 1.0
