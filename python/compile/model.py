"""L2 JAX graphs: NeuSight MLP training + the inference/prediction entries.

Everything here is build-time Python. compile.aot lowers these functions to
HLO text once; the Rust coordinator loads and executes the artifacts via
PJRT with no Python on the request path.

The forward used *inside the train step* is the pure-jnp oracle
(ref.mlp_forward_ref) because interpret-mode pallas_call has no VJP; the
inference entry uses the fused Pallas kernel (kernels.mlp). pytest asserts
the two are allclose, so trained parameters transfer exactly.
"""

import jax
import jax.numpy as jnp

from .kernels import batch_predict as bp
from .kernels import lstsq as lsq
from .kernels import mlp as mlpk
from .kernels import ref

# NeuSight MLP dimensions, fixed at AOT time (the Rust side pads batches).
FEATURE_DIM = 16
HIDDEN_DIM = 128

ADAM_B1 = 0.9
ADAM_B2 = 0.999
ADAM_EPS = 1e-8

PARAM_SHAPES = (
    (FEATURE_DIM, HIDDEN_DIM),  # w1
    (HIDDEN_DIM,),              # b1
    (HIDDEN_DIM, HIDDEN_DIM),   # w2
    (HIDDEN_DIM,),              # b2
    (HIDDEN_DIM, 1),            # w3
    (1,),                       # b3
)


def init_params(seed=0):
    """He-initialized MLP parameters as a flat tuple (w1,b1,w2,b2,w3,b3)."""
    key = jax.random.PRNGKey(seed)
    params = []
    for shape in PARAM_SHAPES:
        key, sub = jax.random.split(key)
        if len(shape) == 2:
            fan_in = shape[0]
            params.append(
                jax.random.normal(sub, shape, jnp.float32)
                * jnp.sqrt(2.0 / fan_in)
            )
        else:
            params.append(jnp.zeros(shape, jnp.float32))
    return tuple(params)


def neusight_infer(x, w1, b1, w2, b2, w3, b3):
    """Inference entry: fused Pallas MLP → (B, 1) utilization."""
    return (mlpk.mlp_forward(x, w1, b1, w2, b2, w3, b3),)


def _latency_from_util(util, scale):
    """NeuSight latency head: wave work-time / predicted utilization.

    scale is the per-sample 'work at 100% utilization' time; dividing by the
    MLP's (0,1) utilization yields predicted latency. Clamped away from 0
    for numerical safety.
    """
    return scale / jnp.maximum(util[:, 0], 1e-4)


def _smape(pred, target):
    """Symmetric mean absolute percentage error — the loss the paper calls
    out for its small-latency imbalance (§IV-B); keeping it faithful keeps
    the baseline's documented failure mode."""
    return jnp.mean(2.0 * jnp.abs(pred - target) / (jnp.abs(pred) + jnp.abs(target) + 1e-12))


def neusight_loss(params, x, scale, y_lat):
    util = ref.mlp_forward_ref(x, *params)
    return _smape(_latency_from_util(util, scale), y_lat)


def neusight_train_step(*args):
    """One Adam step. Flat signature for AOT:

    args = (w1,b1,w2,b2,w3,b3, m1..m6, v1..v6, step, x, scale, y_lat, lr)
    returns (w1',...,b3', m1'..m6', v1'..v6', step+1, loss) — 20 tensors.
    """
    params = tuple(args[0:6])
    m = tuple(args[6:12])
    v = tuple(args[12:18])
    step, x, scale, y_lat, lr = args[18:]

    loss, grads = jax.value_and_grad(neusight_loss)(params, x, scale, y_lat)
    step = step + 1.0
    bc1 = 1.0 - ADAM_B1**step
    bc2 = 1.0 - ADAM_B2**step
    new_p, new_m, new_v = [], [], []
    for p, g, mi, vi in zip(params, grads, m, v):
        mi = ADAM_B1 * mi + (1.0 - ADAM_B1) * g
        vi = ADAM_B2 * vi + (1.0 - ADAM_B2) * g * g
        update = lr * (mi / bc1) / (jnp.sqrt(vi / bc2) + ADAM_EPS)
        new_p.append(p - update)
        new_m.append(mi)
        new_v.append(vi)
    return (*new_p, *new_m, *new_v, step, loss)


def pm2lat_batch_predict(table, base_dur, k_vals, kernel_ids, scale):
    """Inference entry: Pallas batched Eq. 1/2 interpolation."""
    return (bp.batch_predict(table, base_dur, k_vals, kernel_ids, scale),)


def pm2lat_gram(x, y):
    """Fit entry: Pallas Gram accumulation → (XᵀX, Xᵀy).

    The final (P, P) solve happens in Rust (Cholesky): `jnp.linalg.solve`
    lowers to a TYPED_FFI LAPACK custom-call that xla_extension 0.5.1
    cannot execute, so the artifact stops at the Gram products.
    """
    return lsq.gram(x, y)
