"""L1 Pallas kernel: NeuSight's utilization MLP forward, fused.

The baseline (NeuSight, ASPLOS'25) predicts per-wave GPU utilization with a
small MLP; at NAS-preprocessing scale this forward is *the* baseline hot
path (6.5 ms/prediction in the paper). We implement it as one fused Pallas
kernel: both GEMMs, both bias adds, both ReLUs and the sigmoid head execute
per block-row of the batch without leaving VMEM.

Hardware adaptation (DESIGN.md §8): the CUDA formulation would stage tiles
through shared memory per threadblock; here BlockSpec streams (TILE_B, F)
row-blocks of X HBM→VMEM while the weights (F×H + H×H + H×1, ≲130 KB for
H=128) stay VMEM-resident across the whole grid — the MXU sees back-to-back
(TILE_B,128)x(128,128) matmuls, its native shape.

interpret=True always: CPU PJRT cannot execute Mosaic custom-calls.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU-native tile: 128 rows of batch per grid step, hidden width 128.
TILE_B = 128
HIDDEN = 128


def _mlp_kernel(x_ref, w1_ref, b1_ref, w2_ref, b2_ref, w3_ref, b3_ref, o_ref):
    """One block-row of the fused MLP. All operands already in VMEM."""
    x = x_ref[...]  # (TILE_B, F)
    h1 = jnp.maximum(
        jnp.dot(x, w1_ref[...], preferred_element_type=jnp.float32)
        + b1_ref[...],
        0.0,
    )
    h2 = jnp.maximum(
        jnp.dot(h1, w2_ref[...], preferred_element_type=jnp.float32)
        + b2_ref[...],
        0.0,
    )
    logits = (
        jnp.dot(h2, w3_ref[...], preferred_element_type=jnp.float32)
        + b3_ref[...]
    )
    o_ref[...] = jnp.reciprocal(1.0 + jnp.exp(-logits))


@functools.partial(jax.jit, static_argnames=())
def mlp_forward(x, w1, b1, w2, b2, w3, b3):
    """Fused MLP forward via pallas_call.

    x: (B, F) with B a multiple of TILE_B (the L3 caller pads); returns
    (B, 1) utilization in (0, 1). Weights are broadcast to every grid step
    (index_map pins them to block 0), so they are fetched once.
    """
    b, f = x.shape
    h = w1.shape[1]
    assert b % TILE_B == 0, f"batch {b} must be a multiple of {TILE_B}"
    grid = (b // TILE_B,)
    return pl.pallas_call(
        _mlp_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((TILE_B, f), lambda i: (i, 0)),
            pl.BlockSpec((f, h), lambda i: (0, 0)),
            pl.BlockSpec((h,), lambda i: (0,)),
            pl.BlockSpec((h, h), lambda i: (0, 0)),
            pl.BlockSpec((h,), lambda i: (0,)),
            pl.BlockSpec((h, 1), lambda i: (0, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((TILE_B, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, 1), jnp.float32),
        interpret=True,
    )(x, w1, b1, w2, b2, w3, b3)


def vmem_bytes(batch_tile=TILE_B, f=16, h=HIDDEN):
    """Static VMEM footprint estimate for DESIGN.md §Perf (bytes).

    x tile + all weights + intermediates, f32.
    """
    tile = batch_tile * f
    weights = f * h + h + h * h + h + h + 1
    inter = batch_tile * h * 2 + batch_tile  # h1, h2, out
    return 4 * (tile + weights + inter)
