"""L1 Pallas kernel: PM2Lat batched interpolation (Eq. 1 + Eq. 2).

PM2Lat's NAS-preprocessing hot path: given a profiled throughput table
(one row per GEMM kernel implementation, columns = the power-of-two K grid)
and a batch of query configs, predict every latency in one shot.

The grid index needs no search: the K grid is powers of two, so
idx = floor(log2(K/32)) — pure VPU arithmetic, branch-free and lockstep
across lanes. This mirrors the paper's SIMT observation: with a fixed grid
the per-query work is identical, so a vector unit processes queries with
zero divergence.

Hardware adaptation (DESIGN.md §8): a CUDA version would be a 1-D thread
grid with one query per thread and the table in L2; here queries stream
through VMEM in (TILE,)-lane blocks while the (≤128 x 9) table and base
durations stay VMEM-resident for the whole launch.

interpret=True always: CPU PJRT cannot execute Mosaic custom-calls.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import K_GRID_MAX, K_GRID_MIN, N_K_POINTS

TILE = 1024  # queries per grid step; multiple of the (8,128) VPU lane tile
MAX_KERNELS = 128  # table rows (BF16 needs 96; padded to a power of two)


def _predict_kernel(table_ref, base_ref, k_ref, kid_ref, scale_ref, o_ref):
    table = table_ref[...]  # (MAX_KERNELS, N_K_POINTS)
    base = base_ref[...]  # (MAX_KERNELS,)
    k = jnp.clip(k_ref[...], K_GRID_MIN, K_GRID_MAX)  # (TILE,)
    kid = kid_ref[...]
    pos = jnp.log2(k / K_GRID_MIN)
    idx = jnp.clip(jnp.floor(pos).astype(jnp.int32), 0, N_K_POINTS - 2)
    k1 = K_GRID_MIN * jnp.exp2(idx.astype(jnp.float32))
    # Flattened gather: row-major (kid, idx) — one take instead of a 2-D
    # gather, which keeps the interpret path (and a future Mosaic lowering)
    # to plain dynamic-slice machinery.
    flat = table.reshape(-1)
    base_off = kid * N_K_POINTS + idx
    t1 = jnp.take(flat, base_off)
    t3 = jnp.take(flat, base_off + 1)
    org_thr = jnp.take(flat, kid * N_K_POINTS + (N_K_POINTS - 1))
    new_thr = t1 + (k - k1) / k1 * (t3 - t1)  # (K3 - K1) == k1
    org_dur = jnp.take(base, kid)
    o_ref[...] = org_dur * (k / K_GRID_MAX) * (org_thr / new_thr) * scale_ref[...]


@functools.partial(jax.jit, static_argnames=())
def batch_predict(table, base_dur, k_vals, kernel_ids, scale):
    """Batched Eq. 1/2 evaluation via pallas_call.

    table: (MAX_KERNELS, N_K_POINTS) f32; base_dur: (MAX_KERNELS,) f32;
    k_vals/scale: (B,) f32; kernel_ids: (B,) i32; B multiple of TILE.
    Returns (B,) f32 predicted durations.
    """
    (b,) = k_vals.shape
    nk, npts = table.shape
    assert b % TILE == 0, f"batch {b} must be a multiple of {TILE}"
    assert npts == N_K_POINTS
    grid = (b // TILE,)
    return pl.pallas_call(
        _predict_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((nk, npts), lambda i: (0, 0)),
            pl.BlockSpec((nk,), lambda i: (0,)),
            pl.BlockSpec((TILE,), lambda i: (i,)),
            pl.BlockSpec((TILE,), lambda i: (i,)),
            pl.BlockSpec((TILE,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((TILE,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((b,), jnp.float32),
        interpret=True,
    )(table, base_dur, k_vals, kernel_ids, scale)


def vmem_bytes(tile=TILE, nk=MAX_KERNELS, npts=N_K_POINTS):
    """Static VMEM footprint estimate (bytes): table + base + 4 lane vecs."""
    return 4 * (nk * npts + nk + 4 * tile)
