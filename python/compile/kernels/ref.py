"""Pure-jnp oracles for the L1 Pallas kernels.

Every Pallas kernel in this package has a reference implementation here;
pytest (python/tests/) asserts allclose between kernel and oracle across a
hypothesis-driven sweep of shapes. These oracles are also what the L2 train
step uses for differentiable forwards (pallas_call has no registered VJP in
interpret mode), so the pytest equivalence is what guarantees that params
trained through the oracle transfer to the Pallas inference path.
"""

import jax.numpy as jnp

# Power-of-two K grid used by PM2Lat's throughput tables (paper §III-C:
# "powers-of-two values of K (e.g., 32, 64, 128, 256, ..., 8192)").
K_GRID_MIN = 32.0
K_GRID_MAX = 8192.0
N_K_POINTS = 9  # 32, 64, 128, 256, 512, 1024, 2048, 4096, 8192


def mlp_forward_ref(x, w1, b1, w2, b2, w3, b3):
    """NeuSight utilization MLP: 2 ReLU hidden layers + sigmoid head.

    x: (B, F); w1: (F, H); w2: (H, H); w3: (H, 1). Returns (B, 1) in (0, 1).
    """
    h1 = jnp.maximum(x @ w1 + b1, 0.0)
    h2 = jnp.maximum(h1 @ w2 + b2, 0.0)
    return jnp.reciprocal(1.0 + jnp.exp(-(h2 @ w3 + b3)))


def batch_predict_ref(table, base_dur, k_vals, kernel_ids, scale):
    """PM2Lat Eq. (1)+(2): interpolated-throughput latency prediction.

    table:      (n_kernels, N_K_POINTS) throughput at the power-of-two grid.
    base_dur:   (n_kernels,) measured duration at K = 8192 ("orgDur").
    k_vals:     (B,) query K dimension (float32, >= 1).
    kernel_ids: (B,) int32 row index into table / base_dur.
    scale:      (B,) wave/tile scaling factor for the query's (M, N) vs the
                profiled base shape (computed by the Rust caller).

    newThrPut = ThrPut1 + (K - K1)/(K3 - K1) * (ThrPut3 - ThrPut1)   (Eq. 2)
    newDur    = orgDur * (newK / 8192) * (orgThrPut / newThrPut)     (Eq. 1)
    """
    k = jnp.clip(k_vals.astype(jnp.float32), K_GRID_MIN, K_GRID_MAX)
    # Grid index: log2(k/32) in [0, 8]; interpolate between idx and idx+1.
    pos = jnp.log2(k / K_GRID_MIN)
    idx = jnp.clip(jnp.floor(pos).astype(jnp.int32), 0, N_K_POINTS - 2)
    k1 = K_GRID_MIN * jnp.exp2(idx.astype(jnp.float32))
    k3 = 2.0 * k1
    rows = jnp.take(table, kernel_ids, axis=0)  # (B, N_K_POINTS)
    t1 = jnp.take_along_axis(rows, idx[:, None], axis=1)[:, 0]
    t3 = jnp.take_along_axis(rows, (idx + 1)[:, None], axis=1)[:, 0]
    new_thr = t1 + (k - k1) / (k3 - k1) * (t3 - t1)
    org_thr = rows[:, N_K_POINTS - 1]
    org_dur = jnp.take(base_dur, kernel_ids)
    return org_dur * (k / K_GRID_MAX) * (org_thr / new_thr) * scale


def lstsq_ref(x, y, ridge=1e-6):
    """Ridge-regularized least squares via normal equations.

    x: (N, P); y: (N,). Returns (P,) coefficients. PM2Lat's utility-layer
    latency regression (paper §III-C) is exactly this fit over NCU-style
    proxy metrics.
    """
    xtx = x.T @ x + ridge * jnp.eye(x.shape[1], dtype=x.dtype)
    xty = x.T @ y
    return jnp.linalg.solve(xtx, xty)
