"""L1 Pallas kernels (build-time only; lowered to HLO by compile.aot)."""

from . import batch_predict, lstsq, mlp, ref  # noqa: F401
