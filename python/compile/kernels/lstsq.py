"""L1 Pallas kernel: normal-equations accumulation for the utility-layer fit.

PM2Lat fits utility-layer latency with linear regression over NCU-style
proxy metrics (paper §III-C). The fit itself is tiny (P ≈ 8 features), but
the design matrix can be long (one row per profiled sample), so the hot part
is the XᵀX / Xᵀy reduction. This kernel tiles X along N and accumulates both
Gram products in VMEM scratch; the (P, P) solve happens in the L2 graph.

Hardware adaptation: a CUDA implementation would use a grid-stride reduction
with atomics or a two-pass tree; on TPU the natural shape is a sequential
grid walk with a VMEM accumulator — grid step i multiplies a (TILE_N, P)
row-block on the MXU and adds into the resident (P, P) block.

interpret=True always: CPU PJRT cannot execute Mosaic custom-calls.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_N = 256


def _gram_kernel(x_ref, y_ref, xtx_ref, xty_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        xtx_ref[...] = jnp.zeros_like(xtx_ref)
        xty_ref[...] = jnp.zeros_like(xty_ref)

    x = x_ref[...]  # (TILE_N, P)
    y = y_ref[...]  # (TILE_N,)
    xtx_ref[...] += jnp.dot(x.T, x, preferred_element_type=jnp.float32)
    xty_ref[...] += jnp.dot(x.T, y, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=())
def gram(x, y):
    """Accumulate (XᵀX, Xᵀy) over row tiles of X.

    x: (N, P) with N a multiple of TILE_N (caller zero-pads rows — zero rows
    contribute nothing to either product); y: (N,).
    """
    n, p = x.shape
    assert n % TILE_N == 0, f"N {n} must be a multiple of {TILE_N}"
    grid = (n // TILE_N,)
    return pl.pallas_call(
        _gram_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((TILE_N, p), lambda i: (i, 0)),
            pl.BlockSpec((TILE_N,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((p, p), lambda i: (0, 0)),
            pl.BlockSpec((p,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((p, p), jnp.float32),
            jax.ShapeDtypeStruct((p,), jnp.float32),
        ],
        interpret=True,
    )(x, y)


def lstsq(x, y, ridge=1e-6):
    """Full ridge solve: Pallas Gram accumulation + jnp solve (L2 graph)."""
    xtx, xty = gram(x, y)
    p = x.shape[1]
    return jnp.linalg.solve(xtx + ridge * jnp.eye(p, dtype=x.dtype), xty)
