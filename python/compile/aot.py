"""AOT lowering: JAX/Pallas entries → HLO *text* artifacts for the Rust side.

HLO text (not serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(the version behind the published `xla` 0.1.6 crate) rejects; the text
parser reassigns ids and round-trips cleanly.

Usage:  cd python && python -m compile.aot --out-dir ../artifacts
Produces one .hlo.txt per entry plus manifest.json describing shapes, the
initial MLP parameters (params_init.json) so Rust training starts from the
same initialization, and is idempotent (the Makefile skips it when inputs
are unchanged).
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import batch_predict as bp
from .kernels.ref import N_K_POINTS

F32 = jnp.float32
I32 = jnp.int32


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (return_tuple=True so the Rust
    side always unwraps a tuple, regardless of arity)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(shape, dtype)


def entries():
    """(name, fn, arg_specs) for every artifact."""
    f, h = model.FEATURE_DIM, model.HIDDEN_DIM
    param_specs = [_spec(s) for s in model.PARAM_SHAPES]
    out = []

    for b in (128, 1024):
        out.append(
            (
                f"neusight_infer_b{b}",
                model.neusight_infer,
                [_spec((b, f))] + param_specs,
            )
        )

    bt = 512
    train_specs = (
        param_specs  # params
        + [_spec(s) for s in model.PARAM_SHAPES]  # m
        + [_spec(s) for s in model.PARAM_SHAPES]  # v
        + [_spec(()), _spec((bt, f)), _spec((bt,)), _spec((bt,)), _spec(())]
    )
    out.append((f"neusight_train_b{bt}", model.neusight_train_step, train_specs))

    for b in (1024, 4096):
        out.append(
            (
                f"pm2lat_batch_predict_b{b}",
                model.pm2lat_batch_predict,
                [
                    _spec((bp.MAX_KERNELS, N_K_POINTS)),
                    _spec((bp.MAX_KERNELS,)),
                    _spec((b,)),
                    _spec((b,), I32),
                    _spec((b,)),
                ],
            )
        )

    n, p = 4096, 8
    out.append(
        (f"pm2lat_gram_n{n}_p{p}", model.pm2lat_gram, [_spec((n, p)), _spec((n,))])
    )
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {"feature_dim": model.FEATURE_DIM, "hidden_dim": model.HIDDEN_DIM,
                "max_kernels": bp.MAX_KERNELS, "n_k_points": N_K_POINTS,
                "artifacts": {}}
    for name, fn, specs in entries():
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as fh:
            fh.write(text)
        manifest["artifacts"][name] = {
            "file": f"{name}.hlo.txt",
            "args": [[list(s.shape), str(s.dtype)] for s in specs],
        }
        print(f"wrote {path} ({len(text)} chars, {len(specs)} args)")

    # Initial MLP parameters: Rust starts Adam from this exact init.
    params = model.init_params(seed=0)
    pjson = {
        f"p{i}": {"shape": list(p.shape), "data": [float(x) for x in p.reshape(-1)]}
        for i, p in enumerate(params)
    }
    with open(os.path.join(args.out_dir, "params_init.json"), "w") as fh:
        json.dump(pjson, fh)
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as fh:
        json.dump(manifest, fh, indent=1)
    print(f"wrote manifest with {len(manifest['artifacts'])} artifacts")


if __name__ == "__main__":
    main()
