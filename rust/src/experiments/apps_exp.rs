//! §IV-D experiment runners: the partitioning case study and the NAS
//! preprocessing speed comparison.

use std::time::Instant;

use anyhow::Result;

use crate::apps::nas::{self, LatencyCache};
use crate::apps::partition::{self, PartitionResult};
use crate::gpusim::Gpu;
use crate::models::zoo;
use crate::ops::{DType, Op};
use crate::pm2lat::batch::BatchPredictor;

use super::common::Lab;

/// §IV-D1: Qwen3-4B, batch 8, split across 3060M + 5070, 100 requests.
pub fn partition_experiment(lab: &mut Lab) -> Result<String> {
    let cfg = zoo::qwen3_4b();
    let (batch, seq) = (8, 512);
    let mut out = String::from(
        "### §IV-D1: Qwen3-4B partitioning across rtx3060m + rtx5070 (BS=8)\n\n",
    );
    let mut results: Vec<PartitionResult> = Vec::new();
    for predictor in ["PM2Lat", "NeuSight"] {
        let mut d1 = Gpu::by_name("rtx3060m").unwrap();
        let mut d2 = Gpu::by_name("rtx5070").unwrap();
        let result = match predictor {
            "PM2Lat" => {
                let pl1 = lab.pl("rtx3060m", DType::Bf16).unwrap();
                let pl2 = lab.pl("rtx5070", DType::Bf16).unwrap();
                partition::run_experiment(&cfg, batch, seq, &mut d1, &mut d2, "PM2Lat", |gpu, trace| {
                    let pl = if gpu.spec.name == "rtx3060m" { pl1 } else { pl2 };
                    pl.predict_trace(gpu, trace)
                })
            }
            _ => {
                let ns = lab.ns(DType::Bf16);
                partition::run_experiment(&cfg, batch, seq, &mut d1, &mut d2, "NeuSight", |gpu, trace| {
                    ns.predict_trace(&gpu.spec, trace).ok().flatten()
                })
            }
        };
        let Some(r) = result else {
            out.push_str(&format!("{predictor}: no feasible cut\n"));
            continue;
        };
        out.push_str(&format!(
            "- **{}**: cut after block {} | predicted bottleneck {:.0} ms | measured bottleneck {:.0} ms | 100 requests in {:.1} s\n",
            r.predictor,
            r.chosen_cut,
            r.predicted_bottleneck_s * 1e3,
            r.measured.bottleneck_s() * 1e3,
            r.completion_100_s,
        ));
        results.push(r);
    }
    if results.len() == 2 {
        out.push_str(&format!(
            "\nPM2Lat's plan completes 100 requests {:.1} s faster; NeuSight's bottleneck estimate deviates {:.1}% from measurement (PM2Lat: {:.1}%).\n",
            results[1].completion_100_s - results[0].completion_100_s,
            crate::util::stats::rel_err_pct(
                results[1].predicted_bottleneck_s,
                results[1].measured.bottleneck_s()
            ),
            crate::util::stats::rel_err_pct(
                results[0].predicted_bottleneck_s,
                results[0].measured.bottleneck_s()
            ),
        ));
    }
    Ok(out)
}

/// §IV-D2: per-prediction latency of PM2Lat vs NeuSight over NAS configs.
pub fn nas_speed_experiment(lab: &mut Lab, n: usize) -> Result<String> {
    let device = "a100";
    let dtype = DType::F32;
    let configs = nas::sample_configs(n, dtype, 77);
    let gpu = lab.gpu(device);
    let pl = lab.pl(device, dtype).unwrap();
    let table = pl.gemm_table(dtype).unwrap();

    // PM2Lat scalar path (CPU-only analytical prediction).
    let mut cache = LatencyCache::default();
    let pl_report = nas::preprocess_pm2lat(gpu, table, &configs, &mut cache);

    // PM2Lat batched PJRT path (the L1 Pallas kernel evaluating Eq. 1/2).
    let bp = BatchPredictor::new(lab.runtime, table, 4096)?;
    let t0 = Instant::now();
    let mut done = 0;
    for chunk in configs.chunks(4096) {
        let res = bp.predict(gpu, table, chunk)?;
        done += res.iter().flatten().count();
    }
    let pl_batched = nas::SpeedReport::from_run(configs.len(), t0.elapsed().as_secs_f64());

    // NeuSight: per-query prediction (dataset match + MLP via PJRT), the
    // paper's 6.5 ms/prediction regime.
    let ns = lab.ns(dtype);
    let ns_n = n.min(200); // per-query PJRT is slow; sample then scale
    let t0 = Instant::now();
    for op in configs.iter().take(ns_n) {
        let _ = ns.predict(&gpu.spec, &Op::Gemm(*op))?;
    }
    let ns_report = nas::SpeedReport::from_run(ns_n, t0.elapsed().as_secs_f64());

    // NeuSight batched (coordinator-style amortization — our ablation).
    let ops: Vec<Op> = configs.iter().map(|g| Op::Gemm(*g)).collect();
    let t0 = Instant::now();
    let _ = ns.predict_batch(&gpu.spec, &ops)?;
    let ns_batched = nas::SpeedReport::from_run(n, t0.elapsed().as_secs_f64());

    Ok(format!(
        "### §IV-D2: NAS preprocessing speed ({} predictions, device={device})\n\n\
         | path | ms/prediction | full 400M-config space |\n|---|---|---|\n\
         | PM2Lat scalar (CPU) | {:.4} | {:.1} h |\n\
         | PM2Lat batched (Pallas/PJRT b4096) | {:.4} | {:.1} h |\n\
         | NeuSight per-query (PJRT) | {:.3} | {:.0} days |\n\
         | NeuSight batched b1024 | {:.4} | {:.1} h |\n\n\
         cached {} entries; paper reference: PM2Lat 0.045 ms vs NeuSight 6.5 ms → ~5 h vs ~30 days.\n",
        n,
        pl_report.ms_per_prediction,
        pl_report.full_space_hours,
        pl_batched.ms_per_prediction,
        pl_batched.full_space_hours,
        ns_report.ms_per_prediction,
        ns_report.full_space_hours / 24.0,
        ns_batched.ms_per_prediction,
        ns_batched.full_space_hours,
        cache.len().max(done),
    ))
}
