//! Shared experiment infrastructure: predictor construction, sample
//! generators matching the paper's §IV-A evaluation domains, and the
//! result-directory plumbing.

use std::collections::HashMap;
use std::path::PathBuf;

use anyhow::Result;

use crate::gpusim::{all_devices, Gpu};
use crate::neusight::{NeuSight, TrainConfig};
use crate::ops::{DType, GemmOp, Op, UtilKind, UtilOp};
use crate::pm2lat::Pm2Lat;
use crate::profiler::ProfileSpec;
use crate::runtime::Runtime;
use crate::util::prng::Rng;

/// Experiment scale: sample counts per Table II cell etc.
#[derive(Clone, Copy, Debug)]
pub struct Scale {
    pub per_cell: usize,
    pub ns_per_device: usize,
    pub ns_epochs: usize,
    pub model_reps: usize,
    pub custom_per_kind: usize,
}

impl Scale {
    /// Paper-scale: 1000 samples per layer cell.
    pub fn full() -> Scale {
        Scale { per_cell: 1000, ns_per_device: 200, ns_epochs: 60, model_reps: 25, custom_per_kind: 200 }
    }
    /// Bench-scale default (same structure, lighter counts).
    pub fn quick() -> Scale {
        Scale { per_cell: 120, ns_per_device: 120, ns_epochs: 40, model_reps: 5, custom_per_kind: 40 }
    }
    /// From the environment: PM2LAT_FULL=1 selects full scale.
    pub fn from_env() -> Scale {
        if std::env::var("PM2LAT_FULL").map(|v| v == "1").unwrap_or(false) {
            Scale::full()
        } else {
            Scale::quick()
        }
    }
}

/// Where experiment outputs land.
pub fn results_dir() -> PathBuf {
    let dir = crate::runtime::default_artifacts_dir()
        .map(|a| a.parent().unwrap().join("results"))
        .unwrap_or_else(|| PathBuf::from("results"));
    let _ = std::fs::create_dir_all(&dir);
    dir
}

pub fn write_result(name: &str, content: &str) -> Result<PathBuf> {
    let path = results_dir().join(name);
    std::fs::write(&path, content)?;
    Ok(path)
}

/// All predictors, built once and shared across experiments.
pub struct Lab<'rt> {
    pub runtime: &'rt Runtime,
    pub gpus: HashMap<String, Gpu>,
    pub pm2lat: HashMap<(String, DType), Pm2Lat>,
    pub neusight: HashMap<DType, NeuSight<'rt>>,
    pub scale: Scale,
}

impl<'rt> Lab<'rt> {
    /// Build PM2Lat on every (device, dtype) and train NeuSight per dtype.
    pub fn build(runtime: &'rt Runtime, scale: Scale, with_custom: bool) -> Result<Lab<'rt>> {
        let mut gpus = HashMap::new();
        let mut pm2lat = HashMap::new();
        let spec = ProfileSpec::experiment();
        for dev in all_devices() {
            let mut gpu = Gpu::new(dev);
            for dt in [DType::F32, DType::Bf16] {
                if !gpu.spec.supports(dt) {
                    continue;
                }
                let pl = Pm2Lat::build_dtypes(&mut gpu, &spec, &[dt], with_custom);
                gpu.reset();
                pm2lat.insert((gpu.spec.name.to_string(), dt), pl);
            }
            gpus.insert(gpu.spec.name.to_string(), gpu);
        }
        let mut neusight = HashMap::new();
        for dt in [DType::F32, DType::Bf16] {
            let mut train_gpus: Vec<Gpu> =
                all_devices().into_iter().map(Gpu::new).collect();
            let cfg = TrainConfig {
                per_device: scale.ns_per_device,
                epochs: scale.ns_epochs,
                lr: 3e-3,
                seed: 2024 + dt.bytes() as u64,
            };
            let ns = NeuSight::train_on(runtime, &mut train_gpus, dt, cfg, &ProfileSpec::quick())?;
            neusight.insert(dt, ns);
        }
        Ok(Lab { runtime, gpus, pm2lat, neusight, scale })
    }

    pub fn gpu(&self, device: &str) -> &Gpu {
        &self.gpus[device]
    }
    pub fn gpu_mut(&mut self, device: &str) -> &mut Gpu {
        self.gpus.get_mut(device).unwrap()
    }
    pub fn pl(&self, device: &str, dt: DType) -> Option<&Pm2Lat> {
        self.pm2lat.get(&(device.to_string(), dt))
    }
    pub fn ns(&self, dt: DType) -> &NeuSight<'rt> {
        &self.neusight[&dt]
    }
}

/// The Table II layer buckets.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LayerKind {
    Bmm,
    Mm,
    Linear,
    Softmax,
    Vector,
}

impl LayerKind {
    pub fn all() -> [LayerKind; 5] {
        [LayerKind::Bmm, LayerKind::Mm, LayerKind::Linear, LayerKind::Softmax, LayerKind::Vector]
    }
    pub fn name(&self) -> &'static str {
        match self {
            LayerKind::Bmm => "BMM",
            LayerKind::Mm => "MM",
            LayerKind::Linear => "Linear",
            LayerKind::Softmax => "SoftMax",
            LayerKind::Vector => "Vector",
        }
    }

    /// Sample an op from the paper's §IV-A evaluation domain.
    pub fn sample(&self, rng: &mut Rng, dtype: DType) -> Op {
        match self {
            // "For BMM kernels, dimensions are capped at 1024."
            LayerKind::Bmm => Op::Gemm(GemmOp::bmm(
                rng.int_range(1, 64) as usize,
                rng.log_uniform_int(16, 1024) as usize,
                rng.log_uniform_int(16, 1024) as usize,
                rng.log_uniform_int(16, 1024) as usize,
                dtype,
            )),
            // "M and N dimensions go up to 8192, while K is limited to
            // 20000."
            LayerKind::Mm => Op::Gemm(GemmOp::mm(
                rng.log_uniform_int(64, 8192) as usize,
                rng.log_uniform_int(64, 8192) as usize,
                rng.log_uniform_int(32, 20000) as usize,
                dtype,
            )),
            LayerKind::Linear => Op::Gemm(GemmOp::linear(
                rng.log_uniform_int(64, 8192) as usize,
                rng.log_uniform_int(64, 8192) as usize,
                rng.log_uniform_int(32, 20000) as usize,
                dtype,
            )),
            // "Utility layers are tested with batch sizes and input
            // features up to 16384."
            LayerKind::Softmax => {
                let (r, c) = util_shape(rng);
                Op::Util(UtilOp::new(UtilKind::Softmax, r, c, dtype))
            }
            LayerKind::Vector => {
                let kinds = [UtilKind::Relu, UtilKind::Gelu, UtilKind::Add, UtilKind::Mul, UtilKind::Dropout];
                let (r, c) = util_shape(rng);
                Op::Util(UtilOp::new(*rng.choice(&kinds), r, c, dtype))
            }
        }
    }
}

fn util_shape(rng: &mut Rng) -> (usize, usize) {
    loop {
        let r = rng.log_uniform_int(16, 16384) as usize;
        let c = rng.log_uniform_int(16, 16384) as usize;
        if r * c >= 4096 {
            return (r, c);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sample_domains_match_paper() {
        let mut rng = Rng::new(1);
        for _ in 0..200 {
            if let Op::Gemm(g) = LayerKind::Bmm.sample(&mut rng, DType::F32) {
                assert!(g.m <= 1024 && g.n <= 1024 && g.k <= 1024);
                assert!(g.batch >= 1 && g.batch <= 64);
            } else {
                panic!("bmm must be gemm");
            }
            if let Op::Gemm(g) = LayerKind::Mm.sample(&mut rng, DType::F32) {
                assert!(g.m <= 8192 && g.n <= 8192 && g.k <= 20000);
            }
        }
    }

    #[test]
    fn vector_samples_are_elementwise() {
        let mut rng = Rng::new(2);
        for _ in 0..50 {
            if let Op::Util(u) = LayerKind::Vector.sample(&mut rng, DType::F32) {
                assert!(!u.kind.is_reduction());
            } else {
                panic!("vector must be util");
            }
        }
    }

    #[test]
    fn scale_from_env_default_quick() {
        std::env::remove_var("PM2LAT_FULL");
        assert_eq!(Scale::from_env().per_cell, Scale::quick().per_cell);
    }
}
