//! Figure regenerators: Fig 3 (duration vs K), Fig 4 (throughput vs K),
//! Fig 5 (binned max error), Figs 6–9 (error distributions). Output is
//! CSV-in-markdown — the series the paper plots.

use anyhow::Result;

use crate::gpusim::{gemm::GemmConfig, FreqMode, Gpu};
use crate::ops::{DType, GemmOp, Op};
use crate::profiler::{self, ProfileSpec};
use crate::util::stats::{binned_max, Histogram};

use super::common::LayerKind;
use super::tables::SampleRecord;

/// Figs 3 & 4: duration and throughput vs K at a fixed kernel config and
/// wave count, on a locked clock — the §III-C collection experiment.
pub fn figs_3_4(device: &str, kernel_id: usize) -> Result<String> {
    let mut gpu = Gpu::by_name(device).expect("device");
    gpu.set_freq(FreqMode::Fixed(gpu.spec.max_freq_ghz * 0.7));
    let kern = gpu.kernel(DType::F32, kernel_id).expect("kernel").clone();
    let bpsm = crate::gpusim::gemm::blocks_per_sm(&gpu.spec, &kern).unwrap();
    let capacity = bpsm * gpu.spec.sm_count;
    // Fixed 2 complete waves; sweep K densely (powers of two + midpoints).
    let blocks = capacity * 2;
    let mut tm = (blocks as f64).sqrt() as usize;
    while blocks % tm != 0 {
        tm -= 1;
    }
    let (m, n) = (kern.tile_m * tm, kern.tile_n * (blocks / tm));
    let mut out = String::from(
        "### Fig 3 & 4: duration and throughput vs K (fixed waves, fixed config, locked clock)\n\n",
    );
    out.push_str(&format!(
        "device={device} kernel={} tile={}x{}x{} waves=2 m={m} n={n}\n\n",
        kernel_id, kern.tile_m, kern.tile_n, kern.tile_k
    ));
    out.push_str("k,duration_ms,throughput_tflops\n");
    let spec = ProfileSpec::experiment();
    let cfg = GemmConfig { kernel_id, splitk: 1 };
    let mut k = 32usize;
    while k <= 8192 {
        for kk in [k, k + k / 2] {
            if kk > 8192 {
                break;
            }
            let op = GemmOp::mm(m, n, kk, DType::F32);
            let meas =
                profiler::measure_config(&mut gpu, &Op::Gemm(op), Some(cfg), &spec)?;
            out.push_str(&format!(
                "{kk},{:.4},{:.4}\n",
                meas.mean_s * 1e3,
                op.flops() / meas.mean_s / 1e12
            ));
        }
        k *= 2;
    }
    Ok(out)
}

/// Fig 5: worst-case (per-bin max) relative error over the MatMul input
/// domain, 100 bins keyed by log-FLOPs.
pub fn fig5(records: &[SampleRecord]) -> String {
    let mut out = String::from(
        "### Fig 5: maximum relative error of MatMul kernels (100 bins over log-FLOPs)\n\n",
    );
    for dtype in [DType::F32, DType::Bf16] {
        let matmul: Vec<&SampleRecord> = records
            .iter()
            .filter(|r| {
                r.dtype == dtype
                    && matches!(r.layer, LayerKind::Mm | LayerKind::Linear)
                    && r.pl_err.is_finite()
                    && r.ns_err.is_finite()
            })
            .collect();
        if matmul.is_empty() {
            continue;
        }
        let keys: Vec<f64> = matmul.iter().map(|r| r.log_flops).collect();
        let pl: Vec<f64> = matmul.iter().map(|r| r.pl_err).collect();
        let ns: Vec<f64> = matmul.iter().map(|r| r.ns_err).collect();
        let pl_bins = binned_max(&keys, &pl, 100);
        let ns_bins = binned_max(&keys, &ns, 100);
        out.push_str(&format!("\n#### {}\nbin,pl_max_err,ns_max_err\n", dtype.name()));
        for (i, (p, n)) in pl_bins.iter().zip(&ns_bins).enumerate() {
            if p.is_nan() && n.is_nan() {
                continue;
            }
            out.push_str(&format!("{i},{:.1},{:.1}\n", p, n));
        }
        let pl_worst = pl_bins.iter().cloned().filter(|v| !v.is_nan()).fold(0.0, f64::max);
        let ns_worst = ns_bins.iter().cloned().filter(|v| !v.is_nan()).fold(0.0, f64::max);
        out.push_str(&format!(
            "# {} worst-case: PL {:.1}% vs NS {:.1}%\n",
            dtype.name(),
            pl_worst,
            ns_worst
        ));
    }
    out
}

/// Figs 6–9: error distribution histograms for the paper's four panels.
pub fn figs_6_9(records: &[SampleRecord]) -> String {
    let panels = [
        ("Fig 6", "rtx3060m", DType::F32),
        ("Fig 7", "rtx5070", DType::F32),
        ("Fig 8", "l4", DType::Bf16),
        ("Fig 9", "a100", DType::Bf16),
    ];
    let mut out = String::from("### Figs 6–9: error distributions (5%-wide bins, last bin = ≥95%)\n");
    for (fig, device, dtype) in panels {
        let sel: Vec<&SampleRecord> = records
            .iter()
            .filter(|r| r.device == device && r.dtype == dtype)
            .collect();
        if sel.is_empty() {
            continue;
        }
        let mut pl_hist = Histogram::new(0.0, 100.0, 20);
        let mut ns_hist = Histogram::new(0.0, 100.0, 20);
        for r in &sel {
            if r.pl_err.is_finite() {
                pl_hist.add(r.pl_err);
            }
            if r.ns_err.is_finite() {
                ns_hist.add(r.ns_err);
            }
        }
        out.push_str(&format!(
            "\n#### {fig}: {device} ({})\nbin_lo,pl_count,ns_count\n",
            dtype.name()
        ));
        for i in 0..20 {
            out.push_str(&format!(
                "{},{},{}\n",
                i * 5,
                pl_hist.counts[i],
                ns_hist.counts[i]
            ));
        }
        out.push_str(&format!(
            "# below 15%: PL {:.0}% of predictions, NS {:.0}%\n",
            pl_hist.frac_below(15.0) * 100.0,
            ns_hist.frac_below(15.0) * 100.0
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn figs34_series_shapes() {
        let out = figs_3_4("a100", 9).unwrap();
        let lines: Vec<&str> = out
            .lines()
            .filter(|l| l.contains(',') && l.starts_with(|c: char| c.is_ascii_digit()))
            .collect();
        assert!(lines.len() > 10);
        // Duration grows with K; throughput saturates.
        let parse = |l: &str| -> (f64, f64, f64) {
            let p: Vec<f64> = l.split(',').map(|v| v.parse().unwrap()).collect();
            (p[0], p[1], p[2])
        };
        let first = parse(lines[0]);
        let last = parse(lines[lines.len() - 1]);
        assert!(last.1 > first.1 * 10.0, "duration must grow with K");
        assert!(last.2 > first.2, "throughput must grow with K");
    }

    fn fake_records() -> Vec<SampleRecord> {
        (0..500)
            .map(|i| SampleRecord {
                device: "rtx3060m".into(),
                dtype: DType::F32,
                layer: if i % 2 == 0 { LayerKind::Mm } else { LayerKind::Vector },
                log_flops: 10.0 + (i as f64) / 20.0,
                pl_err: (i % 13) as f64,
                ns_err: (i % 37) as f64 * 3.0,
            })
            .collect()
    }

    #[test]
    fn fig5_reports_worst_case_gap() {
        let out = fig5(&fake_records());
        assert!(out.contains("worst-case"));
        assert!(out.contains("fp32"));
    }

    #[test]
    fn figs69_histogram_counts_total() {
        let out = figs_6_9(&fake_records());
        assert!(out.contains("Fig 6"));
        assert!(out.contains("below 15%"));
    }
}
