//! # experiments — regenerate every table and figure of the paper
//!
//! See DESIGN.md §7 for the experiment index. Each runner emits markdown
//! (tables) or CSV series (figures) into `results/`.

pub mod apps_exp;
pub mod common;
pub mod figures;
pub mod tables;

use anyhow::Result;

pub use common::{Lab, Scale};

/// Run the full evaluation suite; returns the combined report.
pub fn run_all(runtime: &crate::runtime::Runtime, scale: Scale) -> Result<String> {
    let mut report = String::new();
    report.push_str(&tables::table1());
    report.push('\n');

    let mut lab = Lab::build(runtime, scale, true)?;

    let t2 = tables::table2(&mut lab)?;
    report.push_str(&t2.markdown);
    report.push('\n');
    common::write_result("table2.md", &t2.markdown)?;

    let f34 = figures::figs_3_4("a100", 9)?;
    common::write_result("figs_3_4.csv", &f34)?;
    report.push_str(&f34);
    report.push('\n');

    let f5 = figures::fig5(&t2.records);
    common::write_result("fig5.csv", &f5)?;
    report.push_str(&f5);
    report.push('\n');

    let f69 = figures::figs_6_9(&t2.records);
    common::write_result("figs_6_9.csv", &f69)?;
    report.push_str(&f69);
    report.push('\n');

    let t45 = tables::table45(&mut lab)?;
    common::write_result("table45.md", &t45)?;
    report.push_str(&t45);
    report.push('\n');

    let t6 = tables::table6(&mut lab)?;
    common::write_result("table6.md", &t6)?;
    report.push_str(&t6);
    report.push('\n');

    let p = apps_exp::partition_experiment(&mut lab)?;
    common::write_result("partition.md", &p)?;
    report.push_str(&p);
    report.push('\n');

    let nas = apps_exp::nas_speed_experiment(&mut lab, 1000)?;
    common::write_result("nas_speed.md", &nas)?;
    report.push_str(&nas);

    common::write_result("full_report.md", &report)?;
    Ok(report)
}
