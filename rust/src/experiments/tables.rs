//! Table regenerators: Table I (specs), Table II (per-layer errors),
//! Tables IV/V (model-level), Table VI (custom kernels).

use anyhow::Result;

use crate::gpusim::all_devices;
use crate::models::{runner, zoo};
use crate::ops::{CustomOp, DType, Op};
use crate::profiler::{self, ProfileSpec};
use crate::util::prng::Rng;
use crate::util::stats::{mean, rel_err_pct, signed_rel_err_pct};
use crate::util::table;

use super::common::{Lab, LayerKind};

/// Table I: specifications of the tested GPUs.
pub fn table1() -> String {
    let devs = all_devices();
    let header: Vec<&str> = std::iter::once("")
        .chain(devs.iter().map(|d| d.name))
        .collect();
    let mut rows = Vec::new();
    let mut row = |label: &str, vals: Vec<String>| {
        let mut r = vec![label.to_string()];
        r.extend(vals);
        rows.push(r);
    };
    row("Max Freq (GHz)", devs.iter().map(|d| format!("{:.3}", d.max_freq_ghz)).collect());
    row("FP32 (TFLOPs)", devs.iter().map(|d| format!("{:.2}", d.fp32_tflops)).collect());
    row("BF16 (TFLOPs)", devs.iter().map(|d| table::cell(d.bf16_tflops, 2)).collect());
    row("DRAM BW (GB/s)", devs.iter().map(|d| format!("{:.0}", d.dram_gbps)).collect());
    row("MEM (GB)", devs.iter().map(|d| format!("{:.0}", d.mem_gb)).collect());
    row("L2 (MB)", devs.iter().map(|d| format!("{:.0}", d.l2_mb)).collect());
    row("SM Count", devs.iter().map(|d| format!("{}", d.sm_count)).collect());
    row("CUDA Cores", devs.iter().map(|d| format!("{}", d.cuda_cores)).collect());
    row("Power (W)", devs.iter().map(|d| format!("{:.0}", d.power_w)).collect());
    format!("### Table I: simulated GPU specifications\n\n{}", table::markdown(&header, &rows))
}

/// One Table II cell outcome.
#[derive(Clone, Debug)]
pub struct Cell {
    pub pl_err: Option<f64>,
    pub ns_err: Option<f64>,
}

/// Per-sample record kept for the figures (5–9).
#[derive(Clone, Debug)]
pub struct SampleRecord {
    pub device: String,
    pub dtype: DType,
    pub layer: LayerKind,
    pub log_flops: f64,
    pub pl_err: f64,
    pub ns_err: f64,
}

pub struct Table2Output {
    pub markdown: String,
    pub records: Vec<SampleRecord>,
}

/// Table II: average relative error per (dtype, layer, device).
pub fn table2(lab: &mut Lab) -> Result<Table2Output> {
    let devices: Vec<String> = {
        let mut v: Vec<String> = lab.gpus.keys().cloned().collect();
        // Table order.
        let order = ["rtx3060m", "t4", "l4", "a100", "rtx5070"];
        v.sort_by_key(|n| order.iter().position(|o| o == n).unwrap_or(9));
        v
    };
    let mut rows = Vec::new();
    let mut records = Vec::new();
    let eval_spec = ProfileSpec { warmup: 2, min_reps: 10, min_total_s: 0.0, max_reps: 20 };
    for dtype in [DType::F32, DType::Bf16] {
        for layer in LayerKind::all() {
            let mut pl_cells = Vec::new();
            let mut ns_cells = Vec::new();
            for device in &devices {
                let supports = lab.gpu(device).spec.supports(dtype);
                if !supports {
                    pl_cells.push(None);
                    ns_cells.push(None);
                    continue;
                }
                let n = lab.scale.per_cell;
                let mut rng = Rng::new(
                    crate::util::prng::hash64(
                        format!("t2/{device}/{dtype}/{}", layer.name()).as_bytes(),
                    ),
                );
                let ops: Vec<Op> =
                    (0..n).map(|_| layer.sample(&mut rng, dtype)).collect();
                // Ground truth: boost-clock measurements, back-to-back
                // (the die heats like a real evaluation pass).
                let mut truths = Vec::with_capacity(n);
                {
                    let gpu = lab.gpu_mut(device);
                    gpu.reset();
                    for op in &ops {
                        truths.push(
                            profiler::measure(gpu, op, &eval_spec)?.mean_s,
                        );
                        // Host-side framework overhead between samples
                        // (tensor allocation, Python dispatch) — the duty
                        // cycle a real per-layer sweep has.
                        gpu.idle(0.03);
                    }
                }
                let gpu = lab.gpu(device);
                let pl = lab.pl(device, dtype).unwrap();
                let ns = lab.ns(dtype);
                let ns_preds = ns.predict_batch(&gpu.spec, &ops)?;
                let mut pl_errs = Vec::with_capacity(n);
                let mut ns_errs = Vec::with_capacity(n);
                for ((op, truth), ns_pred) in
                    ops.iter().zip(&truths).zip(&ns_preds)
                {
                    let pl_pred = pl.predict(gpu, op).unwrap_or(f64::NAN);
                    let ple = rel_err_pct(pl_pred, *truth);
                    let nse = ns_pred
                        .map(|p| rel_err_pct(p, *truth))
                        .unwrap_or(f64::NAN);
                    pl_errs.push(ple);
                    ns_errs.push(nse);
                    let flops = match op {
                        Op::Gemm(g) => g.flops(),
                        Op::Util(u) => u.elems(),
                        Op::Custom(c) => c.flops(),
                        Op::Comm(c) => c.bytes(),
                    };
                    records.push(SampleRecord {
                        device: device.clone(),
                        dtype,
                        layer,
                        log_flops: flops.ln(),
                        pl_err: ple,
                        ns_err: nse,
                    });
                }
                pl_cells.push(Some(mean(&pl_errs)));
                ns_cells.push(Some(mean(&ns_errs)));
            }
            for (tag, cells) in [("NS", ns_cells), ("PL", pl_cells)] {
                let mut row = vec![
                    dtype.name().to_string(),
                    layer.name().to_string(),
                    tag.to_string(),
                ];
                row.extend(cells.iter().map(|c| table::cell(*c, 1)));
                rows.push(row);
            }
        }
    }
    let mut header = vec!["DType", "Layer", ""];
    header.extend(devices.iter().map(|d| d.as_str()));
    let markdown = format!(
        "### Table II: average relative error (%) — PM2Lat (PL) vs NeuSight (NS)\n\n{}",
        table::markdown(&header, &rows)
    );
    Ok(Table2Output { markdown, records })
}

/// Tables IV & V: model-wise signed error per (model, batch, device).
pub fn table45(lab: &mut Lab) -> Result<String> {
    let grid: Vec<(&str, Vec<usize>)> = vec![
        ("gpt2-large", vec![1, 8, 16, 32, 64]),
        ("flan-t5-base", vec![1, 8, 16, 32, 64]),
        ("qwen3-0.6b", vec![1, 8, 16, 32, 64]),
        ("qwen3-4b", vec![1, 8, 16, 32]),
        ("ds-r1-7b", vec![1, 8, 16, 32]),
        ("ds-r1-14b", vec![1, 8, 16]),
    ];
    let devices = ["rtx3060m", "t4", "l4", "a100", "rtx5070"];
    let seq = 512;
    let mut header = vec!["Model".to_string(), "BS".to_string()];
    for d in devices {
        header.push(format!("{d} MeanT(ms)"));
        header.push(format!("{d} PL(%)"));
        header.push(format!("{d} NS(%)"));
    }
    let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut rows = Vec::new();
    for (model_name, batches) in grid {
        let cfg = zoo::by_name(model_name).unwrap();
        for &bs in &batches {
            let mut row = vec![model_name.to_string(), bs.to_string()];
            for device in devices {
                let dtype = cfg.dtype;
                let supports = lab.gpu(device).spec.supports(dtype);
                let fits = lab
                    .gpu(device)
                    .check_memory(cfg.memory_bytes(bs, seq))
                    .is_ok();
                if !supports || !fits {
                    row.extend(["-".to_string(), "-".to_string(), "-".to_string()]);
                    continue;
                }
                let reps = lab.scale.model_reps;
                let run = {
                    let gpu = lab.gpu_mut(device);
                    gpu.reset();
                    runner::run_model(gpu, &cfg, bs, seq, 5.min(reps), reps)
                };
                let Ok(run) = run else {
                    row.extend(["-".to_string(), "-".to_string(), "-".to_string()]);
                    continue;
                };
                let gpu = lab.gpu(device);
                let trace = cfg.trace(bs, seq);
                let pl_pred = lab
                    .pl(device, dtype)
                    .and_then(|pl| pl.predict_trace(gpu, &trace));
                let ns_pred = lab.ns(dtype).predict_trace(&gpu.spec, &trace)?;
                row.push(format!("{:.0}", run.mean_s * 1e3));
                row.push(table::signed_pct(
                    pl_pred.map(|p| signed_rel_err_pct(p, run.mean_s)),
                ));
                row.push(table::signed_pct(
                    ns_pred.map(|p| signed_rel_err_pct(p, run.mean_s)),
                ));
            }
            rows.push(row);
        }
    }
    Ok(format!(
        "### Tables IV & V: model-wise signed error — PM2Lat (PL) vs NeuSight (NS), seq={seq}\n\n{}",
        table::markdown(&header_refs, &rows)
    ))
}

/// Table VI: PM2Lat on custom kernels.
pub fn table6(lab: &mut Lab) -> Result<String> {
    let devices = ["rtx3060m", "t4", "l4", "a100", "rtx5070"];
    let kinds = ["TritonMM", "PL TruthCFG", "TritonVec", "F-Attn", "C-Attn"];
    let eval_spec = ProfileSpec { warmup: 2, min_reps: 10, min_total_s: 0.0, max_reps: 20 };
    let mut rows = Vec::new();
    for kind in kinds {
        let mut row = vec![kind.to_string()];
        for device in devices {
            let dtype = DType::F32;
            let n = lab.scale.custom_per_kind;
            let mut rng = Rng::new(crate::util::prng::hash64(
                format!("t6/{device}/{kind}").as_bytes(),
            ));
            let mut errs = Vec::new();
            for _ in 0..n {
                let op = match kind {
                    "TritonMM" | "PL TruthCFG" => CustomOp::TritonMM {
                        m: rng.log_uniform_int(128, 4096) as usize,
                        n: rng.log_uniform_int(128, 4096) as usize,
                        k: rng.log_uniform_int(64, 8192) as usize,
                        dtype,
                    },
                    "TritonVec" => CustomOp::TritonVec {
                        elems: rng.log_uniform_int(1 << 14, 1 << 26) as usize,
                        dtype,
                    },
                    "F-Attn" => {
                        // Draw order (batch, heads, seq) preserved from
                        // the pre-q/kv vocabulary: same RNG stream, same
                        // evaluation shapes.
                        let batch = rng.int_range(1, 8) as usize;
                        let heads = rng.int_range(8, 32) as usize;
                        let seq = rng.log_uniform_int(128, 4096) as usize;
                        CustomOp::FlashAttn {
                            batch, heads, kv_heads: heads, q_len: seq, kv_len: seq,
                            head_dim: 64, dtype, causal: false,
                        }
                    }
                    _ => {
                        let batch = rng.int_range(1, 8) as usize;
                        let heads = rng.int_range(8, 32) as usize;
                        let seq = rng.log_uniform_int(128, 4096) as usize;
                        CustomOp::CutlassAttn {
                            batch, heads, kv_heads: heads, q_len: seq, kv_len: seq,
                            head_dim: 64, dtype, causal: false,
                        }
                    }
                };
                let supported = crate::gpusim::custom::supported(&lab.gpu(device).spec, &op);
                if !supported {
                    continue;
                }
                let truth = {
                    let gpu = lab.gpu_mut(device);
                    match profiler::measure(gpu, &Op::Custom(op), &eval_spec) {
                        Ok(m) => m.mean_s,
                        Err(_) => continue,
                    }
                };
                let gpu = lab.gpu(device);
                let Some(pl) = lab.pl(device, dtype) else { continue };
                let Some(cm) = pl.custom_model(dtype) else { continue };
                let pred = if kind == "PL TruthCFG" {
                    cm.predict_truth_cfg(gpu, &op)
                } else {
                    cm.predict(gpu, &op)
                };
                if let Some(p) = pred {
                    errs.push(rel_err_pct(p, truth));
                }
            }
            row.push(if errs.is_empty() {
                "-".to_string()
            } else {
                format!("{:.1}", mean(&errs))
            });
        }
        rows.push(row);
    }
    let mut header = vec![""];
    header.extend(devices);
    Ok(format!(
        "### Table VI: PM2Lat error (%) on custom kernels (FP32)\n\n{}",
        table::markdown(&header, &rows)
    ))
}
