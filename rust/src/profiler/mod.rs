//! # profiler — CUPTI/NCU-style measurement collection
//!
//! The paper's collection discipline (§III-C): warm-up repetitions
//! discarded, each config executed ≥25 times with a ≥500 ms total
//! execution floor, averaged. Also exposes the occupancy query (the CUDA
//! occupancy-calculator equivalent) and the boost-clock calibration
//! PM2Lat uses to map locked-clock profiles to boost-clock predictions.
//! Every fitted model in `pm2lat/` — kernel tables, the gemv streaming
//! profile, utility regression, custom-kernel (incl. decode-attention)
//! profiles — consumes only what this module measures.

use crate::gpusim::{gemm, ExecError, FreqMode, Gpu};
use crate::ops::{Counters, DType, GemmOp, Op};
use crate::util::stats;

/// Collection discipline parameters.
#[derive(Clone, Copy, Debug)]
pub struct ProfileSpec {
    pub warmup: usize,
    pub min_reps: usize,
    /// Keep repeating until this much total kernel time has accumulated.
    pub min_total_s: f64,
    /// Hard cap so giant kernels do not profile forever.
    pub max_reps: usize,
}

impl Default for ProfileSpec {
    fn default() -> Self {
        // Paper: "executed at least 25 times with about 500ms as minimum
        // total time of execution ... after a warm-up period".
        ProfileSpec { warmup: 3, min_reps: 25, min_total_s: 0.5, max_reps: 2000 }
    }
}

impl ProfileSpec {
    /// A cheaper discipline for wide sweeps (tests/CI).
    pub fn quick() -> Self {
        ProfileSpec { warmup: 1, min_reps: 5, min_total_s: 0.0, max_reps: 50 }
    }

    /// Middle ground: enough repetitions to suppress noise in collection
    /// without the 500 ms floor (used by accuracy-sensitive tests).
    pub fn medium() -> Self {
        ProfileSpec { warmup: 2, min_reps: 15, min_total_s: 0.0, max_reps: 100 }
    }

    /// The experiment discipline: paper-faithful ≥25 reps with a reduced
    /// total-time floor so whole-table sweeps stay tractable.
    pub fn experiment() -> Self {
        ProfileSpec { warmup: 3, min_reps: 25, min_total_s: 0.02, max_reps: 200 }
    }
}

/// Aggregated measurement of one op under one configuration.
#[derive(Clone, Copy, Debug)]
pub struct Measurement {
    pub mean_s: f64,
    pub std_s: f64,
    pub reps: usize,
    pub counters: Counters,
    pub freq_ghz: f64,
    pub temp_c: f64,
}

/// Measure an op with the library-selected kernel configuration.
pub fn measure(gpu: &mut Gpu, op: &Op, spec: &ProfileSpec) -> Result<Measurement, ExecError> {
    measure_config(gpu, op, None, spec)
}

/// Measure with an explicitly pinned GEMM config (PM2Lat's controlled
/// collection mode).
pub fn measure_config(
    gpu: &mut Gpu,
    op: &Op,
    cfg: Option<gemm::GemmConfig>,
    spec: &ProfileSpec,
) -> Result<Measurement, ExecError> {
    for _ in 0..spec.warmup {
        gpu.exec_config(op, cfg)?;
    }
    let mut durs = Vec::with_capacity(spec.min_reps);
    let mut total = 0.0;
    let mut last = None;
    while durs.len() < spec.min_reps
        || (total < spec.min_total_s && durs.len() < spec.max_reps)
    {
        let s = gpu.exec_config(op, cfg)?;
        total += s.dur_s;
        durs.push(s.dur_s);
        last = Some(s);
    }
    let last = last.expect("min_reps >= 1");
    Ok(Measurement {
        mean_s: stats::mean(&durs),
        std_s: stats::stddev(&durs),
        reps: durs.len(),
        counters: last.counters,
        freq_ghz: last.freq_ghz,
        temp_c: last.temp_c,
    })
}

/// Occupancy query: blocks per SM for a kernel — the public equivalent of
/// the CUDA occupancy calculator (predictors may use this; it reveals
/// nothing about the kernel's internal efficiency).
pub fn occupancy(gpu: &Gpu, dtype: DType, kernel_id: usize) -> Option<usize> {
    let kern = gpu.kernel(dtype, kernel_id)?;
    gemm::blocks_per_sm(&gpu.spec, kern)
}

/// Calibrate the effective boost-to-locked clock speedup: run a sustained
/// compute-bound GEMM at the locked profiling clock, then at boost (hot),
/// and return locked_dur / boost_dur. PM2Lat multiplies its locked-clock
/// profile durations by 1/ratio when predicting boost-clock executions.
pub fn calibrate_boost_ratio(gpu: &mut Gpu, dtype: DType, locked_ghz: f64) -> Option<f64> {
    if !gpu.spec.supports(dtype) {
        return None;
    }
    let op = Op::Gemm(GemmOp::mm(2048, 2048, 4096, dtype));
    let spec = ProfileSpec { warmup: 2, min_reps: 15, min_total_s: 0.3, max_reps: 400 };
    gpu.set_freq(FreqMode::Fixed(locked_ghz));
    let locked = measure_config(gpu, &op, None, &spec).ok()?;
    gpu.set_freq(FreqMode::Boost);
    // Heat the die to the *duty-cycled* steady state an evaluation sweep
    // reaches (kernel bursts separated by host-side framework overhead) —
    // calibrating against a 100%-duty burn would overshoot the thermal
    // state of real measurement passes.
    let burn_until = gpu.clock_s + 3.0;
    while gpu.clock_s < burn_until {
        gpu.exec(&op).ok()?;
        gpu.idle(0.02);
    }
    let boost = measure_config(gpu, &op, None, &spec).ok()?;
    Some(locked.mean_s / boost.mean_s)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::{GemmOp, UtilKind, UtilOp};

    #[test]
    fn respects_min_reps_and_total_time() {
        let mut gpu = Gpu::by_name("a100").unwrap();
        let op = Op::Gemm(GemmOp::mm(256, 256, 256, DType::F32));
        let spec = ProfileSpec { warmup: 2, min_reps: 25, min_total_s: 0.01, max_reps: 2000 };
        let m = measure(&mut gpu, &op, &spec).unwrap();
        assert!(m.reps >= 25);
        assert!(m.reps as f64 * m.mean_s >= 0.0099, "total time floor");
    }

    #[test]
    fn warmup_absorbs_cold_start() {
        // With warm-up, the measured mean should be close to the warm
        // latency; without, the cold first rep inflates it.
        let mut g1 = Gpu::by_name("l4").unwrap();
        let op = Op::Util(UtilOp::new(UtilKind::Gelu, 2048, 2048, DType::F32));
        let with = measure(&mut g1, &op, &ProfileSpec::quick()).unwrap();
        let mut g2 = Gpu::by_name("l4").unwrap();
        let no_warm = ProfileSpec { warmup: 0, min_reps: 5, min_total_s: 0.0, max_reps: 5 };
        let without = measure(&mut g2, &op, &no_warm).unwrap();
        assert!(without.mean_s > with.mean_s, "cold start must inflate mean");
    }

    #[test]
    fn std_small_relative_to_mean() {
        let mut gpu = Gpu::by_name("t4").unwrap();
        let op = Op::Gemm(GemmOp::mm(512, 512, 1024, DType::F32));
        let m = measure(&mut gpu, &op, &ProfileSpec::default()).unwrap();
        assert!(m.std_s / m.mean_s < 0.1, "cv={}", m.std_s / m.mean_s);
    }

    #[test]
    fn occupancy_query_works() {
        let gpu = Gpu::by_name("a100").unwrap();
        let occ = occupancy(&gpu, DType::F32, 0).unwrap();
        assert!(occ >= 1 && occ <= 8);
        assert!(occupancy(&gpu, DType::F32, 999).is_none());
    }

    #[test]
    fn boost_ratio_below_one_or_near_one() {
        // Locked clock is lower than boost → locked is slower → ratio > 1.
        let mut gpu = Gpu::by_name("a100").unwrap();
        let locked = gpu.spec.max_freq_ghz * 0.7;
        let r = calibrate_boost_ratio(&mut gpu, DType::F32, locked).unwrap();
        assert!(r > 1.0 && r < 2.0, "ratio={r}");
    }

    #[test]
    fn boost_ratio_none_for_unsupported() {
        let mut gpu = Gpu::by_name("t4").unwrap();
        assert!(calibrate_boost_ratio(&mut gpu, DType::Bf16, 1.0).is_none());
    }
}
