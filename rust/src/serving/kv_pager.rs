//! Paged KV-cache allocator — vLLM-style block bookkeeping for the
//! serving simulator, with copy-on-write prefix sharing.
//!
//! GPU memory for the KV cache is carved into fixed-size blocks of
//! `block_tokens` tokens each; a request holds a list of blocks that
//! grows as its context grows and is returned wholesale on completion
//! (or preemption). Capacity is derived from the device's HBM minus the
//! model's resident footprint through
//! [`crate::models::TransformerConfig::kv_cache_bytes`], so block-count
//! accounting and byte accounting can never disagree.
//!
//! # Prefix sharing (copy-on-write)
//!
//! Real continuous-batching engines dedupe shared prompt prefixes —
//! system prompts, few-shot templates — so requests carrying the same
//! template reference one physical copy of its KV blocks. The pager
//! models that with *refcounted* physical blocks and a prefix index:
//!
//! * A template is identified by `(prefix_group, prefix_tokens)` on
//!   [`crate::serving::RequestSpec`] — the simulator's stand-in for a
//!   content hash of the token blocks (requests in one group share their
//!   first `prefix_tokens` prompt tokens by construction).
//! * The index maps `(group, prefix_tokens, block index)` to the
//!   physical block holding that slice of the template. The first
//!   request to materialize a prefix block *registers* it on write
//!   ([`KvPager::grow`]); later arrivals *map* the longest registered
//!   run at admission ([`KvPager::map_prefix`]), bumping refcounts
//!   without drawing from the free list — and skipping that much
//!   prefill recompute.
//! * Blocks strictly inside the prefix are append-only history and are
//!   never written again. The one block a holder can write while it is
//!   shared is the partial *boundary* block (`prefix_tokens` not
//!   block-aligned): growing past the prefix writes into it, so the
//!   grow **forks** it copy-on-write while other holders remain, or
//!   retires its registration in place when the writer is the last.
//! * [`KvPager::release`] decrements refcounts; a block returns to the
//!   free list only at refcount zero, so preempting one sharer can
//!   never free another request's prefix.
//!
//! Invariants (enforced with debug assertions after every mutation and
//! exercised by `tests/kv_pager_cow.rs`):
//!
//! * `free + in_use == capacity` after every operation;
//! * Σ logical blocks (over live allocations) == Σ physical · refs;
//! * a request's block count is exactly `ceil(tokens / block_tokens)`;
//! * the free list holds exactly the zero-ref blocks, each once (no
//!   double-free, no orphans);
//! * every registered block is live and the index ↔ per-block tags are
//!   a bijection;
//! * a block registered as template slice `i` sits at context position
//!   `i` of every holder (speculative rollbacks via [`KvPager::truncate`]
//!   drop strictly from the tail and can never reorder a prefix).

use std::collections::HashMap;

/// Default tokens per KV block (vLLM's default page size).
pub const DEFAULT_BLOCK_TOKENS: usize = 16;

/// Static shape of a pager: the block size knob, the block budget, and
/// whether cross-request prefix sharing is live.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct KvPagerConfig {
    pub block_tokens: usize,
    pub capacity_blocks: usize,
    /// Enable copy-on-write prefix sharing. Off, the pager is the plain
    /// private-pages allocator (and every sharing entry point is inert),
    /// so replays are bit-for-bit the pre-sharing behavior.
    pub prefix_share: bool,
}

impl KvPagerConfig {
    /// Size a pager from a device HBM budget: whatever remains after the
    /// model's weights, an activation/workspace reserve and the CUDA
    /// context becomes KV blocks. Clamps to at least one block so a
    /// degenerate budget still constructs (and then preempts constantly —
    /// visible, not silent).
    pub fn for_model(
        cfg: &crate::models::TransformerConfig,
        hbm_bytes: f64,
        block_tokens: usize,
    ) -> KvPagerConfig {
        KvPagerConfig::for_models(&[cfg], hbm_bytes, block_tokens)
    }

    /// Size a pager for several models resident on one device at once —
    /// a speculative draft/target pair keeps *both* weight sets and both
    /// KV caches in HBM, so every model's weights come off the budget
    /// and one logical block carries `block_tokens` context entries in
    /// every resident cache. Sizing for the target alone would
    /// over-promise HBM the moment a draft moves in.
    /// [`KvPagerConfig::for_model`] is exactly `for_models(&[cfg], ..)`.
    pub fn for_models(
        cfgs: &[&crate::models::TransformerConfig],
        hbm_bytes: f64,
        block_tokens: usize,
    ) -> KvPagerConfig {
        assert!(!cfgs.is_empty(), "for_models needs at least one resident model");
        let block_tokens = block_tokens.max(1);
        let bytes_per_block: f64 = cfgs.iter().map(|c| c.kv_cache_bytes(1, block_tokens)).sum();
        // Weights + CUDA context + a workspace reserve proportional to a
        // healthy batch of activations.
        let reserved =
            cfgs.iter().map(|c| c.weight_bytes()).sum::<f64>() + 0.7e9 + 0.05 * hbm_bytes;
        let budget = (hbm_bytes - reserved).max(0.0);
        KvPagerConfig {
            block_tokens,
            capacity_blocks: ((budget / bytes_per_block) as usize).max(1),
            prefix_share: false,
        }
    }

    /// The same geometry with prefix sharing switched on or off.
    pub fn with_prefix_share(mut self, on: bool) -> KvPagerConfig {
        self.prefix_share = on;
        self
    }

    /// Blocks needed to hold `tokens` context entries.
    pub fn blocks_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.block_tokens)
    }

    /// Token capacity if a single request could take every block.
    pub fn capacity_tokens(&self) -> usize {
        self.capacity_blocks * self.block_tokens
    }
}

#[derive(Clone, Debug, PartialEq, Eq, thiserror::Error)]
pub enum PagerError {
    #[error("out of KV blocks: need {need} more, have {free} free")]
    OutOfBlocks { need: usize, free: usize },
    #[error("request {0} holds no allocation")]
    UnknownRequest(usize),
}

/// Index key of one template prefix block: (group, declared prefix
/// tokens, block index). Folding the declared length in keeps templates
/// of different lengths inside one group from aliasing.
type PrefixKey = (u64, usize, usize);

/// A request's relationship to its template: the declared prefix and the
/// clamped number of tokens this request may actually share (`effective
/// = min(declared, caller's cap)` — the simulator caps at `prompt - 1`
/// so at least one prefill token always remains to produce the first
/// output logits).
#[derive(Clone, Copy, Debug)]
struct PrefixShare {
    group: u64,
    declared: usize,
    effective: usize,
}

impl PrefixShare {
    fn key(&self, i: usize) -> PrefixKey {
        (self.group, self.declared, i)
    }

    /// Is block `i` pure template-prefix content for a holder whose
    /// context tops out at `target` tokens after the current grow?
    /// Full blocks inside the effective prefix always are. The partial
    /// boundary block qualifies only when this request carries the whole
    /// declared prefix *and* is not (yet) writing past it — otherwise
    /// the block would mix template and private tokens.
    fn registrable(&self, i: usize, target: usize, block_tokens: usize) -> bool {
        (i + 1) * block_tokens <= self.effective
            || (self.effective == self.declared
                && self.declared % block_tokens != 0
                && i == self.declared / block_tokens
                && target <= self.declared)
    }

    /// Blocks [`KvPager::map_prefix`] may map: the registrable range for
    /// a holder that stays within the declared prefix.
    fn mappable(&self, i: usize, block_tokens: usize) -> bool {
        self.registrable(i, self.declared, block_tokens)
    }

    /// Context tokens materialized once blocks `0..=i` are mapped.
    fn mapped_tokens(&self, i: usize, block_tokens: usize) -> usize {
        ((i + 1) * block_tokens).min(self.effective)
    }
}

/// Per-request allocation: the materialized context length, the actual
/// block ids backing it, and the live prefix relationship (cleared once
/// the request grows past its shared prefix).
#[derive(Clone, Debug, Default)]
struct Alloc {
    tokens: usize,
    blocks: Vec<usize>,
    prefix: Option<PrefixShare>,
}

/// The allocator. Block ids are dense `0..capacity`; the free list is
/// LIFO so recently released blocks are reused first (cache-friendly on
/// real hardware, deterministic here). Physical blocks are refcounted:
/// without sharing every refcount is 0 or 1 and the pager degenerates to
/// the plain private-pages allocator.
#[derive(Clone, Debug)]
pub struct KvPager {
    config: KvPagerConfig,
    free_list: Vec<usize>,
    allocs: HashMap<usize, Alloc>,
    /// Per-physical-block reference count; 0 ⇔ on the free list.
    refs: Vec<u32>,
    /// Physical block → the prefix-index key it is registered under.
    registered: Vec<Option<PrefixKey>>,
    /// Template slice → the physical block holding it.
    prefix_index: HashMap<PrefixKey, usize>,
    /// Σ over live allocations of their block counts (== Σ refs).
    logical: usize,
    peak_in_use: usize,
    peak_logical: usize,
    peak_saved: usize,
    prefix_lookups: u64,
    prefix_hits: u64,
    cow_forks: u64,
}

impl KvPager {
    pub fn new(config: KvPagerConfig) -> KvPager {
        let config = KvPagerConfig {
            block_tokens: config.block_tokens.max(1),
            capacity_blocks: config.capacity_blocks.max(1),
            prefix_share: config.prefix_share,
        };
        KvPager {
            free_list: (0..config.capacity_blocks).rev().collect(),
            allocs: HashMap::new(),
            refs: vec![0; config.capacity_blocks],
            registered: vec![None; config.capacity_blocks],
            prefix_index: HashMap::new(),
            logical: 0,
            peak_in_use: 0,
            peak_logical: 0,
            peak_saved: 0,
            prefix_lookups: 0,
            prefix_hits: 0,
            cow_forks: 0,
            config,
        }
    }

    pub fn config(&self) -> KvPagerConfig {
        self.config
    }

    pub fn capacity_blocks(&self) -> usize {
        self.config.capacity_blocks
    }

    pub fn free_blocks(&self) -> usize {
        self.free_list.len()
    }

    pub fn blocks_in_use(&self) -> usize {
        self.config.capacity_blocks - self.free_list.len()
    }

    /// Σ block counts over live allocations — what the requests would
    /// occupy without sharing. `logical - in_use` is the sharing saving.
    pub fn logical_blocks(&self) -> usize {
        self.logical
    }

    /// High-water mark of `blocks_in_use` over the pager's lifetime.
    pub fn peak_blocks(&self) -> usize {
        self.peak_in_use
    }

    /// High-water mark of [`KvPager::logical_blocks`].
    pub fn peak_logical_blocks(&self) -> usize {
        self.peak_logical
    }

    /// Largest instantaneous `logical - physical` gap — the blocks
    /// sharing saved at the moment it saved the most.
    pub fn peak_blocks_saved(&self) -> usize {
        self.peak_saved
    }

    /// Fraction of blocks currently allocated.
    pub fn occupancy(&self) -> f64 {
        self.blocks_in_use() as f64 / self.config.capacity_blocks as f64
    }

    /// Occupancy the same workload would have without sharing (can
    /// exceed 1.0 — that is the capacity sharing manufactured).
    pub fn effective_occupancy(&self) -> f64 {
        self.logical as f64 / self.config.capacity_blocks as f64
    }

    /// Shareable prefix blocks probed at admission (map-time probes).
    pub fn prefix_lookups(&self) -> u64 {
        self.prefix_lookups
    }

    /// Probes that found a registered block and mapped it.
    pub fn prefix_hits(&self) -> u64 {
        self.prefix_hits
    }

    /// Copy-on-write forks of shared boundary blocks.
    pub fn cow_forks(&self) -> u64 {
        self.cow_forks
    }

    /// Project every pager-owned counter into the unified metrics
    /// schema (`kv.*` keys of [`crate::obs::keys`]) — the one place the
    /// pager's numbers enter a [`crate::obs::MetricsRegistry`], used by
    /// [`crate::obs::ReportBuilder::absorb_pager`] so every simulator
    /// path reports identical KV accounting. `kv.leaked_blocks` is the
    /// *current* allocation: at end of run any non-zero value is a leak.
    /// See `docs/OBSERVABILITY.md` for the operator-facing key table.
    pub fn fill_registry(&self, reg: &mut crate::obs::MetricsRegistry) {
        use crate::obs::keys;
        reg.set(keys::KV_CAPACITY_BLOCKS, self.config.capacity_blocks as u64);
        reg.set(keys::KV_PEAK_BLOCKS, self.peak_in_use as u64);
        reg.set(keys::KV_PEAK_LOGICAL_BLOCKS, self.peak_logical as u64);
        reg.set(keys::KV_BLOCKS_SAVED, self.peak_saved as u64);
        reg.set(keys::KV_LEAKED_BLOCKS, self.blocks_in_use() as u64);
        reg.set(keys::KV_PREFIX_LOOKUPS, self.prefix_lookups);
        reg.set(keys::KV_PREFIX_HITS, self.prefix_hits);
        reg.set(keys::KV_COW_FORKS, self.cow_forks);
    }

    /// Materialized context tokens of a request (0 when unknown).
    pub fn tokens_of(&self, id: usize) -> usize {
        self.allocs.get(&id).map(|a| a.tokens).unwrap_or(0)
    }

    /// Does request `id` hold a live allocation? (Possibly zero blocks:
    /// an admission-time [`KvPager::map_prefix`] with no index hits.)
    pub fn holds(&self, id: usize) -> bool {
        self.allocs.contains_key(&id)
    }

    /// The physical block ids backing request `id`, in context order —
    /// observability for tests (free-list reuse order, sharing).
    pub fn blocks_of(&self, id: usize) -> Option<&[usize]> {
        self.allocs.get(&id).map(|a| a.blocks.as_slice())
    }

    /// Live requests holding an allocation.
    pub fn live_requests(&self) -> usize {
        self.allocs.len()
    }

    /// Would growing request `id` to `tokens` context entries fit?
    pub fn can_grow(&self, id: usize, tokens: usize) -> bool {
        self.physical_need(id, tokens) <= self.free_list.len()
    }

    /// Physical blocks a [`KvPager::grow`] to `tokens` would draw from
    /// the free list: new blocks past the current allocation, plus one
    /// for the copy-on-write fork if this grow crosses the shared-prefix
    /// boundary while peers still reference the boundary block. Shared
    /// blocks a request already maps cost nothing — this is the "account
    /// shared blocks once" admission arithmetic.
    pub fn physical_need(&self, id: usize, tokens: usize) -> usize {
        let want = self.config.blocks_for(tokens);
        match self.allocs.get(&id) {
            None => want,
            Some(a) => {
                want.saturating_sub(a.blocks.len()) + self.pending_fork(a, tokens) as usize
            }
        }
    }

    /// Does growing `a` to `tokens` write into a boundary block other
    /// holders still reference?
    fn pending_fork(&self, a: &Alloc, tokens: usize) -> bool {
        match a.prefix {
            Some(s) if tokens > s.effective => {
                let w = s.effective / self.config.block_tokens;
                w < a.blocks.len()
                    && self.registered[a.blocks[w]].is_some()
                    && self.refs[a.blocks[w]] > 1
            }
            _ => false,
        }
    }

    /// Dry-run of [`KvPager::map_prefix`]: how many context tokens would
    /// a request of template `(group, prefix_tokens)` find registered
    /// right now? Pure — admission policies use it to rank waiters.
    pub fn prefix_hit_tokens(&self, group: u64, prefix_tokens: usize, max_tokens: usize) -> usize {
        let bt = self.config.block_tokens;
        let share =
            PrefixShare { group, declared: prefix_tokens, effective: prefix_tokens.min(max_tokens) };
        let mut tokens = 0usize;
        let mut i = 0usize;
        while share.mappable(i, bt) && self.prefix_index.contains_key(&share.key(i)) {
            tokens = share.mapped_tokens(i, bt);
            i += 1;
        }
        tokens
    }

    /// Create request `id`'s allocation by mapping the longest registered
    /// run of its template's prefix blocks: refcounts bump, nothing is
    /// drawn from the free list. Returns the context tokens the mapping
    /// materialized — prefill the request does *not* have to recompute.
    /// `max_tokens` caps the shareable span (callers pass `prompt - 1`
    /// so the last prompt token is always prefilled for its logits).
    /// An allocation is created even on zero hits, so a later
    /// [`KvPager::grow`] knows the template and registers the blocks it
    /// writes (first arrival publishes, later arrivals share).
    pub fn map_prefix(
        &mut self,
        id: usize,
        group: u64,
        prefix_tokens: usize,
        max_tokens: usize,
    ) -> usize {
        debug_assert!(self.config.prefix_share, "map_prefix with sharing disabled");
        if let Some(a) = self.allocs.get(&id) {
            debug_assert!(false, "map_prefix on a live allocation ({id})");
            return a.tokens;
        }
        let bt = self.config.block_tokens;
        let share =
            PrefixShare { group, declared: prefix_tokens, effective: prefix_tokens.min(max_tokens) };
        let mut blocks = Vec::new();
        let mut tokens = 0usize;
        let mut i = 0usize;
        while share.mappable(i, bt) {
            self.prefix_lookups += 1;
            match self.prefix_index.get(&share.key(i)) {
                Some(&pb) => {
                    self.refs[pb] += 1;
                    self.prefix_hits += 1;
                    blocks.push(pb);
                    tokens = share.mapped_tokens(i, bt);
                    i += 1;
                }
                None => break,
            }
        }
        self.logical += blocks.len();
        self.allocs.insert(id, Alloc { tokens, blocks, prefix: Some(share) });
        self.note_peaks();
        debug_assert!(self.audit());
        tokens
    }

    /// Grow (or create) request `id`'s allocation to cover `tokens`
    /// context entries, appending blocks as needed. Shrinking never
    /// happens here — contexts only grow until [`KvPager::release`].
    /// Growing past a shared prefix triggers the copy-on-write: the
    /// boundary block forks if peers still reference it, or sheds its
    /// registration if the writer is the last holder; blocks written
    /// while still inside the prefix are registered for later arrivals.
    /// Returns the physical blocks drawn from the free list; on failure
    /// the allocation is untouched (all-or-nothing).
    pub fn grow(&mut self, id: usize, tokens: usize) -> Result<usize, PagerError> {
        let need = self.physical_need(id, tokens);
        if need > self.free_list.len() {
            return Err(PagerError::OutOfBlocks { need, free: self.free_list.len() });
        }
        let mut drawn = 0usize;
        // Copy-on-write: crossing the shared-prefix boundary writes into
        // the boundary block.
        let share = self.allocs.get(&id).and_then(|a| a.prefix);
        if let Some(s) = share {
            if tokens > s.effective {
                let w = s.effective / self.config.block_tokens;
                let a = self.allocs.get_mut(&id).expect("prefix implies a live alloc");
                if w < a.blocks.len() && self.registered[a.blocks[w]].is_some() {
                    let pb = a.blocks[w];
                    if self.refs[pb] > 1 {
                        // Fork: private copy for the writer, the shared
                        // original stays registered for its other holders.
                        let nb = self.free_list.pop().expect("need included the fork");
                        self.refs[pb] -= 1;
                        self.refs[nb] = 1;
                        a.blocks[w] = nb;
                        self.cow_forks += 1;
                        drawn += 1;
                    } else {
                        // Last holder: write in place, retire the entry.
                        let key = self.registered[pb].take().expect("checked above");
                        self.prefix_index.remove(&key);
                    }
                }
                self.allocs.get_mut(&id).expect("live alloc").prefix = None;
            }
        }
        let (cur, target, share) = match self.allocs.get(&id) {
            Some(a) => (a.blocks.len(), a.tokens.max(tokens), a.prefix),
            None => (0, tokens, None),
        };
        let want = self.config.blocks_for(tokens);
        let bt = self.config.block_tokens;
        let mut new_blocks = Vec::with_capacity(want.saturating_sub(cur));
        for i in cur..want {
            let nb = self.free_list.pop().expect("need was checked");
            self.refs[nb] = 1;
            drawn += 1;
            if let Some(s) = share {
                // Register-on-write: the first holder to materialize a
                // template block publishes it, unless a peer already did
                // (grow never maps — sharing binds only at admission).
                if s.registrable(i, target, bt) {
                    let key = s.key(i);
                    if let std::collections::hash_map::Entry::Vacant(e) =
                        self.prefix_index.entry(key)
                    {
                        e.insert(nb);
                        self.registered[nb] = Some(key);
                    }
                }
            }
            new_blocks.push(nb);
        }
        let entry = self.allocs.entry(id).or_default();
        let grown = new_blocks.len();
        entry.blocks.extend(new_blocks);
        entry.tokens = entry.tokens.max(tokens);
        self.logical += grown;
        self.note_peaks();
        debug_assert!(self.audit());
        Ok(drawn)
    }

    /// Shrink request `id`'s context back to `tokens` entries, dropping
    /// blocks past the new boundary — the speculative-decoding rollback:
    /// a verification pass that rejects draft tokens must discard their
    /// KV entries, so the serving loop grows a slot to the full
    /// speculated window and truncates back to what was accepted. A
    /// no-op when `tokens` already covers the context (the `k = 0` /
    /// all-accepted path), which keeps plain-decode replays bit-for-bit
    /// untouched. Dropped blocks follow [`KvPager::release`]'s per-block
    /// rule — refcount decrement, free only at zero — so a rollback can
    /// never free a prefix block a peer still maps, and a registration
    /// retires only when its last holder lets go. Returns the physical
    /// blocks actually freed.
    pub fn truncate(&mut self, id: usize, tokens: usize) -> Result<usize, PagerError> {
        let a = self.allocs.get_mut(&id).ok_or(PagerError::UnknownRequest(id))?;
        if tokens >= a.tokens {
            return Ok(0);
        }
        let keep = self.config.blocks_for(tokens);
        let dropped: Vec<usize> = a.blocks.drain(keep..).collect();
        a.tokens = tokens;
        self.logical -= dropped.len();
        let mut freed = 0usize;
        for b in dropped {
            debug_assert!(self.refs[b] > 0, "double-free of block {b}");
            self.refs[b] -= 1;
            if self.refs[b] == 0 {
                if let Some(key) = self.registered[b].take() {
                    self.prefix_index.remove(&key);
                }
                self.free_list.push(b);
                freed += 1;
            }
        }
        self.note_peaks();
        debug_assert!(self.audit());
        Ok(freed)
    }

    /// Drop every block reference request `id` holds (completion, or
    /// preemption with recompute). Blocks return to the free list only
    /// at refcount zero — a sharer's release never frees blocks its
    /// peers still map. Returns the physical blocks actually freed.
    pub fn release(&mut self, id: usize) -> Result<usize, PagerError> {
        let alloc = self.allocs.remove(&id).ok_or(PagerError::UnknownRequest(id))?;
        self.logical -= alloc.blocks.len();
        let mut freed = 0usize;
        for b in alloc.blocks {
            debug_assert!(self.refs[b] > 0, "double-free of block {b}");
            self.refs[b] -= 1;
            if self.refs[b] == 0 {
                if let Some(key) = self.registered[b].take() {
                    self.prefix_index.remove(&key);
                }
                self.free_list.push(b);
                freed += 1;
            }
        }
        self.note_peaks();
        debug_assert!(self.audit());
        Ok(freed)
    }

    fn note_peaks(&mut self) {
        self.peak_in_use = self.peak_in_use.max(self.blocks_in_use());
        self.peak_logical = self.peak_logical.max(self.logical);
        self.peak_saved = self.peak_saved.max(self.logical - self.blocks_in_use());
    }

    /// Refcount-conservation check: Σ logical blocks == Σ physical·refs,
    /// the free list is exactly the zero-ref blocks (no double-free, no
    /// orphans), every allocation's block count matches its tokens, and
    /// the prefix index ↔ per-block registrations form a bijection over
    /// live blocks.
    pub fn audit(&self) -> bool {
        let cap = self.config.capacity_blocks;
        // Recount every block's references from the allocation lists.
        let mut counted = vec![0u32; cap];
        let mut logical = 0usize;
        for a in self.allocs.values() {
            if a.blocks.len() != self.config.blocks_for(a.tokens) {
                return false;
            }
            logical += a.blocks.len();
            let mut in_alloc = std::collections::HashSet::new();
            for &b in &a.blocks {
                // One request never holds the same physical block twice.
                if b >= cap || !in_alloc.insert(b) {
                    return false;
                }
                counted[b] += 1;
            }
        }
        if logical != self.logical || counted != self.refs {
            return false;
        }
        // The free list is exactly the zero-ref blocks, each once.
        let mut on_free = vec![false; cap];
        for &b in &self.free_list {
            if b >= cap || on_free[b] || counted[b] != 0 {
                return false;
            }
            on_free[b] = true;
        }
        let live = counted.iter().filter(|&&c| c > 0).count();
        if live + self.free_list.len() != cap {
            return false;
        }
        // Positional registration: a block registered as template slice
        // `i` may only ever sit at context position `i` of its holders —
        // blocks are appended by `grow`, replaced in place by the COW
        // fork and dropped strictly from the tail by `truncate`, so a
        // rollback that disturbed block order (front drain, swap-remove)
        // is caught here.
        for a in self.allocs.values() {
            for (i, &b) in a.blocks.iter().enumerate() {
                if let Some((_, _, slice)) = self.registered[b] {
                    if slice != i {
                        return false;
                    }
                }
            }
        }
        // Registration bijection over live blocks.
        if self.prefix_index.len() != self.registered.iter().flatten().count() {
            return false;
        }
        self.prefix_index
            .iter()
            .all(|(key, &b)| b < cap && self.registered[b] == Some(*key) && counted[b] > 0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pager(block_tokens: usize, capacity_blocks: usize) -> KvPager {
        KvPager::new(KvPagerConfig { block_tokens, capacity_blocks, prefix_share: false })
    }

    fn sharing(block_tokens: usize, capacity_blocks: usize) -> KvPager {
        KvPager::new(KvPagerConfig { block_tokens, capacity_blocks, prefix_share: true })
    }

    #[test]
    fn grow_allocates_ceil_blocks_and_conserves() {
        let mut p = pager(16, 10);
        assert_eq!(p.grow(1, 1).unwrap(), 1); // 1 token → 1 block
        assert_eq!(p.grow(1, 16).unwrap(), 0); // still 1 block
        assert_eq!(p.grow(1, 17).unwrap(), 1); // crosses a boundary
        assert_eq!(p.blocks_in_use(), 2);
        assert_eq!(p.tokens_of(1), 17);
        assert_eq!(p.grow(2, 64).unwrap(), 4);
        assert_eq!(p.blocks_in_use(), 6);
        assert_eq!(p.logical_blocks(), 6, "no sharing: logical == physical");
        assert!(p.audit());
        assert_eq!(p.release(1).unwrap(), 2);
        assert_eq!(p.release(2).unwrap(), 4);
        assert_eq!(p.blocks_in_use(), 0);
        assert_eq!(p.free_blocks(), 10);
        assert!(p.audit());
        assert_eq!(p.peak_blocks(), 6, "high-water mark survives release");
        assert_eq!(p.peak_blocks_saved(), 0, "no sharing, no savings");
    }

    #[test]
    fn exhaustion_is_all_or_nothing() {
        let mut p = pager(16, 4);
        p.grow(1, 48).unwrap(); // 3 blocks
        assert!(p.can_grow(1, 64));
        assert!(!p.can_grow(2, 32));
        let err = p.grow(2, 32).unwrap_err();
        assert_eq!(err, PagerError::OutOfBlocks { need: 2, free: 1 });
        // The failed grow left no partial allocation behind.
        assert_eq!(p.live_requests(), 1);
        assert_eq!(p.blocks_in_use(), 3);
        assert!(p.audit());
        // A grow that fails on an *existing* allocation keeps it intact.
        let err = p.grow(1, 48 + 32).unwrap_err();
        assert_eq!(err, PagerError::OutOfBlocks { need: 2, free: 1 });
        assert_eq!(p.tokens_of(1), 48);
        // Release unblocks the waiter.
        p.release(1).unwrap();
        assert_eq!(p.grow(2, 32).unwrap(), 2);
        assert!(p.release(99).is_err());
    }

    #[test]
    fn blocks_are_reused_and_never_double_allocated() {
        let mut p = pager(8, 6);
        p.grow(1, 24).unwrap();
        p.grow(2, 24).unwrap();
        assert_eq!(p.free_blocks(), 0);
        p.release(1).unwrap();
        p.grow(3, 17).unwrap(); // reuses freed ids
        assert!(p.audit(), "no duplicate block ids after reuse");
        assert_eq!(p.occupancy(), 5.0 / 6.0);
    }

    #[test]
    fn publisher_registers_and_sharer_maps_without_drawing_blocks() {
        let mut p = sharing(16, 10);
        // First arrival: nothing registered yet — zero hits, but the
        // allocation remembers the template for register-on-write.
        assert_eq!(p.map_prefix(1, 7, 48, 95), 0);
        assert!(p.holds(1));
        assert_eq!(p.prefix_lookups(), 1);
        assert_eq!(p.prefix_hits(), 0);
        // Prefill materializes the prefix: blocks 0..3 register.
        assert_eq!(p.grow(1, 48).unwrap(), 3);
        // Second arrival maps the whole registered run: 3 blocks, no
        // free-list draw, refcounts 2.
        let free_before = p.free_blocks();
        assert_eq!(p.map_prefix(2, 7, 48, 63), 48);
        assert_eq!(p.free_blocks(), free_before, "mapping draws nothing");
        assert_eq!(p.blocks_in_use(), 3);
        assert_eq!(p.logical_blocks(), 6);
        assert_eq!(p.peak_blocks_saved(), 3);
        assert_eq!(p.blocks_of(2).unwrap(), p.blocks_of(1).unwrap());
        assert_eq!(p.prefix_hits(), 3);
        // Growing past the (block-aligned) prefix allocates privately —
        // the crossing block was never shared, so no fork.
        assert_eq!(p.grow(2, 49).unwrap(), 1);
        assert_eq!(p.cow_forks(), 0);
        assert_ne!(p.blocks_of(2).unwrap()[3], p.blocks_of(1).unwrap()[2]);
        // A different template sees none of it.
        assert_eq!(p.prefix_hit_tokens(8, 48, 100), 0);
        assert_eq!(p.prefix_hit_tokens(7, 48, 100), 48);
        assert!(p.audit());
    }

    #[test]
    fn decode_write_forks_shared_boundary_and_last_holder_writes_in_place() {
        // declared = 24 with 16-token blocks: block 1 is a partial
        // boundary block — shareable while its holder stays ≤ 24 tokens.
        let mut p = sharing(16, 10);
        assert_eq!(p.map_prefix(1, 5, 24, 100), 0);
        assert_eq!(p.grow(1, 24).unwrap(), 2); // registers blocks 0 and 1
        assert_eq!(p.map_prefix(2, 5, 24, 100), 24);
        let b1 = p.blocks_of(1).unwrap()[1];
        assert_eq!(p.blocks_of(2).unwrap()[1], b1);
        // Writer 2 crosses the prefix: the boundary block is shared
        // (refs 2), so the write forks it copy-on-write.
        assert_eq!(p.physical_need(2, 25), 1, "no new block, one fork");
        assert_eq!(p.grow(2, 25).unwrap(), 1);
        assert_eq!(p.cow_forks(), 1);
        assert_ne!(p.blocks_of(2).unwrap()[1], b1);
        assert_eq!(p.blocks_of(1).unwrap()[1], b1, "the original stays shared");
        // Writer 1 crosses too: now the last holder — no fork, the
        // registration retires in place.
        assert_eq!(p.physical_need(1, 25), 0);
        assert_eq!(p.grow(1, 25).unwrap(), 0);
        assert_eq!(p.cow_forks(), 1);
        assert_eq!(p.prefix_hit_tokens(5, 24, 100), 16, "only the full block remains");
        assert!(p.audit());
    }

    #[test]
    fn releasing_a_sharer_never_frees_a_peers_prefix() {
        let mut p = sharing(16, 10);
        p.map_prefix(1, 3, 32, 100);
        p.grow(1, 40).unwrap(); // 3 blocks, first two registered
        assert_eq!(p.map_prefix(2, 3, 32, 100), 32);
        // Preempting the sharer frees nothing physical: both its blocks
        // are still referenced by the publisher.
        assert_eq!(p.release(2).unwrap(), 0);
        assert_eq!(p.blocks_in_use(), 3);
        assert_eq!(p.tokens_of(1), 40);
        assert_eq!(p.prefix_hit_tokens(3, 32, 100), 32, "prefix survives");
        // Releasing the publisher too drops refcounts to zero: blocks
        // free, the index empties.
        assert_eq!(p.release(1).unwrap(), 3);
        assert_eq!(p.free_blocks(), 10);
        assert_eq!(p.prefix_hit_tokens(3, 32, 100), 0);
        assert!(p.audit());
    }

    #[test]
    fn sharing_disabled_requests_and_nonshared_ids_take_the_legacy_path() {
        // prefix_share on, but plain grows (no map_prefix) behave exactly
        // like the legacy allocator — the differential-test guarantee.
        let mut on = sharing(16, 8);
        let mut off = pager(16, 8);
        for (id, t) in [(1, 20), (2, 64), (1, 40), (3, 16)] {
            assert_eq!(on.grow(id, t).unwrap(), off.grow(id, t).unwrap());
        }
        assert_eq!(on.release(2).unwrap(), off.release(2).unwrap());
        assert_eq!(on.blocks_in_use(), off.blocks_in_use());
        assert_eq!(on.logical_blocks(), on.blocks_in_use());
        assert_eq!((on.prefix_lookups(), on.cow_forks()), (0, 0));
        assert!(on.audit() && off.audit());
    }

    #[test]
    fn truncate_rolls_back_tail_blocks_and_nops_at_the_boundary() {
        let mut p = pager(16, 10);
        assert!(p.truncate(99, 10).is_err(), "unknown request");
        p.grow(1, 40).unwrap(); // 3 blocks
        assert_eq!(p.truncate(1, 40).unwrap(), 0, "no-op at the context");
        assert_eq!(p.truncate(1, 64).unwrap(), 0, "growing targets are ignored");
        assert_eq!(p.tokens_of(1), 40);
        // Roll back to 17 tokens: ceil(17/16) = 2 blocks, one frees.
        assert_eq!(p.truncate(1, 17).unwrap(), 1);
        assert_eq!(p.tokens_of(1), 17);
        assert_eq!((p.blocks_in_use(), p.logical_blocks()), (2, 2));
        assert!(p.audit());
        // Truncate to zero keeps the (empty) allocation live.
        assert_eq!(p.truncate(1, 0).unwrap(), 2);
        assert!(p.holds(1));
        assert!(p.blocks_of(1).unwrap().is_empty());
        assert!(p.audit());
        // The speculative window pattern: grow to ctx + k + 1, verify,
        // truncate back to the committed context.
        p.grow(3, 14).unwrap();
        let free_before = p.free_blocks();
        p.grow(3, 14 + 5).unwrap(); // speculate k + 1 = 5 tokens
        p.truncate(3, 15).unwrap(); // verification accepted one
        assert_eq!(p.tokens_of(3), 15);
        assert_eq!(p.free_blocks(), free_before, "rejected KV rolled back");
        assert!(p.audit());
    }

    #[test]
    fn truncate_never_frees_a_shared_prefix_block() {
        let mut p = sharing(16, 10);
        p.map_prefix(1, 9, 32, 100);
        p.grow(1, 40).unwrap(); // 3 blocks, the first two registered
        assert_eq!(p.map_prefix(2, 9, 32, 100), 32);
        assert_eq!(p.grow(2, 37).unwrap(), 1); // private tail past the prefix
        let publisher = p.blocks_of(1).unwrap().to_vec();
        // The sharer rolls back into the shared span: its private tail
        // frees, the shared block's refcount drops without freeing or
        // unregistering it.
        assert_eq!(p.truncate(2, 10).unwrap(), 1);
        assert_eq!(p.blocks_in_use(), 3, "publisher still holds all three");
        assert_eq!(p.prefix_hit_tokens(9, 32, 100), 32, "registrations survive");
        assert_eq!(p.blocks_of(1).unwrap(), &publisher[..]);
        assert!(p.audit());
        // The publisher rolls back too: now the last holder of block 1 —
        // it frees and its registration retires.
        assert_eq!(p.truncate(1, 16).unwrap(), 2);
        assert_eq!(p.prefix_hit_tokens(9, 32, 100), 16, "only block 0 remains");
        assert!(p.audit());
    }

    #[test]
    fn for_models_carves_out_every_resident_model() {
        let target = crate::models::zoo::gpt2_large();
        let draft = crate::spec_decode::auto_draft(&target);
        let a100 = crate::gpusim::device_by_name("a100").unwrap();
        let solo = KvPagerConfig::for_model(&target, a100.mem_bytes(), 16);
        let pair = KvPagerConfig::for_models(&[&target, &draft], a100.mem_bytes(), 16);
        assert!(
            pair.capacity_blocks < solo.capacity_blocks,
            "draft weights + draft KV shrink the block budget"
        );
        // for_model is exactly the one-model case.
        assert_eq!(KvPagerConfig::for_models(&[&target], a100.mem_bytes(), 16), solo);
        // Byte accounting: both caches together stay inside the
        // post-reserve budget and fill most of it.
        let budget = a100.mem_bytes()
            - target.weight_bytes()
            - draft.weight_bytes()
            - 0.7e9
            - 0.05 * a100.mem_bytes();
        let used = target.kv_cache_bytes(1, pair.capacity_tokens())
            + draft.kv_cache_bytes(1, pair.capacity_tokens());
        assert!(used <= budget);
        let per_block = target.kv_cache_bytes(1, 16) + draft.kv_cache_bytes(1, 16);
        assert!(used > budget - per_block, "off by < 1 block");
    }

    #[test]
    fn config_sizes_from_device_memory() {
        let cfg = crate::models::zoo::gpt2_large();
        let a100 = crate::gpusim::device_by_name("a100").unwrap();
        let pc = KvPagerConfig::for_model(&cfg, a100.mem_bytes(), 16);
        assert_eq!(pc.block_tokens, 16);
        assert!(!pc.prefix_share, "sharing is opt-in");
        assert!(pc.with_prefix_share(true).prefix_share);
        // Byte accounting matches kv_cache_bytes exactly: capacity in
        // bytes stays within the post-reserve budget and fills most of it.
        let budget = a100.mem_bytes() - cfg.weight_bytes() - 0.7e9 - 0.05 * a100.mem_bytes();
        let used = cfg.kv_cache_bytes(1, pc.capacity_tokens());
        assert!(used <= budget);
        assert!(used > budget - cfg.kv_cache_bytes(1, 16), "off by < 1 block");
        // A model far bigger than HBM still constructs (1 block floor).
        let tiny = KvPagerConfig::for_model(&cfg, 1.0, 16);
        assert_eq!(tiny.capacity_blocks, 1);
        // GQA models pack more tokens per block budget than MHA ones.
        let gqa = crate::models::zoo::qwen3_4b();
        let mut mha = gqa.clone();
        mha.kv_heads = mha.heads;
        let pg = KvPagerConfig::for_model(&gqa, a100.mem_bytes(), 16);
        let pm = KvPagerConfig::for_model(&mha, a100.mem_bytes(), 16);
        assert!(pg.capacity_blocks > 2 * pm.capacity_blocks);
    }
}
