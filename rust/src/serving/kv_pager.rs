//! Paged KV-cache allocator — vLLM-style block bookkeeping for the
//! serving simulator.
//!
//! GPU memory for the KV cache is carved into fixed-size blocks of
//! `block_tokens` tokens each; a request holds a list of blocks that
//! grows as its context grows and is returned wholesale on completion
//! (or preemption). Capacity is derived from the device's HBM minus the
//! model's resident footprint through
//! [`crate::models::TransformerConfig::kv_cache_bytes`], so block-count
//! accounting and byte accounting can never disagree.
//!
//! Invariants (enforced with debug assertions and checked by the
//! property tests):
//!
//! * `free + in_use == capacity` after every operation;
//! * a request's block count is exactly `ceil(tokens / block_tokens)`;
//! * block ids are never double-allocated and all return to the free
//!   list when their owner releases.

use std::collections::HashMap;

/// Default tokens per KV block (vLLM's default page size).
pub const DEFAULT_BLOCK_TOKENS: usize = 16;

/// Static shape of a pager: the block size knob and the block budget.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct KvPagerConfig {
    pub block_tokens: usize,
    pub capacity_blocks: usize,
}

impl KvPagerConfig {
    /// Size a pager from a device HBM budget: whatever remains after the
    /// model's weights, an activation/workspace reserve and the CUDA
    /// context becomes KV blocks. Clamps to at least one block so a
    /// degenerate budget still constructs (and then preempts constantly —
    /// visible, not silent).
    pub fn for_model(
        cfg: &crate::models::TransformerConfig,
        hbm_bytes: f64,
        block_tokens: usize,
    ) -> KvPagerConfig {
        let block_tokens = block_tokens.max(1);
        let bytes_per_block = cfg.kv_cache_bytes(1, block_tokens);
        // Weights + CUDA context + a workspace reserve proportional to a
        // healthy batch of activations.
        let reserved = cfg.weight_bytes() + 0.7e9 + 0.05 * hbm_bytes;
        let budget = (hbm_bytes - reserved).max(0.0);
        KvPagerConfig {
            block_tokens,
            capacity_blocks: ((budget / bytes_per_block) as usize).max(1),
        }
    }

    /// Blocks needed to hold `tokens` context entries.
    pub fn blocks_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.block_tokens)
    }

    /// Token capacity if a single request could take every block.
    pub fn capacity_tokens(&self) -> usize {
        self.capacity_blocks * self.block_tokens
    }
}

#[derive(Clone, Debug, PartialEq, Eq, thiserror::Error)]
pub enum PagerError {
    #[error("out of KV blocks: need {need} more, have {free} free")]
    OutOfBlocks { need: usize, free: usize },
    #[error("request {0} holds no allocation")]
    UnknownRequest(usize),
}

/// Per-request allocation: the materialized context length and the
/// actual block ids backing it.
#[derive(Clone, Debug, Default)]
struct Alloc {
    tokens: usize,
    blocks: Vec<usize>,
}

/// The allocator. Block ids are dense `0..capacity`; the free list is
/// LIFO so recently released blocks are reused first (cache-friendly on
/// real hardware, deterministic here).
#[derive(Clone, Debug)]
pub struct KvPager {
    config: KvPagerConfig,
    free_list: Vec<usize>,
    allocs: HashMap<usize, Alloc>,
    peak_in_use: usize,
}

impl KvPager {
    pub fn new(config: KvPagerConfig) -> KvPager {
        let config = KvPagerConfig {
            block_tokens: config.block_tokens.max(1),
            capacity_blocks: config.capacity_blocks.max(1),
        };
        KvPager {
            free_list: (0..config.capacity_blocks).rev().collect(),
            allocs: HashMap::new(),
            peak_in_use: 0,
            config,
        }
    }

    pub fn config(&self) -> KvPagerConfig {
        self.config
    }

    pub fn capacity_blocks(&self) -> usize {
        self.config.capacity_blocks
    }

    pub fn free_blocks(&self) -> usize {
        self.free_list.len()
    }

    pub fn blocks_in_use(&self) -> usize {
        self.config.capacity_blocks - self.free_list.len()
    }

    /// High-water mark of `blocks_in_use` over the pager's lifetime.
    pub fn peak_blocks(&self) -> usize {
        self.peak_in_use
    }

    /// Fraction of blocks currently allocated.
    pub fn occupancy(&self) -> f64 {
        self.blocks_in_use() as f64 / self.config.capacity_blocks as f64
    }

    /// Materialized context tokens of a request (0 when unknown).
    pub fn tokens_of(&self, id: usize) -> usize {
        self.allocs.get(&id).map(|a| a.tokens).unwrap_or(0)
    }

    /// Live requests holding at least one block.
    pub fn live_requests(&self) -> usize {
        self.allocs.len()
    }

    /// Would growing request `id` to `tokens` context entries fit?
    pub fn can_grow(&self, id: usize, tokens: usize) -> bool {
        let have = self.allocs.get(&id).map(|a| a.blocks.len()).unwrap_or(0);
        let need = self.config.blocks_for(tokens).saturating_sub(have);
        need <= self.free_list.len()
    }

    /// Grow (or create) request `id`'s allocation to cover `tokens`
    /// context entries, appending blocks as needed. Shrinking never
    /// happens here — contexts only grow until [`KvPager::release`].
    /// Returns the number of newly allocated blocks; on failure the
    /// allocation is untouched (all-or-nothing).
    pub fn grow(&mut self, id: usize, tokens: usize) -> Result<usize, PagerError> {
        let entry = self.allocs.entry(id).or_default();
        let want = self.config.blocks_for(tokens);
        let need = want.saturating_sub(entry.blocks.len());
        if need > self.free_list.len() {
            let free = self.free_list.len();
            if entry.blocks.is_empty() {
                self.allocs.remove(&id);
            }
            return Err(PagerError::OutOfBlocks { need, free });
        }
        for _ in 0..need {
            entry.blocks.push(self.free_list.pop().expect("checked above"));
        }
        entry.tokens = entry.tokens.max(tokens);
        self.peak_in_use = self.peak_in_use.max(self.blocks_in_use());
        debug_assert!(self.audit());
        Ok(need)
    }

    /// Return every block request `id` holds (completion, or preemption
    /// with recompute). Returns the freed block count.
    pub fn release(&mut self, id: usize) -> Result<usize, PagerError> {
        let alloc = self.allocs.remove(&id).ok_or(PagerError::UnknownRequest(id))?;
        let n = alloc.blocks.len();
        self.free_list.extend(alloc.blocks);
        debug_assert!(self.audit());
        Ok(n)
    }

    /// Conservation check: free + allocated == capacity, no block id
    /// appears twice, every allocation's block count matches its tokens.
    pub fn audit(&self) -> bool {
        let allocated: usize = self.allocs.values().map(|a| a.blocks.len()).sum();
        if allocated + self.free_list.len() != self.config.capacity_blocks {
            return false;
        }
        let mut seen = vec![false; self.config.capacity_blocks];
        for &b in self.free_list.iter().chain(self.allocs.values().flat_map(|a| &a.blocks)) {
            if b >= seen.len() || seen[b] {
                return false;
            }
            seen[b] = true;
        }
        self.allocs
            .values()
            .all(|a| a.blocks.len() == self.config.blocks_for(a.tokens))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pager(block_tokens: usize, capacity_blocks: usize) -> KvPager {
        KvPager::new(KvPagerConfig { block_tokens, capacity_blocks })
    }

    #[test]
    fn grow_allocates_ceil_blocks_and_conserves() {
        let mut p = pager(16, 10);
        assert_eq!(p.grow(1, 1).unwrap(), 1); // 1 token → 1 block
        assert_eq!(p.grow(1, 16).unwrap(), 0); // still 1 block
        assert_eq!(p.grow(1, 17).unwrap(), 1); // crosses a boundary
        assert_eq!(p.blocks_in_use(), 2);
        assert_eq!(p.tokens_of(1), 17);
        assert_eq!(p.grow(2, 64).unwrap(), 4);
        assert_eq!(p.blocks_in_use(), 6);
        assert!(p.audit());
        assert_eq!(p.release(1).unwrap(), 2);
        assert_eq!(p.release(2).unwrap(), 4);
        assert_eq!(p.blocks_in_use(), 0);
        assert_eq!(p.free_blocks(), 10);
        assert!(p.audit());
        assert_eq!(p.peak_blocks(), 6, "high-water mark survives release");
    }

    #[test]
    fn exhaustion_is_all_or_nothing() {
        let mut p = pager(16, 4);
        p.grow(1, 48).unwrap(); // 3 blocks
        assert!(p.can_grow(1, 64));
        assert!(!p.can_grow(2, 32));
        let err = p.grow(2, 32).unwrap_err();
        assert_eq!(err, PagerError::OutOfBlocks { need: 2, free: 1 });
        // The failed grow left no partial allocation behind.
        assert_eq!(p.live_requests(), 1);
        assert_eq!(p.blocks_in_use(), 3);
        assert!(p.audit());
        // A grow that fails on an *existing* allocation keeps it intact.
        let err = p.grow(1, 48 + 32).unwrap_err();
        assert_eq!(err, PagerError::OutOfBlocks { need: 2, free: 1 });
        assert_eq!(p.tokens_of(1), 48);
        // Release unblocks the waiter.
        p.release(1).unwrap();
        assert_eq!(p.grow(2, 32).unwrap(), 2);
        assert!(p.release(99).is_err());
    }

    #[test]
    fn blocks_are_reused_and_never_double_allocated() {
        let mut p = pager(8, 6);
        p.grow(1, 24).unwrap();
        p.grow(2, 24).unwrap();
        assert_eq!(p.free_blocks(), 0);
        p.release(1).unwrap();
        p.grow(3, 17).unwrap(); // reuses freed ids
        assert!(p.audit(), "no duplicate block ids after reuse");
        assert_eq!(p.occupancy(), 5.0 / 6.0);
    }

    #[test]
    fn config_sizes_from_device_memory() {
        let cfg = crate::models::zoo::gpt2_large();
        let a100 = crate::gpusim::device_by_name("a100").unwrap();
        let pc = KvPagerConfig::for_model(&cfg, a100.mem_bytes(), 16);
        assert_eq!(pc.block_tokens, 16);
        // Byte accounting matches kv_cache_bytes exactly: capacity in
        // bytes stays within the post-reserve budget and fills most of it.
        let budget = a100.mem_bytes() - cfg.weight_bytes() - 0.7e9 - 0.05 * a100.mem_bytes();
        let used = cfg.kv_cache_bytes(1, pc.capacity_tokens());
        assert!(used <= budget);
        assert!(used > budget - cfg.kv_cache_bytes(1, 16), "off by < 1 block");
        // A model far bigger than HBM still constructs (1 block floor).
        let tiny = KvPagerConfig::for_model(&cfg, 1.0, 16);
        assert_eq!(tiny.capacity_blocks, 1);
        // GQA models pack more tokens per block budget than MHA ones.
        let gqa = crate::models::zoo::qwen3_4b();
        let mut mha = gqa.clone();
        mha.kv_heads = mha.heads;
        let pg = KvPagerConfig::for_model(&gqa, a100.mem_bytes(), 16);
        let pm = KvPagerConfig::for_model(&mha, a100.mem_bytes(), 16);
        assert!(pg.capacity_blocks > 2 * pm.capacity_blocks);
    }
}
