//! # serving — the continuous-batching serving simulator
//!
//! PM2Lat's per-kernel and per-step predictions price a single request
//! in isolation; production latency is dominated by *how requests share
//! the GPU* — batching policy, KV-cache memory pressure, and queueing.
//! This layer closes that gap without a single new measurement: every
//! serving iteration is just another [`crate::graph::ModelGraph`]
//! (a ragged mixed prefill+decode batch from
//! [`crate::models::TransformerConfig::mixed_batch_graph`]) that the
//! existing prediction stack can price, so a trace-driven discrete-event
//! replay of an inference server falls out of the engine we already
//! have.
//!
//! The pieces, bottom-up:
//!
//! * [`trace`] — request traces: synthetic Poisson / bursty generators,
//!   JSON replay of recorded arrivals, and rate rescaling over a fixed
//!   request population (the tool behind QPS sweeps).
//! * [`kv_pager`] — the paged KV-cache allocator: fixed-size token
//!   blocks, per-request block lists, capacity derived from device HBM
//!   through `kv_cache_bytes`, conservation-audited. Opt-in
//!   copy-on-write prefix sharing dedupes shared prompt templates:
//!   refcounted physical blocks behind a prefix index, forked on
//!   decode-time writes, freed only at refcount zero.
//! * [`policy`] — pluggable scheduling: static vs. vLLM-style continuous
//!   batching with chunked prefill; FCFS, shortest-prompt, priority,
//!   fair-share, and prefix-hit admission.
//! * [`iter_cache`] — the iteration-price memo: a canonical, exact
//!   [`iter_cache::IterationKey`] computed straight from the slot batch
//!   fronts an LRU of priced iterations, so repeating decode signatures
//!   skip graph construction, rewrite passes, and per-node prediction
//!   entirely. Bit-for-bit safe: both the key and the cold graph build
//!   use the same canonical slot order.
//! * [`simulator`] — the event loop: admission → chunk planning → pager
//!   growth (recompute-preemption under pressure) → one priced mixed
//!   iteration → virtual-time advance; per-request TTFT/TPOT/E2E,
//!   GPU-seconds, KV-occupancy timelines, throughput–latency sweeps and
//!   max-QPS-under-SLO search. [`simulator::simulate_placed`] replays
//!   the same trace on a tensor-parallel placement by rewriting each
//!   iteration graph with [`crate::graph::TensorParallelPass`] (memoized
//!   per structure via [`crate::graph::PassResultCache`] on the hot
//!   path), so SLO curves come out cluster-level.
//!   [`simulator::simulate_hot`] bundles the accelerations behind a
//!   [`simulator::HotPath`]; [`simulator::qps_sweep_parallel`] and
//!   [`simulator::max_qps_under_slo_parallel`] fan independent rate
//!   points across the scoped worker pool for `Sync` (analytical)
//!   pricing — PJRT-backed pricing stays on the calling thread via the
//!   serial entry points. [`simulator::simulate_speculative`] (and its
//!   hot-path twin) replays the trace under speculative decoding: decode
//!   slots become `q = k + 1` verification windows, each iteration also
//!   prices the draft model's rounds, seeded acceptance draws decide the
//!   tokens committed, and rejected speculated KV rolls back through
//!   [`kv_pager::KvPager::truncate`].
//!
//! Every replay is also *observable*: [`simulator::simulate_traced`] and
//! [`simulator::simulate_speculative_traced`] take a
//! [`crate::obs::TraceCtx`] and emit the structured event stream —
//! iteration spans, KV grow/fork/truncate/preempt/release, speculative
//! rounds, cache probes — that [`crate::obs::chrome_trace`] renders as a
//! Perfetto timeline (`serve-sim --trace-out`, and
//! `docs/OBSERVABILITY.md` for the operator's guide). Tracing is
//! zero-cost when off and never perturbs a report: the untraced entry
//! points are the traced ones with [`crate::obs::TraceCtx::off`].
//!
//! Consumed by `Coordinator::simulate_serving` (the cached service
//! path), the `pm2lat serve-sim` CLI, and `benches/serving_capacity.rs`.
//! Anchored to the rest of the stack by the batch-size-1 equivalence
//! property: continuous batching at concurrency 1 reproduces
//! `Pm2Lat::predict_generation`'s latency curve bit-for-bit.

pub mod iter_cache;
pub mod kv_pager;
pub mod policy;
pub mod simulator;
pub mod trace;

pub use iter_cache::{
    canonical_slots, IterCache, IterScope, IterationKey, DEFAULT_ITER_CACHE_CAPACITY,
};
pub use kv_pager::{KvPager, KvPagerConfig, PagerError, DEFAULT_BLOCK_TOKENS};
pub use policy::{Admission, BatchingMode, SchedulerConfig};
pub use simulator::{
    max_qps_under_slo, max_qps_under_slo_hot, max_qps_under_slo_parallel, qps_sweep,
    qps_sweep_hot, qps_sweep_parallel, qps_sweep_placed, simulate, simulate_hot,
    simulate_placed, simulate_speculative, simulate_speculative_hot,
    simulate_speculative_traced, simulate_traced, CapacityPoint, HotPath, RequestMetrics,
    ServingReport, ServingSimConfig, SimError,
};
pub use trace::{
    bursty_trace, parse_trace, poisson_trace, scale_arrivals, shared_prefix_trace, to_json,
    with_priority_classes, with_shared_prefix, RequestSpec,
};
