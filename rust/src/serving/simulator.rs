//! The discrete-event continuous-batching simulator: replay a request
//! trace through an inference-server schedule, pricing every iteration
//! through the PM2Lat prediction stack.
//!
//! The event loop is iteration-granular, like a real serving engine's:
//! each turn admits waiting requests (policy-ordered, KV-gated), plans
//! every running sequence's query window ([`SchedulerConfig::plan_q`]),
//! grows the paged KV cache — preempting the youngest sequence with
//! recompute when blocks run out, exactly vLLM's fallback — then lowers
//! the ragged batch to one
//! [`crate::models::TransformerConfig::mixed_batch_graph`] and asks the
//! pricing callback what the iteration costs. Virtual time advances by
//! that latency; arrivals that landed meanwhile join the next admission
//! round.
//!
//! The pricing callback is the only coupling to the prediction stack:
//! `Pm2Lat::predict_graph` gives the direct path,
//! [`crate::coordinator::Coordinator::simulate_serving`] routes it
//! through the cached service. Everything else — queueing, paging,
//! chunking, preemption — is deterministic integer bookkeeping, audited
//! by conservation checks every iteration (debug builds).
//!
//! Every replay can additionally be *observed*: the `*_traced` entry
//! points ([`simulate_traced`], [`simulate_speculative_traced`]) take a
//! [`crate::obs::TraceCtx`] and emit one
//! [`crate::obs::TraceEvent::IterationSpan`] per iteration plus KV-pager
//! events, speculative-round outcomes, and iteration-memo cache probes.
//! Emission is behind one `Option` check — the untraced entry points
//! pass [`crate::obs::TraceCtx::off`] and stay bit-for-bit what they
//! were (pinned by `tests/obs_trace.rs`). All paths build their
//! [`ServingReport`] through [`crate::obs::ReportBuilder`], so every
//! counter flows through the unified metrics schema exactly once.

use crate::graph::{ModelGraph, Pass, PassCtx, PassResultCache, TensorParallelPass};
use crate::obs::{keys, KvEventKind, ReportBuilder, TraceCtx, TraceEvent};
use crate::models::{SeqSlot, TransformerConfig};
use crate::spec_decode::{AcceptanceModel, SpecConfig};
use crate::util::prng::{Rng, StableHasher};
use crate::util::{pool, stats};

use super::iter_cache::{canonical_slots, IterCache, IterScope, IterationKey};
use super::kv_pager::{KvPager, KvPagerConfig};
use super::policy::{BatchingMode, RunningView, SchedulerConfig, WaitingView};
use super::trace::{scale_arrivals, RequestSpec};

/// Simulator shape: scheduler policy, pager geometry, and the stream
/// count handed to the per-iteration graph schedule.
#[derive(Clone, Copy, Debug)]
pub struct ServingSimConfig {
    pub scheduler: SchedulerConfig,
    pub pager: KvPagerConfig,
    pub streams: usize,
}

#[derive(Clone, Debug, PartialEq, thiserror::Error)]
pub enum SimError {
    #[error("empty request trace")]
    EmptyTrace,
    #[error("model unsupported by the pricing backend (prediction returned None)")]
    Unsupported,
    #[error(
        "request {id} needs {need} KV blocks but the pager holds {capacity} — \
         it can never be scheduled"
    )]
    RequestTooLarge { id: usize, need: usize, capacity: usize },
    #[error("request id {0} appears more than once in the trace")]
    DuplicateRequestId(usize),
    #[error("request {0} has an empty prompt")]
    EmptyPrompt(usize),
    #[error("encoder–decoder models are not servable (mixed-batch graphs are decoder-only)")]
    EncDecUnsupported,
    #[error("KV blocks exhausted with a single running request — pager accounting bug")]
    KvExhausted,
}

/// Timing record of one completed request.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RequestMetrics {
    pub id: usize,
    pub arrival_s: f64,
    /// Absolute time the first output token shipped (prefill end).
    pub first_token_s: f64,
    pub finish_s: f64,
    pub prompt_len: usize,
    pub gen_len: usize,
    pub preemptions: usize,
}

impl RequestMetrics {
    /// Time to first token: queueing + (possibly chunked) prefill.
    pub fn ttft_s(&self) -> f64 {
        self.first_token_s - self.arrival_s
    }

    /// End-to-end latency.
    pub fn e2e_s(&self) -> f64 {
        self.finish_s - self.arrival_s
    }

    /// Time per output token over the decode phase (0 when nothing was
    /// decoded).
    pub fn tpot_s(&self) -> f64 {
        if self.gen_len == 0 {
            0.0
        } else {
            (self.finish_s - self.first_token_s) / self.gen_len as f64
        }
    }
}

/// Everything a serving run produced: per-request records plus the
/// cluster-level aggregates the ISSUE asks for (percentiles, GPU
/// seconds, KV occupancy timeline).
#[derive(Clone, Debug)]
pub struct ServingReport {
    pub completed: Vec<RequestMetrics>,
    pub iterations: usize,
    /// Virtual time when the last request finished.
    pub makespan_s: f64,
    /// Σ iteration latencies — the GPU-seconds actually consumed.
    pub gpu_busy_s: f64,
    pub preemptions: usize,
    pub kv_capacity_blocks: usize,
    pub peak_kv_blocks: usize,
    /// Blocks still allocated at the end — must be 0 (leak detector).
    pub kv_leaked_blocks: usize,
    /// (time, occupancy fraction) samples, decimated to a bounded count.
    pub kv_timeline: Vec<(f64, f64)>,
    /// Largest concurrent batch observed.
    pub max_concurrency: usize,
    /// Prefix-index probes at admission (0 unless prefix sharing ran).
    pub prefix_lookups: u64,
    /// Probes that mapped a registered template block refcounted.
    pub prefix_hits: u64,
    /// Copy-on-write forks of shared boundary blocks.
    pub cow_forks: u64,
    /// Peak Σ of per-request block counts — what the workload would have
    /// occupied without sharing (≥ `peak_kv_blocks`; the gap is sharing).
    pub peak_logical_kv_blocks: usize,
    /// Largest instantaneous `logical − physical` gap: the KV blocks
    /// prefix sharing saved when it saved the most.
    pub kv_blocks_saved: usize,
    /// Speculative verification rounds executed (0 unless the replay ran
    /// with a draft model and `k > 0`).
    pub spec_rounds: usize,
    /// Draft tokens proposed across all rounds (`k` per round).
    pub spec_draft_tokens: usize,
    /// Draft tokens the verification passes accepted (the raw leading
    /// run τ per round, before the generation-tail cap — so
    /// `spec_accepted_tokens / spec_draft_tokens` estimates α faithfully).
    pub spec_accepted_tokens: usize,
    /// Σ draft-model iteration latencies — the share of `gpu_busy_s`
    /// spent drafting rather than verifying.
    pub spec_draft_busy_s: f64,
}

impl ServingReport {
    fn metric_percentile(&self, p: f64, f: impl Fn(&RequestMetrics) -> f64) -> f64 {
        let v: Vec<f64> = self.completed.iter().map(f).collect();
        stats::percentile(&v, p)
    }

    pub fn ttft_percentile_s(&self, p: f64) -> f64 {
        self.metric_percentile(p, RequestMetrics::ttft_s)
    }

    pub fn tpot_percentile_s(&self, p: f64) -> f64 {
        self.metric_percentile(p, RequestMetrics::tpot_s)
    }

    pub fn e2e_percentile_s(&self, p: f64) -> f64 {
        self.metric_percentile(p, RequestMetrics::e2e_s)
    }

    /// Completed requests per second of virtual time.
    pub fn throughput_rps(&self) -> f64 {
        if self.makespan_s > 0.0 {
            self.completed.len() as f64 / self.makespan_s
        } else {
            0.0
        }
    }

    /// Output tokens per second (first token + decode steps).
    pub fn output_tokens_per_s(&self) -> f64 {
        let toks: usize = self.completed.iter().map(|r| 1 + r.gen_len).sum();
        if self.makespan_s > 0.0 {
            toks as f64 / self.makespan_s
        } else {
            0.0
        }
    }

    /// Fraction of the makespan the GPU spent executing iterations.
    pub fn utilization(&self) -> f64 {
        if self.makespan_s > 0.0 {
            self.gpu_busy_s / self.makespan_s
        } else {
            0.0
        }
    }

    pub fn peak_kv_occupancy(&self) -> f64 {
        self.peak_kv_blocks as f64 / self.kv_capacity_blocks.max(1) as f64
    }

    /// Peak *effective* KV occupancy: logical blocks over capacity. Can
    /// exceed 1.0 — that surplus is the capacity sharing manufactured.
    pub fn effective_kv_occupancy(&self) -> f64 {
        self.peak_logical_kv_blocks as f64 / self.kv_capacity_blocks.max(1) as f64
    }

    /// Fraction of shareable prefix-block probes that hit the index.
    pub fn prefix_hit_rate(&self) -> f64 {
        if self.prefix_lookups > 0 {
            self.prefix_hits as f64 / self.prefix_lookups as f64
        } else {
            0.0
        }
    }

    /// Fraction of proposed draft tokens the verifications accepted — the
    /// empirical α̂ of the replay (0 when no speculation ran).
    pub fn spec_acceptance_rate(&self) -> f64 {
        if self.spec_draft_tokens > 0 {
            self.spec_accepted_tokens as f64 / self.spec_draft_tokens as f64
        } else {
            0.0
        }
    }

    /// Mean accepted draft tokens per verification round — the empirical
    /// E[τ] (each round also commits one verification token on top).
    pub fn spec_accepted_per_round(&self) -> f64 {
        if self.spec_rounds > 0 {
            self.spec_accepted_tokens as f64 / self.spec_rounds as f64
        } else {
            0.0
        }
    }

    /// Share of GPU-busy time spent running the draft model.
    pub fn spec_draft_time_share(&self) -> f64 {
        if self.gpu_busy_s > 0.0 {
            self.spec_draft_busy_s / self.gpu_busy_s
        } else {
            0.0
        }
    }

    /// One-paragraph operator summary (the `serve-sim` output body).
    pub fn summary(&self) -> String {
        let mut s = format!(
            "{} requests in {:.2}s ({:.2} req/s, {:.0} tok/s, util {:.0}%) | \
             TTFT p50 {:.1}ms p99 {:.1}ms | TPOT p50 {:.0}µs p99 {:.0}µs | \
             E2E p50 {:.1}ms p99 {:.1}ms | {} iters, batch ≤ {}, \
             KV peak {:.0}% of {} blocks, {} preemptions",
            self.completed.len(),
            self.makespan_s,
            self.throughput_rps(),
            self.output_tokens_per_s(),
            self.utilization() * 100.0,
            self.ttft_percentile_s(50.0) * 1e3,
            self.ttft_percentile_s(99.0) * 1e3,
            self.tpot_percentile_s(50.0) * 1e6,
            self.tpot_percentile_s(99.0) * 1e6,
            self.e2e_percentile_s(50.0) * 1e3,
            self.e2e_percentile_s(99.0) * 1e3,
            self.iterations,
            self.max_concurrency,
            self.peak_kv_occupancy() * 100.0,
            self.kv_capacity_blocks,
            self.preemptions,
        );
        if self.prefix_lookups > 0 {
            s.push_str(&format!(
                " | prefix hit {:.0}% ({} blocks saved, {} COW forks, \
                 effective KV {:.0}%)",
                self.prefix_hit_rate() * 100.0,
                self.kv_blocks_saved,
                self.cow_forks,
                self.effective_kv_occupancy() * 100.0,
            ));
        }
        if self.spec_rounds > 0 {
            s.push_str(&format!(
                " | spec {} rounds, {:.2} accepted/round (α̂ {:.0}%, \
                 draft {:.0}% of busy)",
                self.spec_rounds,
                self.spec_accepted_per_round(),
                self.spec_acceptance_rate() * 100.0,
                self.spec_draft_time_share() * 100.0,
            ));
        }
        s
    }
}

/// Live state of one request inside the simulator.
#[derive(Clone, Debug)]
struct ReqState {
    spec: RequestSpec,
    /// KV tokens materialized in the pager.
    ctx_ready: usize,
    /// Decode steps completed.
    decoded: usize,
    first_token_s: Option<f64>,
    preemptions: usize,
}

impl ReqState {
    fn new(spec: RequestSpec) -> ReqState {
        ReqState { spec, ctx_ready: 0, decoded: 0, first_token_s: None, preemptions: 0 }
    }

    /// Context the KV cache must hold before the next decode step:
    /// the prompt plus every token decoded so far (recompute after a
    /// preemption re-prefills both).
    fn ctx_target(&self) -> usize {
        self.spec.prompt_len + self.decoded
    }

    fn remaining_prefill(&self) -> usize {
        self.ctx_target() - self.ctx_ready
    }

    fn done(&self) -> bool {
        self.decoded == self.spec.gen_len && self.remaining_prefill() == 0
    }

    fn work_tokens(&self) -> usize {
        self.spec.prompt_len + self.spec.gen_len
    }
}

/// Hot-path acceleration state threaded through a replay (and shared
/// across the points of a sweep): the tensor-parallel degree, an
/// optional iteration-price memo, and an optional pass-result cache.
/// All three are pure acceleration — [`simulate_hot`] with any `HotPath`
/// is bit-for-bit identical to the cold path, because pricing is
/// deterministic and both the memo key and the cold graph build use the
/// same canonical slot order (see [`super::iter_cache`]).
///
/// `Copy` + `Sync` (it holds only shared references), so one value fans
/// out across the worker threads of [`qps_sweep_parallel`].
#[derive(Clone, Copy)]
pub struct HotPath<'a> {
    /// Tensor-parallel degree; > 1 rewrites every iteration graph to one
    /// rank's sharded work (collectives included) before pricing.
    pub tp: usize,
    /// Scope folded into every iteration key (model, device, lane, tp,
    /// streams). Ignored when `cache` is `None`.
    pub scope: IterScope,
    /// Iteration-price memo: a hit skips graph construction, rewrite
    /// passes, and per-node prediction entirely.
    pub cache: Option<&'a IterCache>,
    /// Memoized tensor-parallel rewrites (only consulted when `tp > 1`):
    /// structurally identical iteration graphs share one sharded form.
    pub passes: Option<&'a PassResultCache>,
}

impl<'a> HotPath<'a> {
    /// No memoization — the cold path [`simulate`]/[`simulate_placed`]
    /// wrap.
    pub fn cold(tp: usize) -> HotPath<'static> {
        HotPath { tp: tp.max(1), scope: IterScope::default(), cache: None, passes: None }
    }

    /// Fully memoized under `scope`.
    pub fn memoized(
        tp: usize,
        scope: IterScope,
        cache: &'a IterCache,
        passes: &'a PassResultCache,
    ) -> HotPath<'a> {
        HotPath { tp: tp.max(1), scope, cache: Some(cache), passes: Some(passes) }
    }
}

/// Which model one simulated iteration's slot batch prices against: the
/// serving target, or the resident speculative draft. Plain replays only
/// ever see `Target`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum IterPhase {
    Target,
    Draft,
}

/// Speculative-decoding parameters threaded through the event loop.
/// `k = 0` keeps the whole path *live* but degenerate: decode slots are
/// the plain `{1, ctx + 1}` windows, no draft batch is ever priced, the
/// sampler never draws, and every `spec_*` counter stays 0 — so the
/// replay is bit-for-bit the non-speculative one (pinned by
/// `tests/spec_decode.rs`).
#[derive(Clone, Copy)]
struct SpecParams<'a> {
    /// Draft tokens proposed per verification round.
    k: usize,
    acceptance: &'a AcceptanceModel,
    /// Seed of the per-(request, position) acceptance streams.
    seed: u64,
}

/// Price one slot batch under `hp`: memo lookup first (computed straight
/// from the slots — no graph is built on a hit), then the cold path in
/// canonical slot order, tensor-parallel rewrite (pass-cache-served when
/// available) included. Each memo consult emits one `iter-memo`
/// [`TraceEvent::CacheProbe`] through `tc` (nothing is emitted when the
/// memo is absent or disabled — the cache was never consulted).
fn priced_iteration<F>(
    cfg: &TransformerConfig,
    hp: &HotPath<'_>,
    tc: &TraceCtx<'_>,
    slots: &[SeqSlot],
    price: &mut F,
) -> Option<f64>
where
    F: FnMut(&ModelGraph) -> Option<f64>,
{
    let memo = hp
        .cache
        .filter(|c| c.enabled())
        .map(|c| (c, IterationKey::new(hp.scope, slots)));
    if let Some((cache, key)) = &memo {
        let probed = cache.get(key);
        tc.emit(|| TraceEvent::CacheProbe {
            cache: "iter-memo",
            hit: probed.is_some(),
            count: 1,
        });
        if let Some(v) = probed {
            return Some(v);
        }
    }
    // Cold path. The graph is built in the canonical (sorted) slot order
    // the key is defined over, so any permutation of the same batch
    // prices identically — down to the last ulp of the f64 makespan —
    // and a later hit returns exactly what the cold path would have.
    let graph = cfg.mixed_batch_graph(&canonical_slots(slots));
    let v = if hp.tp > 1 {
        let rewrite = || {
            let mut rank = graph.clone();
            TensorParallelPass { tp: hp.tp }.run(&mut rank, &PassCtx::structural());
            rank
        };
        match hp.passes {
            Some(pc) => {
                let tag = PassResultCache::config_tag("tensor-parallel", &hp.tp);
                let rank = pc.rewrite(tag, &graph, rewrite);
                price(&rank)?
            }
            None => price(&rewrite())?,
        }
    } else {
        price(&graph)?
    };
    if let Some((cache, key)) = memo {
        cache.insert(key, v);
    }
    Some(v)
}

/// Replay `trace` with the full hot path: iteration memoization,
/// pass-result reuse, and tensor-parallel placement, per `hp`.
/// Bit-for-bit identical to [`simulate`]/[`simulate_placed`] at the same
/// `tp` — the caches are pure acceleration (property-tested in
/// `tests/serving_hot_path.rs`).
pub fn simulate_hot<F>(
    cfg: &TransformerConfig,
    trace: &[RequestSpec],
    sim: &ServingSimConfig,
    hp: &HotPath<'_>,
    price: &mut F,
) -> Result<ServingReport, SimError>
where
    F: FnMut(&ModelGraph) -> Option<f64>,
{
    simulate_traced(cfg, trace, sim, hp, &TraceCtx::off(), price)
}

/// [`simulate_hot`] with observability: every iteration, KV-pager
/// mutation, and memo probe is emitted through `tc`. With
/// [`TraceCtx::off`] this *is* `simulate_hot` — the untraced entry
/// points delegate here, and `tests/obs_trace.rs` pins that a live
/// sink leaves every report field bit-for-bit unchanged (tracing
/// observes pricing, never participates in it).
pub fn simulate_traced<F>(
    cfg: &TransformerConfig,
    trace: &[RequestSpec],
    sim: &ServingSimConfig,
    hp: &HotPath<'_>,
    tc: &TraceCtx<'_>,
    price: &mut F,
) -> Result<ServingReport, SimError>
where
    F: FnMut(&ModelGraph) -> Option<f64>,
{
    let tcv = *tc;
    let mut price_slots =
        |_phase: IterPhase, slots: &[SeqSlot]| priced_iteration(cfg, hp, &tcv, slots, price);
    simulate_slots(cfg, trace, sim, &mut price_slots, None, tc)
}

/// Replay `trace` under speculative decoding: every decode slot becomes
/// a `q = k + 1` verification window, each iteration additionally prices
/// the draft model's `k` decode rounds (plus its prompt ingestion on
/// prefill chunks), and a seeded acceptance draw decides how many tokens
/// each sequence commits — rejected speculated KV rolls back through the
/// refcount-safe [`KvPager::truncate`]. The cold single-device path;
/// see [`simulate_speculative_hot`] for memoized/tensor-parallel runs.
pub fn simulate_speculative<F>(
    spec: &SpecConfig,
    trace: &[RequestSpec],
    sim: &ServingSimConfig,
    seed: u64,
    price: &mut F,
) -> Result<ServingReport, SimError>
where
    F: FnMut(&ModelGraph) -> Option<f64>,
{
    simulate_speculative_hot(spec, trace, sim, &HotPath::cold(1), IterScope::default(), seed, price)
}

/// [`simulate_speculative`] with the hot path engaged. `hp.scope` is the
/// *target* model's scope and `draft_scope` the draft's; both get the
/// spec tag ([`IterScope::with_spec`]) folded in here, so memo entries
/// can never alias the plain path or another k/acceptance configuration
/// — while staying shared across seeds (prices are seed-independent;
/// only the commit pattern differs). Draft batches price against the
/// draft model under `draft_scope`, target batches against the target
/// under `hp.scope`, both through `price` (which sees one rank's graph
/// when `hp.tp > 1`, draft and target alike). With `k = 0` the
/// speculative machinery stays engaged but degenerate and the report is
/// bit-for-bit [`simulate_hot`]'s.
pub fn simulate_speculative_hot<F>(
    spec: &SpecConfig,
    trace: &[RequestSpec],
    sim: &ServingSimConfig,
    hp: &HotPath<'_>,
    draft_scope: IterScope,
    seed: u64,
    price: &mut F,
) -> Result<ServingReport, SimError>
where
    F: FnMut(&ModelGraph) -> Option<f64>,
{
    simulate_speculative_traced(spec, trace, sim, hp, draft_scope, seed, &TraceCtx::off(), price)
}

/// [`simulate_speculative_hot`] with observability: on top of the plain
/// traced stream, each verification round emits a
/// [`TraceEvent::SpecRound`] and its KV rollback a `truncate`
/// [`TraceEvent::KvEvent`]; the draft passes' cost folds into each
/// iteration span's `draft_dur_s` (one span per DES iteration, drafting
/// included). [`TraceCtx::off`] makes this exactly
/// `simulate_speculative_hot`.
pub fn simulate_speculative_traced<F>(
    spec: &SpecConfig,
    trace: &[RequestSpec],
    sim: &ServingSimConfig,
    hp: &HotPath<'_>,
    draft_scope: IterScope,
    seed: u64,
    tc: &TraceCtx<'_>,
    price: &mut F,
) -> Result<ServingReport, SimError>
where
    F: FnMut(&ModelGraph) -> Option<f64>,
{
    if spec.draft.enc_layers > 0 {
        return Err(SimError::EncDecUnsupported);
    }
    let target_hp = HotPath { scope: hp.scope.with_spec(spec), ..*hp };
    let draft_hp = HotPath { scope: draft_scope.with_spec(spec), ..*hp };
    let tcv = *tc;
    let mut price_slots = |phase: IterPhase, slots: &[SeqSlot]| match phase {
        IterPhase::Target => priced_iteration(&spec.target, &target_hp, &tcv, slots, price),
        IterPhase::Draft => priced_iteration(&spec.draft, &draft_hp, &tcv, slots, price),
    };
    let params = SpecParams { k: spec.k, acceptance: &spec.acceptance, seed };
    simulate_slots(&spec.target, trace, sim, &mut price_slots, Some(params), tc)
}

/// Replay `trace` against `cfg`'s serving schedule, pricing every
/// iteration with `price` (typically `Pm2Lat::predict_graph` or the
/// coordinator's cached graph path). Deterministic for deterministic
/// pricing. Decoder-only models only (the `mixed_batch_graph` contract).
pub fn simulate<F>(
    cfg: &TransformerConfig,
    trace: &[RequestSpec],
    sim: &ServingSimConfig,
    price: &mut F,
) -> Result<ServingReport, SimError>
where
    F: FnMut(&ModelGraph) -> Option<f64>,
{
    simulate_hot(cfg, trace, sim, &HotPath::cold(1), price)
}

/// The discrete-event core: everything in the loop is deterministic
/// integer bookkeeping except the calls into `price_slots`, which map a
/// planned slot batch (tagged with the model it runs on) to that pass's
/// latency — plus, under speculation, the seeded acceptance draws. All
/// public entry points funnel here with a phase-dispatching closure
/// built over [`priced_iteration`]; plain replays pass `spec: None` and
/// only ever price `IterPhase::Target` batches.
fn simulate_slots<F>(
    cfg: &TransformerConfig,
    trace: &[RequestSpec],
    sim: &ServingSimConfig,
    price_slots: &mut F,
    spec: Option<SpecParams<'_>>,
    tc: &TraceCtx<'_>,
) -> Result<ServingReport, SimError>
where
    F: FnMut(IterPhase, &[SeqSlot]) -> Option<f64>,
{
    if trace.is_empty() {
        return Err(SimError::EmptyTrace);
    }
    if cfg.enc_layers > 0 {
        return Err(SimError::EncDecUnsupported);
    }
    let spec_k = spec.map_or(0, |s| s.k);
    let sched = SchedulerConfig {
        max_batch: sim.scheduler.max_batch.max(1),
        chunk_tokens: sim.scheduler.chunk_tokens.max(1),
        ..sim.scheduler
    };
    let mut pager = KvPager::new(sim.pager);
    let capacity = pager.capacity_blocks();
    // Prefix sharing engages only when the pager opts in AND a request
    // declares a template; otherwise every sharing branch below is dead
    // and the replay is bit-for-bit the private-paging path.
    let share_on = pager.config().prefix_share;
    // No request may ever need more blocks than exist, and ids must be
    // unique — the pager keys allocations by id, so a collision would
    // merge two requests' block lists.
    let mut seen_ids = std::collections::HashSet::with_capacity(trace.len());
    for r in trace {
        if !seen_ids.insert(r.id) {
            return Err(SimError::DuplicateRequestId(r.id));
        }
        if r.prompt_len == 0 {
            // A promptless request would masquerade as a decode slot and
            // never produce a first token (GenerationSpec's contract).
            return Err(SimError::EmptyPrompt(r.id));
        }
        // Under speculation the last verification window overshoots the
        // final context by up to `k` speculated tokens before truncating
        // back, so the worst-case footprint is total_len + k.
        let need = pager.config().blocks_for(r.total_len() + spec_k);
        if need > capacity {
            return Err(SimError::RequestTooLarge { id: r.id, need, capacity });
        }
    }
    let total_work: usize = trace.iter().map(|r| r.prompt_len + r.gen_len).sum();

    let mut arrivals: std::collections::VecDeque<RequestSpec> = {
        let mut v = trace.to_vec();
        v.sort_by(|a, b| {
            a.arrival_s.partial_cmp(&b.arrival_s).unwrap().then(a.id.cmp(&b.id))
        });
        v.into_iter().collect()
    };
    let mut waiting: std::collections::VecDeque<ReqState> = Default::default();
    let mut running: Vec<ReqState> = Vec::new();
    let mut completed: Vec<RequestMetrics> = Vec::new();

    let mut now = 0.0f64;
    let mut gpu_busy = 0.0f64;
    let mut iterations = 0usize;
    let mut preemptions = 0usize;
    let mut max_concurrency = 0usize;
    let mut kv_timeline: Vec<(f64, f64)> = Vec::new();
    let mut timeline_stride = 1usize;
    let mut spec_rounds = 0usize;
    let mut spec_draft_tokens = 0usize;
    let mut spec_accepted_tokens = 0usize;
    let mut spec_draft_busy = 0.0f64;

    while completed.len() < trace.len() {
        // Drain arrivals whose time has come.
        while arrivals.front().map(|r| r.arrival_s <= now).unwrap_or(false) {
            waiting.push_back(ReqState::new(arrivals.pop_front().unwrap()));
        }
        // Idle: jump to the next arrival.
        if running.is_empty() && waiting.is_empty() {
            let next = arrivals.front().expect("work remains").arrival_s;
            now = now.max(next);
            continue;
        }

        // --- admission ---
        let admit_allowed = match sched.mode {
            BatchingMode::Continuous => running.len() < sched.max_batch,
            // Static batching admits only between batches.
            BatchingMode::Static => running.is_empty(),
        };
        if admit_allowed && !waiting.is_empty() {
            let views: Vec<WaitingView> = waiting
                .iter()
                .enumerate()
                .map(|(queue_idx, r)| WaitingView {
                    queue_idx,
                    arrival_s: r.spec.arrival_s,
                    remaining_prompt: r.remaining_prefill(),
                    priority: r.spec.priority,
                    // What the prefix index would hand this request for
                    // free right now — the KV gate and the prefix-hit
                    // admission order both read it. Capped at prompt − 1
                    // so a fully-cached prompt still prefills one token
                    // for its first-token logits.
                    prefix_cached_tokens: if share_on && r.spec.prefix_tokens > 0 {
                        pager.prefix_hit_tokens(
                            r.spec.prefix_group,
                            r.spec.prefix_tokens,
                            r.spec.prompt_len - 1,
                        )
                    } else {
                        0
                    },
                })
                .collect();
            let order = sched.admission_order(&views);
            let mut picked: Vec<usize> = Vec::new();
            // Static mode reserves full-lifetime blocks so a batch never
            // preempts; continuous admits against the first chunk and
            // relies on preemption under pressure. Blocks the prefix
            // index already holds are shared — they cost a refcount, not
            // a free block, so they are excluded from the reservation
            // (counting each physical block once across sharers).
            let mut reserve = pager.blocks_in_use();
            for &qi in &order {
                if running.len() + picked.len() >= sched.max_batch {
                    break;
                }
                let r = &waiting[qi];
                let mapped = views[qi].prefix_cached_tokens;
                let bf = |t: usize| pager.config().blocks_for(t);
                let need = match sched.mode {
                    BatchingMode::Static => {
                        // Full lifetime minus the mapped prefix, plus one
                        // block of copy-on-write allowance if the mapped
                        // run ends mid-block (growing past it may fork) —
                        // keeps static batches preemption-free.
                        bf(r.spec.total_len()) - bf(mapped)
                            + (mapped % pager.config().block_tokens != 0) as usize
                    }
                    BatchingMode::Continuous => {
                        let chunk =
                            (r.remaining_prefill() - mapped).min(sched.chunk_tokens);
                        bf(mapped + chunk) - bf(mapped)
                    }
                };
                if reserve + need > capacity {
                    if sched.mode == BatchingMode::Continuous {
                        break; // FCFS head-of-line: wait for blocks
                    }
                    continue; // static: try a smaller member
                }
                reserve += need;
                picked.push(qi);
            }
            // Remove in descending queue order (so indices stay valid),
            // then append in *admission* order — plan_q hands the chunk
            // budget front to back, so the policy's priority (e.g.
            // shortest-prompt) must survive into the running order.
            let mut removed: Vec<(usize, ReqState)> = {
                let mut desc = picked.clone();
                desc.sort_unstable_by(|a, b| b.cmp(a));
                desc.into_iter()
                    .map(|qi| (qi, waiting.remove(qi).expect("picked from the queue")))
                    .collect()
            };
            for &qi in &picked {
                let pos = removed
                    .iter()
                    .position(|(q, _)| *q == qi)
                    .expect("every picked index was removed");
                let mut st = removed.swap_remove(pos).1;
                if share_on && st.spec.prefix_tokens > 0 {
                    // Bind to the template at admission: map the longest
                    // registered prefix run (refcount bumps, zero free
                    // blocks drawn). The mapped context is KV the request
                    // never prefills. First arrival maps nothing but
                    // records the template so its prefill publishes.
                    st.ctx_ready = pager.map_prefix(
                        st.spec.id,
                        st.spec.prefix_group,
                        st.spec.prefix_tokens,
                        st.spec.prompt_len - 1,
                    );
                    // Refcount-only: mapped blocks draw nothing from the
                    // free list, so the delta is zero by construction.
                    tc.emit(|| TraceEvent::KvEvent {
                        t_s: now,
                        kind: KvEventKind::MapPrefix,
                        request: st.spec.id,
                        delta_blocks: 0,
                        tokens: st.ctx_ready,
                        blocks_in_use: pager.blocks_in_use(),
                    });
                }
                running.push(st);
            }
        }
        max_concurrency = max_concurrency.max(running.len());
        if running.is_empty() {
            // Continuous admission hit the KV gate with nothing running:
            // impossible (an empty pager admits any legal request).
            debug_assert!(false, "admission stall with free pager");
            return Err(SimError::KvExhausted);
        }

        // --- plan query windows + grow the pager (preempt on pressure) ---
        let plan = loop {
            let views: Vec<RunningView> = running
                .iter()
                .map(|r| RunningView { remaining_prefill: r.remaining_prefill() })
                .collect();
            let plan = sched.plan_q(&views);
            let mut need = 0usize;
            for (r, p) in running.iter().zip(&plan) {
                if p.q == 0 {
                    continue;
                }
                let new_ctx = if r.remaining_prefill() > 0 {
                    r.ctx_ready + p.q
                } else {
                    // Decode appends this step's token — plus the k
                    // speculated tokens of the verification window, which
                    // must all hold KV until the acceptance draw rolls the
                    // rejects back.
                    r.ctx_ready + spec_k + 1
                };
                // Blocks this grow would actually draw: new blocks past
                // the request's current allocation (shared prefix blocks
                // it maps count as held — they cost nothing again), plus
                // the copy-on-write fork if this step writes a boundary
                // block other sharers still reference.
                need += pager.physical_need(r.spec.id, new_ctx);
            }
            if need <= pager.free_blocks() {
                break plan;
            }
            // vLLM recompute-preemption: evict the youngest running
            // sequence, drop its KV, and requeue it at the head of the
            // waiting queue to re-prefill (prompt + already-emitted
            // tokens) when blocks free up.
            if running.len() <= 1 {
                return Err(SimError::KvExhausted);
            }
            let mut victim = running.pop().expect("len > 1");
            if pager.holds(victim.spec.id) {
                // Refcounted release: blocks the victim shares with other
                // requests stay allocated for them — preempting a sharer
                // never frees a peer's prefix (so this may free nothing).
                let freed =
                    pager.release(victim.spec.id).expect("victim held an allocation");
                tc.emit(|| TraceEvent::KvEvent {
                    t_s: now,
                    kind: KvEventKind::Preempt,
                    request: victim.spec.id,
                    delta_blocks: -(freed as i64),
                    tokens: 0,
                    blocks_in_use: pager.blocks_in_use(),
                });
            }
            victim.ctx_ready = 0;
            victim.preemptions += 1;
            preemptions += 1;
            waiting.push_front(victim);
        };

        // --- commit growth + build the ragged iteration ---
        let mut slots: Vec<SeqSlot> = Vec::new();
        let mut active: Vec<usize> = Vec::new(); // running idx per slot
        // Speculative bookkeeping: prefill chunks the draft must ingest
        // in lockstep, and the committed contexts its decode rounds read.
        let mut draft_prefill: Vec<SeqSlot> = Vec::new();
        let mut draft_decode_ctx: Vec<usize> = Vec::new();
        for (i, (r, p)) in running.iter().zip(&plan).enumerate() {
            if p.q == 0 {
                continue;
            }
            let slot = if r.remaining_prefill() > 0 {
                if spec_k > 0 {
                    draft_prefill.push(SeqSlot::prefill(r.ctx_ready, p.q));
                }
                SeqSlot::prefill(r.ctx_ready, p.q)
            } else if spec_k > 0 {
                // Verification window: q = k + 1 new queries over the
                // speculated span (rectangular causal attention).
                draft_decode_ctx.push(r.ctx_ready);
                SeqSlot::prefill(r.ctx_ready, spec_k + 1)
            } else {
                SeqSlot::decode(r.ctx_ready)
            };
            let forks_before = pager.cow_forks();
            let drawn = pager
                .grow(r.spec.id, slot.kv_len)
                .expect("iteration demand was checked against free blocks");
            tc.emit(|| TraceEvent::KvEvent {
                t_s: now,
                kind: KvEventKind::Grow,
                request: r.spec.id,
                delta_blocks: drawn as i64,
                tokens: slot.kv_len,
                blocks_in_use: pager.blocks_in_use(),
            });
            if pager.cow_forks() > forks_before {
                // The forked block's draw is inside `drawn` above; this
                // marker (delta 0) just pins *when* a shared boundary
                // block went private.
                tc.emit(|| TraceEvent::KvEvent {
                    t_s: now,
                    kind: KvEventKind::Fork,
                    request: r.spec.id,
                    delta_blocks: 0,
                    tokens: slot.kv_len,
                    blocks_in_use: pager.blocks_in_use(),
                });
            }
            slots.push(slot);
            active.push(i);
        }
        debug_assert!(!slots.is_empty(), "a planned iteration cannot be empty");

        // --- price the iteration and advance virtual time ---
        // A speculative iteration costs the draft's work first — its own
        // prompt ingestion alongside target prefill chunks, then k
        // autoregressive draft steps over the decoding sequences — plus
        // the target pass over the ragged batch (verification windows
        // included). Draft and target run back to back on one device, so
        // the latencies sum.
        let mut dt_draft = 0.0f64;
        if spec_k > 0 {
            if !draft_prefill.is_empty() {
                dt_draft +=
                    price_slots(IterPhase::Draft, &draft_prefill).ok_or(SimError::Unsupported)?;
            }
            if !draft_decode_ctx.is_empty() {
                for j in 0..spec_k {
                    let step: Vec<SeqSlot> =
                        draft_decode_ctx.iter().map(|&c| SeqSlot::decode(c + j)).collect();
                    dt_draft +=
                        price_slots(IterPhase::Draft, &step).ok_or(SimError::Unsupported)?;
                }
            }
        }
        let dt = dt_draft + price_slots(IterPhase::Target, &slots).ok_or(SimError::Unsupported)?;
        now += dt;
        gpu_busy += dt;
        spec_draft_busy += dt_draft;
        iterations += 1;
        if iterations % timeline_stride == 0 {
            kv_timeline.push((now, pager.occupancy()));
            if kv_timeline.len() >= 1024 {
                let mut keep = 0usize;
                kv_timeline.retain(|_| {
                    keep += 1;
                    keep % 2 == 0
                });
                timeline_stride *= 2;
            }
        }
        // One span per counted iteration — the invariant the CLI and
        // `tests/obs_trace.rs` check against `ServingReport::iterations`.
        // Emitted before effects run, so slot state (prefill vs decode)
        // still describes what this iteration executed.
        tc.emit(|| {
            let prefill_slots =
                active.iter().filter(|&&i| running[i].remaining_prefill() > 0).count();
            TraceEvent::IterationSpan {
                iter: iterations - 1,
                start_s: now - dt,
                dur_s: dt,
                draft_dur_s: dt_draft,
                batch: slots.len(),
                prefill_slots,
                decode_slots: slots.len() - prefill_slots,
                q_tokens: slots.iter().map(|s| s.q_len).sum(),
                kv_tokens: slots.iter().map(|s| s.kv_len).sum(),
                slot_reqs: active.iter().map(|&i| running[i].spec.id).collect(),
            }
        });

        // --- apply effects: token progress, TTFT, completions ---
        for (&i, slot) in active.iter().zip(&slots) {
            let r = &mut running[i];
            // State is pre-iteration here: zero remaining prefill means
            // the slot was a decode step.
            if r.remaining_prefill() == 0 {
                if let Some(s) = spec.filter(|s| s.k > 0) {
                    // Verification outcome: a seeded per-(request,
                    // position) stream draws the leading accepted run τ —
                    // deterministic, replay-stable, independent of batch
                    // order. The round commits τ + 1 tokens (capped at
                    // the remaining generation) and the rejected
                    // speculated KV rolls back refcount-safely.
                    let mut rng = Rng::new(StableHasher::hash_of(&(
                        s.seed,
                        r.spec.id as u64,
                        r.decoded as u64,
                    )));
                    let tau = s.acceptance.sample(&mut rng, s.k);
                    let advance = (tau + 1).min(r.spec.gen_len - r.decoded);
                    let freed = pager
                        .truncate(r.spec.id, r.ctx_ready + advance)
                        .expect("verified slot held its speculated window");
                    r.decoded += advance;
                    r.ctx_ready += advance;
                    spec_rounds += 1;
                    spec_draft_tokens += s.k;
                    spec_accepted_tokens += tau;
                    tc.emit(|| TraceEvent::KvEvent {
                        t_s: now,
                        kind: KvEventKind::Truncate,
                        request: r.spec.id,
                        delta_blocks: -(freed as i64),
                        tokens: r.ctx_ready,
                        blocks_in_use: pager.blocks_in_use(),
                    });
                    tc.emit(|| TraceEvent::SpecRound {
                        t_s: now,
                        request: r.spec.id,
                        round: spec_rounds,
                        proposed: s.k,
                        accepted: tau,
                        committed: advance,
                    });
                    continue;
                }
                // Decode step: the appended token is now part of context.
                r.decoded += 1;
                r.ctx_ready += 1;
            } else {
                r.ctx_ready += slot.q_len;
                if r.remaining_prefill() == 0 && r.decoded == 0 && r.first_token_s.is_none()
                {
                    // Prefill complete: the LM head samples token one.
                    r.first_token_s = Some(now);
                }
            }
        }
        for i in (0..running.len()).rev() {
            if !running[i].done() {
                continue;
            }
            let r = running.remove(i);
            let freed = pager.release(r.spec.id).expect("completed request held blocks");
            tc.emit(|| TraceEvent::KvEvent {
                t_s: now,
                kind: KvEventKind::Release,
                request: r.spec.id,
                delta_blocks: -(freed as i64),
                tokens: 0,
                blocks_in_use: pager.blocks_in_use(),
            });
            completed.push(RequestMetrics {
                id: r.spec.id,
                arrival_s: r.spec.arrival_s,
                first_token_s: r.first_token_s.expect("done implies first token"),
                finish_s: now,
                prompt_len: r.spec.prompt_len,
                gen_len: r.spec.gen_len,
                preemptions: r.preemptions,
            });
        }

        // --- conservation audit (ISSUE invariant a): every event keeps
        // tokens admitted == tokens completed + tokens in flight ---
        #[cfg(debug_assertions)]
        {
            let inflight: usize = running
                .iter()
                .chain(waiting.iter())
                .map(ReqState::work_tokens)
                .sum();
            let done: usize = completed
                .iter()
                .map(|m| m.prompt_len + m.gen_len)
                .sum();
            let future: usize =
                arrivals.iter().map(|r| r.prompt_len + r.gen_len).sum();
            assert_eq!(done + inflight + future, total_work, "token conservation");
            assert_eq!(
                completed.len() + running.len() + waiting.len() + arrivals.len(),
                trace.len(),
                "request conservation"
            );
            assert!(pager.audit(), "pager block conservation");
        }
    }

    completed.sort_by_key(|m| m.id);
    // Every path builds its report through the unified metrics schema:
    // loop totals under `serving.*`/`spec.*`, the pager's own counters
    // via `KvPager::fill_registry` — so a path that forgot a counter
    // would zero it in the registry AND the report, never just one.
    // Gauges round-trip the f64 bits untouched (ReportBuilder contract),
    // keeping this construction bit-for-bit the old struct literal.
    let mut rb = ReportBuilder::new();
    {
        let reg = rb.registry_mut();
        reg.set(keys::ITERATIONS, iterations as u64);
        reg.set_gauge(keys::MAKESPAN_S, now);
        reg.set_gauge(keys::GPU_BUSY_S, gpu_busy);
        reg.set(keys::PREEMPTIONS, preemptions as u64);
        reg.set(keys::MAX_CONCURRENCY, max_concurrency as u64);
        reg.set(keys::SPEC_ROUNDS, spec_rounds as u64);
        reg.set(keys::SPEC_DRAFT_TOKENS, spec_draft_tokens as u64);
        reg.set(keys::SPEC_ACCEPTED_TOKENS, spec_accepted_tokens as u64);
        reg.set_gauge(keys::SPEC_DRAFT_BUSY_S, spec_draft_busy);
    }
    rb.absorb_pager(&pager);
    Ok(rb.with_completed(completed).with_kv_timeline(kv_timeline).build())
}

/// Replay `trace` on a tensor-parallel placement: every iteration graph
/// is rewritten by [`crate::graph::TensorParallelPass`] — Megatron-style
/// sharded GEMMs plus ring collectives — before pricing, so `price` sees
/// exactly what one rank executes. Symmetric ranks run in lockstep (the
/// collectives ARE the synchronization), so one rank's iteration latency
/// is the cluster's: the report's latencies and SLO curves are
/// cluster-level. `tp <= 1` delegates to [`simulate`] untouched, so the
/// single-device placement reproduces today's traces bit for bit.
pub fn simulate_placed<F>(
    cfg: &TransformerConfig,
    trace: &[RequestSpec],
    sim: &ServingSimConfig,
    tp: usize,
    price: &mut F,
) -> Result<ServingReport, SimError>
where
    F: FnMut(&ModelGraph) -> Option<f64>,
{
    simulate_hot(cfg, trace, sim, &HotPath::cold(tp), price)
}

/// One point of a throughput–latency sweep: the aggregates that matter
/// for capacity planning, without retaining the whole report.
#[derive(Clone, Copy, Debug)]
pub struct CapacityPoint {
    pub qps: f64,
    pub ttft_p50_s: f64,
    pub ttft_p99_s: f64,
    pub tpot_p50_s: f64,
    pub e2e_p99_s: f64,
    pub throughput_rps: f64,
    pub utilization: f64,
    pub peak_kv_occupancy: f64,
    pub preemptions: usize,
}

impl CapacityPoint {
    fn from_report(qps: f64, r: &ServingReport) -> CapacityPoint {
        CapacityPoint {
            qps,
            ttft_p50_s: r.ttft_percentile_s(50.0),
            ttft_p99_s: r.ttft_percentile_s(99.0),
            tpot_p50_s: r.tpot_percentile_s(50.0),
            e2e_p99_s: r.e2e_percentile_s(99.0),
            throughput_rps: r.throughput_rps(),
            utilization: r.utilization(),
            peak_kv_occupancy: r.peak_kv_occupancy(),
            preemptions: r.preemptions,
        }
    }
}

/// Sweep arrival rates over one *unit-rate* base trace (arrivals are
/// rescaled per point, request shapes held fixed — load is the only
/// variable). Returns one [`CapacityPoint`] per rate, in input order.
pub fn qps_sweep<F>(
    cfg: &TransformerConfig,
    unit_trace: &[RequestSpec],
    sim: &ServingSimConfig,
    price: &mut F,
    rates: &[f64],
) -> Result<Vec<CapacityPoint>, SimError>
where
    F: FnMut(&ModelGraph) -> Option<f64>,
{
    qps_sweep_hot(cfg, unit_trace, sim, &HotPath::cold(1), price, rates)
}

/// [`qps_sweep`] over a tensor-parallel placement: each point replays
/// through [`simulate_placed`], so the SLO curve is the cluster's.
pub fn qps_sweep_placed<F>(
    cfg: &TransformerConfig,
    unit_trace: &[RequestSpec],
    sim: &ServingSimConfig,
    tp: usize,
    price: &mut F,
    rates: &[f64],
) -> Result<Vec<CapacityPoint>, SimError>
where
    F: FnMut(&ModelGraph) -> Option<f64>,
{
    qps_sweep_hot(cfg, unit_trace, sim, &HotPath::cold(tp), price, rates)
}

/// Serial sweep with the full hot path. Rate points of one sweep share
/// `hp`'s caches — the same decode signatures recur at every rate, so
/// later points run almost entirely from the memo.
pub fn qps_sweep_hot<F>(
    cfg: &TransformerConfig,
    unit_trace: &[RequestSpec],
    sim: &ServingSimConfig,
    hp: &HotPath<'_>,
    price: &mut F,
    rates: &[f64],
) -> Result<Vec<CapacityPoint>, SimError>
where
    F: FnMut(&ModelGraph) -> Option<f64>,
{
    let mut out = Vec::with_capacity(rates.len());
    for &qps in rates {
        let trace = scale_arrivals(unit_trace, qps);
        let report = simulate_hot(cfg, &trace, sim, hp, price)?;
        out.push(CapacityPoint::from_report(qps, &report));
    }
    Ok(out)
}

/// [`qps_sweep_hot`] with the rate points fanned across a
/// `std::thread::scope` worker pool. Each point is an independent replay
/// over an immutable pricing function, so this needs `F: Fn + Sync` —
/// satisfied by the analytical stack (`Pm2Lat`/`Gpu` are shared
/// immutably, exactly as the coordinator's scalar fan-out already does)
/// but deliberately *not* by the PJRT-backed service closure, which is
/// `FnMut` and stays on the calling thread via the serial
/// [`qps_sweep_hot`] (the PJRT client's thread-affinity constraint).
///
/// Results are in input order and bit-identical to the serial sweep:
/// points are independent, and the shared memo can only ever serve
/// values the cold path would have computed identically.
pub fn qps_sweep_parallel<F>(
    cfg: &TransformerConfig,
    unit_trace: &[RequestSpec],
    sim: &ServingSimConfig,
    hp: &HotPath<'_>,
    price: &F,
    rates: &[f64],
    threads: usize,
) -> Result<Vec<CapacityPoint>, SimError>
where
    F: Fn(&ModelGraph) -> Option<f64> + Sync,
{
    let results = pool::parallel_map(rates, threads, |&qps| {
        let trace = scale_arrivals(unit_trace, qps);
        let mut p = |g: &ModelGraph| price(g);
        simulate_hot(cfg, &trace, sim, hp, &mut p)
            .map(|r| CapacityPoint::from_report(qps, &r))
    });
    results.into_iter().collect()
}

/// Find the maximum sustainable arrival rate whose p99 TTFT stays within
/// `slo_ttft_p99_s`, by doubling from `lo_qps` until the SLO breaks and
/// then log-bisecting for `steps` rounds (p99 TTFT is monotone in load —
/// the ISSUE's property (d) — so bisection is sound). Returns the best
/// passing rate (0.0 if even `lo_qps` violates) and every evaluated
/// point, in evaluation order, for the Pareto print-out.
pub fn max_qps_under_slo<F>(
    cfg: &TransformerConfig,
    unit_trace: &[RequestSpec],
    sim: &ServingSimConfig,
    price: &mut F,
    slo_ttft_p99_s: f64,
    lo_qps: f64,
    steps: usize,
) -> Result<(f64, Vec<CapacityPoint>), SimError>
where
    F: FnMut(&ModelGraph) -> Option<f64>,
{
    max_qps_under_slo_hot(
        cfg,
        unit_trace,
        sim,
        &HotPath::cold(1),
        price,
        slo_ttft_p99_s,
        lo_qps,
        steps,
    )
}

/// [`max_qps_under_slo`] with the full hot path: every probe point's
/// replay shares `hp`'s caches, so the bisection — which replays the
/// same population over and over at nearby rates — runs mostly from the
/// memo after the first probe.
#[allow(clippy::too_many_arguments)]
pub fn max_qps_under_slo_hot<F>(
    cfg: &TransformerConfig,
    unit_trace: &[RequestSpec],
    sim: &ServingSimConfig,
    hp: &HotPath<'_>,
    price: &mut F,
    slo_ttft_p99_s: f64,
    lo_qps: f64,
    steps: usize,
) -> Result<(f64, Vec<CapacityPoint>), SimError>
where
    F: FnMut(&ModelGraph) -> Option<f64>,
{
    assert!(lo_qps > 0.0 && slo_ttft_p99_s > 0.0);
    let mut eval = |qps: f64, out: &mut Vec<CapacityPoint>| -> Result<bool, SimError> {
        let trace = scale_arrivals(unit_trace, qps);
        let report = simulate_hot(cfg, &trace, sim, hp, price)?;
        let point = CapacityPoint::from_report(qps, &report);
        out.push(point);
        Ok(point.ttft_p99_s <= slo_ttft_p99_s)
    };
    let mut points = Vec::new();
    if !eval(lo_qps, &mut points)? {
        return Ok((0.0, points));
    }
    // Double until the SLO breaks (bounded — no workload survives 2^20×).
    let mut lo = lo_qps;
    let mut hi = lo_qps;
    let mut broke = false;
    for _ in 0..20 {
        hi *= 2.0;
        if !eval(hi, &mut points)? {
            broke = true;
            break;
        }
        lo = hi;
    }
    if !broke {
        return Ok((lo, points));
    }
    for _ in 0..steps {
        let mid = (lo * hi).sqrt();
        if eval(mid, &mut points)? {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    Ok((lo, points))
}

/// The SLO search with independent probe points priced on the worker
/// pool. Monotonicity (p99 TTFT never improves with load) is what makes
/// batched probing sound: within any wave the passes form a prefix, so
/// the bracket tightens exactly as it would probing serially.
///
/// Two changes of shape versus the serial search, same guarantees:
/// the doubling ladder evaluates `threads`-sized waves concurrently
/// (same 2^20 overall bound), and each refinement round places
/// `min(threads, 5)` *geometric* interior probes instead of one
/// midpoint — shrinking the bracket (k+1)× per round where bisection
/// manages 2×. The returned rate passes the SLO and some evaluated
/// higher rate fails it, exactly as for [`max_qps_under_slo`]; the
/// probe sequence (and therefore the exact knee estimate) differs.
#[allow(clippy::too_many_arguments)]
pub fn max_qps_under_slo_parallel<F>(
    cfg: &TransformerConfig,
    unit_trace: &[RequestSpec],
    sim: &ServingSimConfig,
    hp: &HotPath<'_>,
    price: &F,
    slo_ttft_p99_s: f64,
    lo_qps: f64,
    steps: usize,
    threads: usize,
) -> Result<(f64, Vec<CapacityPoint>), SimError>
where
    F: Fn(&ModelGraph) -> Option<f64> + Sync,
{
    assert!(lo_qps > 0.0 && slo_ttft_p99_s > 0.0);
    let mut points = Vec::new();
    let mut eval_wave = |rates: &[f64],
                         points: &mut Vec<CapacityPoint>|
     -> Result<Vec<bool>, SimError> {
        let pts = qps_sweep_parallel(cfg, unit_trace, sim, hp, price, rates, threads)?;
        let ok = pts.iter().map(|p| p.ttft_p99_s <= slo_ttft_p99_s).collect();
        points.extend(pts);
        Ok(ok)
    };
    if !eval_wave(&[lo_qps], &mut points)?[0] {
        return Ok((0.0, points));
    }
    let mut lo = lo_qps;
    let mut hi = None;
    let mut base = lo_qps;
    let mut doublings = 0usize;
    while hi.is_none() && doublings < 20 {
        let w = threads.clamp(2, 5).min(20 - doublings);
        let rates: Vec<f64> = (1..=w).map(|i| base * (1u64 << i) as f64).collect();
        doublings += w;
        let ok = eval_wave(&rates, &mut points)?;
        for (&q, &pass) in rates.iter().zip(&ok) {
            if pass {
                lo = lo.max(q);
            } else {
                hi = Some(q);
                break;
            }
        }
        base = *rates.last().expect("wave is non-empty");
    }
    let Some(mut hi) = hi else {
        return Ok((lo, points)); // the SLO survived the whole ladder
    };
    for _ in 0..steps {
        let ratio = hi / lo;
        if ratio <= 1.0 + 1e-9 {
            break;
        }
        let k = threads.clamp(1, 5);
        let mids: Vec<f64> =
            (1..=k).map(|i| lo * ratio.powf(i as f64 / (k + 1) as f64)).collect();
        let ok = eval_wave(&mids, &mut points)?;
        for (&q, &pass) in mids.iter().zip(&ok) {
            if pass {
                lo = lo.max(q);
            } else {
                hi = hi.min(q);
            }
        }
    }
    Ok((lo, points))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::Gpu;
    use crate::models::zoo;
    use crate::ops::DType;
    use crate::pm2lat::Pm2Lat;
    use crate::profiler::ProfileSpec;
    use crate::serving::kv_pager::KvPagerConfig;
    use crate::serving::policy::{Admission, BatchingMode};
    use crate::serving::trace::poisson_trace;

    fn quick_pl(dev: &str, dtype: DType) -> (Gpu, Pm2Lat) {
        let mut gpu = Gpu::by_name(dev).unwrap();
        let pl = Pm2Lat::build_dtypes(&mut gpu, &ProfileSpec::quick(), &[dtype], false);
        gpu.reset();
        (gpu, pl)
    }

    fn ample_sim(cfg: &crate::models::TransformerConfig) -> ServingSimConfig {
        ServingSimConfig {
            scheduler: SchedulerConfig::default(),
            pager: KvPagerConfig::for_model(cfg, 80e9, 16),
            streams: 1,
        }
    }

    #[test]
    fn property_batch_size_1_continuous_batching_reproduces_predict_generation() {
        // ISSUE acceptance: at concurrency 1 with an un-chunked prompt,
        // the simulator's iteration latencies ARE predict_generation's
        // latency curve, bit for bit.
        let (gpu, pl) = quick_pl("a100", DType::F32);
        let cfg = zoo::gpt2_large();
        let (prompt, gen) = (96usize, 5usize);
        let spec = crate::models::GenerationSpec::new(prompt, gen);
        let direct = pl.predict_generation(&gpu, &cfg, 1, &spec, 1).unwrap();

        let trace = vec![RequestSpec { prompt_len: prompt, gen_len: gen, ..RequestSpec::default() }];
        let mut sim = ample_sim(&cfg);
        sim.scheduler.chunk_tokens = prompt; // whole prompt in one iteration
        let mut curve: Vec<f64> = Vec::new();
        let mut price = |g: &ModelGraph| {
            let v = pl.predict_graph(&gpu, g, 1);
            if let Some(v) = v {
                curve.push(v);
            }
            v
        };
        let report = simulate(&cfg, &trace, &sim, &mut price).unwrap();
        assert_eq!(curve.len(), 1 + gen, "one prefill + gen decode iterations");
        assert_eq!(curve[0], direct.prefill_s, "prefill bit-for-bit");
        assert_eq!(&curve[1..], &direct.step_s[..], "decode curve bit-for-bit");
        let m = &report.completed[0];
        assert_eq!(m.ttft_s(), direct.prefill_s, "TTFT is the prefill latency");
        let rel = (m.e2e_s() - direct.total_s()).abs() / direct.total_s();
        assert!(rel < 1e-12, "E2E matches the generation total ({rel})");
        assert_eq!(report.iterations, 1 + gen);
        assert_eq!(report.preemptions, 0);
        assert_eq!(report.kv_leaked_blocks, 0);
    }

    #[test]
    fn placed_tp1_is_bit_identical_and_tp2_prices_rank_collectives() {
        let (gpu, pl) = quick_pl("a100", DType::F32);
        let cfg = zoo::gpt2_large();
        let trace = poisson_trace(8, 40.0, 96, 6, 7);
        let sim = ample_sim(&cfg);
        let mut price = |g: &ModelGraph| pl.predict_graph(&gpu, g, 1);
        let base = simulate(&cfg, &trace, &sim, &mut price).unwrap();
        // The single-device placement is the plain simulator, bit for bit.
        let tp1 = simulate_placed(&cfg, &trace, &sim, 1, &mut price).unwrap();
        assert_eq!(tp1.completed, base.completed);
        assert_eq!(tp1.makespan_s, base.makespan_s);
        assert_eq!(tp1.gpu_busy_s, base.gpu_busy_s);
        // tp=2 reprices every iteration as one rank's sharded graph: the
        // pricing callback must see collectives, everyone still finishes,
        // and the collectives keep the scaling sub-linear.
        let mut comm_nodes = 0usize;
        let mut price2 = |g: &ModelGraph| {
            comm_nodes += g
                .nodes()
                .iter()
                .filter(|n| matches!(n.op, crate::ops::Op::Comm(_)))
                .count();
            pl.predict_graph(&gpu, g, 1)
        };
        let tp2 = simulate_placed(&cfg, &trace, &sim, 2, &mut price2).unwrap();
        assert!(comm_nodes > 0, "rank iteration graphs must carry collectives");
        assert_eq!(tp2.completed.len(), trace.len());
        assert!(
            tp2.gpu_busy_s > base.gpu_busy_s / 2.0,
            "collectives forbid ideal 2× scaling: {} vs {}",
            tp2.gpu_busy_s,
            base.gpu_busy_s
        );
        assert_ne!(tp2.gpu_busy_s, base.gpu_busy_s, "sharding must change the price");
    }

    #[test]
    fn property_kv_pager_never_exceeds_capacity_and_frees_everything() {
        // ISSUE invariant (b): a starved pager preempts instead of
        // overflowing, and every block returns by the end. (The per-event
        // conservation checks of invariant (a) run as debug asserts on
        // this same loop.)
        let (gpu, pl) = quick_pl("a100", DType::F32);
        let cfg = zoo::gpt2_large();
        let trace = poisson_trace(24, 50.0, 96, 12, 11);
        let blocks_for_biggest = trace
            .iter()
            .map(|r| r.total_len().div_ceil(16))
            .max()
            .unwrap();
        // Room for ~2.5 of the largest requests: constant KV pressure.
        let sim = ServingSimConfig {
            scheduler: SchedulerConfig { max_batch: 8, ..SchedulerConfig::default() },
            pager: KvPagerConfig {
                block_tokens: 16,
                capacity_blocks: blocks_for_biggest * 5 / 2,
                prefix_share: false,
            },
            streams: 1,
        };
        let mut price = |g: &ModelGraph| pl.predict_graph(&gpu, g, 1);
        let report = simulate(&cfg, &trace, &sim, &mut price).unwrap();
        assert_eq!(report.completed.len(), trace.len(), "all requests finish");
        assert!(report.preemptions > 0, "pressure must force preemptions");
        assert!(report.peak_kv_blocks <= report.kv_capacity_blocks);
        assert_eq!(report.kv_leaked_blocks, 0, "no leaked blocks");
        assert!(report.completed.iter().all(|m| m.e2e_s() > 0.0));
        // A request the pager can never hold is rejected up front.
        let giant = vec![RequestSpec {
            prompt_len: 16 * sim.pager.capacity_blocks + 1,
            gen_len: 1,
            ..RequestSpec::default()
        }];
        assert!(matches!(
            simulate(&cfg, &giant, &sim, &mut price),
            Err(SimError::RequestTooLarge { .. })
        ));
    }

    #[test]
    fn property_p99_ttft_is_monotone_in_arrival_rate() {
        // ISSUE invariant (d): same request population, scaled arrival
        // intensity — p99 TTFT can only degrade as load rises.
        let (gpu, pl) = quick_pl("a100", DType::F32);
        let cfg = zoo::gpt2_large();
        let unit = poisson_trace(60, 1.0, 64, 6, 5);
        let sim = ServingSimConfig {
            scheduler: SchedulerConfig { max_batch: 8, chunk_tokens: 128, ..Default::default() },
            pager: KvPagerConfig::for_model(&cfg, 80e9, 16),
            streams: 1,
        };
        let mut price = |g: &ModelGraph| pl.predict_graph(&gpu, g, 1);
        // Anchor rates to the solo end-to-end time so the sweep spans
        // light load → saturation on every device profile.
        let solo = simulate(&cfg, &unit[..1], &sim, &mut price).unwrap();
        let e2e = solo.completed[0].e2e_s();
        let rates: Vec<f64> = [0.2, 1.0, 5.0, 25.0].iter().map(|k| k / e2e).collect();
        let points = qps_sweep(&cfg, &unit, &sim, &mut price, &rates).unwrap();
        for w in points.windows(2) {
            assert!(
                w[1].ttft_p99_s >= w[0].ttft_p99_s * (1.0 - 1e-9),
                "p99 TTFT fell as load rose: {} → {} (qps {} → {})",
                w[0].ttft_p99_s,
                w[1].ttft_p99_s,
                w[0].qps,
                w[1].qps
            );
        }
        // And the extremes are far apart: saturation queues for real.
        assert!(points.last().unwrap().ttft_p99_s > points[0].ttft_p99_s * 3.0);
    }

    #[test]
    fn continuous_batching_beats_static_on_ttft_under_load() {
        let (gpu, pl) = quick_pl("a100", DType::F32);
        let cfg = zoo::gpt2_large();
        // A burst of 12 mixed-size requests at t=0: static batching makes
        // later batches wait for full drains; continuous backfills.
        let trace: Vec<RequestSpec> = (0..12)
            .map(|id| RequestSpec {
                id,
                prompt_len: 64 + 32 * (id % 3),
                gen_len: 8 + 4 * (id % 4),
                ..RequestSpec::default()
            })
            .collect();
        let pager = KvPagerConfig::for_model(&cfg, 80e9, 16);
        let run = |mode: BatchingMode| {
            let sim = ServingSimConfig {
                scheduler: SchedulerConfig {
                    mode,
                    max_batch: 4,
                    chunk_tokens: 256,
                    admission: Admission::Fcfs,
                },
                pager,
                streams: 1,
            };
            let mut price = |g: &ModelGraph| pl.predict_graph(&gpu, g, 1);
            simulate(&cfg, &trace, &sim, &mut price).unwrap()
        };
        let stat = run(BatchingMode::Static);
        let cont = run(BatchingMode::Continuous);
        assert_eq!(stat.completed.len(), 12);
        assert_eq!(cont.completed.len(), 12);
        let mean = |r: &ServingReport| {
            r.completed.iter().map(RequestMetrics::ttft_s).sum::<f64>() / 12.0
        };
        assert!(
            mean(&cont) < mean(&stat),
            "continuous {} vs static {}",
            mean(&cont),
            mean(&stat)
        );
        // Static never preempts (admission reserves full lifetimes).
        assert_eq!(stat.preemptions, 0);
        // Both keep the GPU accountable: busy time within the makespan.
        for r in [&stat, &cont] {
            assert!(r.gpu_busy_s <= r.makespan_s * (1.0 + 1e-12));
            assert!(r.utilization() > 0.0 && r.utilization() <= 1.0);
            assert!(!r.kv_timeline.is_empty());
            assert!(r.kv_timeline.iter().all(|&(_, occ)| (0.0..=1.0).contains(&occ)));
        }
    }

    #[test]
    fn shortest_prompt_admission_improves_mean_ttft_on_mixed_queues() {
        let (gpu, pl) = quick_pl("a100", DType::F32);
        let cfg = zoo::gpt2_large();
        // One giant prompt ahead of many small ones, all queued at once,
        // concurrency 1: FCFS makes everyone eat the giant's prefill.
        let mut trace = vec![RequestSpec { prompt_len: 1024, gen_len: 2, ..RequestSpec::default() }];
        trace.extend((1..7).map(|id| RequestSpec {
            id,
            prompt_len: 32,
            gen_len: 2,
            ..RequestSpec::default()
        }));
        let pager = KvPagerConfig::for_model(&cfg, 80e9, 16);
        let run = |admission: Admission| {
            let sim = ServingSimConfig {
                scheduler: SchedulerConfig {
                    admission,
                    max_batch: 1,
                    ..SchedulerConfig::default()
                },
                pager,
                streams: 1,
            };
            let mut price = |g: &ModelGraph| pl.predict_graph(&gpu, g, 1);
            simulate(&cfg, &trace, &sim, &mut price).unwrap()
        };
        let fcfs = run(Admission::Fcfs);
        let sjf = run(Admission::ShortestPrompt);
        let mean_ttft = |r: &ServingReport| {
            r.completed.iter().map(RequestMetrics::ttft_s).sum::<f64>()
                / r.completed.len() as f64
        };
        assert!(mean_ttft(&sjf) < mean_ttft(&fcfs));
        // SJF priority must survive *within* one admission cohort too:
        // with both requests admitted in the same iteration, the chunk
        // budget flows to the short prompt first, so it finishes prefill
        // well before the giant does.
        let cohort = ServingSimConfig {
            scheduler: SchedulerConfig {
                admission: Admission::ShortestPrompt,
                max_batch: 2,
                chunk_tokens: 64,
                ..SchedulerConfig::default()
            },
            pager,
            streams: 1,
        };
        let pair = vec![
            RequestSpec { prompt_len: 1024, gen_len: 2, ..RequestSpec::default() },
            RequestSpec { id: 1, prompt_len: 32, gen_len: 2, ..RequestSpec::default() },
        ];
        let mut price = |g: &ModelGraph| pl.predict_graph(&gpu, g, 1);
        let r = simulate(&cfg, &pair, &cohort, &mut price).unwrap();
        let ttft = |id: usize| {
            r.completed.iter().find(|m| m.id == id).unwrap().ttft_s()
        };
        assert!(
            ttft(1) < ttft(0) / 2.0,
            "short prompt must not starve behind the cohort's giant: {} vs {}",
            ttft(1),
            ttft(0)
        );
        // Work conservation: both serve the same tokens, so GPU seconds
        // agree closely regardless of order.
        let rel = (fcfs.gpu_busy_s - sjf.gpu_busy_s).abs() / fcfs.gpu_busy_s;
        assert!(rel < 0.05, "ordering must not create or destroy work ({rel})");
    }

    #[test]
    fn unsupported_model_and_empty_trace_error() {
        let (gpu, pl) = quick_pl("t4", DType::F32); // no BF16 tables on T4
        let cfg = zoo::qwen3_0_6b(); // BF16 model
        let sim = ample_sim(&cfg);
        let trace = vec![RequestSpec { prompt_len: 16, gen_len: 2, ..RequestSpec::default() }];
        let mut price = |g: &ModelGraph| pl.predict_graph(&gpu, g, 1);
        assert_eq!(simulate(&cfg, &trace, &sim, &mut price), Err(SimError::Unsupported));
        assert_eq!(simulate(&cfg, &[], &sim, &mut price), Err(SimError::EmptyTrace));
        // Colliding ids would merge pager allocations — rejected up front.
        let dup = vec![
            RequestSpec { id: 3, prompt_len: 16, gen_len: 2, ..RequestSpec::default() },
            RequestSpec { id: 3, arrival_s: 0.1, prompt_len: 16, gen_len: 2, ..RequestSpec::default() },
        ];
        assert_eq!(
            simulate(&cfg, &dup, &sim, &mut price),
            Err(SimError::DuplicateRequestId(3))
        );
        // Promptless requests can never emit a first token — rejected.
        let bare = vec![RequestSpec { prompt_len: 0, gen_len: 1, ..RequestSpec::default() }];
        assert_eq!(simulate(&cfg, &bare, &sim, &mut price), Err(SimError::EmptyPrompt(0)));
        // Enc–dec models error instead of panicking in the graph builder.
        let t5 = crate::models::zoo::flan_t5_base();
        let one = vec![RequestSpec { prompt_len: 16, gen_len: 1, ..RequestSpec::default() }];
        assert_eq!(
            simulate(&t5, &one, &sim, &mut price),
            Err(SimError::EncDecUnsupported)
        );
    }

    #[test]
    fn max_qps_search_finds_the_slo_knee() {
        let (gpu, pl) = quick_pl("a100", DType::F32);
        let cfg = zoo::gpt2_large();
        let unit = poisson_trace(40, 1.0, 64, 4, 13);
        let sim = ServingSimConfig {
            scheduler: SchedulerConfig { max_batch: 8, chunk_tokens: 128, ..Default::default() },
            pager: KvPagerConfig::for_model(&cfg, 80e9, 16),
            streams: 1,
        };
        let mut price = |g: &ModelGraph| pl.predict_graph(&gpu, g, 1);
        // SLO: 4× the solo TTFT — loose enough to pass lightly-loaded,
        // tight enough that saturation violates it.
        let solo = simulate(&cfg, &unit[..1], &sim, &mut price).unwrap();
        let slo = solo.completed[0].ttft_s() * 4.0;
        let lo = 0.05 / solo.completed[0].e2e_s();
        let (max_qps, points) =
            max_qps_under_slo(&cfg, &unit, &sim, &mut price, slo, lo, 6).unwrap();
        assert!(max_qps > 0.0, "light load must satisfy the SLO");
        assert!(points.len() >= 3);
        // The found rate passes; some evaluated higher rate fails.
        let at = |q: f64| points.iter().find(|p| p.qps == q).unwrap();
        assert!(at(max_qps).ttft_p99_s <= slo);
        assert!(
            points.iter().any(|p| p.qps > max_qps && p.ttft_p99_s > slo),
            "the search must have witnessed a violation above the knee"
        );
    }

    #[test]
    fn memoized_replay_is_bit_identical_and_actually_hits() {
        let (gpu, pl) = quick_pl("a100", DType::F32);
        let cfg = zoo::gpt2_large();
        // Decode-heavy mixed load: many concurrent sequences, long decode
        // tails — the regime where signatures repeat.
        let trace = poisson_trace(16, 30.0, 48, 12, 3);
        let sim = ample_sim(&cfg);
        let mut price = |g: &ModelGraph| pl.predict_graph(&gpu, g, 1);
        let cold = simulate(&cfg, &trace, &sim, &mut price).unwrap();
        let cache = IterCache::default_sized();
        let passes = PassResultCache::default_sized();
        let scope = IterScope::new(&cfg, "a100", 1, 1);
        let hp = HotPath::memoized(1, scope, &cache, &passes);
        let warm1 = simulate_hot(&cfg, &trace, &sim, &hp, &mut price).unwrap();
        assert_eq!(warm1.completed, cold.completed, "memo must not change results");
        assert_eq!(warm1.makespan_s.to_bits(), cold.makespan_s.to_bits());
        assert_eq!(warm1.gpu_busy_s.to_bits(), cold.gpu_busy_s.to_bits());
        // Second replay prices every iteration from memory.
        let warm2 = simulate_hot(&cfg, &trace, &sim, &hp, &mut price).unwrap();
        assert_eq!(warm2.makespan_s.to_bits(), cold.makespan_s.to_bits());
        assert!(cache.hits() >= warm2.iterations as u64, "full-replay hit coverage");
        assert!(cache.hit_rate() > 0.0);
    }

    #[test]
    fn parallel_sweep_matches_serial_bit_for_bit() {
        let (gpu, pl) = quick_pl("a100", DType::F32);
        let cfg = zoo::gpt2_large();
        let unit = poisson_trace(24, 1.0, 48, 6, 9);
        let sim = ample_sim(&cfg);
        let mut price = |g: &ModelGraph| pl.predict_graph(&gpu, g, 1);
        let solo = simulate(&cfg, &unit[..1], &sim, &mut price).unwrap();
        let base = 1.0 / solo.completed[0].e2e_s();
        let rates: Vec<f64> = [0.5, 1.0, 2.0, 4.0].iter().map(|k| k * base).collect();
        let serial = qps_sweep(&cfg, &unit, &sim, &mut price, &rates).unwrap();
        let cache = IterCache::default_sized();
        let passes = PassResultCache::default_sized();
        let hp = HotPath::memoized(1, IterScope::new(&cfg, "a100", 1, 1), &cache, &passes);
        let par_price = |g: &ModelGraph| pl.predict_graph(&gpu, g, 1);
        let par =
            qps_sweep_parallel(&cfg, &unit, &sim, &hp, &par_price, &rates, 4).unwrap();
        assert_eq!(par.len(), serial.len());
        for (a, b) in par.iter().zip(&serial) {
            assert_eq!(a.qps, b.qps, "input order preserved");
            assert_eq!(a.ttft_p99_s.to_bits(), b.ttft_p99_s.to_bits());
            assert_eq!(a.e2e_p99_s.to_bits(), b.e2e_p99_s.to_bits());
            assert_eq!(a.throughput_rps.to_bits(), b.throughput_rps.to_bits());
            assert_eq!(a.preemptions, b.preemptions);
        }
        assert!(cache.hit_rate() > 0.0, "sweep points must share the memo");
    }

    #[test]
    fn parallel_slo_search_finds_a_sound_bracket() {
        let (gpu, pl) = quick_pl("a100", DType::F32);
        let cfg = zoo::gpt2_large();
        let unit = poisson_trace(40, 1.0, 64, 4, 13);
        let sim = ServingSimConfig {
            scheduler: SchedulerConfig { max_batch: 8, chunk_tokens: 128, ..Default::default() },
            pager: KvPagerConfig::for_model(&cfg, 80e9, 16),
            streams: 1,
        };
        let mut price = |g: &ModelGraph| pl.predict_graph(&gpu, g, 1);
        let solo = simulate(&cfg, &unit[..1], &sim, &mut price).unwrap();
        let slo = solo.completed[0].ttft_s() * 4.0;
        let lo = 0.05 / solo.completed[0].e2e_s();
        let cache = IterCache::default_sized();
        let passes = PassResultCache::default_sized();
        let hp = HotPath::memoized(1, IterScope::new(&cfg, "a100", 1, 1), &cache, &passes);
        let par_price = |g: &ModelGraph| pl.predict_graph(&gpu, g, 1);
        let (max_qps, points) = max_qps_under_slo_parallel(
            &cfg, &unit, &sim, &hp, &par_price, slo, lo, 3, 4,
        )
        .unwrap();
        assert!(max_qps > 0.0, "light load must satisfy the SLO");
        let at = |q: f64| points.iter().find(|p| p.qps == q).unwrap();
        assert!(at(max_qps).ttft_p99_s <= slo, "the returned rate passes");
        assert!(
            points.iter().any(|p| p.qps > max_qps && p.ttft_p99_s > slo),
            "a violation above the knee was witnessed"
        );
        // And the serial search agrees the returned rate is sustainable:
        // it sits at or below the serial knee's failing bracket.
        let (serial_max, serial_points) =
            max_qps_under_slo(&cfg, &unit, &sim, &mut price, slo, lo, 3).unwrap();
        assert!(serial_max > 0.0);
        let serial_fail = serial_points
            .iter()
            .filter(|p| p.ttft_p99_s > slo)
            .map(|p| p.qps)
            .fold(f64::INFINITY, f64::min);
        assert!(max_qps < serial_fail, "parallel knee below the serial violation");
    }
}
