//! Scheduling policies for the serving simulator: how waiting requests
//! are admitted and how an iteration's token budget is split.
//!
//! Two batching modes ship:
//!
//! * **Static batching** — the classic serving regime: admit a batch,
//!   run it to completion (whole-prompt prefill, then decode until every
//!   member finishes), admit the next. Simple, and the baseline every
//!   continuous-batching paper compares against.
//! * **Continuous batching** — the vLLM-style regime: admission happens
//!   every iteration, prefills are *chunked* to a per-iteration token
//!   budget so long prompts cannot stall running decodes, and decode
//!   slots ride along in the same mixed iteration.
//!
//! Admission order is its own axis: FCFS (arrival order) or
//! shortest-prompt-first (an SJF approximation that trades fairness for
//! mean TTFT). Policies are pure functions over small view structs, so
//! they unit-test without an event loop.

/// Admission order over the waiting queue.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Admission {
    /// Arrival order.
    Fcfs,
    /// Shortest remaining prompt first (ties by arrival). Approximates
    /// shortest-job-first on the prefill cost, which dominates TTFT.
    ShortestPrompt,
}

impl Admission {
    pub fn parse(s: &str) -> Option<Admission> {
        match s.to_ascii_lowercase().as_str() {
            "fcfs" => Some(Admission::Fcfs),
            "sjf" | "shortest" | "shortest-prompt" => Some(Admission::ShortestPrompt),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Admission::Fcfs => "fcfs",
            Admission::ShortestPrompt => "shortest-prompt",
        }
    }
}

/// Batching mode.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BatchingMode {
    Static,
    Continuous,
}

impl BatchingMode {
    pub fn parse(s: &str) -> Option<BatchingMode> {
        match s.to_ascii_lowercase().as_str() {
            "static" => Some(BatchingMode::Static),
            "continuous" | "vllm" => Some(BatchingMode::Continuous),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            BatchingMode::Static => "static",
            BatchingMode::Continuous => "continuous",
        }
    }
}

/// A scheduler: mode + admission order + the two capacity knobs.
#[derive(Clone, Copy, Debug)]
pub struct SchedulerConfig {
    pub mode: BatchingMode,
    pub admission: Admission,
    /// Max sequences running concurrently (batch width).
    pub max_batch: usize,
    /// Per-iteration prefill token budget (chunked prefill; continuous
    /// mode only — static batching always prefills whole prompts).
    pub chunk_tokens: usize,
}

impl Default for SchedulerConfig {
    fn default() -> SchedulerConfig {
        SchedulerConfig {
            mode: BatchingMode::Continuous,
            admission: Admission::Fcfs,
            max_batch: 32,
            chunk_tokens: 512,
        }
    }
}

/// What the admission policy sees of one waiting request.
#[derive(Clone, Copy, Debug)]
pub struct WaitingView {
    /// Position in the waiting queue (arrival order).
    pub queue_idx: usize,
    pub arrival_s: f64,
    /// Prompt tokens still to prefill (the SJF cost proxy).
    pub remaining_prompt: usize,
}

/// What the chunk planner sees of one running request.
#[derive(Clone, Copy, Debug)]
pub struct RunningView {
    /// Prompt tokens still to prefill; 0 means the request is decoding.
    pub remaining_prefill: usize,
}

/// The planned query window of one running request for the next
/// iteration. `q == 0` means the request sits this iteration out (its
/// prefill got no budget).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PlannedQ {
    pub q: usize,
}

impl SchedulerConfig {
    /// Order the waiting queue for admission: queue indices, most
    /// admittable first. FCFS returns arrival order; shortest-prompt
    /// sorts by remaining prefill (stable — ties keep arrival order).
    pub fn admission_order(&self, waiting: &[WaitingView]) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..waiting.len()).collect();
        if self.admission == Admission::ShortestPrompt {
            idx.sort_by_key(|&i| (waiting[i].remaining_prompt, waiting[i].queue_idx));
        }
        idx
    }

    /// Split the iteration's prefill budget across the running set, in
    /// running (admission) order. Decode requests always get `q = 1`;
    /// prefilling requests consume the chunk budget front to back, so
    /// the oldest prefill always progresses (≥ 1 token whenever any
    /// budget exists — the no-starvation guarantee). Static batching
    /// has no chunk budget: whole prompts prefill in one iteration.
    pub fn plan_q(&self, running: &[RunningView]) -> Vec<PlannedQ> {
        let mut budget = match self.mode {
            BatchingMode::Static => usize::MAX,
            BatchingMode::Continuous => self.chunk_tokens.max(1),
        };
        running
            .iter()
            .map(|r| {
                if r.remaining_prefill == 0 {
                    PlannedQ { q: 1 }
                } else {
                    let q = r.remaining_prefill.min(budget);
                    budget -= q;
                    PlannedQ { q }
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn waiting(specs: &[(f64, usize)]) -> Vec<WaitingView> {
        specs
            .iter()
            .enumerate()
            .map(|(i, &(arrival_s, remaining_prompt))| WaitingView {
                queue_idx: i,
                arrival_s,
                remaining_prompt,
            })
            .collect()
    }

    #[test]
    fn fcfs_keeps_arrival_order_sjf_sorts_by_prompt() {
        let w = waiting(&[(0.0, 900), (0.1, 10), (0.2, 100), (0.3, 10)]);
        let fcfs = SchedulerConfig::default();
        assert_eq!(fcfs.admission_order(&w), vec![0, 1, 2, 3]);
        let sjf = SchedulerConfig { admission: Admission::ShortestPrompt, ..fcfs };
        // Shortest prompts first; equal prompts keep arrival order.
        assert_eq!(sjf.admission_order(&w), vec![1, 3, 2, 0]);
    }

    #[test]
    fn chunk_budget_flows_front_to_back_over_prefills_only() {
        let cfg = SchedulerConfig { chunk_tokens: 256, ..SchedulerConfig::default() };
        let running = [
            RunningView { remaining_prefill: 0 },   // decoding
            RunningView { remaining_prefill: 100 }, // fits fully
            RunningView { remaining_prefill: 0 },   // decoding
            RunningView { remaining_prefill: 400 }, // gets the remainder
            RunningView { remaining_prefill: 50 },  // starved this round
        ];
        let plan = cfg.plan_q(&running);
        assert_eq!(
            plan.iter().map(|p| p.q).collect::<Vec<_>>(),
            vec![1, 100, 1, 156, 0]
        );
        // Decode slots never consume prefill budget.
        assert_eq!(plan[0].q + plan[2].q, 2);
    }

    #[test]
    fn static_mode_prefills_whole_prompts() {
        let cfg = SchedulerConfig {
            mode: BatchingMode::Static,
            chunk_tokens: 8, // ignored in static mode
            ..SchedulerConfig::default()
        };
        let plan = cfg.plan_q(&[
            RunningView { remaining_prefill: 5000 },
            RunningView { remaining_prefill: 1 },
        ]);
        assert_eq!(plan[0].q, 5000);
        assert_eq!(plan[1].q, 1);
    }

    #[test]
    fn oldest_prefill_always_progresses() {
        // The no-starvation guarantee: with any positive budget the first
        // prefilling request gets at least one token.
        let cfg = SchedulerConfig { chunk_tokens: 1, ..SchedulerConfig::default() };
        let plan = cfg.plan_q(&[
            RunningView { remaining_prefill: 0 },
            RunningView { remaining_prefill: 1_000_000 },
            RunningView { remaining_prefill: 7 },
        ]);
        assert_eq!(plan[1].q, 1);
        assert_eq!(plan[2].q, 0);
    }

    #[test]
    fn parse_names_round_trip() {
        for a in [Admission::Fcfs, Admission::ShortestPrompt] {
            assert_eq!(Admission::parse(a.name()), Some(a));
        }
        for m in [BatchingMode::Static, BatchingMode::Continuous] {
            assert_eq!(BatchingMode::parse(m.name()), Some(m));
        }
        assert_eq!(Admission::parse("sjf"), Some(Admission::ShortestPrompt));
        assert_eq!(BatchingMode::parse("vllm"), Some(BatchingMode::Continuous));
        assert!(Admission::parse("lifo").is_none());
        assert!(BatchingMode::parse("x").is_none());
    }
}
