//! Scheduling policies for the serving simulator: how waiting requests
//! are admitted and how an iteration's token budget is split.
//!
//! Two batching modes ship:
//!
//! * **Static batching** — the classic serving regime: admit a batch,
//!   run it to completion (whole-prompt prefill, then decode until every
//!   member finishes), admit the next. Simple, and the baseline every
//!   continuous-batching paper compares against.
//! * **Continuous batching** — the vLLM-style regime: admission happens
//!   every iteration, prefills are *chunked* to a per-iteration token
//!   budget so long prompts cannot stall running decodes, and decode
//!   slots ride along in the same mixed iteration.
//!
//! Admission order is its own axis: FCFS (arrival order),
//! shortest-prompt-first (an SJF approximation that trades fairness for
//! mean TTFT), strict priority (higher request classes preempt the queue
//! order), fair-share (deterministic round-robin across classes, so
//! one chatty tenant cannot starve the rest), or prefix-hit (largest
//! shared-prefix cache hit first — admit the requests whose prefill the
//! copy-on-write pager can skip). Policies are pure functions over small
//! view structs, so they unit-test without an event loop.

/// Admission order over the waiting queue.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Admission {
    /// Arrival order.
    Fcfs,
    /// Shortest remaining prompt first (ties by arrival). Approximates
    /// shortest-job-first on the prefill cost, which dominates TTFT.
    ShortestPrompt,
    /// Highest request priority first (ties by arrival). Strict: a
    /// waiting high class always beats every lower class.
    Priority,
    /// Round-robin across priority *classes* (class = the priority
    /// field as a tenant id): take the first waiter of each class in
    /// turn, cycling until the queue is ordered. Arrival order within a
    /// class; deterministic (classes cycle in ascending class id from
    /// the lowest present). An all-one-class queue degrades to FCFS.
    FairShare,
    /// Largest shared-prefix cache hit first (ties by arrival): admit
    /// the requests the copy-on-write KV pager can serve mostly from
    /// registered template blocks, maximizing skipped prefill per
    /// admission slot. With sharing off — or a trace with no shared
    /// prefixes — every hit is 0 and this degrades to FCFS.
    PrefixHit,
}

impl Admission {
    pub fn parse(s: &str) -> Option<Admission> {
        match s.to_ascii_lowercase().as_str() {
            "fcfs" => Some(Admission::Fcfs),
            "sjf" | "shortest" | "shortest-prompt" => Some(Admission::ShortestPrompt),
            "priority" => Some(Admission::Priority),
            "fair" | "fair-share" => Some(Admission::FairShare),
            "prefix" | "prefix-hit" => Some(Admission::PrefixHit),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Admission::Fcfs => "fcfs",
            Admission::ShortestPrompt => "shortest-prompt",
            Admission::Priority => "priority",
            Admission::FairShare => "fair-share",
            Admission::PrefixHit => "prefix-hit",
        }
    }
}

/// Batching mode.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BatchingMode {
    Static,
    Continuous,
}

impl BatchingMode {
    pub fn parse(s: &str) -> Option<BatchingMode> {
        match s.to_ascii_lowercase().as_str() {
            "static" => Some(BatchingMode::Static),
            "continuous" | "vllm" => Some(BatchingMode::Continuous),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            BatchingMode::Static => "static",
            BatchingMode::Continuous => "continuous",
        }
    }
}

/// A scheduler: mode + admission order + the two capacity knobs.
#[derive(Clone, Copy, Debug)]
pub struct SchedulerConfig {
    pub mode: BatchingMode,
    pub admission: Admission,
    /// Max sequences running concurrently (batch width).
    pub max_batch: usize,
    /// Per-iteration prefill token budget (chunked prefill; continuous
    /// mode only — static batching always prefills whole prompts).
    pub chunk_tokens: usize,
}

impl Default for SchedulerConfig {
    fn default() -> SchedulerConfig {
        SchedulerConfig {
            mode: BatchingMode::Continuous,
            admission: Admission::Fcfs,
            max_batch: 32,
            chunk_tokens: 512,
        }
    }
}

/// What the admission policy sees of one waiting request.
#[derive(Clone, Copy, Debug)]
pub struct WaitingView {
    /// Position in the waiting queue (arrival order).
    pub queue_idx: usize,
    pub arrival_s: f64,
    /// Prompt tokens still to prefill (the SJF cost proxy).
    pub remaining_prompt: usize,
    /// Scheduling class ([`crate::serving::RequestSpec::priority`]).
    pub priority: u8,
    /// Context tokens the KV pager's prefix index would hand this
    /// request for free right now (0 with sharing off or no template).
    pub prefix_cached_tokens: usize,
}

/// What the chunk planner sees of one running request.
#[derive(Clone, Copy, Debug)]
pub struct RunningView {
    /// Prompt tokens still to prefill; 0 means the request is decoding.
    pub remaining_prefill: usize,
}

/// The planned query window of one running request for the next
/// iteration. `q == 0` means the request sits this iteration out (its
/// prefill got no budget).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PlannedQ {
    pub q: usize,
}

impl SchedulerConfig {
    /// Order the waiting queue for admission: queue indices, most
    /// admittable first. FCFS returns arrival order; shortest-prompt
    /// sorts by remaining prefill; priority sorts descending by class;
    /// fair-share interleaves classes round-robin; prefix-hit sorts
    /// descending by cached prefix tokens. All orders are stable —
    /// ties keep arrival order — and every policy is a permutation of
    /// the queue (admission can reorder but never drop).
    pub fn admission_order(&self, waiting: &[WaitingView]) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..waiting.len()).collect();
        match self.admission {
            Admission::Fcfs => {}
            Admission::ShortestPrompt => {
                idx.sort_by_key(|&i| (waiting[i].remaining_prompt, waiting[i].queue_idx));
            }
            Admission::Priority => {
                idx.sort_by_key(|&i| {
                    (std::cmp::Reverse(waiting[i].priority), waiting[i].queue_idx)
                });
            }
            Admission::PrefixHit => {
                idx.sort_by_key(|&i| {
                    (std::cmp::Reverse(waiting[i].prefix_cached_tokens), waiting[i].queue_idx)
                });
            }
            Admission::FairShare => {
                // One FIFO lane per class (ascending class id), then deal
                // one request from each non-empty lane per round.
                let mut lanes: Vec<(u8, Vec<usize>)> = Vec::new();
                idx.sort_by_key(|&i| (waiting[i].priority, waiting[i].queue_idx));
                for i in idx.drain(..) {
                    match lanes.last_mut() {
                        Some((c, lane)) if *c == waiting[i].priority => lane.push(i),
                        _ => lanes.push((waiting[i].priority, vec![i])),
                    }
                }
                let mut cursors = vec![0usize; lanes.len()];
                while idx.len() < waiting.len() {
                    for (l, (_, lane)) in lanes.iter().enumerate() {
                        if cursors[l] < lane.len() {
                            idx.push(lane[cursors[l]]);
                            cursors[l] += 1;
                        }
                    }
                }
            }
        }
        idx
    }

    /// Split the iteration's prefill budget across the running set, in
    /// running (admission) order. Decode requests always get `q = 1`;
    /// prefilling requests consume the chunk budget front to back, so
    /// the oldest prefill always progresses (≥ 1 token whenever any
    /// budget exists — the no-starvation guarantee). Static batching
    /// has no chunk budget: whole prompts prefill in one iteration.
    pub fn plan_q(&self, running: &[RunningView]) -> Vec<PlannedQ> {
        let mut budget = match self.mode {
            BatchingMode::Static => usize::MAX,
            BatchingMode::Continuous => self.chunk_tokens.max(1),
        };
        running
            .iter()
            .map(|r| {
                if r.remaining_prefill == 0 {
                    PlannedQ { q: 1 }
                } else {
                    let q = r.remaining_prefill.min(budget);
                    budget -= q;
                    PlannedQ { q }
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn waiting(specs: &[(f64, usize)]) -> Vec<WaitingView> {
        specs
            .iter()
            .enumerate()
            .map(|(i, &(arrival_s, remaining_prompt))| WaitingView {
                queue_idx: i,
                arrival_s,
                remaining_prompt,
                priority: 0,
                prefix_cached_tokens: 0,
            })
            .collect()
    }

    fn classed(specs: &[(usize, u8)]) -> Vec<WaitingView> {
        specs
            .iter()
            .enumerate()
            .map(|(i, &(remaining_prompt, priority))| WaitingView {
                queue_idx: i,
                arrival_s: i as f64,
                remaining_prompt,
                priority,
                prefix_cached_tokens: 0,
            })
            .collect()
    }

    #[test]
    fn fcfs_keeps_arrival_order_sjf_sorts_by_prompt() {
        let w = waiting(&[(0.0, 900), (0.1, 10), (0.2, 100), (0.3, 10)]);
        let fcfs = SchedulerConfig::default();
        assert_eq!(fcfs.admission_order(&w), vec![0, 1, 2, 3]);
        let sjf = SchedulerConfig { admission: Admission::ShortestPrompt, ..fcfs };
        // Shortest prompts first; equal prompts keep arrival order.
        assert_eq!(sjf.admission_order(&w), vec![1, 3, 2, 0]);
    }

    #[test]
    fn priority_admits_high_classes_first_with_stable_ties() {
        let w = classed(&[(100, 0), (100, 2), (100, 1), (100, 2), (100, 0)]);
        let cfg = SchedulerConfig {
            admission: Admission::Priority,
            ..SchedulerConfig::default()
        };
        assert_eq!(cfg.admission_order(&w), vec![1, 3, 2, 0, 4]);
        // All-equal classes degrade to FCFS.
        let flat = classed(&[(10, 3), (20, 3), (30, 3)]);
        assert_eq!(cfg.admission_order(&flat), vec![0, 1, 2]);
    }

    #[test]
    fn fair_share_interleaves_classes_round_robin() {
        // Class 0 floods the queue; class 1 and 2 each have stragglers.
        let w = classed(&[(1, 0), (1, 0), (1, 0), (1, 1), (1, 0), (1, 2), (1, 1)]);
        let cfg = SchedulerConfig {
            admission: Admission::FairShare,
            ..SchedulerConfig::default()
        };
        let order = cfg.admission_order(&w);
        // Round 1: first of class 0, 1, 2 → 0, 3, 5. Round 2: 1, 6.
        // Round 3+: class 0's leftovers in arrival order.
        assert_eq!(order, vec![0, 3, 5, 1, 6, 2, 4]);
        // Every policy emits a permutation of the queue.
        for adm in [
            Admission::Fcfs,
            Admission::ShortestPrompt,
            Admission::Priority,
            Admission::FairShare,
            Admission::PrefixHit,
        ] {
            let cfg = SchedulerConfig { admission: adm, ..SchedulerConfig::default() };
            let mut o = cfg.admission_order(&w);
            o.sort_unstable();
            assert_eq!(o, (0..w.len()).collect::<Vec<_>>(), "{}", adm.name());
        }
        // One class only → FCFS order (the degenerate single-tenant case).
        let flat = classed(&[(9, 5), (8, 5), (7, 5)]);
        assert_eq!(cfg.admission_order(&flat), vec![0, 1, 2]);
    }

    #[test]
    fn prefix_hit_admits_largest_cache_hits_first() {
        let mut w = waiting(&[(0.0, 300), (0.1, 300), (0.2, 300), (0.3, 300)]);
        w[1].prefix_cached_tokens = 256;
        w[3].prefix_cached_tokens = 64;
        let cfg = SchedulerConfig {
            admission: Admission::PrefixHit,
            ..SchedulerConfig::default()
        };
        // Biggest hit first; zero-hit requests keep arrival order.
        assert_eq!(cfg.admission_order(&w), vec![1, 3, 0, 2]);
        // All-zero hits (sharing off, or a private trace) == FCFS.
        let flat = waiting(&[(0.0, 10), (0.1, 20), (0.2, 30)]);
        assert_eq!(cfg.admission_order(&flat), vec![0, 1, 2]);
    }

    #[test]
    fn chunk_budget_flows_front_to_back_over_prefills_only() {
        let cfg = SchedulerConfig { chunk_tokens: 256, ..SchedulerConfig::default() };
        let running = [
            RunningView { remaining_prefill: 0 },   // decoding
            RunningView { remaining_prefill: 100 }, // fits fully
            RunningView { remaining_prefill: 0 },   // decoding
            RunningView { remaining_prefill: 400 }, // gets the remainder
            RunningView { remaining_prefill: 50 },  // starved this round
        ];
        let plan = cfg.plan_q(&running);
        assert_eq!(
            plan.iter().map(|p| p.q).collect::<Vec<_>>(),
            vec![1, 100, 1, 156, 0]
        );
        // Decode slots never consume prefill budget.
        assert_eq!(plan[0].q + plan[2].q, 2);
    }

    #[test]
    fn static_mode_prefills_whole_prompts() {
        let cfg = SchedulerConfig {
            mode: BatchingMode::Static,
            chunk_tokens: 8, // ignored in static mode
            ..SchedulerConfig::default()
        };
        let plan = cfg.plan_q(&[
            RunningView { remaining_prefill: 5000 },
            RunningView { remaining_prefill: 1 },
        ]);
        assert_eq!(plan[0].q, 5000);
        assert_eq!(plan[1].q, 1);
    }

    #[test]
    fn oldest_prefill_always_progresses() {
        // The no-starvation guarantee: with any positive budget the first
        // prefilling request gets at least one token.
        let cfg = SchedulerConfig { chunk_tokens: 1, ..SchedulerConfig::default() };
        let plan = cfg.plan_q(&[
            RunningView { remaining_prefill: 0 },
            RunningView { remaining_prefill: 1_000_000 },
            RunningView { remaining_prefill: 7 },
        ]);
        assert_eq!(plan[1].q, 1);
        assert_eq!(plan[2].q, 0);
    }

    #[test]
    fn parse_names_round_trip() {
        for a in [
            Admission::Fcfs,
            Admission::ShortestPrompt,
            Admission::Priority,
            Admission::FairShare,
            Admission::PrefixHit,
        ] {
            assert_eq!(Admission::parse(a.name()), Some(a));
        }
        for m in [BatchingMode::Static, BatchingMode::Continuous] {
            assert_eq!(BatchingMode::parse(m.name()), Some(m));
        }
        assert_eq!(Admission::parse("sjf"), Some(Admission::ShortestPrompt));
        assert_eq!(Admission::parse("fair"), Some(Admission::FairShare));
        assert_eq!(Admission::parse("prefix"), Some(Admission::PrefixHit));
        assert_eq!(BatchingMode::parse("vllm"), Some(BatchingMode::Continuous));
        assert!(Admission::parse("lifo").is_none());
        assert!(BatchingMode::parse("x").is_none());
    }
}
