//! Iteration-level memoized pricing for the serving hot path.
//!
//! Decode-heavy serving traces repeat a small set of batch signatures
//! thousands of times: once every running sequence is past prefill, the
//! iteration is "B decode slots at kv lengths k₁…k_B", and consecutive
//! iterations differ only by +1 on each kv length — across a long replay
//! (and *especially* across the points of a QPS sweep, which replay the
//! same population at different arrival rates) the same signatures recur
//! constantly. Rebuilding and re-pricing a fresh
//! [`crate::models::TransformerConfig::mixed_batch_graph`] for each one
//! is pure recomputation.
//!
//! [`IterCache`] memoizes the *iteration latency itself*, keyed by a
//! canonical [`IterationKey`] computed straight from the `&[SeqSlot]`
//! batch — before any graph exists. A hit skips graph construction,
//! every rewrite pass (tensor-parallel sharding included), and all
//! per-node prediction.
//!
//! Exactness contract. Pricing is deterministic, so a hit must be
//! bit-identical to the cold path. Two ingredients make that true:
//!
//! * The key is **order-insensitive**: slots are sorted by
//!   `(q_len, kv_len)`. `mixed_batch_graph` only reads those two fields,
//!   so two batches with equal sorted signatures build *node-identical*
//!   graphs — provided the simulator also builds the graph from the same
//!   canonical order. [`canonical_slots`] is that shared ordering; the
//!   simulator uses it on cold paths too, so the f64 summation order
//!   (and hence the last-ulp of the makespan) is a function of the key.
//! * The key is **exact**, not a hash: the full sorted `(q_len, kv_len)`
//!   vector is stored and compared, so distinct signatures can never
//!   alias. [`IterScope`] folds in everything else the price depends on
//!   (model shape, dtype, device, pricing lane, tensor-parallel degree,
//!   stream count) as a stable 64-bit tag — scopes are few (typically
//!   one per replay) and chosen by the caller, so a tag collision would
//!   require two *deliberately different* scopes hashing equal.
//!
//! The cache is `Sync` (one mutex around an arena-backed LRU — the same
//! O(1) recency structure as `coordinator/cache.rs`, unsharded because
//! iteration pricing is orders of magnitude coarser than per-op lookups)
//! so one instance can be shared across the worker threads of a parallel
//! QPS sweep: whichever rate point prices a signature first populates it
//! for every other point.
//!
//! Observability: a traced replay ([`crate::serving::simulate_traced`])
//! emits one `iter-memo` [`crate::obs::TraceEvent::CacheProbe`] per
//! lookup, and the hit/miss totals those probes sum to are exactly
//! [`IterCache::hits`] / [`IterCache::misses`] — the conservation test
//! in `rust/tests/obs_trace.rs` pins that equality. Note the flip side
//! for kernel-level tracing: a memo hit skips pricing entirely, so no
//! per-node records appear for memoized iterations.

use std::collections::HashMap;
use std::hash::Hash;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::models::{SeqSlot, TransformerConfig};
use crate::util::prng::StableHasher;

/// Default entry bound: decode signatures are small (a few hundred bytes
/// each), so 16 Ki entries is a few MB — enough for every kv-bucket
/// signature of a long replay plus a whole sweep's worth of variants.
pub const DEFAULT_ITER_CACHE_CAPACITY: usize = 1 << 14;

/// Everything an iteration's price depends on *besides* the slot batch.
/// One scope per (model, device, pricing lane, tp, streams) replay; the
/// scope is folded into every [`IterationKey`] as a stable tag so one
/// shared cache can serve many scopes without aliasing.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub struct IterScope {
    /// Stable hash of the model shape (dims, dtype, gating).
    pub model: u64,
    /// Stable hash of the device name the pricing backend targets.
    pub device: u64,
    /// Caller-chosen pricing-lane tag (e.g. direct vs batched-PJRT
    /// service path — the two agree only to ~1e-3 relative, so their
    /// memoized values must never mix). 0 for a single-lane replay.
    pub lane: u64,
    /// Tensor-parallel degree the iteration graph is rewritten to.
    pub tp: u16,
    /// Stream count of the per-iteration schedule.
    pub streams: u16,
    /// Stable hash of the KV-pager configuration (block size, capacity,
    /// prefix sharing). Iteration *prices* do not read the pager, but the
    /// slot batches a replay produces do — two replays under different KV
    /// semantics must not share memo entries, or a sweep comparing
    /// sharing on/off would cross-pollinate its lanes. 0 for callers
    /// outside a pager's reach.
    pub pager: u64,
    /// Stable hash of the speculative-decoding semantics (draft model
    /// shape, draft length k, acceptance model) the replay runs under —
    /// see [`crate::spec_decode::SpecConfig::scope_tag`]. Speculation
    /// changes which slot batches a replay produces (verification
    /// windows, draft decode rounds) and which model a batch is priced
    /// for, so memo entries must never mix across k/acceptance
    /// configurations. Deliberately excludes the stochastic seed: prices
    /// are seed-independent, so sweeps across seeds share entries. 0 for
    /// non-speculative replays.
    pub spec: u64,
}

impl IterScope {
    /// Scope for pricing `cfg` on `device` at `tp`-way tensor parallelism
    /// with `streams`-wide schedules. The model tag hashes every field of
    /// the config that shapes an iteration graph.
    pub fn new(
        cfg: &TransformerConfig,
        device: &str,
        tp: usize,
        streams: usize,
    ) -> IterScope {
        let model = StableHasher::hash_of(&(
            cfg.name,
            cfg.layers,
            cfg.enc_layers,
            cfg.hidden,
            cfg.heads,
            cfg.kv_heads,
            cfg.ffn_hidden,
            cfg.vocab,
            cfg.dtype,
            cfg.gated_ffn,
        ));
        IterScope {
            model,
            device: StableHasher::hash_of(&device),
            lane: 0,
            tp: tp as u16,
            streams: streams as u16,
            pager: 0,
            spec: 0,
        }
    }

    /// Same scope under a different pricing lane (direct vs service).
    pub fn with_lane(mut self, lane: u64) -> IterScope {
        self.lane = lane;
        self
    }

    /// Same scope under a specific KV-pager configuration, so replays
    /// with different paging semantics (block size, capacity, prefix
    /// sharing on/off) can never collide in a shared cache.
    pub fn with_pager(mut self, pager: &crate::serving::KvPagerConfig) -> IterScope {
        self.pager = StableHasher::hash_of(&(
            pager.block_tokens,
            pager.capacity_blocks,
            pager.prefix_share,
        ));
        self
    }

    /// Same scope under specific speculative-decoding semantics, so a
    /// speculative replay can never share memo entries with the plain
    /// path (or with a different k/acceptance) in a shared cache.
    pub fn with_spec(mut self, spec: &crate::spec_decode::SpecConfig) -> IterScope {
        self.spec = spec.scope_tag();
        self
    }

    /// The 64-bit tag folded into every key under this scope.
    pub fn tag(&self) -> u64 {
        StableHasher::hash_of(&(
            self.model,
            self.device,
            self.lane,
            self.tp,
            self.streams,
            self.pager,
            self.spec,
        ))
    }
}

/// Canonical signature of one priced iteration: the scope tag plus the
/// *sorted* `(q_len, kv_len)` multiset of the slot batch. Exact — the
/// full vector is compared on lookup, so equal keys imply node-identical
/// canonical graphs (a slot's role is determined by its shape:
/// `mixed_batch_graph` reads nothing but `q_len`/`kv_len`).
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct IterationKey {
    scope: u64,
    slots: Vec<(u32, u32)>,
}

impl IterationKey {
    /// Key for pricing `slots` under `scope`. Order-insensitive: any
    /// permutation of the batch yields the same key.
    pub fn new(scope: IterScope, slots: &[SeqSlot]) -> IterationKey {
        let mut v: Vec<(u32, u32)> =
            slots.iter().map(|s| (s.q_len as u32, s.kv_len as u32)).collect();
        v.sort_unstable();
        IterationKey { scope: scope.tag(), slots: v }
    }

    /// Number of slots in the signature.
    pub fn batch(&self) -> usize {
        self.slots.len()
    }
}

/// The batch in the canonical order the key (and therefore the memoized
/// price) is defined over: sorted by `(q_len, kv_len)`. The simulator
/// builds every iteration graph from this order — cold paths included —
/// so the price of a batch is a pure function of its [`IterationKey`],
/// down to the last ulp of the f64 makespan summation.
pub fn canonical_slots(slots: &[SeqSlot]) -> Vec<SeqSlot> {
    let mut v = slots.to_vec();
    v.sort_unstable_by_key(|s| (s.q_len, s.kv_len));
    v
}

const NIL: usize = usize::MAX;

struct Entry {
    key: IterationKey,
    value: f64,
    prev: usize,
    next: usize,
}

/// Arena-backed intrusive LRU (head = most recently used); same shape as
/// the coordinator cache's shard, specialized to iteration keys.
struct Lru {
    map: HashMap<IterationKey, usize>,
    entries: Vec<Entry>,
    head: usize,
    tail: usize,
    free: Vec<usize>,
}

impl Lru {
    fn new() -> Lru {
        Lru { map: HashMap::new(), entries: Vec::new(), head: NIL, tail: NIL, free: Vec::new() }
    }

    fn detach(&mut self, i: usize) {
        let (p, n) = (self.entries[i].prev, self.entries[i].next);
        if p == NIL {
            self.head = n;
        } else {
            self.entries[p].next = n;
        }
        if n == NIL {
            self.tail = p;
        } else {
            self.entries[n].prev = p;
        }
        self.entries[i].prev = NIL;
        self.entries[i].next = NIL;
    }

    fn attach_front(&mut self, i: usize) {
        self.entries[i].prev = NIL;
        self.entries[i].next = self.head;
        if self.head != NIL {
            self.entries[self.head].prev = i;
        }
        self.head = i;
        if self.tail == NIL {
            self.tail = i;
        }
    }

    fn get(&mut self, key: &IterationKey) -> Option<f64> {
        let i = *self.map.get(key)?;
        if self.head != i {
            self.detach(i);
            self.attach_front(i);
        }
        Some(self.entries[i].value)
    }

    /// Returns true when an LRU entry was evicted to make room.
    fn insert(&mut self, key: IterationKey, value: f64, capacity: usize) -> bool {
        if let Some(&i) = self.map.get(&key) {
            self.entries[i].value = value;
            if self.head != i {
                self.detach(i);
                self.attach_front(i);
            }
            return false;
        }
        let mut evicted = false;
        if self.map.len() >= capacity {
            let lru = self.tail;
            self.detach(lru);
            let old = std::mem::replace(
                &mut self.entries[lru].key,
                IterationKey { scope: 0, slots: Vec::new() },
            );
            self.map.remove(&old);
            self.free.push(lru);
            evicted = true;
        }
        let entry = Entry { key: key.clone(), value, prev: NIL, next: NIL };
        let i = match self.free.pop() {
            Some(slot) => {
                self.entries[slot] = entry;
                slot
            }
            None => {
                self.entries.push(entry);
                self.entries.len() - 1
            }
        };
        self.map.insert(key, i);
        self.attach_front(i);
        evicted
    }
}

/// The shared, `Sync` iteration-price memo. Capacity 0 disables it (every
/// lookup misses, nothing is stored) — the off-switch `serve-sim
/// --no-iter-cache` uses.
pub struct IterCache {
    inner: Mutex<Lru>,
    capacity: usize,
    hits: AtomicU64,
    misses: AtomicU64,
    insertions: AtomicU64,
    evictions: AtomicU64,
}

impl IterCache {
    pub fn new(capacity: usize) -> IterCache {
        IterCache {
            inner: Mutex::new(Lru::new()),
            capacity,
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            insertions: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
        }
    }

    /// The default-sized cache every hot path starts from.
    pub fn default_sized() -> IterCache {
        IterCache::new(DEFAULT_ITER_CACHE_CAPACITY)
    }

    pub fn enabled(&self) -> bool {
        self.capacity > 0
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn get(&self, key: &IterationKey) -> Option<f64> {
        if !self.enabled() {
            return None;
        }
        let v = self.inner.lock().unwrap().get(key);
        match v {
            Some(_) => self.hits.fetch_add(1, Ordering::Relaxed),
            None => self.misses.fetch_add(1, Ordering::Relaxed),
        };
        v
    }

    pub fn insert(&self, key: IterationKey, value: f64) {
        if !self.enabled() {
            return;
        }
        let evicted = self.inner.lock().unwrap().insert(key, value, self.capacity);
        self.insertions.fetch_add(1, Ordering::Relaxed);
        if evicted {
            self.evictions.fetch_add(1, Ordering::Relaxed);
        }
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn clear(&self) {
        *self.inner.lock().unwrap() = Lru::new();
    }

    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    pub fn evictions(&self) -> u64 {
        self.evictions.load(Ordering::Relaxed)
    }

    /// Lookups served from memory, as a fraction of all lookups.
    pub fn hit_rate(&self) -> f64 {
        let h = self.hits() as f64;
        let m = self.misses() as f64;
        if h + m > 0.0 {
            h / (h + m)
        } else {
            0.0
        }
    }

    /// One-line operator summary for CLI/bench output.
    pub fn stats(&self) -> String {
        format!(
            "iter-cache: {} entries (cap {}), {} hits / {} misses ({:.1}% hit rate), {} evictions",
            self.len(),
            self.capacity,
            self.hits(),
            self.misses(),
            self.hit_rate() * 100.0,
            self.evictions(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::zoo;

    fn slots(sig: &[(usize, usize)]) -> Vec<SeqSlot> {
        sig.iter().map(|&(q, kv)| SeqSlot { q_len: q, kv_len: kv }).collect()
    }

    #[test]
    fn key_is_order_insensitive_and_exact() {
        let scope = IterScope::new(&zoo::gpt2_large(), "a100", 1, 1);
        let a = IterationKey::new(scope, &slots(&[(1, 33), (1, 97), (64, 64)]));
        let b = IterationKey::new(scope, &slots(&[(64, 64), (1, 97), (1, 33)]));
        assert_eq!(a, b, "any permutation of the batch is the same key");
        assert_eq!(a.batch(), 3);
        // Different multisets — even with equal sums — are different keys.
        let c = IterationKey::new(scope, &slots(&[(1, 34), (1, 96), (64, 64)]));
        assert_ne!(a, c);
        // Multiplicity matters: {x, x} is not {x}.
        let d1 = IterationKey::new(scope, &slots(&[(1, 50)]));
        let d2 = IterationKey::new(scope, &slots(&[(1, 50), (1, 50)]));
        assert_ne!(d1, d2);
    }

    #[test]
    fn scope_discriminates_every_dimension() {
        let cfg = zoo::gpt2_large();
        let batch = slots(&[(1, 128)]);
        let base = IterScope::new(&cfg, "a100", 1, 1);
        let pc = crate::serving::KvPagerConfig {
            block_tokens: 16,
            capacity_blocks: 100,
            prefix_share: false,
        };
        let spec = crate::spec_decode::SpecConfig::new(
            crate::spec_decode::auto_draft(&cfg),
            cfg.clone(),
            4,
            crate::spec_decode::AcceptanceModel::uniform(0.8),
        );
        let variants = [
            IterScope::new(&cfg, "l4", 1, 1),
            IterScope::new(&cfg, "a100", 2, 1),
            IterScope::new(&cfg, "a100", 1, 4),
            IterScope::new(&zoo::qwen3_0_6b(), "a100", 1, 1),
            base.with_lane(1),
            base.with_pager(&pc),
            base.with_pager(&pc.with_prefix_share(true)),
            base.with_pager(&crate::serving::KvPagerConfig { block_tokens: 32, ..pc }),
            base.with_spec(&spec),
        ];
        let k0 = IterationKey::new(base, &batch);
        for v in variants {
            assert_ne!(k0, IterationKey::new(v, &batch), "scope {v:?} must not alias");
        }
        // k and acceptance both separate speculative scopes.
        let mut spec_k5 = spec.clone();
        spec_k5.k = 5;
        assert_ne!(
            IterationKey::new(base.with_spec(&spec), &batch),
            IterationKey::new(base.with_spec(&spec_k5), &batch),
        );
        // Sharing on vs off under otherwise-identical pagers must also
        // differ from *each other* — the cross-config leak the tag fixes.
        assert_ne!(
            IterationKey::new(base.with_pager(&pc), &batch),
            IterationKey::new(base.with_pager(&pc.with_prefix_share(true)), &batch),
        );
    }

    #[test]
    fn canonical_order_matches_key_order() {
        // The graph the simulator builds (canonical order) and the key
        // must sort identically, or a hit could return a price computed
        // over a differently-ordered summation.
        let b = slots(&[(7, 9), (1, 40), (1, 12), (7, 3)]);
        let canon = canonical_slots(&b);
        let sig: Vec<(u32, u32)> =
            canon.iter().map(|s| (s.q_len as u32, s.kv_len as u32)).collect();
        let mut expect: Vec<(u32, u32)> =
            b.iter().map(|s| (s.q_len as u32, s.kv_len as u32)).collect();
        expect.sort_unstable();
        assert_eq!(sig, expect);
    }

    #[test]
    fn lru_roundtrip_eviction_and_counters() {
        let c = IterCache::new(2);
        let scope = IterScope::default();
        let k = |n: usize| IterationKey::new(scope, &slots(&[(1, n)]));
        let v = 0.1f64 + 0.2f64; // non-representable sum: bit-exactness probe
        c.insert(k(1), v);
        c.insert(k(2), 2.0);
        assert_eq!(c.get(&k(1)), Some(v), "hits are bit-identical");
        c.insert(k(3), 3.0); // evicts k(2): k(1) was just touched
        assert_eq!(c.get(&k(2)), None, "LRU entry evicted");
        assert_eq!(c.get(&k(3)), Some(3.0));
        assert_eq!(c.len(), 2);
        assert_eq!(c.evictions(), 1);
        assert_eq!(c.hits(), 2);
        assert_eq!(c.misses(), 1);
        assert!(c.hit_rate() > 0.6 && c.hit_rate() < 0.7);
        c.clear();
        assert!(c.is_empty());
    }

    #[test]
    fn disabled_cache_is_a_noop() {
        let c = IterCache::new(0);
        assert!(!c.enabled());
        let k = IterationKey::new(IterScope::default(), &slots(&[(1, 1)]));
        c.insert(k.clone(), 1.0);
        assert_eq!(c.get(&k), None);
        assert_eq!(c.hits() + c.misses(), 0, "disabled lookups are not counted");
        assert_eq!(c.hit_rate(), 0.0);
    }
}
