//! Request traces for the serving simulator: the workload description a
//! production inference server sees — who arrives when, with how long a
//! prompt, wanting how many tokens.
//!
//! Traces come from three places:
//!
//! * [`poisson_trace`] — memoryless arrivals at a target rate, the
//!   standard open-loop serving benchmark;
//! * [`bursty_trace`] — arrivals clumped into bursts (a chat app's
//!   fan-out, a retry storm), the tail-latency stressor;
//! * [`parse_trace`] — a JSON file of recorded arrivals, so real
//!   production traces replay through the simulator unchanged.
//!
//! [`scale_arrivals`] rescales one trace's arrival times to a different
//! rate *without changing the request shapes* — the tool behind QPS
//! sweeps and the monotone-load property test: comparing load points on
//! the same request population isolates queueing from sampling noise.

use anyhow::{anyhow, Result};

use crate::util::json::Json;
use crate::util::prng::Rng;

/// One serving request: arrive at `arrival_s`, prefill `prompt_len`
/// tokens, then emit one token at prefill end plus `gen_len` decode
/// steps (the [`crate::models::GenerationSpec`] convention).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RequestSpec {
    pub id: usize,
    pub arrival_s: f64,
    pub prompt_len: usize,
    pub gen_len: usize,
    /// Scheduling class: higher wins under the `priority` admission
    /// policy; doubles as the tenant id for `fair-share`. 0 (the default
    /// everywhere) keeps every policy equivalent to its classless form.
    pub priority: u8,
    /// Prompt-template identity: requests sharing a `prefix_group` share
    /// their first [`RequestSpec::prefix_tokens`] prompt tokens verbatim
    /// (the trace-level stand-in for a content hash of the token
    /// blocks). Only meaningful when `prefix_tokens > 0`.
    pub prefix_group: u64,
    /// How many leading prompt tokens are the shared template. 0 (the
    /// default everywhere) means a fully private prompt, which keeps the
    /// copy-on-write pager bit-for-bit equivalent to private paging.
    pub prefix_tokens: usize,
}

impl Default for RequestSpec {
    fn default() -> RequestSpec {
        RequestSpec {
            id: 0,
            arrival_s: 0.0,
            prompt_len: 1,
            gen_len: 0,
            priority: 0,
            prefix_group: 0,
            prefix_tokens: 0,
        }
    }
}

impl RequestSpec {
    /// Total context length once fully decoded.
    pub fn total_len(&self) -> usize {
        self.prompt_len + self.gen_len
    }
}

/// Stamp a trace with round-robin priority classes (`id % classes`) —
/// the deterministic multi-tenant workload behind priority / fair-share
/// admission tests and sweeps. `classes = 1` leaves the trace all-zero,
/// i.e. untouched.
pub fn with_priority_classes(trace: &[RequestSpec], classes: u8) -> Vec<RequestSpec> {
    let classes = classes.max(1);
    trace
        .iter()
        .map(|r| RequestSpec { priority: (r.id % classes as usize) as u8, ..*r })
        .collect()
}

/// Draw a (prompt, gen) shape around the requested means: log-uniform
/// over [mean/4, mean·4], the heavy-tailed mix real serving logs show.
fn sample_lens(rng: &mut Rng, mean_prompt: usize, mean_gen: usize) -> (usize, usize) {
    let draw = |rng: &mut Rng, mean: usize| {
        let mean = mean.max(1) as u64;
        rng.log_uniform_int((mean / 4).max(1), mean * 4) as usize
    };
    (draw(rng, mean_prompt), draw(rng, mean_gen))
}

/// Poisson arrivals at `qps` requests/second: exponential inter-arrival
/// gaps, log-uniform prompt/gen lengths around the means. Deterministic
/// for a fixed seed.
pub fn poisson_trace(
    n: usize,
    qps: f64,
    mean_prompt: usize,
    mean_gen: usize,
    seed: u64,
) -> Vec<RequestSpec> {
    assert!(qps > 0.0, "arrival rate must be positive");
    let mut rng = Rng::new(seed);
    let mut t = 0.0f64;
    (0..n)
        .map(|id| {
            // Exponential gap; 1 - u keeps ln's argument in (0, 1].
            t += -(1.0 - rng.uniform()).ln() / qps;
            let (prompt_len, gen_len) = sample_lens(&mut rng, mean_prompt, mean_gen);
            RequestSpec { id, arrival_s: t, prompt_len, gen_len, ..RequestSpec::default() }
        })
        .collect()
}

/// Bursty arrivals: bursts of `burst` simultaneous requests, with the
/// bursts themselves Poisson so the *average* rate is still `qps`. The
/// tail-latency stressor — p99 TTFT degrades long before mean load does.
pub fn bursty_trace(
    n: usize,
    qps: f64,
    mean_prompt: usize,
    mean_gen: usize,
    burst: usize,
    seed: u64,
) -> Vec<RequestSpec> {
    assert!(qps > 0.0, "arrival rate must be positive");
    let burst = burst.max(1);
    let mut rng = Rng::new(seed);
    let mut t = 0.0f64;
    let mut out = Vec::with_capacity(n);
    while out.len() < n {
        t += -(1.0 - rng.uniform()).ln() * burst as f64 / qps;
        for _ in 0..burst.min(n - out.len()) {
            let (prompt_len, gen_len) = sample_lens(&mut rng, mean_prompt, mean_gen);
            out.push(RequestSpec {
                id: out.len(),
                arrival_s: t,
                prompt_len,
                gen_len,
                ..RequestSpec::default()
            });
        }
    }
    out
}

/// Stamp a trace with shared prompt templates: every request keeps its
/// shape but declares its first `min(prefix_tokens, prompt_len - 1)`
/// prompt tokens shared with the other members of its group (`id %
/// groups`, round-robin like [`with_priority_classes`]). The clamp
/// leaves at least one private prompt token so every request still
/// produces first-token logits from its own prefill. `prefix_tokens =
/// 0` leaves the trace untouched.
pub fn with_shared_prefix(
    trace: &[RequestSpec],
    prefix_tokens: usize,
    groups: u64,
) -> Vec<RequestSpec> {
    let groups = groups.max(1);
    trace
        .iter()
        .map(|r| RequestSpec {
            prefix_group: r.id as u64 % groups,
            prefix_tokens: prefix_tokens.min(r.prompt_len.saturating_sub(1)),
            ..*r
        })
        .collect()
}

/// Poisson arrivals where every prompt is a shared `prefix_tokens`-token
/// template (one of `groups` templates, round-robin) followed by a
/// private log-uniform tail around `mean_private` tokens — the workload
/// shape prefix caching exists for (system prompts, few-shot headers).
/// Deterministic for a fixed seed, like [`poisson_trace`].
pub fn shared_prefix_trace(
    n: usize,
    qps: f64,
    prefix_tokens: usize,
    mean_private: usize,
    mean_gen: usize,
    groups: u64,
    seed: u64,
) -> Vec<RequestSpec> {
    let base = poisson_trace(n, qps, mean_private, mean_gen, seed);
    let groups = groups.max(1);
    base.iter()
        .map(|r| RequestSpec {
            prompt_len: prefix_tokens + r.prompt_len,
            prefix_group: r.id as u64 % groups,
            prefix_tokens,
            ..*r
        })
        .collect()
}

/// Rescale a trace's arrival times to `factor`× the original rate
/// (arrival times divide by `factor`), keeping every request's shape.
/// A unit-rate base trace plus this is how QPS sweeps hold the workload
/// population fixed across load points.
pub fn scale_arrivals(trace: &[RequestSpec], factor: f64) -> Vec<RequestSpec> {
    assert!(factor > 0.0, "rate factor must be positive");
    trace
        .iter()
        .map(|r| RequestSpec { arrival_s: r.arrival_s / factor, ..*r })
        .collect()
}

/// Parse a JSON trace: an array of objects with `arrival_s`,
/// `prompt_len` and `gen_len` (ids are assigned by position; arrivals
/// must be non-negative, prompts non-empty). The format [`to_json`]
/// writes round-trips through here.
pub fn parse_trace(text: &str) -> Result<Vec<RequestSpec>> {
    let v = Json::parse(text).map_err(|e| anyhow!("trace: {e}"))?;
    let arr = v.as_arr().ok_or_else(|| anyhow!("trace: expected a JSON array"))?;
    let mut out = Vec::with_capacity(arr.len());
    for (id, item) in arr.iter().enumerate() {
        let field = |name: &str| {
            item.get(name)
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow!("trace[{id}]: missing numeric `{name}`"))
        };
        let arrival_s = field("arrival_s")?;
        let prompt_len = field("prompt_len")? as usize;
        let gen_len = field("gen_len")? as usize;
        if arrival_s < 0.0 {
            return Err(anyhow!("trace[{id}]: negative arrival time"));
        }
        if prompt_len == 0 {
            return Err(anyhow!("trace[{id}]: empty prompt"));
        }
        // `priority` is optional — recorded traces predate the field.
        let priority = match item.get("priority") {
            None => 0,
            Some(p) => {
                let p = p
                    .as_f64()
                    .ok_or_else(|| anyhow!("trace[{id}]: non-numeric `priority`"))?;
                if !(0.0..=255.0).contains(&p) {
                    return Err(anyhow!("trace[{id}]: priority out of range"));
                }
                p as u8
            }
        };
        // Shared-prefix fields are optional too — absent means private.
        let opt_usize = |name: &str| -> Result<usize> {
            match item.get(name) {
                None => Ok(0),
                Some(v) => {
                    let v = v
                        .as_f64()
                        .ok_or_else(|| anyhow!("trace[{id}]: non-numeric `{name}`"))?;
                    if v < 0.0 {
                        return Err(anyhow!("trace[{id}]: negative `{name}`"));
                    }
                    Ok(v as usize)
                }
            }
        };
        let prefix_group = opt_usize("prefix_group")? as u64;
        let prefix_tokens = opt_usize("prefix_tokens")?;
        if prefix_tokens >= prompt_len {
            return Err(anyhow!("trace[{id}]: prefix_tokens must leave a private prompt token"));
        }
        out.push(RequestSpec {
            id,
            arrival_s,
            prompt_len,
            gen_len,
            priority,
            prefix_group,
            prefix_tokens,
        });
    }
    out.sort_by(|a, b| a.arrival_s.partial_cmp(&b.arrival_s).unwrap());
    // Re-id in arrival order so downstream bookkeeping is positional.
    for (i, r) in out.iter_mut().enumerate() {
        r.id = i;
    }
    Ok(out)
}

/// Serialize a trace in the [`parse_trace`] format.
pub fn to_json(trace: &[RequestSpec]) -> Json {
    Json::Arr(
        trace
            .iter()
            .map(|r| {
                Json::obj(vec![
                    ("arrival_s", Json::Num(r.arrival_s)),
                    ("prompt_len", Json::from(r.prompt_len)),
                    ("gen_len", Json::from(r.gen_len)),
                    ("priority", Json::from(r.priority as usize)),
                    ("prefix_group", Json::from(r.prefix_group as usize)),
                    ("prefix_tokens", Json::from(r.prefix_tokens)),
                ])
            })
            .collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_is_deterministic_with_target_rate() {
        let a = poisson_trace(400, 8.0, 256, 32, 7);
        let b = poisson_trace(400, 8.0, 256, 32, 7);
        assert_eq!(a, b, "same seed, same trace");
        assert_eq!(a.len(), 400);
        // Mean inter-arrival ≈ 1/qps over a long trace.
        let span = a.last().unwrap().arrival_s;
        let rate = a.len() as f64 / span;
        assert!((rate - 8.0).abs() / 8.0 < 0.2, "rate {rate}");
        // Arrivals sorted, ids positional, shapes near the means.
        assert!(a.windows(2).all(|w| w[0].arrival_s <= w[1].arrival_s));
        assert!(a.iter().enumerate().all(|(i, r)| r.id == i));
        assert!(a.iter().all(|r| r.prompt_len >= 64 && r.prompt_len <= 1024));
        assert!(a.iter().all(|r| r.gen_len >= 8 && r.gen_len <= 128));
    }

    #[test]
    fn bursty_clumps_arrivals_at_the_same_rate() {
        let b = bursty_trace(320, 16.0, 128, 16, 8, 3);
        assert_eq!(b.len(), 320);
        // Whole bursts share one arrival instant.
        let simultaneous = b.windows(2).filter(|w| w[0].arrival_s == w[1].arrival_s).count();
        assert!(simultaneous >= 320 / 8 * 6, "{simultaneous} co-arrivals");
        // Average rate stays near qps.
        let rate = b.len() as f64 / b.last().unwrap().arrival_s;
        assert!((rate - 16.0).abs() / 16.0 < 0.35, "rate {rate}");
    }

    #[test]
    fn priority_classes_are_round_robin_and_degree_one_is_identity() {
        let base = poisson_trace(30, 4.0, 64, 8, 2);
        let classed = with_priority_classes(&base, 3);
        assert!(classed.iter().all(|r| r.priority == (r.id % 3) as u8));
        assert_eq!(with_priority_classes(&base, 1), base);
        assert_eq!(with_priority_classes(&base, 0), base, "0 clamps to 1");
    }

    #[test]
    fn shared_prefix_traces_stamp_templates() {
        // Generator: prompt = template + private tail, groups round-robin.
        let t = shared_prefix_trace(40, 4.0, 256, 64, 8, 3, 5);
        assert!(t.iter().all(|r| r.prefix_tokens == 256 && r.prompt_len > 256));
        assert!(t.iter().all(|r| r.prefix_group == r.id as u64 % 3));
        // Arrivals and private shapes match the underlying Poisson draw.
        let base = poisson_trace(40, 4.0, 64, 8, 5);
        for (s, b) in t.iter().zip(&base) {
            assert_eq!(s.arrival_s, b.arrival_s);
            assert_eq!(s.prompt_len, 256 + b.prompt_len);
            assert_eq!(s.gen_len, b.gen_len);
        }
        // Stamper: shapes untouched, prefix clamped below the prompt.
        let stamped = with_shared_prefix(&base, 1024, 2);
        for (s, b) in stamped.iter().zip(&base) {
            assert_eq!((s.prompt_len, s.gen_len, s.arrival_s), (b.prompt_len, b.gen_len, b.arrival_s));
            assert_eq!(s.prefix_tokens, 1024.min(b.prompt_len - 1));
            assert!(s.prefix_tokens < s.prompt_len);
        }
        // Zero prefix is the identity.
        assert_eq!(with_shared_prefix(&base, 0, 4)[0].prefix_tokens, 0);
    }

    #[test]
    fn scale_arrivals_rescales_times_only() {
        let base = poisson_trace(50, 1.0, 128, 16, 1);
        let fast = scale_arrivals(&base, 4.0);
        for (a, b) in base.iter().zip(&fast) {
            assert_eq!(a.prompt_len, b.prompt_len);
            assert_eq!(a.gen_len, b.gen_len);
            assert!((b.arrival_s - a.arrival_s / 4.0).abs() < 1e-15);
        }
    }

    #[test]
    fn json_round_trip_and_errors() {
        let base = poisson_trace(20, 2.0, 64, 8, 9);
        let text = to_json(&base).to_string();
        let back = parse_trace(&text).unwrap();
        assert_eq!(base.len(), back.len());
        for (a, b) in base.iter().zip(&back) {
            assert_eq!((a.prompt_len, a.gen_len), (b.prompt_len, b.gen_len));
            assert!((a.arrival_s - b.arrival_s).abs() < 1e-12);
        }
        // Out-of-order input is sorted and re-ided.
        let jumbled = r#"[
            {"arrival_s": 5.0, "prompt_len": 10, "gen_len": 2},
            {"arrival_s": 1.0, "prompt_len": 20, "gen_len": 3}
        ]"#;
        let t = parse_trace(jumbled).unwrap();
        assert_eq!(t[0].prompt_len, 20);
        assert_eq!(t[0].id, 0);
        // Priorities survive the round trip; absent ones default to 0.
        let classed = with_priority_classes(&base, 3);
        let back2 = parse_trace(&to_json(&classed).to_string()).unwrap();
        assert!(back2.iter().zip(&classed).all(|(a, b)| a.priority == b.priority));
        let legacy = r#"[{"arrival_s": 0.0, "prompt_len": 4, "gen_len": 1}]"#;
        assert_eq!(parse_trace(legacy).unwrap()[0].priority, 0);
        assert!(parse_trace(
            r#"[{"arrival_s": 0.0, "prompt_len": 4, "gen_len": 1, "priority": 999}]"#
        )
        .is_err());
        // Shared-prefix fields round-trip; absent ones default private.
        let shared = shared_prefix_trace(8, 2.0, 32, 16, 4, 2, 11);
        let back3 = parse_trace(&to_json(&shared).to_string()).unwrap();
        assert!(back3
            .iter()
            .zip(&shared)
            .all(|(a, b)| (a.prefix_group, a.prefix_tokens) == (b.prefix_group, b.prefix_tokens)));
        assert_eq!(parse_trace(legacy).unwrap()[0].prefix_tokens, 0);
        // A prefix consuming the whole prompt is rejected (no private
        // token left to prefill).
        assert!(parse_trace(
            r#"[{"arrival_s": 0.0, "prompt_len": 4, "gen_len": 1, "prefix_tokens": 4}]"#
        )
        .is_err());
        // Malformed traces are rejected with a reason.
        assert!(parse_trace("{}").is_err());
        assert!(parse_trace(r#"[{"arrival_s": 1.0}]"#).is_err());
        assert!(parse_trace(r#"[{"arrival_s": -1.0, "prompt_len": 4, "gen_len": 1}]"#).is_err());
        assert!(parse_trace(r#"[{"arrival_s": 0.0, "prompt_len": 0, "gen_len": 1}]"#).is_err());
    }
}
