//! PM2Lat GEMM path (paper §III-C "MatMul Latency Prediction"):
//! per-kernel throughput tables on the power-of-two K grid, collected at a
//! locked clock with complete blocks/waves, then Eq. (1)/(2) interpolation
//! plus wave scaling at predict time. Partial (tail) waves are profiled
//! explicitly — "the same strategy is also applied for partial MatMul
//! cases" — via a measured tail-response curve per kernel.

use crate::gpusim::{gemm, heuristic, FreqMode, Gpu};
use crate::ops::{DType, GemmOp, Op};
use crate::profiler::{self, ProfileSpec};

/// The K grid: 32, 64, ..., 8192 (paper §III-C).
pub const K_GRID: [usize; 9] = [32, 64, 128, 256, 512, 1024, 2048, 4096, 8192];
pub const K_MAX: f64 = 8192.0;

/// Tail waves quantize by resident blocks per SM: a tail of `t` blocks
/// runs at occupancy r = ceil(t / SMs) ∈ [1, bpsm]. PM2Lat profiles every
/// occupancy level (bpsm ≤ 8, so at most 8 extra points per kernel).
pub fn tail_levels(bpsm: usize) -> Vec<usize> {
    (1..=bpsm).collect()
}

/// Profiled characteristics of one kernel implementation.
///
/// The total duration model is
///   dur(K, blocks) = launch + w(K) · (full_waves + tail(frac)) ,
/// with w(K) from Eq. (1)/(2) over the *work* throughput table (launch
/// subtracted), and tail(·) the measured partial-wave response.
#[derive(Clone, Debug)]
pub struct KernelProfile {
    pub kernel_id: usize,
    /// Base collection shape (complete blocks, complete waves).
    pub base_m: usize,
    pub base_n: usize,
    /// Blocks per wave observed via the occupancy query.
    pub wave_capacity: usize,
    /// Waves in the base collection shape.
    pub base_waves: usize,
    /// Launch overhead (seconds), separated via the one-wave shape.
    pub launch_s: f64,
    /// Per-wave work at K = 8192 (seconds).
    pub work8192_s: f64,
    /// Work throughput (FLOP/s over duration-minus-launch) per K_GRID pt.
    pub throughput: [f64; 9],
    /// Measured tail response per occupancy level r = 1..=bpsm, in units
    /// of a full wave's work (tail[r-1] = cost of a tail running r blocks
    /// per SM; tail[bpsm-1] ≈ 1.0). Collected at K = 8192 (`tail`) and at
    /// K = 512 (`tail_lo`) — the compute/memory balance of a partial wave
    /// shifts with K, so the response is interpolated in log-K.
    pub tail: Vec<f64>,
    pub tail_lo: Vec<f64>,
    /// SM count (public) — determines the tail occupancy level.
    pub sm_count: usize,
}

/// K at which the low tail staircase is collected.
pub const TAIL_K_LO: f64 = 512.0;

impl KernelProfile {
    /// Eq. (2): linear interpolation of throughput between the two
    /// bracketing grid points (log-indexed — the grid is powers of two).
    pub fn interp_throughput(&self, k: f64) -> f64 {
        let kc = k.clamp(K_GRID[0] as f64, K_MAX);
        let pos = (kc / K_GRID[0] as f64).log2();
        let idx = (pos.floor() as usize).min(K_GRID.len() - 2);
        let k1 = K_GRID[idx] as f64;
        let t1 = self.throughput[idx];
        let t3 = self.throughput[idx + 1];
        t1 + (kc - k1) / k1 * (t3 - t1)
    }

    /// Eq. (1): per-wave work for a new K (beyond the grid, the K factor
    /// keeps growing linearly while throughput saturates — the paper's
    /// "beyond this point the throughput is unlikely to change further").
    pub fn work_at_k(&self, k: f64) -> f64 {
        let new_thr = self.interp_throughput(k);
        let org_thr = self.throughput[K_GRID.len() - 1];
        self.work8192_s * (k / K_MAX) * (org_thr / new_thr)
    }

    /// Tail response for `tail_blocks` residual blocks at depth `k`: the
    /// measured cost at occupancy level r = ceil(tail_blocks / SMs),
    /// log-K-interpolated between the two profiled staircases.
    pub fn tail_response(&self, tail_blocks: usize, k: f64) -> f64 {
        if tail_blocks == 0 {
            return 0.0;
        }
        let r = tail_blocks.div_ceil(self.sm_count).min(self.tail.len());
        let hi = self.tail[r - 1];
        let lo = self.tail_lo[r - 1];
        let t = ((k.max(1.0).log2() - TAIL_K_LO.log2())
            / (K_MAX.log2() - TAIL_K_LO.log2()))
        .clamp(0.0, 1.0);
        lo + t * (hi - lo)
    }

    /// Effective wave count (full + tail response) for a block count at
    /// per-block depth `k`.
    pub fn effective_waves(&self, blocks: usize, k: f64) -> f64 {
        let full = blocks / self.wave_capacity;
        full as f64 + self.tail_response(blocks % self.wave_capacity, k)
    }
}

/// Measured streaming-bandwidth profile for gemv-degenerate GEMMs — the
/// decode-step regime, where every projection is a `batch × n × k` GEMM
/// bounded by weight streaming, not tensor-core throughput. The same
/// collect-then-interpolate strategy as the kernel tables: measure the
/// achieved bandwidth at log-spaced working-set sizes (spanning the
/// L2-resident → DRAM-resident transition), then predict
/// `launch + io_bytes / bw(io_bytes)`. Memory-bound, so no boost-clock
/// correction is needed (§IV-A: clocks barely move memory-bound kernels).
#[derive(Clone, Debug)]
pub struct GemvProfile {
    pub launch_s: f64,
    /// Working-set sizes (bytes) of the collection shapes, ascending.
    pub ws_bytes: Vec<f64>,
    /// Achieved bytes/s at each collection working set.
    pub bw: Vec<f64>,
}

impl GemvProfile {
    /// Effective bandwidth for a working set: log-space interpolation
    /// between the bracketing measured points, clamped at the grid ends.
    pub fn bw_at(&self, bytes: f64) -> f64 {
        let first = self.ws_bytes[0];
        let last = *self.ws_bytes.last().unwrap();
        let b = bytes.clamp(first, last);
        let mut i = 0;
        while i + 2 < self.ws_bytes.len() && self.ws_bytes[i + 1] < b {
            i += 1;
        }
        let (w1, w2) = (self.ws_bytes[i], self.ws_bytes[i + 1]);
        let t = (b.ln() - w1.ln()) / (w2.ln() - w1.ln());
        self.bw[i] + t.clamp(0.0, 1.0) * (self.bw[i + 1] - self.bw[i])
    }

    /// Predicted latency of a gemv-degenerate GEMM.
    pub fn predict(&self, op: &GemmOp) -> f64 {
        let bytes = op.io_bytes();
        self.launch_s + bytes / self.bw_at(bytes)
    }
}

/// Measured skinny-GEMM profile for `8 < min(m, n) ≤ 32` — the
/// continuous-batching decode regime, where an iteration over 9–32
/// sequences makes every projection an `r × n × k` GEMM that the library
/// serves with streaming kernels, not 64/128-row tensor-core tiles.
/// One [`GemvProfile`]-style bandwidth staircase is collected per rows
/// level of [`SKINNY_ROWS_GRID`] (the achieved bandwidth ramps with row
/// parallelism), and predictions interpolate between the bracketing
/// levels' predictions linearly in `r`.
#[derive(Clone, Debug)]
pub struct SkinnyProfile {
    /// Collected rows levels, ascending (the `min(m, n)` of the shapes).
    pub rows: Vec<usize>,
    /// One streaming profile per rows level.
    pub levels: Vec<GemvProfile>,
}

impl SkinnyProfile {
    /// Predicted latency of a skinny (but not gemv-degenerate) GEMM.
    pub fn predict(&self, op: &GemmOp) -> f64 {
        let r = op.m.min(op.n) as f64;
        let first = self.rows[0] as f64;
        let last = *self.rows.last().unwrap() as f64;
        let rc = r.clamp(first, last);
        let mut i = 0;
        while i + 2 < self.rows.len() && (self.rows[i + 1] as f64) < rc {
            i += 1;
        }
        let (r1, r2) = (self.rows[i] as f64, self.rows[i + 1] as f64);
        let t = ((rc - r1) / (r2 - r1)).clamp(0.0, 1.0);
        let p1 = self.levels[i].predict(op);
        let p2 = self.levels[i + 1].predict(op);
        p1 + t * (p2 - p1)
    }
}

/// Full per-(device, dtype) GEMM model: one profile per kernel in the
/// registry, the gemv and skinny (decode-regime) streaming profiles,
/// plus the clock calibration.
#[derive(Clone, Debug)]
pub struct GemmTable {
    pub device: String,
    pub dtype: DType,
    pub profiles: Vec<KernelProfile>,
    /// Memory-bound route for gemv-degenerate (decode-step) GEMMs.
    pub gemv: GemvProfile,
    /// Streaming route for the skinny band (`8 < min(m,n) ≤ 32`) — the
    /// continuous-batching decode regime.
    pub skinny: SkinnyProfile,
    /// Locked collection clock (GHz).
    pub locked_ghz: f64,
    /// locked_dur / boost_dur from the calibration burn (≥1).
    pub boost_speedup: f64,
    /// Public DRAM bandwidth (for the split-K epilogue estimate).
    pub dram_bw: f64,
}

/// Pick a base (m, n) giving exactly `blocks` complete tiles: factor into
/// a near-square tile grid.
fn tile_grid_shape(tile_m: usize, tile_n: usize, blocks: usize) -> (usize, usize) {
    let mut best = (blocks, 1);
    let mut best_gap = usize::MAX;
    let mut d = 1;
    while d * d <= blocks {
        if blocks % d == 0 {
            let other = blocks / d;
            let gap = other - d;
            if gap < best_gap {
                best_gap = gap;
                best = (other, d);
            }
        }
        d += 1;
    }
    (tile_m * best.0, tile_n * best.1)
}

/// Collect the throughput table for every kernel of `dtype` on this
/// device. This is PM2Lat's one-time, per-device data collection —
/// deliberately at a locked (lower) clock so the die stays cool (§IV-A).
pub fn collect(gpu: &mut Gpu, dtype: DType, spec: &ProfileSpec) -> Option<GemmTable> {
    if !gpu.spec.supports(dtype) {
        return None;
    }
    let locked_ghz = gpu.spec.max_freq_ghz * 0.7;
    gpu.set_freq(FreqMode::Fixed(locked_ghz));
    let kernels: Vec<_> = gpu.kernels(dtype).to_vec();
    let mut profiles = Vec::with_capacity(kernels.len());
    for kern in &kernels {
        let capacity = match profiler::occupancy(gpu, dtype, kern.id) {
            Some(bpsm) => bpsm * gpu.spec.sm_count,
            None => continue,
        };
        let cfg = gemm::GemmConfig { kernel_id: kern.id, splitk: 1 };
        let meas = |gpu: &mut Gpu, m: usize, n: usize, k: usize| {
            profiler::measure_config(
                gpu,
                &Op::Gemm(GemmOp::mm(m, n, k, dtype)),
                Some(cfg),
                spec,
            )
            .map(|r| r.mean_s)
        };
        // 2 complete waves of complete blocks (wave-quantization-free).
        let waves = 2;
        let (m, n) = tile_grid_shape(kern.tile_m, kern.tile_n, capacity * waves);
        // Launch overhead from one-block kernels: d(K) ≈ launch + work(K)
        // with work(64) ≈ 2·work(32) ⇒ launch ≈ 2·d(32) − d(64). These
        // are microsecond-scale measurements, so the subtraction is
        // well-conditioned (unlike differencing two multi-ms waves).
        let t32 = meas(gpu, kern.tile_m, kern.tile_n, 32).ok()?;
        let t64 = meas(gpu, kern.tile_m, kern.tile_n, 64).ok()?;
        let launch = (2.0 * t32 - t64).clamp(0.15 * t32, t32);
        // K sweep at the base shape → work-throughput table.
        let mut throughput = [0.0; 9];
        let mut d8192 = 0.0;
        for (i, &k) in K_GRID.iter().enumerate() {
            let dur = meas(gpu, m, n, k).ok()?;
            if k == 8192 {
                d8192 = dur;
            }
            let op = GemmOp::mm(m, n, k, dtype);
            throughput[i] = op.flops() / (dur - launch).max(dur * 0.05);
        }
        let work8192 = (d8192 - launch).max(d8192 * 0.25) / waves as f64;
        // Partial-wave response: one point per occupancy level (tail of
        // sm_count × r blocks runs r blocks per SM), at two K depths.
        let bpsm = capacity / gpu.spec.sm_count;
        let k_lo = TAIL_K_LO as usize;
        let d512 = meas(gpu, m, n, k_lo).ok()?;
        let work512 = (d512 - launch).max(d512 * 0.25) / waves as f64;
        let mut tail = Vec::with_capacity(bpsm);
        let mut tail_lo = Vec::with_capacity(bpsm);
        for r in tail_levels(bpsm) {
            let blocks = gpu.spec.sm_count * r;
            let (mf, nf) = tile_grid_shape(kern.tile_m, kern.tile_n, blocks);
            let df = meas(gpu, mf, nf, 8192).ok()?;
            tail.push(((df - launch) / work8192).clamp(0.02, 1.2));
            let dl = meas(gpu, mf, nf, k_lo).ok()?;
            tail_lo.push(((dl - launch) / work512).clamp(0.02, 1.2));
        }
        // Enforce monotonicity (noise can invert close points).
        for i in 1..tail.len() {
            tail[i] = tail[i].max(tail[i - 1]);
            tail_lo[i] = tail_lo[i].max(tail_lo[i - 1]);
        }
        profiles.push(KernelProfile {
            kernel_id: kern.id,
            base_m: m,
            base_n: n,
            wave_capacity: capacity,
            base_waves: waves,
            launch_s: launch,
            work8192_s: work8192,
            throughput,
            tail,
            tail_lo,
            sm_count: gpu.spec.sm_count,
        });
    }
    // Gemv (decode-regime) streaming profile: measure achieved bandwidth
    // at log-spaced working sets through the *library* dispatch (no
    // pinned config — the library routes skinny shapes to its streaming
    // kernels, exactly what a decode-step projection hits in production).
    // Pure memory-bound, so the locked clock transfers without
    // correction.
    let gemv = collect_gemv(gpu, dtype, spec)?;
    // Boost calibration burn (hot, like an evaluation run).
    let boost_speedup =
        profiler::calibrate_boost_ratio(gpu, dtype, locked_ghz).unwrap_or(1.0);
    gpu.set_freq(FreqMode::Boost);
    // Skinny band (9 ..= 32 rows): arithmetic intensity approaches
    // machine balance near the top of the band, so it is *partially*
    // clock-sensitive — collect at the evaluation (boost) clock like the
    // custom kernels (short launches, little sustained heat; idle first
    // so the calibration burn's heat cannot derate the staircase).
    gpu.idle(5.0);
    let skinny = collect_skinny(gpu, dtype, spec)?;
    Some(GemmTable {
        device: gpu.spec.name.to_string(),
        dtype,
        profiles,
        gemv,
        skinny,
        locked_ghz,
        boost_speedup,
        dram_bw: gpu.spec.dram_bw(),
    })
}

/// Working-set K grid for the gemv profile (n is fixed at 4096, so the
/// weight slab spans ~1 MB → ~270 MB in FP32: both cache plateaus and the
/// transition between them on every simulated device).
const GEMV_K_GRID: [usize; 5] = [64, 256, 1024, 4096, 16384];
const GEMV_N: usize = 4096;

/// One streaming-bandwidth staircase at a fixed row count `rows`: launch
/// overhead from two L2-resident shapes with a 2× byte ratio
/// (d ≈ launch + bytes/bw on a shared bandwidth plateau, so
/// launch ≈ 2·d1 − d2 — the same well-conditioned trick as the kernel
/// tables' one-block shapes), then achieved bandwidth at each working
/// set of the [`GEMV_K_GRID`]. Shared by the gemv (`rows = 1`) and
/// skinny (`rows = 9..=32`) collections so the two profiles can never
/// diverge in methodology.
fn collect_stream_profile(
    gpu: &mut Gpu,
    rows: usize,
    dtype: DType,
    spec: &ProfileSpec,
) -> Option<GemvProfile> {
    let meas = |gpu: &mut Gpu, m: usize, n: usize, k: usize| {
        profiler::measure(gpu, &Op::Gemm(GemmOp::linear(m, n, k, dtype)), spec)
            .map(|r| r.mean_s)
            .ok()
    };
    let d1 = meas(gpu, rows, 512, 64)?;
    let d2 = meas(gpu, rows, 512, 128)?;
    let launch = (2.0 * d1 - d2).clamp(0.15 * d1, d1);
    let mut ws_bytes = Vec::with_capacity(GEMV_K_GRID.len());
    let mut bw = Vec::with_capacity(GEMV_K_GRID.len());
    for &k in &GEMV_K_GRID {
        let op = GemmOp::linear(rows, GEMV_N, k, dtype);
        let dur = meas(gpu, rows, GEMV_N, k)?;
        let bytes = op.io_bytes();
        ws_bytes.push(bytes);
        bw.push(bytes / (dur - launch).max(dur * 0.05));
    }
    Some(GemvProfile { launch_s: launch, ws_bytes, bw })
}

fn collect_gemv(gpu: &mut Gpu, dtype: DType, spec: &ProfileSpec) -> Option<GemvProfile> {
    collect_stream_profile(gpu, 1, dtype, spec)
}

/// Rows levels of the skinny-GEMM collection (the `min(m, n)` band the
/// library serves with streaming kernels above the gemv cut).
pub const SKINNY_ROWS_GRID: [usize; 4] = [9, 16, 24, 32];

fn collect_skinny(gpu: &mut Gpu, dtype: DType, spec: &ProfileSpec) -> Option<SkinnyProfile> {
    let mut rows = Vec::with_capacity(SKINNY_ROWS_GRID.len());
    let mut levels = Vec::with_capacity(SKINNY_ROWS_GRID.len());
    for &r in &SKINNY_ROWS_GRID {
        rows.push(r);
        levels.push(collect_stream_profile(gpu, r, dtype, spec)?);
    }
    Some(SkinnyProfile { rows, levels })
}

impl GemmTable {
    /// Predict the boost-clock latency of a GEMM. `gpu` is only consulted
    /// for the *public* interfaces a real deployment has: the cuBLASLt
    /// heuristic (runs on the target device) and the occupancy calculator.
    /// Skinny shapes route to the measured memory-bound profiles instead
    /// of the tensor-core kernel tables — gemv-degenerate ones
    /// (`min(m,n) ≤ 8`, single-digit decode batches) to the gemv profile,
    /// the `9 ..= 32` band (continuous-batching decode) to the
    /// rows-interpolated skinny profile. The same regime split the
    /// library's own dispatch makes.
    pub fn predict(&self, gpu: &Gpu, op: &GemmOp) -> Option<f64> {
        if gemm::is_gemv_degenerate(op) {
            if !gpu.spec.supports(op.dtype) {
                return None;
            }
            return Some(self.gemv.predict(op));
        }
        if gemm::is_skinny(op) {
            if !gpu.spec.supports(op.dtype) {
                return None;
            }
            return Some(self.skinny.predict(op));
        }
        let cfg = heuristic::algo_get_heuristic_cached(gpu, op)?;
        self.predict_with_config(gpu, op, cfg)
    }

    /// Predict with a known kernel configuration (used by the TruthCFG
    /// variant and by the batched PJRT path that pre-resolves configs).
    pub fn predict_with_config(
        &self,
        gpu: &Gpu,
        op: &GemmOp,
        cfg: gemm::GemmConfig,
    ) -> Option<f64> {
        let profile = self.profiles.iter().find(|p| p.kernel_id == cfg.kernel_id)?;
        let kern = gpu.kernel(op.dtype, cfg.kernel_id)?;
        let kb = op.k.div_ceil(cfg.splitk) as f64;
        let tiles_m = op.m.div_ceil(kern.tile_m);
        let tiles_n = op.n.div_ceil(kern.tile_n);
        let blocks = tiles_m * tiles_n * op.batch * cfg.splitk;
        let work = profile.work_at_k(kb) * profile.effective_waves(blocks, kb)
            / self.boost_speedup;
        Some(profile.launch_s + work + self.splitk_epilogue(op, cfg, profile))
    }

    /// Split-K epilogue estimate from *public* quantities: partial-product
    /// reduction traffic over the spec DRAM bandwidth plus a half launch.
    fn splitk_epilogue(
        &self,
        op: &GemmOp,
        cfg: gemm::GemmConfig,
        profile: &KernelProfile,
    ) -> f64 {
        if cfg.splitk <= 1 {
            return 0.0;
        }
        let bytes =
            (op.batch * op.m * op.n) as f64 * (cfg.splitk as f64 + 1.0) * 4.0;
        bytes / self.dram_bw + 0.5 * profile.launch_s
    }

    /// Work scale factor relative to the K=8192 per-wave work — the
    /// `scale` input of the batched L1 prediction kernel (launch and
    /// epilogue are added host-side after the PJRT call).
    pub fn scale_factor(&self, gpu: &Gpu, op: &GemmOp, cfg: gemm::GemmConfig) -> Option<f64> {
        let profile = self.profiles.iter().find(|p| p.kernel_id == cfg.kernel_id)?;
        let kern = gpu.kernel(op.dtype, cfg.kernel_id)?;
        let tiles_m = op.m.div_ceil(kern.tile_m);
        let tiles_n = op.n.div_ceil(kern.tile_n);
        let blocks = tiles_m * tiles_n * op.batch * cfg.splitk;
        Some(profile.effective_waves(blocks, op.k.div_ceil(cfg.splitk) as f64) / self.boost_speedup)
    }

    /// Host-side additive part for the batched path (launch + epilogue).
    pub fn host_offset(&self, op: &GemmOp, cfg: gemm::GemmConfig) -> Option<f64> {
        let profile = self.profiles.iter().find(|p| p.kernel_id == cfg.kernel_id)?;
        Some(profile.launch_s + self.splitk_epilogue(op, cfg, profile))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::rel_err_pct;

    fn quick_table(dev: &str, dtype: DType) -> (Gpu, GemmTable) {
        let mut gpu = Gpu::by_name(dev).unwrap();
        let table = collect(&mut gpu, dtype, &ProfileSpec::quick()).unwrap();
        gpu.reset();
        (gpu, table)
    }

    #[test]
    fn collects_profile_per_kernel() {
        let (_, table) = quick_table("a100", DType::F32);
        assert_eq!(table.profiles.len(), 13);
        let mut ramps = Vec::new();
        for p in &table.profiles {
            assert!(p.work8192_s > 0.0);
            assert!(p.launch_s >= 0.0);
            // Throughput must ramp up with K (rational curve).
            assert!(p.throughput[8] > p.throughput[0] * 1.2,
                    "kernel {} barely ramps", p.kernel_id);
            ramps.push(p.throughput[8] / p.throughput[0]);
            // Tail response is monotone and bounded.
            assert!(p.tail[0] <= p.tail[1] && p.tail[1] <= p.tail[2]);
            assert!(p.tail[2] <= 1.2);
        }
        // Dispersion: some kernels ramp much harder than others.
        assert!(ramps.iter().cloned().fold(0.0, f64::max) > 1.8);
        assert!(table.boost_speedup > 1.0 && table.boost_speedup < 2.0);
    }

    #[test]
    fn tile_grid_shape_is_exact_tiling() {
        let (m, n) = tile_grid_shape(128, 64, 216 * 2);
        assert_eq!(m % 128, 0);
        assert_eq!(n % 64, 0);
        assert_eq!((m / 128) * (n / 64), 216 * 2);
    }

    #[test]
    fn interp_exact_on_grid_points() {
        let (_, table) = quick_table("l4", DType::F32);
        let p = &table.profiles[0];
        for (i, &k) in K_GRID.iter().enumerate() {
            let t = p.interp_throughput(k as f64);
            assert!((t - p.throughput[i]).abs() / p.throughput[i] < 1e-12);
        }
    }

    #[test]
    fn tail_response_is_occupancy_staircase() {
        let p = KernelProfile {
            kernel_id: 0,
            base_m: 0,
            base_n: 0,
            wave_capacity: 400, // 100 SMs × bpsm 4
            base_waves: 2,
            launch_s: 0.0,
            work8192_s: 1.0,
            throughput: [1.0; 9],
            tail: vec![0.25, 0.5, 0.75, 1.0],
            tail_lo: vec![0.25, 0.5, 0.75, 1.0],
            sm_count: 100,
        };
        // Equal staircases at both K depths → K interp is the identity.
        assert_eq!(p.tail_response(0, 8192.0), 0.0);
        assert_eq!(p.tail_response(1, 8192.0), 0.25); // 1 block → r=1
        assert_eq!(p.tail_response(100, 512.0), 0.25); // exactly 1/SM
        assert_eq!(p.tail_response(101, 8192.0), 0.5); // r=2
        assert_eq!(p.tail_response(399, 1024.0), 1.0); // r=4
        // effective_waves: 950 blocks = 2 full + 150 tail (r=2).
        assert!((p.effective_waves(950, 8192.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn tail_response_interpolates_in_k() {
        let p = KernelProfile {
            kernel_id: 0,
            base_m: 0,
            base_n: 0,
            wave_capacity: 400,
            base_waves: 2,
            launch_s: 0.0,
            work8192_s: 1.0,
            throughput: [1.0; 9],
            tail: vec![0.2],
            tail_lo: vec![0.6],
            sm_count: 400,
        };
        assert_eq!(p.tail_response(10, 512.0), 0.6);
        assert_eq!(p.tail_response(10, 8192.0), 0.2);
        assert_eq!(p.tail_response(10, 64.0), 0.6); // clamped below grid
        let mid = p.tail_response(10, 2048.0); // log-midpoint of 512..8192
        assert!((mid - 0.4).abs() < 1e-12, "mid={mid}");
    }

    #[test]
    fn predict_accuracy_on_boost_ground_truth() {
        // End-to-end sanity: PM2Lat predictions vs fresh boost-clock
        // measurements must land under ~10% mean error.
        let (mut gpu, table) = quick_table("a100", DType::F32);
        gpu.reset();
        gpu.set_freq(FreqMode::Boost);
        let mut errs = Vec::new();
        let mut rng = crate::util::prng::Rng::new(1234);
        for _ in 0..25 {
            let m = rng.log_uniform_int(64, 8192) as usize;
            let n = rng.log_uniform_int(64, 8192) as usize;
            let k = rng.log_uniform_int(32, 16384) as usize;
            let op = GemmOp::mm(m, n, k, DType::F32);
            let pred = table.predict(&gpu, &op).unwrap();
            let truth = profiler::measure(
                &mut gpu,
                &Op::Gemm(op),
                &ProfileSpec::quick(),
            )
            .unwrap()
            .mean_s;
            errs.push(rel_err_pct(pred, truth));
        }
        let mean = crate::util::stats::mean(&errs);
        assert!(mean < 10.0, "mean rel err {mean}% errs={errs:?}");
    }

    #[test]
    fn k_above_grid_extrapolates_linearly() {
        let (gpu, table) = quick_table("rtx5070", DType::F32);
        let op1 = GemmOp::mm(1024, 1024, 8192, DType::F32);
        let op2 = GemmOp::mm(1024, 1024, 16384, DType::F32);
        let t1 = table.predict(&gpu, &op1).unwrap();
        let t2 = table.predict(&gpu, &op2).unwrap();
        // K doubles past the grid end → duration ≈ doubles (same config).
        let ratio = t2 / t1;
        assert!(ratio > 1.5 && ratio < 2.6, "ratio={ratio}");
    }

    #[test]
    fn bmm_scales_with_waves_not_batch_naively() {
        let (gpu, table) = quick_table("l4", DType::F32);
        let single = GemmOp::bmm(1, 128, 128, 256, DType::F32);
        let batched = GemmOp::bmm(64, 128, 128, 256, DType::F32);
        let t1 = table.predict(&gpu, &single).unwrap();
        let t64 = table.predict(&gpu, &batched).unwrap();
        // One tile per matrix: 64 small matrices still fit in ≤ a wave or
        // two → far less than 64× slower.
        assert!(t64 < t1 * 16.0, "wave quantization should compress cost");
    }

    #[test]
    fn t4_bf16_collect_returns_none() {
        let mut gpu = Gpu::by_name("t4").unwrap();
        assert!(collect(&mut gpu, DType::Bf16, &ProfileSpec::quick()).is_none());
    }

    #[test]
    fn gemv_profile_bandwidth_interpolation_is_clamped_and_smooth() {
        let p = GemvProfile {
            launch_s: 1e-6,
            ws_bytes: vec![1e6, 1e7, 1e8],
            bw: vec![2e12, 1e12, 5e11],
        };
        assert_eq!(p.bw_at(1e5), 2e12, "clamped below the grid");
        assert_eq!(p.bw_at(1e9), 5e11, "clamped above the grid");
        assert_eq!(p.bw_at(1e7), 1e12, "exact on grid points");
        let mid = p.bw_at(10f64.powf(6.5));
        assert!((mid - 1.5e12).abs() < 1e9, "log-midpoint blends linearly");
        // Latency = launch + bytes/bw, monotone in bytes.
        let small = GemmOp::linear(1, 512, 512, DType::F32);
        let large = GemmOp::linear(1, 4096, 4096, DType::F32);
        assert!(p.predict(&large) > p.predict(&small));
    }

    #[test]
    fn decode_projections_route_to_the_measured_memory_bound_model() {
        // The regime split of the ISSUE: decode-step GEMMs must be priced
        // by the gemv profile, and track the simulator's (boost-clock)
        // ground truth closely — the route is memory-bound, so the
        // locked-clock collection transfers without correction.
        let (mut gpu, table) = quick_table("a100", DType::F32);
        gpu.reset();
        gpu.set_freq(FreqMode::Boost);
        let mut errs = Vec::new();
        let mut rng = crate::util::prng::Rng::new(4242);
        for _ in 0..20 {
            let m = rng.int_range(1, 8) as usize; // decode batch
            let n = rng.log_uniform_int(1024, 8192) as usize;
            let k = rng.log_uniform_int(512, 8192) as usize;
            let op = GemmOp::linear(m, n, k, DType::F32);
            assert!(crate::gpusim::gemm::is_gemv_degenerate(&op));
            let pred = table.predict(&gpu, &op).unwrap();
            assert_eq!(pred, table.gemv.predict(&op), "must take the gemv route");
            let truth = profiler::measure(&mut gpu, &Op::Gemm(op), &ProfileSpec::quick())
                .unwrap()
                .mean_s;
            errs.push(rel_err_pct(pred, truth));
        }
        let mean = crate::util::stats::mean(&errs);
        assert!(mean < 25.0, "gemv mean rel err {mean}% errs={errs:?}");
    }

    #[test]
    fn skinny_band_routes_to_the_measured_profile_and_tracks_truth() {
        // ISSUE skinny-GEMM satellite: decode batches of 9–32 no longer
        // price through the tensor-core tables — they take the measured
        // rows-interpolated streaming profile, and must track the
        // simulator's boost-clock ground truth.
        let (mut gpu, table) = quick_table("a100", DType::F32);
        gpu.reset();
        gpu.set_freq(FreqMode::Boost);
        let mut errs = Vec::new();
        let mut rng = crate::util::prng::Rng::new(777);
        for _ in 0..20 {
            let m = rng.int_range(9, 32) as usize; // continuous-batching band
            let n = rng.log_uniform_int(1024, 8192) as usize;
            let k = rng.log_uniform_int(512, 8192) as usize;
            let op = GemmOp::linear(m, n, k, DType::F32);
            assert!(crate::gpusim::gemm::is_skinny(&op));
            assert!(!crate::gpusim::gemm::is_gemv_degenerate(&op));
            let pred = table.predict(&gpu, &op).unwrap();
            assert_eq!(pred, table.skinny.predict(&op), "must take the skinny route");
            let truth = profiler::measure(&mut gpu, &Op::Gemm(op), &ProfileSpec::quick())
                .unwrap()
                .mean_s;
            errs.push(rel_err_pct(pred, truth));
        }
        let mean = crate::util::stats::mean(&errs);
        assert!(mean < 25.0, "skinny mean rel err {mean}% errs={errs:?}");
        // Prediction is continuous across the gemv boundary: an m=8 and
        // an m=9 shape of the same (n, k) must predict within a small
        // factor of each other.
        let t8 = table.predict(&gpu, &GemmOp::linear(8, 4096, 4096, DType::F32)).unwrap();
        let t9 = table.predict(&gpu, &GemmOp::linear(9, 4096, 4096, DType::F32)).unwrap();
        assert!(
            (t9 / t8 - 1.0).abs() < 0.35,
            "gemv→skinny boundary cliff: {t8} vs {t9}"
        );
        // And it interpolates monotonically in rows at fixed (n, k).
        let mut prev = 0.0;
        for m in [9usize, 16, 24, 32] {
            let t = table.predict(&gpu, &GemmOp::linear(m, 4096, 4096, DType::F32)).unwrap();
            assert!(t > prev, "m={m}: {t} <= {prev}");
            prev = t;
        }
    }

    #[test]
    fn scale_factor_plus_offset_matches_predict() {
        let (gpu, table) = quick_table("a100", DType::F32);
        let op = GemmOp::mm(2048, 512, 777, DType::F32);
        let cfg = heuristic::algo_get_heuristic(&gpu.spec, &op).unwrap();
        let profile = table.profiles.iter().find(|p| p.kernel_id == cfg.kernel_id).unwrap();
        let via_predict = table.predict_with_config(&gpu, &op, cfg).unwrap();
        let kb = op.k.div_ceil(cfg.splitk) as f64;
        let via_scale = profile.work_at_k(kb)
            * table.scale_factor(&gpu, &op, cfg).unwrap()
            + table.host_offset(&op, cfg).unwrap();
        assert!((via_predict - via_scale).abs() / via_predict < 1e-12);
    }
}
