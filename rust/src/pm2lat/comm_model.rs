//! PM2Lat on collectives: a measured staircase over (participant count ×
//! payload size), mirroring the GemmTable/AttnProfile split — `gpusim`'s
//! `comm.rs` is the hidden ground truth, this profile is what the
//! predictor learns from timing collectives like any other op.
//!
//! Collection is cheap (two kinds × 3 ring sizes × 6 payloads) because
//! collectives are launch + wire time with no kernel-selection surface:
//! there is no autotuner to differentiate, so one staircase per dtype
//! suffices. Prediction interpolates the payload axis piecewise-linearly
//! (linear extrapolation beyond the grid, like `VecProfile`) and rescales
//! the launch-free work across ring sizes by the per-rank wire volume
//! `steps(p)·(bytes/p)` of the ring algorithm.

use crate::gpusim::Gpu;
use crate::ops::{CommKind, CommOp, DType, Op};
use crate::profiler::{self, ProfileSpec};

/// Ring-size collection grid.
pub const PARTS_GRID: [usize; 3] = [2, 4, 8];
/// Payload collection grid in elements (log2-spaced).
pub const COMM_ELEMS_GRID: [usize; 6] =
    [1 << 12, 1 << 14, 1 << 16, 1 << 18, 1 << 20, 1 << 22];

/// Measured collective staircase for one (device, dtype).
#[derive(Clone, Debug)]
pub struct CommProfile {
    pub device: String,
    pub dtype: DType,
    /// Durations at [PARTS_GRID row][COMM_ELEMS_GRID column].
    pub all_reduce: [[f64; 6]; 3],
    pub all_gather: [[f64; 6]; 3],
    /// Launch + rendezvous overhead, measured from a single-participant
    /// collective (a local no-op: pure launch).
    pub launch_s: f64,
}

impl CommProfile {
    /// Per-rank ring wire volume factor: `steps(p) / p` (× bytes gives
    /// the bytes each rank moves). The unit that transfers measured work
    /// between ring sizes.
    fn volume(kind: CommKind, p: usize) -> f64 {
        kind.ring_steps(p) as f64 / p.max(1) as f64
    }

    /// Predict one collective. Single-participant collectives are
    /// launch-only, matching the simulator's degenerate case exactly.
    pub fn predict(&self, c: &CommOp) -> f64 {
        if c.participants <= 1 {
            return self.launch_s;
        }
        let grid = match c.kind {
            CommKind::AllReduce => &self.all_reduce,
            CommKind::AllGather => &self.all_gather,
        };
        // Nearest collected ring size at or below the request (the first
        // row for p < 2); work rescales by wire volume below.
        let pi = PARTS_GRID
            .iter()
            .rposition(|&p| p <= c.participants)
            .unwrap_or(0);
        let row = &grid[pi];
        // Piecewise-linear in payload between grid points, linear beyond.
        let e = (c.elems as f64)
            .clamp(COMM_ELEMS_GRID[0] as f64, *COMM_ELEMS_GRID.last().unwrap() as f64);
        let mut idx = 0;
        while idx + 2 < COMM_ELEMS_GRID.len() && (COMM_ELEMS_GRID[idx + 1] as f64) < e {
            idx += 1;
        }
        let e1 = COMM_ELEMS_GRID[idx] as f64;
        let e3 = COMM_ELEMS_GRID[idx + 1] as f64;
        let d1 = row[idx];
        let d3 = row[idx + 1];
        let dur = d1 + (e - e1) / (e3 - e1) * (d3 - d1);
        let extra = (c.elems as f64 / e).max(1.0);
        // The smallest-payload measurement is effectively wire-free, so
        // it isolates the per-step fixed cost; everything above it is
        // payload-proportional wire time. The two components rescale
        // differently across ring sizes: fixed cost by the step count,
        // wire time by the per-rank volume `steps(p)·(bytes/p)`.
        let p0 = PARTS_GRID[pi];
        let fixed = (row[0] - self.launch_s).max(0.0);
        let wire = (dur - row[0]).max(0.0) * extra;
        let step_ratio =
            c.kind.ring_steps(c.participants) as f64 / c.kind.ring_steps(p0) as f64;
        self.launch_s
            + fixed * step_ratio
            + wire * Self::volume(c.kind, c.participants) / Self::volume(c.kind, p0)
    }
}

/// Time the collective staircase on `gpu`. Collectives run on the copy
/// engines at any core clock, so no locked-clock discipline is needed —
/// the grid collects directly under the profiler's noise averaging.
pub fn collect(gpu: &mut Gpu, dtype: DType, spec: &ProfileSpec) -> Option<CommProfile> {
    if !gpu.spec.supports(dtype) {
        return None;
    }
    let launch_s = profiler::measure(
        gpu,
        &Op::Comm(CommOp::all_reduce(COMM_ELEMS_GRID[0], dtype, 1)),
        spec,
    )
    .ok()?
    .mean_s;
    let mut all_reduce = [[0.0; 6]; 3];
    let mut all_gather = [[0.0; 6]; 3];
    for (pi, &parts) in PARTS_GRID.iter().enumerate() {
        for (ei, &elems) in COMM_ELEMS_GRID.iter().enumerate() {
            all_reduce[pi][ei] = profiler::measure(
                gpu,
                &Op::Comm(CommOp::all_reduce(elems, dtype, parts)),
                spec,
            )
            .ok()?
            .mean_s;
            all_gather[pi][ei] = profiler::measure(
                gpu,
                &Op::Comm(CommOp::all_gather(elems, dtype, parts)),
                spec,
            )
            .ok()?
            .mean_s;
        }
    }
    Some(CommProfile {
        device: gpu.spec.name.to_string(),
        dtype,
        all_reduce,
        all_gather,
        launch_s,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn profile(device: &str, dtype: DType) -> (Gpu, CommProfile) {
        let mut gpu = Gpu::by_name(device).unwrap();
        let p = collect(&mut gpu, dtype, &ProfileSpec::quick()).unwrap();
        gpu.reset();
        (gpu, p)
    }

    #[test]
    fn grid_points_predict_close_to_ground_truth() {
        let (gpu, p) = profile("a100", DType::Bf16);
        for &parts in &PARTS_GRID {
            for &elems in &COMM_ELEMS_GRID {
                let c = CommOp::all_reduce(elems, DType::Bf16, parts);
                let truth = crate::gpusim::comm::comm_latency(&gpu.spec, &c);
                let pred = p.predict(&c);
                let err = (pred - truth).abs() / truth;
                assert!(err < 0.10, "p={parts} elems={elems}: err={err}");
            }
        }
    }

    #[test]
    fn off_grid_ring_sizes_rescale_by_wire_volume() {
        let (gpu, p) = profile("a100", DType::Bf16);
        // tp = 3 and tp = 16 are both off the collected grid.
        for parts in [3usize, 16] {
            let c = CommOp::all_reduce(1 << 19, DType::Bf16, parts);
            let truth = crate::gpusim::comm::comm_latency(&gpu.spec, &c);
            let pred = p.predict(&c);
            let err = (pred - truth).abs() / truth;
            assert!(err < 0.25, "p={parts}: pred={pred} truth={truth} err={err}");
        }
    }

    #[test]
    fn single_participant_is_launch_only() {
        let (_, p) = profile("l4", DType::F32);
        let c = CommOp::all_gather(1 << 20, DType::F32, 1);
        assert_eq!(p.predict(&c), p.launch_s);
    }

    #[test]
    fn predictions_monotone_in_payload() {
        let (_, p) = profile("t4", DType::F32);
        let mut prev = 0.0;
        for elems in [1 << 12, 1 << 15, 1 << 18, 1 << 21, 1 << 24] {
            let t = p.predict(&CommOp::all_reduce(elems, DType::F32, 4));
            assert!(t > prev, "elems={elems}");
            prev = t;
        }
    }

    #[test]
    fn unsupported_dtype_collects_nothing() {
        let mut gpu = Gpu::by_name("t4").unwrap();
        assert!(collect(&mut gpu, DType::Bf16, &ProfileSpec::quick()).is_none());
    }
}
