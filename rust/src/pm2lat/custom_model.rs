//! PM2Lat on custom kernels (paper §IV-C / Table VI): the same
//! interpolation-with-kernel-differentiation strategy, adapted with
//! kernel-specific collection resolutions — Triton MatMul profiles each
//! autotune config like a cuBLAS kernel; fused attention profiles a
//! sequence-length grid; Triton vector kernels an element-count grid.

use crate::gpusim::custom::{triton_autotune, triton_registry};
use crate::gpusim::{gemm, FreqMode, Gpu};
use crate::ops::{CustomOp, DType, GemmOp, Op};
use crate::profiler::{self, ProfileSpec};

use super::gemm_model::{KernelProfile, K_GRID};

/// Sequence-length collection grid for attention kernels.
pub const SEQ_GRID: [usize; 7] = [128, 256, 512, 1024, 2048, 4096, 8192];
/// Element-count grid for Triton vector kernels (log2 sizes).
pub const ELEMS_GRID: [usize; 8] = [
    1 << 14, 1 << 16, 1 << 18, 1 << 20, 1 << 22, 1 << 24, 1 << 25, 1 << 26,
];

/// Profiled model for one fused-attention family on one device.
///
/// Fused attention launches one block per (batch, head, Q-tile); PM2Lat
/// applies the same wave quantization it uses for GEMM — the profile
/// stores per-wave durations on the seq grid and predictions scale by the
/// query's wave count (block_q and per-SM residency are public kernel
/// launch parameters).
///
/// Two collection grids cover the two generation regimes:
///
/// * **prefill** (`q == kv == S`): square kernels on `SEQ_GRID`, the
///   compute-bound wave-quantized path (unchanged from §IV-C);
/// * **decode** (`q == 1, kv == S`): single-query kernels streaming a
///   KV cache of `S` entries. Decode launches `batch·heads` thin blocks —
///   almost always a fraction of one wave — so predictions scale with the
///   *block* count over the measured launch-free staircase, not with wave
///   counts. This is the memory-bound route of the ISSUE: decode-shaped
///   attention never prices through the tensor-core wave model.
#[derive(Clone, Debug)]
pub struct AttnProfile {
    /// Prefill durations at SEQ_GRID with the base (batch, heads, head_dim).
    pub dur_s: [f64; 7],
    /// Decode-step durations (q = 1, kv = SEQ_GRID[i]) at the base shape.
    pub decode_dur_s: [f64; 7],
    /// Launch overhead, measured from a single-block decode kernel whose
    /// stream time is negligible.
    pub launch_s: f64,
    pub base_batch: usize,
    pub base_heads: usize,
    pub base_head_dim: usize,
    /// Q-tile rows per block (from the kernel's launch configuration).
    pub block_q: usize,
    /// Blocks per wave (SMs × resident blocks/SM).
    pub wave_capacity: usize,
}

impl AttnProfile {
    fn blocks(&self, batch: usize, heads: usize, q_len: usize) -> usize {
        batch * heads * q_len.div_ceil(self.block_q)
    }

    fn waves(&self, batch: usize, heads: usize, q_len: usize) -> usize {
        self.blocks(batch, heads, q_len).div_ceil(self.wave_capacity)
    }

    /// Bracket `kv` on the seq grid: (index, clamped kv, linear fraction,
    /// beyond-grid extrapolation factor).
    fn bracket(kv_len: usize) -> (usize, f64, f64, f64) {
        let s = (kv_len as f64)
            .clamp(SEQ_GRID[0] as f64, *SEQ_GRID.last().unwrap() as f64);
        let pos = (s / SEQ_GRID[0] as f64).log2();
        let idx = (pos.floor() as usize).min(SEQ_GRID.len() - 2);
        let s1 = SEQ_GRID[idx] as f64;
        let frac = (s - s1) / s1;
        let extra = if (kv_len as f64) > s { kv_len as f64 / s } else { 1.0 };
        (idx, s, frac, extra)
    }

    /// Predict a fused attention kernel of any (q, kv) shape. Prefill
    /// shapes (`q ≥ block_q`) take the wave-quantized path; decode shapes
    /// take the measured block-proportional staircase, with the thin
    /// tile's compute share as a secondary floor.
    ///
    /// `kv_heads < heads` (GQA) scales the *decode* staircase by the
    /// grouped-traffic ratio: the collection shapes are MHA, and the
    /// decode regime is KV-stream-bound, so the measured work shrinks by
    /// exactly the share of cache bytes that grouping removes (computed
    /// from the op's own traffic model — no extra collection needed).
    /// Prefill stays on the measured wave path: it is compute-bound and
    /// grouping never changes the math.
    #[allow(clippy::too_many_arguments)]
    pub fn predict(
        &self,
        batch: usize,
        heads: usize,
        kv_heads: usize,
        q_len: usize,
        kv_len: usize,
        head_dim: usize,
        causal: bool,
    ) -> f64 {
        // Degenerate window: launch-only (mirrors the simulator's gate
        // and guards the 0/0 causal ratio).
        if q_len == 0 || kv_len == 0 {
            return self.launch_s;
        }
        let ratio = crate::ops::attended_pairs(q_len, kv_len, causal)
            / crate::ops::attended_pairs(q_len, kv_len, false);
        let hd = head_dim as f64 / self.base_head_dim as f64;
        let (idx, _, frac, extra) = Self::bracket(kv_len);
        // Per-wave duration at the bracketing grid points (per-block work
        // is linear in kv; the q·kv total lives in the block count).
        let (d1, d3) = (self.dur_s[idx], self.dur_s[idx + 1]);
        let w1 = self.waves(self.base_batch, self.base_heads, SEQ_GRID[idx]) as f64;
        let w3 = self.waves(self.base_batch, self.base_heads, SEQ_GRID[idx + 1]) as f64;
        let per_wave = d1 / w1 + frac * (d3 / w3 - d1 / w1);
        let tile_cost =
            per_wave * extra * self.waves(batch, heads, q_len) as f64 * hd;
        if q_len >= self.block_q {
            return tile_cost * ratio;
        }
        // Decode regime: launch-free staircase interpolated in kv, scaled
        // by the query's block count (decode runs sub-wave, so cost is
        // proportional to resident blocks, not quantized waves). GQA
        // scales the measured (MHA-collected) work by the grouped share
        // of the per-lane traffic: (2·kv·ρ + 4·q) / (2·kv + 4·q) with
        // ρ = kv_heads / heads — 1 for MHA, → ρ as the cache stream
        // dominates.
        let rho = kv_heads.min(heads).max(1) as f64 / heads.max(1) as f64;
        let q = q_len as f64;
        let kv = kv_len as f64;
        let mem_ratio = (2.0 * kv * rho + 4.0 * q) / (2.0 * kv + 4.0 * q);
        let (e1, e3) = (self.decode_dur_s[idx], self.decode_dur_s[idx + 1]);
        let work1 = (e1 - self.launch_s).max(e1 * 0.05);
        let work3 = (e3 - self.launch_s).max(e3 * 0.05);
        let work = (work1 + frac * (work3 - work1)) * mem_ratio;
        let base_blocks = self.blocks(self.base_batch, self.base_heads, 1) as f64;
        let floor = self.launch_s
            + work * extra * hd * self.blocks(batch, heads, q_len) as f64
                / base_blocks;
        floor.max(tile_cost * q_len as f64 / self.block_q as f64) * ratio
    }
}

/// Profiled model for Triton vector kernels: duration at ELEMS_GRID.
#[derive(Clone, Debug)]
pub struct VecProfile {
    pub dur_s: [f64; 8],
}

impl VecProfile {
    pub fn predict(&self, elems: usize) -> f64 {
        let e = (elems as f64)
            .clamp(ELEMS_GRID[0] as f64, *ELEMS_GRID.last().unwrap() as f64);
        // Piecewise-linear in elems between grid points.
        let mut idx = 0;
        while idx + 2 < ELEMS_GRID.len() && (ELEMS_GRID[idx + 1] as f64) < e {
            idx += 1;
        }
        let e1 = ELEMS_GRID[idx] as f64;
        let e3 = ELEMS_GRID[idx + 1] as f64;
        let d1 = self.dur_s[idx];
        let d3 = self.dur_s[idx + 1];
        let base = d1 + (e - e1) / (e3 - e1) * (d3 - d1);
        let extra = (elems as f64 / e).max(1.0); // linear beyond grid
        base * extra
    }
}

/// All custom-kernel profiles for one (device, dtype).
#[derive(Clone, Debug)]
pub struct CustomModel {
    pub device: String,
    pub dtype: DType,
    /// Triton MatMul: a GemmTable over the Triton registry.
    pub triton_mm: Option<TritonTable>,
    pub triton_vec: Option<VecProfile>,
    pub flash_attn: Option<AttnProfile>,
    pub cutlass_attn: Option<AttnProfile>,
}

/// Triton GEMM table: per-config profiles (reuses the Eq. 1/2 machinery).
#[derive(Clone, Debug)]
pub struct TritonTable {
    pub profiles: Vec<KernelProfile>,
    pub boost_speedup: f64,
}

impl TritonTable {
    /// Predict with an explicit Triton config id ("PL TruthCFG": the
    /// config Triton's autotuner actually selected).
    pub fn predict_with_config(&self, gpu: &Gpu, m: usize, n: usize, k: usize, dtype: DType, config_id: usize) -> Option<f64> {
        let profile = self.profiles.iter().find(|p| p.kernel_id == config_id)?;
        let kern = triton_registry(&gpu.spec, dtype).into_iter().nth(config_id)?;
        let blocks = m.div_ceil(kern.tile_m) * n.div_ceil(kern.tile_n);
        let work = profile.work_at_k(k as f64) * profile.effective_waves(blocks, k as f64)
            / self.boost_speedup;
        Some(profile.launch_s + work)
    }

    /// Plain "PL": PM2Lat picks the config it *believes* the autotuner
    /// will choose — the argmin of its own profiled predictions (slightly
    /// different from the autotuner's true pick; Table VI shows both).
    pub fn predict(&self, gpu: &Gpu, m: usize, n: usize, k: usize, dtype: DType) -> Option<f64> {
        self.profiles
            .iter()
            .filter_map(|p| self.predict_with_config(gpu, m, n, k, dtype, p.kernel_id))
            .fold(None, |best, t| Some(best.map_or(t, |b: f64| b.min(t))))
    }
}

/// Collect every custom-kernel profile available on this device.
/// Triton MatMul collects at the locked clock (then boost-calibrates like
/// the GEMM tables); vector + attention kernels collect directly at boost
/// (their evaluation condition — short launches, little sustained heat).
pub fn collect(gpu: &mut Gpu, dtype: DType, spec: &ProfileSpec) -> CustomModel {
    let locked = gpu.spec.max_freq_ghz * 0.7;
    gpu.set_freq(FreqMode::Fixed(locked));
    let triton_mm = collect_triton_mm(gpu, dtype, spec);
    gpu.set_freq(FreqMode::Boost);
    gpu.idle(5.0);
    let triton_vec = collect_vec(gpu, dtype, spec);
    let flash_attn = collect_attn(gpu, dtype, spec, true);
    let cutlass_attn = collect_attn(gpu, dtype, spec, false);
    CustomModel {
        device: gpu.spec.name.to_string(),
        dtype,
        triton_mm,
        triton_vec,
        flash_attn,
        cutlass_attn,
    }
}

fn collect_triton_mm(gpu: &mut Gpu, dtype: DType, spec: &ProfileSpec) -> Option<TritonTable> {
    let kernels = triton_registry(&gpu.spec, dtype);
    if kernels.is_empty() {
        return None;
    }
    let mut profiles = Vec::new();
    for kern in &kernels {
        // Some autotune configs overflow shared memory on small-smem
        // devices — Triton's autotuner skips them, and so do we.
        let Some(bpsm) = gemm::blocks_per_sm(&gpu.spec, kern) else {
            continue;
        };
        let capacity = bpsm * gpu.spec.sm_count;
        let waves = 2;
        let blocks = capacity * waves;
        // Near-square factorization of the block grid.
        let mut tm_count = (blocks as f64).sqrt() as usize;
        while blocks % tm_count != 0 {
            tm_count -= 1;
        }
        let (m, n) = (kern.tile_m * tm_count, kern.tile_n * (blocks / tm_count));
        // Pin the Triton config by evaluating its latency directly:
        // Triton benchmarks configs in isolation the same way.
        let sim = |gpu: &mut Gpu, m: usize, n: usize, k: usize| -> Option<f64> {
            let op = GemmOp::mm(m, n, k, dtype);
            gemm::gemm_latency(&gpu.spec, kern, &op, 1, locked_freq(gpu))
                .map(|b| b * measure_noise(gpu, &op, kern.id, spec))
        };
        // One-wave shape separates launch from per-wave work.
        let mut tm1 = (capacity as f64).sqrt() as usize;
        while capacity % tm1 != 0 {
            tm1 -= 1;
        }
        let _ = tm1;
        // Launch from one-block kernels (well-conditioned subtraction,
        // see gemm_model::collect).
        let Some(t32) = sim(gpu, kern.tile_m, kern.tile_n, 32) else { continue };
        let Some(t64) = sim(gpu, kern.tile_m, kern.tile_n, 64) else { continue };
        let launch = (2.0 * t32 - t64).clamp(0.15 * t32, t32);
        let mut throughput = [0.0; 9];
        let mut d8192 = 0.0;
        let mut ok = true;
        for (i, &k) in K_GRID.iter().enumerate() {
            let Some(dur) = sim(gpu, m, n, k) else {
                ok = false;
                break;
            };
            if k == 8192 {
                d8192 = dur;
            }
            let op = GemmOp::mm(m, n, k, dtype);
            throughput[i] = op.flops() / (dur - launch).max(dur * 0.05);
        }
        if !ok {
            continue;
        }
        let work8192 = (d8192 - launch).max(d8192 * 0.25) / waves as f64;
        // Partial-wave response per occupancy level, at two K depths.
        let k_lo = crate::pm2lat::gemm_model::TAIL_K_LO as usize;
        let Some(d512) = sim(gpu, m, n, k_lo) else { continue };
        let work512 = (d512 - launch).max(d512 * 0.25) / waves as f64;
        let bpsm = capacity / gpu.spec.sm_count;
        let mut tail = Vec::with_capacity(bpsm);
        let mut tail_lo = Vec::with_capacity(bpsm);
        for r in crate::pm2lat::gemm_model::tail_levels(bpsm) {
            let blocks = gpu.spec.sm_count * r;
            let mut tmf = (blocks as f64).sqrt() as usize;
            while blocks % tmf != 0 {
                tmf -= 1;
            }
            let (mf, nf) = (kern.tile_m * tmf, kern.tile_n * (blocks / tmf));
            let (Some(df), Some(dl)) = (sim(gpu, mf, nf, 8192), sim(gpu, mf, nf, k_lo))
            else {
                ok = false;
                break;
            };
            tail.push(((df - launch) / work8192).clamp(0.02, 1.2));
            tail_lo.push(((dl - launch) / work512).clamp(0.02, 1.2));
        }
        if !ok {
            continue;
        }
        for i in 1..tail.len() {
            tail[i] = tail[i].max(tail[i - 1]);
            tail_lo[i] = tail_lo[i].max(tail_lo[i - 1]);
        }
        profiles.push(KernelProfile {
            kernel_id: kern.id,
            base_m: m,
            base_n: n,
            wave_capacity: capacity,
            base_waves: waves,
            launch_s: launch,
            work8192_s: work8192,
            throughput,
            tail,
            tail_lo,
            sm_count: gpu.spec.sm_count,
        });
    }
    if profiles.is_empty() {
        return None;
    }
    let boost_speedup = profiler::calibrate_boost_ratio(gpu, dtype, locked_freq(gpu))
        .unwrap_or(1.0);
    gpu.set_freq(FreqMode::Fixed(locked_freq(gpu)));
    Some(TritonTable { profiles, boost_speedup })
}

fn locked_freq(gpu: &Gpu) -> f64 {
    gpu.spec.max_freq_ghz * 0.7
}

/// Measurement noise proxy for pinned Triton configs: run a handful of
/// repetitions through the executor to keep the collection honest (the
/// executor cannot pin Triton configs directly, so we time the modelled
/// kernel under the profiler's noise discipline).
fn measure_noise(gpu: &mut Gpu, op: &GemmOp, config_id: usize, spec: &ProfileSpec) -> f64 {
    let mut rng = crate::util::prng::Rng::new(
        crate::ops::Op::Gemm(*op).stable_hash() ^ (config_id as u64) ^ 0x7717,
    );
    let mut acc = 0.0;
    let reps = spec.min_reps.max(3);
    for _ in 0..reps {
        acc += rng.lognormal_noise(gpu.noise_sigma);
    }
    acc / reps as f64
}

fn collect_vec(gpu: &mut Gpu, dtype: DType, spec: &ProfileSpec) -> Option<VecProfile> {
    let mut dur_s = [0.0; 8];
    for (i, &elems) in ELEMS_GRID.iter().enumerate() {
        let op = Op::Custom(CustomOp::TritonVec { elems, dtype });
        dur_s[i] = profiler::measure(gpu, &op, spec).ok()?.mean_s;
    }
    Some(VecProfile { dur_s })
}

fn collect_attn(gpu: &mut Gpu, dtype: DType, spec: &ProfileSpec, flash: bool) -> Option<AttnProfile> {
    let (base_batch, base_heads, base_head_dim) = (8usize, 16usize, 64usize);
    let params =
        crate::gpusim::custom::attn_params(&gpu.spec, if flash { "flash" } else { "cutlass" }, dtype);
    let mk = |batch: usize, heads: usize, q_len: usize, kv_len: usize| {
        if flash {
            CustomOp::FlashAttn {
                batch, heads, kv_heads: heads, q_len, kv_len,
                head_dim: base_head_dim, dtype, causal: false,
            }
        } else {
            CustomOp::CutlassAttn {
                batch, heads, kv_heads: heads, q_len, kv_len,
                head_dim: base_head_dim, dtype, causal: false,
            }
        }
    };
    let mut dur_s = [0.0; 7];
    let mut decode_dur_s = [0.0; 7];
    for (i, &seq) in SEQ_GRID.iter().enumerate() {
        // Prefill point (q = kv = S) and decode point (q = 1, kv = S).
        dur_s[i] = profiler::measure(gpu, &Op::Custom(mk(base_batch, base_heads, seq, seq)), spec)
            .ok()?
            .mean_s;
        decode_dur_s[i] =
            profiler::measure(gpu, &Op::Custom(mk(base_batch, base_heads, 1, seq)), spec)
                .ok()?
                .mean_s;
    }
    // Launch overhead: a single-block decode kernel over the smallest
    // cache streams negligible bytes — its duration is ≈ pure launch.
    let launch_s = profiler::measure(gpu, &Op::Custom(mk(1, 1, 1, SEQ_GRID[0])), spec)
        .ok()?
        .mean_s;
    Some(AttnProfile {
        dur_s,
        decode_dur_s,
        launch_s,
        base_batch,
        base_heads,
        base_head_dim,
        block_q: params.block_q,
        wave_capacity: gpu.spec.sm_count * 2,
    })
}

impl CustomModel {
    /// Unified custom-op prediction ("PL" column of Table VI).
    pub fn predict(&self, gpu: &Gpu, op: &CustomOp) -> Option<f64> {
        match *op {
            CustomOp::TritonMM { m, n, k, dtype } => {
                self.triton_mm.as_ref()?.predict(gpu, m, n, k, dtype)
            }
            CustomOp::TritonVec { elems, .. } => {
                Some(self.triton_vec.as_ref()?.predict(elems))
            }
            CustomOp::FlashAttn { batch, heads, kv_heads, q_len, kv_len, head_dim, causal, .. } => {
                Some(self.flash_attn.as_ref()?.predict(
                    batch, heads, kv_heads, q_len, kv_len, head_dim, causal,
                ))
            }
            CustomOp::CutlassAttn { batch, heads, kv_heads, q_len, kv_len, head_dim, causal, .. } => {
                Some(self.cutlass_attn.as_ref()?.predict(
                    batch, heads, kv_heads, q_len, kv_len, head_dim, causal,
                ))
            }
        }
    }

    /// "PL TruthCFG": prediction given the config Triton actually chose.
    pub fn predict_truth_cfg(&self, gpu: &Gpu, op: &CustomOp) -> Option<f64> {
        match *op {
            CustomOp::TritonMM { m, n, k, dtype } => {
                let cfg = triton_autotune(&gpu.spec, m, n, k, dtype)?;
                self.triton_mm.as_ref()?.predict_with_config(gpu, m, n, k, dtype, cfg)
            }
            _ => self.predict(gpu, op),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::{mean, rel_err_pct};

    fn model(dev: &str, dtype: DType) -> (Gpu, CustomModel) {
        let mut gpu = Gpu::by_name(dev).unwrap();
        let m = collect(&mut gpu, dtype, &ProfileSpec::quick());
        gpu.reset();
        (gpu, m)
    }

    #[test]
    fn gates_propagate_to_model() {
        let (_, m_t4) = model("t4", DType::F32);
        assert!(m_t4.flash_attn.is_none(), "no FA2 on Turing");
        assert!(m_t4.cutlass_attn.is_some());
        let (_, m_5070) = model("rtx5070", DType::F32);
        assert!(m_5070.flash_attn.is_none() && m_5070.cutlass_attn.is_none());
        let (_, m_a100) = model("a100", DType::Bf16);
        assert!(m_a100.flash_attn.is_some() && m_a100.triton_mm.is_some());
    }

    #[test]
    fn triton_mm_error_in_table6_range() {
        // Actively-cooled device: passive devices (T4/L4) carry the
        // boost-calibration thermal gap the paper documents in §IV-A —
        // their error levels are asserted at the Table II/VI experiment
        // level instead.
        let (mut gpu, m) = model("rtx3060m", DType::F32);
        let mut errs = Vec::new();
        let mut rng = crate::util::prng::Rng::new(17);
        for _ in 0..15 {
            let mm = rng.log_uniform_int(128, 4096) as usize;
            let n = rng.log_uniform_int(128, 4096) as usize;
            let k = rng.log_uniform_int(64, 8192) as usize;
            let op = CustomOp::TritonMM { m: mm, n, k, dtype: DType::F32 };
            let pred = m.predict(&gpu, &op).unwrap();
            let truth = profiler::measure(&mut gpu, &Op::Custom(op), &ProfileSpec::quick())
                .unwrap()
                .mean_s;
            errs.push(rel_err_pct(pred, truth));
        }
        let e = mean(&errs);
        assert!(e < 20.0, "TritonMM err {e}%");
    }

    #[test]
    fn attention_prediction_tracks_truth() {
        let (mut gpu, m) = model("a100", DType::Bf16);
        let mut errs = Vec::new();
        for (b, h, s) in [(2, 16, 512), (8, 8, 1024), (4, 32, 2048), (1, 8, 4096)] {
            let op = CustomOp::FlashAttn {
                batch: b, heads: h, kv_heads: h, q_len: s, kv_len: s, head_dim: 64,
                dtype: DType::Bf16, causal: false,
            };
            let pred = m.predict(&gpu, &op).unwrap();
            let truth = profiler::measure(&mut gpu, &Op::Custom(op), &ProfileSpec::quick())
                .unwrap()
                .mean_s;
            errs.push(rel_err_pct(pred, truth));
        }
        assert!(mean(&errs) < 25.0, "F-Attn errs {errs:?}");
    }

    #[test]
    fn decode_attention_prediction_tracks_truth_and_grows_with_kv() {
        // The decode staircase: q = 1 kernels streaming a growing cache,
        // off the base collection shape in batch/heads and between grid
        // points in kv.
        let (mut gpu, m) = model("a100", DType::Bf16);
        let mut errs = Vec::new();
        for (b, h, kv) in [
            (4usize, 8usize, 256usize),
            (2, 16, 700),
            (8, 16, 1024),
            (1, 32, 3000),
            (4, 16, 8192),
        ] {
            let op = CustomOp::FlashAttn {
                batch: b, heads: h, kv_heads: h, q_len: 1, kv_len: kv, head_dim: 64,
                dtype: DType::Bf16, causal: true,
            };
            let pred = m.predict(&gpu, &op).unwrap();
            let truth = profiler::measure(&mut gpu, &Op::Custom(op), &ProfileSpec::quick())
                .unwrap()
                .mean_s;
            errs.push(rel_err_pct(pred, truth));
        }
        assert!(mean(&errs) < 30.0, "decode F-Attn errs {errs:?}");
        // Monotone in kv at fixed lanes: the per-step cost of a decode
        // loop grows as the cache fills.
        let mut prev = 0.0;
        for kv in [128usize, 300, 512, 1024, 2048, 4096, 8192, 16000] {
            let p = m
                .predict(&gpu, &CustomOp::FlashAttn {
                    batch: 4, heads: 16, kv_heads: 16, q_len: 1, kv_len: kv, head_dim: 64,
                    dtype: DType::Bf16, causal: true,
                })
                .unwrap();
            assert!(p > prev, "kv={kv}: {p} <= {prev}");
            prev = p;
        }
    }

    #[test]
    fn gqa_decode_prediction_tracks_the_grouped_truth() {
        // ISSUE GQA satellite: grouped-cache decode kernels are priced by
        // the MHA-collected staircase scaled by the grouped-traffic
        // ratio — predictions must stay close to the simulator's grouped
        // ground truth, and an MHA op must predict bit-identically to the
        // pre-GQA model (ρ = 1).
        let (mut gpu, m) = model("a100", DType::Bf16);
        let mut errs = Vec::new();
        for (b, h, kvh, kv) in [
            (4usize, 16usize, 4usize, 1024usize),
            (8, 16, 2, 4096),
            (2, 32, 8, 2048),
            (1, 8, 1, 8192),
        ] {
            let op = CustomOp::FlashAttn {
                batch: b, heads: h, kv_heads: kvh, q_len: 1, kv_len: kv,
                head_dim: 64, dtype: DType::Bf16, causal: true,
            };
            let pred = m.predict(&gpu, &op).unwrap();
            let truth = profiler::measure(&mut gpu, &Op::Custom(op), &ProfileSpec::quick())
                .unwrap()
                .mean_s;
            errs.push(rel_err_pct(pred, truth));
        }
        assert!(mean(&errs) < 35.0, "GQA decode errs {errs:?}");
        // Grouping shrinks the prediction monotonically at fixed lanes.
        let p_of = |kvh| {
            m.predict(&gpu, &CustomOp::FlashAttn {
                batch: 4, heads: 16, kv_heads: kvh, q_len: 1, kv_len: 4096,
                head_dim: 64, dtype: DType::Bf16, causal: true,
            })
            .unwrap()
        };
        assert!(p_of(4) < p_of(8) && p_of(8) < p_of(16));
    }

    #[test]
    fn vec_interpolates_between_grid() {
        let (_, m) = model("rtx3060m", DType::F32);
        let v = m.triton_vec.as_ref().unwrap();
        let d_lo = v.predict(1 << 16);
        let d_mid = v.predict(3 << 15); // between 2^16 and 2^17... lands in range
        let d_hi = v.predict(1 << 20);
        assert!(d_lo <= d_mid && d_mid <= d_hi);
    }

    #[test]
    fn truth_cfg_close_to_plain() {
        let (gpu, m) = model("a100", DType::F32);
        let op = CustomOp::TritonMM { m: 1024, n: 1024, k: 2048, dtype: DType::F32 };
        let plain = m.predict(&gpu, &op).unwrap();
        let truth_cfg = m.predict_truth_cfg(&gpu, &op).unwrap();
        let ratio = plain / truth_cfg;
        assert!(ratio > 0.7 && ratio < 1.4, "ratio={ratio}");
    }
}
