//! Batched PM2Lat prediction through the L1 Pallas kernel (via PJRT):
//! pack a GemmTable into the (MAX_KERNELS × N_K_POINTS) tensor layout the
//! `pm2lat_batch_predict_*` artifacts expect, resolve configs, and predict
//! thousands of GEMM latencies per executable launch. This is the NAS
//! preprocessing hot path of §IV-D2.

use anyhow::{anyhow, Result};

use crate::gpusim::{heuristic, Gpu};
use crate::ops::GemmOp;
use crate::runtime::{ArgValue, Runtime};

use super::gemm_model::{GemmTable, K_GRID};

/// GemmTable packed for the artifact: row `kernel_id` holds the profiled
/// throughput normalized so the kernel's Eq. (1) evaluates directly.
pub struct PackedTable {
    pub table: Vec<f32>,
    pub base_dur: Vec<f32>,
    pub nk: usize,
    pub npts: usize,
}

pub fn pack(table: &GemmTable, nk: usize, npts: usize) -> PackedTable {
    assert_eq!(npts, K_GRID.len());
    let mut t = vec![1.0f32; nk * npts];
    let mut base = vec![0.0f32; nk];
    for p in &table.profiles {
        if p.kernel_id >= nk {
            continue;
        }
        for (j, &thr) in p.throughput.iter().enumerate() {
            // Normalize to the K=8192 throughput so values stay O(1).
            t[p.kernel_id * npts + j] = (thr / p.throughput[npts - 1]) as f32;
        }
        // Per-wave work at K = 8192: the artifact multiplies by the K
        // factor, the interpolated 1/throughput and the scale lane.
        base[p.kernel_id] = p.work8192_s as f32;
    }
    PackedTable { table: t, base_dur: base, nk, npts }
}

/// Batched prediction session bound to one artifact batch size.
pub struct BatchPredictor<'rt> {
    runtime: &'rt Runtime,
    artifact: String,
    pub batch: usize,
    packed: PackedTable,
}

impl<'rt> BatchPredictor<'rt> {
    pub fn new(runtime: &'rt Runtime, table: &GemmTable, batch: usize) -> Result<Self> {
        let artifact = format!("pm2lat_batch_predict_b{batch}");
        if !runtime.manifest.artifacts.contains_key(&artifact) {
            return Err(anyhow!("no artifact {artifact}"));
        }
        let nk = runtime.manifest.max_kernels;
        let npts = runtime.manifest.n_k_points;
        runtime.warm(&artifact)?;
        Ok(BatchPredictor {
            runtime,
            artifact,
            batch,
            packed: pack(table, nk, npts),
        })
    }

    /// Predict a batch of GEMMs. The per-query config is resolved through
    /// the heuristic API; K/scale are packed into lanes; one PJRT launch
    /// evaluates Eq. (1)/(2) for every lane. Short batches are padded.
    pub fn predict(&self, gpu: &Gpu, table: &GemmTable, ops: &[GemmOp]) -> Result<Vec<Option<f64>>> {
        let b = self.batch;
        let mut k_vals = vec![0f32; b];
        let mut kids = vec![0i32; b];
        let mut scale = vec![0f32; b];
        let mut offset = vec![0f64; ops.len()];
        let mut valid = vec![false; ops.len()];
        let mut out = vec![None; ops.len()];
        if ops.len() > b {
            return Err(anyhow!("batch too large: {} > {}", ops.len(), b));
        }
        for (i, op) in ops.iter().enumerate() {
            let Some(cfg) = heuristic::algo_get_heuristic_cached(gpu, op) else {
                continue;
            };
            let Some(s) = table.scale_factor(gpu, op, cfg) else {
                continue;
            };
            let Some(off) = table.host_offset(op, cfg) else {
                continue;
            };
            let kb = op.k.div_ceil(cfg.splitk) as f64;
            k_vals[i] = kb as f32;
            kids[i] = cfg.kernel_id as i32;
            // The artifact computes work·(K/8192)·(orgThr/newThr)·scale
            // with the *normalized* table (orgThr = 1), matching Eq. (1)
            // exactly. K beyond the grid is clamped in-kernel; fold the
            // linear extrapolation into the scale lane. Launch + split-K
            // epilogue are additive host-side terms.
            let k_clamped = kb.clamp(K_GRID[0] as f64, *K_GRID.last().unwrap() as f64);
            scale[i] = (s * (kb / k_clamped)) as f32;
            offset[i] = off;
            valid[i] = true;
        }
        let result = self.runtime.call(
            &self.artifact,
            &[
                ArgValue::F32(&self.packed.table, &[self.packed.nk, self.packed.npts]),
                ArgValue::F32(&self.packed.base_dur, &[self.packed.nk]),
                ArgValue::F32(&k_vals, &[b]),
                ArgValue::I32(&kids, &[b]),
                ArgValue::F32(&scale, &[b]),
            ],
        )?;
        for (i, v) in valid.iter().enumerate() {
            if *v {
                out[i] = Some(result[0][i] as f64 + offset[i]);
            }
        }
        Ok(out)
    }

    /// Predict arbitrarily many GEMMs, internally chunking to the artifact
    /// batch size (`ops.len().div_ceil(self.batch)` PJRT launches).
    /// Results in input order; the service's batched path routes through
    /// this so callers never handle lane-count limits themselves.
    pub fn predict_all(
        &self,
        gpu: &Gpu,
        table: &GemmTable,
        ops: &[GemmOp],
    ) -> Result<Vec<Option<f64>>> {
        let mut out = Vec::with_capacity(ops.len());
        for chunk in ops.chunks(self.batch) {
            out.extend(self.predict(gpu, table, chunk)?);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::DType;
    use crate::pm2lat::gemm_model;
    use crate::profiler::ProfileSpec;

    #[test]
    fn batched_matches_scalar_path() {
        let rt = Runtime::open_default().expect("make artifacts");
        let mut gpu = Gpu::by_name("a100").unwrap();
        let table = gemm_model::collect(&mut gpu, DType::F32, &ProfileSpec::quick()).unwrap();
        gpu.reset();
        let bp = BatchPredictor::new(&rt, &table, 1024).unwrap();
        let mut rng = crate::util::prng::Rng::new(7);
        let ops: Vec<GemmOp> = (0..200)
            .map(|_| {
                GemmOp::mm(
                    rng.log_uniform_int(64, 8192) as usize,
                    rng.log_uniform_int(64, 8192) as usize,
                    rng.log_uniform_int(32, 20000) as usize,
                    DType::F32,
                )
            })
            .collect();
        let batched = bp.predict(&gpu, &table, &ops).unwrap();
        for (op, got) in ops.iter().zip(&batched) {
            let want = table.predict(&gpu, op).unwrap();
            let got = got.expect("valid op");
            assert!(
                (got - want).abs() / want < 2e-3,
                "op {op:?}: batched {got} scalar {want}"
            );
        }
    }

    #[test]
    fn predict_all_chunks_match_scalar() {
        let rt = Runtime::open_default().expect("make artifacts");
        let mut gpu = Gpu::by_name("a100").unwrap();
        let table = gemm_model::collect(&mut gpu, DType::F32, &ProfileSpec::quick()).unwrap();
        gpu.reset();
        // Batch 1024 artifact, 2500 ops → 3 chunks.
        let bp = BatchPredictor::new(&rt, &table, 1024).unwrap();
        let mut rng = crate::util::prng::Rng::new(15);
        let ops: Vec<GemmOp> = (0..2500)
            .map(|_| {
                GemmOp::mm(
                    rng.log_uniform_int(64, 8192) as usize,
                    rng.log_uniform_int(64, 8192) as usize,
                    rng.log_uniform_int(64, 8192) as usize,
                    DType::F32,
                )
            })
            .collect();
        let all = bp.predict_all(&gpu, &table, &ops).unwrap();
        assert_eq!(all.len(), ops.len());
        for (op, got) in ops.iter().zip(&all).step_by(97) {
            let want = table.predict(&gpu, op).unwrap();
            let got = got.expect("valid op");
            assert!((got - want).abs() / want < 2e-3, "op {op:?}: {got} vs {want}");
        }
    }

    #[test]
    fn unsupported_lane_is_none() {
        let rt = Runtime::open_default().expect("make artifacts");
        let mut gpu = Gpu::by_name("t4").unwrap();
        let table = gemm_model::collect(&mut gpu, DType::F32, &ProfileSpec::quick()).unwrap();
        let bp = BatchPredictor::new(&rt, &table, 1024).unwrap();
        let ops = vec![
            GemmOp::mm(128, 128, 128, DType::F32),
            GemmOp::mm(128, 128, 128, DType::Bf16), // unsupported on T4
        ];
        let out = bp.predict(&gpu, &table, &ops).unwrap();
        assert!(out[0].is_some());
        assert!(out[1].is_none());
    }
}
