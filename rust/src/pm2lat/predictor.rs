//! Unified PM2Lat predictor: one-time per-device collection (GEMM tables,
//! utility regression, custom-kernel profiles), then fast analytical
//! prediction for any op — and whole models by sequential-kernel summation
//! (paper §III).

use crate::gpusim::Gpu;
use crate::models::transformer::{GenerationSpec, TransformerConfig};
use crate::ops::{DType, Op};
use crate::profiler::ProfileSpec;

use super::comm_model::{self, CommProfile};
use super::custom_model::{self, CustomModel};
use super::gemm_model::{self, GemmTable};
use super::utility_model::{self, UtilityModel};

/// Predicted latency of one autoregressive generation: the prefill pass
/// plus every decode step. Decode-step cost grows with the KV cache, so
/// the vector is the full latency *curve*, not just a total.
#[derive(Clone, Debug, PartialEq)]
pub struct GenerationPrediction {
    pub prefill_s: f64,
    /// Per-step decode latency; `step_s[t]` reads a cache of
    /// `prompt_len + t + 1` entries.
    pub step_s: Vec<f64>,
}

impl GenerationPrediction {
    pub fn total_s(&self) -> f64 {
        self.prefill_s + self.step_s.iter().sum::<f64>()
    }

    /// Mean decode-step latency — the serving TPOT metric; 0 when
    /// nothing is generated.
    pub fn time_per_output_token_s(&self) -> f64 {
        if self.step_s.is_empty() {
            0.0
        } else {
            self.step_s.iter().sum::<f64>() / self.step_s.len() as f64
        }
    }

    /// Steady-state decode throughput (tokens/s); 0 without decode steps.
    pub fn tokens_per_s(&self) -> f64 {
        let tpot = self.time_per_output_token_s();
        if tpot > 0.0 {
            1.0 / tpot
        } else {
            0.0
        }
    }
}

/// All fitted PM2Lat state for one device.
pub struct Pm2Lat {
    pub device: String,
    gemm: [Option<GemmTable>; 2],
    util: [Option<UtilityModel>; 2],
    custom: [Option<CustomModel>; 2],
    comm: [Option<CommProfile>; 2],
}

fn slot(dtype: DType) -> usize {
    match dtype {
        DType::F32 => 0,
        DType::Bf16 => 1,
    }
}

impl Pm2Lat {
    /// Run the full data-collection and fitting pass on the target device
    /// ("for newer or newly added devices, we rerun the full
    /// data-collection and analysis process on the target hardware").
    pub fn build(gpu: &mut Gpu, spec: &ProfileSpec) -> Pm2Lat {
        Self::build_dtypes(gpu, spec, &[DType::F32, DType::Bf16], true)
    }

    /// Collection restricted to selected dtypes / skipping custom kernels
    /// (cheaper for focused experiments).
    pub fn build_dtypes(
        gpu: &mut Gpu,
        spec: &ProfileSpec,
        dtypes: &[DType],
        with_custom: bool,
    ) -> Pm2Lat {
        let mut out = Pm2Lat {
            device: gpu.spec.name.to_string(),
            gemm: [None, None],
            util: [None, None],
            custom: [None, None],
            comm: [None, None],
        };
        for &dt in dtypes {
            if !gpu.spec.supports(dt) {
                continue;
            }
            out.gemm[slot(dt)] = gemm_model::collect(gpu, dt, spec);
            out.util[slot(dt)] = utility_model::fit(gpu, dt, spec);
            if with_custom {
                out.custom[slot(dt)] = Some(custom_model::collect(gpu, dt, spec));
            }
            out.comm[slot(dt)] = comm_model::collect(gpu, dt, spec);
            gpu.reset();
        }
        out
    }

    pub fn gemm_table(&self, dtype: DType) -> Option<&GemmTable> {
        self.gemm[slot(dtype)].as_ref()
    }
    pub fn utility_model(&self, dtype: DType) -> Option<&UtilityModel> {
        self.util[slot(dtype)].as_ref()
    }
    pub fn custom_model(&self, dtype: DType) -> Option<&CustomModel> {
        self.custom[slot(dtype)].as_ref()
    }
    pub fn comm_profile(&self, dtype: DType) -> Option<&CommProfile> {
        self.comm[slot(dtype)].as_ref()
    }

    /// Predict the latency of one op on the profiled device. `gpu` is
    /// consulted only through public interfaces (heuristic API, occupancy
    /// calculator, NCU counter export) — never the latency physics.
    pub fn predict(&self, gpu: &Gpu, op: &Op) -> Option<f64> {
        match op {
            Op::Gemm(g) => self.gemm[slot(g.dtype)].as_ref()?.predict(gpu, g),
            Op::Util(u) => {
                let counters = gpu.counters(op, None).ok()?;
                Some(self.util[slot(u.dtype)].as_ref()?.predict(u, &counters))
            }
            Op::Custom(c) => {
                self.custom[slot(op.dtype())].as_ref()?.predict(gpu, c)
            }
            // Collectives are priced from the measured staircase — the
            // same learn-from-timings discipline as every other op family.
            Op::Comm(c) => Some(self.comm[slot(c.dtype)].as_ref()?.predict(c)),
        }
    }

    /// Whole-model latency: sequential CUDA-kernel execution (paper §III:
    /// "aggregates the predicted latencies of all layers, assuming
    /// sequential execution").
    pub fn predict_trace(&self, gpu: &Gpu, trace: &[Op]) -> Option<f64> {
        let mut total = 0.0;
        for op in trace {
            total += self.predict(gpu, op)?;
        }
        Some(total)
    }

    /// Whole-model latency over the graph IR: per-node predictions
    /// aggregated as the `streams`-bounded critical path. `streams = 1`
    /// reproduces [`Pm2Lat::predict_trace`] over the lowered trace
    /// bit-for-bit; more streams expose branch concurrency. `None` when
    /// any node's op is unsupported on the device (fused attention nodes
    /// require the custom-kernel profile, i.e. `build` with custom
    /// collection enabled).
    pub fn predict_graph(
        &self,
        gpu: &Gpu,
        graph: &crate::graph::ModelGraph,
        streams: usize,
    ) -> Option<f64> {
        crate::graph::predict_graph_latency(graph, streams, |op| self.predict(gpu, op))
    }

    /// [`Pm2Lat::predict_graph`] with the per-node predictions of large
    /// graphs fanned across the scoped worker pool. Per-node predictions
    /// are independent pure functions of `(gpu, op)` — the same shared
    /// immutable borrow the coordinator's scalar fan-out already
    /// exploits — and the schedule then consumes the durations in node
    /// order, so the result is bit-identical to the serial path. Small
    /// graphs (or `threads <= 1`) take the serial path directly: thread
    /// spawn costs more than the prediction below a few hundred nodes.
    /// A big ragged serving iteration (dozens of slots × dozens of
    /// layers) clears the threshold comfortably.
    pub fn predict_graph_pooled(
        &self,
        gpu: &Gpu,
        graph: &crate::graph::ModelGraph,
        streams: usize,
        threads: usize,
    ) -> Option<f64> {
        const MIN_PARALLEL_NODES: usize = 512;
        const CHUNK: usize = 64;
        if threads <= 1 || graph.len() < MIN_PARALLEL_NODES {
            return self.predict_graph(gpu, graph, streams);
        }
        let per_node = crate::util::pool::parallel_map_chunked(
            graph.nodes(),
            threads,
            CHUNK,
            |n| self.predict(gpu, &n.op),
        );
        let mut dur = Vec::with_capacity(per_node.len());
        for v in per_node {
            dur.push(v?);
        }
        Some(crate::graph::schedule::schedule(graph, streams, &dur).makespan_s)
    }

    /// [`Pm2Lat::predict_graph`] with kernel-band observability: one
    /// [`crate::obs::TraceEvent::KernelPriced`] per non-collective node
    /// and one [`crate::obs::TraceEvent::CommPriced`] per collective,
    /// emitted to `sink` in node order as each prediction lands. The
    /// returned latency is bit-identical to `predict_graph` — same
    /// per-node predictions in the same order, same schedule over the
    /// same duration vector; the sink only watches them go by. Drives
    /// `serve-sim --trace-level kernel`.
    pub fn predict_graph_traced(
        &self,
        gpu: &Gpu,
        graph: &crate::graph::ModelGraph,
        streams: usize,
        sink: &dyn crate::obs::TraceSink,
    ) -> Option<f64> {
        use crate::obs::TraceEvent;
        let mut dur = Vec::with_capacity(graph.len());
        for (i, n) in graph.nodes().iter().enumerate() {
            let v = self.predict(gpu, &n.op)?;
            match &n.op {
                Op::Comm(c) => sink.emit(&TraceEvent::CommPriced {
                    node: i,
                    op: c.kind.name(),
                    bytes: c.bytes(),
                    dur_s: v,
                }),
                Op::Gemm(_) => {
                    sink.emit(&TraceEvent::KernelPriced { node: i, op: "gemm", dur_s: v })
                }
                Op::Util(_) => {
                    sink.emit(&TraceEvent::KernelPriced { node: i, op: "util", dur_s: v })
                }
                Op::Custom(c) => {
                    sink.emit(&TraceEvent::KernelPriced { node: i, op: c.name(), dur_s: v })
                }
            }
            dur.push(v);
        }
        Some(crate::graph::schedule::schedule(graph, streams, &dur).makespan_s)
    }

    /// Whole-generation latency: the prefill graph plus one decode graph
    /// per emitted token, each aggregated as the `streams`-bounded
    /// critical path. With `gen_len == 0` this is bit-for-bit the plain
    /// prefill prediction (`predict_graph` over `cfg.graph(batch,
    /// prompt_len)`). Decode steps route through the memory-bound models
    /// (gemv projections, KV-bound attention) automatically — the regime
    /// split lives in [`Pm2Lat::predict`], not here. `None` when any op
    /// is unsupported on the device.
    pub fn predict_generation(
        &self,
        gpu: &Gpu,
        cfg: &TransformerConfig,
        batch: usize,
        spec: &GenerationSpec,
        streams: usize,
    ) -> Option<GenerationPrediction> {
        let (prefill, steps) = cfg.generation_graphs(batch, spec);
        self.predict_generation_graphs(gpu, &prefill, &steps, streams)
    }

    /// Aggregate an already-expanded generation — the prefill graph plus
    /// per-step decode graphs, possibly rewritten by passes (causal
    /// propagation, fusion) — into one [`GenerationPrediction`]. This is
    /// the single place generation aggregation lives; `predict_generation`
    /// and pass-driving callers (e.g. `pm2lat generate --fuse`) both feed
    /// it.
    pub fn predict_generation_graphs(
        &self,
        gpu: &Gpu,
        prefill: &crate::graph::ModelGraph,
        steps: &[crate::graph::ModelGraph],
        streams: usize,
    ) -> Option<GenerationPrediction> {
        let prefill_s = self.predict_graph(gpu, prefill, streams)?;
        let mut step_s = Vec::with_capacity(steps.len());
        for g in steps {
            step_s.push(self.predict_graph(gpu, g, streams)?);
        }
        Some(GenerationPrediction { prefill_s, step_s })
    }

    /// Expected latency curve of a speculative-decoding generation: the
    /// target's prefill, the draft's prompt ingestion, then one
    /// [`crate::spec_decode::SpecRound`] per expected verification round
    /// — `k` draft decode steps plus one `q = k + 1` target verification
    /// pass ([`TransformerConfig::verify_graph`]), each round committing
    /// `E[τ] + 1` tokens in expectation (the closed form of
    /// [`crate::spec_decode::AcceptanceModel`], clamped at the tail).
    /// The committed context is integerized round to round, so KV
    /// windows stay real graph shapes. With `k = 0` the curve *is* plain
    /// decode bit for bit: no draft graphs run, every verification pass
    /// is node-identical to the matching
    /// [`TransformerConfig::decode_graph`], and the rounds reproduce
    /// [`Pm2Lat::predict_generation`]'s `step_s` exactly — the
    /// degenerate anchor in `tests/spec_decode.rs`. Note the draft's
    /// re-ingestion of tokens it did not itself propose (the corrected
    /// token per round) is not modeled, the standard simplification in
    /// speculative-decoding cost analyses. `None` when any op of either
    /// model is unsupported on the device.
    pub fn predict_speculative(
        &self,
        gpu: &Gpu,
        spec: &crate::spec_decode::SpecConfig,
        batch: usize,
        gen: &GenerationSpec,
        streams: usize,
    ) -> Option<crate::spec_decode::SpeculativePrediction> {
        use crate::spec_decode::{SpecRound, SpeculativePrediction};
        let k = spec.k;
        let prefill_s =
            self.predict_graph(gpu, &spec.target.graph(batch, gen.prompt_len), streams)?;
        let draft_prefill_s = if k > 0 {
            self.predict_graph(gpu, &spec.draft.graph(batch, gen.prompt_len), streams)?
        } else {
            0.0
        };
        // E[tokens/round] ≥ 1 always — the verification pass's own token
        // guarantees progress, so the loop terminates in ≤ gen_len rounds.
        let m = spec.acceptance.expected_tokens_per_round(k);
        let mut rounds = Vec::new();
        let mut produced = 0.0f64;
        while produced + 1e-9 < gen.gen_len as f64 {
            let committed = gen.prompt_len + produced.round() as usize;
            let tokens = m.min(gen.gen_len as f64 - produced);
            let mut draft_s = 0.0;
            for j in 0..k {
                let g = spec.draft.decode_graph(batch, committed + j + 1);
                draft_s += self.predict_graph(gpu, &g, streams)?;
            }
            let kv_len = committed + k + 1;
            let verify_s =
                self.predict_graph(gpu, &spec.target.verify_graph(batch, kv_len, k), streams)?;
            rounds.push(SpecRound { kv_len, draft_s, verify_s, tokens });
            produced += tokens;
        }
        Some(SpeculativePrediction { prefill_s, draft_prefill_s, gen_len: gen.gen_len, k, rounds })
    }

    /// Throughput-vs-acceptance curve at fixed `k`: expected decode
    /// tokens/s of [`Pm2Lat::predict_speculative`] for each uniform α in
    /// `alphas` — how good the draft has to be before speculation pays.
    pub fn speculative_alpha_curve(
        &self,
        gpu: &Gpu,
        spec: &crate::spec_decode::SpecConfig,
        batch: usize,
        gen: &GenerationSpec,
        streams: usize,
        alphas: &[f64],
    ) -> Option<Vec<(f64, f64)>> {
        let mut curve = Vec::with_capacity(alphas.len());
        for &a in alphas {
            let mut s = spec.clone();
            s.acceptance = crate::spec_decode::AcceptanceModel::uniform(a);
            let p = self.predict_speculative(gpu, &s, batch, gen, streams)?;
            curve.push((a, p.tokens_per_s()));
        }
        Some(curve)
    }

    /// Crossover-k analysis: expected decode throughput at each draft
    /// length in `ks` against the plain-decode baseline
    /// ([`Pm2Lat::predict_generation`] of the target over the same
    /// generation). Returns the per-k
    /// [`crate::spec_decode::CrossoverPoint`] rows plus the argmax k; a
    /// speedup < 1 everywhere
    /// means this draft/acceptance pairing never pays on this device.
    pub fn speculative_crossover(
        &self,
        gpu: &Gpu,
        spec: &crate::spec_decode::SpecConfig,
        batch: usize,
        gen: &GenerationSpec,
        streams: usize,
        ks: &[usize],
    ) -> Option<(Vec<crate::spec_decode::CrossoverPoint>, usize)> {
        let base =
            self.predict_generation(gpu, &spec.target, batch, gen, streams)?.tokens_per_s();
        let mut points = Vec::with_capacity(ks.len());
        let mut best = (0usize, f64::NEG_INFINITY);
        for &k in ks {
            let mut s = spec.clone();
            s.k = k;
            let tps = self.predict_speculative(gpu, &s, batch, gen, streams)?.tokens_per_s();
            if tps > best.1 {
                best = (k, tps);
            }
            points.push(crate::spec_decode::CrossoverPoint {
                k,
                tokens_per_s: tps,
                speedup: if base > 0.0 { tps / base } else { 0.0 },
            });
        }
        Some((points, best.0))
    }

    /// Per-prediction cost is the headline of §IV-D2 — expose a cheap
    /// query used by the speed benchmarks: number of fitted tables.
    pub fn n_tables(&self) -> usize {
        self.gemm.iter().flatten().count()
            + self.util.iter().flatten().count()
            + self.custom.iter().flatten().count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::{GemmOp, UtilKind, UtilOp};
    use crate::profiler;
    use crate::util::stats::{mean, rel_err_pct};

    #[test]
    fn pooled_graph_prediction_is_bit_identical_to_serial() {
        let (gpu, pl) = build("a100", &[DType::F32]);
        let cfg = crate::models::zoo::gpt2_large();
        // A big ragged serving iteration: well past the parallel
        // threshold (36 layers × a dozen slots of attention subgraphs).
        let slots: Vec<crate::models::SeqSlot> = (0..12)
            .map(|i| crate::models::SeqSlot { q_len: 1 + (i % 3) * 16, kv_len: 64 + i * 7 })
            .collect();
        let g = cfg.mixed_batch_graph(&slots);
        assert!(g.len() >= 512, "test graph must clear the parallel threshold");
        for streams in [1usize, 4] {
            let serial = pl.predict_graph(&gpu, &g, streams).unwrap();
            let pooled = pl.predict_graph_pooled(&gpu, &g, streams, 4).unwrap();
            assert_eq!(pooled.to_bits(), serial.to_bits(), "streams={streams}");
        }
        // Below the threshold the pooled entry point IS the serial path.
        let small = cfg.decode_graph(1, 64);
        assert_eq!(
            pl.predict_graph_pooled(&gpu, &small, 1, 4),
            pl.predict_graph(&gpu, &small, 1)
        );
    }

    fn build(dev: &str, dtypes: &[DType]) -> (Gpu, Pm2Lat) {
        let mut gpu = Gpu::by_name(dev).unwrap();
        let pl = Pm2Lat::build_dtypes(&mut gpu, &ProfileSpec::quick(), dtypes, false);
        gpu.reset();
        (gpu, pl)
    }

    #[test]
    fn mixed_op_trace_prediction() {
        let (mut gpu, pl) = build("a100", &[DType::F32]);
        let trace = vec![
            Op::Gemm(GemmOp::linear(512, 2048, 768, DType::F32)),
            Op::Util(UtilOp::new(UtilKind::Gelu, 512, 2048, DType::F32)),
            Op::Gemm(GemmOp::linear(512, 768, 2048, DType::F32)),
            Op::Util(UtilOp::new(UtilKind::Add, 512, 768, DType::F32)),
        ];
        let pred = pl.predict_trace(&gpu, &trace).unwrap();
        let mut truth = 0.0;
        for op in &trace {
            truth += profiler::measure(&mut gpu, op, &ProfileSpec::quick())
                .unwrap()
                .mean_s;
        }
        let err = rel_err_pct(pred, truth);
        assert!(err < 15.0, "trace err {err}% (pred {pred} truth {truth})");
    }

    #[test]
    fn bf16_supported_on_a100_not_t4() {
        let (gpu_a, pl_a) = build("a100", &[DType::Bf16]);
        assert!(pl_a
            .predict(&gpu_a, &Op::Gemm(GemmOp::mm(512, 512, 512, DType::Bf16)))
            .is_some());
        let (gpu_t, pl_t) = build("t4", &[DType::F32, DType::Bf16]);
        assert!(pl_t
            .predict(&gpu_t, &Op::Gemm(GemmOp::mm(512, 512, 512, DType::Bf16)))
            .is_none());
        assert!(pl_t
            .predict(&gpu_t, &Op::Gemm(GemmOp::mm(512, 512, 512, DType::F32)))
            .is_some());
    }

    #[test]
    fn per_layer_error_under_10pct_on_active_device() {
        // The paper's headline: PM2Lat stably under ~10% per-layer error
        // on actively-cooled devices. Collection uses the medium spec —
        // the 5-rep quick spec leaves too much noise in the profile.
        let mut gpu = Gpu::by_name("rtx5070").unwrap();
        let pl = Pm2Lat::build_dtypes(&mut gpu, &ProfileSpec::medium(), &[DType::F32], false);
        gpu.reset();
        let mut rng = crate::util::prng::Rng::new(99);
        let mut errs = Vec::new();
        for _ in 0..30 {
            let m = rng.log_uniform_int(64, 8192) as usize;
            let n = rng.log_uniform_int(64, 8192) as usize;
            let k = rng.log_uniform_int(32, 20000) as usize;
            let op = Op::Gemm(GemmOp::mm(m, n, k, DType::F32));
            let pred = pl.predict(&gpu, &op).unwrap();
            let truth = profiler::measure(&mut gpu, &op, &ProfileSpec::quick())
                .unwrap()
                .mean_s;
            errs.push(rel_err_pct(pred, truth));
        }
        let e = mean(&errs);
        assert!(e < 10.0, "MM mean err {e}%");
    }

    #[test]
    fn predict_trace_none_when_any_op_unsupported() {
        let (gpu, pl) = build("t4", &[DType::F32]);
        let trace = vec![
            Op::Gemm(GemmOp::mm(128, 128, 128, DType::F32)),
            Op::Gemm(GemmOp::mm(128, 128, 128, DType::Bf16)),
        ];
        assert!(pl.predict_trace(&gpu, &trace).is_none());
        let g = crate::graph::ModelGraph::from_trace(&trace);
        assert!(pl.predict_graph(&gpu, &g, 2).is_none());
    }

    #[test]
    fn predict_graph_one_stream_matches_predict_trace_exactly() {
        let (gpu, pl) = build("a100", &[DType::F32]);
        let cfg = crate::models::zoo::gpt2_large();
        let g = cfg.graph(1, 128);
        let via_trace = pl.predict_trace(&gpu, &cfg.trace(1, 128)).unwrap();
        let via_graph = pl.predict_graph(&gpu, &g, 1).unwrap();
        assert_eq!(via_graph, via_trace, "streams=1 is the sequential sum");
        // More streams can only shorten the predicted critical path.
        let wide = pl.predict_graph(&gpu, &g, 4).unwrap();
        assert!(wide <= via_trace * (1.0 + 1e-12));
    }

    #[test]
    fn n_tables_counts_fits() {
        let (_, pl) = build("a100", &[DType::F32]);
        assert_eq!(pl.n_tables(), 2); // gemm + util, no custom
    }

    #[test]
    fn collectives_are_priced_like_any_other_op() {
        use crate::ops::CommOp;
        let (gpu, pl) = build("a100", &[DType::F32]);
        let c = CommOp::all_reduce(1 << 18, DType::F32, 2);
        let t = pl.predict(&gpu, &Op::Comm(c)).unwrap();
        assert!(t > 0.0);
        // A trace with a collective in the middle sums all three terms.
        let trace = vec![
            Op::Gemm(GemmOp::mm(256, 256, 256, DType::F32)),
            Op::Comm(c),
            Op::Gemm(GemmOp::mm(256, 256, 256, DType::F32)),
        ];
        let total = pl.predict_trace(&gpu, &trace).unwrap();
        let gemms = pl
            .predict_trace(&gpu, &[trace[0].clone(), trace[2].clone()])
            .unwrap();
        let err = (total - (gemms + t)).abs();
        assert!(err < 1e-12 * total, "sequential sum includes the collective");
        // Unsupported dtype on the device → no comm profile → None.
        let (gpu_t, pl_t) = build("t4", &[DType::F32]);
        assert!(pl_t
            .predict(&gpu_t, &Op::Comm(CommOp::all_reduce(1 << 14, DType::Bf16, 4)))
            .is_none());
    }

    #[test]
    fn property_generation_with_zero_tokens_is_plain_prefill_bit_for_bit() {
        use crate::models::transformer::GenerationSpec;
        let (gpu, pl) = build("a100", &[DType::F32]);
        let cfg = crate::models::zoo::gpt2_large();
        for (batch, prompt, streams) in [(1usize, 128usize, 1usize), (4, 256, 2)] {
            let spec = GenerationSpec::new(prompt, 0);
            let gen = pl.predict_generation(&gpu, &cfg, batch, &spec, streams).unwrap();
            let plain = pl.predict_graph(&gpu, &cfg.graph(batch, prompt), streams).unwrap();
            assert_eq!(gen.prefill_s, plain, "prefill must be the identical prediction");
            assert_eq!(gen.total_s(), plain);
            assert!(gen.step_s.is_empty());
            assert_eq!(gen.time_per_output_token_s(), 0.0);
            assert_eq!(gen.tokens_per_s(), 0.0);
        }
    }

    #[test]
    fn property_decode_step_prediction_grows_with_kv_len() {
        // ISSUE acceptance: per-step latencies where decode-step cost
        // grows with kv_len. Strict monotonicity over the whole curve is
        // the predictor-level decode invariant.
        use crate::models::transformer::GenerationSpec;
        let (gpu, pl) = build("a100", &[DType::F32]);
        let cfg = crate::models::zoo::gpt2_large();
        let spec = GenerationSpec::new(512, 16);
        let gen = pl.predict_generation(&gpu, &cfg, 1, &spec, 1).unwrap();
        assert_eq!(gen.step_s.len(), 16);
        for t in 1..gen.step_s.len() {
            assert!(
                gen.step_s[t] > gen.step_s[t - 1],
                "step {t}: {} <= {}",
                gen.step_s[t],
                gen.step_s[t - 1]
            );
        }
        // And decode is far cheaper than prefill (memory-bound single
        // token vs compute-bound prompt pass).
        assert!(gen.time_per_output_token_s() < gen.prefill_s / 4.0);
        assert!(gen.tokens_per_s() > 0.0);
        // Widely separated caches differ strongly.
        let far = pl
            .predict_generation(&gpu, &cfg, 1, &GenerationSpec::new(8192, 1), 1)
            .unwrap();
        assert!(far.step_s[0] > gen.step_s[0] * 1.1);
    }

    #[test]
    fn generation_unsupported_dtype_is_none() {
        use crate::models::transformer::GenerationSpec;
        let (gpu, pl) = build("t4", &[DType::F32]);
        let cfg = crate::models::zoo::qwen3_0_6b(); // BF16 — no T4 path
        assert!(pl
            .predict_generation(&gpu, &cfg, 1, &GenerationSpec::new(64, 4), 1)
            .is_none());
    }
}
