//! PM2Lat utility-layer path (paper §III-C "Utility Layer Latency
//! Prediction"): NCU-style proxy metrics (memory traffic + instruction
//! counts) → *relative-error-weighted* linear regression per device. No
//! hand-crafted per-layer analytical model; everything comes from measured
//! implementation behaviour.

use crate::gpusim::{FreqMode, Gpu};
use crate::ops::{Counters, DType, UtilKind, UtilOp};
use crate::profiler::{self, ProfileSpec};
use crate::util::stats;

pub const N_FEATURES: usize = 8;

/// Feature vector from the NCU-like counters (+ per-kind structure the
/// counters expose). Scaled to O(1) magnitudes for a well-conditioned fit.
pub fn features(op: &UtilOp, c: &Counters) -> [f64; N_FEATURES] {
    [
        1.0,
        c.dram_bytes / 1e9,
        c.l2_bytes / 1e9,
        c.flops / 1e9,
        c.int_ops / 1e9,
        // sqrt term lets the fit bend through the L2→DRAM transition.
        ((c.dram_bytes + c.l2_bytes) / 1e9).sqrt(),
        if op.kind.is_reduction() { 1.0 } else { 0.0 },
        if op.kind.is_reduction() {
            op.rows as f64 * (op.cols.max(2) as f64).log2() / 1e6
        } else {
            0.0
        },
    ]
}

/// Fitted per-device utility-latency regression.
#[derive(Clone, Debug)]
pub struct UtilityModel {
    pub device: String,
    pub coeffs: Vec<f64>,
    /// Mean training relative error (%) — collection-time self-check.
    pub train_err_pct: f64,
}

/// Size grid for collection: log-spaced rows/cols covering the paper's
/// evaluation domain ("batch sizes and input features up to 16384").
fn collection_sizes() -> Vec<(usize, usize)> {
    let pts = [8usize, 32, 128, 512, 2048, 8192, 16384];
    let mut out = Vec::new();
    for &r in &pts {
        for &c in &pts {
            // Skip degenerate tiny tensors dominated purely by launch.
            if r * c >= 1024 {
                out.push((r, c));
            }
        }
    }
    out
}

/// Collect measurements and fit the regression. Runs at boost clock —
/// utility layers are memory-bound, so clocks matter little (§IV-A), and
/// they barely heat the die.
pub fn fit(gpu: &mut Gpu, dtype: DType, spec: &ProfileSpec) -> Option<UtilityModel> {
    if !gpu.spec.supports(dtype) {
        return None;
    }
    gpu.set_freq(FreqMode::Boost);
    let mut xs: Vec<Vec<f64>> = Vec::new();
    let mut ys: Vec<f64> = Vec::new();
    let mut raw: Vec<([f64; N_FEATURES], f64)> = Vec::new();
    for kind in UtilKind::all() {
        for &(rows, cols) in &collection_sizes() {
            let op = UtilOp::new(*kind, rows, cols, dtype);
            let meas = profiler::measure(
                gpu,
                &crate::ops::Op::Util(op),
                spec,
            )
            .ok()?;
            let f = features(&op, &meas.counters);
            raw.push((f, meas.mean_s));
            // Relative-error weighting: divide the row and the target by
            // the measured latency so the LSQ objective approximates mean
            // relative error rather than absolute (keeps microsecond ops
            // from being sacrificed to millisecond ones).
            let w = 1.0 / meas.mean_s;
            xs.push(f.iter().map(|v| v * w).collect());
            ys.push(1.0);
        }
    }
    let coeffs = stats::ridge_fit(&xs, &ys, 1e-6)?;
    let errs: Vec<f64> = raw
        .iter()
        .map(|(f, y)| stats::rel_err_pct(stats::dot(&coeffs, f).max(1e-9), *y))
        .collect();
    Some(UtilityModel {
        device: gpu.spec.name.to_string(),
        coeffs,
        train_err_pct: stats::mean(&errs),
    })
}

impl UtilityModel {
    /// Predict latency for a utility op given its counters (queried from
    /// the NCU-style export, exactly as the paper scales measured metrics).
    pub fn predict(&self, op: &UtilOp, counters: &Counters) -> f64 {
        stats::dot(&self.coeffs, &features(op, counters)).max(1e-9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::Op;

    fn quick_fit(dev: &str) -> (Gpu, UtilityModel) {
        let mut gpu = Gpu::by_name(dev).unwrap();
        let m = fit(&mut gpu, DType::F32, &ProfileSpec::quick()).unwrap();
        (gpu, m)
    }

    #[test]
    fn training_error_is_small() {
        let (_, m) = quick_fit("a100");
        assert!(m.train_err_pct < 12.0, "train err {}%", m.train_err_pct);
    }

    #[test]
    fn vector_ops_predict_within_10pct() {
        let (mut gpu, m) = quick_fit("rtx3060m");
        let mut errs = Vec::new();
        let mut rng = crate::util::prng::Rng::new(5);
        for kind in [UtilKind::Relu, UtilKind::Add, UtilKind::Mul, UtilKind::Gelu] {
            for _ in 0..10 {
                let rows = rng.log_uniform_int(16, 16384) as usize;
                let cols = rng.log_uniform_int(16, 16384) as usize;
                if rows * cols < 1024 {
                    continue;
                }
                let op = UtilOp::new(kind, rows, cols, DType::F32);
                let truth = profiler::measure(&mut gpu, &Op::Util(op), &ProfileSpec::quick())
                    .unwrap();
                let pred = m.predict(&op, &truth.counters);
                errs.push(stats::rel_err_pct(pred, truth.mean_s));
            }
        }
        let mean = stats::mean(&errs);
        assert!(mean < 10.0, "vector mean err {mean}%");
    }

    #[test]
    fn softmax_harder_than_vector() {
        // The paper's Table II asymmetry: reductions carry nonlinear
        // structure a linear fit cannot fully capture.
        let (mut gpu, m) = quick_fit("l4");
        let mut vec_errs = Vec::new();
        let mut sm_errs = Vec::new();
        let mut rng = crate::util::prng::Rng::new(6);
        for _ in 0..20 {
            let rows = rng.log_uniform_int(16, 8192) as usize;
            let cols = rng.log_uniform_int(64, 16384) as usize;
            let v = UtilOp::new(UtilKind::Add, rows, cols, DType::F32);
            let s = UtilOp::new(UtilKind::Softmax, rows, cols, DType::F32);
            for (op, errs) in [(v, &mut vec_errs), (s, &mut sm_errs)] {
                let truth =
                    profiler::measure(&mut gpu, &Op::Util(op), &ProfileSpec::quick())
                        .unwrap();
                errs.push(stats::rel_err_pct(m.predict(&op, &truth.counters), truth.mean_s));
            }
        }
        assert!(stats::mean(&sm_errs) > stats::mean(&vec_errs) * 0.8,
                "softmax {} vector {}", stats::mean(&sm_errs), stats::mean(&vec_errs));
    }

    #[test]
    fn features_scale_invariant_structure() {
        let op = UtilOp::new(UtilKind::Relu, 128, 128, DType::F32);
        let c = Counters { flops: 1e9, dram_bytes: 2e9, l2_bytes: 5e8, int_ops: 3e9, mem_insts: 1e6 };
        let f = features(&op, &c);
        assert_eq!(f[0], 1.0);
        assert_eq!(f[6], 0.0);
        let sm = UtilOp::new(UtilKind::Softmax, 128, 128, DType::F32);
        assert_eq!(features(&sm, &c)[6], 1.0);
    }

    #[test]
    fn t4_bf16_fit_none() {
        let mut gpu = Gpu::by_name("t4").unwrap();
        assert!(fit(&mut gpu, DType::Bf16, &ProfileSpec::quick()).is_none());
    }
}
