//! # pm2lat — the paper's predictor
//!
//! Kernel-aware, lightweight, analytical latency prediction:
//! * [`gemm_model`] — per-kernel throughput tables on the power-of-two K
//!   grid + Eq. (1)/(2) interpolation + wave scaling (§III-C MatMul path);
//! * [`utility_model`] — NCU-proxy-metric linear regression for
//!   memory-bound layers (§III-C utility path);
//! * [`custom_model`] — the same strategy adapted to Triton / Flash /
//!   CUTLASS attention kernels (§IV-C);
//! * [`comm_model`] — measured collective staircase (AllReduce/AllGather
//!   over ring size × payload) for tensor-parallel placements;
//! * [`predictor`] — the unified per-device facade + whole-model
//!   sequential aggregation;
//! * [`batch`] — the PJRT/Pallas-accelerated batched prediction path used
//!   for NAS preprocessing (§IV-D2).

pub mod batch;
pub mod comm_model;
pub mod custom_model;
pub mod gemm_model;
pub mod predictor;
pub mod utility_model;

pub use comm_model::{CommProfile, COMM_ELEMS_GRID, PARTS_GRID};
pub use gemm_model::{
    GemmTable, GemvProfile, KernelProfile, SkinnyProfile, K_GRID, SKINNY_ROWS_GRID,
};
pub use predictor::{GenerationPrediction, Pm2Lat};
