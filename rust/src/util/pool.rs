//! Scoped thread pool (no tokio/rayon in the offline vendor set).
//!
//! `parallel_map` fans a deterministic-order workload across worker threads
//! using std::thread::scope; results come back in input order regardless of
//! scheduling, so parallel experiment sweeps remain bit-reproducible.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Number of workers: respects PM2LAT_THREADS, defaults to available cores.
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("PM2LAT_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
}

/// Map `f` over `items` on `threads` workers; output order == input order.
/// `f` must be Sync (called concurrently from many threads).
pub fn parallel_map<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let threads = threads.max(1).min(items.len().max(1));
    if threads <= 1 || items.len() <= 1 {
        return items.iter().map(&f).collect();
    }
    let next = AtomicUsize::new(0);
    let results: Vec<Mutex<Option<R>>> =
        items.iter().map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= items.len() {
                    break;
                }
                let r = f(&items[i]);
                *results[i].lock().unwrap() = Some(r);
            });
        }
    });
    results
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("worker panicked"))
        .collect()
}

/// Chunked variant: hands each worker contiguous ranges to reduce
/// coordination overhead for very cheap per-item work.
pub fn parallel_map_chunked<T, R, F>(
    items: &[T],
    threads: usize,
    chunk: usize,
    f: F,
) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let chunk = chunk.max(1);
    if threads <= 1 || items.len() <= chunk {
        return items.iter().map(&f).collect();
    }
    // Never spawn more workers than there are chunks — small batches on a
    // many-core host would otherwise pay thread-creation for idle workers.
    let threads = threads.min(items.len().div_ceil(chunk));
    let next = AtomicUsize::new(0);
    let results: Vec<Mutex<Vec<R>>> = (0..items.len().div_ceil(chunk))
        .map(|_| Mutex::new(Vec::new()))
        .collect();
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let c = next.fetch_add(1, Ordering::Relaxed);
                let start = c * chunk;
                if start >= items.len() {
                    break;
                }
                let end = (start + chunk).min(items.len());
                let out: Vec<R> = items[start..end].iter().map(&f).collect();
                *results[c].lock().unwrap() = out;
            });
        }
    });
    results
        .into_iter()
        .flat_map(|m| m.into_inner().unwrap())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let items: Vec<usize> = (0..1000).collect();
        let out = parallel_map(&items, 8, |&x| x * 2);
        assert_eq!(out, (0..1000).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn single_thread_fallback() {
        let items = vec![1, 2, 3];
        assert_eq!(parallel_map(&items, 1, |&x| x + 1), vec![2, 3, 4]);
    }

    #[test]
    fn empty_input() {
        let items: Vec<u32> = vec![];
        assert!(parallel_map(&items, 4, |&x| x).is_empty());
    }

    #[test]
    fn chunked_matches_plain() {
        let items: Vec<usize> = (0..237).collect();
        let a = parallel_map(&items, 4, |&x| x * x);
        let b = parallel_map_chunked(&items, 4, 16, |&x| x * x);
        assert_eq!(a, b);
    }

    #[test]
    fn actually_parallel() {
        use std::collections::HashSet;
        use std::sync::Mutex;
        let seen = Mutex::new(HashSet::new());
        let items: Vec<usize> = (0..64).collect();
        parallel_map(&items, 8, |_| {
            seen.lock().unwrap().insert(std::thread::current().id());
            std::thread::sleep(std::time::Duration::from_millis(1));
        });
        assert!(seen.lock().unwrap().len() > 1);
    }
}
