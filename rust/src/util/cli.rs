//! Tiny CLI argument parser (no clap in the offline vendor set).
//!
//! Model: `prog <subcommand> [--flag] [--key value] [positional...]`.

use std::collections::BTreeMap;

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an explicit iterator (tests) — first item is NOT the
    /// program name.
    pub fn parse_from<I: IntoIterator<Item = String>>(items: I) -> Args {
        let mut out = Args::default();
        let mut iter = items.into_iter().peekable();
        while let Some(item) = iter.next() {
            if let Some(name) = item.strip_prefix("--") {
                // --key=value | --key value | --flag
                if let Some((k, v)) = name.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    out.options.insert(name.to_string(), v);
                } else {
                    out.flags.push(name.to_string());
                }
            } else if out.subcommand.is_none() && out.positional.is_empty() {
                out.subcommand = Some(item);
            } else {
                out.positional.push(item);
            }
        }
        out
    }

    pub fn parse_env() -> Args {
        Args::parse_from(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn opt_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.opt(name).unwrap_or(default)
    }

    pub fn opt_usize(&self, name: &str, default: usize) -> usize {
        self.opt(name)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn opt_f64(&self, name: &str, default: f64) -> f64 {
        self.opt(name)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse_from(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_and_positional() {
        let a = parse("predict gpt2 extra");
        assert_eq!(a.subcommand.as_deref(), Some("predict"));
        assert_eq!(a.positional, vec!["gpt2", "extra"]);
    }

    #[test]
    fn options_both_syntaxes() {
        let a = parse("run --device a100 --dtype=bf16");
        assert_eq!(a.opt("device"), Some("a100"));
        assert_eq!(a.opt("dtype"), Some("bf16"));
    }

    #[test]
    fn trailing_flag_not_eating_value() {
        let a = parse("run --verbose --n 5 --quiet");
        assert!(a.flag("verbose"));
        assert!(a.flag("quiet"));
        assert_eq!(a.opt_usize("n", 0), 5);
    }

    #[test]
    fn typed_accessors_defaults() {
        let a = parse("x");
        assert_eq!(a.opt_usize("missing", 7), 7);
        assert_eq!(a.opt_f64("missing", 1.5), 1.5);
        assert_eq!(a.opt_or("missing", "d"), "d");
    }
}
