//! Micro-benchmark harness (criterion is not in the offline vendor set).
//!
//! Calibrates iteration count to a target measurement time, reports
//! mean/median/p95 with outlier-robust statistics, and renders a compact
//! report. Used by every `cargo bench` target (harness = false).

use std::time::{Duration, Instant};

#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub p95_ns: f64,
    pub min_ns: f64,
}

impl BenchResult {
    pub fn mean(&self) -> Duration {
        Duration::from_nanos(self.mean_ns as u64)
    }
    pub fn human(&self) -> String {
        fn h(ns: f64) -> String {
            if ns < 1e3 {
                format!("{ns:.1} ns")
            } else if ns < 1e6 {
                format!("{:.2} µs", ns / 1e3)
            } else if ns < 1e9 {
                format!("{:.2} ms", ns / 1e6)
            } else {
                format!("{:.3} s", ns / 1e9)
            }
        }
        format!(
            "{:<44} {:>12}/iter (median {}, p95 {}, {} iters)",
            self.name,
            h(self.mean_ns),
            h(self.median_ns),
            h(self.p95_ns),
            self.iters
        )
    }
}

pub struct Bench {
    /// Target total measurement time per benchmark.
    pub target: Duration,
    /// Number of measurement samples.
    pub samples: usize,
    pub results: Vec<BenchResult>,
}

impl Default for Bench {
    fn default() -> Self {
        Bench::new()
    }
}

impl Bench {
    pub fn new() -> Self {
        // PM2LAT_BENCH_FAST=1 shrinks budgets for CI smoke runs.
        let fast = std::env::var("PM2LAT_BENCH_FAST").is_ok();
        Bench {
            target: if fast {
                Duration::from_millis(200)
            } else {
                Duration::from_secs(1)
            },
            samples: if fast { 10 } else { 30 },
            results: Vec::new(),
        }
    }

    /// Benchmark `f`, auto-calibrating iterations per sample.
    pub fn run<F: FnMut()>(&mut self, name: &str, mut f: F) -> BenchResult {
        // Warm-up + calibration: find iters such that one sample takes
        // roughly target/samples.
        let mut iters = 1u64;
        loop {
            let t0 = Instant::now();
            for _ in 0..iters {
                f();
            }
            let el = t0.elapsed();
            let per_sample = self.target.as_secs_f64() / self.samples as f64;
            if el.as_secs_f64() >= per_sample || iters >= (1 << 30) {
                let scale = per_sample / el.as_secs_f64().max(1e-12);
                iters = ((iters as f64 * scale).ceil() as u64).max(1);
                break;
            }
            iters *= 4;
        }
        let mut per_iter: Vec<f64> = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t0 = Instant::now();
            for _ in 0..iters {
                f();
            }
            per_iter.push(t0.elapsed().as_nanos() as f64 / iters as f64);
        }
        per_iter.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let result = BenchResult {
            name: name.to_string(),
            iters,
            mean_ns: per_iter.iter().sum::<f64>() / per_iter.len() as f64,
            median_ns: per_iter[per_iter.len() / 2],
            p95_ns: per_iter
                [((per_iter.len() as f64 * 0.95) as usize).min(per_iter.len() - 1)],
            min_ns: per_iter[0],
        };
        println!("{}", result.human());
        self.results.push(result.clone());
        result
    }

    /// Time a one-shot (non-repeatable) operation.
    pub fn run_once<F: FnOnce()>(&mut self, name: &str, f: F) -> BenchResult {
        let t0 = Instant::now();
        f();
        let ns = t0.elapsed().as_nanos() as f64;
        let result = BenchResult {
            name: name.to_string(),
            iters: 1,
            mean_ns: ns,
            median_ns: ns,
            p95_ns: ns,
            min_ns: ns,
        };
        println!("{}", result.human());
        self.results.push(result.clone());
        result
    }

    pub fn section(&self, title: &str) {
        println!("\n=== {title} ===");
    }
}

/// Prevent the optimizer from eliding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        std::env::set_var("PM2LAT_BENCH_FAST", "1");
        let mut b = Bench::new();
        b.target = Duration::from_millis(20);
        b.samples = 5;
        let r = b.run("noop-ish", || {
            black_box((0..100).sum::<u64>());
        });
        assert!(r.mean_ns > 0.0);
        assert!(r.min_ns <= r.mean_ns);
        assert_eq!(b.results.len(), 1);
    }

    #[test]
    fn run_once_records() {
        let mut b = Bench::new();
        let r = b.run_once("sleep", || {
            std::thread::sleep(Duration::from_millis(2))
        });
        assert!(r.mean_ns >= 2e6);
    }
}
