//! # util::table — ASCII/markdown table formatting
//!
//! Renders experiment reports (the paper's Tables I–VI and the serving
//! benchmarks) as GitHub-flavoured markdown: header + rows, cells padded
//! for terminal readability. No external table crate in the offline
//! vendor set, so this stays deliberately tiny.

/// Render rows as a GitHub-flavoured markdown table. `rows` excludes the
/// header; all rows must have `header.len()` cells.
pub fn markdown(header: &[&str], rows: &[Vec<String>]) -> String {
    let cols = header.len();
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for row in rows {
        assert_eq!(row.len(), cols, "row width mismatch");
        for (i, cell) in row.iter().enumerate() {
            widths[i] = widths[i].max(cell.len());
        }
    }
    let mut out = String::new();
    let fmt_row = |cells: Vec<String>, widths: &[usize]| -> String {
        let mut line = String::from("|");
        for (cell, w) in cells.iter().zip(widths) {
            line.push_str(&format!(" {:<w$} |", cell, w = w));
        }
        line.push('\n');
        line
    };
    out.push_str(&fmt_row(
        header.iter().map(|s| s.to_string()).collect(),
        &widths,
    ));
    let mut sep = String::from("|");
    for w in &widths {
        sep.push_str(&format!("{}|", "-".repeat(w + 2)));
    }
    sep.push('\n');
    out.push_str(&sep);
    for row in rows {
        out.push_str(&fmt_row(row.clone(), &widths));
    }
    out
}

/// Format a float with fixed decimals, or "-" when None (the paper's OOM
/// and unsupported-dtype cells).
pub fn cell(v: Option<f64>, decimals: usize) -> String {
    match v {
        Some(x) => format!("{:.*}", decimals, x),
        None => "-".to_string(),
    }
}

/// Signed percent cell: "+3.1" / "-2.5" like Tables IV/V.
pub fn signed_pct(v: Option<f64>) -> String {
    match v {
        Some(x) => format!("{}{:.1}", if x >= 0.0 { "+" } else { "" }, x),
        None => "-".to_string(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned_markdown() {
        let t = markdown(
            &["name", "v"],
            &[
                vec!["a".into(), "1.0".into()],
                vec!["long-name".into(), "2".into()],
            ],
        );
        let lines: Vec<&str> = t.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].contains("| name"));
        assert!(lines[1].starts_with("|--"));
        let width = lines[0].len();
        assert!(lines.iter().all(|l| l.len() == width));
    }

    #[test]
    fn cells() {
        assert_eq!(cell(Some(3.14159), 2), "3.14");
        assert_eq!(cell(None, 2), "-");
        assert_eq!(signed_pct(Some(3.14)), "+3.1");
        assert_eq!(signed_pct(Some(-2.51)), "-2.5");
        assert_eq!(signed_pct(None), "-");
    }

    #[test]
    #[should_panic]
    fn rejects_ragged_rows() {
        markdown(&["a", "b"], &[vec!["only-one".into()]]);
    }
}
