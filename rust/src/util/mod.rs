//! From-scratch utility substrate: JSON, PRNG, stats, CLI, thread pool,
//! table formatting and a micro-bench harness. The offline vendor set has
//! no serde/clap/criterion/rand/tokio, so these are first-class modules
//! with their own test suites.

pub mod bench;
pub mod cli;
pub mod json;
pub mod pool;
pub mod prng;
pub mod stats;
pub mod table;
