//! Minimal JSON: parser + serializer (no serde in the offline vendor set).
//!
//! Covers the full JSON grammar we use: objects, arrays, strings (with
//! escapes), numbers, bools, null. Used for the artifact manifest,
//! params_init.json, profile caches and experiment outputs.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }
    /// Convenience: array of f64.
    pub fn as_f64_vec(&self) -> Option<Vec<f64>> {
        self.as_arr()
            .map(|a| a.iter().filter_map(|v| v.as_f64()).collect())
    }

    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn arr_f64(v: &[f64]) -> Json {
        Json::Arr(v.iter().map(|&x| Json::Num(x)).collect())
    }
}

impl From<f64> for Json {
    fn from(v: f64) -> Self {
        Json::Num(v)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Self {
        Json::Num(v as f64)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Self {
        Json::Str(v.to_string())
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Self {
        Json::Bool(v)
    }
}

#[derive(Debug, Clone)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}
impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.i }
    }
    fn skip_ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }
    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }
    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }
    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected token")),
        }
    }
    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err("bad literal"))
        }
    }
    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-')
            {
                self.i += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }
    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(
                                &self.b[self.i + 1..self.i + 5],
                            )
                            .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(
                                char::from_u32(cp).unwrap_or('\u{fffd}'),
                            );
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(c) => {
                    // UTF-8 passthrough.
                    let len = utf8_len(c);
                    let end = (self.i + len).min(self.b.len());
                    out.push_str(
                        std::str::from_utf8(&self.b[self.i..end])
                            .map_err(|_| self.err("bad utf8"))?,
                    );
                    self.i = end;
                }
            }
        }
    }
    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }
    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\t' => write!(f, "\\t")?,
                        '\r' => write!(f, "\\r")?,
                        c if (c as u32) < 0x20 => {
                            write!(f, "\\u{:04x}", c as u32)?
                        }
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(o) => {
                write!(f, "{{")?;
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{}", Json::Str(k.clone()), v)?;
                }
                write!(f, "}}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_nested() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": {"c": "x\ny", "d": true}, "e": null}"#;
        let v = Json::parse(src).unwrap();
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn parses_numbers() {
        assert_eq!(Json::parse("42").unwrap().as_f64(), Some(42.0));
        assert_eq!(Json::parse("-1.5e-3").unwrap().as_f64(), Some(-1.5e-3));
    }

    #[test]
    fn parses_escapes() {
        let v = Json::parse(r#""aA\n""#).unwrap();
        assert_eq!(v.as_str(), Some("aA\n"));
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("{} x").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\"}").is_err());
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
    }

    #[test]
    fn get_and_accessors() {
        let v = Json::parse(r#"{"xs": [1, 2, 3]}"#).unwrap();
        assert_eq!(v.get("xs").unwrap().as_f64_vec().unwrap(), vec![1., 2., 3.]);
        assert!(v.get("missing").is_none());
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::parse("\"héllo→\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo→"));
    }

    #[test]
    fn display_integers_exact() {
        assert_eq!(Json::Num(3.0).to_string(), "3");
        assert_eq!(Json::Num(3.5).to_string(), "3.5");
    }
}
