//! Statistics helpers: summary stats, relative error, histograms, binning,
//! and a small dense linear-algebra kit (Cholesky ridge solve) used as the
//! pure-Rust mirror of the L1 lstsq artifact.

/// Mean of a slice (0.0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64
}

pub fn stddev(xs: &[f64]) -> f64 {
    variance(xs).sqrt()
}

/// Percentile via linear interpolation on the sorted copy; p in [0, 100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

pub fn max(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
}

pub fn min(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::INFINITY, f64::min)
}

/// Relative error in percent: 100 * |pred - truth| / truth.
/// The paper's per-layer metric (Table II).
pub fn rel_err_pct(pred: f64, truth: f64) -> f64 {
    debug_assert!(truth > 0.0);
    100.0 * (pred - truth).abs() / truth
}

/// Signed relative error in percent: the paper's model-level metric
/// (Tables IV/V report signed +/− deviations).
pub fn signed_rel_err_pct(pred: f64, truth: f64) -> f64 {
    debug_assert!(truth > 0.0);
    100.0 * (pred - truth) / truth
}

/// Histogram with fixed-width bins over [lo, hi); values outside are
/// clamped into the edge bins (matches the paper's error-distribution
/// figures, where the last bin is ">= 95%").
#[derive(Clone, Debug)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub counts: Vec<u64>,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, bins: usize) -> Self {
        assert!(bins > 0 && hi > lo);
        Histogram { lo, hi, counts: vec![0; bins] }
    }
    pub fn add(&mut self, x: f64) {
        let bins = self.counts.len();
        let idx = ((x - self.lo) / (self.hi - self.lo) * bins as f64)
            .floor()
            .clamp(0.0, (bins - 1) as f64) as usize;
        self.counts[idx] += 1;
    }
    pub fn total(&self) -> u64 {
        self.counts.iter().sum()
    }
    /// Fraction of mass in bins fully below x.
    pub fn frac_below(&self, x: f64) -> f64 {
        let total = self.total();
        if total == 0 {
            return 0.0;
        }
        let bins = self.counts.len();
        let width = (self.hi - self.lo) / bins as f64;
        let mut acc = 0u64;
        for (i, c) in self.counts.iter().enumerate() {
            let upper = self.lo + (i as f64 + 1.0) * width;
            if upper <= x {
                acc += c;
            }
        }
        acc as f64 / total as f64
    }
}

/// Per-bin maxima over a keyed domain — Fig 5's "input domain divided into
/// 100 bins, only the maximum error in each bin is plotted".
pub fn binned_max(keys: &[f64], values: &[f64], bins: usize) -> Vec<f64> {
    assert_eq!(keys.len(), values.len());
    let lo = min(keys);
    let hi = max(keys) + 1e-12;
    let mut out = vec![f64::NAN; bins];
    for (&k, &v) in keys.iter().zip(values) {
        let idx = (((k - lo) / (hi - lo)) * bins as f64)
            .floor()
            .clamp(0.0, (bins - 1) as f64) as usize;
        if out[idx].is_nan() || v > out[idx] {
            out[idx] = v;
        }
    }
    out
}

/// Dense column-major symmetric positive-definite solve via Cholesky.
/// `a` is n×n row-major, `b` length n. Ridge-stabilized fit mirror of the
/// L1 lstsq kernel; also the fallback when artifacts are absent.
pub fn cholesky_solve(a: &[f64], b: &[f64], n: usize) -> Option<Vec<f64>> {
    assert_eq!(a.len(), n * n);
    assert_eq!(b.len(), n);
    let mut l = vec![0.0; n * n];
    for i in 0..n {
        for j in 0..=i {
            let mut sum = a[i * n + j];
            for k in 0..j {
                sum -= l[i * n + k] * l[j * n + k];
            }
            if i == j {
                if sum <= 0.0 {
                    return None;
                }
                l[i * n + j] = sum.sqrt();
            } else {
                l[i * n + j] = sum / l[j * n + j];
            }
        }
    }
    // Forward: L z = b
    let mut z = vec![0.0; n];
    for i in 0..n {
        let mut sum = b[i];
        for k in 0..i {
            sum -= l[i * n + k] * z[k];
        }
        z[i] = sum / l[i * n + i];
    }
    // Backward: Lᵀ x = z
    let mut x = vec![0.0; n];
    for i in (0..n).rev() {
        let mut sum = z[i];
        for k in i + 1..n {
            sum -= l[k * n + i] * x[k];
        }
        x[i] = sum / l[i * n + i];
    }
    Some(x)
}

/// Ridge least squares: rows of `xs` are feature vectors, `ys` targets.
pub fn ridge_fit(xs: &[Vec<f64>], ys: &[f64], ridge: f64) -> Option<Vec<f64>> {
    let n = xs.len();
    if n == 0 {
        return None;
    }
    let p = xs[0].len();
    let mut xtx = vec![0.0; p * p];
    let mut xty = vec![0.0; p];
    for (row, &y) in xs.iter().zip(ys) {
        debug_assert_eq!(row.len(), p);
        for i in 0..p {
            xty[i] += row[i] * y;
            for j in 0..p {
                xtx[i * p + j] += row[i] * row[j];
            }
        }
    }
    for i in 0..p {
        xtx[i * p + i] += ridge;
    }
    cholesky_solve(&xtx, &xty, p)
}

pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_stats() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert!((stddev(&xs) - 1.2909944).abs() < 1e-6);
        assert_eq!(median(&xs), 2.5);
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
    }

    #[test]
    fn rel_err() {
        assert_eq!(rel_err_pct(110.0, 100.0), 10.0);
        assert_eq!(rel_err_pct(90.0, 100.0), 10.0);
        assert_eq!(signed_rel_err_pct(90.0, 100.0), -10.0);
    }

    #[test]
    fn histogram_clamps_edges() {
        let mut h = Histogram::new(0.0, 100.0, 10);
        h.add(-5.0);
        h.add(50.0);
        h.add(250.0);
        assert_eq!(h.counts[0], 1);
        assert_eq!(h.counts[5], 1);
        assert_eq!(h.counts[9], 1);
        assert_eq!(h.total(), 3);
        assert!((h.frac_below(60.0) - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn binned_max_takes_max_per_bin() {
        let keys = [0.0, 0.1, 5.0, 9.9];
        let vals = [1.0, 7.0, 2.0, 3.0];
        let out = binned_max(&keys, &vals, 2);
        assert_eq!(out[0], 7.0);
        assert_eq!(out[1], 3.0);
    }

    #[test]
    fn cholesky_solves_spd() {
        // A = [[4,2],[2,3]], b = [2, 5] → x = [-0.5, 2.0]
        let x = cholesky_solve(&[4.0, 2.0, 2.0, 3.0], &[2.0, 5.0], 2).unwrap();
        assert!((x[0] + 0.5).abs() < 1e-12);
        assert!((x[1] - 2.0).abs() < 1e-12);
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        assert!(cholesky_solve(&[1.0, 2.0, 2.0, 1.0], &[1.0, 1.0], 2).is_none());
    }

    #[test]
    fn ridge_recovers_coefficients() {
        let mut rng = crate::util::prng::Rng::new(11);
        let truth = [2.0, -1.0, 0.5];
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        for _ in 0..500 {
            let row: Vec<f64> = (0..3).map(|_| rng.normal()).collect();
            ys.push(dot(&row, &truth));
            xs.push(row);
        }
        let fit = ridge_fit(&xs, &ys, 1e-9).unwrap();
        for (f, t) in fit.iter().zip(truth.iter()) {
            assert!((f - t).abs() < 1e-6, "{fit:?}");
        }
    }
}
