//! Deterministic PRNG: splitmix64 seeding + xoshiro256** core.
//!
//! Every stochastic element of the repo (measurement noise, sample
//! generation, procedural kernel efficiencies) flows through this module so
//! that the whole reproduction is bit-stable run to run. No external rand
//! crates are used.

/// splitmix64 — used to expand a u64 seed into xoshiro state and as a
/// stateless hash for procedural parameters.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Stateless 64-bit hash of a byte string (FNV-1a folded through splitmix).
/// Used to derive stable per-(device, kernel) procedural parameters.
pub fn hash64(bytes: &[u8]) -> u64 {
    let mut h = StableHasher::new();
    std::hash::Hasher::write(&mut h, bytes);
    std::hash::Hasher::finish(&h)
}

/// A `std::hash::Hasher` over the same FNV-1a + splitmix construction as
/// [`hash64`] — deterministic across runs and independent of the standard
/// library's (unspecified, randomizable) default hasher. Lets `#[derive
/// (Hash)]` types produce stable identities without allocating a debug
/// string first: `Op::stable_hash` on the service hot path feeds every
/// structured field straight through this.
#[derive(Clone, Debug)]
pub struct StableHasher {
    h: u64,
}

impl StableHasher {
    pub fn new() -> StableHasher {
        StableHasher { h: 0xcbf2_9ce4_8422_2325 }
    }

    /// Hash any `Hash` value through the stable construction.
    pub fn hash_of<T: std::hash::Hash + ?Sized>(value: &T) -> u64 {
        use std::hash::Hasher;
        let mut h = StableHasher::new();
        value.hash(&mut h);
        h.finish()
    }
}

impl Default for StableHasher {
    fn default() -> StableHasher {
        StableHasher::new()
    }
}

impl std::hash::Hasher for StableHasher {
    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.h ^= b as u64;
            self.h = self.h.wrapping_mul(0x100_0000_01b3);
        }
    }

    // The std default methods feed integers through `to_ne_bytes`, which
    // would make derived hashes differ across endianness/word size.
    // Canonicalize every integer to little-endian (usize widened to u64)
    // so `stable_hash` identities — and the simulator noise streams they
    // seed — are the same on every platform.
    #[inline]
    fn write_u8(&mut self, x: u8) {
        self.write(&[x]);
    }
    #[inline]
    fn write_u16(&mut self, x: u16) {
        self.write(&x.to_le_bytes());
    }
    #[inline]
    fn write_u32(&mut self, x: u32) {
        self.write(&x.to_le_bytes());
    }
    #[inline]
    fn write_u64(&mut self, x: u64) {
        self.write(&x.to_le_bytes());
    }
    #[inline]
    fn write_u128(&mut self, x: u128) {
        self.write(&x.to_le_bytes());
    }
    #[inline]
    fn write_usize(&mut self, x: usize) {
        self.write_u64(x as u64);
    }
    #[inline]
    fn write_isize(&mut self, x: isize) {
        self.write_u64(x as i64 as u64);
    }

    #[inline]
    fn finish(&self) -> u64 {
        let mut s = self.h;
        splitmix64(&mut s)
    }
}

/// xoshiro256** — fast, high-quality, deterministic.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal variate from Box-Muller.
    spare_normal: Option<f64>,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare_normal: None }
    }

    /// Derive an independent stream for a labelled sub-purpose.
    pub fn fork(&self, label: &str) -> Rng {
        let mut seed = self.s[0] ^ self.s[2];
        seed ^= hash64(label.as_bytes());
        Rng::new(seed)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn int_range(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(hi >= lo);
        let span = (hi - lo) as u64 + 1;
        lo + (self.next_u64() % span) as i64
    }

    /// Pick a uniformly random element.
    pub fn choice<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[(self.next_u64() % items.len() as u64) as usize]
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(v) = self.spare_normal.take() {
            return v;
        }
        loop {
            let u1 = self.uniform();
            let u2 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.spare_normal = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    /// Lognormal multiplicative noise with multiplicative sigma ~ `sigma`.
    /// Mean-corrected so E[x] == 1.
    pub fn lognormal_noise(&mut self, sigma: f64) -> f64 {
        let mu = -0.5 * sigma * sigma;
        (mu + sigma * self.normal()).exp()
    }

    /// log-uniform integer in [lo, hi] — matches how the paper samples
    /// layer dimensions over wide ranges.
    pub fn log_uniform_int(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(lo >= 1 && hi >= lo);
        let l = (lo as f64).ln();
        let h = (hi as f64).ln();
        let v = self.range(l, h).exp().round() as u64;
        v.clamp(lo, hi)
    }

    /// Shuffle a slice in place (Fisher-Yates).
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = (self.next_u64() % (i as u64 + 1)) as usize;
            items.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn fork_streams_are_independent_and_stable() {
        let root = Rng::new(99);
        let mut f1 = root.fork("noise");
        let mut f2 = root.fork("noise");
        let mut g = root.fork("samples");
        assert_eq!(f1.next_u64(), f2.next_u64());
        assert_ne!(f1.next_u64(), g.next_u64());
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut r = Rng::new(3);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let v = r.uniform();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(4);
        let n = 50_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let v = r.normal();
            s += v;
            s2 += v * v;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn lognormal_noise_mean_one() {
        let mut r = Rng::new(5);
        let n = 50_000;
        let mean: f64 =
            (0..n).map(|_| r.lognormal_noise(0.05)).sum::<f64>() / n as f64;
        assert!((mean - 1.0).abs() < 0.005, "mean={mean}");
    }

    #[test]
    fn int_range_inclusive() {
        let mut r = Rng::new(6);
        let mut seen_lo = false;
        let mut seen_hi = false;
        for _ in 0..1000 {
            let v = r.int_range(3, 5);
            assert!((3..=5).contains(&v));
            seen_lo |= v == 3;
            seen_hi |= v == 5;
        }
        assert!(seen_lo && seen_hi);
    }

    #[test]
    fn log_uniform_spans_orders_of_magnitude() {
        let mut r = Rng::new(8);
        let vals: Vec<u64> =
            (0..2000).map(|_| r.log_uniform_int(32, 8192)).collect();
        let small = vals.iter().filter(|&&v| v < 256).count();
        let large = vals.iter().filter(|&&v| v > 2048).count();
        // log-uniform gives comparable mass per octave.
        assert!(small > 400 && large > 400, "small={small} large={large}");
    }

    #[test]
    fn hash64_stable_and_sensitive() {
        assert_eq!(hash64(b"a100/k3"), hash64(b"a100/k3"));
        assert_ne!(hash64(b"a100/k3"), hash64(b"a100/k4"));
    }

    #[test]
    fn stable_hasher_matches_hash64_on_raw_bytes() {
        use std::hash::Hasher;
        let mut h = StableHasher::new();
        h.write(b"a100/k3");
        assert_eq!(h.finish(), hash64(b"a100/k3"));
    }

    #[test]
    fn stable_hasher_distinguishes_structured_values() {
        assert_eq!(StableHasher::hash_of(&(1u32, 2u32)), StableHasher::hash_of(&(1u32, 2u32)));
        assert_ne!(StableHasher::hash_of(&(1u32, 2u32)), StableHasher::hash_of(&(2u32, 1u32)));
        assert_ne!(StableHasher::hash_of(&1u64), StableHasher::hash_of(&2u64));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(10);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }
}
