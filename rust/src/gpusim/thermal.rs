//! Thermal state machine: dissipated energy heats the die; cooling decays
//! toward ambient; frequency derates past a cooling-class-dependent
//! threshold. Passive devices (T4, L4) throttle earlier and harder —
//! the paper's §IV-A thermal discussion (PM2Lat's 32.6% BMM/L4 cell).

use super::device::{Cooling, DeviceSpec};

pub const AMBIENT_C: f64 = 30.0;

#[derive(Clone, Debug)]
pub struct Thermal {
    pub temp_c: f64,
    /// Effective heat capacity (J/°C): die + heatsink.
    heat_capacity: f64,
    /// Cooling time constant (s).
    tau: f64,
    throttle_start_c: f64,
    throttle_full_c: f64,
    min_derate: f64,
}

impl Thermal {
    pub fn new(dev: &DeviceSpec) -> Thermal {
        // NOTE: constants are *simulation-scaled*: virtual busy time in the
        // experiments is seconds, not the minutes a physical card needs to
        // soak, so capacities/time-constants are compressed accordingly.
        // What is preserved: passive cards reach throttle under ~1 s of
        // sustained compute-bound load, active cards rarely throttle, and
        // equilibrium temperature sits near (but below) the full-derate
        // point — the qualitative behaviour §IV-A builds its argument on.
        let (tau, start, full, min_derate, capacity) = match dev.cooling {
            // Passive cards soak heat: slow cooling, early throttle.
            Cooling::Passive => (2.2, 62.0, 92.0, 0.80, dev.power_w * 0.030),
            Cooling::Active => (1.6, 83.0, 102.0, 0.82, dev.power_w * 0.042),
        };
        Thermal {
            temp_c: AMBIENT_C,
            heat_capacity: capacity,
            tau,
            throttle_start_c: start,
            throttle_full_c: full,
            min_derate,
        }
    }

    /// Advance by `dur` seconds while drawing `power_w` watts.
    pub fn advance(&mut self, power_w: f64, dur: f64) {
        // Integrate in sub-steps for stability on long kernels.
        let mut remaining = dur;
        while remaining > 0.0 {
            let dt = remaining.min(0.05);
            let heat = power_w * dt / self.heat_capacity;
            let cool = (self.temp_c - AMBIENT_C) * dt / self.tau;
            self.temp_c = (self.temp_c + heat - cool).max(AMBIENT_C);
            remaining -= dt;
        }
    }

    /// Idle cooling (exponential decay toward ambient).
    pub fn idle(&mut self, dur: f64) {
        let decay = (-dur / self.tau).exp();
        self.temp_c = AMBIENT_C + (self.temp_c - AMBIENT_C) * decay;
    }

    /// Frequency derate factor in [min_derate, 1].
    pub fn derate(&self) -> f64 {
        if self.temp_c <= self.throttle_start_c {
            1.0
        } else {
            let t = ((self.temp_c - self.throttle_start_c)
                / (self.throttle_full_c - self.throttle_start_c))
                .min(1.0);
            1.0 - (1.0 - self.min_derate) * t
        }
    }

    pub fn reset(&mut self) {
        self.temp_c = AMBIENT_C;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::device::device_by_name;

    #[test]
    fn heats_under_load_cools_idle() {
        let d = device_by_name("t4").unwrap();
        let mut th = Thermal::new(&d);
        th.advance(d.power_w, 20.0);
        let hot = th.temp_c;
        assert!(hot > 50.0, "temp={hot}");
        th.idle(120.0);
        assert!(th.temp_c < hot && th.temp_c < 35.0);
    }

    #[test]
    fn passive_throttles_earlier_than_active() {
        let t4 = device_by_name("t4").unwrap(); // passive, 70 W
        let a100 = device_by_name("a100").unwrap(); // active, 400 W
        let mut tht = Thermal::new(&t4);
        let mut tha = Thermal::new(&a100);
        // Equal *temperature* → passive must derate more.
        tht.temp_c = 75.0;
        tha.temp_c = 75.0;
        assert!(tht.derate() < 1.0);
        assert_eq!(tha.derate(), 1.0);
    }

    #[test]
    fn sustained_load_reaches_equilibrium_below_max() {
        let d = device_by_name("l4").unwrap();
        let mut th = Thermal::new(&d);
        th.advance(d.power_w, 600.0);
        let t1 = th.temp_c;
        th.advance(d.power_w, 600.0);
        // Equilibrium: negligible change.
        assert!((th.temp_c - t1).abs() < 1.0);
        assert!(th.temp_c < 150.0);
    }

    #[test]
    fn derate_bounded() {
        let d = device_by_name("t4").unwrap();
        let mut th = Thermal::new(&d);
        th.temp_c = 200.0;
        assert!(th.derate() >= 0.66 - 1e-12);
        th.reset();
        assert_eq!(th.derate(), 1.0);
    }
}
