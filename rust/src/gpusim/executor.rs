//! The simulated GPU: executes ops against the latency physics with a
//! virtual clock, thermal state, frequency control, cold-start effects and
//! deterministic measurement noise. This is the "hardware" every predictor
//! is evaluated against; its API mirrors what CUPTI-instrumented execution
//! gives you on a real card — a duration and a set of counters, nothing
//! about the closed-source kernel internals.

use std::collections::HashSet;

use crate::ops::{Counters, CustomOp, GemmOp, Op, UtilOp};
use crate::util::prng::{hash64, Rng};

use super::comm;
use super::custom;
use super::device::{device_by_name, DeviceSpec};
use super::gemm::{self, GemmConfig};
use super::heuristic;
use super::kernel::{registry, GemmKernel};
use super::thermal::Thermal;
use super::utility;

/// Core-clock policy. PM2Lat collects throughput at a fixed (lower)
/// frequency (§III-C / §IV-A); evaluation runs boost.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FreqMode {
    /// Boost clock subject to thermal derating.
    Boost,
    /// Locked clock (e.g. `nvidia-smi -lgc`): thermally stable.
    Fixed(f64),
}

/// One measured execution, CUPTI-style.
#[derive(Clone, Copy, Debug)]
pub struct Sample {
    pub dur_s: f64,
    pub counters: Counters,
    pub freq_ghz: f64,
    pub temp_c: f64,
}

#[derive(Clone, Debug, PartialEq, Eq, thiserror::Error)]
pub enum ExecError {
    #[error("dtype not supported on this device")]
    UnsupportedDtype,
    #[error("kernel not supported on this architecture")]
    UnsupportedKernel,
    #[error("unknown kernel id {0}")]
    UnknownKernel(usize),
    #[error("out of device memory: need {need_mb} MB, have {have_mb} MB")]
    OutOfMemory { need_mb: u64, have_mb: u64 },
}

/// A simulated GPU instance with mutable execution state.
pub struct Gpu {
    pub spec: DeviceSpec,
    fp32_kernels: Vec<GemmKernel>,
    bf16_kernels: Vec<GemmKernel>,
    freq_mode: FreqMode,
    thermal: Thermal,
    /// Virtual wall-clock (seconds since reset).
    pub clock_s: f64,
    /// Ops already JIT-warmed (first launch pays a cold penalty).
    warm: HashSet<u64>,
    exec_count: u64,
    /// Measurement noise sigma (lognormal). ~2.5% like real CUPTI runs.
    pub noise_sigma: f64,
    seed: u64,
}

impl Gpu {
    pub fn new(spec: DeviceSpec) -> Gpu {
        let fp32_kernels = registry(&spec, crate::ops::DType::F32);
        let bf16_kernels = registry(&spec, crate::ops::DType::Bf16);
        let seed = hash64(spec.name.as_bytes());
        Gpu {
            thermal: Thermal::new(&spec),
            fp32_kernels,
            bf16_kernels,
            freq_mode: FreqMode::Boost,
            clock_s: 0.0,
            warm: HashSet::new(),
            exec_count: 0,
            noise_sigma: 0.025,
            seed,
            spec,
        }
    }

    pub fn by_name(name: &str) -> Option<Gpu> {
        device_by_name(name).map(Gpu::new)
    }

    pub fn kernels(&self, dtype: crate::ops::DType) -> &[GemmKernel] {
        match dtype {
            crate::ops::DType::F32 => &self.fp32_kernels,
            crate::ops::DType::Bf16 => &self.bf16_kernels,
        }
    }

    pub fn kernel(&self, dtype: crate::ops::DType, id: usize) -> Option<&GemmKernel> {
        self.kernels(dtype).get(id)
    }

    /// Reset execution state (clock, thermals, JIT cache).
    pub fn reset(&mut self) {
        self.thermal.reset();
        self.clock_s = 0.0;
        self.warm.clear();
        self.exec_count = 0;
    }

    pub fn set_freq(&mut self, mode: FreqMode) {
        self.freq_mode = mode;
    }

    pub fn temp_c(&self) -> f64 {
        self.thermal.temp_c
    }

    /// Current effective core clock (GHz) after thermal derating.
    pub fn current_freq(&self) -> f64 {
        match self.freq_mode {
            FreqMode::Fixed(f) => f.min(self.spec.max_freq_ghz),
            FreqMode::Boost => self.spec.max_freq_ghz * self.thermal.derate(),
        }
    }

    /// Let the device sit idle (cooling) for `dur` seconds of virtual time.
    pub fn idle(&mut self, dur: f64) {
        self.thermal.idle(dur);
        self.clock_s += dur;
    }

    /// Noise-free model latency at an explicit frequency — the internal
    /// physics; used by the heuristic and by ground-truth assertions in
    /// tests. Predictors never call this.
    pub fn model_latency(
        &self,
        op: &Op,
        cfg: Option<GemmConfig>,
        freq_ghz: f64,
    ) -> Result<f64, ExecError> {
        match op {
            Op::Gemm(g) => {
                // Library dispatch: skinny shapes (min(m,n) ≤ 32 — decode
                // projections and small continuous-batching iterations)
                // take the memory-bound streaming family, gemv-degenerate
                // ones its `min(m,n) ≤ 8` sub-route. An explicitly pinned
                // config still runs the pinned tile kernel — PM2Lat's
                // controlled collection depends on it.
                if cfg.is_none() && gemm::is_skinny(g) {
                    return gemm::skinny_latency(&self.spec, g, freq_ghz)
                        .ok_or(ExecError::UnsupportedDtype);
                }
                let cfg = match cfg {
                    Some(c) => c,
                    None => heuristic::algo_get_heuristic(&self.spec, g)
                        .ok_or(ExecError::UnsupportedDtype)?,
                };
                let kern = self
                    .kernel(g.dtype, cfg.kernel_id)
                    .ok_or(ExecError::UnknownKernel(cfg.kernel_id))?;
                gemm::gemm_latency(&self.spec, kern, g, cfg.splitk, freq_ghz)
                    .ok_or(ExecError::UnsupportedKernel)
            }
            Op::Util(u) => {
                if !self.spec.supports(u.dtype) {
                    return Err(ExecError::UnsupportedDtype);
                }
                Ok(utility::util_latency(&self.spec, u, freq_ghz))
            }
            Op::Custom(c) => custom::custom_latency(&self.spec, c, freq_ghz)
                .ok_or(ExecError::UnsupportedKernel),
            // Collectives run on the copy/NCCL engines: link-bound, not
            // core-clock-bound, so `freq_ghz` does not enter.
            Op::Comm(c) => Ok(comm::comm_latency(&self.spec, c)),
        }
    }

    /// Counters for an op (NCU-style export).
    pub fn counters(&self, op: &Op, cfg: Option<GemmConfig>) -> Result<Counters, ExecError> {
        match op {
            Op::Gemm(g) => {
                if cfg.is_none() && gemm::is_skinny(g) {
                    if !self.spec.supports(g.dtype) {
                        return Err(ExecError::UnsupportedDtype);
                    }
                    // The residency split depends only on the working set,
                    // so the whole streaming family shares one counter
                    // model.
                    return Ok(gemm::gemv_counters(&self.spec, g));
                }
                let cfg = match cfg {
                    Some(c) => c,
                    None => heuristic::algo_get_heuristic(&self.spec, g)
                        .ok_or(ExecError::UnsupportedDtype)?,
                };
                let kern = self
                    .kernel(g.dtype, cfg.kernel_id)
                    .ok_or(ExecError::UnknownKernel(cfg.kernel_id))?;
                Ok(gemm::gemm_counters(&self.spec, kern, g, cfg.splitk))
            }
            Op::Util(u) => Ok(utility::util_counters(&self.spec, u)),
            Op::Custom(c) => Ok(custom::custom_counters(&self.spec, c)),
            // Link traffic stages through HBM on both ends; no math.
            Op::Comm(c) => Ok(Counters {
                dram_bytes: c.io_bytes(),
                mem_insts: c.io_bytes() / 16.0,
                ..Counters::default()
            }),
        }
    }

    /// Execute with the library-selected configuration (what a framework
    /// call does).
    pub fn exec(&mut self, op: &Op) -> Result<Sample, ExecError> {
        self.exec_config(op, None)
    }

    /// Execute with an explicitly pinned GEMM config — PM2Lat's controlled
    /// collection ("we manually specify kernel settings and analyze their
    /// behavior in isolation", §III-C).
    pub fn exec_config(
        &mut self,
        op: &Op,
        cfg: Option<GemmConfig>,
    ) -> Result<Sample, ExecError> {
        let freq = self.current_freq();
        let base = self.model_latency(op, cfg, freq)?;
        let counters = self.counters(op, cfg)?;
        // Cold-start: first launch of a distinct op pays JIT/load cost.
        let key = op.stable_hash() ^ cfg.map(|c| c.kernel_id as u64 + 1).unwrap_or(0);
        let cold = if self.warm.insert(key) { 1.18 } else { 1.0 };
        // Deterministic measurement noise: varies per repetition.
        let mut rng = Rng::new(
            self.seed ^ key.rotate_left(17) ^ self.exec_count.wrapping_mul(0x9e37),
        );
        let noise = rng.lognormal_noise(self.noise_sigma);
        let dur = base * cold * noise;
        // Power draw tracks achieved utilization (compute-heavy ops heat
        // the die; memory-bound ops much less).
        let util = match op {
            Op::Gemm(g) => gemm::utilization(&self.spec, g, base),
            Op::Util(_) => 0.12,
            // Copy engines barely heat the die.
            Op::Comm(_) => 0.05,
            Op::Custom(c) => {
                let peak = self
                    .spec
                    .peak_tflops(op.dtype())
                    .unwrap_or(self.spec.fp32_tflops)
                    * 1e12;
                (c.flops() / (peak * base)).min(1.0)
            }
        };
        // Dynamic power ∝ f²·V ≈ f²: locked-low-clock profiling (PM2Lat's
        // collection mode) barely heats the die; boost-clock sweeps do.
        let freq_factor = (freq / self.spec.max_freq_ghz).powi(2);
        let power = self.spec.power_w * (0.3 + 0.7 * util) * freq_factor;
        self.thermal.advance(power, dur);
        self.clock_s += dur;
        self.exec_count += 1;
        Ok(Sample { dur_s: dur, counters, freq_ghz: freq, temp_c: self.thermal.temp_c })
    }

    /// Convenience wrappers.
    pub fn exec_gemm(&mut self, g: &GemmOp) -> Result<Sample, ExecError> {
        self.exec(&Op::Gemm(*g))
    }
    pub fn exec_util(&mut self, u: &UtilOp) -> Result<Sample, ExecError> {
        self.exec(&Op::Util(*u))
    }
    pub fn exec_custom(&mut self, c: &CustomOp) -> Result<Sample, ExecError> {
        self.exec(&Op::Custom(*c))
    }

    /// OOM check for a model footprint (weights + activations), in bytes.
    pub fn check_memory(&self, need_bytes: f64) -> Result<(), ExecError> {
        if need_bytes > self.spec.mem_bytes() {
            Err(ExecError::OutOfMemory {
                need_mb: (need_bytes / 1e6) as u64,
                have_mb: (self.spec.mem_bytes() / 1e6) as u64,
            })
        } else {
            Ok(())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::{DType, GemmOp, UtilKind};

    fn gpu(name: &str) -> Gpu {
        Gpu::by_name(name).unwrap()
    }

    #[test]
    fn exec_returns_positive_latency_and_counters() {
        let mut g = gpu("a100");
        let s = g.exec_gemm(&GemmOp::mm(512, 512, 512, DType::F32)).unwrap();
        assert!(s.dur_s > 0.0);
        assert!(s.counters.flops > 0.0);
        assert!(g.clock_s > 0.0);
    }

    #[test]
    fn cold_start_then_stable() {
        let mut g = gpu("l4");
        let op = GemmOp::mm(1024, 1024, 1024, DType::F32);
        let first = g.exec_gemm(&op).unwrap().dur_s;
        let rest: Vec<f64> =
            (0..10).map(|_| g.exec_gemm(&op).unwrap().dur_s).collect();
        let warm_mean = crate::util::stats::mean(&rest);
        assert!(first > warm_mean * 1.08, "first={first} warm={warm_mean}");
    }

    #[test]
    fn noise_varies_but_is_deterministic() {
        let mut g1 = gpu("t4");
        let mut g2 = gpu("t4");
        let op = GemmOp::mm(256, 256, 256, DType::F32);
        let a: Vec<f64> = (0..5).map(|_| g1.exec_gemm(&op).unwrap().dur_s).collect();
        let b: Vec<f64> = (0..5).map(|_| g2.exec_gemm(&op).unwrap().dur_s).collect();
        assert_eq!(a, b, "same device+sequence must reproduce exactly");
        assert!(a[1] != a[2] || a[2] != a[3], "reps must differ (noise)");
    }

    #[test]
    fn t4_rejects_bf16() {
        let mut g = gpu("t4");
        let err = g.exec_gemm(&GemmOp::mm(128, 128, 128, DType::Bf16));
        assert_eq!(err.unwrap_err(), ExecError::UnsupportedDtype);
    }

    #[test]
    fn sustained_load_throttles_passive_device() {
        let mut g = gpu("l4");
        g.set_freq(FreqMode::Boost);
        let op = GemmOp::mm(8192, 8192, 8192, DType::Bf16);
        let f_cold = g.current_freq();
        for _ in 0..200 {
            g.exec_gemm(&op).unwrap();
        }
        let f_hot = g.current_freq();
        assert!(g.temp_c() > 60.0, "temp={}", g.temp_c());
        assert!(f_hot < f_cold, "should throttle: {f_hot} vs {f_cold}");
        // Latency under throttle is higher than cold.
        g.reset();
        let cold_t = g.exec_gemm(&op).unwrap();
        let _ = cold_t;
    }

    #[test]
    fn fixed_frequency_is_thermally_stable() {
        let mut g = gpu("t4");
        g.set_freq(FreqMode::Fixed(1.0));
        let op = GemmOp::mm(2048, 2048, 2048, DType::F32);
        for _ in 0..50 {
            g.exec_gemm(&op).unwrap();
        }
        assert_eq!(g.current_freq(), 1.0, "locked clock never derates");
    }

    #[test]
    fn pinned_config_differs_from_heuristic_choice() {
        let mut g = gpu("a100");
        let op = Op::Gemm(GemmOp::mm(2048, 2048, 2048, DType::F32));
        // Worst kernel pinned should be slower than heuristic pick.
        let mut worst: Option<(GemmConfig, f64)> = None;
        for k in g.kernels(DType::F32).to_vec() {
            let cfg = GemmConfig { kernel_id: k.id, splitk: 1 };
            if let Ok(t) = g.model_latency(&op, Some(cfg), g.spec.max_freq_ghz) {
                if worst.map(|(_, wt)| t > wt).unwrap_or(true) {
                    worst = Some((cfg, t));
                }
            }
        }
        let (wcfg, _) = worst.unwrap();
        let auto = g.model_latency(&op, None, g.spec.max_freq_ghz).unwrap();
        let pinned = g.model_latency(&op, Some(wcfg), g.spec.max_freq_ghz).unwrap();
        assert!(pinned > auto);
    }

    #[test]
    fn oom_detection() {
        let g = gpu("rtx3060m"); // 6 GB
        assert!(g.check_memory(5.0e9).is_ok());
        assert!(matches!(
            g.check_memory(8.0e9),
            Err(ExecError::OutOfMemory { .. })
        ));
    }

    #[test]
    fn idle_cools_device() {
        let mut g = gpu("t4");
        let op = GemmOp::mm(4096, 4096, 4096, DType::F32);
        for _ in 0..200 {
            g.exec_gemm(&op).unwrap();
        }
        let hot = g.temp_c();
        g.idle(300.0);
        assert!(g.temp_c() < hot - 5.0);
    }
}
