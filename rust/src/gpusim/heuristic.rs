//! `cublasLtMatmulAlgoGetHeuristic()` emulation: given a GEMM query, return
//! the optimal kernel configuration (implementation + split-K) for this
//! device — the API the paper discovered removes NeuSight's dataset
//! matching (§III-B). It must run "on the target device": it consults the
//! device's private kernel registry and latency physics, which is exactly
//! what the closed-source heuristic does on real hardware.

use crate::ops::{DType, GemmOp};

use super::device::DeviceSpec;
use super::gemm::{self, GemmConfig};
use super::kernel::{registry, GemmKernel};

pub const SPLITK_CANDIDATES: [usize; 4] = [1, 2, 4, 8];

/// Return the best (kernel, split-K) for this op, or None when the dtype
/// path does not exist on the device (T4 + BF16).
///
/// NOTE: regenerates the registry per call; on hot paths prefer
/// [`algo_get_heuristic_cached`], which reuses the device's precomputed
/// kernel set (§Perf iteration 1: −40% FP32 / −50% BF16 per-prediction).
pub fn algo_get_heuristic(dev: &DeviceSpec, op: &GemmOp) -> Option<GemmConfig> {
    let kernels = registry(dev, op.dtype);
    best_config(dev, op, &kernels)
}

/// Hot-path variant over the `Gpu`'s cached registry.
pub fn algo_get_heuristic_cached(gpu: &super::Gpu, op: &GemmOp) -> Option<GemmConfig> {
    best_config(&gpu.spec, op, gpu.kernels(op.dtype))
}

/// Heuristic over an explicit kernel set (reused by the Triton autotuner
/// and by tests with synthetic registries).
pub fn best_config(
    dev: &DeviceSpec,
    op: &GemmOp,
    kernels: &[GemmKernel],
) -> Option<GemmConfig> {
    let mut best: Option<(GemmConfig, f64)> = None;
    for kern in kernels {
        for &splitk in &SPLITK_CANDIDATES {
            // split-K only makes sense while per-block K stays a full slab.
            if splitk > 1 && op.k / splitk < kern.tile_k * 2 {
                continue;
            }
            // §Perf iteration 2: split-K exists to create parallelism; if
            // the un-split grid already fills a wave, extra splits only
            // add reduction cost — prune them (cuBLASLt does the same).
            if splitk > 1 {
                let blocks =
                    op.m.div_ceil(kern.tile_m) * op.n.div_ceil(kern.tile_n) * op.batch;
                if let Some(bpsm) = gemm::blocks_per_sm(dev, kern) {
                    if blocks >= dev.sm_count * bpsm {
                        continue;
                    }
                }
            }
            if let Some(t) = gemm::gemm_latency(dev, kern, op, splitk, dev.max_freq_ghz)
            {
                if best.map(|(_, bt)| t < bt).unwrap_or(true) {
                    best = Some((GemmConfig { kernel_id: kern.id, splitk }, t));
                }
            }
        }
    }
    best.map(|(cfg, _)| cfg)
}

/// Number of distinct kernel configurations the heuristic can return for a
/// dtype on this device — the paper's "13 FP32 vs ~100 BF16" count.
pub fn config_space_size(dev: &DeviceSpec, dtype: DType) -> usize {
    registry(dev, dtype).len()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::device::device_by_name;
    use crate::ops::GemmApi;

    #[test]
    fn returns_config_for_supported_dtype() {
        let d = device_by_name("a100").unwrap();
        let cfg = algo_get_heuristic(&d, &GemmOp::mm(1024, 1024, 1024, DType::F32));
        assert!(cfg.is_some());
        assert!(cfg.unwrap().kernel_id < 13);
    }

    #[test]
    fn none_for_t4_bf16() {
        let t4 = device_by_name("t4").unwrap();
        assert!(algo_get_heuristic(&t4, &GemmOp::mm(512, 512, 512, DType::Bf16)).is_none());
    }

    #[test]
    fn selection_depends_on_shape() {
        // Big vs tiny shapes must not always pick the same kernel.
        let d = device_by_name("a100").unwrap();
        let mut distinct = std::collections::HashSet::new();
        for (m, n, k) in
            [(64, 64, 8192), (8192, 8192, 64), (4096, 4096, 4096), (128, 4096, 256)]
        {
            let cfg =
                algo_get_heuristic(&d, &GemmOp::mm(m, n, k, DType::F32)).unwrap();
            distinct.insert((cfg.kernel_id, cfg.splitk));
        }
        assert!(distinct.len() >= 2, "heuristic should be shape-sensitive");
    }

    #[test]
    fn transpose_mode_can_change_selection() {
        // Paper §III-B: Linear (TN) vs MatMul (NN) lead to different
        // library/algorithm/tile selections. Over a sample of shapes at
        // least some must differ.
        let mut differs = false;
        let mut rng = crate::util::prng::Rng::new(7);
        'outer: for dev_name in ["rtx5070", "a100", "l4"] {
            let d = device_by_name(dev_name).unwrap();
            for _ in 0..30 {
                let m = rng.log_uniform_int(64, 8192) as usize;
                let n = rng.log_uniform_int(64, 8192) as usize;
                let k = rng.log_uniform_int(64, 8192) as usize;
                for dt in [DType::F32, DType::Bf16] {
                    let nn = algo_get_heuristic(&d, &GemmOp::mm(m, n, k, dt));
                    let tn = algo_get_heuristic(&d, &GemmOp::linear(m, n, k, dt));
                    if nn.is_some() && nn != tn {
                        differs = true;
                        break 'outer;
                    }
                }
            }
        }
        assert!(differs);
    }

    #[test]
    fn splitk_chosen_for_skinny_large_k() {
        let d = device_by_name("a100").unwrap();
        let cfg =
            algo_get_heuristic(&d, &GemmOp::mm(64, 64, 16384, DType::F32)).unwrap();
        assert!(cfg.splitk > 1, "expected split-K, got {cfg:?}");
    }

    #[test]
    fn bf16_space_much_larger_than_fp32() {
        let d = device_by_name("l4").unwrap();
        assert_eq!(config_space_size(&d, DType::F32), 13);
        assert_eq!(config_space_size(&d, DType::Bf16), 96);
    }

    #[test]
    fn deterministic() {
        let d = device_by_name("l4").unwrap();
        let op = GemmOp { api: GemmApi::Bmm, batch: 16, m: 256, n: 256, k: 64, dtype: DType::Bf16 };
        assert_eq!(algo_get_heuristic(&d, &op), algo_get_heuristic(&d, &op));
    }

    #[test]
    fn bf16_selection_varies_more_across_shapes() {
        // With 96 kernels the heuristic's selection map is much richer —
        // the mechanism behind NeuSight's BF16 failures.
        let d = device_by_name("a100").unwrap();
        let mut rng = crate::util::prng::Rng::new(42);
        let mut fp32_sel = std::collections::HashSet::new();
        let mut bf16_sel = std::collections::HashSet::new();
        for _ in 0..40 {
            let m = rng.log_uniform_int(64, 8192) as usize;
            let n = rng.log_uniform_int(64, 8192) as usize;
            let k = rng.log_uniform_int(64, 8192) as usize;
            if let Some(c) = algo_get_heuristic(&d, &GemmOp::mm(m, n, k, DType::F32)) {
                fp32_sel.insert(c.kernel_id);
            }
            if let Some(c) = algo_get_heuristic(&d, &GemmOp::mm(m, n, k, DType::Bf16)) {
                bf16_sel.insert(c.kernel_id);
            }
        }
        assert!(bf16_sel.len() > fp32_sel.len(),
                "bf16 {} <= fp32 {}", bf16_sel.len(), fp32_sel.len());
    }
}
