//! Ground-truth models for the custom kernels of paper §IV-C / Table VI:
//! Triton MatMul (with its own autotuner config space), Triton fused
//! vector kernels, FlashAttention-2 and CUTLASS (xFormers) attention.
//! Architecture gates reproduce the paper's "-" cells: FA2 needs Ampere+
//! (not Turing/T4); neither attention kernel supports Blackwell (RTX 50xx).

use crate::ops::{Counters, CustomOp, DType, GemmOp};
use crate::util::prng::hash64;

use super::device::{Arch, DeviceSpec};
use super::gemm;
use super::kernel::{GemmKernel, Library};

/// Triton's autotune space: 8 configurations per dtype. Distinct from the
/// cuBLAS registry — Triton codegen has its own efficiency profile.
pub fn triton_registry(dev: &DeviceSpec, dtype: DType) -> Vec<GemmKernel> {
    if !dev.supports(dtype) {
        return Vec::new();
    }
    let tiles: [(usize, usize, usize, usize); 8] = [
        (32, 32, 32, 2),
        (64, 32, 32, 2),
        (64, 64, 32, 2),
        (128, 64, 32, 3),
        (64, 128, 32, 3),
        (128, 128, 32, 3),
        (128, 128, 64, 4),
        (64, 64, 64, 2),
    ];
    tiles
        .iter()
        .enumerate()
        .map(|(i, &(tm, tn, tk, stages))| {
            let h = hash64(
                format!("{}/triton/{}/{}", dev.name, dtype.name(), i).as_bytes(),
            );
            let u = |s: u32| ((h >> s) & 0xffff) as f64 / 65535.0;
            GemmKernel {
                id: i,
                library: Library::Cutlass, // codegen'd; closest bucket
                dtype,
                tile_m: tm,
                tile_n: tn,
                tile_k: tk,
                stages,
                swizzle: i % 2 == 1,
                threads: 128,
                // Triton typically lands a bit under cuBLAS peak.
                base_eff: 0.45 + 0.4 * u(0),
                k_half: tk as f64 * (1.2 + 1.0 * u(16)),
                l2_frac_nn: 0.3 + 0.3 * u(32),
                l2_frac_tn: 0.25 + 0.3 * u(48),
                mem_eff: 0.6 + 0.25 * u(24),
                trans_eff_tn: 0.88 + 0.14 * u(8),
            }
        })
        .collect()
}

/// Triton's autotuner: pick the fastest config for this shape (noise-free
/// model argmin — exactly what repeated autotune timing converges to).
pub fn triton_autotune(dev: &DeviceSpec, m: usize, n: usize, k: usize, dtype: DType) -> Option<usize> {
    let op = GemmOp::mm(m, n, k, dtype);
    let reg = triton_registry(dev, dtype);
    let mut best: Option<(usize, f64)> = None;
    for kern in &reg {
        if let Some(t) = gemm::gemm_latency(dev, kern, &op, 1, dev.max_freq_ghz) {
            if best.map(|(_, bt)| t < bt).unwrap_or(true) {
                best = Some((kern.id, t));
            }
        }
    }
    best.map(|(id, _)| id)
}

/// Attention kernel family parameters (shared shape between FA2 and
/// CUTLASS attention; constants differ per family + device).
#[derive(Clone, Copy, Debug)]
pub struct AttnKernelParams {
    pub block_q: usize,
    pub base_eff: f64,
    pub seq_half: f64,
    pub mem_eff: f64,
    pub l2_frac: f64,
}

pub fn attn_params(dev: &DeviceSpec, family: &str, dtype: DType) -> AttnKernelParams {
    let h = hash64(format!("{}/{}/{}", dev.name, family, dtype.name()).as_bytes());
    let u = |s: u32| ((h >> s) & 0xffff) as f64 / 65535.0;
    let flash = family == "flash";
    AttnKernelParams {
        block_q: if flash { 128 } else { 64 },
        base_eff: if flash { 0.55 + 0.3 * u(0) } else { 0.45 + 0.3 * u(0) },
        seq_half: 96.0 * (0.8 + 0.8 * u(16)),
        mem_eff: 0.65 + 0.25 * u(32),
        l2_frac: 0.55 + 0.2 * u(48),
    }
}

/// Architecture gate for Table VI's "-" cells.
pub fn supported(dev: &DeviceSpec, op: &CustomOp) -> bool {
    match op {
        CustomOp::FlashAttn { dtype, .. } => {
            dev.arch >= Arch::Ampere
                && dev.arch != Arch::Blackwell
                && dev.supports(*dtype)
        }
        CustomOp::CutlassAttn { dtype, .. } => {
            dev.arch != Arch::Blackwell && dev.supports(*dtype)
        }
        CustomOp::TritonMM { dtype, .. } | CustomOp::TritonVec { dtype, .. } => {
            dev.supports(*dtype)
        }
    }
}

/// Fused-attention latency: wave model over B·H·ceil(q/block_q) blocks,
/// each streaming K/V once (O(kv·d) memory — the whole point of fusing).
///
/// Prefill (`q == kv == S`) keeps the historical behaviour: partial
/// Q-tiles execute fully, like the GEMM model's partial blocks. A decode
/// step (`q < block_q`, typically `q == 1`) takes the flash-decoding
/// layout instead — one thin tile whose compute scales with the actual
/// query rows while the memory stream is the whole KV cache — so decode
/// kernels land in the memory-bound regime, not the tensor-core one.
///
/// Grouped-query attention (`kv_heads < heads`): the KV cache holds only
/// `batch·kv_heads` lanes, and the query-head groups sharing a lane
/// stream it once (the group reads coalesce in L2/SMEM, as in the real
/// kernels) — so the per-block K/V bytes scale by `kv_heads / heads`
/// while compute is untouched. MHA (`kv_heads == heads`) is bit-identical
/// to the pre-GQA model.
#[allow(clippy::too_many_arguments)]
fn attn_latency(
    dev: &DeviceSpec,
    family: &str,
    batch: usize,
    heads: usize,
    kv_heads: usize,
    q_len: usize,
    kv_len: usize,
    head_dim: usize,
    dtype: DType,
    causal: bool,
    freq_ghz: f64,
) -> f64 {
    let p = attn_params(dev, family, dtype);
    // Degenerate window: nothing to attend — a launch-only kernel (and a
    // guard against 0/0 in the causal ratio below).
    if q_len == 0 || kv_len == 0 {
        return dev.launch_us * 1e-6;
    }
    let blocks = batch * heads * q_len.div_ceil(p.block_q);
    let bpsm = 2usize;
    let capacity = dev.sm_count * bpsm;
    let full_waves = blocks / capacity;
    let tail = blocks % capacity;
    let dsize = dtype.bytes() as f64;
    // Rows a Q-tile actually computes: full tiles when q ≥ block_q
    // (partial trailing tiles execute fully, §III-C), the thin
    // flash-decoding tile otherwise.
    let q_rows = q_len.min(p.block_q) as f64;
    // Per-block compute: Q-tile rows against all kv keys, twice (QKᵀ and
    // PV); the causal mask skips exactly the unattended pairs.
    let causal_ratio = crate::ops::attended_pairs(q_len, kv_len, causal)
        / crate::ops::attended_pairs(q_len, kv_len, false);
    let block_flops =
        4.0 * q_rows * kv_len as f64 * head_dim as f64 * causal_ratio;
    let eff = p.base_eff * kv_len as f64 / (kv_len as f64 + p.seq_half);
    let peak = dev.peak_tflops(dtype).unwrap_or(dev.fp32_tflops) * 1e12
        * (freq_ghz / dev.max_freq_ghz);
    let per_sm = peak / dev.sm_count as f64;
    let t_compute = block_flops * bpsm as f64 / (per_sm * eff);
    // Per-block memory: stream K,V (kv×d each, shared across a query-head
    // group under GQA) + the Q/O rows.
    let kv_share = kv_heads.min(heads).max(1) as f64 / heads.max(1) as f64;
    let block_bytes = (2.0 * kv_len as f64 * head_dim as f64 * kv_share
        + 2.0 * q_rows * head_dim as f64)
        * dsize;
    let wave_bytes = block_bytes * capacity as f64;
    let t_mem = wave_bytes * (1.0 - p.l2_frac) / (dev.dram_bw() * p.mem_eff)
        + wave_bytes * p.l2_frac / (dev.l2_bw() * p.mem_eff);
    let combine = |tc: f64, tm: f64| tc.max(tm) + 0.2 * tc.min(tm);
    let wave_t = combine(t_compute, t_mem);
    let tail_t = if tail > 0 {
        combine(t_compute, t_mem * tail as f64 / capacity as f64)
    } else {
        0.0
    };
    dev.launch_us * 1e-6 + full_waves as f64 * wave_t + tail_t
}

/// Noise-free custom-op latency; None when gated by architecture.
pub fn custom_latency(dev: &DeviceSpec, op: &CustomOp, freq_ghz: f64) -> Option<f64> {
    if !supported(dev, op) {
        return None;
    }
    match *op {
        CustomOp::TritonMM { m, n, k, dtype } => {
            let id = triton_autotune(dev, m, n, k, dtype)?;
            let kern = &triton_registry(dev, dtype)[id];
            gemm::gemm_latency(dev, kern, &GemmOp::mm(m, n, k, dtype), 1, freq_ghz)
        }
        CustomOp::TritonVec { elems, dtype } => {
            // Fused elementwise chain: one read + one write, a few ALU ops.
            let dsize = dtype.bytes() as f64;
            let bytes = elems as f64 * dsize * 2.0;
            let bw = super::utility::effective_bw(dev, bytes);
            let freq_scale = freq_ghz / dev.max_freq_ghz;
            let t_alu = elems as f64 * 4.0 / (dev.int_gops * 1e9 * freq_scale);
            Some(dev.launch_us * 1e-6 + (bytes / bw).max(t_alu))
        }
        CustomOp::FlashAttn { batch, heads, kv_heads, q_len, kv_len, head_dim, dtype, causal } => {
            Some(attn_latency(dev, "flash", batch, heads, kv_heads, q_len, kv_len, head_dim, dtype, causal, freq_ghz))
        }
        CustomOp::CutlassAttn { batch, heads, kv_heads, q_len, kv_len, head_dim, dtype, causal } => {
            Some(attn_latency(dev, "cutlass", batch, heads, kv_heads, q_len, kv_len, head_dim, dtype, causal, freq_ghz))
        }
    }
}

/// Counters for custom ops (coarser than GEMM — fused kernels expose
/// less). Byte totals come from the op's own traffic model
/// ([`CustomOp::io_bytes`]), which for attention includes the KV-cache
/// stream and append.
pub fn custom_counters(dev: &DeviceSpec, op: &CustomOp) -> Counters {
    let flops = op.flops();
    let bytes = op.io_bytes();
    let l2_share = if bytes < dev.l2_bytes() { 0.7 } else { 0.3 };
    Counters {
        flops,
        dram_bytes: bytes * (1.0 - l2_share),
        l2_bytes: bytes * l2_share,
        int_ops: flops * 0.05,
        mem_insts: bytes / 128.0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::device::device_by_name;

    #[test]
    fn arch_gates_match_table6() {
        let t4 = device_by_name("t4").unwrap();
        let b5070 = device_by_name("rtx5070").unwrap();
        let a100 = device_by_name("a100").unwrap();
        let fa = CustomOp::FlashAttn {
            batch: 1, heads: 8, kv_heads: 8, q_len: 512, kv_len: 512, head_dim: 64,
            dtype: DType::F32, causal: false,
        };
        let ca = CustomOp::CutlassAttn {
            batch: 1, heads: 8, kv_heads: 8, q_len: 512, kv_len: 512, head_dim: 64,
            dtype: DType::F32, causal: false,
        };
        assert!(!supported(&t4, &fa), "FA2 unsupported on Turing");
        assert!(supported(&t4, &ca), "CUTLASS attention works on T4");
        assert!(!supported(&b5070, &fa) && !supported(&b5070, &ca),
                "no attention kernels on Blackwell");
        assert!(supported(&a100, &fa) && supported(&a100, &ca));
    }

    #[test]
    fn triton_autotune_picks_valid_config() {
        let d = device_by_name("l4").unwrap();
        let id = triton_autotune(&d, 1024, 1024, 1024, DType::F32).unwrap();
        assert!(id < 8);
        // Autotune is shape-dependent: tiny vs huge shapes may differ.
        let small = triton_autotune(&d, 64, 64, 64, DType::F32).unwrap();
        let big = triton_autotune(&d, 4096, 4096, 4096, DType::F32).unwrap();
        let _ = (small, big); // both valid; equality is allowed but rare
    }

    #[test]
    fn attention_latency_scales_superlinearly_in_seq() {
        let d = device_by_name("a100").unwrap();
        let mk = |seq| CustomOp::FlashAttn {
            batch: 4, heads: 16, kv_heads: 16, q_len: seq, kv_len: seq, head_dim: 64,
            dtype: DType::Bf16, causal: false,
        };
        let t1 = custom_latency(&d, &mk(512), d.max_freq_ghz).unwrap();
        let t2 = custom_latency(&d, &mk(2048), d.max_freq_ghz).unwrap();
        // O(S²) compute: 4× seq → ~16× flops (memory is O(S)).
        assert!(t2 / t1 > 6.0, "ratio={}", t2 / t1);
    }

    #[test]
    fn causal_cheaper_than_full() {
        let d = device_by_name("l4").unwrap();
        let mk = |causal| CustomOp::FlashAttn {
            batch: 2, heads: 8, kv_heads: 8, q_len: 2048, kv_len: 2048, head_dim: 64,
            dtype: DType::Bf16, causal,
        };
        let tc = custom_latency(&d, &mk(true), d.max_freq_ghz).unwrap();
        let tf = custom_latency(&d, &mk(false), d.max_freq_ghz).unwrap();
        assert!(tc < tf);
    }

    #[test]
    fn decode_step_latency_monotone_in_kv_and_far_cheaper_than_prefill() {
        // The decode regime: one query streaming a growing KV cache.
        let d = device_by_name("a100").unwrap();
        let dec = |kv| CustomOp::FlashAttn {
            batch: 8, heads: 16, kv_heads: 16, q_len: 1, kv_len: kv, head_dim: 64,
            dtype: DType::Bf16, causal: true,
        };
        let mut prev = 0.0;
        for kv in [128usize, 512, 2048, 8192] {
            let t = custom_latency(&d, &dec(kv), d.max_freq_ghz).unwrap();
            assert!(t > prev, "kv={kv}: {t} <= {prev}");
            prev = t;
        }
        // A decode step at kv = 2048 does ~1/2048 of the prefill pairs —
        // it must be orders of magnitude cheaper than the square kernel.
        let prefill = CustomOp::FlashAttn {
            batch: 8, heads: 16, kv_heads: 16, q_len: 2048, kv_len: 2048, head_dim: 64,
            dtype: DType::Bf16, causal: true,
        };
        let tp = custom_latency(&d, &prefill, d.max_freq_ghz).unwrap();
        let td = custom_latency(&d, &dec(2048), d.max_freq_ghz).unwrap();
        assert!(tp / td > 20.0, "prefill {tp} vs decode step {td}");
    }

    #[test]
    fn decode_step_is_memory_bound_not_compute_bound() {
        // At q = 1 the Q-tile is thin: halving the clock (a pure compute
        // effect) must barely move a decode step, while it clearly slows
        // the compute-bound prefill kernel.
        let d = device_by_name("a100").unwrap();
        let dec = CustomOp::FlashAttn {
            batch: 8, heads: 16, kv_heads: 16, q_len: 1, kv_len: 4096, head_dim: 64,
            dtype: DType::F32, causal: true,
        };
        let t_full = custom_latency(&d, &dec, d.max_freq_ghz).unwrap();
        let t_half = custom_latency(&d, &dec, d.max_freq_ghz / 2.0).unwrap();
        assert!(t_half < t_full * 1.15, "decode step must be memory-bound");
        let pre = CustomOp::FlashAttn {
            batch: 8, heads: 16, kv_heads: 16, q_len: 4096, kv_len: 4096, head_dim: 64,
            dtype: DType::F32, causal: false,
        };
        let p_full = custom_latency(&d, &pre, d.max_freq_ghz).unwrap();
        let p_half = custom_latency(&d, &pre, d.max_freq_ghz / 2.0).unwrap();
        assert!(p_half > p_full * 1.5, "prefill stays compute-bound");
    }

    #[test]
    fn gqa_decode_streams_the_grouped_cache() {
        // ISSUE GQA satellite: with the same query lanes, a grouped KV
        // cache streams fewer bytes, so the memory-bound decode step gets
        // cheaper — approaching the group factor for long caches.
        let d = device_by_name("a100").unwrap();
        let mk = |kv_heads| CustomOp::FlashAttn {
            batch: 8, heads: 16, kv_heads, q_len: 1, kv_len: 8192, head_dim: 64,
            dtype: DType::Bf16, causal: true,
        };
        let t_mha = custom_latency(&d, &mk(16), d.max_freq_ghz).unwrap();
        let t_gqa = custom_latency(&d, &mk(4), d.max_freq_ghz).unwrap();
        assert!(t_gqa < t_mha, "grouped cache must be cheaper: {t_gqa} vs {t_mha}");
        assert!(
            t_mha / t_gqa > 2.0,
            "long-cache decode is stream-dominated: ratio {}",
            t_mha / t_gqa
        );
        // Still monotone in kv_len under grouping.
        let mut prev = 0.0;
        for kv in [512usize, 2048, 8192] {
            let op = CustomOp::FlashAttn {
                batch: 8, heads: 16, kv_heads: 4, q_len: 1, kv_len: kv, head_dim: 64,
                dtype: DType::Bf16, causal: true,
            };
            let t = custom_latency(&d, &op, d.max_freq_ghz).unwrap();
            assert!(t > prev);
            prev = t;
        }
        // Compute-bound prefill barely moves: grouping only touches the
        // K/V stream, which prefill amortizes over q_len rows.
        let pre = |kv_heads| CustomOp::FlashAttn {
            batch: 2, heads: 16, kv_heads, q_len: 2048, kv_len: 2048, head_dim: 64,
            dtype: DType::Bf16, causal: false,
        };
        let p_mha = custom_latency(&d, &pre(16), d.max_freq_ghz).unwrap();
        let p_gqa = custom_latency(&d, &pre(4), d.max_freq_ghz).unwrap();
        assert!(p_gqa <= p_mha && p_gqa > p_mha * 0.7, "{p_gqa} vs {p_mha}");
    }

    #[test]
    fn flash_vs_cutlass_differ() {
        let d = device_by_name("a100").unwrap();
        let fa = CustomOp::FlashAttn {
            batch: 2, heads: 8, kv_heads: 8, q_len: 1024, kv_len: 1024, head_dim: 64,
            dtype: DType::Bf16, causal: false,
        };
        let ca = CustomOp::CutlassAttn {
            batch: 2, heads: 8, kv_heads: 8, q_len: 1024, kv_len: 1024, head_dim: 64,
            dtype: DType::Bf16, causal: false,
        };
        let tf = custom_latency(&d, &fa, d.max_freq_ghz).unwrap();
        let tc = custom_latency(&d, &ca, d.max_freq_ghz).unwrap();
        assert!((tf - tc).abs() / tf > 0.02, "families should differ");
    }

    #[test]
    fn tritonvec_memory_bound() {
        let d = device_by_name("rtx3060m").unwrap();
        let small = CustomOp::TritonVec { elems: 1 << 16, dtype: DType::F32 };
        let large = CustomOp::TritonVec { elems: 1 << 26, dtype: DType::F32 };
        let ts = custom_latency(&d, &small, d.max_freq_ghz).unwrap();
        let tl = custom_latency(&d, &large, d.max_freq_ghz).unwrap();
        assert!(tl > ts * 50.0);
    }

    #[test]
    fn gated_op_returns_none() {
        let t4 = device_by_name("t4").unwrap();
        let fa = CustomOp::FlashAttn {
            batch: 1, heads: 1, kv_heads: 1, q_len: 128, kv_len: 128, head_dim: 64,
            dtype: DType::F32, causal: false,
        };
        assert!(custom_latency(&t4, &fa, t4.max_freq_ghz).is_none());
    }
}
