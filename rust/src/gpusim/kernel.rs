//! GEMM kernel implementations: descriptors + the procedural per-(device,
//! kernel) efficiency parameters that make *kernel identity* matter.
//!
//! The paper's central observation: NVIDIA ships ~13 FP32 and ~100 BF16
//! algorithm/tile combinations for MatMul; same FLOPs, very different
//! latency, because memory access patterns and pipelining differ per
//! implementation. We reproduce that by generating a registry of distinct
//! kernels per (device, dtype), each with its own efficiency curve drawn
//! from a stable hash — unobservable from the outside, exactly like closed
//! -source cuBLAS kernels, but perfectly reproducible.

use crate::ops::{DType, Trans};
use crate::util::prng::hash64;

use super::device::DeviceSpec;

/// Which library "ships" the kernel (affects naming + mild efficiency
/// prior; cuBLAS can internally invoke CUTLASS, §III-A).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Library {
    Cublas,
    Cutlass,
}

/// A distinct GEMM kernel implementation.
#[derive(Clone, Debug)]
pub struct GemmKernel {
    /// Index within the (device, dtype) registry — the identity PM2Lat
    /// profiles against.
    pub id: usize,
    pub library: Library,
    pub dtype: DType,
    /// Output tile processed per thread block.
    pub tile_m: usize,
    pub tile_n: usize,
    /// K-slab depth staged through shared memory per iteration.
    pub tile_k: usize,
    /// Software pipeline stages (compute/memory overlap depth).
    pub stages: usize,
    /// Whether the kernel uses a swizzled block→tile mapping (better L2
    /// reuse).
    pub swizzle: bool,
    pub threads: usize,
    // ---- procedural performance characteristics (hidden from predictors) ----
    /// Peak fraction of device FLOPs this kernel can reach (K → ∞).
    pub base_eff: f64,
    /// Rational ramp half-point: eff(K) = base_eff · K/(K + k_half).
    pub k_half: f64,
    /// Fraction of operand traffic served from L2 for NN / TN layouts.
    pub l2_frac_nn: f64,
    pub l2_frac_tn: f64,
    /// Memory-path efficiency (coalescing quality).
    pub mem_eff: f64,
    /// Compute-efficiency multiplier for the TN layout (transposed loads
    /// cost ldmatrix/shuffle overhead that differs per implementation —
    /// why Linear vs MatMul pick different kernels, §III-B).
    pub trans_eff_tn: f64,
}

impl GemmKernel {
    /// Rational efficiency ramp in the per-block K depth — the source of
    /// the paper's Fig. 4 curve shape (y = (aK+b)/(cK+d)).
    pub fn eff_at_k(&self, k_per_block: f64) -> f64 {
        self.base_eff * k_per_block / (k_per_block + self.k_half)
    }
    /// Compute-efficiency multiplier for a transpose layout.
    pub fn trans_eff(&self, trans: Trans) -> f64 {
        match trans {
            Trans::NN => 1.0,
            Trans::TN => self.trans_eff_tn,
        }
    }
    pub fn l2_frac(&self, trans: Trans) -> f64 {
        match trans {
            Trans::NN => self.l2_frac_nn,
            Trans::TN => self.l2_frac_tn,
        }
    }
    /// Compute/memory overlap factor from pipeline depth.
    pub fn overlap(&self) -> f64 {
        1.0 - 0.45 / self.stages as f64
    }
    /// Shared-memory footprint per block in bytes (A-slab + B-slab per
    /// stage) — the occupancy limiter.
    pub fn smem_bytes(&self) -> f64 {
        ((self.tile_m + self.tile_n) * self.tile_k * self.dtype.bytes()
            * self.stages) as f64
    }
    pub fn name(&self) -> String {
        format!(
            "{}_{}_{}x{}x{}_s{}{}",
            match self.library {
                Library::Cublas => "cublas",
                Library::Cutlass => "cutlass",
            },
            self.dtype.name(),
            self.tile_m,
            self.tile_n,
            self.tile_k,
            self.stages,
            if self.swizzle { "_sw" } else { "" }
        )
    }
}

/// FP32 (CUDA-core path): 13 algorithm/tile combinations, as counted by
/// the paper for NVIDIA libraries.
const FP32_TILES: [(usize, usize, usize); 13] = [
    (32, 32, 8),
    (64, 32, 8),
    (32, 64, 8),
    (64, 64, 8),
    (128, 64, 8),
    (64, 128, 8),
    (128, 128, 8),
    (128, 64, 16),
    (64, 128, 16),
    (128, 128, 16),
    (256, 64, 16),
    (64, 256, 16),
    (128, 256, 16),
];

/// BF16 (tensor-core path): 16 tiles × 3 stage depths × 2 swizzle modes =
/// 96 kernels ("nearly 100" in the paper).
const BF16_TILES: [(usize, usize, usize); 16] = [
    (64, 64, 32),
    (128, 64, 32),
    (64, 128, 32),
    (128, 128, 32),
    (256, 64, 32),
    (64, 256, 32),
    (256, 128, 32),
    (128, 256, 32),
    (64, 64, 64),
    (128, 64, 64),
    (64, 128, 64),
    (128, 128, 64),
    (256, 128, 64),
    (128, 256, 64),
    (256, 256, 32),
    (32, 128, 32),
];

fn unit(h: u64, shift: u32) -> f64 {
    ((h >> shift) & 0xffff) as f64 / 65535.0
}

fn make_kernel(
    dev: &DeviceSpec,
    dtype: DType,
    id: usize,
    tile: (usize, usize, usize),
    stages: usize,
    swizzle: bool,
    library: Library,
) -> GemmKernel {
    let h = hash64(
        format!("{}/{}/k{}/{}x{}x{}/s{}/{}", dev.name, dtype.name(), id,
                tile.0, tile.1, tile.2, stages, swizzle)
            .as_bytes(),
    );
    // BF16 kernels have much wider efficiency dispersion — the paper's
    // explanation for NeuSight's BF16 blow-up (§IV-A): more combinations,
    // larger performance disparity among them.
    let (eff_lo, eff_hi) = match dtype {
        DType::F32 => (0.58, 0.92),
        DType::Bf16 => (0.33, 0.95),
    };
    // Bigger tiles amortize better (mild prior) + hashed dispersion.
    let tile_bonus =
        (((tile.0 * tile.1) as f64).log2() - 10.0).max(0.0) * 0.012;
    let base_eff =
        (eff_lo + (eff_hi - eff_lo) * unit(h, 0) + tile_bonus).min(0.97);
    // Deeper K-slabs and more stages ramp slower but reach higher peaks.
    let k_half = (tile.2 as f64) * (1.0 + stages as f64 * 0.5)
        * (0.8 + 1.4 * unit(h, 16));
    let l2_frac_nn = 0.28 + 0.34 * unit(h, 32) + if swizzle { 0.12 } else { 0.0 };
    let l2_frac_tn =
        (l2_frac_nn + 0.22 * (unit(h, 48) - 0.5)).clamp(0.15, 0.78);
    let mem_eff = 0.62 + 0.3 * unit(h, 24);
    let trans_eff_tn = 0.80 + 0.28 * unit(h, 8);
    let threads = ((tile.0 / 16) * (tile.1 / 16) * 8).clamp(64, 256);
    GemmKernel {
        id,
        library,
        dtype,
        tile_m: tile.0,
        tile_n: tile.1,
        tile_k: tile.2,
        stages,
        swizzle,
        threads,
        base_eff,
        k_half,
        l2_frac_nn: l2_frac_nn.min(0.78),
        l2_frac_tn,
        mem_eff,
        trans_eff_tn,
    }
}

/// Generate the kernel registry for (device, dtype). Empty when the device
/// lacks the dtype path (T4 + BF16).
pub fn registry(dev: &DeviceSpec, dtype: DType) -> Vec<GemmKernel> {
    if !dev.supports(dtype) {
        return Vec::new();
    }
    let mut out = Vec::new();
    match dtype {
        DType::F32 => {
            for (i, &tile) in FP32_TILES.iter().enumerate() {
                // stages=2, no swizzle on the classic CUDA-core path; the
                // last few large-tile kernels come from CUTLASS.
                let lib = if i >= 10 { Library::Cutlass } else { Library::Cublas };
                out.push(make_kernel(dev, dtype, out.len(), tile, 2, false, lib));
            }
        }
        DType::Bf16 => {
            for &tile in BF16_TILES.iter() {
                for stages in [2usize, 3, 4] {
                    for swizzle in [false, true] {
                        let lib = if stages >= 3 {
                            Library::Cutlass
                        } else {
                            Library::Cublas
                        };
                        out.push(make_kernel(
                            dev, dtype, out.len(), tile, stages, swizzle, lib,
                        ));
                    }
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::device::{all_devices, device_by_name};

    #[test]
    fn fp32_has_13_kernels_bf16_96() {
        let a100 = device_by_name("a100").unwrap();
        assert_eq!(registry(&a100, DType::F32).len(), 13);
        assert_eq!(registry(&a100, DType::Bf16).len(), 96);
    }

    #[test]
    fn t4_bf16_registry_empty() {
        let t4 = device_by_name("t4").unwrap();
        assert!(registry(&t4, DType::Bf16).is_empty());
        assert_eq!(registry(&t4, DType::F32).len(), 13);
    }

    #[test]
    fn kernels_are_distinct_and_stable() {
        let l4 = device_by_name("l4").unwrap();
        let a = registry(&l4, DType::Bf16);
        let b = registry(&l4, DType::Bf16);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.base_eff, y.base_eff);
            assert_eq!(x.name(), y.name());
        }
        let mut effs: Vec<f64> = a.iter().map(|k| k.base_eff).collect();
        effs.sort_by(|x, y| x.partial_cmp(y).unwrap());
        effs.dedup_by(|x, y| (*x - *y).abs() < 1e-12);
        assert!(effs.len() > 90, "efficiencies should be almost all distinct");
    }

    #[test]
    fn same_kernel_differs_across_devices() {
        let a100 = device_by_name("a100").unwrap();
        let l4 = device_by_name("l4").unwrap();
        let ka = &registry(&a100, DType::F32)[3];
        let kl = &registry(&l4, DType::F32)[3];
        assert_ne!(ka.base_eff, kl.base_eff);
    }

    #[test]
    fn bf16_dispersion_wider_than_fp32() {
        // Aggregated over all devices, BF16 efficiency spread must exceed
        // FP32's — the mechanism behind the paper's BF16 findings.
        let mut f32_span = 0.0f64;
        let mut bf16_span = 0.0f64;
        for d in all_devices() {
            for (dt, span) in
                [(DType::F32, &mut f32_span), (DType::Bf16, &mut bf16_span)]
            {
                let ks = registry(&d, dt);
                if ks.is_empty() {
                    continue;
                }
                let lo = ks.iter().map(|k| k.base_eff).fold(f64::MAX, f64::min);
                let hi = ks.iter().map(|k| k.base_eff).fold(0.0, f64::max);
                *span = span.max(hi - lo);
            }
        }
        assert!(bf16_span > f32_span, "bf16 {bf16_span} <= fp32 {f32_span}");
    }

    #[test]
    fn eff_ramp_is_rational_and_monotone() {
        let a100 = device_by_name("a100").unwrap();
        let k = &registry(&a100, DType::F32)[5];
        let mut prev = 0.0;
        for kk in [8.0, 32.0, 128.0, 1024.0, 8192.0] {
            let e = k.eff_at_k(kk);
            assert!(e > prev && e < k.base_eff);
            prev = e;
        }
        // Saturates at base_eff.
        assert!(k.eff_at_k(1e9) > k.base_eff * 0.999);
    }

    #[test]
    fn transpose_changes_l2_behaviour() {
        let dev = device_by_name("rtx5070").unwrap();
        let ks = registry(&dev, DType::F32);
        assert!(ks.iter().any(|k| (k.l2_frac(Trans::NN) - k.l2_frac(Trans::TN)).abs() > 0.02));
    }

    #[test]
    fn smem_scales_with_stages() {
        let dev = device_by_name("a100").unwrap();
        let ks = registry(&dev, DType::Bf16);
        let k2 = ks.iter().find(|k| k.stages == 2).unwrap();
        let k4 = ks
            .iter()
            .find(|k| {
                k.stages == 4 && k.tile_m == k2.tile_m && k.tile_n == k2.tile_n
                    && k.tile_k == k2.tile_k
            })
            .unwrap();
        assert_eq!(k4.smem_bytes(), 2.0 * k2.smem_bytes());
    }
}
