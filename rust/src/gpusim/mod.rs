//! # gpusim — the simulated GPU substrate
//!
//! Wave-level SIMT latency simulator instantiated with the paper's five
//! Table-I devices. It reproduces the phenomena PM2Lat is built on —
//! tile/wave quantization, per-kernel efficiency disparity (13 FP32 / 96
//! BF16 implementations), rational throughput-vs-K curves, composite
//! DRAM+L2+L1 bandwidth, launch overhead, thermal throttling and
//! measurement noise — behind the same observational API real hardware
//! offers: execute an op, get a duration + NCU-style counters. See
//! DESIGN.md §1 for the substitution argument, §3 for the model.

pub mod comm;
pub mod custom;
pub mod device;
pub mod executor;
pub mod gemm;
pub mod heuristic;
pub mod kernel;
pub mod thermal;
pub mod utility;

pub use device::{all_devices, device_by_name, Arch, Cooling, DeviceSpec};
pub use executor::{ExecError, FreqMode, Gpu, Sample};
pub use gemm::{
    is_gemv_degenerate, is_skinny, GemmConfig, WaveInfo, GEMV_DEGENERATE_MAX, SKINNY_GEMM_MAX,
};
pub use kernel::GemmKernel;
