//! Device specifications — Table I of the paper, plus derived architectural
//! parameters (cache bandwidths, shared memory, launch overhead) that the
//! paper points out are NOT publicly disclosed. We procedurally derive them
//! per device — which is precisely why predictors must treat them as
//! unobservable, exactly as on real hardware.

use crate::ops::DType;
use crate::util::prng::hash64;

/// Cooling class: passive devices (T4, L4) throttle earlier under
/// sustained load (paper §IV-A thermal discussion).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Cooling {
    Active,
    Passive,
}

/// GPU architecture generation — gates custom kernels (Table VI notes:
/// FlashAttention-2 needs Ampere+; neither attention kernel supports
/// Blackwell yet).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Arch {
    Turing,
    Ampere,
    AdaLovelace,
    Blackwell,
}

/// Public specification (Table I) + procedurally derived internals.
#[derive(Clone, Debug)]
pub struct DeviceSpec {
    pub name: &'static str,
    pub arch: Arch,
    pub max_freq_ghz: f64,
    pub fp32_tflops: f64,
    /// None ⇒ dtype unsupported (T4 has no BF16 tensor path).
    pub bf16_tflops: Option<f64>,
    pub dram_gbps: f64,
    pub mem_gb: f64,
    pub l2_mb: f64,
    pub sm_count: usize,
    pub cuda_cores: usize,
    pub power_w: f64,
    pub cooling: Cooling,
    // ---- derived, "undisclosed" internals (stable per device) ----
    /// L2 bandwidth as a multiple of DRAM bandwidth (≈3–6×).
    pub l2_bw_ratio: f64,
    /// L1/shared bandwidth as a multiple of L2 bandwidth (≈2.5–4×).
    pub l1_bw_ratio: f64,
    /// Kernel launch overhead in microseconds (µs).
    pub launch_us: f64,
    /// Shared memory per SM in KiB (occupancy limiter).
    pub smem_kib: f64,
    /// Max resident threads per SM.
    pub max_threads_per_sm: usize,
    /// Integer/ALU throughput in Gops/s at max frequency.
    pub int_gops: f64,
    /// Peer link bandwidth in GB/s per direction (NVLink on A100, PCIe
    /// on the rest) — the β of the collective α–β cost.
    pub link_gbs: f64,
    // ---- derived comm internals (stable per device) ----
    /// Achievable fraction of `link_gbs` under ring traffic (bus
    /// contention, protocol overhead) — ≈0.55–0.80.
    pub bus_derate: f64,
    /// Collective launch + rendezvous overhead in microseconds (µs);
    /// collectives synchronize every rank so this dwarfs `launch_us`.
    pub comm_launch_us: f64,
}

impl DeviceSpec {
    /// Peak TFLOPs for a dtype at max frequency; None if unsupported.
    pub fn peak_tflops(&self, dtype: DType) -> Option<f64> {
        match dtype {
            DType::F32 => Some(self.fp32_tflops),
            DType::Bf16 => self.bf16_tflops,
        }
    }
    pub fn supports(&self, dtype: DType) -> bool {
        self.peak_tflops(dtype).is_some()
    }
    pub fn dram_bw(&self) -> f64 {
        self.dram_gbps * 1e9
    }
    pub fn l2_bw(&self) -> f64 {
        self.dram_bw() * self.l2_bw_ratio
    }
    pub fn l1_bw(&self) -> f64 {
        self.l2_bw() * self.l1_bw_ratio
    }
    pub fn l2_bytes(&self) -> f64 {
        self.l2_mb * 1024.0 * 1024.0
    }
    pub fn mem_bytes(&self) -> f64 {
        self.mem_gb * 1024.0 * 1024.0 * 1024.0
    }
    pub fn cores_per_sm(&self) -> usize {
        self.cuda_cores / self.sm_count
    }

    fn derive(mut self) -> Self {
        // Stable per-device internals from the device name; these are the
        // "unobservable" parameters the paper refuses to model (§III-B).
        let h = hash64(self.name.as_bytes());
        let u = |shift: u32| ((h >> shift) & 0xffff) as f64 / 65535.0;
        self.l2_bw_ratio = 3.0 + 3.0 * u(0);
        self.l1_bw_ratio = 2.5 + 1.5 * u(16);
        self.launch_us = 2.5 + 4.0 * u(32);
        self.int_gops = self.cuda_cores as f64 * self.max_freq_ghz * 0.9;
        self.bus_derate = 0.55 + 0.25 * u(48);
        self.comm_launch_us = 5.0 + 10.0 * u(24);
        self
    }
    /// Effective per-direction link bandwidth in bytes/s under ring
    /// traffic.
    pub fn link_bw(&self) -> f64 {
        self.link_gbs * 1e9 * self.bus_derate
    }
}

/// The five devices of Table I, with arch-correct derived limits.
pub fn all_devices() -> Vec<DeviceSpec> {
    vec![
        DeviceSpec {
            name: "rtx3060m",
            arch: Arch::Ampere,
            max_freq_ghz: 2.090,
            fp32_tflops: 16.05,
            bf16_tflops: Some(32.10),
            dram_gbps: 336.0,
            mem_gb: 6.0,
            l2_mb: 3.0,
            sm_count: 30,
            cuda_cores: 3840,
            power_w: 130.0,
            cooling: Cooling::Active,
            l2_bw_ratio: 0.0,
            l1_bw_ratio: 0.0,
            launch_us: 0.0,
            smem_kib: 100.0,
            max_threads_per_sm: 1536,
            int_gops: 0.0,
            link_gbs: 16.0,
            bus_derate: 0.0,
            comm_launch_us: 0.0,
        }
        .derive(),
        DeviceSpec {
            name: "t4",
            arch: Arch::Turing,
            max_freq_ghz: 1.590,
            fp32_tflops: 8.141,
            bf16_tflops: None,
            dram_gbps: 320.0,
            mem_gb: 16.0,
            l2_mb: 4.0,
            sm_count: 40,
            cuda_cores: 2560,
            power_w: 70.0,
            cooling: Cooling::Passive,
            l2_bw_ratio: 0.0,
            l1_bw_ratio: 0.0,
            launch_us: 0.0,
            smem_kib: 64.0,
            max_threads_per_sm: 1024,
            int_gops: 0.0,
            link_gbs: 16.0,
            bus_derate: 0.0,
            comm_launch_us: 0.0,
        }
        .derive(),
        DeviceSpec {
            name: "l4",
            arch: Arch::AdaLovelace,
            max_freq_ghz: 2.040,
            fp32_tflops: 30.29,
            bf16_tflops: Some(121.16),
            dram_gbps: 300.0,
            mem_gb: 24.0,
            l2_mb: 48.0,
            sm_count: 58,
            cuda_cores: 7242,
            power_w: 70.0,
            cooling: Cooling::Passive,
            l2_bw_ratio: 0.0,
            l1_bw_ratio: 0.0,
            launch_us: 0.0,
            smem_kib: 100.0,
            max_threads_per_sm: 1536,
            int_gops: 0.0,
            link_gbs: 32.0,
            bus_derate: 0.0,
            comm_launch_us: 0.0,
        }
        .derive(),
        DeviceSpec {
            name: "a100",
            arch: Arch::Ampere,
            max_freq_ghz: 1.410,
            fp32_tflops: 19.49,
            bf16_tflops: Some(311.87),
            dram_gbps: 1560.0,
            mem_gb: 40.0,
            l2_mb: 40.0,
            sm_count: 108,
            cuda_cores: 6912,
            power_w: 400.0,
            cooling: Cooling::Active,
            l2_bw_ratio: 0.0,
            l1_bw_ratio: 0.0,
            launch_us: 0.0,
            smem_kib: 164.0,
            max_threads_per_sm: 2048,
            int_gops: 0.0,
            link_gbs: 300.0,
            bus_derate: 0.0,
            comm_launch_us: 0.0,
        }
        .derive(),
        DeviceSpec {
            name: "rtx5070",
            arch: Arch::Blackwell,
            max_freq_ghz: 3.090,
            fp32_tflops: 37.97,
            bf16_tflops: Some(75.94),
            dram_gbps: 672.0,
            mem_gb: 12.0,
            l2_mb: 48.0,
            sm_count: 48,
            cuda_cores: 6144,
            power_w: 250.0,
            cooling: Cooling::Active,
            l2_bw_ratio: 0.0,
            l1_bw_ratio: 0.0,
            launch_us: 0.0,
            smem_kib: 100.0,
            max_threads_per_sm: 1536,
            int_gops: 0.0,
            link_gbs: 64.0,
            bus_derate: 0.0,
            comm_launch_us: 0.0,
        }
        .derive(),
    ]
}

pub fn device_by_name(name: &str) -> Option<DeviceSpec> {
    all_devices()
        .into_iter()
        .find(|d| d.name.eq_ignore_ascii_case(name))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn five_devices_table1() {
        let devs = all_devices();
        assert_eq!(devs.len(), 5);
        let names: Vec<&str> = devs.iter().map(|d| d.name).collect();
        assert_eq!(names, vec!["rtx3060m", "t4", "l4", "a100", "rtx5070"]);
    }

    #[test]
    fn t4_has_no_bf16() {
        let t4 = device_by_name("t4").unwrap();
        assert!(!t4.supports(DType::Bf16));
        assert!(t4.supports(DType::F32));
        assert!(device_by_name("a100").unwrap().supports(DType::Bf16));
    }

    #[test]
    fn derived_params_in_plausible_ranges() {
        for d in all_devices() {
            assert!(d.l2_bw_ratio >= 3.0 && d.l2_bw_ratio <= 6.0, "{}", d.name);
            assert!(d.l1_bw_ratio >= 2.5 && d.l1_bw_ratio <= 4.0);
            assert!(d.launch_us >= 2.5 && d.launch_us <= 6.5);
            assert!(d.int_gops > 0.0);
            assert!(d.cores_per_sm() > 0);
        }
    }

    #[test]
    fn derived_params_stable() {
        let a = device_by_name("a100").unwrap();
        let b = device_by_name("a100").unwrap();
        assert_eq!(a.l2_bw_ratio, b.l2_bw_ratio);
        assert_eq!(a.launch_us, b.launch_us);
    }

    #[test]
    fn bandwidth_hierarchy_ordering() {
        for d in all_devices() {
            assert!(d.l1_bw() > d.l2_bw());
            assert!(d.l2_bw() > d.dram_bw());
        }
    }

    #[test]
    fn passive_devices_are_t4_l4() {
        for d in all_devices() {
            let expect = matches!(d.name, "t4" | "l4");
            assert_eq!(d.cooling == Cooling::Passive, expect, "{}", d.name);
        }
    }

    #[test]
    fn lookup_case_insensitive() {
        assert!(device_by_name("A100").is_some());
        assert!(device_by_name("nope").is_none());
    }

    #[test]
    fn comm_internals_derived_and_plausible() {
        for d in all_devices() {
            assert!(d.link_gbs > 0.0, "{}", d.name);
            assert!(d.bus_derate >= 0.55 && d.bus_derate <= 0.80, "{}", d.name);
            assert!(d.comm_launch_us >= 5.0 && d.comm_launch_us <= 15.0);
            assert!(d.link_bw() < d.link_gbs * 1e9);
        }
        // NVLink on the A100 dominates every PCIe-class link.
        let a100 = device_by_name("a100").unwrap();
        for d in all_devices() {
            assert!(a100.link_gbs >= d.link_gbs);
        }
    }
}
