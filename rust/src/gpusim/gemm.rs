//! Wave-level GEMM latency model: tile grid → occupancy → waves →
//! max(compute, memory) per wave with stage-dependent overlap.
//!
//! This is the ground-truth physics of the simulated GPU. Both behaviours
//! the paper highlights in §III-C hold by construction:
//!   * a thread block executes fully even if its tile is partially filled
//!     (block FLOPs always use the full tile);
//!   * the final wave runs all its blocks in parallel (lockstep compute
//!     time), only its memory pressure is lighter.

use crate::ops::{Counters, GemmOp};

use super::device::DeviceSpec;
use super::kernel::GemmKernel;
use super::utility;

/// Largest `min(m, n)` the library still dispatches to the gemv-family
/// (memory-bound streaming) path instead of a tiled tensor-core kernel.
/// Autoregressive decode lives here: every projection of a decode step is
/// a `batch × n × k` GEMM with `batch ≤` a handful, whose cost is set by
/// streaming the `k × n` weight matrix — not by tensor-core throughput.
pub const GEMV_DEGENERATE_MAX: usize = 8;

/// Largest `min(m, n)` the library routes to the *skinny-GEMM* family —
/// streaming kernels with a few query rows per CTA, still bounded by the
/// weight stream rather than tensor-core throughput. Continuous-batching
/// decode lives here: an iteration over 9–32 concurrent sequences makes
/// every projection an `r × n × k` GEMM with `r` in exactly this band,
/// which a tiled 64/128-row kernel would waste almost entirely.
pub const SKINNY_GEMM_MAX: usize = 32;

/// Is this GEMM gemv-degenerate (skinny enough that the library routes it
/// to the memory-bound path)? Shared by the simulator's dispatch and the
/// predictor's routing so the two can never disagree.
pub fn is_gemv_degenerate(op: &GemmOp) -> bool {
    op.m.min(op.n) <= GEMV_DEGENERATE_MAX
}

/// Is this GEMM in the skinny band (gemv-degenerate included)? The
/// library dispatches everything here away from the tiled tensor-core
/// kernels; PM2Lat routes the same shapes to its measured streaming
/// profiles. One shared predicate so simulator and predictor can never
/// disagree about the regime split.
pub fn is_skinny(op: &GemmOp) -> bool {
    op.m.min(op.n) <= SKINNY_GEMM_MAX
}

/// Noise-free gemv-family latency: stream the operands once at the
/// composite (L2/DRAM-blended) bandwidth, with a CUDA-core MAC floor that
/// only binds far outside the degenerate domain. No tile grid, no waves —
/// the whole point is that skinny shapes cannot fill one.
pub fn gemv_latency(dev: &DeviceSpec, op: &GemmOp, freq_ghz: f64) -> Option<f64> {
    if !dev.supports(op.dtype) {
        return None;
    }
    let bytes = op.io_bytes();
    // Skinny access patterns fall slightly short of the streaming optimum.
    let t_mem = bytes / (utility::effective_bw(dev, bytes) * 0.92);
    let freq_scale = freq_ghz / dev.max_freq_ghz;
    let t_compute = op.flops() / (dev.fp32_tflops * 1e12 * 0.5 * freq_scale);
    Some(dev.launch_us * 1e-6 + t_mem.max(t_compute) + 0.2 * t_mem.min(t_compute))
}

/// Noise-free skinny-GEMM latency for `8 < min(m, n) ≤ 32`: still a
/// streaming model (the weight slab is read once; a handful of output
/// rows cannot amortize a tensor-core tile), but the extra row
/// parallelism lifts the achieved bandwidth toward the streaming optimum
/// and engages the MMA pipes enough to raise the compute floor. Delegates
/// to [`gemv_latency`] inside the gemv-degenerate band so the two routes
/// form one continuous family with no cliff at the boundary.
pub fn skinny_latency(dev: &DeviceSpec, op: &GemmOp, freq_ghz: f64) -> Option<f64> {
    if is_gemv_degenerate(op) {
        return gemv_latency(dev, op, freq_ghz);
    }
    if !dev.supports(op.dtype) {
        return None;
    }
    let bytes = op.io_bytes();
    let r = op.m.min(op.n) as f64;
    // Bandwidth efficiency ramps 0.92 → 0.98 across the 9..=32 band: the
    // extra rows add memory parallelism. The compute floor is the gemv
    // family's CUDA-core MAC model — by r ≈ 32 the arithmetic intensity
    // approaches machine balance and the floor starts to bind, which is
    // exactly why libraries cut over to tiled kernels past this band.
    let eff = 0.92
        + 0.06 * ((r - GEMV_DEGENERATE_MAX as f64)
            / (SKINNY_GEMM_MAX - GEMV_DEGENERATE_MAX) as f64);
    let t_mem = bytes / (utility::effective_bw(dev, bytes) * eff);
    let freq_scale = freq_ghz / dev.max_freq_ghz;
    let t_compute = op.flops() / (dev.fp32_tflops * 1e12 * 0.5 * freq_scale);
    Some(dev.launch_us * 1e-6 + t_mem.max(t_compute) + 0.2 * t_mem.min(t_compute))
}

/// NCU-style counters for the gemv path (residency split mirrors the
/// composite-bandwidth blend, like the utility kernels).
pub fn gemv_counters(dev: &DeviceSpec, op: &GemmOp) -> Counters {
    let bytes = op.io_bytes();
    let l2_share = if bytes <= 0.45 * dev.l2_bytes() {
        0.9
    } else if bytes >= 3.0 * dev.l2_bytes() {
        0.15
    } else {
        0.5
    };
    Counters {
        flops: op.flops(),
        dram_bytes: bytes * (1.0 - l2_share),
        l2_bytes: bytes * l2_share,
        int_ops: op.flops() * 0.1,
        mem_insts: bytes / 128.0,
    }
}

/// Kernel selection for one GEMM: which implementation + split-K factor.
/// This is what `algo_get_heuristic` returns — and what PM2Lat profiles
/// against (paper §III-B "Dataset Matching" fix).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct GemmConfig {
    pub kernel_id: usize,
    pub splitk: usize,
}

/// Wave decomposition of a GEMM launch.
#[derive(Clone, Copy, Debug)]
pub struct WaveInfo {
    pub blocks: usize,
    /// Blocks resident per SM (occupancy).
    pub blocks_per_sm: usize,
    /// Blocks per wave = SMs × blocks_per_sm.
    pub wave_capacity: usize,
    pub full_waves: usize,
    pub tail_blocks: usize,
}

impl WaveInfo {
    pub fn total_waves(&self) -> usize {
        self.full_waves + (self.tail_blocks > 0) as usize
    }
}

/// Occupancy: how many blocks of `kern` fit per SM. None = kernel cannot
/// launch on this device (shared-memory overflow).
pub fn blocks_per_sm(dev: &DeviceSpec, kern: &GemmKernel) -> Option<usize> {
    let smem_lim = (dev.smem_kib * 1024.0 / kern.smem_bytes()).floor() as usize;
    if smem_lim == 0 {
        return None;
    }
    let thread_lim = dev.max_threads_per_sm / kern.threads;
    Some(smem_lim.min(thread_lim).min(8).max(1))
}

/// Wave decomposition for (op, kern, splitk).
pub fn wave_info(
    dev: &DeviceSpec,
    kern: &GemmKernel,
    op: &GemmOp,
    splitk: usize,
) -> Option<WaveInfo> {
    let bpsm = blocks_per_sm(dev, kern)?;
    let tiles_m = op.m.div_ceil(kern.tile_m);
    let tiles_n = op.n.div_ceil(kern.tile_n);
    let blocks = tiles_m * tiles_n * op.batch * splitk;
    let wave_capacity = dev.sm_count * bpsm;
    Some(WaveInfo {
        blocks,
        blocks_per_sm: bpsm,
        wave_capacity,
        full_waves: blocks / wave_capacity,
        tail_blocks: blocks % wave_capacity,
    })
}

/// Internal per-wave timing breakdown (also drives counters + power).
struct WaveTimes {
    t_compute: f64,
    t_mem_full: f64,
    dram_bytes_per_block: f64,
    l2_bytes_per_block: f64,
}

fn wave_times(
    dev: &DeviceSpec,
    kern: &GemmKernel,
    op: &GemmOp,
    splitk: usize,
    waves: &WaveInfo,
    freq_ghz: f64,
) -> WaveTimes {
    let kb = op.k.div_ceil(splitk) as f64;
    let dsize = op.dtype.bytes() as f64;
    // --- compute: blocks on an SM share its FLOP throughput ---
    let block_flops = 2.0 * kern.tile_m as f64 * kern.tile_n as f64 * kb;
    let peak = dev.peak_tflops(op.dtype).expect("dtype gated earlier")
        * 1e12
        * (freq_ghz / dev.max_freq_ghz);
    let per_sm = peak / dev.sm_count as f64;
    let eff = kern.eff_at_k(kb) * kern.trans_eff(op.trans());
    let t_compute = block_flops * waves.blocks_per_sm as f64 / (per_sm * eff);
    // --- memory: operand slabs + output tile per block ---
    let in_bytes = (kern.tile_m + kern.tile_n) as f64 * kb * dsize;
    let out_bytes = (kern.tile_m * kern.tile_n) as f64 * dsize;
    // L2 residency: the kernel's swizzle-/layout-dependent reuse fraction,
    // blending up toward near-full residency as the operand set shrinks
    // below the L2 capacity (smooth, like a real cache's hit curve).
    let mut l2f = kern.l2_frac(op.trans());
    let ws = op.io_bytes() / dev.l2_bytes();
    if ws < 3.0 {
        let resident = 0.85;
        let t = if ws <= 0.4 {
            1.0
        } else {
            // log-space ramp from fully-resident (0.4×L2) to none (3×L2).
            1.0 - (ws.ln() - 0.4f64.ln()) / (3.0f64.ln() - 0.4f64.ln())
        };
        l2f = l2f.max(l2f + (resident - l2f) * t.clamp(0.0, 1.0));
    }
    let dram_bytes_per_block = in_bytes * (1.0 - l2f) + out_bytes;
    let l2_bytes_per_block = in_bytes * l2f;
    let cap = waves.wave_capacity as f64;
    let t_mem_full = (dram_bytes_per_block * cap)
        / (dev.dram_bw() * kern.mem_eff)
        + (l2_bytes_per_block * cap) / (dev.l2_bw() * kern.mem_eff);
    WaveTimes { t_compute, t_mem_full, dram_bytes_per_block, l2_bytes_per_block }
}

/// Noise-free GEMM latency in seconds at a given core frequency.
/// None = kernel cannot run this op on this device.
pub fn gemm_latency(
    dev: &DeviceSpec,
    kern: &GemmKernel,
    op: &GemmOp,
    cfg_splitk: usize,
    freq_ghz: f64,
) -> Option<f64> {
    if !dev.supports(op.dtype) || kern.dtype != op.dtype {
        return None;
    }
    let splitk = cfg_splitk.max(1);
    let waves = wave_info(dev, kern, op, splitk)?;
    let wt = wave_times(dev, kern, op, splitk, &waves, freq_ghz);
    let overlap = kern.overlap();
    let combine = |tc: f64, tm: f64| tc.max(tm) + (1.0 - overlap) * tc.min(tm);
    let full_wave_t = combine(wt.t_compute, wt.t_mem_full);
    let tail_frac = waves.tail_blocks as f64 / waves.wave_capacity as f64;
    let tail_t = if waves.tail_blocks > 0 {
        // Tail wave: fewer blocks resident per SM share its throughput, so
        // per-block compute speeds up; aggregate memory pressure shrinks
        // proportionally. (SIMT lockstep still holds *within* the wave.)
        let tail_bpsm = waves.tail_blocks.div_ceil(dev.sm_count);
        let t_compute_tail =
            wt.t_compute * tail_bpsm as f64 / waves.blocks_per_sm as f64;
        combine(t_compute_tail, wt.t_mem_full * tail_frac)
    } else {
        0.0
    };
    // Split-K epilogue: partial products reduced through DRAM.
    let reduce_t = if splitk > 1 {
        let bytes =
            (op.batch * op.m * op.n) as f64 * (splitk as f64 + 1.0) * 4.0;
        bytes / dev.dram_bw() + dev.launch_us * 1e-6 * 0.5
    } else {
        0.0
    };
    let sched_t = 0.15e-6 * waves.total_waves() as f64;
    Some(
        dev.launch_us * 1e-6
            + waves.full_waves as f64 * full_wave_t
            + tail_t
            + reduce_t
            + sched_t,
    )
}

/// NCU-style counters for the op under this kernel config.
pub fn gemm_counters(
    dev: &DeviceSpec,
    kern: &GemmKernel,
    op: &GemmOp,
    cfg_splitk: usize,
) -> Counters {
    let splitk = cfg_splitk.max(1);
    let waves = match wave_info(dev, kern, op, splitk) {
        Some(w) => w,
        None => return Counters::default(),
    };
    let wt = wave_times(dev, kern, op, splitk, &waves, dev.max_freq_ghz);
    let nb = waves.blocks as f64;
    Counters {
        flops: op.flops(),
        dram_bytes: wt.dram_bytes_per_block * nb,
        l2_bytes: wt.l2_bytes_per_block * nb,
        int_ops: nb * (kern.tile_m * kern.tile_n) as f64 * 0.5,
        mem_insts: (wt.dram_bytes_per_block + wt.l2_bytes_per_block) * nb / 128.0,
    }
}

/// Achieved-FLOPs utilization (for power draw + NeuSight's target).
pub fn utilization(dev: &DeviceSpec, op: &GemmOp, latency_s: f64) -> f64 {
    let peak = match dev.peak_tflops(op.dtype) {
        Some(p) => p * 1e12,
        None => return 0.0,
    };
    (op.flops() / (peak * latency_s)).min(1.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::device::device_by_name;
    use crate::gpusim::kernel::registry;
    use crate::ops::{DType, GemmOp};

    fn a100_fp32() -> (DeviceSpec, Vec<GemmKernel>) {
        let d = device_by_name("a100").unwrap();
        let ks = registry(&d, DType::F32);
        (d, ks)
    }

    #[test]
    fn latency_monotone_in_k() {
        let (d, ks) = a100_fp32();
        let k = &ks[9];
        let mut prev = 0.0;
        for kk in [64, 256, 1024, 4096, 8192] {
            let op = GemmOp::mm(2048, 2048, kk, DType::F32);
            let t = gemm_latency(&d, k, &op, 1, d.max_freq_ghz).unwrap();
            assert!(t > prev, "k={kk}: {t} <= {prev}");
            prev = t;
        }
    }

    #[test]
    fn duration_linear_in_k_at_fixed_waves() {
        // Fig 3: fixed tile/waves, duration ≈ linear in K at large K.
        let (d, ks) = a100_fp32();
        let k = &ks[9];
        let op1 = GemmOp::mm(2048, 2048, 4096, DType::F32);
        let op2 = GemmOp::mm(2048, 2048, 8192, DType::F32);
        let t1 = gemm_latency(&d, k, &op1, 1, d.max_freq_ghz).unwrap();
        let t2 = gemm_latency(&d, k, &op2, 1, d.max_freq_ghz).unwrap();
        let ratio = t2 / t1;
        assert!(ratio > 1.7 && ratio < 2.2, "ratio={ratio}");
    }

    #[test]
    fn throughput_saturates_rationally() {
        // Fig 4: throughput (flops/s) grows with K and saturates.
        let (d, ks) = a100_fp32();
        let k = &ks[9];
        let thr = |kk: usize| {
            let op = GemmOp::mm(2048, 2048, kk, DType::F32);
            op.flops() / gemm_latency(&d, k, &op, 1, d.max_freq_ghz).unwrap()
        };
        let t32 = thr(32);
        let t1024 = thr(1024);
        let t8192 = thr(8192);
        assert!(t1024 > t32 * 2.0);
        assert!(t8192 > t1024);
        // Diminishing returns: last doubling gains < 20%.
        assert!(thr(8192) / thr(4096) < 1.2);
    }

    #[test]
    fn partial_tiles_execute_fully() {
        // m=129 with tile 128 costs the same as m=256 block count-wise.
        let (d, ks) = a100_fp32();
        let k = ks.iter().find(|k| k.tile_m == 128 && k.tile_n == 128).unwrap();
        let t_full =
            gemm_latency(&d, k, &GemmOp::mm(256, 1024, 1024, DType::F32), 1, d.max_freq_ghz)
                .unwrap();
        let t_partial =
            gemm_latency(&d, k, &GemmOp::mm(129, 1024, 1024, DType::F32), 1, d.max_freq_ghz)
                .unwrap();
        assert_eq!(
            wave_info(&d, k, &GemmOp::mm(129, 1024, 1024, DType::F32), 1)
                .unwrap()
                .blocks,
            wave_info(&d, k, &GemmOp::mm(256, 1024, 1024, DType::F32), 1)
                .unwrap()
                .blocks
        );
        // Same blocks → same latency.
        assert!((t_full - t_partial).abs() < 1e-12);
    }

    #[test]
    fn splitk_helps_small_mn_large_k() {
        let (d, ks) = a100_fp32();
        let k = ks.iter().find(|k| k.tile_m == 128 && k.tile_n == 128).unwrap();
        let op = GemmOp::mm(128, 128, 16384, DType::F32);
        let t1 = gemm_latency(&d, k, &op, 1, d.max_freq_ghz).unwrap();
        let t8 = gemm_latency(&d, k, &op, 8, d.max_freq_ghz).unwrap();
        assert!(t8 < t1, "splitk should help: {t8} vs {t1}");
    }

    #[test]
    fn kernels_differ_on_same_op() {
        // The paper's core phenomenon: same FLOPs, different kernels,
        // significantly different latency.
        let (d, ks) = a100_fp32();
        let op = GemmOp::mm(1024, 1024, 1024, DType::F32);
        let ts: Vec<f64> = ks
            .iter()
            .filter_map(|k| gemm_latency(&d, k, &op, 1, d.max_freq_ghz))
            .collect();
        let lo = ts.iter().cloned().fold(f64::MAX, f64::min);
        let hi = ts.iter().cloned().fold(0.0, f64::max);
        assert!(hi / lo > 1.3, "kernel disparity too small: {}", hi / lo);
    }

    #[test]
    fn frequency_scales_compute_latency() {
        let (d, ks) = a100_fp32();
        let k = &ks[9];
        let op = GemmOp::mm(4096, 4096, 4096, DType::F32);
        let t_full = gemm_latency(&d, k, &op, 1, d.max_freq_ghz).unwrap();
        let t_half = gemm_latency(&d, k, &op, 1, d.max_freq_ghz / 2.0).unwrap();
        assert!(t_half > t_full * 1.3, "compute-bound op must slow down");
    }

    #[test]
    fn wrong_dtype_kernel_rejected() {
        let (d, ks) = a100_fp32();
        let op = GemmOp::mm(128, 128, 128, DType::Bf16);
        assert!(gemm_latency(&d, &ks[0], &op, 1, d.max_freq_ghz).is_none());
    }

    #[test]
    fn counters_positive_and_flops_exact() {
        let (d, ks) = a100_fp32();
        let op = GemmOp::mm(512, 512, 512, DType::F32);
        let c = gemm_counters(&d, &ks[3], &op, 1);
        assert_eq!(c.flops, op.flops());
        assert!(c.dram_bytes > 0.0 && c.l2_bytes > 0.0);
    }

    #[test]
    fn utilization_bounded() {
        let (d, _) = a100_fp32();
        let op = GemmOp::mm(4096, 4096, 4096, DType::F32);
        let u = utilization(&d, &op, 0.02);
        assert!(u > 0.0 && u <= 1.0);
    }

    #[test]
    fn gemv_degenerate_classification() {
        assert!(is_gemv_degenerate(&GemmOp::linear(1, 5120, 1280, DType::F32)));
        assert!(is_gemv_degenerate(&GemmOp::linear(8, 5120, 1280, DType::F32)));
        assert!(is_gemv_degenerate(&GemmOp::bmm(160, 1, 512, 64, DType::Bf16)));
        assert!(!is_gemv_degenerate(&GemmOp::linear(64, 5120, 1280, DType::F32)));
        assert!(!is_gemv_degenerate(&GemmOp::mm(512, 512, 512, DType::F32)));
    }

    #[test]
    fn gemv_latency_is_memory_bound_and_monotone_in_weight_bytes() {
        let (d, _) = a100_fp32();
        // Decode-step projection: m = batch, streaming a k×n weight.
        let mut prev = 0.0;
        for k in [256usize, 1024, 4096, 16384] {
            let op = GemmOp::linear(1, 4096, k, DType::F32);
            let t = gemv_latency(&d, &op, d.max_freq_ghz).unwrap();
            assert!(t > prev, "k={k}: {t} <= {prev}");
            prev = t;
        }
        // Frequency insensitivity: the route is bandwidth-limited.
        let op = GemmOp::linear(4, 8192, 4096, DType::F32);
        let t_full = gemv_latency(&d, &op, d.max_freq_ghz).unwrap();
        let t_half = gemv_latency(&d, &op, d.max_freq_ghz / 2.0).unwrap();
        assert!(t_half < t_full * 1.1, "gemv must not be clock-bound");
        // Unsupported dtypes still gate.
        let t4 = crate::gpusim::device::device_by_name("t4").unwrap();
        assert!(gemv_latency(&t4, &GemmOp::linear(1, 64, 64, DType::Bf16), 1.0).is_none());
    }

    #[test]
    fn skinny_band_classification_and_continuity() {
        // ISSUE skinny-GEMM satellite: 9..=32 joins the streaming family.
        assert!(is_skinny(&GemmOp::linear(9, 5120, 1280, DType::F32)));
        assert!(is_skinny(&GemmOp::linear(32, 5120, 1280, DType::F32)));
        assert!(!is_skinny(&GemmOp::linear(33, 5120, 1280, DType::F32)));
        assert!(is_skinny(&GemmOp::linear(1, 64, 64, DType::F32)));
        // Inside the gemv band the two routes are the same function.
        let (d, _) = a100_fp32();
        let op8 = GemmOp::linear(8, 4096, 4096, DType::F32);
        assert_eq!(
            skinny_latency(&d, &op8, d.max_freq_ghz),
            gemv_latency(&d, &op8, d.max_freq_ghz)
        );
        // No cliff at the 8 → 9 boundary: +1 row cannot change cost much.
        let t8 = skinny_latency(&d, &op8, d.max_freq_ghz).unwrap();
        let t9 = skinny_latency(&d, &GemmOp::linear(9, 4096, 4096, DType::F32), d.max_freq_ghz)
            .unwrap();
        let ratio = t9 / t8;
        assert!(ratio > 0.85 && ratio < 1.25, "boundary cliff: {ratio}");
        // Monotone in rows and depth within the band.
        let mut prev = 0.0;
        for r in [9usize, 16, 24, 32] {
            let t = skinny_latency(&d, &GemmOp::linear(r, 4096, 4096, DType::F32), d.max_freq_ghz)
                .unwrap();
            assert!(t > prev, "r={r}");
            prev = t;
        }
        let mut prev = 0.0;
        for k in [256usize, 1024, 4096, 16384] {
            let t = skinny_latency(&d, &GemmOp::linear(16, 4096, k, DType::F32), d.max_freq_ghz)
                .unwrap();
            assert!(t > prev, "k={k}");
            prev = t;
        }
    }

    #[test]
    fn skinny_route_is_bandwidth_led_and_beats_the_tiled_model() {
        let (d, ks) = a100_fp32();
        let op = GemmOp::linear(16, 8192, 4096, DType::F32);
        let t_full = skinny_latency(&d, &op, d.max_freq_ghz).unwrap();
        // The band is transitional: arithmetic intensity is r/2 FLOP/byte,
        // which approaches machine balance near r = 32 — so unlike pure
        // gemv it is not fully clock-insensitive, but it must stay well
        // below the 2× slowdown of a compute-bound tiled kernel.
        let t_half = skinny_latency(&d, &op, d.max_freq_ghz / 2.0).unwrap();
        assert!(t_half < t_full * 1.7, "skinny band over-rotates on clock");
        // A 64/128-row tiled kernel wastes ≥ 4× of every block on a
        // 16-row operand — the streaming route must win.
        let best_tiled = ks
            .iter()
            .filter_map(|k| gemm_latency(&d, k, &op, 1, d.max_freq_ghz))
            .fold(f64::MAX, f64::min);
        assert!(
            t_full < best_tiled,
            "skinny {t_full} should beat tiled {best_tiled}"
        );
        // Unsupported dtypes still gate.
        let t4 = crate::gpusim::device::device_by_name("t4").unwrap();
        assert!(skinny_latency(&t4, &GemmOp::linear(16, 64, 64, DType::Bf16), 1.0).is_none());
    }

    #[test]
    fn gemv_counters_split_residency_and_sum_to_io() {
        let (d, _) = a100_fp32();
        let op = GemmOp::linear(2, 4096, 4096, DType::F32);
        let c = gemv_counters(&d, &op);
        assert_eq!(c.flops, op.flops());
        let total = c.dram_bytes + c.l2_bytes;
        assert!((total - op.io_bytes()).abs() / total < 1e-9);
    }
}
