//! Ring-collective latency model — the simulator-side ground truth that
//! `pm2lat`'s measured `CommProfile` staircase approximates, mirroring
//! the GemmTable/AttnProfile split for compute kernels.
//!
//! The α–β cost of a ring collective over `p` symmetric ranks:
//!
//! ```text
//! t = α + steps · (hop + chunk / β_eff)
//!   α      = comm_launch_us          (launch + rendezvous of all ranks)
//!   steps  = 2(p−1)  AllReduce       (reduce-scatter + all-gather)
//!            (p−1)   AllGather
//!   chunk  = bytes / p               (per-hop payload)
//!   β_eff  = link_gbs · bus_derate   (achievable link bandwidth)
//!   hop    = per-step synchronization cost (a fixed fraction of α:
//!            every step is a neighbour exchange with its own latency)
//! ```
//!
//! Collectives run on the copy/NCCL engines, not the SM clock, so —
//! unlike every compute op in `executor.rs` — their latency does not
//! scale with the simulated core frequency.

use crate::ops::CommOp;

use super::device::DeviceSpec;

/// Per-hop latency as a fraction of the launch overhead: each ring step
/// is a neighbour send/recv with its own (much smaller) fixed cost.
const HOP_LAUNCH_FRACTION: f64 = 0.1;

/// Latency in seconds of one collective on `spec`'s peer link. A single
/// participant degenerates to launch overhead only (a local no-op kernel).
pub fn comm_latency(spec: &DeviceSpec, c: &CommOp) -> f64 {
    let alpha = spec.comm_launch_us * 1e-6;
    let steps = c.kind.ring_steps(c.participants) as f64;
    if steps == 0.0 {
        return alpha;
    }
    let chunk = c.bytes() / c.participants.max(1) as f64;
    let hop = alpha * HOP_LAUNCH_FRACTION;
    alpha + steps * (hop + chunk / spec.link_bw())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::device_by_name;
    use crate::ops::{CommKind, DType};

    fn a100() -> DeviceSpec {
        device_by_name("a100").unwrap()
    }

    #[test]
    fn single_participant_is_launch_only() {
        let spec = a100();
        let c = CommOp::all_reduce(1 << 20, DType::Bf16, 1);
        assert_eq!(comm_latency(&spec, &c), spec.comm_launch_us * 1e-6);
    }

    #[test]
    fn latency_monotone_in_bytes_and_participants() {
        let spec = a100();
        let mk = |elems, p| CommOp::all_reduce(elems, DType::Bf16, p);
        assert!(comm_latency(&spec, &mk(1 << 22, 4)) > comm_latency(&spec, &mk(1 << 20, 4)));
        // More ranks ⇒ more ring steps; the fixed hop cost keeps the
        // total growing even though the per-hop chunk shrinks.
        assert!(comm_latency(&spec, &mk(1 << 20, 8)) > comm_latency(&spec, &mk(1 << 20, 2)));
    }

    #[test]
    fn all_reduce_moves_twice_the_all_gather_volume() {
        let spec = a100();
        let ar = CommOp::all_reduce(1 << 24, DType::F32, 4);
        let ag = CommOp::all_gather(1 << 24, DType::F32, 4);
        let alpha = spec.comm_launch_us * 1e-6;
        let wire = |t: f64, steps: f64| t - alpha - steps * alpha * 0.1;
        // Stripped of fixed costs, the ratio is exactly the step ratio.
        let r = wire(comm_latency(&spec, &ar), 6.0) / wire(comm_latency(&spec, &ag), 3.0);
        assert!((r - 2.0).abs() < 1e-9, "r={r}");
    }

    #[test]
    fn nvlink_beats_pcie_on_the_same_collective() {
        let c = CommOp::all_reduce(1 << 24, DType::F32, 4);
        let a = comm_latency(&a100(), &c);
        let t4 = comm_latency(&device_by_name("t4").unwrap(), &c);
        assert!(a < t4, "a100={a} t4={t4}");
    }
}
