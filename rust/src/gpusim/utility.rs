//! Ground-truth latency model for memory-bound utility layers
//! (paper §III-A "Utility Layers"): DRAM/L2 residency-driven bandwidth, a
//! per-element instruction cost, and — for reductions — extra passes plus
//! an occupancy penalty at low row counts. The model is intentionally
//! *nonlinear* in places a linear regression cannot fully capture, which is
//! what produces the paper's SoftMax-vs-Vector error asymmetry (Table II).

use crate::ops::{Counters, UtilKind, UtilOp};
use crate::util::prng::hash64;

use super::device::DeviceSpec;

/// Per-(device, kind) hidden implementation factor: every utility kernel
/// is its own closed-source implementation with its own constants.
fn impl_factor(dev: &DeviceSpec, kind: UtilKind) -> f64 {
    let h = hash64(format!("{}/util/{}", dev.name, kind.name()).as_bytes());
    0.82 + 0.3 * ((h & 0xffff) as f64 / 65535.0)
}

/// Effective bandwidth for a streaming working set of `bytes`:
/// L2-resident sets stream near L2 bandwidth, larger sets blend toward
/// DRAM with a smooth transition (composite bandwidth, paper Fig. 2).
pub fn effective_bw(dev: &DeviceSpec, bytes: f64) -> f64 {
    let l2 = dev.l2_bytes();
    if bytes <= 0.45 * l2 {
        dev.l2_bw() * 0.62
    } else if bytes >= 3.0 * l2 {
        dev.dram_bw() * 0.88
    } else {
        // log-space blend between the two plateaus.
        let lo = (0.45f64 * l2).ln();
        let hi = (3.0f64 * l2).ln();
        let t = (bytes.ln() - lo) / (hi - lo);
        let a = dev.l2_bw() * 0.62;
        let b = dev.dram_bw() * 0.88;
        a * (1.0 - t) + b * t
    }
}

/// Noise-free utility-op latency at `freq_ghz` (seconds).
pub fn util_latency(dev: &DeviceSpec, op: &UtilOp, freq_ghz: f64) -> f64 {
    let elems = op.elems();
    let dsize = op.dtype.bytes() as f64;
    let bytes = elems * dsize * op.passes();
    let bw = effective_bw(dev, bytes);
    let t_mem = bytes / bw;
    let freq_scale = freq_ghz / dev.max_freq_ghz;
    let t_alu =
        elems * op.instrs_per_elem() / (dev.int_gops * 1e9 * freq_scale);
    let mut t = t_mem.max(t_alu) + 0.25 * t_mem.min(t_alu);
    if op.kind.is_reduction() {
        // Tree-reduction passes: log2(cols) sync steps per row.
        let passes = (op.cols.max(2) as f64).log2();
        t += op.rows as f64 * passes * 2.0e-9 / freq_scale;
        // Occupancy cliff: few rows cannot fill the SMs, and the
        // per-row working set may thrash L1 for very wide rows.
        let rows_needed = (dev.sm_count * 8) as f64;
        if (op.rows as f64) < rows_needed {
            let deficit = rows_needed / op.rows.max(1) as f64;
            t *= 1.0 + 0.35 * deficit.ln_1p();
        }
        if op.cols > 4096 {
            t *= 1.0 + 0.08 * ((op.cols as f64 / 4096.0).ln());
        }
    }
    dev.launch_us * 1e-6 + t * impl_factor(dev, op.kind)
}

/// NCU-style counters (what PM2Lat's regression consumes).
pub fn util_counters(dev: &DeviceSpec, op: &UtilOp) -> Counters {
    let elems = op.elems();
    let dsize = op.dtype.bytes() as f64;
    let bytes = elems * dsize * op.passes();
    // Residency split mirrors effective_bw's blend.
    let l2_share = if bytes <= 0.45 * dev.l2_bytes() {
        0.9
    } else if bytes >= 3.0 * dev.l2_bytes() {
        0.15
    } else {
        0.5
    };
    Counters {
        flops: elems * op.instrs_per_elem() * 0.5,
        dram_bytes: bytes * (1.0 - l2_share),
        l2_bytes: bytes * l2_share,
        int_ops: elems * 1.5,
        mem_insts: bytes / 128.0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::device::device_by_name;
    use crate::ops::DType;

    #[test]
    fn latency_scales_with_elements() {
        let d = device_by_name("a100").unwrap();
        let small = UtilOp::new(UtilKind::Relu, 1024, 1024, DType::F32);
        let large = UtilOp::new(UtilKind::Relu, 8192, 8192, DType::F32);
        let ts = util_latency(&d, &small, d.max_freq_ghz);
        let tl = util_latency(&d, &large, d.max_freq_ghz);
        assert!(tl > ts * 10.0, "{tl} vs {ts}");
    }

    #[test]
    fn alu_cost_matters_when_int_throughput_is_low() {
        // On a real GPU elementwise ops are memory-bound (GeLU ≈ ReLU); the
        // ALU term only dominates when integer throughput is small. Build a
        // synthetic device to exercise that regime.
        let mut d = device_by_name("rtx3060m").unwrap();
        d.int_gops = 5.0; // pathological ALU-starved device
        let relu = UtilOp::new(UtilKind::Relu, 512, 512, DType::F32);
        let gelu = UtilOp::new(UtilKind::Gelu, 512, 512, DType::F32);
        let t_relu = util_latency(&d, &relu, d.max_freq_ghz);
        let t_gelu = util_latency(&d, &gelu, d.max_freq_ghz);
        assert!(t_gelu > t_relu * 2.0, "gelu={t_gelu} relu={t_relu}");
    }

    #[test]
    fn gelu_and_relu_comparable_in_memory_bound_regime() {
        // Same bytes moved → within the per-kind implementation factor.
        let d = device_by_name("rtx3060m").unwrap();
        let relu = UtilOp::new(UtilKind::Relu, 4096, 4096, DType::F32);
        let gelu = UtilOp::new(UtilKind::Gelu, 4096, 4096, DType::F32);
        let r = util_latency(&d, &relu, d.max_freq_ghz);
        let g = util_latency(&d, &gelu, d.max_freq_ghz);
        assert!(g / r > 0.6 && g / r < 1.7, "ratio={}", g / r);
    }

    #[test]
    fn l2_resident_faster_than_dram() {
        let d = device_by_name("l4").unwrap(); // 48 MB L2
        let bytes_small = 4.0 * 1024.0 * 1024.0;
        let bytes_big = 1024.0 * 1024.0 * 1024.0;
        assert!(effective_bw(&d, bytes_small) > effective_bw(&d, bytes_big) * 1.5);
    }

    #[test]
    fn effective_bw_monotone_decreasing() {
        let d = device_by_name("a100").unwrap();
        let mut prev = f64::MAX;
        for mb in [1.0, 8.0, 20.0, 40.0, 80.0, 200.0, 1000.0] {
            let bw = effective_bw(&d, mb * 1024.0 * 1024.0);
            assert!(bw <= prev + 1.0);
            prev = bw;
        }
    }

    #[test]
    fn softmax_has_reduction_overhead() {
        let d = device_by_name("t4").unwrap();
        let sm = UtilOp::new(UtilKind::Softmax, 64, 8192, DType::F32);
        let add = UtilOp::new(UtilKind::Add, 64, 8192, DType::F32);
        // Softmax moves similar bytes but pays reduction + occupancy cost.
        assert!(
            util_latency(&d, &sm, d.max_freq_ghz)
                > util_latency(&d, &add, d.max_freq_ghz)
        );
    }

    #[test]
    fn counters_sum_to_pass_bytes() {
        let d = device_by_name("a100").unwrap();
        let op = UtilOp::new(UtilKind::Mul, 2048, 2048, DType::Bf16);
        let c = util_counters(&d, &op);
        let expect = op.elems() * 2.0 * op.passes();
        assert!((c.dram_bytes + c.l2_bytes - expect).abs() / expect < 1e-9);
    }

    #[test]
    fn impl_factor_stable_per_device_kind() {
        let d = device_by_name("l4").unwrap();
        assert_eq!(impl_factor(&d, UtilKind::Gelu), impl_factor(&d, UtilKind::Gelu));
        assert_ne!(impl_factor(&d, UtilKind::Gelu), impl_factor(&d, UtilKind::Relu));
    }
}
