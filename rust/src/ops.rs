//! Operation vocabulary shared by the simulator, the predictors and the
//! model zoo: GEMM-family ops, memory-bound utility ops, and the custom
//! fused kernels of paper §IV-C.

use std::fmt;

/// Numeric precision. FP32 executes on the CUDA-core path, BF16 on the
/// tensor-core path — with very different kernel registries (paper §I:
/// ~13 FP32 vs ~100 BF16 algorithm/tile combinations).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum DType {
    F32,
    Bf16,
}

impl DType {
    pub fn bytes(&self) -> usize {
        match self {
            DType::F32 => 4,
            DType::Bf16 => 2,
        }
    }
    pub fn name(&self) -> &'static str {
        match self {
            DType::F32 => "fp32",
            DType::Bf16 => "bf16",
        }
    }
    pub fn parse(s: &str) -> Option<DType> {
        match s.to_ascii_lowercase().as_str() {
            "fp32" | "f32" | "float32" => Some(DType::F32),
            "bf16" | "bfloat16" => Some(DType::Bf16),
            _ => None,
        }
    }
}

impl fmt::Display for DType {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.name())
    }
}

/// Transpose mode of the A operand. PyTorch `Linear` uses TN (first matrix
/// transposed); `torch.matmul` / ONNX / TF use NN — and the paper observed
/// that this changes library/algorithm/tile selection (§III-B).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Trans {
    NN,
    TN,
}

/// Which framework-level API issued the GEMM. Affects the transpose mode
/// and therefore kernel selection; also how the paper buckets its per-layer
/// evaluation (Table II rows: BMM / MM / Linear).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum GemmApi {
    MatMul,
    Linear,
    Bmm,
}

impl GemmApi {
    pub fn trans(&self) -> Trans {
        match self {
            GemmApi::Linear => Trans::TN,
            _ => Trans::NN,
        }
    }
    pub fn name(&self) -> &'static str {
        match self {
            GemmApi::MatMul => "MM",
            GemmApi::Linear => "Linear",
            GemmApi::Bmm => "BMM",
        }
    }
}

/// Which GEMM dimension a tensor-parallel split shards. Megatron-style
/// column parallelism splits the output dimension `n` (QKV / FFN-up);
/// row parallelism splits the contraction dimension `k` (attention
/// output projection / FFN-down) and leaves a partial sum that an
/// AllReduce completes.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ShardDim {
    Col,
    Row,
}

/// Shard annotation on a GEMM: this op is one rank's `1/parts` slice of
/// a tensor-parallel split along `dim`. The annotated dimensions are
/// already divided — the op describes exactly the kernel one rank runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Shard {
    pub dim: ShardDim,
    pub parts: usize,
}

/// A dense GEMM: C[b] = A[b] (m×k) · B[b] (k×n) for b in 0..batch.
/// `shard` records a tensor-parallel split (None for the ordinary
/// single-device op; the constructors never set it).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct GemmOp {
    pub api: GemmApi,
    pub batch: usize,
    pub m: usize,
    pub n: usize,
    pub k: usize,
    pub dtype: DType,
    pub shard: Option<Shard>,
}

// Manual Hash: fields in declaration order (exactly what the derive
// produced before `shard` existed), with `shard` folded in only when
// present. Unsharded GEMMs therefore keep their pre-placement
// `stable_hash` identities — the simulator noise streams and cache keys
// they seed are bit-for-bit unchanged, which is what makes
// `Placement::single()` reproduce historical predictions exactly.
impl std::hash::Hash for GemmOp {
    fn hash<H: std::hash::Hasher>(&self, state: &mut H) {
        self.api.hash(state);
        self.batch.hash(state);
        self.m.hash(state);
        self.n.hash(state);
        self.k.hash(state);
        self.dtype.hash(state);
        if let Some(s) = self.shard {
            s.hash(state);
        }
    }
}

impl GemmOp {
    pub fn mm(m: usize, n: usize, k: usize, dtype: DType) -> GemmOp {
        GemmOp { api: GemmApi::MatMul, batch: 1, m, n, k, dtype, shard: None }
    }
    pub fn linear(m: usize, n: usize, k: usize, dtype: DType) -> GemmOp {
        GemmOp { api: GemmApi::Linear, batch: 1, m, n, k, dtype, shard: None }
    }
    pub fn bmm(batch: usize, m: usize, n: usize, k: usize, dtype: DType) -> GemmOp {
        GemmOp { api: GemmApi::Bmm, batch, m, n, k, dtype, shard: None }
    }
    /// This op as one rank's slice of a `parts`-way split along `dim`.
    /// The sharded dimension is divided here; callers pass the *full*
    /// (unsharded) op.
    pub fn sharded(mut self, dim: ShardDim, parts: usize) -> GemmOp {
        assert!(parts >= 1, "a shard needs at least one part");
        match dim {
            ShardDim::Col => {
                assert_eq!(self.n % parts, 0, "column split must divide n");
                self.n /= parts;
            }
            ShardDim::Row => {
                assert_eq!(self.k % parts, 0, "row split must divide k");
                self.k /= parts;
            }
        }
        self.shard = Some(Shard { dim, parts });
        self
    }
    /// 2·b·m·n·k multiply-accumulate FLOPs.
    pub fn flops(&self) -> f64 {
        2.0 * self.batch as f64 * self.m as f64 * self.n as f64 * self.k as f64
    }
    /// Minimal operand + output traffic in bytes (no tiling reuse).
    pub fn io_bytes(&self) -> f64 {
        let d = self.dtype.bytes() as f64;
        self.batch as f64
            * ((self.m * self.k + self.k * self.n) as f64 * d
                + (self.m * self.n) as f64 * d)
    }
    pub fn trans(&self) -> Trans {
        self.api.trans()
    }
}

/// Memory-bound utility layer kinds (paper §III "Utility Layers").
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum UtilKind {
    Relu,
    Gelu,
    Add,
    Mul,
    Dropout,
    Softmax,
    LayerNorm,
    MaxPool,
}

impl UtilKind {
    pub fn name(&self) -> &'static str {
        match self {
            UtilKind::Relu => "ReLU",
            UtilKind::Gelu => "GeLU",
            UtilKind::Add => "Add",
            UtilKind::Mul => "Mul",
            UtilKind::Dropout => "Dropout",
            UtilKind::Softmax => "SoftMax",
            UtilKind::LayerNorm => "LayerNorm",
            UtilKind::MaxPool => "MaxPool",
        }
    }
    /// Elementwise "Vector" ops vs row-reduction ops: the paper's Table II
    /// buckets ReLU/GeLU/Add/Mul/Dropout as "Vector" and reports SoftMax
    /// separately (reductions behave differently).
    pub fn is_reduction(&self) -> bool {
        matches!(self, UtilKind::Softmax | UtilKind::LayerNorm | UtilKind::MaxPool)
    }
    pub fn all() -> &'static [UtilKind] {
        &[
            UtilKind::Relu,
            UtilKind::Gelu,
            UtilKind::Add,
            UtilKind::Mul,
            UtilKind::Dropout,
            UtilKind::Softmax,
            UtilKind::LayerNorm,
            UtilKind::MaxPool,
        ]
    }
}

/// A utility op over a logical (rows × cols) tensor; reductions reduce
/// along cols.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct UtilOp {
    pub kind: UtilKind,
    pub rows: usize,
    pub cols: usize,
    pub dtype: DType,
}

impl UtilOp {
    pub fn new(kind: UtilKind, rows: usize, cols: usize, dtype: DType) -> UtilOp {
        UtilOp { kind, rows, cols, dtype }
    }
    pub fn elems(&self) -> f64 {
        self.rows as f64 * self.cols as f64
    }
    /// (reads + writes) per element for the ground memory model.
    pub fn passes(&self) -> f64 {
        match self.kind {
            UtilKind::Relu | UtilKind::Gelu => 2.0,
            UtilKind::Add | UtilKind::Mul => 3.0,
            UtilKind::Dropout => 2.25, // mask stream is byte-wide
            UtilKind::Softmax => 3.0,  // read, re-read after max, write
            UtilKind::LayerNorm => 2.6,
            UtilKind::MaxPool => 1.25, // 4:1 downsample write
        }
    }
    /// Arithmetic instructions per element (transcendental ops cost more).
    pub fn instrs_per_elem(&self) -> f64 {
        match self.kind {
            UtilKind::Relu => 1.0,
            UtilKind::Gelu => 9.0,
            UtilKind::Add | UtilKind::Mul => 1.0,
            UtilKind::Dropout => 3.0,
            UtilKind::Softmax => 7.0,
            UtilKind::LayerNorm => 6.0,
            UtilKind::MaxPool => 1.5,
        }
    }
}

/// Query–key pairs an attention kernel actually evaluates. The query
/// window is aligned to the *end* of the key window (the autoregressive
/// convention): query `i` of `q_len` attends `kv_len - q_len + 1 + i`
/// keys under a causal mask. Prefill (`q == kv`) evaluates the lower
/// triangle `q·(q+1)/2`; a decode step (`q == 1`) sees the whole cache —
/// the mask removes nothing, every kernel is KV-bound instead.
pub fn attended_pairs(q_len: usize, kv_len: usize, causal: bool) -> f64 {
    let (q, kv) = (q_len as f64, kv_len as f64);
    if !causal {
        return q * kv;
    }
    if kv_len >= q_len {
        q * kv - q * (q - 1.0) / 2.0
    } else {
        // Degenerate window (more queries than keys): only the trailing
        // kv_len queries attend anything.
        kv * (kv + 1.0) / 2.0
    }
}

/// Custom computation-intensive kernels of paper §IV-C / Table VI.
///
/// Attention kernels distinguish the query length from the key/value
/// length: prefill is `q_len == kv_len == seq`, an autoregressive decode
/// step is `q_len == 1, kv_len == t` (the kernel streams a KV cache of
/// `t` entries per lane and appends the new token's K/V rows).
///
/// `kv_heads` is the grouped-query structure: the kernel runs
/// `batch·heads` query lanes but the KV cache holds only
/// `batch·kv_heads` distinct lanes — query-head groups share one K/V
/// stream, so GQA cache *traffic* (not just footprint) shrinks by
/// `heads / kv_heads`. MHA is `kv_heads == heads`; compute is unchanged
/// either way (every query head still evaluates its pairs).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum CustomOp {
    /// Triton matmul: autotuned from Triton's own config space.
    TritonMM { m: usize, n: usize, k: usize, dtype: DType },
    /// Triton fused elementwise vector kernel.
    TritonVec { elems: usize, dtype: DType },
    /// FlashAttention-2 fused attention.
    FlashAttn { batch: usize, heads: usize, kv_heads: usize, q_len: usize, kv_len: usize, head_dim: usize, dtype: DType, causal: bool },
    /// CUTLASS (xFormers) fused attention.
    CutlassAttn { batch: usize, heads: usize, kv_heads: usize, q_len: usize, kv_len: usize, head_dim: usize, dtype: DType, causal: bool },
}

impl CustomOp {
    pub fn name(&self) -> &'static str {
        match self {
            CustomOp::TritonMM { .. } => "TritonMM",
            CustomOp::TritonVec { .. } => "TritonVec",
            CustomOp::FlashAttn { .. } => "F-Attn",
            CustomOp::CutlassAttn { .. } => "C-Attn",
        }
    }
    pub fn flops(&self) -> f64 {
        match *self {
            CustomOp::TritonMM { m, n, k, .. } => 2.0 * m as f64 * n as f64 * k as f64,
            CustomOp::TritonVec { elems, .. } => elems as f64,
            CustomOp::FlashAttn { batch, heads, q_len, kv_len, head_dim, causal, .. }
            | CustomOp::CutlassAttn { batch, heads, q_len, kv_len, head_dim, causal, .. } => {
                4.0 * batch as f64
                    * heads as f64
                    * attended_pairs(q_len, kv_len, causal)
                    * head_dim as f64
            }
        }
    }

    /// Minimal operand + output traffic in bytes. For attention this is
    /// the KV-cache traffic model: every *query* lane (`batch·heads`)
    /// reads its query block (`q·d`) and writes its output block
    /// (`q·d`); every *KV* lane (`batch·kv_heads`) streams the whole K
    /// and V cache (`2·kv·d`) and appends the new tokens' K/V rows
    /// (`2·q·d`). Under MHA (`kv_heads == heads`) this is the historical
    /// per-lane `(4q + 2kv)·d`; under GQA the dominant cache stream
    /// shrinks by the group factor, which is exactly what makes grouped
    /// decode cheaper on hardware. Prefill (`q == kv`) degenerates to
    /// reading Q/K/V once and writing O plus the full cache; a decode
    /// step (`q == 1`) is dominated by the `2·kv·d` stream — the
    /// memory-bound regime of autoregressive generation.
    pub fn io_bytes(&self) -> f64 {
        match *self {
            CustomOp::TritonMM { m, n, k, dtype } => {
                ((m * k + k * n + m * n) * dtype.bytes()) as f64
            }
            CustomOp::TritonVec { elems, dtype } => (elems * dtype.bytes() * 2) as f64,
            CustomOp::FlashAttn { batch, heads, kv_heads, q_len, kv_len, head_dim, dtype, .. }
            | CustomOp::CutlassAttn { batch, heads, kv_heads, q_len, kv_len, head_dim, dtype, .. } => {
                let q_lanes = batch as f64 * heads as f64;
                let kv_lanes = batch as f64 * kv_heads.min(heads).max(1) as f64;
                let d = head_dim as f64;
                let q_side = q_lanes * 2.0 * q_len as f64 * d;
                let kv_side =
                    kv_lanes * (2.0 * q_len as f64 + 2.0 * kv_len as f64) * d;
                (q_side + kv_side) * dtype.bytes() as f64
            }
        }
    }
}

/// Collective kinds used by tensor parallelism. Ring algorithms on the
/// intra-node link: AllReduce completes row-parallel partial sums,
/// AllGather reassembles column-parallel output slices.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum CommKind {
    AllReduce,
    AllGather,
}

impl CommKind {
    pub fn name(&self) -> &'static str {
        match self {
            CommKind::AllReduce => "AllReduce",
            CommKind::AllGather => "AllGather",
        }
    }
    /// Ring steps for `p` participants: all-reduce is reduce-scatter +
    /// all-gather (2(p−1) hops of `bytes/p`); all-gather is p−1 hops.
    pub fn ring_steps(&self, participants: usize) -> usize {
        let p = participants.max(1);
        match self {
            CommKind::AllReduce => 2 * (p - 1),
            CommKind::AllGather => p - 1,
        }
    }
}

/// A collective over `elems` elements of `dtype` across `participants`
/// ranks. `elems` is the size of the tensor each rank holds: the full
/// partial-sum tensor for AllReduce, one shard for AllGather.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct CommOp {
    pub kind: CommKind,
    pub elems: usize,
    pub dtype: DType,
    pub participants: usize,
}

impl CommOp {
    pub fn all_reduce(elems: usize, dtype: DType, participants: usize) -> CommOp {
        CommOp { kind: CommKind::AllReduce, elems, dtype, participants }
    }
    pub fn all_gather(elems: usize, dtype: DType, participants: usize) -> CommOp {
        CommOp { kind: CommKind::AllGather, elems, dtype, participants }
    }
    /// Payload bytes held per rank.
    pub fn bytes(&self) -> f64 {
        (self.elems * self.dtype.bytes()) as f64
    }
    /// Per-rank link traffic of the ring algorithm: each of the
    /// `ring_steps` hops sends and receives one `bytes/p` chunk, so a
    /// single participant degenerates to zero — a local no-op.
    pub fn io_bytes(&self) -> f64 {
        let p = self.participants.max(1) as f64;
        2.0 * self.kind.ring_steps(self.participants) as f64 * (self.bytes() / p)
    }
}

/// Where a graph runs: the device set and the tensor-parallel degree.
/// `single()` is the implicit placement every pre-placement call site
/// assumed; the stack guarantees it reproduces those predictions
/// bit-for-bit.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct Placement {
    /// One entry per rank; today's placements are symmetric (the same
    /// device model replicated `tp` times).
    pub devices: Vec<String>,
    /// Tensor-parallel degree (== devices.len()).
    pub tp: usize,
}

impl Placement {
    /// The classic single-device placement.
    pub fn single(device: &str) -> Placement {
        Placement { devices: vec![device.to_string()], tp: 1 }
    }
    /// `tp` ranks of the same device model.
    pub fn replicated(device: &str, tp: usize) -> Placement {
        let tp = tp.max(1);
        Placement { devices: vec![device.to_string(); tp], tp }
    }
    pub fn degree(&self) -> usize {
        self.tp
    }
    pub fn is_single(&self) -> bool {
        self.tp <= 1
    }
    /// Internal consistency: at least one rank, degree matches devices.
    pub fn is_valid(&self) -> bool {
        self.tp >= 1 && self.devices.len() == self.tp
    }
}

/// Any simulated operation.
// `Comm` is deliberately the LAST variant: derived `Hash` folds the
// variant index in first, so appending keeps every existing op's
// `stable_hash` (and the noise streams seeded from it) unchanged.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Op {
    Gemm(GemmOp),
    Util(UtilOp),
    Custom(CustomOp),
    Comm(CommOp),
}

impl Op {
    /// Minimal memory traffic of any op (operands + outputs; for attention,
    /// KV-cache streams and appends). The numerator of every
    /// arithmetic-intensity / memory-bound-routing decision.
    pub fn io_bytes(&self) -> f64 {
        match self {
            Op::Gemm(g) => g.io_bytes(),
            Op::Util(u) => u.elems() * u.dtype.bytes() as f64 * u.passes(),
            Op::Custom(c) => c.io_bytes(),
            Op::Comm(c) => c.io_bytes(),
        }
    }

    pub fn dtype(&self) -> DType {
        match self {
            Op::Gemm(g) => g.dtype,
            Op::Util(u) => u.dtype,
            Op::Custom(c) => match *c {
                CustomOp::TritonMM { dtype, .. }
                | CustomOp::TritonVec { dtype, .. }
                | CustomOp::FlashAttn { dtype, .. }
                | CustomOp::CutlassAttn { dtype, .. } => dtype,
            },
            Op::Comm(c) => c.dtype,
        }
    }
    /// Stable 64-bit identity for noise seeding and caches. Hashes the
    /// structured fields directly through the deterministic
    /// [`StableHasher`](crate::util::prng::StableHasher) — no `format!`
    /// allocation on the service hot path.
    pub fn stable_hash(&self) -> u64 {
        crate::util::prng::StableHasher::hash_of(self)
    }
}

/// NCU-style counters exported by the simulator for every executed op —
/// the proxy metrics PM2Lat's utility-layer regression consumes (§III-C).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Counters {
    pub flops: f64,
    pub dram_bytes: f64,
    pub l2_bytes: f64,
    pub int_ops: f64,
    pub mem_insts: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gemm_flops_and_bytes() {
        let g = GemmOp::mm(128, 256, 64, DType::F32);
        assert_eq!(g.flops(), 2.0 * 128.0 * 256.0 * 64.0);
        let bytes = (128 * 64 + 64 * 256 + 128 * 256) as f64 * 4.0;
        assert_eq!(g.io_bytes(), bytes);
    }

    #[test]
    fn bmm_scales_with_batch() {
        let a = GemmOp::bmm(1, 64, 64, 64, DType::Bf16);
        let b = GemmOp::bmm(8, 64, 64, 64, DType::Bf16);
        assert_eq!(b.flops(), 8.0 * a.flops());
        assert_eq!(b.io_bytes(), 8.0 * a.io_bytes());
    }

    #[test]
    fn linear_uses_tn() {
        assert_eq!(GemmOp::linear(1, 1, 1, DType::F32).trans(), Trans::TN);
        assert_eq!(GemmOp::mm(1, 1, 1, DType::F32).trans(), Trans::NN);
        assert_eq!(GemmOp::bmm(1, 1, 1, 1, DType::F32).trans(), Trans::NN);
    }

    #[test]
    fn causal_prefill_attention_evaluates_the_lower_triangle() {
        let mk = |causal| CustomOp::FlashAttn {
            batch: 2, heads: 8, kv_heads: 8, q_len: 512, kv_len: 512, head_dim: 64,
            dtype: DType::Bf16, causal,
        };
        // Exact triangular accounting: q·(q+1)/2 of q² pairs survive the
        // mask — asymptotically half the full square.
        let ratio = mk(true).flops() / mk(false).flops();
        assert_eq!(ratio, (512.0 * 513.0 / 2.0) / (512.0 * 512.0));
        assert!(ratio > 0.5 && ratio < 0.51);
        assert_eq!(attended_pairs(512, 512, true), 512.0 * 513.0 / 2.0);
        assert_eq!(attended_pairs(512, 512, false), 512.0 * 512.0);
    }

    #[test]
    fn decode_step_sees_the_whole_cache_regardless_of_mask() {
        // q = 1: the causal mask removes nothing — decode work is set by
        // the cache length alone.
        for kv in [1usize, 17, 512, 4096] {
            assert_eq!(attended_pairs(1, kv, true), attended_pairs(1, kv, false));
        }
        // Degenerate window (more queries than keys) stays triangular.
        assert_eq!(attended_pairs(8, 4, true), 4.0 * 5.0 / 2.0);
    }

    #[test]
    fn property_decode_attention_flops_and_io_monotone_in_kv_len() {
        // ISSUE decode invariant: at q_len = 1, both FLOPs and memory
        // traffic grow strictly with the KV-cache length, for both fused
        // families, both dtypes, causal or not.
        for dtype in [DType::F32, DType::Bf16] {
            for causal in [false, true] {
                let mut prev = (0.0f64, 0.0f64);
                for kv in [1usize, 2, 64, 129, 1024, 8191] {
                    let fa = CustomOp::FlashAttn {
                        batch: 4, heads: 8, kv_heads: 8, q_len: 1, kv_len: kv, head_dim: 64,
                        dtype, causal,
                    };
                    let ca = CustomOp::CutlassAttn {
                        batch: 4, heads: 8, kv_heads: 8, q_len: 1, kv_len: kv, head_dim: 64,
                        dtype, causal,
                    };
                    assert_eq!(fa.flops(), ca.flops(), "families share the math");
                    assert!(fa.flops() > prev.0, "flops not monotone at kv={kv}");
                    assert!(fa.io_bytes() > prev.1, "io not monotone at kv={kv}");
                    prev = (fa.flops(), fa.io_bytes());
                }
            }
        }
    }

    #[test]
    fn attention_io_bytes_model_kv_cache_traffic() {
        // One decode step: read Q (1·d) + stream the cache (2·kv·d),
        // write O (1·d) + append K/V (2·d) — per lane, times dtype width.
        let op = CustomOp::FlashAttn {
            batch: 2, heads: 4, kv_heads: 4, q_len: 1, kv_len: 100, head_dim: 64,
            dtype: DType::Bf16, causal: true,
        };
        let per_lane = (4.0 * 1.0 + 2.0 * 100.0) * 64.0 * 2.0;
        assert_eq!(op.io_bytes(), 8.0 * per_lane);
        // Unified Op::io_bytes covers every family.
        assert_eq!(Op::Custom(op).io_bytes(), op.io_bytes());
        let g = GemmOp::mm(64, 64, 64, DType::F32);
        assert_eq!(Op::Gemm(g).io_bytes(), g.io_bytes());
        let u = UtilOp::new(UtilKind::Add, 32, 32, DType::F32);
        assert_eq!(Op::Util(u).io_bytes(), u.elems() * 4.0 * u.passes());
    }

    #[test]
    fn gqa_attention_streams_the_grouped_cache_not_the_expanded_one() {
        // ISSUE GQA satellite: kv_heads drives the KV *traffic*, not just
        // the footprint. Same query lanes, grouped cache → the dominant
        // 2·kv·d stream shrinks by the group factor, compute is unchanged.
        let mk = |kv_heads| CustomOp::FlashAttn {
            batch: 2, heads: 16, kv_heads, q_len: 1, kv_len: 4096, head_dim: 64,
            dtype: DType::Bf16, causal: true,
        };
        let mha = mk(16);
        let gqa = mk(4);
        assert_eq!(mha.flops(), gqa.flops(), "grouping never changes the math");
        assert!(gqa.io_bytes() < mha.io_bytes());
        // Exact accounting: q-lanes·2q·d + kv-lanes·(2q + 2kv)·d, ×dtype.
        let d = 64.0 * 2.0;
        let expect = |kvh: f64| (32.0 * 2.0 + 2.0 * kvh * (2.0 + 2.0 * 4096.0)) * d;
        assert_eq!(mha.io_bytes(), expect(16.0));
        assert_eq!(gqa.io_bytes(), expect(4.0));
        // The decode stream dominates, so a 4× group shrinks traffic ~4×.
        let ratio = mha.io_bytes() / gqa.io_bytes();
        assert!(ratio > 3.5 && ratio < 4.1, "ratio={ratio}");
        // CUTLASS shares the traffic model.
        let ca = CustomOp::CutlassAttn {
            batch: 2, heads: 16, kv_heads: 4, q_len: 1, kv_len: 4096, head_dim: 64,
            dtype: DType::Bf16, causal: true,
        };
        assert_eq!(ca.io_bytes(), gqa.io_bytes());
    }

    #[test]
    fn dtype_parse_roundtrip() {
        assert_eq!(DType::parse("fp32"), Some(DType::F32));
        assert_eq!(DType::parse("BF16"), Some(DType::Bf16));
        assert_eq!(DType::parse("int8"), None);
        assert_eq!(DType::F32.bytes(), 4);
        assert_eq!(DType::Bf16.bytes(), 2);
    }

    #[test]
    fn op_hash_stable_and_distinct() {
        let a = Op::Gemm(GemmOp::mm(128, 128, 128, DType::F32));
        let b = Op::Gemm(GemmOp::mm(128, 128, 129, DType::F32));
        assert_eq!(a.stable_hash(), a.stable_hash());
        assert_ne!(a.stable_hash(), b.stable_hash());
        // Variant discriminants, dtypes and APIs all feed the hash.
        let c = Op::Gemm(GemmOp::mm(128, 128, 128, DType::Bf16));
        let d = Op::Gemm(GemmOp::linear(128, 128, 128, DType::F32));
        let e = Op::Util(UtilOp::new(UtilKind::Relu, 128, 128, DType::F32));
        let f = Op::Util(UtilOp::new(UtilKind::Gelu, 128, 128, DType::F32));
        let hashes = [a, c, d, e, f].map(|op| op.stable_hash());
        for (i, x) in hashes.iter().enumerate() {
            for y in &hashes[i + 1..] {
                assert_ne!(x, y);
            }
        }
    }

    #[test]
    fn util_vector_vs_reduction_buckets() {
        assert!(!UtilKind::Relu.is_reduction());
        assert!(UtilKind::Softmax.is_reduction());
        assert_eq!(UtilKind::all().len(), 8);
    }

    #[test]
    fn unsharded_gemm_hash_ignores_the_shard_slot() {
        // The Placement::single() bit-for-bit guarantee starts here: an
        // op with `shard: None` must hash exactly as it did before the
        // field existed (fields in declaration order, nothing appended).
        use crate::util::prng::StableHasher;
        let g = GemmOp::linear(256, 512, 1024, DType::Bf16);
        let mut h = StableHasher::new();
        use std::hash::{Hash, Hasher};
        g.api.hash(&mut h);
        g.batch.hash(&mut h);
        g.m.hash(&mut h);
        g.n.hash(&mut h);
        g.k.hash(&mut h);
        g.dtype.hash(&mut h);
        assert_eq!(StableHasher::hash_of(&g), h.finish());
        // Sharding changes both the dims and the identity.
        let col = g.sharded(ShardDim::Col, 4);
        assert_eq!(col.n, 512 / 4);
        assert_eq!(col.k, 1024);
        let row = g.sharded(ShardDim::Row, 4);
        assert_eq!(row.k, 1024 / 4);
        assert_eq!(row.n, 512);
        assert_ne!(StableHasher::hash_of(&col), StableHasher::hash_of(&g));
        assert_ne!(StableHasher::hash_of(&col), StableHasher::hash_of(&row));
    }

    #[test]
    fn shard_flops_sum_to_the_unsharded_gemm() {
        let g = GemmOp::linear(128, 4096, 1024, DType::Bf16);
        for parts in [2usize, 4, 8] {
            let col: f64 =
                (0..parts).map(|_| g.sharded(ShardDim::Col, parts).flops()).sum();
            let row: f64 =
                (0..parts).map(|_| g.sharded(ShardDim::Row, parts).flops()).sum();
            assert_eq!(col, g.flops());
            assert_eq!(row, g.flops());
        }
    }

    #[test]
    fn comm_ring_traffic_matches_shard_math() {
        let elems = 128 * 4096;
        let ar = CommOp::all_reduce(elems, DType::Bf16, 4);
        let ag = CommOp::all_gather(elems, DType::Bf16, 4);
        assert_eq!(ar.bytes(), (elems * 2) as f64);
        // Ring all-reduce: 2(p−1) hops × send+recv of bytes/p per rank.
        assert_eq!(ar.io_bytes(), 2.0 * 6.0 * ar.bytes() / 4.0);
        // All-gather does half the hops of all-reduce at equal p.
        assert_eq!(ag.io_bytes(), ar.io_bytes() / 2.0);
        // A single participant is a local no-op.
        assert_eq!(CommOp::all_reduce(elems, DType::F32, 1).io_bytes(), 0.0);
        // Comm is a first-class Op with the shared accessors.
        let op = Op::Comm(ar);
        assert_eq!(op.io_bytes(), ar.io_bytes());
        assert_eq!(op.dtype(), DType::Bf16);
        assert_ne!(op.stable_hash(), Op::Comm(ag).stable_hash());
    }

    #[test]
    fn placement_constructors() {
        let single = Placement::single("a100");
        assert!(single.is_single() && single.is_valid());
        assert_eq!(single.degree(), 1);
        let tp4 = Placement::replicated("a100", 4);
        assert!(!tp4.is_single() && tp4.is_valid());
        assert_eq!(tp4.devices.len(), 4);
        // Degenerate degree clamps to a valid single placement.
        assert!(Placement::replicated("t4", 0).is_single());
    }
}
