//! Operation vocabulary shared by the simulator, the predictors and the
//! model zoo: GEMM-family ops, memory-bound utility ops, and the custom
//! fused kernels of paper §IV-C.

use std::fmt;

/// Numeric precision. FP32 executes on the CUDA-core path, BF16 on the
/// tensor-core path — with very different kernel registries (paper §I:
/// ~13 FP32 vs ~100 BF16 algorithm/tile combinations).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum DType {
    F32,
    Bf16,
}

impl DType {
    pub fn bytes(&self) -> usize {
        match self {
            DType::F32 => 4,
            DType::Bf16 => 2,
        }
    }
    pub fn name(&self) -> &'static str {
        match self {
            DType::F32 => "fp32",
            DType::Bf16 => "bf16",
        }
    }
    pub fn parse(s: &str) -> Option<DType> {
        match s.to_ascii_lowercase().as_str() {
            "fp32" | "f32" | "float32" => Some(DType::F32),
            "bf16" | "bfloat16" => Some(DType::Bf16),
            _ => None,
        }
    }
}

impl fmt::Display for DType {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.name())
    }
}

/// Transpose mode of the A operand. PyTorch `Linear` uses TN (first matrix
/// transposed); `torch.matmul` / ONNX / TF use NN — and the paper observed
/// that this changes library/algorithm/tile selection (§III-B).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Trans {
    NN,
    TN,
}

/// Which framework-level API issued the GEMM. Affects the transpose mode
/// and therefore kernel selection; also how the paper buckets its per-layer
/// evaluation (Table II rows: BMM / MM / Linear).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum GemmApi {
    MatMul,
    Linear,
    Bmm,
}

impl GemmApi {
    pub fn trans(&self) -> Trans {
        match self {
            GemmApi::Linear => Trans::TN,
            _ => Trans::NN,
        }
    }
    pub fn name(&self) -> &'static str {
        match self {
            GemmApi::MatMul => "MM",
            GemmApi::Linear => "Linear",
            GemmApi::Bmm => "BMM",
        }
    }
}

/// A dense GEMM: C[b] = A[b] (m×k) · B[b] (k×n) for b in 0..batch.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct GemmOp {
    pub api: GemmApi,
    pub batch: usize,
    pub m: usize,
    pub n: usize,
    pub k: usize,
    pub dtype: DType,
}

impl GemmOp {
    pub fn mm(m: usize, n: usize, k: usize, dtype: DType) -> GemmOp {
        GemmOp { api: GemmApi::MatMul, batch: 1, m, n, k, dtype }
    }
    pub fn linear(m: usize, n: usize, k: usize, dtype: DType) -> GemmOp {
        GemmOp { api: GemmApi::Linear, batch: 1, m, n, k, dtype }
    }
    pub fn bmm(batch: usize, m: usize, n: usize, k: usize, dtype: DType) -> GemmOp {
        GemmOp { api: GemmApi::Bmm, batch, m, n, k, dtype }
    }
    /// 2·b·m·n·k multiply-accumulate FLOPs.
    pub fn flops(&self) -> f64 {
        2.0 * self.batch as f64 * self.m as f64 * self.n as f64 * self.k as f64
    }
    /// Minimal operand + output traffic in bytes (no tiling reuse).
    pub fn io_bytes(&self) -> f64 {
        let d = self.dtype.bytes() as f64;
        self.batch as f64
            * ((self.m * self.k + self.k * self.n) as f64 * d
                + (self.m * self.n) as f64 * d)
    }
    pub fn trans(&self) -> Trans {
        self.api.trans()
    }
}

/// Memory-bound utility layer kinds (paper §III "Utility Layers").
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum UtilKind {
    Relu,
    Gelu,
    Add,
    Mul,
    Dropout,
    Softmax,
    LayerNorm,
    MaxPool,
}

impl UtilKind {
    pub fn name(&self) -> &'static str {
        match self {
            UtilKind::Relu => "ReLU",
            UtilKind::Gelu => "GeLU",
            UtilKind::Add => "Add",
            UtilKind::Mul => "Mul",
            UtilKind::Dropout => "Dropout",
            UtilKind::Softmax => "SoftMax",
            UtilKind::LayerNorm => "LayerNorm",
            UtilKind::MaxPool => "MaxPool",
        }
    }
    /// Elementwise "Vector" ops vs row-reduction ops: the paper's Table II
    /// buckets ReLU/GeLU/Add/Mul/Dropout as "Vector" and reports SoftMax
    /// separately (reductions behave differently).
    pub fn is_reduction(&self) -> bool {
        matches!(self, UtilKind::Softmax | UtilKind::LayerNorm | UtilKind::MaxPool)
    }
    pub fn all() -> &'static [UtilKind] {
        &[
            UtilKind::Relu,
            UtilKind::Gelu,
            UtilKind::Add,
            UtilKind::Mul,
            UtilKind::Dropout,
            UtilKind::Softmax,
            UtilKind::LayerNorm,
            UtilKind::MaxPool,
        ]
    }
}

/// A utility op over a logical (rows × cols) tensor; reductions reduce
/// along cols.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct UtilOp {
    pub kind: UtilKind,
    pub rows: usize,
    pub cols: usize,
    pub dtype: DType,
}

impl UtilOp {
    pub fn new(kind: UtilKind, rows: usize, cols: usize, dtype: DType) -> UtilOp {
        UtilOp { kind, rows, cols, dtype }
    }
    pub fn elems(&self) -> f64 {
        self.rows as f64 * self.cols as f64
    }
    /// (reads + writes) per element for the ground memory model.
    pub fn passes(&self) -> f64 {
        match self.kind {
            UtilKind::Relu | UtilKind::Gelu => 2.0,
            UtilKind::Add | UtilKind::Mul => 3.0,
            UtilKind::Dropout => 2.25, // mask stream is byte-wide
            UtilKind::Softmax => 3.0,  // read, re-read after max, write
            UtilKind::LayerNorm => 2.6,
            UtilKind::MaxPool => 1.25, // 4:1 downsample write
        }
    }
    /// Arithmetic instructions per element (transcendental ops cost more).
    pub fn instrs_per_elem(&self) -> f64 {
        match self.kind {
            UtilKind::Relu => 1.0,
            UtilKind::Gelu => 9.0,
            UtilKind::Add | UtilKind::Mul => 1.0,
            UtilKind::Dropout => 3.0,
            UtilKind::Softmax => 7.0,
            UtilKind::LayerNorm => 6.0,
            UtilKind::MaxPool => 1.5,
        }
    }
}

/// Custom computation-intensive kernels of paper §IV-C / Table VI.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum CustomOp {
    /// Triton matmul: autotuned from Triton's own config space.
    TritonMM { m: usize, n: usize, k: usize, dtype: DType },
    /// Triton fused elementwise vector kernel.
    TritonVec { elems: usize, dtype: DType },
    /// FlashAttention-2 fused attention.
    FlashAttn { batch: usize, heads: usize, seq: usize, head_dim: usize, dtype: DType, causal: bool },
    /// CUTLASS (xFormers) fused attention.
    CutlassAttn { batch: usize, heads: usize, seq: usize, head_dim: usize, dtype: DType, causal: bool },
}

impl CustomOp {
    pub fn name(&self) -> &'static str {
        match self {
            CustomOp::TritonMM { .. } => "TritonMM",
            CustomOp::TritonVec { .. } => "TritonVec",
            CustomOp::FlashAttn { .. } => "F-Attn",
            CustomOp::CutlassAttn { .. } => "C-Attn",
        }
    }
    pub fn flops(&self) -> f64 {
        match *self {
            CustomOp::TritonMM { m, n, k, .. } => 2.0 * m as f64 * n as f64 * k as f64,
            CustomOp::TritonVec { elems, .. } => elems as f64,
            CustomOp::FlashAttn { batch, heads, seq, head_dim, causal, .. }
            | CustomOp::CutlassAttn { batch, heads, seq, head_dim, causal, .. } => {
                let full = 4.0
                    * batch as f64
                    * heads as f64
                    * seq as f64
                    * seq as f64
                    * head_dim as f64;
                if causal {
                    full * 0.5
                } else {
                    full
                }
            }
        }
    }
}

/// Any simulated operation.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Op {
    Gemm(GemmOp),
    Util(UtilOp),
    Custom(CustomOp),
}

impl Op {
    pub fn dtype(&self) -> DType {
        match self {
            Op::Gemm(g) => g.dtype,
            Op::Util(u) => u.dtype,
            Op::Custom(c) => match *c {
                CustomOp::TritonMM { dtype, .. }
                | CustomOp::TritonVec { dtype, .. }
                | CustomOp::FlashAttn { dtype, .. }
                | CustomOp::CutlassAttn { dtype, .. } => dtype,
            },
        }
    }
    /// Stable 64-bit identity for noise seeding and caches. Hashes the
    /// structured fields directly through the deterministic
    /// [`StableHasher`](crate::util::prng::StableHasher) — no `format!`
    /// allocation on the service hot path.
    pub fn stable_hash(&self) -> u64 {
        crate::util::prng::StableHasher::hash_of(self)
    }
}

/// NCU-style counters exported by the simulator for every executed op —
/// the proxy metrics PM2Lat's utility-layer regression consumes (§III-C).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Counters {
    pub flops: f64,
    pub dram_bytes: f64,
    pub l2_bytes: f64,
    pub int_ops: f64,
    pub mem_insts: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gemm_flops_and_bytes() {
        let g = GemmOp::mm(128, 256, 64, DType::F32);
        assert_eq!(g.flops(), 2.0 * 128.0 * 256.0 * 64.0);
        let bytes = (128 * 64 + 64 * 256 + 128 * 256) as f64 * 4.0;
        assert_eq!(g.io_bytes(), bytes);
    }

    #[test]
    fn bmm_scales_with_batch() {
        let a = GemmOp::bmm(1, 64, 64, 64, DType::Bf16);
        let b = GemmOp::bmm(8, 64, 64, 64, DType::Bf16);
        assert_eq!(b.flops(), 8.0 * a.flops());
        assert_eq!(b.io_bytes(), 8.0 * a.io_bytes());
    }

    #[test]
    fn linear_uses_tn() {
        assert_eq!(GemmOp::linear(1, 1, 1, DType::F32).trans(), Trans::TN);
        assert_eq!(GemmOp::mm(1, 1, 1, DType::F32).trans(), Trans::NN);
        assert_eq!(GemmOp::bmm(1, 1, 1, 1, DType::F32).trans(), Trans::NN);
    }

    #[test]
    fn causal_attention_halves_flops() {
        let mk = |causal| CustomOp::FlashAttn {
            batch: 2, heads: 8, seq: 512, head_dim: 64, dtype: DType::Bf16, causal,
        };
        assert_eq!(mk(true).flops() * 2.0, mk(false).flops());
    }

    #[test]
    fn dtype_parse_roundtrip() {
        assert_eq!(DType::parse("fp32"), Some(DType::F32));
        assert_eq!(DType::parse("BF16"), Some(DType::Bf16));
        assert_eq!(DType::parse("int8"), None);
        assert_eq!(DType::F32.bytes(), 4);
        assert_eq!(DType::Bf16.bytes(), 2);
    }

    #[test]
    fn op_hash_stable_and_distinct() {
        let a = Op::Gemm(GemmOp::mm(128, 128, 128, DType::F32));
        let b = Op::Gemm(GemmOp::mm(128, 128, 129, DType::F32));
        assert_eq!(a.stable_hash(), a.stable_hash());
        assert_ne!(a.stable_hash(), b.stable_hash());
        // Variant discriminants, dtypes and APIs all feed the hash.
        let c = Op::Gemm(GemmOp::mm(128, 128, 128, DType::Bf16));
        let d = Op::Gemm(GemmOp::linear(128, 128, 128, DType::F32));
        let e = Op::Util(UtilOp::new(UtilKind::Relu, 128, 128, DType::F32));
        let f = Op::Util(UtilOp::new(UtilKind::Gelu, 128, 128, DType::F32));
        let hashes = [a, c, d, e, f].map(|op| op.stable_hash());
        for (i, x) in hashes.iter().enumerate() {
            for y in &hashes[i + 1..] {
                assert_ne!(x, y);
            }
        }
    }

    #[test]
    fn util_vector_vs_reduction_buckets() {
        assert!(!UtilKind::Relu.is_reduction());
        assert!(UtilKind::Softmax.is_reduction());
        assert_eq!(UtilKind::all().len(), 8);
    }
}
