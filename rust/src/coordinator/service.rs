//! The prediction service: device-keyed routing + request batching over
//! the PJRT-backed predictors.

use std::collections::HashMap;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::gpusim::Gpu;
use crate::neusight::NeuSight;
use crate::ops::{DType, GemmOp, Op};
use crate::pm2lat::batch::BatchPredictor;
use crate::pm2lat::Pm2Lat;
use crate::runtime::Runtime;

use super::metrics::Metrics;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum PredictorKind {
    Pm2Lat,
    /// PM2Lat through the batched Pallas/PJRT artifact (GEMM only; other
    /// ops fall back to the scalar path).
    Pm2LatBatched,
    NeuSight,
}

/// One prediction request.
#[derive(Clone, Debug)]
pub struct Request {
    pub device: String,
    pub op: Op,
    pub kind: PredictorKind,
}

/// The service. Owns the per-device simulated GPUs (standing in for the
/// target-device daemons that answer heuristic/occupancy queries), the
/// fitted PM2Lat state, and the trained NeuSight sessions.
pub struct Coordinator<'rt> {
    runtime: &'rt Runtime,
    gpus: HashMap<String, Gpu>,
    pm2lat: HashMap<String, Pm2Lat>,
    neusight: HashMap<DType, NeuSight<'rt>>,
    batchers: HashMap<String, BatchPredictor<'rt>>,
    pub metrics: Metrics,
}

impl<'rt> Coordinator<'rt> {
    pub fn new(runtime: &'rt Runtime) -> Coordinator<'rt> {
        Coordinator {
            runtime,
            gpus: HashMap::new(),
            pm2lat: HashMap::new(),
            neusight: HashMap::new(),
            batchers: HashMap::new(),
            metrics: Metrics::new(),
        }
    }

    /// Register a device with its fitted PM2Lat state.
    pub fn register_device(&mut self, gpu: Gpu, pm2lat: Pm2Lat) -> Result<()> {
        let name = gpu.spec.name.to_string();
        // Pre-build the batched predictor when an F32 table exists.
        if let Some(table) = pm2lat.gemm_table(DType::F32) {
            if let Ok(bp) = BatchPredictor::new(self.runtime, table, 1024) {
                self.batchers.insert(name.clone(), bp);
            }
        }
        self.pm2lat.insert(name.clone(), pm2lat);
        self.gpus.insert(name, gpu);
        Ok(())
    }

    pub fn register_neusight(&mut self, ns: NeuSight<'rt>) {
        self.neusight.insert(ns.dtype, ns);
    }

    pub fn devices(&self) -> Vec<String> {
        let mut v: Vec<String> = self.gpus.keys().cloned().collect();
        v.sort();
        v
    }

    /// Serve a batch of requests; responses in request order.
    pub fn submit(&self, requests: &[Request]) -> Result<Vec<Option<f64>>> {
        let t0 = Instant::now();
        let mut out = vec![None; requests.len()];
        let mut pjrt_calls = 0usize;
        // Group by (device, kind) to batch PJRT-backed paths.
        let mut groups: HashMap<(String, PredictorKind), Vec<usize>> = HashMap::new();
        for (i, r) in requests.iter().enumerate() {
            groups
                .entry((r.device.clone(), r.kind))
                .or_default()
                .push(i);
        }
        for ((device, kind), idxs) in groups {
            let gpu = self
                .gpus
                .get(&device)
                .ok_or_else(|| anyhow!("unknown device {device}"))?;
            match kind {
                PredictorKind::Pm2Lat => {
                    let pl = self
                        .pm2lat
                        .get(&device)
                        .ok_or_else(|| anyhow!("no pm2lat for {device}"))?;
                    for i in idxs {
                        out[i] = pl.predict(gpu, &requests[i].op);
                    }
                }
                PredictorKind::Pm2LatBatched => {
                    let pl = self.pm2lat.get(&device).ok_or_else(|| anyhow!("no pm2lat"))?;
                    // Split GEMM F32 lanes from everything else.
                    let mut gemm_idx: Vec<usize> = Vec::new();
                    let mut gemm_ops: Vec<GemmOp> = Vec::new();
                    for &i in &idxs {
                        if let Op::Gemm(g) = requests[i].op {
                            if g.dtype == DType::F32 && self.batchers.contains_key(&device) {
                                gemm_idx.push(i);
                                gemm_ops.push(g);
                                continue;
                            }
                        }
                        out[i] = pl.predict(gpu, &requests[i].op);
                    }
                    if !gemm_ops.is_empty() {
                        let bp = &self.batchers[&device];
                        let table = pl.gemm_table(DType::F32).unwrap();
                        for (chunk_i, chunk) in gemm_ops.chunks(bp.batch).enumerate() {
                            let res = bp.predict(gpu, table, chunk)?;
                            pjrt_calls += 1;
                            for (j, v) in res.into_iter().enumerate() {
                                out[gemm_idx[chunk_i * bp.batch + j]] = v;
                            }
                        }
                    }
                }
                PredictorKind::NeuSight => {
                    // Group further by dtype → one batched MLP call each.
                    let mut by_dtype: HashMap<DType, Vec<usize>> = HashMap::new();
                    for &i in &idxs {
                        by_dtype.entry(requests[i].op.dtype()).or_default().push(i);
                    }
                    for (dt, sub) in by_dtype {
                        let Some(ns) = self.neusight.get(&dt) else {
                            self.metrics.record_unsupported(sub.len());
                            continue;
                        };
                        let ops: Vec<Op> = sub.iter().map(|&i| requests[i].op).collect();
                        let res = ns.predict_batch(&gpu.spec, &ops)?;
                        pjrt_calls += ops.len().div_ceil(1024);
                        for (j, v) in res.into_iter().enumerate() {
                            out[sub[j]] = v;
                        }
                    }
                }
            }
        }
        self.metrics.record_batch(requests.len(), pjrt_calls, t0.elapsed());
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::profiler::ProfileSpec;

    fn coordinator(rt: &Runtime) -> Coordinator<'_> {
        let mut c = Coordinator::new(rt);
        for dev in ["a100", "t4"] {
            let mut gpu = Gpu::by_name(dev).unwrap();
            let pl = Pm2Lat::build_dtypes(
                &mut gpu,
                &ProfileSpec::quick(),
                &[DType::F32],
                false,
            );
            gpu.reset();
            c.register_device(gpu, pl).unwrap();
        }
        c
    }

    #[test]
    fn routes_by_device_and_answers_in_order() {
        let rt = Runtime::open_default().expect("make artifacts");
        let c = coordinator(&rt);
        let reqs: Vec<Request> = (0..40)
            .map(|i| Request {
                device: if i % 2 == 0 { "a100" } else { "t4" }.to_string(),
                op: Op::Gemm(GemmOp::mm(2048 + i, 2048, 2048, DType::F32)),
                kind: PredictorKind::Pm2Lat,
            })
            .collect();
        let out = c.submit(&reqs).unwrap();
        assert_eq!(out.len(), 40);
        assert!(out.iter().all(|o| o.is_some()));
        // A100 is faster than T4 on aggregate (tiny ops are launch-bound,
        // so compare sums, not single pairs).
        let a100: f64 = out.iter().step_by(2).map(|o| o.unwrap()).sum();
        let t4: f64 = out.iter().skip(1).step_by(2).map(|o| o.unwrap()).sum();
        assert!(a100 < t4, "a100 {a100} vs t4 {t4}");
        assert_eq!(c.metrics.requests.load(std::sync::atomic::Ordering::Relaxed), 40);
    }

    #[test]
    fn batched_path_matches_scalar_path() {
        let rt = Runtime::open_default().expect("make artifacts");
        let c = coordinator(&rt);
        let mut rng = crate::util::prng::Rng::new(21);
        let ops: Vec<Op> = (0..100)
            .map(|_| {
                Op::Gemm(GemmOp::mm(
                    rng.log_uniform_int(64, 4096) as usize,
                    rng.log_uniform_int(64, 4096) as usize,
                    rng.log_uniform_int(64, 8192) as usize,
                    DType::F32,
                ))
            })
            .collect();
        let scalar: Vec<Request> = ops
            .iter()
            .map(|op| Request { device: "a100".into(), op: *op, kind: PredictorKind::Pm2Lat })
            .collect();
        let batched: Vec<Request> = ops
            .iter()
            .map(|op| Request { device: "a100".into(), op: *op, kind: PredictorKind::Pm2LatBatched })
            .collect();
        let a = c.submit(&scalar).unwrap();
        let b = c.submit(&batched).unwrap();
        for (x, y) in a.iter().zip(&b) {
            let (x, y) = (x.unwrap(), y.unwrap());
            assert!((x - y).abs() / x < 2e-3, "{x} vs {y}");
        }
    }

    #[test]
    fn unknown_device_is_error() {
        let rt = Runtime::open_default().expect("make artifacts");
        let c = coordinator(&rt);
        let req = Request {
            device: "h100".into(),
            op: Op::Gemm(GemmOp::mm(64, 64, 64, DType::F32)),
            kind: PredictorKind::Pm2Lat,
        };
        assert!(c.submit(&[req]).is_err());
    }

    #[test]
    fn unsupported_dtype_lane_is_none() {
        let rt = Runtime::open_default().expect("make artifacts");
        let c = coordinator(&rt);
        let req = Request {
            device: "t4".into(),
            op: Op::Gemm(GemmOp::mm(64, 64, 64, DType::Bf16)),
            kind: PredictorKind::Pm2Lat,
        };
        assert_eq!(c.submit(&[req]).unwrap(), vec![None]);
    }
}
