//! The prediction service: device-keyed routing, a parallel cached scalar
//! path, and request batching over the PJRT-backed predictors.
//!
//! Two layers:
//!
//! * [`Engine`] — the analytical core: interned devices (routing is a
//!   borrowed `&str` lookup, group keys carry the integer id — no
//!   per-request `String` clone on the hot path), the sharded LRU
//!   prediction cache, service metrics, and the multi-threaded scalar
//!   PM2Lat path. The engine is plain `Send + Sync` data; any number of
//!   client threads may call [`Engine::submit_scalar`] concurrently on a
//!   shared reference.
//! * [`Coordinator`] — the engine plus the PJRT-backed accelerators
//!   (batched GEMM artifact, NeuSight MLP). PJRT executions stay on the
//!   calling thread — the FFI client is not known to be thread-safe — but
//!   every analytical lane still fans out through the engine's pool, and
//!   batched-path results are written back into the shared cache.

use std::collections::HashMap;
use std::ops::Deref;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::gpusim::Gpu;
use crate::graph::ModelGraph;
use crate::neusight::NeuSight;
use crate::obs::{keys, MetricsRegistry, TraceCtx, TraceEvent, TraceSink};
use crate::ops::{DType, GemmOp, Op, UtilKind, UtilOp};
use crate::pm2lat::batch::BatchPredictor;
use crate::pm2lat::Pm2Lat;
use crate::runtime::Runtime;
use crate::util::pool;

use super::cache::PredictionCache;
use super::metrics::Metrics;

/// Default bound on cached predictions per service instance.
pub const DEFAULT_CACHE_CAPACITY: usize = 1 << 16;
/// Work items per chunk handed to a scalar-path worker thread.
const SCALAR_CHUNK: usize = 64;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum PredictorKind {
    Pm2Lat,
    /// PM2Lat through the batched Pallas/PJRT artifact (GEMM only; other
    /// ops fall back to the scalar path).
    Pm2LatBatched,
    NeuSight,
}

/// One prediction request.
#[derive(Clone, Debug)]
pub struct Request {
    pub device: String,
    pub op: Op,
    pub kind: PredictorKind,
}

/// A whole-model prediction request: the response is the sequential-kernel
/// sum over `trace` (paper §III), or `None` when any op is unsupported.
#[derive(Clone, Debug)]
pub struct TraceRequest {
    pub device: String,
    pub trace: Vec<Op>,
    pub kind: PredictorKind,
}

/// A whole-model graph prediction request: per-node predictions are
/// aggregated as the `streams`-bounded critical path (1 = the sequential
/// sum of [`TraceRequest`]). Node ops ride the same per-op cache as every
/// other lane, so structurally repeated subgraphs (transformer blocks)
/// hit at subgraph granularity, and GEMM lanes from all nodes of all
/// graphs in one call share batched PJRT launches.
#[derive(Clone, Debug)]
pub struct GraphRequest {
    pub device: String,
    pub graph: ModelGraph,
    pub kind: PredictorKind,
    pub streams: usize,
}

/// A placement-routed graph prediction request: `graph` is one rank's
/// graph (already rewritten by
/// [`crate::graph::TensorParallelPass`] when the placement is sharded —
/// per-rank shards plus the collectives that rejoin them). Every device
/// in the placement prices its rank; ranks run concurrently, so the
/// response is the *slowest* rank's makespan. The collectives inside the
/// rank graph already charge the cross-rank rendezvous at full
/// participant count. With `Placement::single` this is exactly
/// [`GraphRequest`] — same resolved lanes, same cache keys, bit-for-bit.
#[derive(Clone, Debug)]
pub struct PlacedGraphRequest {
    pub placement: crate::ops::Placement,
    pub graph: ModelGraph,
    pub kind: PredictorKind,
    pub streams: usize,
}

/// A whole-generation prediction request: prefill over `prompt_len`
/// tokens, then `gen_len` autoregressive decode steps. The service
/// expands the request into the prefill graph plus per-step decode
/// graphs; all node ops across all steps (and all requests in the batch)
/// join one resolved submission, so batched GEMM lanes amortize across
/// steps and the cache + within-batch dedup absorb the projections that
/// repeat identically from step to step (only the attention ops change
/// with kv_len).
#[derive(Clone, Debug)]
pub struct GenerationRequest {
    pub device: String,
    pub config: crate::models::TransformerConfig,
    pub batch: usize,
    pub spec: crate::models::transformer::GenerationSpec,
    pub kind: PredictorKind,
    pub streams: usize,
}

/// A serving-simulation request: replay `trace` against `config`'s
/// continuous-batching schedule on `device`, pricing every iteration
/// graph through the cached service path (`kind` selects the scalar or
/// batched-PJRT lane). Iterations share ops heavily — decode projections
/// repeat identically across steps — so the LRU and the within-batch
/// dedup amortize most of a long replay.
#[derive(Clone, Debug)]
pub struct ServingRequest {
    pub device: String,
    pub config: crate::models::TransformerConfig,
    pub trace: Vec<crate::serving::RequestSpec>,
    pub sim: crate::serving::ServingSimConfig,
    pub kind: PredictorKind,
    /// Memoize whole-iteration prices keyed by the canonical slot
    /// signature ([`crate::serving::IterationKey`]): a repeated decode
    /// signature skips graph construction and the per-node submission
    /// entirely. Bit-identical to the cold path; costs one LRU per call.
    pub iter_cache: bool,
}

/// A speculative-decoding serving request: replay `trace` under a
/// draft/target pairing ([`crate::spec_decode::SpecConfig`]) — decode
/// slots become `q = k + 1` verification windows, each iteration also
/// prices the draft model's rounds through the same cached service path,
/// and `seed` drives the per-(request, position) acceptance draws.
/// `spec.k == 0` reproduces [`ServingRequest`]'s plain replay bit for
/// bit.
#[derive(Clone, Debug)]
pub struct SpeculativeServingRequest {
    pub device: String,
    pub spec: crate::spec_decode::SpecConfig,
    pub trace: Vec<crate::serving::RequestSpec>,
    pub sim: crate::serving::ServingSimConfig,
    pub kind: PredictorKind,
    /// Iteration-price memo, as in [`ServingRequest::iter_cache`] —
    /// draft and target iterations memoize under separate scopes, both
    /// tagged with the speculation semantics.
    pub iter_cache: bool,
    /// Seed of the stochastic acceptance draws (deterministic replay).
    pub seed: u64,
}

/// A request after device interning: (device id, tensor-parallel degree,
/// kind, op). The degree rides into the cache key so per-placement
/// predictions never alias; single-device paths pass `1`.
type Resolved = (usize, u16, PredictorKind, Op);

/// One registered device: the simulated GPU standing in for the
/// target-device daemon, plus its fitted PM2Lat state.
struct DeviceEntry {
    name: String,
    gpu: Gpu,
    pm2lat: Pm2Lat,
}

/// The analytical service core. See the module docs for the split between
/// `Engine` and [`Coordinator`].
pub struct Engine {
    devices: Vec<DeviceEntry>,
    index: HashMap<String, usize>,
    cache: PredictionCache,
    threads: usize,
    pub metrics: Metrics,
}

impl Engine {
    pub fn new() -> Engine {
        Engine {
            devices: Vec::new(),
            index: HashMap::new(),
            cache: PredictionCache::new(DEFAULT_CACHE_CAPACITY),
            threads: pool::default_threads(),
            metrics: Metrics::new(),
        }
    }

    /// Worker threads for the scalar path (1 = fully serial).
    pub fn set_threads(&mut self, threads: usize) {
        self.threads = threads.max(1);
    }

    /// Replace the cache with one bounded at `capacity` entries
    /// (0 disables caching).
    pub fn set_cache_capacity(&mut self, capacity: usize) {
        self.cache = PredictionCache::new(capacity);
    }

    /// Replace the cache with one built from a full sizing policy
    /// (entry bound ∧ memory budget, optional TTL). Resets eviction
    /// counters along with the entries.
    pub fn set_cache_config(&mut self, cfg: super::cache::CacheConfig) {
        self.cache = PredictionCache::with_config(cfg);
    }

    pub fn with_threads(mut self, threads: usize) -> Engine {
        self.set_threads(threads);
        self
    }

    pub fn with_cache_capacity(mut self, capacity: usize) -> Engine {
        self.set_cache_capacity(capacity);
        self
    }

    pub fn with_cache_config(mut self, cfg: super::cache::CacheConfig) -> Engine {
        self.set_cache_config(cfg);
        self
    }

    /// One-line operational summary: the metrics counters plus cache
    /// residency and eviction breakdown (LRU displacement vs lazy TTL
    /// expiry). The eviction counters live on the cache rather than in
    /// [`Metrics`] so they survive metric resets and stay exact under
    /// concurrent submission.
    pub fn service_summary(&self) -> String {
        format!(
            "{} | cache {}/{} entries, evictions: {} lru, {} ttl",
            self.metrics.summary(),
            self.cache.len(),
            self.cache.capacity(),
            self.cache.lru_evictions(),
            self.cache.ttl_evictions(),
        )
    }

    /// Project the service's live counters into the unified metrics
    /// schema (the `service.*` keys of [`crate::obs::keys`]) — the same
    /// vocabulary `ServingReport::metrics_registry` speaks, so service-
    /// and serving-side numbers land in one diffable namespace. Includes
    /// the cache's residency and eviction breakdown alongside the atomic
    /// counters [`Engine::service_summary`] formats.
    pub fn metrics_registry(&self) -> MetricsRegistry {
        use std::sync::atomic::Ordering::Relaxed;
        let mut reg = MetricsRegistry::new();
        let m = &self.metrics;
        reg.set(keys::SERVICE_REQUESTS, m.requests.load(Relaxed));
        reg.set(keys::SERVICE_BATCHES, m.batches.load(Relaxed));
        reg.set(keys::SERVICE_PJRT_CALLS, m.pjrt_calls.load(Relaxed));
        reg.set(keys::SERVICE_UNSUPPORTED, m.unsupported.load(Relaxed));
        reg.set(keys::SERVICE_BATCHER_ERRORS, m.batcher_errors.load(Relaxed));
        reg.set(keys::SERVICE_CACHE_HITS, m.cache_hits.load(Relaxed));
        reg.set(keys::SERVICE_CACHE_MISSES, m.cache_misses.load(Relaxed));
        reg.set(keys::SERVICE_CACHE_BATCHED_DEDUP, m.batched_dedup.load(Relaxed));
        reg.set(keys::SERVICE_CACHE_SCALAR_DEDUP, m.scalar_dedup.load(Relaxed));
        reg.set(keys::SERVICE_CACHE_ENTRIES, self.cache.len() as u64);
        reg.set(keys::SERVICE_CACHE_CAPACITY, self.cache.capacity() as u64);
        reg.set(keys::SERVICE_CACHE_LRU_EVICTIONS, self.cache.lru_evictions());
        reg.set(keys::SERVICE_CACHE_TTL_EVICTIONS, self.cache.ttl_evictions());
        reg
    }

    /// Register a device with its fitted PM2Lat state. Duplicate
    /// registration is an error (the seed silently overwrote the previous
    /// state). Returns the interned device id.
    pub fn register_device(&mut self, gpu: Gpu, pm2lat: Pm2Lat) -> Result<usize> {
        let name = gpu.spec.name.to_string();
        if self.index.contains_key(&name) {
            return Err(anyhow!("device {name} is already registered"));
        }
        let id = self.devices.len();
        self.devices.push(DeviceEntry { name: name.clone(), gpu, pm2lat });
        self.index.insert(name, id);
        Ok(id)
    }

    /// Interned id for a device name — borrowed lookup, no allocation.
    pub fn device_id(&self, name: &str) -> Option<usize> {
        self.index.get(name).copied()
    }

    pub fn devices(&self) -> Vec<String> {
        let mut v: Vec<String> = self.devices.iter().map(|d| d.name.clone()).collect();
        v.sort();
        v
    }

    pub fn gpu(&self, name: &str) -> Option<&Gpu> {
        self.device_id(name).map(|i| &self.devices[i].gpu)
    }

    pub fn pm2lat(&self, name: &str) -> Option<&Pm2Lat> {
        self.device_id(name).map(|i| &self.devices[i].pm2lat)
    }

    pub fn cache(&self) -> &PredictionCache {
        &self.cache
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Scalar analytical prediction memoized in the shared cache. PM2Lat
    /// is deterministic per device, so hits are bit-identical to fresh
    /// predictions; unsupported ops stay uncached (cheap to re-derive).
    /// With the cache disabled no lookup happens and no hit/miss is
    /// counted — a no-cache service reports a clean zero, not all-miss.
    fn predict_cached(&self, dev: usize, tp: u16, op: &Op) -> Option<f64> {
        if self.cache.enabled() {
            if let Some(v) = self.cache.get(dev as u32, tp, PredictorKind::Pm2Lat, op) {
                self.metrics.record_cache(true);
                return Some(v);
            }
            self.metrics.record_cache(false);
        }
        let entry = &self.devices[dev];
        let v = entry.pm2lat.predict(&entry.gpu, op);
        if let Some(val) = v {
            self.cache.insert(dev as u32, tp, PredictorKind::Pm2Lat, op, val);
        }
        v
    }

    /// Run the scalar path over (device id, op) work items on the thread
    /// pool. Results come back in input order regardless of scheduling,
    /// and every value is deterministic — concurrent runs are
    /// bit-reproducible.
    ///
    /// Identical `(device, op)` items within one batch are predicted once
    /// and fanned out (predictions are deterministic, so the fan-out is
    /// exact). Decode workloads make duplicates the common case: step
    /// `t+1` differs from step `t` only in kv_len, so every projection op
    /// repeats across the steps of one submission. Deduped lanes are
    /// tallied in `metrics.scalar_dedup`, and count as cache hits only
    /// when the cache is enabled *and* the unique lane produced a value
    /// (it is then cached — a non-deduped lookup would have hit);
    /// duplicates of unsupported ops never inflate the hit rate.
    fn run_scalar(&self, work: &[(usize, u16, Op)]) -> Vec<Option<f64>> {
        let mut index: HashMap<(usize, u16, Op), usize> = HashMap::with_capacity(work.len());
        let mut uniq: Vec<(usize, u16, Op)> = Vec::with_capacity(work.len());
        let mut mult: Vec<u64> = Vec::with_capacity(work.len());
        let mut slot: Vec<usize> = Vec::with_capacity(work.len());
        for &(dev, tp, op) in work {
            let next = uniq.len();
            let e = *index.entry((dev, tp, op)).or_insert(next);
            if e == next {
                uniq.push((dev, tp, op));
                mult.push(0);
            }
            mult[e] += 1;
            slot.push(e);
        }
        let dups = work.len() - uniq.len();
        if dups > 0 {
            self.metrics.record_scalar_dedup(dups);
        }
        let res =
            pool::parallel_map_chunked(&uniq, self.threads, SCALAR_CHUNK, |(dev, tp, op)| {
                self.predict_cached(*dev, *tp, op)
            });
        if dups > 0 && self.cache.enabled() {
            // Count dedup-served lanes as cache hits only when the unique
            // lane actually produced (and therefore cached) a value —
            // duplicates of an unsupported op were never cacheable and
            // must not inflate the hit rate.
            let extra: u64 = res
                .iter()
                .zip(&mult)
                .filter(|(r, _)| r.is_some())
                .map(|(_, m)| m - 1)
                .sum();
            if extra > 0 {
                use std::sync::atomic::Ordering;
                self.metrics.cache_hits.fetch_add(extra, Ordering::Relaxed);
            }
        }
        slot.into_iter().map(|i| res[i]).collect()
    }

    /// Serve a batch of requests on the analytical path only; responses in
    /// request order. `Pm2LatBatched` degrades to the scalar pipeline (no
    /// runtime here); `NeuSight` lanes are counted unsupported and answer
    /// `None`. Deliberately *not* named `submit`: [`Coordinator`] derefs
    /// to `Engine`, and shadowing the full-service `submit` with these
    /// degraded semantics would be a silent-misroute trap. Use
    /// [`Coordinator::submit`] for the PJRT-accelerated paths.
    pub fn submit_scalar(&self, requests: &[Request]) -> Result<Vec<Option<f64>>> {
        let t0 = Instant::now();
        // Resolve every device before touching metrics, so a rejected
        // batch (unknown device) leaves no partial trace behind.
        let mut resolved: Vec<usize> = Vec::with_capacity(requests.len());
        for r in requests {
            resolved.push(
                self.device_id(&r.device)
                    .ok_or_else(|| anyhow!("unknown device {}", r.device))?,
            );
        }
        let mut out = vec![None; requests.len()];
        let mut work: Vec<(usize, u16, Op)> = Vec::with_capacity(requests.len());
        let mut slots: Vec<usize> = Vec::with_capacity(requests.len());
        let mut unsupported = 0usize;
        for (i, (r, &dev)) in requests.iter().zip(&resolved).enumerate() {
            match r.kind {
                PredictorKind::NeuSight => unsupported += 1,
                _ => {
                    work.push((dev, 1, r.op));
                    slots.push(i);
                }
            }
        }
        if unsupported > 0 {
            self.metrics.record_unsupported(unsupported);
        }
        for (slot, v) in slots.iter().zip(self.run_scalar(&work)) {
            out[*slot] = v;
        }
        self.metrics.record_batch(requests.len(), 0, t0.elapsed());
        Ok(out)
    }
}

impl Default for Engine {
    fn default() -> Engine {
        Engine::new()
    }
}

/// The full service: engine + PJRT-backed accelerators. Derefs to
/// [`Engine`], so `coordinator.metrics`, `.devices()`, `.cache()` etc.
/// resolve to the shared core.
pub struct Coordinator<'rt> {
    engine: Engine,
    runtime: &'rt Runtime,
    neusight: HashMap<DType, NeuSight<'rt>>,
    /// Indexed by interned device id; `None` = scalar fallback only.
    batchers: Vec<Option<BatchPredictor<'rt>>>,
    /// Observability sink for the serving-simulation APIs
    /// ([`Coordinator::with_trace_sink`]); `None` = tracing off, the
    /// replays take the bit-identical untraced path.
    trace: Option<Arc<dyn TraceSink>>,
}

impl<'rt> Deref for Coordinator<'rt> {
    type Target = Engine;
    fn deref(&self) -> &Engine {
        &self.engine
    }
}

impl<'rt> Coordinator<'rt> {
    pub fn new(runtime: &'rt Runtime) -> Coordinator<'rt> {
        Coordinator {
            engine: Engine::new(),
            runtime,
            neusight: HashMap::new(),
            batchers: Vec::new(),
            trace: None,
        }
    }

    pub fn with_threads(mut self, threads: usize) -> Self {
        self.engine.set_threads(threads);
        self
    }

    /// Install a trace sink on the serving-simulation APIs:
    /// [`Coordinator::simulate_serving`] and
    /// [`Coordinator::submit_speculative`] then emit the full structured
    /// stream — iteration spans, KV events, spec rounds, plus
    /// `coordinator-op` cache probes aggregated per pricing call — into
    /// `sink`. Reports stay bit-for-bit identical with or without a sink
    /// (`tests/obs_trace.rs`); pass the sink to
    /// [`crate::obs::chrome_trace`] afterwards to render the run.
    pub fn with_trace_sink(mut self, sink: Arc<dyn TraceSink>) -> Self {
        self.trace = Some(sink);
        self
    }

    pub fn with_cache_capacity(mut self, capacity: usize) -> Self {
        self.engine.set_cache_capacity(capacity);
        self
    }

    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    /// Mutable engine access for post-build configuration (cache policy,
    /// thread count). Device registration must go through
    /// [`Coordinator::register_device`] so the batcher table stays in
    /// sync.
    pub fn engine_mut(&mut self) -> &mut Engine {
        &mut self.engine
    }

    /// Register a device with its fitted PM2Lat state. Duplicate
    /// registration is an error. A failed batched-predictor build is
    /// surfaced in `metrics.batcher_errors` + stderr (the seed silently
    /// discarded it) and the device degrades to the scalar path.
    pub fn register_device(&mut self, gpu: Gpu, pm2lat: Pm2Lat) -> Result<()> {
        if self.engine.device_id(gpu.spec.name).is_some() {
            return Err(anyhow!("device {} is already registered", gpu.spec.name));
        }
        let batcher = match pm2lat.gemm_table(DType::F32) {
            Some(table) => match BatchPredictor::new(self.runtime, table, 1024) {
                Ok(bp) => Some(bp),
                Err(e) => {
                    self.engine.metrics.record_batcher_error();
                    eprintln!(
                        "coordinator: no batched path for {} ({e}); using scalar fallback",
                        gpu.spec.name
                    );
                    None
                }
            },
            None => None,
        };
        let id = self.engine.register_device(gpu, pm2lat)?;
        debug_assert_eq!(id, self.batchers.len());
        self.batchers.push(batcher);
        Ok(())
    }

    pub fn register_neusight(&mut self, ns: NeuSight<'rt>) {
        self.neusight.insert(ns.dtype, ns);
    }

    /// Intern a device name or reject the whole batch — shared by every
    /// submission API so routing semantics cannot drift between them.
    fn resolve_device(&self, name: &str) -> Result<usize> {
        self.engine
            .device_id(name)
            .ok_or_else(|| anyhow!("unknown device {name}"))
    }

    /// Dispatch one resolved batch and record service metrics — the
    /// shared back half of [`Coordinator::submit`],
    /// [`Coordinator::submit_traces`] and [`Coordinator::submit_graphs`].
    fn dispatch_recorded(&self, t0: Instant, resolved: &[Resolved]) -> Result<Vec<Option<f64>>> {
        let (out, pjrt_calls) = self.submit_resolved(resolved)?;
        self.engine
            .metrics
            .record_batch(resolved.len(), pjrt_calls, t0.elapsed());
        Ok(out)
    }

    /// Serve a batch of requests; responses in request order. Scalar
    /// analytical lanes fan out across the engine's thread pool; PJRT-
    /// backed lanes are grouped per (device, kind) and executed on the
    /// calling thread, with cache misses amortized into batched launches.
    pub fn submit(&self, requests: &[Request]) -> Result<Vec<Option<f64>>> {
        let t0 = Instant::now();
        let mut resolved: Vec<Resolved> = Vec::with_capacity(requests.len());
        for r in requests {
            resolved.push((self.resolve_device(&r.device)?, 1, r.kind, r.op));
        }
        self.dispatch_recorded(t0, &resolved)
    }

    /// Trace-level API: one response per model trace — the sequential-
    /// kernel sum, or `None` when any op is unsupported on the device.
    /// Whole traces ride the same batching/caching/concurrency machinery
    /// as [`Coordinator::submit`]; the device is interned once per trace.
    pub fn submit_traces(&self, traces: &[TraceRequest]) -> Result<Vec<Option<f64>>> {
        let t0 = Instant::now();
        let mut resolved: Vec<Resolved> = Vec::new();
        let mut spans: Vec<(usize, usize)> = Vec::with_capacity(traces.len());
        for t in traces {
            let dev = self.resolve_device(&t.device)?;
            let start = resolved.len();
            resolved.extend(t.trace.iter().map(|op| (dev, 1, t.kind, *op)));
            spans.push((start, resolved.len()));
        }
        let per_op = self.dispatch_recorded(t0, &resolved)?;
        Ok(spans
            .into_iter()
            .map(|(a, b)| {
                let mut total = 0.0;
                for v in &per_op[a..b] {
                    total += (*v)?;
                }
                Some(total)
            })
            .collect())
    }

    /// Graph-level API: one response per model graph — the makespan of
    /// the per-request `streams`-bounded schedule over per-node
    /// predictions, or `None` when any node is unsupported on the device.
    /// All node ops across all graphs join one resolved batch, so GEMM
    /// lanes batch across graph nodes and identical nodes (repeated
    /// transformer blocks) are served from the cache / deduped within the
    /// batch. With `streams = 1` the response is bit-identical to
    /// [`Coordinator::submit_traces`] over the lowered trace. Note that
    /// serving *fused* graphs requires the device's `Pm2Lat` to carry
    /// custom-kernel profiles (`Pm2Lat::build` / `build_dtypes` with
    /// custom collection enabled); otherwise fused-attention nodes answer
    /// `None`.
    pub fn submit_graphs(&self, graphs: &[GraphRequest]) -> Result<Vec<Option<f64>>> {
        let t0 = Instant::now();
        let mut resolved: Vec<Resolved> = Vec::new();
        let mut spans: Vec<(usize, usize)> = Vec::with_capacity(graphs.len());
        for gr in graphs {
            let dev = self.resolve_device(&gr.device)?;
            let start = resolved.len();
            resolved.extend(gr.graph.nodes().iter().map(|n| (dev, 1, gr.kind, n.op)));
            spans.push((start, resolved.len()));
        }
        let per_op = self.dispatch_recorded(t0, &resolved)?;
        Ok(graphs
            .iter()
            .zip(spans)
            .map(|(gr, (a, b))| {
                let mut dur = Vec::with_capacity(b - a);
                for v in &per_op[a..b] {
                    dur.push((*v)?);
                }
                Some(crate::graph::schedule::schedule(&gr.graph, gr.streams, &dur).makespan_s)
            })
            .collect())
    }

    /// Placement-level API: one response per placed graph — the slowest
    /// rank's `streams`-bounded makespan, or `None` when any node is
    /// unsupported on any rank's device. Symmetric placements (the common
    /// case: one device model × tp) collapse to a single priced rank —
    /// duplicate device names dedup before resolution, and identical
    /// lanes for the remaining ranks would dedup inside the batch anyway.
    /// The tensor-parallel degree rides into every cache key, so
    /// per-placement entries partition cleanly and a `tp = 1` placement
    /// is bit-identical to [`Coordinator::submit_graphs`].
    pub fn submit_placed_graphs(
        &self,
        reqs: &[PlacedGraphRequest],
    ) -> Result<Vec<Option<f64>>> {
        let t0 = Instant::now();
        let mut resolved: Vec<Resolved> = Vec::new();
        // Per request: one (device id, span) per *distinct* rank device.
        let mut shapes: Vec<Vec<(usize, usize)>> = Vec::with_capacity(reqs.len());
        for pr in reqs {
            if !pr.placement.is_valid() {
                return Err(anyhow!(
                    "invalid placement: {} devices for tp={}",
                    pr.placement.devices.len(),
                    pr.placement.tp
                ));
            }
            let tp = pr.placement.tp.min(u16::MAX as usize) as u16;
            let mut seen: Vec<usize> = Vec::new();
            let mut spans = Vec::new();
            for name in &pr.placement.devices {
                let dev = self.resolve_device(name)?;
                if seen.contains(&dev) {
                    continue;
                }
                seen.push(dev);
                let start = resolved.len();
                resolved.extend(pr.graph.nodes().iter().map(|n| (dev, tp, pr.kind, n.op)));
                spans.push((start, resolved.len()));
            }
            shapes.push(spans);
        }
        let per_op = self.dispatch_recorded(t0, &resolved)?;
        Ok(reqs
            .iter()
            .zip(shapes)
            .map(|(pr, spans)| {
                let mut worst = 0.0f64;
                for (a, b) in spans {
                    let mut dur = Vec::with_capacity(b - a);
                    for v in &per_op[a..b] {
                        dur.push((*v)?);
                    }
                    let rank =
                        crate::graph::schedule::schedule(&pr.graph, pr.streams, &dur)
                            .makespan_s;
                    worst = worst.max(rank);
                }
                Some(worst)
            })
            .collect())
    }

    /// Generation-level API: one response per generation request — the
    /// prefill makespan plus every decode step's makespan, or `None` when
    /// any op is unsupported on the device. The whole batch (prefill +
    /// all steps of all requests) is one resolved submission: decode step
    /// `t+1` differs from step `t` only in kv_len, so the batched GEMM
    /// lanes, the within-batch dedup (scalar and batched) and the LRU
    /// absorb the per-step projections — the marginal cost of a longer
    /// generation is just its attention ops.
    pub fn submit_generations(
        &self,
        reqs: &[GenerationRequest],
    ) -> Result<Vec<Option<crate::pm2lat::predictor::GenerationPrediction>>> {
        let t0 = Instant::now();
        let mut resolved: Vec<Resolved> = Vec::new();
        // Per request: the graphs (prefill first) and each graph's span.
        let mut shapes: Vec<(Vec<ModelGraph>, Vec<(usize, usize)>, usize)> =
            Vec::with_capacity(reqs.len());
        for r in reqs {
            let dev = self.resolve_device(&r.device)?;
            let (prefill, steps) = r.config.generation_graphs(r.batch, &r.spec);
            let mut graphs = Vec::with_capacity(1 + steps.len());
            graphs.push(prefill);
            graphs.extend(steps);
            let mut spans = Vec::with_capacity(graphs.len());
            for g in &graphs {
                let start = resolved.len();
                resolved.extend(g.nodes().iter().map(|n| (dev, 1, r.kind, n.op)));
                spans.push((start, resolved.len()));
            }
            shapes.push((graphs, spans, r.streams));
        }
        let per_op = self.dispatch_recorded(t0, &resolved)?;
        let mut out = Vec::with_capacity(reqs.len());
        for (graphs, spans, streams) in &shapes {
            let mut makespans = Vec::with_capacity(graphs.len());
            let mut ok = true;
            for (g, &(a, b)) in graphs.iter().zip(spans) {
                let mut dur = Vec::with_capacity(b - a);
                for v in &per_op[a..b] {
                    match v {
                        Some(x) => dur.push(*x),
                        None => {
                            ok = false;
                            break;
                        }
                    }
                }
                if !ok {
                    break;
                }
                makespans
                    .push(crate::graph::schedule::schedule(g, *streams, &dur).makespan_s);
            }
            out.push(ok.then(|| crate::pm2lat::predictor::GenerationPrediction {
                prefill_s: makespans[0],
                step_s: makespans[1..].to_vec(),
            }));
        }
        Ok(out)
    }

    /// Serving-simulation API: replay a request trace through the
    /// discrete-event continuous-batching simulator
    /// ([`crate::serving::simulate`]), pricing every mixed
    /// prefill+decode iteration through this service's cached graph path
    /// — one [`Coordinator::submit_graphs`] batch per iteration, so GEMM
    /// lanes batch across the iteration's nodes and the LRU absorbs the
    /// ops that repeat from iteration to iteration (all of them except
    /// the growing attention windows). With `req.iter_cache` the
    /// iteration-level memo sits in front of all of that: a repeated slot
    /// signature never even builds the graph. Deterministic either way;
    /// `Err` on unknown devices, unsupported models, or impossible
    /// traces.
    pub fn simulate_serving(
        &self,
        req: &ServingRequest,
    ) -> Result<crate::serving::ServingReport> {
        self.resolve_device(&req.device)?; // reject unknown devices early
        let tc = match &self.trace {
            Some(s) => TraceCtx::iter(s.as_ref()),
            None => TraceCtx::off(),
        };
        let mut price = |g: &ModelGraph| -> Option<f64> {
            // Per-call op-cache delta: the engine's hit/miss counters are
            // process-wide atomics, so the probe aggregates what *this*
            // pricing batch contributed (racy only if another thread
            // submits concurrently — then probes blur across callers but
            // totals stay exact).
            let before = tc.on().then(|| {
                use std::sync::atomic::Ordering::Relaxed;
                (self.metrics.cache_hits.load(Relaxed), self.metrics.cache_misses.load(Relaxed))
            });
            let v = self
                .submit_graphs(&[GraphRequest {
                    device: req.device.clone(),
                    graph: g.clone(),
                    kind: req.kind,
                    streams: req.sim.streams,
                }])
                .ok()
                .and_then(|mut r| r.pop())
                .flatten();
            if let Some((h0, m0)) = before {
                use std::sync::atomic::Ordering::Relaxed;
                let dh = self.metrics.cache_hits.load(Relaxed).saturating_sub(h0);
                let dm = self.metrics.cache_misses.load(Relaxed).saturating_sub(m0);
                if dh > 0 {
                    tc.emit(|| TraceEvent::CacheProbe {
                        cache: "coordinator-op",
                        hit: true,
                        count: dh,
                    });
                }
                if dm > 0 {
                    tc.emit(|| TraceEvent::CacheProbe {
                        cache: "coordinator-op",
                        hit: false,
                        count: dm,
                    });
                }
            }
            v
        };
        // The pricing path is a cache-key dimension (scalar vs batched
        // PJRT agree only approximately), exactly as in PredictionCache.
        let lane = match req.kind {
            PredictorKind::Pm2Lat => 1,
            PredictorKind::Pm2LatBatched => 2,
            PredictorKind::NeuSight => 3,
        };
        let scope = crate::serving::IterScope::new(&req.config, &req.device, 1, req.sim.streams)
            .with_lane(lane)
            .with_pager(&req.sim.pager);
        let icache = crate::serving::IterCache::default_sized();
        let hp = crate::serving::simulator::HotPath {
            tp: 1,
            scope,
            cache: req.iter_cache.then_some(&icache),
            passes: None,
        };
        crate::serving::simulate_traced(&req.config, &req.trace, &req.sim, &hp, &tc, &mut price)
            .map_err(|e| anyhow!("serving simulation: {e}"))
    }

    /// Speculative-decoding serving API: [`Coordinator::simulate_serving`]
    /// with a resident draft model — every iteration prices the draft's
    /// decode rounds and the target's verification windows through the
    /// same cached graph path, and the seeded acceptance draws decide how
    /// many tokens each sequence commits per round. Deterministic for a
    /// fixed `req.seed`; with `spec.k == 0` the report is bit-for-bit the
    /// plain [`Coordinator::simulate_serving`] replay.
    pub fn submit_speculative(
        &self,
        req: &SpeculativeServingRequest,
    ) -> Result<crate::serving::ServingReport> {
        self.resolve_device(&req.device)?; // reject unknown devices early
        let tc = match &self.trace {
            Some(s) => TraceCtx::iter(s.as_ref()),
            None => TraceCtx::off(),
        };
        let mut price = |g: &ModelGraph| -> Option<f64> {
            // Same per-call op-cache delta probe as simulate_serving.
            let before = tc.on().then(|| {
                use std::sync::atomic::Ordering::Relaxed;
                (self.metrics.cache_hits.load(Relaxed), self.metrics.cache_misses.load(Relaxed))
            });
            let v = self
                .submit_graphs(&[GraphRequest {
                    device: req.device.clone(),
                    graph: g.clone(),
                    kind: req.kind,
                    streams: req.sim.streams,
                }])
                .ok()
                .and_then(|mut r| r.pop())
                .flatten();
            if let Some((h0, m0)) = before {
                use std::sync::atomic::Ordering::Relaxed;
                let dh = self.metrics.cache_hits.load(Relaxed).saturating_sub(h0);
                let dm = self.metrics.cache_misses.load(Relaxed).saturating_sub(m0);
                if dh > 0 {
                    tc.emit(|| TraceEvent::CacheProbe {
                        cache: "coordinator-op",
                        hit: true,
                        count: dh,
                    });
                }
                if dm > 0 {
                    tc.emit(|| TraceEvent::CacheProbe {
                        cache: "coordinator-op",
                        hit: false,
                        count: dm,
                    });
                }
            }
            v
        };
        let lane = match req.kind {
            PredictorKind::Pm2Lat => 1,
            PredictorKind::Pm2LatBatched => 2,
            PredictorKind::NeuSight => 3,
        };
        let scope =
            crate::serving::IterScope::new(&req.spec.target, &req.device, 1, req.sim.streams)
                .with_lane(lane)
                .with_pager(&req.sim.pager);
        let draft_scope =
            crate::serving::IterScope::new(&req.spec.draft, &req.device, 1, req.sim.streams)
                .with_lane(lane)
                .with_pager(&req.sim.pager);
        let icache = crate::serving::IterCache::default_sized();
        let hp = crate::serving::simulator::HotPath {
            tp: 1,
            scope,
            cache: req.iter_cache.then_some(&icache),
            passes: None,
        };
        crate::serving::simulate_speculative_traced(
            &req.spec,
            &req.trace,
            &req.sim,
            &hp,
            draft_scope,
            req.seed,
            &tc,
            &mut price,
        )
        .map_err(|e| anyhow!("speculative serving simulation: {e}"))
    }

    /// Shared dispatch: scatter per-request answers, return the PJRT
    /// launch count for metrics.
    fn submit_resolved(&self, reqs: &[Resolved]) -> Result<(Vec<Option<f64>>, usize)> {
        let mut out = vec![None; reqs.len()];
        let mut pjrt_calls = 0usize;
        let mut scalar: Vec<(usize, u16, Op)> = Vec::new();
        let mut scalar_slots: Vec<usize> = Vec::new();
        let mut groups: HashMap<(usize, PredictorKind), Vec<usize>> = HashMap::new();
        for (i, &(dev, tp, kind, op)) in reqs.iter().enumerate() {
            match kind {
                PredictorKind::Pm2Lat => {
                    scalar.push((dev, tp, op));
                    scalar_slots.push(i);
                }
                _ => groups.entry((dev, kind)).or_default().push(i),
            }
        }
        // PJRT-backed groups on the calling thread. Non-batchable lanes
        // spill into `scalar` and join the parallel fan-out below.
        for (&(dev, kind), idxs) in &groups {
            match kind {
                PredictorKind::Pm2Lat => unreachable!("scalar kinds are not grouped"),
                PredictorKind::Pm2LatBatched => {
                    pjrt_calls += self.run_batched(
                        dev,
                        idxs,
                        reqs,
                        &mut out,
                        &mut scalar,
                        &mut scalar_slots,
                    )?;
                }
                PredictorKind::NeuSight => {
                    pjrt_calls += self.run_neusight(dev, idxs, reqs, &mut out)?;
                }
            }
        }
        for (slot, v) in scalar_slots.iter().zip(self.engine.run_scalar(&scalar)) {
            out[*slot] = v;
        }
        Ok((out, pjrt_calls))
    }

    /// Batched PM2Lat group for one device: cache hits answer immediately,
    /// misses are deduplicated within the batch (identical `(device, op)`
    /// misses launch once and fan the result out — predictions are
    /// deterministic, so the fan-out is exact), evaluated in as few PJRT
    /// launches as possible and written back; non-GEMM / non-F32 lanes
    /// spill to the scalar fan-out.
    fn run_batched(
        &self,
        dev: usize,
        idxs: &[usize],
        reqs: &[Resolved],
        out: &mut [Option<f64>],
        scalar: &mut Vec<(usize, u16, Op)>,
        scalar_slots: &mut Vec<usize>,
    ) -> Result<usize> {
        use std::collections::hash_map::Entry;
        let entry = &self.engine.devices[dev];
        let bp = self.batchers[dev].as_ref();
        // One entry per *unique* missed (tp, op); each fans out to every
        // requesting slot.
        let mut miss_ops: Vec<GemmOp> = Vec::new();
        let mut miss_tps: Vec<u16> = Vec::new();
        let mut miss_slots: Vec<Vec<usize>> = Vec::new();
        let mut miss_index: HashMap<(u16, GemmOp), usize> = HashMap::new();
        let cache_on = self.engine.cache.enabled();
        for &i in idxs {
            let tp = reqs[i].1;
            let op = &reqs[i].3;
            let gemm = match op {
                // Skinny (decode-regime) GEMMs spill to the scalar path:
                // the PJRT artifact evaluates the tensor-core wave model,
                // and `min(m,n) ≤ 32` shapes must route to the measured
                // memory-bound profiles (gemv ≤ 8, skinny 9..=32) instead.
                Op::Gemm(g)
                    if g.dtype == DType::F32
                        && bp.is_some()
                        && !crate::gpusim::gemm::is_skinny(g) =>
                {
                    *g
                }
                _ => {
                    scalar.push((dev, tp, *op));
                    scalar_slots.push(i);
                    continue;
                }
            };
            if cache_on {
                if let Some(v) =
                    self.engine.cache.get(dev as u32, tp, PredictorKind::Pm2LatBatched, op)
                {
                    self.engine.metrics.record_cache(true);
                    out[i] = Some(v);
                    continue;
                }
                self.engine.metrics.record_cache(false);
            }
            match miss_index.entry((tp, gemm)) {
                Entry::Occupied(e) => {
                    miss_slots[*e.get()].push(i);
                    self.engine.metrics.record_dedup(1);
                }
                Entry::Vacant(e) => {
                    e.insert(miss_ops.len());
                    miss_slots.push(vec![i]);
                    miss_ops.push(gemm);
                    miss_tps.push(tp);
                }
            }
        }
        if miss_ops.is_empty() {
            return Ok(0);
        }
        let bp = bp.expect("batchable lanes imply a batcher");
        let table = entry
            .pm2lat
            .gemm_table(DType::F32)
            .expect("batcher implies an F32 table");
        let res = bp.predict_all(&entry.gpu, table, &miss_ops)?;
        for (((slots, g), &tp), v) in
            miss_slots.iter().zip(&miss_ops).zip(&miss_tps).zip(res)
        {
            if let Some(val) = v {
                self.engine.cache.insert(
                    dev as u32,
                    tp,
                    PredictorKind::Pm2LatBatched,
                    &Op::Gemm(*g),
                    val,
                );
            }
            for &slot in slots {
                out[slot] = v;
            }
        }
        Ok(miss_ops.len().div_ceil(bp.batch))
    }

    /// NeuSight group for one device: split by dtype, one batched MLP
    /// launch per sub-group. Learned-model outputs are not memoized.
    fn run_neusight(
        &self,
        dev: usize,
        idxs: &[usize],
        reqs: &[Resolved],
        out: &mut [Option<f64>],
    ) -> Result<usize> {
        let entry = &self.engine.devices[dev];
        let mut by_dtype: HashMap<DType, Vec<usize>> = HashMap::new();
        for &i in idxs {
            by_dtype.entry(reqs[i].3.dtype()).or_default().push(i);
        }
        let mut pjrt_calls = 0usize;
        for (dt, sub) in by_dtype {
            let Some(ns) = self.neusight.get(&dt) else {
                self.engine.metrics.record_unsupported(sub.len());
                continue;
            };
            let ops: Vec<Op> = sub.iter().map(|&i| reqs[i].3).collect();
            let res = ns.predict_batch(&entry.gpu.spec, &ops)?;
            pjrt_calls += ops.len().div_ceil(1024);
            for (j, v) in res.into_iter().enumerate() {
                out[sub[j]] = v;
            }
        }
        Ok(pjrt_calls)
    }
}

/// Deterministic mixed workload in an arbitrary dtype: `unique` distinct
/// ops (≈70% GEMM, 30% utility) spread over `devices`, then sampled with
/// repetition to `n` requests — a NAS-like distribution where hot
/// configurations recur and the cache can earn its keep. The RNG stream
/// is dtype-independent, so the BF16 workload mirrors the F32 one shape
/// for shape.
pub fn mixed_workload_dtyped(
    devices: &[String],
    n: usize,
    unique: usize,
    seed: u64,
    dtype: DType,
) -> Vec<Request> {
    let mut rng = crate::util::prng::Rng::new(seed);
    let unique = unique.max(1);
    let ops: Vec<Op> = (0..unique)
        .map(|_| {
            if rng.uniform() < 0.7 {
                Op::Gemm(GemmOp::mm(
                    rng.log_uniform_int(64, 4096) as usize,
                    rng.log_uniform_int(64, 4096) as usize,
                    rng.log_uniform_int(64, 8192) as usize,
                    dtype,
                ))
            } else {
                Op::Util(UtilOp::new(
                    *rng.choice(UtilKind::all()),
                    rng.log_uniform_int(64, 8192) as usize,
                    rng.log_uniform_int(64, 8192) as usize,
                    dtype,
                ))
            }
        })
        .collect();
    (0..n)
        .map(|_| Request {
            device: rng.choice(devices).clone(),
            op: *rng.choice(&ops),
            kind: PredictorKind::Pm2Lat,
        })
        .collect()
}

/// The historical F32 mixed workload (same RNG stream as ever).
pub fn mixed_workload(devices: &[String], n: usize, unique: usize, seed: u64) -> Vec<Request> {
    mixed_workload_dtyped(devices, n, unique, seed, DType::F32)
}

/// Build a service over named devices with PM2Lat fitted for the given
/// dtypes (quick profile fit — serving benchmarks measure dispatch
/// overhead, not fit quality). Devices that lack a dtype simply skip that
/// table and answer `None` for its lanes. Shared by `pm2lat serve-bench`
/// and `benches/serve_throughput.rs` so the two A/B harnesses cannot
/// drift apart.
pub fn build_service<'rt>(
    runtime: &'rt Runtime,
    threads: usize,
    cache_capacity: usize,
    devices: &[&str],
    dtypes: &[DType],
) -> Result<Coordinator<'rt>> {
    let mut c = Coordinator::new(runtime)
        .with_threads(threads)
        .with_cache_capacity(cache_capacity);
    for dev in devices {
        let mut gpu =
            Gpu::by_name(dev).ok_or_else(|| anyhow!("unknown device {dev}"))?;
        let pl = crate::pm2lat::Pm2Lat::build_dtypes(
            &mut gpu,
            &crate::profiler::ProfileSpec::quick(),
            dtypes,
            false,
        );
        gpu.reset();
        c.register_device(gpu, pl)?;
    }
    Ok(c)
}

/// Build an F32-only service over named devices.
pub fn build_f32_service<'rt>(
    runtime: &'rt Runtime,
    threads: usize,
    cache_capacity: usize,
    devices: &[&str],
) -> Result<Coordinator<'rt>> {
    build_service(runtime, threads, cache_capacity, devices, &[DType::F32])
}

/// Train a small NeuSight baseline over every simulated device — enough
/// signal for serving benchmarks (which measure dispatch overhead, not
/// fit quality). Deterministic for a fixed dtype.
pub fn quick_neusight(runtime: &Runtime, dtype: DType) -> Result<NeuSight<'_>> {
    let mut gpus: Vec<Gpu> =
        crate::gpusim::all_devices().into_iter().map(Gpu::new).collect();
    NeuSight::train_on(
        runtime,
        &mut gpus,
        dtype,
        crate::neusight::TrainConfig { per_device: 40, epochs: 10, lr: 3e-3, seed: 4 },
        &crate::profiler::ProfileSpec::quick(),
    )
}

/// Submit `requests` in `chunk`-sized service batches, timing the whole
/// run. Returns (elapsed seconds, answers in request order).
pub fn timed_submit(
    coord: &Coordinator<'_>,
    requests: &[Request],
    chunk: usize,
) -> Result<(f64, Vec<Option<f64>>)> {
    let chunk = chunk.max(1);
    let t0 = Instant::now();
    let mut out = Vec::with_capacity(requests.len());
    for batch in requests.chunks(chunk) {
        out.extend(coord.submit(batch)?);
    }
    Ok((t0.elapsed().as_secs_f64(), out))
}

/// Re-kind a workload onto another predictor lane.
pub fn to_kind(requests: &[Request], kind: PredictorKind) -> Vec<Request> {
    requests
        .iter()
        .map(|r| Request { device: r.device.clone(), op: r.op, kind })
        .collect()
}

/// Re-kind a workload onto the batched PJRT path.
pub fn to_batched(requests: &[Request]) -> Vec<Request> {
    to_kind(requests, PredictorKind::Pm2LatBatched)
}

/// One serial-baseline vs cold-cache vs warm-cache A/B measurement.
pub struct AbReport {
    pub serial_s: f64,
    pub cold_s: f64,
    pub warm_s: f64,
    /// Cache hit rate during the cold / warm cached passes only
    /// (computed from counter deltas, not the cumulative metric).
    pub cold_hit_rate: f64,
    pub warm_hit_rate: f64,
    /// All three answer vectors bit-identical.
    pub identical: bool,
}

/// Run the canonical service A/B: `requests` through `baseline` once,
/// then twice through `cached` (cold, then warm). Shared by
/// `pm2lat serve-bench` and `benches/serve_throughput.rs` so the two
/// harnesses measure exactly the same protocol.
pub fn ab_phases(
    baseline: &Coordinator<'_>,
    cached: &Coordinator<'_>,
    requests: &[Request],
    chunk: usize,
) -> Result<AbReport> {
    use std::sync::atomic::Ordering;
    let snap = || {
        (
            cached.metrics.cache_hits.load(Ordering::Relaxed),
            cached.metrics.cache_misses.load(Ordering::Relaxed),
        )
    };
    let rate = |before: (u64, u64), after: (u64, u64)| {
        let (h, m) = (after.0 - before.0, after.1 - before.1);
        if h + m == 0 {
            0.0
        } else {
            h as f64 / (h + m) as f64
        }
    };
    let (serial_s, o0) = timed_submit(baseline, requests, chunk)?;
    let s0 = snap();
    let (cold_s, o1) = timed_submit(cached, requests, chunk)?;
    let s1 = snap();
    let (warm_s, o2) = timed_submit(cached, requests, chunk)?;
    let s2 = snap();
    Ok(AbReport {
        serial_s,
        cold_s,
        warm_s,
        cold_hit_rate: rate(s0, s1),
        warm_hit_rate: rate(s1, s2),
        identical: o0 == o1 && o1 == o2,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::profiler::ProfileSpec;
    use std::sync::atomic::Ordering;

    fn fitted(dev: &str) -> (Gpu, Pm2Lat) {
        let mut gpu = Gpu::by_name(dev).unwrap();
        let pl = Pm2Lat::build_dtypes(&mut gpu, &ProfileSpec::quick(), &[DType::F32], false);
        gpu.reset();
        (gpu, pl)
    }

    fn coordinator(rt: &Runtime) -> Coordinator<'_> {
        let mut c = Coordinator::new(rt);
        for dev in ["a100", "t4"] {
            let (gpu, pl) = fitted(dev);
            c.register_device(gpu, pl).unwrap();
        }
        c
    }

    fn engine() -> Engine {
        let mut e = Engine::new();
        for dev in ["a100", "t4"] {
            let (gpu, pl) = fitted(dev);
            e.register_device(gpu, pl).unwrap();
        }
        e
    }

    fn gemm_requests(n: usize, seed: u64) -> Vec<Request> {
        let mut rng = crate::util::prng::Rng::new(seed);
        (0..n)
            .map(|i| Request {
                device: if i % 2 == 0 { "a100" } else { "t4" }.to_string(),
                op: Op::Gemm(GemmOp::mm(
                    rng.log_uniform_int(64, 4096) as usize,
                    rng.log_uniform_int(64, 4096) as usize,
                    rng.log_uniform_int(64, 8192) as usize,
                    DType::F32,
                )),
                kind: PredictorKind::Pm2Lat,
            })
            .collect()
    }

    #[test]
    fn engine_is_send_and_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<Engine>();
    }

    #[test]
    fn routes_by_device_and_answers_in_order() {
        let rt = Runtime::open_default().expect("make artifacts");
        let c = coordinator(&rt);
        let reqs: Vec<Request> = (0..40)
            .map(|i| Request {
                device: if i % 2 == 0 { "a100" } else { "t4" }.to_string(),
                op: Op::Gemm(GemmOp::mm(2048 + i, 2048, 2048, DType::F32)),
                kind: PredictorKind::Pm2Lat,
            })
            .collect();
        let out = c.submit(&reqs).unwrap();
        assert_eq!(out.len(), 40);
        assert!(out.iter().all(|o| o.is_some()));
        // A100 is faster than T4 on aggregate (tiny ops are launch-bound,
        // so compare sums, not single pairs).
        let a100: f64 = out.iter().step_by(2).map(|o| o.unwrap()).sum();
        let t4: f64 = out.iter().skip(1).step_by(2).map(|o| o.unwrap()).sum();
        assert!(a100 < t4, "a100 {a100} vs t4 {t4}");
        assert_eq!(c.metrics.requests.load(Ordering::Relaxed), 40);
    }

    #[test]
    fn batched_path_matches_scalar_path() {
        let rt = Runtime::open_default().expect("make artifacts");
        let c = coordinator(&rt);
        let mut rng = crate::util::prng::Rng::new(21);
        let ops: Vec<Op> = (0..100)
            .map(|_| {
                Op::Gemm(GemmOp::mm(
                    rng.log_uniform_int(64, 4096) as usize,
                    rng.log_uniform_int(64, 4096) as usize,
                    rng.log_uniform_int(64, 8192) as usize,
                    DType::F32,
                ))
            })
            .collect();
        let scalar: Vec<Request> = ops
            .iter()
            .map(|op| Request { device: "a100".into(), op: *op, kind: PredictorKind::Pm2Lat })
            .collect();
        let batched: Vec<Request> = ops
            .iter()
            .map(|op| Request { device: "a100".into(), op: *op, kind: PredictorKind::Pm2LatBatched })
            .collect();
        let a = c.submit(&scalar).unwrap();
        let b = c.submit(&batched).unwrap();
        for (x, y) in a.iter().zip(&b) {
            let (x, y) = (x.unwrap(), y.unwrap());
            assert!((x - y).abs() / x < 2e-3, "{x} vs {y}");
        }
    }

    #[test]
    fn unknown_device_is_error() {
        let rt = Runtime::open_default().expect("make artifacts");
        let c = coordinator(&rt);
        let req = Request {
            device: "h100".into(),
            op: Op::Gemm(GemmOp::mm(64, 64, 64, DType::F32)),
            kind: PredictorKind::Pm2Lat,
        };
        assert!(c.submit(&[req]).is_err());
    }

    #[test]
    fn unsupported_dtype_lane_is_none() {
        let rt = Runtime::open_default().expect("make artifacts");
        let c = coordinator(&rt);
        let req = Request {
            device: "t4".into(),
            op: Op::Gemm(GemmOp::mm(64, 64, 64, DType::Bf16)),
            kind: PredictorKind::Pm2Lat,
        };
        assert_eq!(c.submit(&[req]).unwrap(), vec![None]);
    }

    #[test]
    fn duplicate_registration_rejected() {
        let rt = Runtime::open_default().expect("make artifacts");
        let mut c = coordinator(&rt);
        let (gpu, pl) = fitted("t4");
        assert!(c.register_device(gpu, pl).is_err());
        assert_eq!(c.devices().len(), 2, "failed re-registration must not clobber");
    }

    #[test]
    fn engine_duplicate_registration_rejected() {
        let mut e = engine();
        let (gpu, pl) = fitted("a100");
        assert!(e.register_device(gpu, pl).is_err());
    }

    #[test]
    fn cache_hits_bit_identical_and_counted() {
        let e = engine();
        let reqs = gemm_requests(200, 31);
        let fresh = e.submit_scalar(&reqs).unwrap();
        assert!(fresh.iter().all(|o| o.is_some()));
        let hits_before = e.metrics.cache_hits.load(Ordering::Relaxed);
        let cached = e.submit_scalar(&reqs).unwrap();
        assert_eq!(fresh, cached, "cache hits must be bit-identical");
        let hits_after = e.metrics.cache_hits.load(Ordering::Relaxed);
        assert_eq!(hits_after - hits_before, reqs.len() as u64, "second pass all-hit");
    }

    #[test]
    fn parallel_and_cached_match_serial_uncached() {
        let fast = engine(); // default threads + cache
        let slow = engine().with_threads(1).with_cache_capacity(0);
        let reqs = gemm_requests(300, 77);
        let a = fast.submit_scalar(&reqs).unwrap();
        let b = slow.submit_scalar(&reqs).unwrap();
        assert_eq!(a, b, "parallelism and caching must not change results");
    }

    #[test]
    fn engine_serves_concurrent_clients() {
        let e = engine().with_threads(2);
        let reqs = gemm_requests(40, 5);
        let expected = e.submit_scalar(&reqs).unwrap();
        std::thread::scope(|s| {
            for _ in 0..4 {
                s.spawn(|| {
                    for _ in 0..5 {
                        assert_eq!(e.submit_scalar(&reqs).unwrap(), expected);
                    }
                });
            }
        });
        // 1 warm-up + 4 clients × 5 batches, every request accounted for.
        assert_eq!(e.metrics.requests.load(Ordering::Relaxed), 40 * 21);
        assert!(e.metrics.cache_hit_rate() > 0.9);
    }

    #[test]
    fn cache_capacity_bounds_entries() {
        let e = engine().with_cache_capacity(64);
        let reqs = gemm_requests(2000, 13);
        e.submit_scalar(&reqs).unwrap();
        assert!(e.cache().len() <= e.cache().capacity());
        assert!(e.cache().capacity() >= 64);
    }

    #[test]
    fn neusight_kind_unsupported_on_bare_engine() {
        let e = engine();
        let req = Request {
            device: "a100".into(),
            op: Op::Gemm(GemmOp::mm(64, 64, 64, DType::F32)),
            kind: PredictorKind::NeuSight,
        };
        assert_eq!(e.submit_scalar(std::slice::from_ref(&req)).unwrap(), vec![None]);
        assert_eq!(e.metrics.unsupported.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn trace_api_matches_scalar_trace_sum() {
        let rt = Runtime::open_default().expect("make artifacts");
        let c = coordinator(&rt);
        let trace: Vec<Op> = (0..8)
            .map(|i| Op::Gemm(GemmOp::mm(256 + 64 * i, 512, 512, DType::F32)))
            .collect();
        let direct: f64 = {
            let gpu = c.gpu("a100").unwrap();
            let pl = c.pm2lat("a100").unwrap();
            pl.predict_trace(gpu, &trace).unwrap()
        };
        let req = TraceRequest {
            device: "a100".into(),
            trace: trace.clone(),
            kind: PredictorKind::Pm2Lat,
        };
        let via = c.submit_traces(std::slice::from_ref(&req)).unwrap();
        assert_eq!(via.len(), 1);
        assert_eq!(via[0], Some(direct), "same ops, same order, same sum");
        // A trace with an unsupported op answers None, not an error.
        let bad = TraceRequest {
            device: "t4".into(),
            trace: vec![
                Op::Gemm(GemmOp::mm(128, 128, 128, DType::F32)),
                Op::Gemm(GemmOp::mm(128, 128, 128, DType::Bf16)),
            ],
            kind: PredictorKind::Pm2Lat,
        };
        assert_eq!(c.submit_traces(std::slice::from_ref(&bad)).unwrap(), vec![None]);
    }

    #[test]
    fn graph_api_matches_trace_api_with_one_stream() {
        let rt = Runtime::open_default().expect("make artifacts");
        let c = coordinator(&rt);
        let trace: Vec<Op> = (0..8)
            .map(|i| Op::Gemm(GemmOp::mm(256 + 64 * i, 512, 512, DType::F32)))
            .collect();
        for kind in [PredictorKind::Pm2Lat, PredictorKind::Pm2LatBatched] {
            let via_trace = c
                .submit_traces(&[TraceRequest {
                    device: "a100".into(),
                    trace: trace.clone(),
                    kind,
                }])
                .unwrap();
            let via_graph = c
                .submit_graphs(&[GraphRequest {
                    device: "a100".into(),
                    graph: ModelGraph::from_trace(&trace),
                    kind,
                    streams: 1,
                }])
                .unwrap();
            assert_eq!(via_graph, via_trace, "kind {kind:?}: same ops, same sum");
        }
        // Unknown devices are errors; unsupported lanes answer None.
        let bad = GraphRequest {
            device: "h100".into(),
            graph: ModelGraph::from_trace(&trace),
            kind: PredictorKind::Pm2Lat,
            streams: 1,
        };
        assert!(c.submit_graphs(std::slice::from_ref(&bad)).is_err());
        let none = GraphRequest {
            device: "t4".into(),
            graph: ModelGraph::from_trace(&[Op::Gemm(GemmOp::mm(64, 64, 64, DType::Bf16))]),
            kind: PredictorKind::Pm2Lat,
            streams: 1,
        };
        assert_eq!(c.submit_graphs(std::slice::from_ref(&none)).unwrap(), vec![None]);
    }

    #[test]
    fn placed_single_is_bit_identical_to_submit_graphs() {
        let rt = Runtime::open_default().expect("make artifacts");
        let c = coordinator(&rt);
        let cfg = crate::models::zoo::gpt2_large();
        let g = cfg.graph(1, 64);
        let plain = c
            .submit_graphs(&[GraphRequest {
                device: "a100".into(),
                graph: g.clone(),
                kind: PredictorKind::Pm2Lat,
                streams: 2,
            }])
            .unwrap();
        let placed = c
            .submit_placed_graphs(&[PlacedGraphRequest {
                placement: crate::ops::Placement::single("a100"),
                graph: g,
                kind: PredictorKind::Pm2Lat,
                streams: 2,
            }])
            .unwrap();
        assert_eq!(placed, plain, "single placement is the plain graph path");
    }

    #[test]
    fn placed_tp2_prices_collectives_and_beats_tp1_per_rank() {
        use crate::graph::{Pass, PassCtx, TensorParallelPass};
        let rt = Runtime::open_default().expect("make artifacts");
        let c = coordinator(&rt);
        let cfg = crate::models::zoo::gpt2_large();
        let g1 = cfg.graph(1, 256);
        let mut g2 = g1.clone();
        let sharded = TensorParallelPass { tp: 2 }.run(&mut g2, &PassCtx::structural());
        assert!(sharded > 0, "gpt2 must shard");
        assert!(
            g2.nodes().iter().any(|n| matches!(n.op, Op::Comm(_))),
            "sharding inserts collectives"
        );
        let out = c
            .submit_placed_graphs(&[
                PlacedGraphRequest {
                    placement: crate::ops::Placement::single("a100"),
                    graph: g1,
                    kind: PredictorKind::Pm2Lat,
                    streams: 1,
                },
                PlacedGraphRequest {
                    placement: crate::ops::Placement::replicated("a100", 2),
                    graph: g2.clone(),
                    kind: PredictorKind::Pm2Lat,
                    streams: 1,
                },
            ])
            .unwrap();
        let (tp1, tp2) = (out[0].unwrap(), out[1].unwrap());
        // The rank graph's collectives were priced (comm profile present),
        // and the whole placed path agrees with the direct predictor.
        let direct = {
            let gpu = c.gpu("a100").unwrap();
            let pl = c.pm2lat("a100").unwrap();
            pl.predict_graph(gpu, &g2, 1).unwrap()
        };
        assert_eq!(tp2, direct, "placed rank == direct rank prediction");
        // Sharding helps but sub-linearly: collectives + unsharded rows
        // keep the rank above half the single-device latency.
        assert!(tp2 < tp1, "tp=2 rank {tp2} vs tp=1 {tp1}");
        assert!(tp2 > tp1 / 2.0, "scaling must be sub-linear");
        // Unknown rank devices reject the batch; malformed placements too.
        let bad = PlacedGraphRequest {
            placement: crate::ops::Placement::replicated("h100", 2),
            graph: g2.clone(),
            kind: PredictorKind::Pm2Lat,
            streams: 1,
        };
        assert!(c.submit_placed_graphs(std::slice::from_ref(&bad)).is_err());
        let malformed = PlacedGraphRequest {
            placement: crate::ops::Placement {
                devices: vec!["a100".into()],
                tp: 2,
            },
            graph: g2,
            kind: PredictorKind::Pm2Lat,
            streams: 1,
        };
        assert!(c.submit_placed_graphs(std::slice::from_ref(&malformed)).is_err());
    }

    #[test]
    fn batched_dedup_launches_identical_misses_once() {
        let rt = Runtime::open_default().expect("make artifacts");
        let c = coordinator(&rt);
        let op = Op::Gemm(GemmOp::mm(1024, 1024, 1024, DType::F32));
        let reqs: Vec<Request> = (0..50)
            .map(|_| Request {
                device: "a100".into(),
                op,
                kind: PredictorKind::Pm2LatBatched,
            })
            .collect();
        let out = c.submit(&reqs).unwrap();
        let v = out[0].expect("supported op");
        assert!(out.iter().all(|o| *o == Some(v)), "fan-out is exact");
        assert_eq!(c.metrics.batched_dedup.load(Ordering::Relaxed), 49);
        assert_eq!(c.metrics.pjrt_calls.load(Ordering::Relaxed), 1, "one launch");
        // Dedup without a cache is still exact (pure determinism).
        let mut nc = Coordinator::new(&rt).with_cache_capacity(0);
        let (gpu, pl) = fitted("a100");
        nc.register_device(gpu, pl).unwrap();
        let out2 = nc.submit(&reqs).unwrap();
        assert_eq!(out, out2);
    }

    #[test]
    fn submit_graphs_round_trips_model_blocks_through_the_cache() {
        let rt = Runtime::open_default().expect("make artifacts");
        let c = coordinator(&rt);
        let cfg = crate::models::zoo::gpt2_large();
        let req = GraphRequest {
            device: "a100".into(),
            graph: cfg.graph(1, 64),
            kind: PredictorKind::Pm2LatBatched,
            streams: 1,
        };
        let first = c.submit_graphs(std::slice::from_ref(&req)).unwrap();
        assert!(first[0].is_some());
        // 36 structurally identical blocks in one call: the batched path
        // dedups repeated GEMM nodes within the batch.
        assert!(
            c.metrics.batched_dedup.load(Ordering::Relaxed) > 100,
            "repeated blocks must dedup ({} lanes saved)",
            c.metrics.batched_dedup.load(Ordering::Relaxed)
        );
        let hits_before = c.metrics.cache_hits.load(Ordering::Relaxed);
        let second = c.submit_graphs(std::slice::from_ref(&req)).unwrap();
        assert_eq!(first, second, "cache hits are bit-identical");
        let gemm_nodes = req
            .graph
            .nodes()
            .iter()
            .filter(|n| matches!(n.op, Op::Gemm(_)))
            .count();
        let hits = c.metrics.cache_hits.load(Ordering::Relaxed) - hits_before;
        assert!(
            hits >= gemm_nodes as u64,
            "repeated blocks must hit the cache ({hits} hits, {gemm_nodes} GEMM nodes)"
        );
    }

    #[test]
    fn fused_graph_round_trips_through_submit_graphs_with_cache_hits() {
        use crate::graph::{AttentionFusion, Pass, PassCtx};
        let rt = Runtime::open_default().expect("make artifacts");
        let mut c = Coordinator::new(&rt);
        // Fused attention nodes are priced by the custom-kernel profile,
        // so the registered Pm2Lat must be built with custom collection.
        let mut gpu = Gpu::by_name("a100").unwrap();
        let pl = Pm2Lat::build_dtypes(&mut gpu, &ProfileSpec::quick(), &[DType::F32], true);
        gpu.reset();
        c.register_device(gpu, pl).unwrap();

        let cfg = crate::models::zoo::gpt2_large();
        let mut g = cfg.graph(1, 64);
        let dev = crate::gpusim::device_by_name("a100").unwrap();
        let rewrites = AttentionFusion::default().run(&mut g, &PassCtx::for_device(&dev));
        assert_eq!(rewrites, cfg.layers, "one fused subgraph per transformer block");

        let n_nodes = g.len();
        let req = GraphRequest {
            device: "a100".into(),
            graph: g,
            kind: PredictorKind::Pm2LatBatched,
            streams: 1,
        };
        let first = c.submit_graphs(std::slice::from_ref(&req)).unwrap();
        assert!(first[0].is_some(), "fused kernels priced via the custom profile");
        let hits_before = c.metrics.cache_hits.load(Ordering::Relaxed);
        let second = c.submit_graphs(std::slice::from_ref(&req)).unwrap();
        assert_eq!(first, second, "cached round trip is bit-identical");
        let hits = c.metrics.cache_hits.load(Ordering::Relaxed) - hits_before;
        assert!(
            hits >= n_nodes as u64,
            "every node (incl. repeated fused blocks) must hit: {hits} of {n_nodes}"
        );
    }

    #[test]
    fn scalar_dedup_predicts_identical_lanes_once() {
        let e = engine();
        let op = Op::Gemm(GemmOp::mm(1536, 1536, 1536, DType::F32));
        let reqs: Vec<Request> = (0..64)
            .map(|_| Request { device: "a100".into(), op, kind: PredictorKind::Pm2Lat })
            .collect();
        let out = e.submit_scalar(&reqs).unwrap();
        let v = out[0].expect("supported op");
        assert!(out.iter().all(|o| *o == Some(v)), "fan-out is exact");
        assert_eq!(e.metrics.scalar_dedup.load(Ordering::Relaxed), 63);
        // Only the unique lane consulted the predictor: one miss, and the
        // deduped lanes count as hits (the value is cached by the time a
        // non-deduped lookup would run).
        assert_eq!(e.metrics.cache_misses.load(Ordering::Relaxed), 1);
        assert_eq!(e.metrics.cache_hits.load(Ordering::Relaxed), 63);
        // Dedup without a cache is still exact (pure determinism).
        let mut nc = Engine::new().with_cache_capacity(0);
        let (gpu, pl) = fitted("a100");
        nc.register_device(gpu, pl).unwrap();
        let out2 = nc.submit_scalar(&reqs).unwrap();
        assert_eq!(out, out2);
        assert_eq!(nc.metrics.scalar_dedup.load(Ordering::Relaxed), 63);
        assert_eq!(nc.metrics.cache_hits.load(Ordering::Relaxed), 0, "no cache, no hits");
        // Duplicates of an *unsupported* op dedup but never count as
        // hits — nothing was cached, so the hit rate must not inflate.
        let bad_op = Op::Gemm(GemmOp::mm(64, 64, 64, DType::Bf16));
        let bad: Vec<Request> = (0..8)
            .map(|_| Request { device: "t4".into(), op: bad_op, kind: PredictorKind::Pm2Lat })
            .collect();
        let hits_before = e.metrics.cache_hits.load(Ordering::Relaxed);
        let none = e.submit_scalar(&bad).unwrap();
        assert!(none.iter().all(|o| o.is_none()));
        assert_eq!(e.metrics.cache_hits.load(Ordering::Relaxed), hits_before);
        assert_eq!(e.metrics.scalar_dedup.load(Ordering::Relaxed), 63 + 7);
    }

    #[test]
    fn submit_generations_matches_direct_prediction_and_amortizes_steps() {
        let rt = Runtime::open_default().expect("make artifacts");
        let c = coordinator(&rt);
        let cfg = crate::models::zoo::gpt2_large();
        let spec = crate::models::transformer::GenerationSpec::new(64, 6);
        let req = GenerationRequest {
            device: "a100".into(),
            config: cfg.clone(),
            batch: 1,
            spec,
            kind: PredictorKind::Pm2Lat,
            streams: 1,
        };
        let out = c.submit_generations(std::slice::from_ref(&req)).unwrap();
        let gen = out[0].clone().expect("gpt2 F32 supported");
        assert_eq!(gen.step_s.len(), 6);
        // Bit-identical to the direct predictor path: same ops, same
        // per-op predictions, same schedule aggregation.
        let direct = {
            let gpu = c.gpu("a100").unwrap();
            let pl = c.pm2lat("a100").unwrap();
            pl.predict_generation(gpu, &cfg, 1, &spec, 1).unwrap()
        };
        assert_eq!(gen, direct, "service generation == direct prediction");
        // Decode-step cost grows with kv_len through the service too.
        for t in 1..gen.step_s.len() {
            assert!(gen.step_s[t] > gen.step_s[t - 1]);
        }
        // Steps repeat every projection op: the scalar dedup must have
        // absorbed a large share of the lanes.
        assert!(
            c.metrics.scalar_dedup.load(Ordering::Relaxed) > 100,
            "decode steps must dedup ({} lanes saved)",
            c.metrics.scalar_dedup.load(Ordering::Relaxed)
        );
        // Unknown device errors; unsupported dtype answers None.
        let bad = GenerationRequest { device: "h100".into(), ..req.clone() };
        assert!(c.submit_generations(std::slice::from_ref(&bad)).is_err());
        let none = GenerationRequest {
            device: "t4".into(),
            config: crate::models::zoo::qwen3_0_6b(), // BF16 on T4
            batch: 1,
            spec,
            kind: PredictorKind::Pm2Lat,
            streams: 1,
        };
        assert_eq!(c.submit_generations(std::slice::from_ref(&none)).unwrap(), vec![None]);
    }

    #[test]
    fn simulate_serving_matches_the_direct_simulator_bit_for_bit() {
        use crate::serving::{
            poisson_trace, simulate, KvPagerConfig, SchedulerConfig, ServingSimConfig,
        };
        let rt = Runtime::open_default().expect("make artifacts");
        let c = coordinator(&rt);
        let cfg = crate::models::zoo::gpt2_large();
        let sim = ServingSimConfig {
            scheduler: SchedulerConfig { max_batch: 4, chunk_tokens: 128, ..Default::default() },
            pager: KvPagerConfig::for_model(&cfg, 40e9, 16),
            streams: 1,
        };
        let trace = poisson_trace(10, 40.0, 64, 6, 3);
        let req = ServingRequest {
            device: "a100".into(),
            config: cfg.clone(),
            trace: trace.clone(),
            sim,
            kind: PredictorKind::Pm2Lat,
            iter_cache: false,
        };
        let via_service = c.simulate_serving(&req).unwrap();
        // The iteration-level memo must change nothing but the speed.
        let memoized = c
            .simulate_serving(&ServingRequest { iter_cache: true, ..req.clone() })
            .unwrap();
        assert_eq!(memoized.makespan_s, via_service.makespan_s, "memo changed the replay");
        assert_eq!(memoized.gpu_busy_s, via_service.gpu_busy_s);
        assert_eq!(memoized.completed, via_service.completed);
        // The scalar service path memoizes the same deterministic
        // predictions the direct path computes — identical replay.
        let direct = {
            let gpu = c.gpu("a100").unwrap();
            let pl = c.pm2lat("a100").unwrap();
            let mut price =
                |g: &crate::graph::ModelGraph| pl.predict_graph(gpu, g, 1);
            simulate(&cfg, &trace, &sim, &mut price).unwrap()
        };
        assert_eq!(via_service.completed, direct.completed, "bit-identical replay");
        assert_eq!(via_service.iterations, direct.iterations);
        assert_eq!(via_service.makespan_s, direct.makespan_s);
        assert_eq!(via_service.gpu_busy_s, direct.gpu_busy_s);
        assert_eq!(via_service.kv_leaked_blocks, 0);
        // Iterations repeat most ops — the cache must be earning hits.
        assert!(c.metrics.cache_hit_rate() > 0.5, "{}", c.metrics.summary());
        // Unknown devices are rejected before simulation starts.
        let bad = ServingRequest { device: "h100".into(), ..req };
        assert!(c.simulate_serving(&bad).is_err());
    }

    #[test]
    fn submit_speculative_at_k0_matches_plain_serving_bit_for_bit() {
        use crate::serving::{poisson_trace, KvPagerConfig, SchedulerConfig, ServingSimConfig};
        use crate::spec_decode::{auto_draft, AcceptanceModel, SpecConfig};
        let rt = Runtime::open_default().expect("make artifacts");
        let c = coordinator(&rt);
        let cfg = crate::models::zoo::gpt2_large();
        let sim = ServingSimConfig {
            scheduler: SchedulerConfig { max_batch: 4, chunk_tokens: 128, ..Default::default() },
            pager: KvPagerConfig::for_models(&[&cfg, &auto_draft(&cfg)], 40e9, 16),
            streams: 1,
        };
        let trace = poisson_trace(8, 40.0, 48, 6, 5);
        let mk = |k: usize| SpeculativeServingRequest {
            device: "a100".into(),
            spec: SpecConfig::new(
                auto_draft(&cfg),
                cfg.clone(),
                k,
                AcceptanceModel::uniform(0.9),
            ),
            trace: trace.clone(),
            sim,
            kind: PredictorKind::Pm2Lat,
            iter_cache: false,
            seed: 7,
        };
        // k = 0 is *exactly* the plain replay: no draft pricing, plain
        // decode slots, the same f64 bits in every metric.
        let k0 = c.submit_speculative(&mk(0)).unwrap();
        let plain = c
            .simulate_serving(&ServingRequest {
                device: "a100".into(),
                config: cfg.clone(),
                trace: trace.clone(),
                sim,
                kind: PredictorKind::Pm2Lat,
                iter_cache: false,
            })
            .unwrap();
        assert_eq!(k0.completed, plain.completed, "k=0 replay diverged");
        assert_eq!(k0.makespan_s.to_bits(), plain.makespan_s.to_bits());
        assert_eq!(k0.gpu_busy_s.to_bits(), plain.gpu_busy_s.to_bits());
        assert_eq!(k0.iterations, plain.iterations);
        assert_eq!((k0.spec_rounds, k0.spec_draft_tokens, k0.spec_accepted_tokens), (0, 0, 0));
        assert_eq!(k0.spec_draft_busy_s, 0.0);
        // Speculation proper: rounds run, tokens accept, nothing leaks,
        // and the iteration memo changes nothing but the speed.
        let sp = c.submit_speculative(&mk(4)).unwrap();
        assert!(sp.spec_rounds > 0 && sp.spec_accepted_tokens > 0, "{}", sp.summary());
        assert_eq!(sp.kv_leaked_blocks, 0);
        let memo = c
            .submit_speculative(&SpeculativeServingRequest { iter_cache: true, ..mk(4) })
            .unwrap();
        assert_eq!(memo.completed, sp.completed, "memo changed the speculative replay");
        assert_eq!(memo.makespan_s.to_bits(), sp.makespan_s.to_bits());
        assert_eq!(memo.spec_accepted_tokens, sp.spec_accepted_tokens);
        // Unknown devices are rejected before simulation starts.
        assert!(c
            .submit_speculative(&SpeculativeServingRequest { device: "h100".into(), ..mk(4) })
            .is_err());
    }

    #[test]
    fn graph_streams_shorten_branchy_models() {
        let rt = Runtime::open_default().expect("make artifacts");
        let c = coordinator(&rt);
        let cfg = crate::models::zoo::flan_t5_base(); // enc–dec branches
        let mk = |streams| GraphRequest {
            device: "a100".into(),
            graph: cfg.graph(1, 64),
            kind: PredictorKind::Pm2Lat,
            streams,
        };
        let out = c.submit_graphs(&[mk(1), mk(4)]).unwrap();
        let (one, four) = (out[0].unwrap(), out[1].unwrap());
        assert!(four < one, "4 streams {four} vs sequential {one}");
    }

    #[test]
    fn mixed_workload_is_deterministic_and_mixed() {
        let devs = vec!["a100".to_string(), "t4".to_string()];
        let a = mixed_workload(&devs, 500, 50, 9);
        let b = mixed_workload(&devs, 500, 50, 9);
        assert_eq!(a.len(), 500);
        assert!(a.iter().zip(&b).all(|(x, y)| x.op == y.op && x.device == y.device));
        assert!(a.iter().any(|r| matches!(r.op, Op::Gemm(_))));
        assert!(a.iter().any(|r| matches!(r.op, Op::Util(_))));
        assert!(a.iter().any(|r| r.device == "a100"));
        assert!(a.iter().any(|r| r.device == "t4"));
    }
}
