//! Sharded, capacity-bounded LRU memoizing analytical predictions.
//!
//! PM2Lat is deterministic per device, so a cache hit is bit-identical to
//! re-running the predictor — the cache is pure acceleration, never an
//! approximation. The key carries the *computation path* (scalar vs
//! batched-PJRT) because the two pipelines agree only to ~1e-3 relative;
//! a hit must reproduce exactly what the missed path would have computed.
//!
//! Layout: 16 independently-locked shards, each a `HashMap` index over an
//! arena-allocated intrusive doubly-linked recency list. Eviction is O(1);
//! freed arena slots are reused, so shard memory is bounded by its
//! capacity regardless of churn.
//!
//! Shards are partitioned by device: the upper two shard-index bits come
//! from the device id, the lower two from the key hash. Each device class
//! owns a quarter of the capacity, so one hot device floods only its own
//! partition and cross-device workloads never contend on a lock. The key
//! also carries the tensor-parallel degree — a sharded GEMM rank and its
//! unsharded twin are different computations with different latencies.

use std::collections::hash_map::DefaultHasher;
use std::collections::HashMap;
use std::hash::{Hash, Hasher};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::ops::Op;

use super::service::PredictorKind;

/// Cache sizing policy: an entry bound, an optional per-entry TTL, and an
/// optional approximate memory budget.
///
/// * **TTL** — entries older than `ttl` are expired lazily on lookup
///   (an expired hit is a miss and frees the slot). Analytical
///   predictions never go stale, so this is an *operational* knob: it
///   bounds how long a long-lived service pins memory for traffic that
///   stopped recurring, without paying a sweeper thread.
/// * **Memory budget** — `mem_budget_bytes` converts to an entry bound
///   via [`CacheConfig::approx_entry_bytes`] (arena node + map slot,
///   padded ~1.5× for `HashMap` overhead) and the *tighter* of the two
///   bounds wins. Approximate by design: entries are fixed-size, so the
///   translation is off by at most the map's load-factor slack.
#[derive(Clone, Copy, Debug)]
pub struct CacheConfig {
    /// Maximum entries across all shards (rounded up to shard
    /// granularity); 0 disables the cache.
    pub capacity: usize,
    /// Per-entry time-to-live; `None` = entries live until evicted.
    pub ttl: Option<Duration>,
    /// Approximate total memory bound; `None` = entry bound only.
    pub mem_budget_bytes: Option<usize>,
}

impl CacheConfig {
    pub fn entries(capacity: usize) -> CacheConfig {
        CacheConfig { capacity, ttl: None, mem_budget_bytes: None }
    }

    pub fn with_ttl(mut self, ttl: Duration) -> CacheConfig {
        self.ttl = Some(ttl);
        self
    }

    pub fn with_mem_budget_mb(mut self, mb: usize) -> CacheConfig {
        self.mem_budget_bytes = Some(mb.saturating_mul(1 << 20));
        self
    }

    /// Approximate resident bytes per cached entry: the arena node plus
    /// the map slot, padded 1.5× for hash-table overhead.
    pub fn approx_entry_bytes() -> usize {
        (std::mem::size_of::<Node>() + std::mem::size_of::<(CacheKey, usize)>()) * 3 / 2
    }

    /// The entry bound after applying the memory budget (the tighter of
    /// the two bounds).
    pub fn effective_capacity(&self) -> usize {
        match self.mem_budget_bytes {
            Some(bytes) => self.capacity.min(bytes / Self::approx_entry_bytes()),
            None => self.capacity,
        }
    }
}

/// Cache key: (interned device id, tensor-parallel degree, computation
/// path, op). `tp = 1` is the single-device placement.
pub type CacheKey = (u32, u16, PredictorKind, Op);

const N_SHARDS: usize = 16;
const NIL: usize = usize::MAX;

struct Node {
    key: CacheKey,
    value: f64,
    /// Insertion/refresh time; populated only when a TTL is configured,
    /// so the TTL-free path never touches the clock.
    stamp: Option<Instant>,
    prev: usize,
    next: usize,
}

/// One shard: map index + arena LRU list (`head` = most recently used).
struct Shard {
    map: HashMap<CacheKey, usize>,
    nodes: Vec<Node>,
    head: usize,
    tail: usize,
    free: Vec<usize>,
}

impl Shard {
    fn new() -> Shard {
        Shard {
            map: HashMap::new(),
            nodes: Vec::new(),
            head: NIL,
            tail: NIL,
            free: Vec::new(),
        }
    }

    /// Unlink node `i` from the recency list.
    fn detach(&mut self, i: usize) {
        let (p, n) = (self.nodes[i].prev, self.nodes[i].next);
        if p == NIL {
            self.head = n;
        } else {
            self.nodes[p].next = n;
        }
        if n == NIL {
            self.tail = p;
        } else {
            self.nodes[n].prev = p;
        }
        self.nodes[i].prev = NIL;
        self.nodes[i].next = NIL;
    }

    /// Link node `i` at the most-recently-used end.
    fn attach_front(&mut self, i: usize) {
        self.nodes[i].prev = NIL;
        self.nodes[i].next = self.head;
        if self.head != NIL {
            self.nodes[self.head].prev = i;
        }
        self.head = i;
        if self.tail == NIL {
            self.tail = i;
        }
    }

    /// Look up `key`. The second slot of the return reports a lazy TTL
    /// expiry: the entry existed but was older than `ttl`, so it was
    /// removed and the lookup missed.
    fn get(&mut self, key: &CacheKey, ttl: Option<Duration>) -> (Option<f64>, bool) {
        let Some(&i) = self.map.get(key) else {
            return (None, false);
        };
        if let (Some(ttl), Some(stamp)) = (ttl, self.nodes[i].stamp) {
            if stamp.elapsed() >= ttl {
                self.detach(i);
                self.map.remove(key);
                self.free.push(i);
                return (None, true);
            }
        }
        if self.head != i {
            self.detach(i);
            self.attach_front(i);
        }
        (Some(self.nodes[i].value), false)
    }

    /// Insert `key → value`; returns `true` when a resident entry was
    /// evicted to make room.
    fn insert(
        &mut self,
        key: CacheKey,
        value: f64,
        capacity: usize,
        stamp: Option<Instant>,
    ) -> bool {
        if capacity == 0 {
            return false;
        }
        if let Some(&i) = self.map.get(&key) {
            self.nodes[i].value = value;
            self.nodes[i].stamp = stamp;
            if self.head != i {
                self.detach(i);
                self.attach_front(i);
            }
            return false;
        }
        let mut evicted_one = false;
        if self.map.len() >= capacity {
            let lru = self.tail;
            self.detach(lru);
            let evicted = self.nodes[lru].key;
            self.map.remove(&evicted);
            self.free.push(lru);
            evicted_one = true;
        }
        let node = Node { key, value, stamp, prev: NIL, next: NIL };
        let i = match self.free.pop() {
            Some(slot) => {
                self.nodes[slot] = node;
                slot
            }
            None => {
                self.nodes.push(node);
                self.nodes.len() - 1
            }
        };
        self.map.insert(key, i);
        self.attach_front(i);
        evicted_one
    }
}

/// The concurrent prediction cache. All methods take `&self`; per-shard
/// `Mutex`es keep contention low under multi-threaded submission.
pub struct PredictionCache {
    shards: Vec<Mutex<Shard>>,
    per_shard: usize,
    ttl: Option<Duration>,
    lru_evictions: AtomicU64,
    ttl_evictions: AtomicU64,
}

impl PredictionCache {
    /// `capacity` bounds total entries across shards (rounded up to shard
    /// granularity); 0 disables the cache entirely.
    pub fn new(capacity: usize) -> PredictionCache {
        PredictionCache::with_config(CacheConfig::entries(capacity))
    }

    /// Build from a full sizing policy (entry bound ∧ memory budget, plus
    /// an optional TTL).
    pub fn with_config(cfg: CacheConfig) -> PredictionCache {
        PredictionCache {
            shards: (0..N_SHARDS).map(|_| Mutex::new(Shard::new())).collect(),
            per_shard: cfg.effective_capacity().div_ceil(N_SHARDS),
            ttl: cfg.ttl,
            lru_evictions: AtomicU64::new(0),
            ttl_evictions: AtomicU64::new(0),
        }
    }

    pub fn enabled(&self) -> bool {
        self.per_shard > 0
    }

    /// Effective entry bound (0 when disabled).
    pub fn capacity(&self) -> usize {
        self.per_shard * N_SHARDS
    }

    /// Configured per-entry TTL, if any.
    pub fn ttl(&self) -> Option<Duration> {
        self.ttl
    }

    /// Entries displaced to make room for newer ones.
    pub fn lru_evictions(&self) -> u64 {
        self.lru_evictions.load(Ordering::Relaxed)
    }

    /// Entries lazily expired on lookup because they outlived the TTL.
    pub fn ttl_evictions(&self) -> u64 {
        self.ttl_evictions.load(Ordering::Relaxed)
    }

    /// Device-partitioned shard index: bits [3:2] from the device id,
    /// bits [1:0] from the key hash. Each device class gets a private
    /// 4-shard partition (a quarter of capacity).
    fn shard_of(&self, key: &CacheKey) -> usize {
        let mut h = DefaultHasher::new();
        key.hash(&mut h);
        (((key.0 as usize) & 3) << 2) | ((h.finish() as usize) & 3)
    }

    pub fn get(&self, device: u32, tp: u16, path: PredictorKind, op: &Op) -> Option<f64> {
        if !self.enabled() {
            return None;
        }
        let key = (device, tp, path, *op);
        let (hit, expired) = self.shards[self.shard_of(&key)]
            .lock()
            .unwrap()
            .get(&key, self.ttl);
        if expired {
            self.ttl_evictions.fetch_add(1, Ordering::Relaxed);
        }
        hit
    }

    pub fn insert(&self, device: u32, tp: u16, path: PredictorKind, op: &Op, value: f64) {
        if !self.enabled() {
            return;
        }
        let key = (device, tp, path, *op);
        let stamp = self.ttl.map(|_| Instant::now());
        let evicted = self.shards[self.shard_of(&key)]
            .lock()
            .unwrap()
            .insert(key, value, self.per_shard, stamp);
        if evicted {
            self.lru_evictions.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Current number of cached entries (sums shard sizes; O(shards)).
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().map.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn clear(&self) {
        for s in &self.shards {
            *s.lock().unwrap() = Shard::new();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::{DType, GemmOp};

    const P: PredictorKind = PredictorKind::Pm2Lat;

    fn op(i: usize) -> Op {
        Op::Gemm(GemmOp::mm(i + 1, 64, 64, DType::F32))
    }

    #[test]
    fn roundtrip_exact_values() {
        let c = PredictionCache::new(1024);
        let v = 0.1f64 + 0.2f64; // deliberately non-representable sum
        c.insert(0, 1, P, &op(0), v);
        assert_eq!(c.get(0, 1, P, &op(0)), Some(v), "hits must be bit-identical");
        assert_eq!(c.get(0, 1, P, &op(1)), None);
        assert_eq!(c.get(1, 1, P, &op(0)), None, "device id is part of the key");
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn distinct_paths_do_not_collide() {
        let c = PredictionCache::new(1024);
        c.insert(0, 1, PredictorKind::Pm2Lat, &op(0), 1.0);
        c.insert(0, 1, PredictorKind::Pm2LatBatched, &op(0), 2.0);
        assert_eq!(c.get(0, 1, PredictorKind::Pm2Lat, &op(0)), Some(1.0));
        assert_eq!(c.get(0, 1, PredictorKind::Pm2LatBatched, &op(0)), Some(2.0));
    }

    #[test]
    fn placement_degree_is_part_of_the_key() {
        // A tp=2 rank prediction must never be served to a tp=1 request
        // (and vice versa) — the graphs differ, so the latencies do.
        let c = PredictionCache::new(1024);
        c.insert(0, 1, P, &op(0), 1.0);
        c.insert(0, 2, P, &op(0), 0.6);
        assert_eq!(c.get(0, 1, P, &op(0)), Some(1.0));
        assert_eq!(c.get(0, 2, P, &op(0)), Some(0.6));
        assert_eq!(c.get(0, 4, P, &op(0)), None);
    }

    #[test]
    fn shards_are_partitioned_by_device() {
        let c = PredictionCache::new(4096);
        for i in 0..64 {
            c.insert(2, 1, P, &op(i), i as f64);
        }
        // Device 2 may only populate shard partition [8, 12).
        for (si, s) in c.shards.iter().enumerate() {
            let n = s.lock().unwrap().map.len();
            if (8..12).contains(&si) {
                continue;
            }
            assert_eq!(n, 0, "shard {si} leaked outside device 2's partition");
        }
        assert_eq!(c.len(), 64);
        // A different device class lands in a disjoint partition, so the
        // two never contend on a shard lock.
        c.insert(5, 1, P, &op(0), 9.0);
        let p5: usize = (4..8).map(|si| c.shards[si].lock().unwrap().map.len()).sum();
        assert_eq!(p5, 1);
    }

    #[test]
    fn lru_evicts_oldest_first() {
        let mut s = Shard::new();
        assert!(!s.insert((0, 1, P, op(0)), 0.0, 2, None));
        assert!(!s.insert((0, 1, P, op(1)), 1.0, 2, None));
        // Touch op0 so op1 becomes least-recently used.
        assert_eq!(s.get(&(0, 1, P, op(0)), None).0, Some(0.0));
        assert!(s.insert((0, 1, P, op(2)), 2.0, 2, None), "eviction reported");
        assert_eq!(s.get(&(0, 1, P, op(0)), None).0, Some(0.0));
        assert_eq!(s.get(&(0, 1, P, op(1)), None).0, None, "LRU entry evicted");
        assert_eq!(s.get(&(0, 1, P, op(2)), None).0, Some(2.0));
        assert_eq!(s.map.len(), 2);
    }

    #[test]
    fn arena_slots_are_reused() {
        let mut s = Shard::new();
        for i in 0..100 {
            s.insert((0, 1, P, op(i)), i as f64, 2, None);
        }
        assert_eq!(s.map.len(), 2);
        assert!(s.nodes.len() <= 3, "churn must not grow the arena");
    }

    #[test]
    fn ttl_expires_lazily_and_is_counted() {
        // A zero TTL expires every entry at its first lookup; a long TTL
        // keeps everything alive — both without any sweeper thread.
        let c = PredictionCache::with_config(
            CacheConfig::entries(1024).with_ttl(Duration::ZERO),
        );
        c.insert(0, 1, P, &op(0), 1.0);
        assert_eq!(c.len(), 1);
        assert_eq!(c.get(0, 1, P, &op(0)), None, "zero TTL expires on lookup");
        assert_eq!(c.ttl_evictions(), 1);
        assert_eq!(c.len(), 0, "expired entry freed its slot");
        // Re-inserting after expiry works (slot reuse, fresh stamp).
        c.insert(0, 1, P, &op(0), 2.0);
        assert_eq!(c.len(), 1);

        let keep = PredictionCache::with_config(
            CacheConfig::entries(1024).with_ttl(Duration::from_secs(3600)),
        );
        keep.insert(0, 1, P, &op(0), 1.0);
        assert_eq!(keep.get(0, 1, P, &op(0)), Some(1.0));
        assert_eq!(keep.ttl_evictions(), 0);
    }

    #[test]
    fn lru_evictions_are_counted_globally() {
        let c = PredictionCache::new(32);
        for i in 0..500 {
            c.insert(0, 1, P, &op(i), i as f64);
        }
        // All 500 inserts land in device 0's 4-shard partition, so churn
        // is guaranteed; at least 500 - capacity inserts displaced someone.
        assert!(
            c.lru_evictions() >= 500 - c.capacity() as u64,
            "expected ≥ {} LRU evictions, saw {}",
            500 - c.capacity(),
            c.lru_evictions()
        );
        assert_eq!(c.ttl_evictions(), 0, "no TTL configured");
    }

    #[test]
    fn mem_budget_tightens_the_entry_bound() {
        let per = CacheConfig::approx_entry_bytes();
        assert!(per > 0);
        // Budget for ~64 entries must beat a 1M-entry bound...
        let tight = CacheConfig::entries(1 << 20);
        let tight = CacheConfig {
            mem_budget_bytes: Some(64 * per),
            ..tight
        };
        assert!(tight.effective_capacity() <= 64);
        let c = PredictionCache::with_config(tight);
        assert!(c.capacity() <= 64 + N_SHARDS, "budget bound ignored");
        // ...and a huge budget must leave the entry bound in charge.
        let loose = CacheConfig::entries(128).with_mem_budget_mb(4096);
        assert_eq!(loose.effective_capacity(), 128);
    }

    #[test]
    fn capacity_bound_holds_globally() {
        let c = PredictionCache::new(32);
        for i in 0..500 {
            c.insert(0, 1, P, &op(i), i as f64);
        }
        assert!(c.len() <= c.capacity(), "{} > {}", c.len(), c.capacity());
        assert!(c.capacity() >= 32);
    }

    #[test]
    fn update_existing_key_replaces_value() {
        let c = PredictionCache::new(64);
        c.insert(0, 1, P, &op(0), 1.0);
        c.insert(0, 1, P, &op(0), 5.0);
        assert_eq!(c.get(0, 1, P, &op(0)), Some(5.0));
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn disabled_cache_is_noop() {
        let c = PredictionCache::new(0);
        assert!(!c.enabled());
        c.insert(0, 1, P, &op(0), 1.0);
        assert_eq!(c.get(0, 1, P, &op(0)), None);
        assert!(c.is_empty());
        assert_eq!(c.capacity(), 0);
    }

    #[test]
    fn clear_empties_every_shard() {
        let c = PredictionCache::new(256);
        for i in 0..100 {
            c.insert(0, 1, P, &op(i), i as f64);
        }
        assert!(!c.is_empty());
        c.clear();
        assert!(c.is_empty());
        assert_eq!(c.get(0, 1, P, &op(3)), None);
    }
}
