//! Sharded, capacity-bounded LRU memoizing analytical predictions.
//!
//! PM2Lat is deterministic per device, so a cache hit is bit-identical to
//! re-running the predictor — the cache is pure acceleration, never an
//! approximation. The key carries the *computation path* (scalar vs
//! batched-PJRT) because the two pipelines agree only to ~1e-3 relative;
//! a hit must reproduce exactly what the missed path would have computed.
//!
//! Layout: 16 independently-locked shards, each a `HashMap` index over an
//! arena-allocated intrusive doubly-linked recency list. Eviction is O(1);
//! freed arena slots are reused, so shard memory is bounded by its
//! capacity regardless of churn.
//!
//! Shards are partitioned by device: the upper two shard-index bits come
//! from the device id, the lower two from the key hash. Each device class
//! owns a quarter of the capacity, so one hot device floods only its own
//! partition and cross-device workloads never contend on a lock. The key
//! also carries the tensor-parallel degree — a sharded GEMM rank and its
//! unsharded twin are different computations with different latencies.

use std::collections::hash_map::DefaultHasher;
use std::collections::HashMap;
use std::hash::{Hash, Hasher};
use std::sync::Mutex;

use crate::ops::Op;

use super::service::PredictorKind;

/// Cache key: (interned device id, tensor-parallel degree, computation
/// path, op). `tp = 1` is the single-device placement.
pub type CacheKey = (u32, u16, PredictorKind, Op);

const N_SHARDS: usize = 16;
const NIL: usize = usize::MAX;

struct Node {
    key: CacheKey,
    value: f64,
    prev: usize,
    next: usize,
}

/// One shard: map index + arena LRU list (`head` = most recently used).
struct Shard {
    map: HashMap<CacheKey, usize>,
    nodes: Vec<Node>,
    head: usize,
    tail: usize,
    free: Vec<usize>,
}

impl Shard {
    fn new() -> Shard {
        Shard {
            map: HashMap::new(),
            nodes: Vec::new(),
            head: NIL,
            tail: NIL,
            free: Vec::new(),
        }
    }

    /// Unlink node `i` from the recency list.
    fn detach(&mut self, i: usize) {
        let (p, n) = (self.nodes[i].prev, self.nodes[i].next);
        if p == NIL {
            self.head = n;
        } else {
            self.nodes[p].next = n;
        }
        if n == NIL {
            self.tail = p;
        } else {
            self.nodes[n].prev = p;
        }
        self.nodes[i].prev = NIL;
        self.nodes[i].next = NIL;
    }

    /// Link node `i` at the most-recently-used end.
    fn attach_front(&mut self, i: usize) {
        self.nodes[i].prev = NIL;
        self.nodes[i].next = self.head;
        if self.head != NIL {
            self.nodes[self.head].prev = i;
        }
        self.head = i;
        if self.tail == NIL {
            self.tail = i;
        }
    }

    fn get(&mut self, key: &CacheKey) -> Option<f64> {
        let i = *self.map.get(key)?;
        if self.head != i {
            self.detach(i);
            self.attach_front(i);
        }
        Some(self.nodes[i].value)
    }

    fn insert(&mut self, key: CacheKey, value: f64, capacity: usize) {
        if capacity == 0 {
            return;
        }
        if let Some(&i) = self.map.get(&key) {
            self.nodes[i].value = value;
            if self.head != i {
                self.detach(i);
                self.attach_front(i);
            }
            return;
        }
        if self.map.len() >= capacity {
            let lru = self.tail;
            self.detach(lru);
            let evicted = self.nodes[lru].key;
            self.map.remove(&evicted);
            self.free.push(lru);
        }
        let node = Node { key, value, prev: NIL, next: NIL };
        let i = match self.free.pop() {
            Some(slot) => {
                self.nodes[slot] = node;
                slot
            }
            None => {
                self.nodes.push(node);
                self.nodes.len() - 1
            }
        };
        self.map.insert(key, i);
        self.attach_front(i);
    }
}

/// The concurrent prediction cache. All methods take `&self`; per-shard
/// `Mutex`es keep contention low under multi-threaded submission.
pub struct PredictionCache {
    shards: Vec<Mutex<Shard>>,
    per_shard: usize,
}

impl PredictionCache {
    /// `capacity` bounds total entries across shards (rounded up to shard
    /// granularity); 0 disables the cache entirely.
    pub fn new(capacity: usize) -> PredictionCache {
        PredictionCache {
            shards: (0..N_SHARDS).map(|_| Mutex::new(Shard::new())).collect(),
            per_shard: capacity.div_ceil(N_SHARDS),
        }
    }

    pub fn enabled(&self) -> bool {
        self.per_shard > 0
    }

    /// Effective entry bound (0 when disabled).
    pub fn capacity(&self) -> usize {
        self.per_shard * N_SHARDS
    }

    /// Device-partitioned shard index: bits [3:2] from the device id,
    /// bits [1:0] from the key hash. Each device class gets a private
    /// 4-shard partition (a quarter of capacity).
    fn shard_of(&self, key: &CacheKey) -> usize {
        let mut h = DefaultHasher::new();
        key.hash(&mut h);
        (((key.0 as usize) & 3) << 2) | ((h.finish() as usize) & 3)
    }

    pub fn get(&self, device: u32, tp: u16, path: PredictorKind, op: &Op) -> Option<f64> {
        if !self.enabled() {
            return None;
        }
        let key = (device, tp, path, *op);
        self.shards[self.shard_of(&key)].lock().unwrap().get(&key)
    }

    pub fn insert(&self, device: u32, tp: u16, path: PredictorKind, op: &Op, value: f64) {
        if !self.enabled() {
            return;
        }
        let key = (device, tp, path, *op);
        self.shards[self.shard_of(&key)]
            .lock()
            .unwrap()
            .insert(key, value, self.per_shard);
    }

    /// Current number of cached entries (sums shard sizes; O(shards)).
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().map.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn clear(&self) {
        for s in &self.shards {
            *s.lock().unwrap() = Shard::new();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::{DType, GemmOp};

    const P: PredictorKind = PredictorKind::Pm2Lat;

    fn op(i: usize) -> Op {
        Op::Gemm(GemmOp::mm(i + 1, 64, 64, DType::F32))
    }

    #[test]
    fn roundtrip_exact_values() {
        let c = PredictionCache::new(1024);
        let v = 0.1f64 + 0.2f64; // deliberately non-representable sum
        c.insert(0, 1, P, &op(0), v);
        assert_eq!(c.get(0, 1, P, &op(0)), Some(v), "hits must be bit-identical");
        assert_eq!(c.get(0, 1, P, &op(1)), None);
        assert_eq!(c.get(1, 1, P, &op(0)), None, "device id is part of the key");
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn distinct_paths_do_not_collide() {
        let c = PredictionCache::new(1024);
        c.insert(0, 1, PredictorKind::Pm2Lat, &op(0), 1.0);
        c.insert(0, 1, PredictorKind::Pm2LatBatched, &op(0), 2.0);
        assert_eq!(c.get(0, 1, PredictorKind::Pm2Lat, &op(0)), Some(1.0));
        assert_eq!(c.get(0, 1, PredictorKind::Pm2LatBatched, &op(0)), Some(2.0));
    }

    #[test]
    fn placement_degree_is_part_of_the_key() {
        // A tp=2 rank prediction must never be served to a tp=1 request
        // (and vice versa) — the graphs differ, so the latencies do.
        let c = PredictionCache::new(1024);
        c.insert(0, 1, P, &op(0), 1.0);
        c.insert(0, 2, P, &op(0), 0.6);
        assert_eq!(c.get(0, 1, P, &op(0)), Some(1.0));
        assert_eq!(c.get(0, 2, P, &op(0)), Some(0.6));
        assert_eq!(c.get(0, 4, P, &op(0)), None);
    }

    #[test]
    fn shards_are_partitioned_by_device() {
        let c = PredictionCache::new(4096);
        for i in 0..64 {
            c.insert(2, 1, P, &op(i), i as f64);
        }
        // Device 2 may only populate shard partition [8, 12).
        for (si, s) in c.shards.iter().enumerate() {
            let n = s.lock().unwrap().map.len();
            if (8..12).contains(&si) {
                continue;
            }
            assert_eq!(n, 0, "shard {si} leaked outside device 2's partition");
        }
        assert_eq!(c.len(), 64);
        // A different device class lands in a disjoint partition, so the
        // two never contend on a shard lock.
        c.insert(5, 1, P, &op(0), 9.0);
        let p5: usize = (4..8).map(|si| c.shards[si].lock().unwrap().map.len()).sum();
        assert_eq!(p5, 1);
    }

    #[test]
    fn lru_evicts_oldest_first() {
        let mut s = Shard::new();
        s.insert((0, 1, P, op(0)), 0.0, 2);
        s.insert((0, 1, P, op(1)), 1.0, 2);
        // Touch op0 so op1 becomes least-recently used.
        assert_eq!(s.get(&(0, 1, P, op(0))), Some(0.0));
        s.insert((0, 1, P, op(2)), 2.0, 2);
        assert_eq!(s.get(&(0, 1, P, op(0))), Some(0.0));
        assert_eq!(s.get(&(0, 1, P, op(1))), None, "LRU entry evicted");
        assert_eq!(s.get(&(0, 1, P, op(2))), Some(2.0));
        assert_eq!(s.map.len(), 2);
    }

    #[test]
    fn arena_slots_are_reused() {
        let mut s = Shard::new();
        for i in 0..100 {
            s.insert((0, 1, P, op(i)), i as f64, 2);
        }
        assert_eq!(s.map.len(), 2);
        assert!(s.nodes.len() <= 3, "churn must not grow the arena");
    }

    #[test]
    fn capacity_bound_holds_globally() {
        let c = PredictionCache::new(32);
        for i in 0..500 {
            c.insert(0, 1, P, &op(i), i as f64);
        }
        assert!(c.len() <= c.capacity(), "{} > {}", c.len(), c.capacity());
        assert!(c.capacity() >= 32);
    }

    #[test]
    fn update_existing_key_replaces_value() {
        let c = PredictionCache::new(64);
        c.insert(0, 1, P, &op(0), 1.0);
        c.insert(0, 1, P, &op(0), 5.0);
        assert_eq!(c.get(0, 1, P, &op(0)), Some(5.0));
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn disabled_cache_is_noop() {
        let c = PredictionCache::new(0);
        assert!(!c.enabled());
        c.insert(0, 1, P, &op(0), 1.0);
        assert_eq!(c.get(0, 1, P, &op(0)), None);
        assert!(c.is_empty());
        assert_eq!(c.capacity(), 0);
    }

    #[test]
    fn clear_empties_every_shard() {
        let c = PredictionCache::new(256);
        for i in 0..100 {
            c.insert(0, 1, P, &op(i), i as f64);
        }
        assert!(!c.is_empty());
        c.clear();
        assert!(c.is_empty());
        assert_eq!(c.get(0, 1, P, &op(3)), None);
    }
}
