//! Service metrics: request counts, batch sizes, per-call service time.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

#[derive(Default)]
pub struct Metrics {
    pub requests: AtomicU64,
    pub batches: AtomicU64,
    pub pjrt_calls: AtomicU64,
    pub unsupported: AtomicU64,
    service_ns: Mutex<Vec<u64>>,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn record_batch(&self, n_requests: usize, pjrt_calls: usize, service: std::time::Duration) {
        self.requests.fetch_add(n_requests as u64, Ordering::Relaxed);
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.pjrt_calls.fetch_add(pjrt_calls as u64, Ordering::Relaxed);
        self.service_ns.lock().unwrap().push(service.as_nanos() as u64);
    }

    pub fn record_unsupported(&self, n: usize) {
        self.unsupported.fetch_add(n as u64, Ordering::Relaxed);
    }

    /// Mean service time per *batch* in microseconds.
    pub fn mean_batch_us(&self) -> f64 {
        let v = self.service_ns.lock().unwrap();
        if v.is_empty() {
            return 0.0;
        }
        v.iter().sum::<u64>() as f64 / v.len() as f64 / 1e3
    }

    /// Mean service time per *request* in microseconds.
    pub fn mean_request_us(&self) -> f64 {
        let reqs = self.requests.load(Ordering::Relaxed);
        if reqs == 0 {
            return 0.0;
        }
        let v = self.service_ns.lock().unwrap();
        v.iter().sum::<u64>() as f64 / reqs as f64 / 1e3
    }

    pub fn summary(&self) -> String {
        format!(
            "requests={} batches={} pjrt_calls={} unsupported={} mean_batch={:.1}µs mean_req={:.2}µs",
            self.requests.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            self.pjrt_calls.load(Ordering::Relaxed),
            self.unsupported.load(Ordering::Relaxed),
            self.mean_batch_us(),
            self.mean_request_us(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn accumulates() {
        let m = Metrics::new();
        m.record_batch(100, 2, Duration::from_micros(500));
        m.record_batch(50, 1, Duration::from_micros(250));
        assert_eq!(m.requests.load(Ordering::Relaxed), 150);
        assert_eq!(m.batches.load(Ordering::Relaxed), 2);
        assert_eq!(m.pjrt_calls.load(Ordering::Relaxed), 3);
        assert!((m.mean_batch_us() - 375.0).abs() < 1.0);
        assert!((m.mean_request_us() - 5.0).abs() < 0.1);
    }

    #[test]
    fn empty_metrics_zero() {
        let m = Metrics::new();
        assert_eq!(m.mean_batch_us(), 0.0);
        assert_eq!(m.mean_request_us(), 0.0);
    }
}
