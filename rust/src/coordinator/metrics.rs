//! Service metrics: request/batch/PJRT/cache counters plus a *bounded*
//! service-time reservoir.
//!
//! The seed kept every per-batch service time in an unbounded
//! `Mutex<Vec<u64>>` — a memory leak under sustained traffic. Metrics now
//! hold at most [`RESERVOIR_CAP`] samples (Vitter's algorithm R, uniform
//! over the whole stream), so memory is O(1) regardless of request count
//! while p50/p99 stay statistically faithful. Means remain exact via a
//! running sum.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::util::prng::Rng;
use crate::util::stats;

/// Fixed bound on retained service-time samples.
pub const RESERVOIR_CAP: usize = 4096;

/// Uniform reservoir over the stream of per-batch service times.
struct Reservoir {
    samples: Vec<u64>,
    seen: u64,
    rng: Rng,
}

impl Reservoir {
    fn new() -> Reservoir {
        Reservoir { samples: Vec::new(), seen: 0, rng: Rng::new(0xC0FFEE) }
    }

    fn record(&mut self, x: u64) {
        self.seen += 1;
        if self.samples.len() < RESERVOIR_CAP {
            self.samples.push(x);
        } else {
            let j = self.rng.next_u64() % self.seen;
            if (j as usize) < RESERVOIR_CAP {
                self.samples[j as usize] = x;
            }
        }
    }
}

pub struct Metrics {
    pub requests: AtomicU64,
    pub batches: AtomicU64,
    pub pjrt_calls: AtomicU64,
    pub unsupported: AtomicU64,
    pub cache_hits: AtomicU64,
    pub cache_misses: AtomicU64,
    /// Lanes answered by within-batch dedup on the batched GEMM path:
    /// identical `(device, op)` misses in one submission launch once and
    /// fan the result out.
    pub batched_dedup: AtomicU64,
    /// Lanes answered by within-batch dedup on the *scalar* fan-out path:
    /// identical `(device, op)` work items are predicted once per batch.
    /// Decode workloads make these common — consecutive decode steps
    /// share every projection op.
    pub scalar_dedup: AtomicU64,
    /// Batched-predictor builds that failed at device registration (the
    /// device degrades to the scalar path).
    pub batcher_errors: AtomicU64,
    service_ns_sum: AtomicU64,
    reservoir: Mutex<Reservoir>,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics {
            requests: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            pjrt_calls: AtomicU64::new(0),
            unsupported: AtomicU64::new(0),
            cache_hits: AtomicU64::new(0),
            cache_misses: AtomicU64::new(0),
            batched_dedup: AtomicU64::new(0),
            scalar_dedup: AtomicU64::new(0),
            batcher_errors: AtomicU64::new(0),
            service_ns_sum: AtomicU64::new(0),
            reservoir: Mutex::new(Reservoir::new()),
        }
    }

    pub fn record_batch(&self, n_requests: usize, pjrt_calls: usize, service: std::time::Duration) {
        self.requests.fetch_add(n_requests as u64, Ordering::Relaxed);
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.pjrt_calls.fetch_add(pjrt_calls as u64, Ordering::Relaxed);
        let ns = service.as_nanos() as u64;
        self.service_ns_sum.fetch_add(ns, Ordering::Relaxed);
        self.reservoir.lock().unwrap().record(ns);
    }

    pub fn record_unsupported(&self, n: usize) {
        self.unsupported.fetch_add(n as u64, Ordering::Relaxed);
    }

    pub fn record_cache(&self, hit: bool) {
        if hit {
            self.cache_hits.fetch_add(1, Ordering::Relaxed);
        } else {
            self.cache_misses.fetch_add(1, Ordering::Relaxed);
        }
    }

    pub fn record_batcher_error(&self) {
        self.batcher_errors.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_dedup(&self, n: usize) {
        self.batched_dedup.fetch_add(n as u64, Ordering::Relaxed);
    }

    pub fn record_scalar_dedup(&self, n: usize) {
        self.scalar_dedup.fetch_add(n as u64, Ordering::Relaxed);
    }

    /// Mean service time per *batch* in microseconds (exact).
    pub fn mean_batch_us(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            return 0.0;
        }
        self.service_ns_sum.load(Ordering::Relaxed) as f64 / b as f64 / 1e3
    }

    /// Mean service time per *request* in microseconds (exact).
    pub fn mean_request_us(&self) -> f64 {
        let reqs = self.requests.load(Ordering::Relaxed);
        if reqs == 0 {
            return 0.0;
        }
        self.service_ns_sum.load(Ordering::Relaxed) as f64 / reqs as f64 / 1e3
    }

    /// (p50, p99) per-batch service time in microseconds, estimated from
    /// the bounded reservoir.
    pub fn service_percentiles_us(&self) -> (f64, f64) {
        let r = self.reservoir.lock().unwrap();
        if r.samples.is_empty() {
            return (0.0, 0.0);
        }
        let v: Vec<f64> = r.samples.iter().map(|&x| x as f64 / 1e3).collect();
        (stats::percentile(&v, 50.0), stats::percentile(&v, 99.0))
    }

    /// Number of retained service-time samples — never exceeds
    /// [`RESERVOIR_CAP`].
    pub fn service_samples(&self) -> usize {
        self.reservoir.lock().unwrap().samples.len()
    }

    /// Fraction of cache lookups that hit (0.0 when no lookups yet).
    pub fn cache_hit_rate(&self) -> f64 {
        let h = self.cache_hits.load(Ordering::Relaxed) as f64;
        let m = self.cache_misses.load(Ordering::Relaxed) as f64;
        if h + m == 0.0 {
            0.0
        } else {
            h / (h + m)
        }
    }

    pub fn summary(&self) -> String {
        let (p50, p99) = self.service_percentiles_us();
        format!(
            "requests={} batches={} pjrt_calls={} unsupported={} \
             mean_batch={:.1}µs mean_req={:.2}µs p50_batch={:.1}µs p99_batch={:.1}µs \
             cache_hit_rate={:.1}% batched_dedup={} scalar_dedup={} batcher_errors={}",
            self.requests.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            self.pjrt_calls.load(Ordering::Relaxed),
            self.unsupported.load(Ordering::Relaxed),
            self.mean_batch_us(),
            self.mean_request_us(),
            p50,
            p99,
            self.cache_hit_rate() * 100.0,
            self.batched_dedup.load(Ordering::Relaxed),
            self.scalar_dedup.load(Ordering::Relaxed),
            self.batcher_errors.load(Ordering::Relaxed),
        )
    }
}

impl Default for Metrics {
    fn default() -> Metrics {
        Metrics::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn accumulates() {
        let m = Metrics::new();
        m.record_batch(100, 2, Duration::from_micros(500));
        m.record_batch(50, 1, Duration::from_micros(250));
        assert_eq!(m.requests.load(Ordering::Relaxed), 150);
        assert_eq!(m.batches.load(Ordering::Relaxed), 2);
        assert_eq!(m.pjrt_calls.load(Ordering::Relaxed), 3);
        assert!((m.mean_batch_us() - 375.0).abs() < 1.0);
        assert!((m.mean_request_us() - 5.0).abs() < 0.1);
    }

    #[test]
    fn empty_metrics_zero() {
        let m = Metrics::new();
        assert_eq!(m.mean_batch_us(), 0.0);
        assert_eq!(m.mean_request_us(), 0.0);
        assert_eq!(m.service_percentiles_us(), (0.0, 0.0));
        assert_eq!(m.cache_hit_rate(), 0.0);
    }

    #[test]
    fn reservoir_is_bounded_with_sane_percentiles() {
        let m = Metrics::new();
        // 20k batches, service times 1µs..21µs — far more than the cap.
        for i in 0..20_000u64 {
            m.record_batch(1, 0, Duration::from_nanos(1_000 + i));
        }
        assert!(m.service_samples() <= RESERVOIR_CAP);
        let (p50, p99) = m.service_percentiles_us();
        assert!(p50 > 0.0 && p50 <= p99, "p50 {p50} p99 {p99}");
        assert!(p99 <= 21.0, "p99 {p99}µs exceeds stream max");
        assert!((m.mean_batch_us() - 11.0).abs() < 0.5, "exact mean survives");
    }

    #[test]
    fn cache_counters_and_rate() {
        let m = Metrics::new();
        m.record_cache(true);
        m.record_cache(true);
        m.record_cache(true);
        m.record_cache(false);
        assert_eq!(m.cache_hits.load(Ordering::Relaxed), 3);
        assert_eq!(m.cache_misses.load(Ordering::Relaxed), 1);
        assert!((m.cache_hit_rate() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn summary_reports_percentiles_and_hit_rate() {
        let m = Metrics::new();
        m.record_batch(10, 1, Duration::from_micros(100));
        m.record_cache(true);
        m.record_batcher_error();
        m.record_dedup(3);
        m.record_scalar_dedup(7);
        let s = m.summary();
        assert!(s.contains("p50_batch="), "{s}");
        assert!(s.contains("p99_batch="), "{s}");
        assert!(s.contains("cache_hit_rate=100.0%"), "{s}");
        assert!(s.contains("batched_dedup=3"), "{s}");
        assert!(s.contains("scalar_dedup=7"), "{s}");
        assert!(s.contains("batcher_errors=1"), "{s}");
    }
}
