//! # coordinator — the prediction service (L3)
//!
//! A deployment-shaped front end over the predictors: clients submit
//! prediction requests (op + device + predictor kind); the coordinator
//! routes per device, *batches* NeuSight MLP queries and PM2Lat GEMM
//! queries so each PJRT executable launch is amortized over up to 1024
//! lanes, fans independent device groups across a thread pool, and
//! exposes service metrics. This is the machinery the NAS-preprocessing
//! application (§IV-D2) runs on at millions-of-queries scale.

pub mod metrics;
pub mod service;

pub use metrics::Metrics;
pub use service::{Coordinator, PredictorKind, Request};
