//! # coordinator — the prediction service (L3)
//!
//! A deployment-shaped front end over the predictors, built in two layers:
//!
//! * [`Engine`] — the analytical core. Devices are interned at
//!   registration, so request routing is a borrowed `&str` lookup and
//!   group keys carry an integer id — the hot path allocates nothing per
//!   request. Scalar PM2Lat predictions fan out over `util::pool` worker
//!   threads in input-order-stable chunks, and every (device, path, op)
//!   result is memoized in a sharded, capacity-bounded LRU
//!   ([`PredictionCache`]) — PM2Lat is deterministic per device, so cache
//!   hits are bit-identical to fresh predictions. The engine is plain
//!   `Send + Sync` data: any number of client threads may call
//!   [`Engine::submit_scalar`] concurrently on a shared reference.
//! * [`Coordinator`] — the engine plus the PJRT-backed accelerators:
//!   batched PM2Lat GEMM evaluation (up to 1024 lanes amortize one
//!   executable launch) and the NeuSight MLP. PJRT work stays on the
//!   calling thread (the FFI client is not known to be thread-safe);
//!   batched-path cache misses are collected per (device, kind) group,
//!   evaluated in as few launches as possible, and written back into the
//!   shared cache, while non-batchable lanes spill into the engine's
//!   parallel scalar fan-out.
//!
//! [`Metrics`] tracks request/batch/PJRT/cache/dedup counters plus a
//! *bounded* service-time reservoir: p50/p99 come from at most
//! [`RESERVOIR_CAP`] retained samples (Vitter's algorithm R), so metrics
//! memory is O(1) under sustained traffic. Identical `(device, op)` work
//! items within one submission are deduplicated on *both* fan-out paths:
//! batched misses launch one PJRT lane and fan the result out
//! (`batched_dedup`), and scalar work items are predicted once per batch
//! (`scalar_dedup`) — decode workloads repeat every projection op across
//! steps, so the scalar dedup is what makes generation serving cheap.
//! Three whole-model APIs sit on top: the trace-level
//! [`Coordinator::submit_traces`] (sequential sum), the graph-level
//! [`Coordinator::submit_graphs`], which accepts
//! [`crate::graph::ModelGraph`] requests, batches GEMM lanes across graph
//! nodes, caches at subgraph granularity (repeated transformer blocks hit
//! per-node), and aggregates latency as the stream-capped critical path —
//! and the generation-level [`Coordinator::submit_generations`], which
//! expands a (prompt, generate) request into prefill + per-step decode
//! graphs and answers the full latency curve
//! ([`crate::pm2lat::predictor::GenerationPrediction`]: prefill, per-step
//! decode, time-per-output-token). Placements are first-class:
//! [`Coordinator::submit_placed_graphs`] routes one rank graph (sharded
//! by [`crate::graph::TensorParallelPass`], collectives included) to
//! every device of a [`crate::ops::Placement`] and answers the slowest
//! rank's makespan; the tensor-parallel degree is a cache-key dimension,
//! and cache shards are partitioned per device class, so placements
//! never alias and hot devices evict only their own quarter. On top of
//! those, [`Coordinator::simulate_serving`] replays a whole request trace
//! through the continuous-batching serving simulator
//! ([`crate::serving`]), pricing every mixed prefill+decode iteration
//! as one cached graph submission — and
//! [`Coordinator::submit_speculative`] does the same under speculative
//! decoding, pricing a draft/target pair's rounds and verification
//! windows through the identical cached path. The NAS preprocessing application
//! (§IV-D2) and the model runner consume the service through these rather
//! than driving raw `Pm2Lat`. `pm2lat serve-bench` and
//! `benches/serve_throughput.rs` measure requests/sec against the serial
//! no-cache baseline, across F32 scalar/batched, BF16 and NeuSight lanes;
//! `benches/decode_throughput.rs` sweeps generation shapes through
//! `submit_generations`, and `serve-bench --slo-p99-us N` turns the p99
//! reservoir into a CI gate.

pub mod cache;
pub mod metrics;
pub mod service;

pub use cache::{CacheConfig, PredictionCache};
pub use metrics::{Metrics, RESERVOIR_CAP};
pub use service::{
    ab_phases, build_f32_service, build_service, mixed_workload, mixed_workload_dtyped,
    quick_neusight, timed_submit, to_batched, to_kind, AbReport, Coordinator, Engine,
    GenerationRequest, GraphRequest, PlacedGraphRequest, PredictorKind, Request,
    ServingRequest, SpeculativeServingRequest, TraceRequest, DEFAULT_CACHE_CAPACITY,
};
