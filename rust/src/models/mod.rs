//! # models — the Table III transformer zoo
//!
//! Architecture configs ([`zoo`]), the kernel-trace expansion
//! ([`transformer`]), and ground-truth execution on the simulator
//! ([`runner`]).

pub mod runner;
pub mod transformer;
pub mod zoo;

pub use transformer::TransformerConfig;
