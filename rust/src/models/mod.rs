//! # models — the Table III transformer zoo
//!
//! Architecture configs ([`zoo`]), the graph/kernel-trace expansion for
//! both generation phases — prefill ([`TransformerConfig::graph`]) and
//! autoregressive decode ([`TransformerConfig::decode_graph`],
//! [`GenerationSpec`]) — and ground-truth execution on the simulator
//! ([`runner`], including whole-generation runs).

pub mod runner;
pub mod transformer;
pub mod zoo;

pub use transformer::{GenerationSpec, SeqSlot, TransformerConfig};
