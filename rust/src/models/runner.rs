//! Ground-truth model execution on the simulator: run a model graph (or
//! its lowered kernel trace) end-to-end with the paper's 5-warmup /
//! 25-measurement protocol and report the mean latency — the MeanT
//! columns of Tables IV/V. Graph execution issues kernels in lowered
//! order (identical device-state evolution to the flat trace) and
//! aggregates the measured durations through the dependency-aware
//! scheduler; `streams = 1` reproduces the sequential sum bit-for-bit.

use crate::gpusim::{ExecError, FreqMode, Gpu};
use crate::graph::{schedule, ModelGraph};
use crate::ops::Op;

use super::transformer::{GenerationSpec, TransformerConfig};

/// Measured model execution.
#[derive(Clone, Copy, Debug)]
pub struct ModelRun {
    pub mean_s: f64,
    pub reps: usize,
}

/// Execute a trace once, summing per-kernel durations (sequential CUDA
/// stream semantics).
pub fn run_trace_once(gpu: &mut Gpu, trace: &[Op]) -> Result<f64, ExecError> {
    let mut total = 0.0;
    for op in trace {
        total += gpu.exec(op)?.dur_s;
    }
    Ok(total)
}

/// Execute a model graph once on up to `streams` concurrent streams.
/// Kernels are issued in lowered order — the same op sequence, and
/// therefore the same JIT/thermal/noise evolution, as the flat-trace
/// path — and the measured durations are aggregated as the makespan of
/// the dependency-aware schedule. `streams = 1` is bit-identical to
/// [`run_trace_once`] over the lowered trace.
pub fn run_graph_once(gpu: &mut Gpu, g: &ModelGraph, streams: usize) -> Result<f64, ExecError> {
    let mut dur = vec![0.0f64; g.len()];
    for id in g.lowered_ids() {
        dur[id.index()] = gpu.exec(&g.node(id).op)?.dur_s;
    }
    Ok(schedule::schedule(g, streams, &dur).makespan_s)
}

/// Predict a whole model through the prediction service (trace-level API):
/// the coordinator batches GEMM lanes through the PJRT artifact, fans the
/// rest across its thread pool, and memoizes repeated layers — so the
/// runner is a *consumer of the service*, not of raw `Pm2Lat`. Returns
/// `Ok(None)` when any op is unsupported on the device.
pub fn predict_model(
    coord: &crate::coordinator::Coordinator<'_>,
    device: &str,
    cfg: &TransformerConfig,
    batch: usize,
    seq: usize,
) -> anyhow::Result<Option<f64>> {
    use crate::coordinator::{PredictorKind, TraceRequest};
    let req = TraceRequest {
        device: device.to_string(),
        trace: cfg.trace(batch, seq),
        kind: PredictorKind::Pm2LatBatched,
    };
    let mut out = coord.submit_traces(std::slice::from_ref(&req))?;
    Ok(out.pop().unwrap_or(None))
}

/// Graph-level service prediction: the whole model as one [`ModelGraph`]
/// through [`Coordinator::submit_graphs`] — subgraph-granularity caching,
/// GEMM lanes batched across graph nodes, and latency aggregated as the
/// `streams`-bounded critical path. `streams = 1` matches
/// [`predict_model`] bit-for-bit.
///
/// [`Coordinator::submit_graphs`]: crate::coordinator::Coordinator::submit_graphs
pub fn predict_model_graph(
    coord: &crate::coordinator::Coordinator<'_>,
    device: &str,
    cfg: &TransformerConfig,
    batch: usize,
    seq: usize,
    streams: usize,
) -> anyhow::Result<Option<f64>> {
    use crate::coordinator::{GraphRequest, PredictorKind};
    let req = GraphRequest {
        device: device.to_string(),
        graph: cfg.graph(batch, seq),
        kind: PredictorKind::Pm2LatBatched,
        streams,
    };
    let mut out = coord.submit_graphs(std::slice::from_ref(&req))?;
    Ok(out.pop().unwrap_or(None))
}

/// Paper protocol (§IV-B): warm-up ×5, then 25 measured repetitions.
pub fn run_model(
    gpu: &mut Gpu,
    cfg: &TransformerConfig,
    batch: usize,
    seq: usize,
    warmup: usize,
    reps: usize,
) -> Result<ModelRun, ExecError> {
    gpu.check_memory(cfg.memory_bytes(batch, seq))?;
    gpu.set_freq(FreqMode::Boost);
    let trace = cfg.trace(batch, seq);
    for _ in 0..warmup {
        run_trace_once(gpu, &trace)?;
    }
    let mut total = 0.0;
    for _ in 0..reps {
        total += run_trace_once(gpu, &trace)?;
    }
    Ok(ModelRun { mean_s: total / reps as f64, reps })
}

/// Measurement protocol over an arbitrary graph (e.g. after fusion
/// passes). The caller is responsible for a memory check when the graph
/// came from a model config — see [`run_model_graph`].
pub fn run_graph(
    gpu: &mut Gpu,
    g: &ModelGraph,
    warmup: usize,
    reps: usize,
    streams: usize,
) -> Result<ModelRun, ExecError> {
    gpu.set_freq(FreqMode::Boost);
    for _ in 0..warmup {
        run_graph_once(gpu, g, streams)?;
    }
    let mut total = 0.0;
    for _ in 0..reps {
        total += run_graph_once(gpu, g, streams)?;
    }
    Ok(ModelRun { mean_s: total / reps as f64, reps })
}

/// Ground-truth autoregressive generation: memory check against the
/// fully grown KV cache, then the prefill graph followed by one decode
/// graph per emitted token, all on up to `streams` concurrent streams.
/// The device state (thermals, JIT cache, noise stream) evolves across
/// steps exactly as a real generation loop's would — generation is
/// inherently serial (step `t+1` consumes step `t`'s token), so there is
/// no rep-averaging. Returns the measured latency curve in the same
/// [`GenerationPrediction`] shape the predictors answer with, so
/// predicted and measured generations compare field-for-field.
///
/// [`GenerationPrediction`]: crate::pm2lat::GenerationPrediction
pub fn run_generation(
    gpu: &mut Gpu,
    cfg: &TransformerConfig,
    batch: usize,
    spec: &GenerationSpec,
    streams: usize,
) -> Result<crate::pm2lat::GenerationPrediction, ExecError> {
    gpu.check_memory(cfg.generation_memory_bytes(batch, spec))?;
    gpu.set_freq(FreqMode::Boost);
    let (prefill, steps) = cfg.generation_graphs(batch, spec);
    let prefill_s = run_graph_once(gpu, &prefill, streams)?;
    let mut step_s = Vec::with_capacity(steps.len());
    for g in &steps {
        step_s.push(run_graph_once(gpu, g, streams)?);
    }
    Ok(crate::pm2lat::GenerationPrediction { prefill_s, step_s })
}

/// Graph analogue of [`run_model`]: memory check, then the measurement
/// protocol over the model graph. `streams = 1` reproduces [`run_model`]
/// bit-for-bit.
pub fn run_model_graph(
    gpu: &mut Gpu,
    cfg: &TransformerConfig,
    batch: usize,
    seq: usize,
    warmup: usize,
    reps: usize,
    streams: usize,
) -> Result<ModelRun, ExecError> {
    gpu.check_memory(cfg.memory_bytes(batch, seq))?;
    run_graph(gpu, &cfg.graph(batch, seq), warmup, reps, streams)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::zoo;

    #[test]
    fn gpt2_runs_on_a100() {
        let mut gpu = Gpu::by_name("a100").unwrap();
        let cfg = zoo::gpt2_large();
        let run = run_model(&mut gpu, &cfg, 1, 128, 1, 3).unwrap();
        assert!(run.mean_s > 0.0);
    }

    #[test]
    fn oom_cells_match_capacity() {
        // Qwen3-4B BF16 (~8 GB weights) cannot run on the 6 GB 3060M —
        // a "-" cell of Table IV.
        let mut gpu = Gpu::by_name("rtx3060m").unwrap();
        let cfg = zoo::qwen3_4b();
        assert!(matches!(
            run_model(&mut gpu, &cfg, 1, 512, 0, 1),
            Err(ExecError::OutOfMemory { .. })
        ));
        // And DS-R1-14B not even on the 24 GB L4 at batch 8.
        let mut l4 = Gpu::by_name("l4").unwrap();
        assert!(run_model(&mut l4, &zoo::deepseek_r1_14b(), 8, 512, 0, 1).is_err());
        // The graph path enforces the same capacity contract.
        let mut small = Gpu::by_name("rtx3060m").unwrap();
        assert!(matches!(
            run_model_graph(&mut small, &cfg, 1, 512, 0, 1, 2),
            Err(ExecError::OutOfMemory { .. })
        ));
    }

    #[test]
    fn bf16_model_rejected_on_t4() {
        let mut gpu = Gpu::by_name("t4").unwrap();
        let cfg = zoo::qwen3_0_6b();
        assert!(run_model(&mut gpu, &cfg, 1, 128, 0, 1).is_err());
    }

    #[test]
    fn latency_scales_with_batch() {
        let mut gpu = Gpu::by_name("a100").unwrap();
        let cfg = zoo::qwen3_0_6b();
        let b1 = run_model(&mut gpu, &cfg, 1, 256, 1, 3).unwrap().mean_s;
        gpu.reset();
        let b8 = run_model(&mut gpu, &cfg, 8, 256, 1, 3).unwrap().mean_s;
        assert!(b8 > b1, "batch 8 slower than 1");
        // ...but sublinearly (wave quantization + underutilized small
        // batches — the paper's A100 anomaly).
        assert!(b8 < b1 * 8.0);
    }

    #[test]
    fn graph_execution_with_one_stream_is_bit_identical_to_trace() {
        let cfg = zoo::qwen3_0_6b();
        let g = cfg.graph(1, 64);
        let trace = cfg.trace(1, 64);
        let mut gpu_a = Gpu::by_name("a100").unwrap();
        let mut gpu_b = Gpu::by_name("a100").unwrap();
        for _ in 0..3 {
            let a = run_trace_once(&mut gpu_a, &trace).unwrap();
            let b = run_graph_once(&mut gpu_b, &g, 1).unwrap();
            assert_eq!(a, b, "streams=1 must reproduce the sequential sum exactly");
        }
        // And the full protocol agrees too.
        gpu_a.reset();
        gpu_b.reset();
        let legacy = run_model(&mut gpu_a, &cfg, 1, 64, 1, 3).unwrap();
        let graphed = run_model_graph(&mut gpu_b, &cfg, 1, 64, 1, 3, 1).unwrap();
        assert_eq!(legacy.mean_s, graphed.mean_s);
    }

    #[test]
    fn generation_ground_truth_decode_steps_are_cheap_and_grow_with_cache() {
        let mut gpu = Gpu::by_name("a100").unwrap();
        let cfg = zoo::gpt2_large();
        let spec = GenerationSpec::new(256, 24);
        let run = run_generation(&mut gpu, &cfg, 1, &spec, 1).unwrap();
        assert_eq!(run.step_s.len(), 24);
        assert!(run.prefill_s > 0.0);
        // A decode step touches ~1/seq of the prefill FLOPs: it must be
        // far cheaper than the prompt pass.
        let tpot = run.time_per_output_token_s();
        assert!(tpot > 0.0 && tpot < run.prefill_s / 4.0, "tpot {tpot} vs prefill {}", run.prefill_s);
        assert!((run.total_s() - (run.prefill_s + run.step_s.iter().sum::<f64>())).abs() < 1e-15);
        // Decode-step cost grows with the cache: steps over a ~4k-token
        // cache stream ~16× the attention bytes of steps over ~260 tokens
        // (well above the ~2.5% single-execution noise).
        gpu.reset();
        let long = run_generation(&mut gpu, &cfg, 1, &GenerationSpec::new(4096, 8), 1).unwrap();
        let short_tpot = tpot;
        let long_tpot = long.time_per_output_token_s();
        assert!(
            long_tpot > short_tpot * 1.1,
            "kv≈4100 step {long_tpot} vs kv≈260 step {short_tpot}"
        );
        // OOM contract includes the grown KV cache.
        let mut small = Gpu::by_name("rtx3060m").unwrap();
        let big = GenerationSpec::new(512, 8192);
        assert!(run_generation(&mut small, &zoo::qwen3_4b(), 8, &big, 1).is_err());
    }

    #[test]
    fn extra_streams_never_slow_a_model_down() {
        let cfg = zoo::flan_t5_base(); // enc–dec: real branch concurrency
        let mut gpu_a = Gpu::by_name("a100").unwrap();
        let mut gpu_b = Gpu::by_name("a100").unwrap();
        let g = cfg.graph(1, 64);
        let one = run_graph_once(&mut gpu_a, &g, 1).unwrap();
        let four = run_graph_once(&mut gpu_b, &g, 4).unwrap();
        // Same measured kernel durations (identical issue order), so the
        // multi-stream makespan can only shrink.
        assert!(four <= one * (1.0 + 1e-12), "4 streams {four} vs 1 stream {one}");
        assert!(four < one, "enc–dec branches must actually overlap");
    }
}
