//! Ground-truth model execution on the simulator: run a model's kernel
//! trace end-to-end (with the paper's 5-warmup / 25-measurement protocol)
//! and report the mean latency — the MeanT columns of Tables IV/V.

use crate::gpusim::{ExecError, FreqMode, Gpu};
use crate::ops::Op;

use super::transformer::TransformerConfig;

/// Measured model execution.
#[derive(Clone, Copy, Debug)]
pub struct ModelRun {
    pub mean_s: f64,
    pub reps: usize,
}

/// Execute a trace once, summing per-kernel durations (sequential CUDA
/// stream semantics).
pub fn run_trace_once(gpu: &mut Gpu, trace: &[Op]) -> Result<f64, ExecError> {
    let mut total = 0.0;
    for op in trace {
        total += gpu.exec(op)?.dur_s;
    }
    Ok(total)
}

/// Predict a whole model through the prediction service (trace-level API):
/// the coordinator batches GEMM lanes through the PJRT artifact, fans the
/// rest across its thread pool, and memoizes repeated layers — so the
/// runner is a *consumer of the service*, not of raw `Pm2Lat`. Returns
/// `Ok(None)` when any op is unsupported on the device.
pub fn predict_model(
    coord: &crate::coordinator::Coordinator<'_>,
    device: &str,
    cfg: &TransformerConfig,
    batch: usize,
    seq: usize,
) -> anyhow::Result<Option<f64>> {
    use crate::coordinator::{PredictorKind, TraceRequest};
    let req = TraceRequest {
        device: device.to_string(),
        trace: cfg.trace(batch, seq),
        kind: PredictorKind::Pm2LatBatched,
    };
    let mut out = coord.submit_traces(std::slice::from_ref(&req))?;
    Ok(out.pop().unwrap_or(None))
}

/// Paper protocol (§IV-B): warm-up ×5, then 25 measured repetitions.
pub fn run_model(
    gpu: &mut Gpu,
    cfg: &TransformerConfig,
    batch: usize,
    seq: usize,
    warmup: usize,
    reps: usize,
) -> Result<ModelRun, ExecError> {
    gpu.check_memory(cfg.memory_bytes(batch, seq))?;
    gpu.set_freq(FreqMode::Boost);
    let trace = cfg.trace(batch, seq);
    for _ in 0..warmup {
        run_trace_once(gpu, &trace)?;
    }
    let mut total = 0.0;
    for _ in 0..reps {
        total += run_trace_once(gpu, &trace)?;
    }
    Ok(ModelRun { mean_s: total / reps as f64, reps })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::zoo;

    #[test]
    fn gpt2_runs_on_a100() {
        let mut gpu = Gpu::by_name("a100").unwrap();
        let cfg = zoo::gpt2_large();
        let run = run_model(&mut gpu, &cfg, 1, 128, 1, 3).unwrap();
        assert!(run.mean_s > 0.0);
    }

    #[test]
    fn oom_cells_match_capacity() {
        // Qwen3-4B BF16 (~8 GB weights) cannot run on the 6 GB 3060M —
        // a "-" cell of Table IV.
        let mut gpu = Gpu::by_name("rtx3060m").unwrap();
        let cfg = zoo::qwen3_4b();
        assert!(matches!(
            run_model(&mut gpu, &cfg, 1, 512, 0, 1),
            Err(ExecError::OutOfMemory { .. })
        ));
        // And DS-R1-14B not even on the 24 GB L4 at batch 8.
        let mut l4 = Gpu::by_name("l4").unwrap();
        assert!(run_model(&mut l4, &zoo::deepseek_r1_14b(), 8, 512, 0, 1).is_err());
    }

    #[test]
    fn bf16_model_rejected_on_t4() {
        let mut gpu = Gpu::by_name("t4").unwrap();
        let cfg = zoo::qwen3_0_6b();
        assert!(run_model(&mut gpu, &cfg, 1, 128, 0, 1).is_err());
    }

    #[test]
    fn latency_scales_with_batch() {
        let mut gpu = Gpu::by_name("a100").unwrap();
        let cfg = zoo::qwen3_0_6b();
        let b1 = run_model(&mut gpu, &cfg, 1, 256, 1, 3).unwrap().mean_s;
        gpu.reset();
        let b8 = run_model(&mut gpu, &cfg, 8, 256, 1, 3).unwrap().mean_s;
        assert!(b8 > b1, "batch 8 slower than 1");
        // ...but sublinearly (wave quantization + underutilized small
        // batches — the paper's A100 anomaly).
        assert!(b8 < b1 * 8.0);
    }
}
