//! Transformer graph builder: a model configuration expands into the
//! per-layer CUDA-kernel trace (the `Op` sequence) that both the simulator
//! executes for ground truth and the predictors sum over (paper §IV-B).
//! Inference/prefill only — the paper evaluates inference and notes the
//! backward pass reuses the same kernel types.

use crate::ops::{DType, GemmOp, Op, UtilKind, UtilOp};

/// Architecture description (decoder-only or encoder–decoder).
#[derive(Clone, Debug)]
pub struct TransformerConfig {
    pub name: &'static str,
    /// Reported parameter count (for Table III).
    pub params_b: f64,
    pub layers: usize,
    /// Encoder layers (encoder–decoder models only).
    pub enc_layers: usize,
    pub hidden: usize,
    pub heads: usize,
    /// KV heads (GQA); == heads for MHA.
    pub kv_heads: usize,
    pub ffn_hidden: usize,
    pub vocab: usize,
    pub dtype: DType,
    /// Gated FFN (SwiGLU / gated GeLU): up + gate + down projections.
    pub gated_ffn: bool,
}

impl TransformerConfig {
    pub fn head_dim(&self) -> usize {
        self.hidden / self.heads
    }

    /// Exact weight parameter count from the architecture.
    pub fn weight_params(&self) -> f64 {
        let h = self.hidden as f64;
        let hd = self.head_dim() as f64;
        let q = h * h;
        let kv = 2.0 * h * (self.kv_heads as f64 * hd);
        let o = h * h;
        let ffn = if self.gated_ffn {
            3.0 * h * self.ffn_hidden as f64
        } else {
            2.0 * h * self.ffn_hidden as f64
        };
        let per_layer = q + kv + o + ffn + 2.0 * h;
        let dec = self.layers as f64 * per_layer;
        // Encoder layers + decoder cross-attention.
        let enc = self.enc_layers as f64 * per_layer;
        let cross = if self.enc_layers > 0 {
            self.layers as f64 * (q + kv + o)
        } else {
            0.0
        };
        let embed = self.vocab as f64 * h;
        dec + enc + cross + embed
    }

    pub fn weight_bytes(&self) -> f64 {
        self.weight_params() * self.dtype.bytes() as f64
    }

    /// Peak activation estimate for (batch, seq) prefill: transient
    /// buffers + materialized attention scores + framework overhead.
    pub fn activation_bytes(&self, batch: usize, seq: usize) -> f64 {
        let d = self.dtype.bytes() as f64;
        let per_sample = seq as f64 * self.hidden.max(self.ffn_hidden) as f64 * d * 6.0
            + self.heads as f64 * (seq as f64).powi(2) * d * 2.0;
        batch as f64 * per_sample
    }

    /// Total memory needed (weights + activations + CUDA context).
    pub fn memory_bytes(&self, batch: usize, seq: usize) -> f64 {
        self.weight_bytes() + self.activation_bytes(batch, seq) + 0.7e9
    }

    /// One attention + FFN block's kernel trace (self-attention).
    fn block_trace(&self, batch: usize, seq: usize, out: &mut Vec<Op>) {
        let dt = self.dtype;
        let h = self.hidden;
        let hd = self.head_dim();
        let rows = batch * seq;
        let kv_dim = self.kv_heads * hd;
        // Pre-norm.
        out.push(Op::Util(UtilOp::new(UtilKind::LayerNorm, rows, h, dt)));
        // QKV projection (fused as one Linear, TN like torch Linear).
        out.push(Op::Gemm(GemmOp::linear(rows, h + 2 * kv_dim, h, dt)));
        // Attention scores + weighted values as batched MatMul (the
        // non-fused PyTorch/ONNX path the paper's Table II "BMM" row
        // profiles), plus the softmax.
        out.push(Op::Gemm(GemmOp::bmm(batch * self.heads, seq, seq, hd, dt)));
        out.push(Op::Util(UtilOp::new(
            UtilKind::Softmax,
            batch * self.heads * seq,
            seq,
            dt,
        )));
        out.push(Op::Gemm(GemmOp::bmm(batch * self.heads, seq, hd, seq, dt)));
        // Output projection + residual.
        out.push(Op::Gemm(GemmOp::linear(rows, h, h, dt)));
        out.push(Op::Util(UtilOp::new(UtilKind::Add, rows, h, dt)));
        // FFN.
        out.push(Op::Util(UtilOp::new(UtilKind::LayerNorm, rows, h, dt)));
        if self.gated_ffn {
            out.push(Op::Gemm(GemmOp::linear(rows, 2 * self.ffn_hidden, h, dt)));
            out.push(Op::Util(UtilOp::new(UtilKind::Gelu, rows, self.ffn_hidden, dt)));
            out.push(Op::Util(UtilOp::new(UtilKind::Mul, rows, self.ffn_hidden, dt)));
        } else {
            out.push(Op::Gemm(GemmOp::linear(rows, self.ffn_hidden, h, dt)));
            out.push(Op::Util(UtilOp::new(UtilKind::Gelu, rows, self.ffn_hidden, dt)));
        }
        out.push(Op::Gemm(GemmOp::linear(rows, h, self.ffn_hidden, dt)));
        out.push(Op::Util(UtilOp::new(UtilKind::Add, rows, h, dt)));
    }

    /// Full inference (prefill) trace for (batch, seq).
    pub fn trace(&self, batch: usize, seq: usize) -> Vec<Op> {
        let mut out = Vec::new();
        // Encoder stack (enc–dec models).
        for _ in 0..self.enc_layers {
            self.block_trace(batch, seq, &mut out);
        }
        // Decoder stack (+ cross-attention for enc–dec).
        for _ in 0..self.layers {
            self.block_trace(batch, seq, &mut out);
            if self.enc_layers > 0 {
                let dt = self.dtype;
                let h = self.hidden;
                let hd = self.head_dim();
                let rows = batch * seq;
                out.push(Op::Util(UtilOp::new(UtilKind::LayerNorm, rows, h, dt)));
                out.push(Op::Gemm(GemmOp::linear(rows, h, h, dt))); // Q
                out.push(Op::Gemm(GemmOp::linear(rows, 2 * h, h, dt))); // KV from enc
                out.push(Op::Gemm(GemmOp::bmm(batch * self.heads, seq, seq, hd, dt)));
                out.push(Op::Util(UtilOp::new(UtilKind::Softmax, batch * self.heads * seq, seq, dt)));
                out.push(Op::Gemm(GemmOp::bmm(batch * self.heads, seq, hd, seq, dt)));
                out.push(Op::Gemm(GemmOp::linear(rows, h, h, dt)));
                out.push(Op::Util(UtilOp::new(UtilKind::Add, rows, h, dt)));
            }
        }
        // Final norm + LM head.
        out.push(Op::Util(UtilOp::new(UtilKind::LayerNorm, batch * seq, self.hidden, self.dtype)));
        out.push(Op::Gemm(GemmOp::linear(batch * seq, self.vocab, self.hidden, self.dtype)));
        out
    }

    /// Trace of a contiguous decoder-block range [lo, hi) — the unit the
    /// partitioner (§IV-D1) splits on. `include_head` appends the LM head.
    pub fn block_range_trace(
        &self,
        batch: usize,
        seq: usize,
        lo: usize,
        hi: usize,
        include_head: bool,
    ) -> Vec<Op> {
        let mut out = Vec::new();
        for _ in lo..hi.min(self.layers) {
            self.block_trace(batch, seq, &mut out);
        }
        if include_head {
            out.push(Op::Util(UtilOp::new(UtilKind::LayerNorm, batch * seq, self.hidden, self.dtype)));
            out.push(Op::Gemm(GemmOp::linear(batch * seq, self.vocab, self.hidden, self.dtype)));
        }
        out
    }

    /// Weight bytes of a block range (+ embeddings/head on the end hosts).
    pub fn block_range_weight_bytes(&self, lo: usize, hi: usize, include_head: bool) -> f64 {
        let h = self.hidden as f64;
        let hd = self.head_dim() as f64;
        let ffn = if self.gated_ffn {
            3.0 * h * self.ffn_hidden as f64
        } else {
            2.0 * h * self.ffn_hidden as f64
        };
        let per_layer =
            h * h * 2.0 + 2.0 * h * (self.kv_heads as f64 * hd) + ffn + 2.0 * h;
        let mut params = (hi.min(self.layers) - lo) as f64 * per_layer;
        if include_head {
            params += self.vocab as f64 * h;
        }
        if lo == 0 {
            params += self.vocab as f64 * h; // embedding table
        }
        params * self.dtype.bytes() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::zoo;

    #[test]
    fn trace_structure_counts() {
        let cfg = zoo::gpt2_large();
        let trace = cfg.trace(1, 512);
        let gemms = trace.iter().filter(|o| matches!(o, Op::Gemm(_))).count();
        // 5 GEMMs per block (qkv, 2 bmm, out, ffn-up, ffn-down = 6) + head.
        assert_eq!(gemms, cfg.layers * 6 + 1);
        let softmaxes = trace
            .iter()
            .filter(|o| matches!(o, Op::Util(u) if u.kind == UtilKind::Softmax))
            .count();
        assert_eq!(softmaxes, cfg.layers);
    }

    #[test]
    fn gated_ffn_adds_mul() {
        let cfg = zoo::qwen3_0_6b();
        let trace = cfg.trace(1, 128);
        assert!(trace
            .iter()
            .any(|o| matches!(o, Op::Util(u) if u.kind == UtilKind::Mul)));
    }

    #[test]
    fn enc_dec_has_cross_attention() {
        let t5 = zoo::flan_t5_base();
        let plain = zoo::gpt2_large();
        let t5_gemms_per_layer = t5.trace(1, 128).iter().filter(|o| matches!(o, Op::Gemm(_))).count()
            as f64
            / (t5.layers + t5.enc_layers) as f64;
        let gpt_gemms_per_layer = plain.trace(1, 128).iter().filter(|o| matches!(o, Op::Gemm(_))).count()
            as f64
            / plain.layers as f64;
        assert!(t5_gemms_per_layer > gpt_gemms_per_layer);
    }

    #[test]
    fn block_range_composes_to_full_decoder() {
        let cfg = zoo::qwen3_4b();
        let a = cfg.block_range_trace(2, 256, 0, 12, false);
        let b = cfg.block_range_trace(2, 256, 12, cfg.layers, true);
        let full = cfg.trace(2, 256);
        assert_eq!(a.len() + b.len(), full.len());
    }

    #[test]
    fn block_weights_sum_to_total() {
        let cfg = zoo::qwen3_4b();
        let a = cfg.block_range_weight_bytes(0, 12, false);
        let b = cfg.block_range_weight_bytes(12, cfg.layers, true);
        // The split holds the untied LM head on the tail device, so the
        // sum exceeds the (tied-embedding) total by exactly vocab × h.
        let total = cfg.weight_bytes()
            + (cfg.vocab * cfg.hidden * cfg.dtype.bytes()) as f64;
        let sum = a + b;
        assert!((sum - total).abs() / total < 0.01, "{sum} vs {total}");
    }
}
