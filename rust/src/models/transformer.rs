//! Transformer graph builder: a model configuration expands into a typed
//! [`ModelGraph`] — the canonical representation the simulator executes,
//! the predictors schedule, and the fusion passes rewrite. The legacy
//! flat kernel trace (paper §IV-B) is the graph's lossless lowered view:
//! `trace()` returns exactly the op sequence the pre-graph builder
//! emitted, so every sequential consumer is unchanged.
//!
//! Both generation phases are first-class:
//!
//! * **prefill** ([`TransformerConfig::graph`]): the whole prompt in one
//!   forward pass (`q == kv == seq`), decoder self-attention annotated
//!   causal so the fusion pass can emit masked kernels;
//! * **decode** ([`TransformerConfig::decode_graph`]): one autoregressive
//!   step (`q == 1`) reading a KV cache of `kv_len` entries — every GEMM
//!   collapses to a gemv-degenerate projection and attention becomes a
//!   KV-bound cache stream, the regime where NeuSight-style predictors
//!   degrade hardest. [`GenerationSpec`] expands a (prompt, generate)
//!   request into the prefill graph plus one decode graph per emitted
//!   token; KV shapes are GQA-aware throughout (`kv_heads` drive the
//!   projection widths and cache footprint).

use crate::graph::{ModelGraph, NodeId};
use crate::ops::{DType, GemmOp, Op, UtilKind, UtilOp};

/// One generation request: run the prompt through prefill, then emit
/// `gen_len` tokens autoregressively. Decode step `t` attends a cache of
/// [`GenerationSpec::kv_len_at`]`(t) = prompt_len + t + 1` entries (the
/// prompt, the previously generated tokens, and the token being
/// processed, whose K/V rows are appended this step).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct GenerationSpec {
    pub prompt_len: usize,
    pub gen_len: usize,
}

impl GenerationSpec {
    /// Panics on an empty prompt (a contract violation, like feeding a
    /// zero-dimension GEMM anywhere else in the op vocabulary); callers
    /// holding user input should clamp or validate first — the CLI does.
    pub fn new(prompt_len: usize, gen_len: usize) -> GenerationSpec {
        assert!(prompt_len >= 1, "generation needs a non-empty prompt");
        GenerationSpec { prompt_len, gen_len }
    }

    /// KV-cache length decode step `t` (0-based) attends.
    pub fn kv_len_at(&self, step: usize) -> usize {
        self.prompt_len + step + 1
    }

    /// Total context length after the final step.
    pub fn total_len(&self) -> usize {
        self.prompt_len + self.gen_len
    }
}

/// One sequence's contribution to a mixed continuous-batching iteration:
/// it processes `q_len` new tokens against a KV window of `kv_len`
/// entries (`kv_len` counts the new tokens — their K/V rows are appended
/// by this iteration's QKV projection, the same convention as
/// [`GenerationSpec::kv_len_at`]). A whole-prompt prefill is
/// `{q: prompt, kv: prompt}`, a chunked-prefill continuation is
/// `{q: chunk, kv: done + chunk}`, and a decode step is
/// `{q: 1, kv: ctx + 1}`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SeqSlot {
    pub q_len: usize,
    pub kv_len: usize,
}

impl SeqSlot {
    pub fn prefill(done: usize, chunk: usize) -> SeqSlot {
        SeqSlot { q_len: chunk, kv_len: done + chunk }
    }

    pub fn decode(ctx: usize) -> SeqSlot {
        SeqSlot { q_len: 1, kv_len: ctx + 1 }
    }
}

/// Architecture description (decoder-only or encoder–decoder).
#[derive(Clone, Debug)]
pub struct TransformerConfig {
    pub name: &'static str,
    /// Reported parameter count (for Table III).
    pub params_b: f64,
    pub layers: usize,
    /// Encoder layers (encoder–decoder models only).
    pub enc_layers: usize,
    pub hidden: usize,
    pub heads: usize,
    /// KV heads (GQA); == heads for MHA.
    pub kv_heads: usize,
    pub ffn_hidden: usize,
    pub vocab: usize,
    pub dtype: DType,
    /// Gated FFN (SwiGLU / gated GeLU): up + gate + down projections.
    pub gated_ffn: bool,
}

impl TransformerConfig {
    pub fn head_dim(&self) -> usize {
        self.hidden / self.heads
    }

    /// Exact weight parameter count from the architecture.
    pub fn weight_params(&self) -> f64 {
        let h = self.hidden as f64;
        let hd = self.head_dim() as f64;
        let q = h * h;
        let kv = 2.0 * h * (self.kv_heads as f64 * hd);
        let o = h * h;
        let ffn = if self.gated_ffn {
            3.0 * h * self.ffn_hidden as f64
        } else {
            2.0 * h * self.ffn_hidden as f64
        };
        let per_layer = q + kv + o + ffn + 2.0 * h;
        let dec = self.layers as f64 * per_layer;
        // Encoder layers + decoder cross-attention.
        let enc = self.enc_layers as f64 * per_layer;
        let cross = if self.enc_layers > 0 {
            self.layers as f64 * (q + kv + o)
        } else {
            0.0
        };
        let embed = self.vocab as f64 * h;
        dec + enc + cross + embed
    }

    pub fn weight_bytes(&self) -> f64 {
        self.weight_params() * self.dtype.bytes() as f64
    }

    /// Peak activation estimate for (batch, seq) prefill: transient
    /// buffers + materialized attention scores + framework overhead.
    pub fn activation_bytes(&self, batch: usize, seq: usize) -> f64 {
        let d = self.dtype.bytes() as f64;
        let per_sample = seq as f64 * self.hidden.max(self.ffn_hidden) as f64 * d * 6.0
            + self.heads as f64 * (seq as f64).powi(2) * d * 2.0;
        batch as f64 * per_sample
    }

    /// Total memory needed (weights + activations + CUDA context).
    pub fn memory_bytes(&self, batch: usize, seq: usize) -> f64 {
        self.weight_bytes() + self.activation_bytes(batch, seq) + 0.7e9
    }

    /// One attention + FFN block (self-attention) appended to the graph.
    /// `input` is the incoming residual stream (None for the first block,
    /// where embeddings are not modeled as ops); the returned node is the
    /// block's residual output. Node insertion order matches the legacy
    /// flat trace exactly, so lowering reproduces it.
    ///
    /// The block is phase-generic: prefill passes `q_len == kv_len ==
    /// seq`; a decode step passes `q_len == 1` and the cache length, so
    /// the scores/context BMMs become KV-cache streams and every
    /// projection a gemv-degenerate `batch × n × k` GEMM. `causal` marks
    /// the scores node for causal-mask propagation (decoder
    /// self-attention; encoders stay bidirectional).
    fn block_graph(
        &self,
        batch: usize,
        q_len: usize,
        kv_len: usize,
        causal: bool,
        g: &mut ModelGraph,
        input: Option<NodeId>,
    ) -> NodeId {
        let dt = self.dtype;
        let h = self.hidden;
        let hd = self.head_dim();
        let rows = batch * q_len;
        let kv_dim = self.kv_heads * hd;
        let residual: Vec<NodeId> = input.into_iter().collect();
        // Pre-norm.
        let ln1 = g.add_node(Op::Util(UtilOp::new(UtilKind::LayerNorm, rows, h, dt)), &residual);
        // QKV projection (fused as one Linear, TN like torch Linear) —
        // in decode this projects the new token only; its K/V rows are
        // the cache append.
        let qkv = g.add_node(Op::Gemm(GemmOp::linear(rows, h + 2 * kv_dim, h, dt)), &[ln1]);
        // Attention scores + weighted values as batched MatMul (the
        // non-fused PyTorch/ONNX path the paper's Table II "BMM" row
        // profiles), plus the softmax — the exact subgraph the attention
        // fusion pass rewrites to FlashAttn/CutlassAttn.
        let scores = g.add_node(
            Op::Gemm(GemmOp::bmm(batch * self.heads, q_len, kv_len, hd, dt)),
            &[qkv],
        );
        if causal {
            g.mark_causal(scores);
        }
        if self.kv_heads < self.heads {
            // GQA: the BMM itself is MHA-expanded (repeat-interleaved KV),
            // but fusion can stream the grouped cache — record how many
            // query heads share each KV lane.
            g.mark_kv_groups(scores, self.heads / self.kv_heads);
        }
        let probs = g.add_node(
            Op::Util(UtilOp::new(UtilKind::Softmax, batch * self.heads * q_len, kv_len, dt)),
            &[scores],
        );
        let ctx = g.add_node(
            Op::Gemm(GemmOp::bmm(batch * self.heads, q_len, hd, kv_len, dt)),
            &[probs, qkv],
        );
        // Output projection + residual.
        let proj = g.add_node(Op::Gemm(GemmOp::linear(rows, h, h, dt)), &[ctx]);
        let mut add1_in = vec![proj];
        add1_in.extend(input);
        let add1 = g.add_node(Op::Util(UtilOp::new(UtilKind::Add, rows, h, dt)), &add1_in);
        // FFN.
        let ln2 = g.add_node(Op::Util(UtilOp::new(UtilKind::LayerNorm, rows, h, dt)), &[add1]);
        let ffn_out = if self.gated_ffn {
            let upgate = g.add_node(
                Op::Gemm(GemmOp::linear(rows, 2 * self.ffn_hidden, h, dt)),
                &[ln2],
            );
            let act = g.add_node(
                Op::Util(UtilOp::new(UtilKind::Gelu, rows, self.ffn_hidden, dt)),
                &[upgate],
            );
            // Gate: the activated half times the gate half of `upgate`.
            g.add_node(
                Op::Util(UtilOp::new(UtilKind::Mul, rows, self.ffn_hidden, dt)),
                &[act, upgate],
            )
        } else {
            let up = g.add_node(
                Op::Gemm(GemmOp::linear(rows, self.ffn_hidden, h, dt)),
                &[ln2],
            );
            g.add_node(
                Op::Util(UtilOp::new(UtilKind::Gelu, rows, self.ffn_hidden, dt)),
                &[up],
            )
        };
        let down =
            g.add_node(Op::Gemm(GemmOp::linear(rows, h, self.ffn_hidden, dt)), &[ffn_out]);
        g.add_node(Op::Util(UtilOp::new(UtilKind::Add, rows, h, dt)), &[down, add1])
    }

    /// Decoder cross-attention (enc–dec models): attends from the decoder
    /// residual `dec` over the encoder output `enc`. The Q and KV
    /// projections read different streams, so they are independent
    /// branches a multi-stream schedule can overlap.
    fn cross_attn_graph(
        &self,
        batch: usize,
        seq: usize,
        g: &mut ModelGraph,
        dec: NodeId,
        enc: NodeId,
    ) -> NodeId {
        let dt = self.dtype;
        let h = self.hidden;
        let hd = self.head_dim();
        let rows = batch * seq;
        let ln = g.add_node(Op::Util(UtilOp::new(UtilKind::LayerNorm, rows, h, dt)), &[dec]);
        let q = g.add_node(Op::Gemm(GemmOp::linear(rows, h, h, dt)), &[ln]);
        let kv = g.add_node(Op::Gemm(GemmOp::linear(rows, 2 * h, h, dt)), &[enc]);
        let scores =
            g.add_node(Op::Gemm(GemmOp::bmm(batch * self.heads, seq, seq, hd, dt)), &[q, kv]);
        let probs = g.add_node(
            Op::Util(UtilOp::new(UtilKind::Softmax, batch * self.heads * seq, seq, dt)),
            &[scores],
        );
        let ctx = g.add_node(
            Op::Gemm(GemmOp::bmm(batch * self.heads, seq, hd, seq, dt)),
            &[probs, kv],
        );
        let proj = g.add_node(Op::Gemm(GemmOp::linear(rows, h, h, dt)), &[ctx]);
        g.add_node(Op::Util(UtilOp::new(UtilKind::Add, rows, h, dt)), &[proj, dec])
    }

    /// Final norm + LM head; marks the head as the graph output.
    fn head_graph(&self, batch: usize, seq: usize, g: &mut ModelGraph, input: Option<NodeId>) {
        let residual: Vec<NodeId> = input.into_iter().collect();
        let ln = g.add_node(
            Op::Util(UtilOp::new(UtilKind::LayerNorm, batch * seq, self.hidden, self.dtype)),
            &residual,
        );
        let head = g.add_node(
            Op::Gemm(GemmOp::linear(batch * seq, self.vocab, self.hidden, self.dtype)),
            &[ln],
        );
        g.mark_output(head);
    }

    /// Full inference (prefill) model graph for (batch, seq). The decoder
    /// stack depends on the encoder only through cross-attention KV, so
    /// decoder self-attention prefixes are schedulable concurrently with
    /// the encoder on multi-stream devices. Decoder self-attention scores
    /// are annotated causal (encoders stay bidirectional), so the
    /// standard pass pipeline fuses them into masked kernels.
    pub fn graph(&self, batch: usize, seq: usize) -> ModelGraph {
        let mut g = ModelGraph::new();
        // Encoder stack (enc–dec models): bidirectional.
        let mut enc_last: Option<NodeId> = None;
        for _ in 0..self.enc_layers {
            enc_last = Some(self.block_graph(batch, seq, seq, false, &mut g, enc_last));
        }
        // Decoder stack (+ cross-attention for enc–dec): causal.
        let mut cur: Option<NodeId> = None;
        for _ in 0..self.layers {
            let block = self.block_graph(batch, seq, seq, true, &mut g, cur);
            cur = Some(if self.enc_layers > 0 {
                let enc = enc_last.expect("encoder stack precedes cross-attention");
                self.cross_attn_graph(batch, seq, &mut g, block, enc)
            } else {
                block
            });
        }
        self.head_graph(batch, seq, &mut g, cur);
        g
    }

    /// One autoregressive decode step as a model graph: `q_len = 1` per
    /// sample, self-attention reading a KV cache of `kv_len` entries
    /// (`kv_len` counts the token being generated — its K/V rows are
    /// appended by this step's QKV projection). Every projection is a
    /// `batch × n × k` gemv-degenerate GEMM and the attention BMMs are
    /// KV-cache streams, so the whole step prices through the
    /// memory-bound routes. For enc–dec models the per-layer
    /// cross-attention reads its cached encoder KV, approximated at
    /// `kv_len` entries — callers that know the true prompt length should
    /// use [`TransformerConfig::decode_graph_with_cross`], which this
    /// method delegates to.
    pub fn decode_graph(&self, batch: usize, kv_len: usize) -> ModelGraph {
        self.decode_graph_with_cross(batch, kv_len, kv_len)
    }

    /// [`TransformerConfig::decode_graph`] with the cached cross-KV
    /// length spelled out: enc–dec cross-attention reads exactly
    /// `cross_len` encoder entries per layer — the prompt length, fixed
    /// at prefill — instead of the growing `kv_len` (which overestimated
    /// every late step). `cross_len` is ignored by decoder-only models,
    /// and `cross_len == kv_len` reproduces the legacy approximation.
    pub fn decode_graph_with_cross(
        &self,
        batch: usize,
        kv_len: usize,
        cross_len: usize,
    ) -> ModelGraph {
        assert!(kv_len >= 1, "decode step needs a non-empty KV cache");
        assert!(
            self.enc_layers == 0 || cross_len >= 1,
            "enc–dec decode needs a non-empty cross KV cache"
        );
        let mut g = ModelGraph::new();
        let mut cur: Option<NodeId> = None;
        for _ in 0..self.layers {
            let block = self.block_graph(batch, 1, kv_len, true, &mut g, cur);
            cur = Some(if self.enc_layers > 0 {
                self.cross_attn_decode_graph(batch, cross_len, &mut g, block)
            } else {
                block
            });
        }
        self.head_graph(batch, 1, &mut g, cur);
        g
    }

    /// Lowered view of [`TransformerConfig::decode_graph`].
    pub fn decode_trace(&self, batch: usize, kv_len: usize) -> Vec<Op> {
        self.decode_graph(batch, kv_len).lower()
    }

    /// One speculative-decoding *verification* iteration: the target
    /// model scores `k` draft tokens plus its own next-token position in
    /// a single pass — `q_len = k + 1` new queries against a KV cache of
    /// `kv_len` entries (`kv_len` counts the speculated window, whose
    /// K/V rows this pass appends). Attention over the window is
    /// *rectangular causal* — exactly the chunked-prefill slot shape the
    /// existing `q_len`/`kv_len` machinery and `CausalMaskPropagation`
    /// already price, which makes this a graph builder, not an ops
    /// change. `k = 0` (no speculation: score one token against the
    /// cache) emits node-for-node the graph of
    /// [`TransformerConfig::decode_graph`] — the degenerate anchor
    /// `tests/spec_decode.rs` pins bit for bit.
    pub fn verify_graph(&self, batch: usize, kv_len: usize, k: usize) -> ModelGraph {
        assert_eq!(self.enc_layers, 0, "speculative verification is decoder-only");
        assert!(kv_len >= k + 1, "kv window must cover the speculated tokens");
        let mut g = ModelGraph::new();
        let mut cur: Option<NodeId> = None;
        for _ in 0..self.layers {
            cur = Some(self.block_graph(batch, k + 1, kv_len, true, &mut g, cur));
        }
        self.head_graph(batch, k + 1, &mut g, cur);
        g
    }

    /// One tensor-parallel rank's prefill graph: [`TransformerConfig::graph`]
    /// rewritten by [`crate::graph::TensorParallelPass`] — sharded GEMMs
    /// plus the AllReduces that stitch the ranks together. `tp <= 1`
    /// skips the pass entirely, so the single-device placement is the
    /// plain builder output bit for bit.
    pub fn graph_tp(&self, batch: usize, seq: usize, tp: usize) -> ModelGraph {
        Self::apply_tp(self.graph(batch, seq), tp)
    }

    /// One tensor-parallel rank's decode-step graph (see
    /// [`TransformerConfig::graph_tp`]).
    pub fn decode_graph_tp(&self, batch: usize, kv_len: usize, tp: usize) -> ModelGraph {
        Self::apply_tp(self.decode_graph(batch, kv_len), tp)
    }

    fn apply_tp(mut g: ModelGraph, tp: usize) -> ModelGraph {
        if tp > 1 {
            use crate::graph::{Pass, PassCtx, TensorParallelPass};
            TensorParallelPass { tp }.run(&mut g, &PassCtx::structural());
        }
        g
    }

    /// Decode-step cross-attention (enc–dec models): the new token's
    /// query against the *cached* encoder KV — no per-step KV projection,
    /// that cost was paid once at prefill.
    fn cross_attn_decode_graph(
        &self,
        batch: usize,
        cross_len: usize,
        g: &mut ModelGraph,
        dec: NodeId,
    ) -> NodeId {
        let dt = self.dtype;
        let h = self.hidden;
        let hd = self.head_dim();
        let ln = g.add_node(Op::Util(UtilOp::new(UtilKind::LayerNorm, batch, h, dt)), &[dec]);
        let q = g.add_node(Op::Gemm(GemmOp::linear(batch, h, h, dt)), &[ln]);
        let scores = g.add_node(
            Op::Gemm(GemmOp::bmm(batch * self.heads, 1, cross_len, hd, dt)),
            &[q],
        );
        let probs = g.add_node(
            Op::Util(UtilOp::new(UtilKind::Softmax, batch * self.heads, cross_len, dt)),
            &[scores],
        );
        let ctx = g.add_node(
            Op::Gemm(GemmOp::bmm(batch * self.heads, 1, hd, cross_len, dt)),
            &[probs],
        );
        let proj = g.add_node(Op::Gemm(GemmOp::linear(batch, h, h, dt)), &[ctx]);
        g.add_node(Op::Util(UtilOp::new(UtilKind::Add, batch, h, dt)), &[proj, dec])
    }

    /// One continuous-batching iteration as a model graph: a *ragged*
    /// batch where every sequence contributes its own `(q_len, kv_len)`
    /// window — prefill chunks (`q > 1`) and decode steps (`q == 1`)
    /// mixed freely, the iteration unit of a vLLM-style serving engine.
    ///
    /// Row-wise ops (norms, projections, FFN, LM head) flatten across the
    /// batch (`rows = Σ q_len`, exactly how a serving engine packs the
    /// ragged batch into one GEMM); attention stays per-sequence — each
    /// slot gets its own causal scores→softmax→context subgraph over its
    /// own KV window, because cache lengths differ per sequence.
    ///
    /// Two exact degenerations anchor the serving simulator to the
    /// existing prediction stack (the batch-size-1 equivalence of the
    /// ISSUE):
    ///
    /// * one slot `{q: p, kv: p}` lowers node-for-node to
    ///   [`TransformerConfig::graph`]`(1, p)` — a whole-prompt prefill;
    /// * one slot `{q: 1, kv: t}` lowers node-for-node to
    ///   [`TransformerConfig::decode_graph`]`(1, t)` — one decode step.
    ///
    /// Decoder-only models only (serving simulation targets LLM decoders;
    /// enc–dec serving would need per-slot cross-KV bookkeeping).
    pub fn mixed_batch_graph(&self, slots: &[SeqSlot]) -> ModelGraph {
        assert!(!slots.is_empty(), "an iteration needs at least one sequence");
        assert_eq!(
            self.enc_layers, 0,
            "mixed-batch serving graphs are decoder-only"
        );
        for s in slots {
            assert!(s.q_len >= 1, "empty query window");
            assert!(s.kv_len >= s.q_len, "kv window must cover the new tokens");
        }
        let mut g = ModelGraph::new();
        let mut cur: Option<NodeId> = None;
        for _ in 0..self.layers {
            cur = Some(self.mixed_block_graph(slots, &mut g, cur));
        }
        let rows: usize = slots.iter().map(|s| s.q_len).sum();
        self.head_graph(1, rows, &mut g, cur);
        g
    }

    /// One decoder block over a ragged slot batch. With a single slot
    /// this emits exactly the node sequence of
    /// [`TransformerConfig::block_graph`]`(1, q, kv, causal)` — the
    /// anchor for the serving simulator's bit-for-bit equivalence.
    fn mixed_block_graph(
        &self,
        slots: &[SeqSlot],
        g: &mut ModelGraph,
        input: Option<NodeId>,
    ) -> NodeId {
        let dt = self.dtype;
        let h = self.hidden;
        let hd = self.head_dim();
        let rows: usize = slots.iter().map(|s| s.q_len).sum();
        let kv_dim = self.kv_heads * hd;
        let residual: Vec<NodeId> = input.into_iter().collect();
        let ln1 = g.add_node(Op::Util(UtilOp::new(UtilKind::LayerNorm, rows, h, dt)), &residual);
        // One packed QKV projection over every sequence's new tokens.
        let qkv = g.add_node(Op::Gemm(GemmOp::linear(rows, h + 2 * kv_dim, h, dt)), &[ln1]);
        // Per-sequence attention: each slot reads its own KV window.
        let mut ctxs: Vec<NodeId> = Vec::with_capacity(slots.len());
        for s in slots {
            let scores = g.add_node(
                Op::Gemm(GemmOp::bmm(self.heads, s.q_len, s.kv_len, hd, dt)),
                &[qkv],
            );
            g.mark_causal(scores);
            if self.kv_heads < self.heads {
                g.mark_kv_groups(scores, self.heads / self.kv_heads);
            }
            let probs = g.add_node(
                Op::Util(UtilOp::new(UtilKind::Softmax, self.heads * s.q_len, s.kv_len, dt)),
                &[scores],
            );
            ctxs.push(g.add_node(
                Op::Gemm(GemmOp::bmm(self.heads, s.q_len, hd, s.kv_len, dt)),
                &[probs, qkv],
            ));
        }
        let proj = g.add_node(Op::Gemm(GemmOp::linear(rows, h, h, dt)), &ctxs);
        let mut add1_in = vec![proj];
        add1_in.extend(input);
        let add1 = g.add_node(Op::Util(UtilOp::new(UtilKind::Add, rows, h, dt)), &add1_in);
        let ln2 = g.add_node(Op::Util(UtilOp::new(UtilKind::LayerNorm, rows, h, dt)), &[add1]);
        let ffn_out = if self.gated_ffn {
            let upgate = g.add_node(
                Op::Gemm(GemmOp::linear(rows, 2 * self.ffn_hidden, h, dt)),
                &[ln2],
            );
            let act = g.add_node(
                Op::Util(UtilOp::new(UtilKind::Gelu, rows, self.ffn_hidden, dt)),
                &[upgate],
            );
            g.add_node(
                Op::Util(UtilOp::new(UtilKind::Mul, rows, self.ffn_hidden, dt)),
                &[act, upgate],
            )
        } else {
            let up = g.add_node(
                Op::Gemm(GemmOp::linear(rows, self.ffn_hidden, h, dt)),
                &[ln2],
            );
            g.add_node(
                Op::Util(UtilOp::new(UtilKind::Gelu, rows, self.ffn_hidden, dt)),
                &[up],
            )
        };
        let down =
            g.add_node(Op::Gemm(GemmOp::linear(rows, h, self.ffn_hidden, dt)), &[ffn_out]);
        g.add_node(Op::Util(UtilOp::new(UtilKind::Add, rows, h, dt)), &[down, add1])
    }

    /// Expand a generation request: the prefill graph over the prompt
    /// plus one decode graph per generated token (step `t` reads a cache
    /// of `prompt_len + t + 1` entries; enc–dec cross-attention reads the
    /// fixed `prompt_len` cross KV). Consecutive steps differ only in
    /// their attention ops, so per-op caches absorb the projections.
    pub fn generation_graphs(
        &self,
        batch: usize,
        spec: &GenerationSpec,
    ) -> (ModelGraph, Vec<ModelGraph>) {
        let prefill = self.graph(batch, spec.prompt_len);
        let steps = (0..spec.gen_len)
            .map(|t| {
                self.decode_graph_with_cross(batch, spec.kv_len_at(t), spec.prompt_len)
            })
            .collect();
        (prefill, steps)
    }

    /// KV-cache footprint at a context of `kv_len` tokens: per decoder
    /// layer, K and V of `kv_heads · head_dim` per token (GQA models
    /// cache `kv_heads`, not `heads` — an 4–8× footprint saving that is
    /// the point of grouped-query attention).
    pub fn kv_cache_bytes(&self, batch: usize, kv_len: usize) -> f64 {
        let per_token = 2.0 * (self.kv_heads * self.head_dim()) as f64;
        self.layers as f64
            * per_token
            * kv_len as f64
            * batch as f64
            * self.dtype.bytes() as f64
    }

    /// Cached cross-attention KV for enc–dec models: each decoder layer
    /// holds K and V of the full hidden width per encoder token (the
    /// prefill emits one `Linear(rows, 2h, h)` per layer over the
    /// encoder output). Zero for decoder-only models.
    pub fn cross_kv_cache_bytes(&self, batch: usize, prompt_len: usize) -> f64 {
        if self.enc_layers == 0 {
            return 0.0;
        }
        self.layers as f64
            * 2.0
            * self.hidden as f64
            * prompt_len as f64
            * batch as f64
            * self.dtype.bytes() as f64
    }

    /// Total memory for a generation run: weights, prefill activations,
    /// the fully grown self-attention KV cache, the cached cross KV
    /// (enc–dec models), and CUDA context.
    pub fn generation_memory_bytes(&self, batch: usize, spec: &GenerationSpec) -> f64 {
        self.memory_bytes(batch, spec.prompt_len)
            + self.kv_cache_bytes(batch, spec.total_len())
            + self.cross_kv_cache_bytes(batch, spec.prompt_len)
    }

    /// Full inference (prefill) trace for (batch, seq): the lowered view
    /// of [`TransformerConfig::graph`] — identical to the legacy flat
    /// builder's output, op for op.
    pub fn trace(&self, batch: usize, seq: usize) -> Vec<Op> {
        self.graph(batch, seq).lower()
    }

    /// Graph of a contiguous decoder-block range [lo, hi) — the unit the
    /// partitioner (§IV-D1) splits on. `include_head` appends the LM head.
    pub fn block_range_graph(
        &self,
        batch: usize,
        seq: usize,
        lo: usize,
        hi: usize,
        include_head: bool,
    ) -> ModelGraph {
        let mut g = ModelGraph::new();
        let mut cur: Option<NodeId> = None;
        for _ in lo..hi.min(self.layers) {
            cur = Some(self.block_graph(batch, seq, seq, true, &mut g, cur));
        }
        if include_head {
            self.head_graph(batch, seq, &mut g, cur);
        } else if let Some(c) = cur {
            g.mark_output(c);
        }
        g
    }

    /// Lowered view of [`TransformerConfig::block_range_graph`].
    pub fn block_range_trace(
        &self,
        batch: usize,
        seq: usize,
        lo: usize,
        hi: usize,
        include_head: bool,
    ) -> Vec<Op> {
        self.block_range_graph(batch, seq, lo, hi, include_head).lower()
    }

    /// Weight bytes of a block range (+ embeddings/head on the end hosts).
    pub fn block_range_weight_bytes(&self, lo: usize, hi: usize, include_head: bool) -> f64 {
        let h = self.hidden as f64;
        let hd = self.head_dim() as f64;
        let ffn = if self.gated_ffn {
            3.0 * h * self.ffn_hidden as f64
        } else {
            2.0 * h * self.ffn_hidden as f64
        };
        let per_layer =
            h * h * 2.0 + 2.0 * h * (self.kv_heads as f64 * hd) + ffn + 2.0 * h;
        let mut params = (hi.min(self.layers) - lo) as f64 * per_layer;
        if include_head {
            params += self.vocab as f64 * h;
        }
        if lo == 0 {
            params += self.vocab as f64 * h; // embedding table
        }
        params * self.dtype.bytes() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::zoo;

    /// The pre-graph flat builder, kept verbatim as the regression anchor
    /// for lossless lowering: graphs must reproduce this op sequence
    /// exactly (the acceptance bar for the graph-IR refactor).
    fn legacy_trace(cfg: &TransformerConfig, batch: usize, seq: usize) -> Vec<Op> {
        let dt = cfg.dtype;
        let h = cfg.hidden;
        let hd = cfg.head_dim();
        let rows = batch * seq;
        let kv_dim = cfg.kv_heads * hd;
        let block = |out: &mut Vec<Op>| {
            out.push(Op::Util(UtilOp::new(UtilKind::LayerNorm, rows, h, dt)));
            out.push(Op::Gemm(GemmOp::linear(rows, h + 2 * kv_dim, h, dt)));
            out.push(Op::Gemm(GemmOp::bmm(batch * cfg.heads, seq, seq, hd, dt)));
            out.push(Op::Util(UtilOp::new(
                UtilKind::Softmax,
                batch * cfg.heads * seq,
                seq,
                dt,
            )));
            out.push(Op::Gemm(GemmOp::bmm(batch * cfg.heads, seq, hd, seq, dt)));
            out.push(Op::Gemm(GemmOp::linear(rows, h, h, dt)));
            out.push(Op::Util(UtilOp::new(UtilKind::Add, rows, h, dt)));
            out.push(Op::Util(UtilOp::new(UtilKind::LayerNorm, rows, h, dt)));
            if cfg.gated_ffn {
                out.push(Op::Gemm(GemmOp::linear(rows, 2 * cfg.ffn_hidden, h, dt)));
                out.push(Op::Util(UtilOp::new(UtilKind::Gelu, rows, cfg.ffn_hidden, dt)));
                out.push(Op::Util(UtilOp::new(UtilKind::Mul, rows, cfg.ffn_hidden, dt)));
            } else {
                out.push(Op::Gemm(GemmOp::linear(rows, cfg.ffn_hidden, h, dt)));
                out.push(Op::Util(UtilOp::new(UtilKind::Gelu, rows, cfg.ffn_hidden, dt)));
            }
            out.push(Op::Gemm(GemmOp::linear(rows, h, cfg.ffn_hidden, dt)));
            out.push(Op::Util(UtilOp::new(UtilKind::Add, rows, h, dt)));
        };
        let mut out = Vec::new();
        for _ in 0..cfg.enc_layers {
            block(&mut out);
        }
        for _ in 0..cfg.layers {
            block(&mut out);
            if cfg.enc_layers > 0 {
                out.push(Op::Util(UtilOp::new(UtilKind::LayerNorm, rows, h, dt)));
                out.push(Op::Gemm(GemmOp::linear(rows, h, h, dt)));
                out.push(Op::Gemm(GemmOp::linear(rows, 2 * h, h, dt)));
                out.push(Op::Gemm(GemmOp::bmm(batch * cfg.heads, seq, seq, hd, dt)));
                out.push(Op::Util(UtilOp::new(
                    UtilKind::Softmax,
                    batch * cfg.heads * seq,
                    seq,
                    dt,
                )));
                out.push(Op::Gemm(GemmOp::bmm(batch * cfg.heads, seq, hd, seq, dt)));
                out.push(Op::Gemm(GemmOp::linear(rows, h, h, dt)));
                out.push(Op::Util(UtilOp::new(UtilKind::Add, rows, h, dt)));
            }
        }
        out.push(Op::Util(UtilOp::new(UtilKind::LayerNorm, rows, h, dt)));
        out.push(Op::Gemm(GemmOp::linear(rows, cfg.vocab, h, dt)));
        out
    }

    #[test]
    fn property_lowered_graph_matches_legacy_trace_for_every_zoo_model() {
        for cfg in zoo::all_models() {
            for (batch, seq) in [(1, 128), (2, 256)] {
                let g = cfg.graph(batch, seq);
                g.validate().unwrap_or_else(|e| panic!("{}: {e}", cfg.name));
                let lowered = g.lower();
                let legacy = legacy_trace(&cfg, batch, seq);
                assert_eq!(
                    lowered, legacy,
                    "{} b={batch} s={seq}: lowering must be a permutation-free \
                     (order-exact) match of the legacy flat trace",
                    cfg.name
                );
                assert_eq!(cfg.trace(batch, seq), legacy);
            }
        }
    }

    #[test]
    fn trace_structure_counts() {
        let cfg = zoo::gpt2_large();
        let trace = cfg.trace(1, 512);
        let gemms = trace.iter().filter(|o| matches!(o, Op::Gemm(_))).count();
        // 5 GEMMs per block (qkv, 2 bmm, out, ffn-up, ffn-down = 6) + head.
        assert_eq!(gemms, cfg.layers * 6 + 1);
        let softmaxes = trace
            .iter()
            .filter(|o| matches!(o, Op::Util(u) if u.kind == UtilKind::Softmax))
            .count();
        assert_eq!(softmaxes, cfg.layers);
    }

    #[test]
    fn gated_ffn_adds_mul() {
        let cfg = zoo::qwen3_0_6b();
        let trace = cfg.trace(1, 128);
        assert!(trace
            .iter()
            .any(|o| matches!(o, Op::Util(u) if u.kind == UtilKind::Mul)));
    }

    #[test]
    fn enc_dec_has_cross_attention() {
        let t5 = zoo::flan_t5_base();
        let plain = zoo::gpt2_large();
        let t5_gemms_per_layer = t5.trace(1, 128).iter().filter(|o| matches!(o, Op::Gemm(_))).count()
            as f64
            / (t5.layers + t5.enc_layers) as f64;
        let gpt_gemms_per_layer = plain.trace(1, 128).iter().filter(|o| matches!(o, Op::Gemm(_))).count()
            as f64
            / plain.layers as f64;
        assert!(t5_gemms_per_layer > gpt_gemms_per_layer);
    }

    #[test]
    fn block_range_composes_to_full_decoder() {
        let cfg = zoo::qwen3_4b();
        let a = cfg.block_range_trace(2, 256, 0, 12, false);
        let b = cfg.block_range_trace(2, 256, 12, cfg.layers, true);
        let full = cfg.trace(2, 256);
        assert_eq!(a.len() + b.len(), full.len());
    }

    #[test]
    fn block_weights_sum_to_total() {
        let cfg = zoo::qwen3_4b();
        let a = cfg.block_range_weight_bytes(0, 12, false);
        let b = cfg.block_range_weight_bytes(12, cfg.layers, true);
        // The split holds the untied LM head on the tail device, so the
        // sum exceeds the (tied-embedding) total by exactly vocab × h.
        let total = cfg.weight_bytes()
            + (cfg.vocab * cfg.hidden * cfg.dtype.bytes()) as f64;
        let sum = a + b;
        assert!((sum - total).abs() / total < 0.01, "{sum} vs {total}");
    }

    #[test]
    fn property_decode_graph_validates_and_lowers_losslessly() {
        // ISSUE decode invariant: for every zoo model and several
        // (batch, kv) points, the decode-step graph passes structural
        // validation and its lowering is the exact lossless view.
        for cfg in zoo::all_models() {
            for (batch, kv) in [(1usize, 1usize), (1, 128), (4, 513), (8, 2048)] {
                let g = cfg.decode_graph(batch, kv);
                g.validate().unwrap_or_else(|e| panic!("{} kv={kv}: {e}", cfg.name));
                let trace = cfg.decode_trace(batch, kv);
                assert_eq!(g.lower(), trace, "{}: lossless lowering", cfg.name);
                assert_eq!(g.len(), trace.len());
                assert_eq!(g.outputs().len(), 1, "LM head marked");
                // Every GEMM in a decode step is decode-shaped: either a
                // batch-rows projection or a q=1 attention stream — all
                // gemv-degenerate at decode batch sizes.
                for op in &trace {
                    if let Op::Gemm(gm) = op {
                        assert!(
                            gm.m <= batch.max(1),
                            "{}: decode GEMM with m={} (batch {batch})",
                            cfg.name,
                            gm.m
                        );
                        if batch <= 8 {
                            assert!(crate::gpusim::gemm::is_gemv_degenerate(gm));
                        }
                    }
                }
                // Self-attention reads the whole cache.
                let has_kv_stream = trace.iter().any(|op| {
                    matches!(op, Op::Gemm(gm) if gm.m == 1 || gm.batch > 1)
                        && matches!(op, Op::Gemm(gm) if gm.n == kv || gm.k == kv)
                });
                assert!(has_kv_stream, "{}: no kv-shaped BMM at kv={kv}", cfg.name);
            }
        }
    }

    #[test]
    fn decode_graph_marks_self_attention_causal() {
        let cfg = zoo::qwen3_0_6b();
        let g = cfg.decode_graph(2, 77);
        let causal_scores = (0..g.len())
            .filter(|&i| {
                let id = crate::graph::NodeId(i);
                g.is_causal(id)
                    && matches!(g.node(id).op, Op::Gemm(gm) if gm.m == 1 && gm.n == 77)
            })
            .count();
        assert_eq!(causal_scores, cfg.layers, "one causal scores BMM per layer");
    }

    #[test]
    fn generation_spec_expands_to_prefill_plus_growing_steps() {
        let cfg = zoo::gpt2_large();
        let spec = GenerationSpec::new(128, 5);
        assert_eq!(spec.kv_len_at(0), 129);
        assert_eq!(spec.total_len(), 133);
        let (prefill, steps) = cfg.generation_graphs(2, &spec);
        assert_eq!(prefill.lower(), cfg.trace(2, 128), "prefill is the plain graph");
        assert_eq!(steps.len(), 5);
        for (t, step) in steps.iter().enumerate() {
            assert_eq!(step.lower(), cfg.decode_trace(2, 129 + t));
        }
        // gen_len = 0 degenerates to prefill-only.
        let (_, none) = cfg.generation_graphs(2, &GenerationSpec::new(128, 0));
        assert!(none.is_empty());
        // Consecutive steps share every non-attention op — the property
        // that lets the service cache absorb the projections.
        let a = steps[0].lower();
        let b = steps[1].lower();
        let shared = a.iter().filter(|op| b.contains(op)).count();
        assert!(shared * 10 >= a.len() * 7, "{shared} of {} ops shared", a.len());
    }

    #[test]
    fn cross_length_aware_decode_reads_the_cached_prompt() {
        let t5 = zoo::flan_t5_base();
        // The legacy entry point is the cross_len == kv_len delegation.
        assert_eq!(
            t5.decode_graph(1, 64).lower(),
            t5.decode_graph_with_cross(1, 64, 64).lower()
        );
        // With the true cross length, every layer's two cross-attention
        // BMMs read exactly the prompt's 100 cached entries while
        // self-attention still streams the full 200-token cache (100 and
        // 200 both exceed the head dim, so `n.max(k)` is the KV length).
        let g = t5.decode_graph_with_cross(1, 200, 100);
        g.validate().unwrap();
        let bmm_kvs: Vec<usize> = g
            .lower()
            .iter()
            .filter_map(|op| match op {
                Op::Gemm(gm) if gm.batch > 1 => Some(gm.n.max(gm.k)),
                _ => None,
            })
            .collect();
        assert_eq!(bmm_kvs.iter().filter(|&&kv| kv == 100).count(), 2 * t5.layers);
        assert_eq!(bmm_kvs.iter().filter(|&&kv| kv == 200).count(), 2 * t5.layers);
        // Decoder-only models ignore the cross length entirely.
        let cfg = zoo::gpt2_large();
        assert_eq!(
            cfg.decode_graph(1, 64).lower(),
            cfg.decode_graph_with_cross(1, 64, 7).lower()
        );
        // Generation expansion pins cross KV at the prompt length, so a
        // late step is strictly cheaper than the old approximation.
        let (_, steps) = t5.generation_graphs(1, &GenerationSpec::new(48, 3));
        for (t, s) in steps.iter().enumerate() {
            assert_eq!(s.lower(), t5.decode_graph_with_cross(1, 49 + t, 48).lower());
        }
        let flops = |g: &ModelGraph| -> f64 {
            g.lower()
                .iter()
                .filter_map(|op| match op {
                    Op::Gemm(gm) => Some(gm.flops()),
                    _ => None,
                })
                .sum()
        };
        assert!(flops(&steps[2]) < flops(&t5.decode_graph(1, 51)));
    }

    #[test]
    fn tp_builders_shard_ranks_and_degrade_to_identity() {
        let cfg = zoo::gpt2_large();
        // tp = 1 is the plain builder, bit for bit.
        assert_eq!(cfg.graph_tp(1, 64, 1).lower(), cfg.graph(1, 64).lower());
        assert_eq!(cfg.decode_graph_tp(1, 64, 1).lower(), cfg.decode_trace(1, 64));
        // tp = 2 rank graphs carry sharded GEMMs and collectives.
        for g in [cfg.graph_tp(1, 64, 2), cfg.decode_graph_tp(1, 64, 2)] {
            g.validate().unwrap();
            assert!(g.lower().iter().any(|op| matches!(op, Op::Comm(_))));
            assert!(g
                .lower()
                .iter()
                .any(|op| matches!(op, Op::Gemm(gm) if gm.shard.is_some())));
        }
    }

    #[test]
    fn property_single_slot_mixed_batch_graph_is_bit_equivalent() {
        // ISSUE acceptance anchor: the serving simulator's iteration
        // graphs degenerate exactly to the existing prefill / decode
        // graphs at batch size 1 — node for node, so streams=1 latency
        // aggregation is bit-for-bit identical.
        for cfg in zoo::all_models().into_iter().filter(|c| c.enc_layers == 0) {
            for p in [17usize, 128] {
                let mixed = cfg.mixed_batch_graph(&[SeqSlot::prefill(0, p)]);
                mixed.validate().unwrap();
                assert_eq!(mixed.lower(), cfg.graph(1, p).lower(), "{} prefill", cfg.name);
                assert_eq!(mixed.len(), cfg.graph(1, p).len());
            }
            for kv in [1usize, 97, 2048] {
                let mixed = cfg.mixed_batch_graph(&[SeqSlot::decode(kv - 1)]);
                mixed.validate().unwrap();
                assert_eq!(
                    mixed.lower(),
                    cfg.decode_trace(1, kv),
                    "{} decode kv={kv}",
                    cfg.name
                );
            }
        }
    }

    #[test]
    fn mixed_batch_graph_packs_rows_and_keeps_attention_ragged() {
        let cfg = zoo::qwen3_0_6b();
        let slots = [
            SeqSlot::prefill(0, 256),  // admission-iteration prefill
            SeqSlot::prefill(128, 64), // chunked-prefill continuation
            SeqSlot::decode(512),      // two decode sequences at
            SeqSlot::decode(1023),     // different cache depths
        ];
        let g = cfg.mixed_batch_graph(&slots);
        g.validate().unwrap();
        let rows: usize = slots.iter().map(|s| s.q_len).sum();
        let trace = g.lower();
        // Row ops flatten across the ragged batch: the packed QKV
        // projection covers Σ q rows, once per layer.
        let qkv_width = cfg.hidden + 2 * cfg.kv_heads * cfg.head_dim();
        let packed = trace
            .iter()
            .filter(|op| matches!(op, Op::Gemm(gm) if gm.m == rows && gm.n == qkv_width))
            .count();
        assert_eq!(packed, cfg.layers);
        // Attention stays per sequence: one softmax per slot per layer,
        // each over its own kv window.
        let softmaxes = trace
            .iter()
            .filter(|op| matches!(op, Op::Util(u) if u.kind == UtilKind::Softmax))
            .count();
        assert_eq!(softmaxes, slots.len() * cfg.layers);
        for s in &slots {
            assert!(trace.iter().any(|op| matches!(
                op,
                Op::Util(u) if u.kind == UtilKind::Softmax
                    && u.rows == cfg.heads * s.q_len && u.cols == s.kv_len
            )));
        }
        // Every scores BMM is causal-marked, and GQA models carry the
        // grouping annotation fusion needs.
        let groups = cfg.heads / cfg.kv_heads;
        let annotated = (0..g.len())
            .filter(|&i| {
                let id = crate::graph::NodeId(i);
                g.is_causal(id) && g.kv_groups(id) == groups
            })
            .count();
        assert_eq!(annotated, slots.len() * cfg.layers);
        // The LM head covers the whole packed row block.
        assert!(trace.iter().any(|op| matches!(
            op,
            Op::Gemm(gm) if gm.m == rows && gm.n == cfg.vocab
        )));
    }

    #[test]
    #[should_panic(expected = "decoder-only")]
    fn mixed_batch_graph_rejects_enc_dec_models() {
        zoo::flan_t5_base().mixed_batch_graph(&[SeqSlot::decode(16)]);
    }

    #[test]
    fn builder_annotates_gqa_groups_on_scores() {
        // ISSUE GQA satellite: prefill and decode builders both annotate
        // the scores BMM with the query-head grouping; MHA models don't.
        let gqa = zoo::qwen3_4b(); // 32 / 8 → groups of 4
        let g = gqa.decode_graph(1, 64);
        let marked = (0..g.len())
            .filter(|&i| g.kv_groups(crate::graph::NodeId(i)) == 4)
            .count();
        assert_eq!(marked, gqa.layers);
        let mha = zoo::gpt2_large();
        let g2 = mha.graph(1, 64);
        assert!((0..g2.len()).all(|i| g2.kv_groups(crate::graph::NodeId(i)) == 1));
    }

    #[test]
    fn kv_cache_is_gqa_aware() {
        let cfg = zoo::qwen3_4b(); // 32 heads, 8 kv_heads
        let mut mha = cfg.clone();
        mha.kv_heads = mha.heads;
        let gqa_bytes = cfg.kv_cache_bytes(1, 4096);
        let mha_bytes = mha.kv_cache_bytes(1, 4096);
        assert_eq!(mha_bytes, 4.0 * gqa_bytes, "kv_heads drive the cache footprint");
        // And the decode QKV projection width follows kv_heads too.
        let trace = cfg.decode_trace(1, 64);
        let qkv_width = cfg.hidden + 2 * cfg.kv_heads * cfg.head_dim();
        assert!(trace
            .iter()
            .any(|op| matches!(op, Op::Gemm(gm) if gm.n == qkv_width)));
        // Generation memory includes the grown cache.
        let spec = GenerationSpec::new(512, 512);
        assert!(
            cfg.generation_memory_bytes(1, &spec)
                > cfg.memory_bytes(1, 512) + cfg.kv_cache_bytes(1, 1024) * 0.99
        );
    }

    #[test]
    fn graph_wires_residuals_and_marks_the_head_output() {
        let cfg = zoo::gpt2_large();
        let g = cfg.graph(1, 64);
        assert_eq!(g.outputs().len(), 1, "LM head is the single marked output");
        assert_eq!(g.sinks(), g.outputs().to_vec(), "no dangling nodes");
        // Every non-initial LayerNorm consumes the running residual.
        let cons = g.consumers();
        let orphans = (0..g.len())
            .filter(|&i| g.node(crate::graph::NodeId(i)).inputs.is_empty())
            .count();
        assert_eq!(orphans, 1, "only the first pre-norm has no producer");
        assert!(cons.iter().take(g.len() - 2).all(|c| !c.is_empty()));
    }
}
