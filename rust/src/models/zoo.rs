//! The six Table-III models, with architecture parameters from their
//! published configs (GPT-2 Large, FLAN-T5 Base, Qwen3 0.6B/4B,
//! DeepSeek-R1-Distill-Qwen 7B/14B).

use crate::ops::DType;

use super::transformer::TransformerConfig;

pub fn gpt2_large() -> TransformerConfig {
    TransformerConfig {
        name: "gpt2-large",
        params_b: 0.774,
        layers: 36,
        enc_layers: 0,
        hidden: 1280,
        heads: 20,
        kv_heads: 20,
        ffn_hidden: 5120,
        vocab: 50257,
        dtype: DType::F32,
        gated_ffn: false,
    }
}

pub fn flan_t5_base() -> TransformerConfig {
    TransformerConfig {
        name: "flan-t5-base",
        params_b: 0.250,
        layers: 12,
        enc_layers: 12,
        hidden: 768,
        heads: 12,
        kv_heads: 12,
        ffn_hidden: 2048,
        vocab: 32128,
        dtype: DType::F32,
        gated_ffn: true, // gated-GELU FFN in T5 v1.1 / FLAN
    }
}

pub fn qwen3_0_6b() -> TransformerConfig {
    TransformerConfig {
        name: "qwen3-0.6b",
        params_b: 0.6,
        layers: 28,
        enc_layers: 0,
        hidden: 1024,
        heads: 16,
        kv_heads: 8,
        ffn_hidden: 3072,
        vocab: 151936,
        dtype: DType::Bf16,
        gated_ffn: true,
    }
}

pub fn qwen3_4b() -> TransformerConfig {
    TransformerConfig {
        name: "qwen3-4b",
        params_b: 4.0,
        layers: 36,
        enc_layers: 0,
        hidden: 2560,
        heads: 32,
        kv_heads: 8,
        ffn_hidden: 9728,
        vocab: 151936,
        dtype: DType::Bf16,
        gated_ffn: true,
    }
}

pub fn deepseek_r1_7b() -> TransformerConfig {
    TransformerConfig {
        name: "ds-r1-7b",
        params_b: 7.0,
        layers: 28,
        enc_layers: 0,
        hidden: 3584,
        heads: 28,
        kv_heads: 4,
        ffn_hidden: 18944,
        vocab: 152064,
        dtype: DType::Bf16,
        gated_ffn: true,
    }
}

pub fn deepseek_r1_14b() -> TransformerConfig {
    TransformerConfig {
        name: "ds-r1-14b",
        params_b: 14.0,
        layers: 48,
        enc_layers: 0,
        hidden: 5120,
        heads: 40,
        kv_heads: 8,
        ffn_hidden: 13824,
        vocab: 152064,
        dtype: DType::Bf16,
        gated_ffn: true,
    }
}

pub fn all_models() -> Vec<TransformerConfig> {
    vec![
        gpt2_large(),
        flan_t5_base(),
        qwen3_0_6b(),
        qwen3_4b(),
        deepseek_r1_7b(),
        deepseek_r1_14b(),
    ]
}

pub fn by_name(name: &str) -> Option<TransformerConfig> {
    all_models().into_iter().find(|m| m.name.eq_ignore_ascii_case(name))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parameter_counts_match_reported() {
        // Architecture-derived counts should land near the reported sizes
        // (within 20% — embeddings/tied weights vary by convention).
        for cfg in all_models() {
            let derived = cfg.weight_params() / 1e9;
            let ratio = derived / cfg.params_b;
            assert!(
                ratio > 0.75 && ratio < 1.35,
                "{}: derived {derived:.2}B vs reported {}B",
                cfg.name,
                cfg.params_b
            );
        }
    }

    #[test]
    fn dtype_assignment_matches_table3() {
        assert_eq!(gpt2_large().dtype, DType::F32);
        assert_eq!(flan_t5_base().dtype, DType::F32);
        assert_eq!(qwen3_4b().dtype, DType::Bf16);
        assert_eq!(deepseek_r1_14b().dtype, DType::Bf16);
    }

    #[test]
    fn lookup_by_name() {
        assert!(by_name("qwen3-4b").is_some());
        assert!(by_name("GPT2-LARGE").is_some());
        assert!(by_name("llama").is_none());
    }

    #[test]
    fn memory_ordering_by_size() {
        let small = qwen3_0_6b().memory_bytes(1, 512);
        let big = deepseek_r1_14b().memory_bytes(1, 512);
        assert!(big > small * 5.0);
    }
}
