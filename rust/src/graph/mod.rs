//! # graph — the typed model-graph IR (canonical model representation)
//!
//! Every layer of the repo used to consume models as a flat `Vec<Op>` and
//! aggregate latency by sequential summation — a representation that can
//! express neither kernel fusion (you cannot fuse what has no structure)
//! nor multi-stream concurrency (you cannot find a critical path on a
//! list). This module replaces it end-to-end:
//!
//! * [`ir`] — [`ModelGraph`]: nodes (`Op` + input edges) with derived
//!   tensor-shape metadata, structural validation (acyclicity by
//!   append-only construction, shape agreement), and lossless lowering to
//!   a topologically ordered `Vec<Op>`. Lowering reproduces insertion
//!   order exactly, so every flat-trace consumer keeps working unchanged.
//! * [`passes`] — the rewrite-pass framework ([`Pass`], [`PassManager`])
//!   with causal-mask propagation (annotation spreading + decode-shape
//!   inference, so fusion can emit `causal: true` kernels), attention
//!   fusion (unfused BMM→SoftMax→BMM → FlashAttn/CUTLASS for both
//!   prefill and decode-step shapes, device/dtype-gated, optionally
//!   cost-gated) and dead-node elimination.
//! * [`schedule`] — dependency-aware latency aggregation: list-schedule
//!   the graph onto a bounded number of concurrent streams and report the
//!   makespan. `streams = 1` reproduces the paper's sequential-kernel sum
//!   bit-for-bit; more streams expose branch concurrency (gated-FFN
//!   lanes, encoder/decoder prefixes, cross-attention Q/KV projections).
//!
//! The stack consumes the IR at every level: `TransformerConfig::graph`
//! builds it (with `trace()` as the lowered view), `models::runner`
//! executes schedules on the simulator, `Pm2Lat::predict_graph` predicts
//! critical-path latency, and `Coordinator::submit_graphs` serves graphs
//! with subgraph-granularity caching and cross-node GEMM batching.

pub mod ir;
pub mod passes;
pub mod schedule;

pub use ir::{output_shape, GraphError, ModelGraph, Node, NodeId, TensorShape};
pub use passes::{
    AttentionFusion, CausalMaskPropagation, DeadNodeElimination, Pass, PassCtx, PassManager,
    PassResultCache, TensorParallelPass,
};
pub use schedule::{critical_path_s, predict_graph_latency, Schedule, ScheduledOp};
