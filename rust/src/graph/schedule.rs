//! Dependency-aware latency aggregation: list-schedule a [`ModelGraph`]
//! onto a bounded number of concurrent streams and report the makespan.
//!
//! The paper aggregates whole-model latency as a sequential kernel sum
//! (§III) — that is exactly the `streams = 1` schedule, reproduced
//! bit-for-bit (same additions in the same order). With more streams,
//! independent branches (gated-FFN lanes, encoder vs. decoder prefixes,
//! cross-attention Q/KV projections) overlap and the predicted latency
//! becomes the critical-path length under the stream cap — the
//! multi-stream scenario axis flat traces cannot express.

use crate::ops::Op;

use super::ir::{ModelGraph, NodeId};

/// Placement of one node in a schedule.
#[derive(Clone, Copy, Debug)]
pub struct ScheduledOp {
    pub id: NodeId,
    pub stream: usize,
    pub start_s: f64,
    pub finish_s: f64,
}

/// A complete schedule over `streams` concurrent streams.
#[derive(Clone, Debug)]
pub struct Schedule {
    /// In issue (lowered) order.
    pub ops: Vec<ScheduledOp>,
    pub streams: usize,
    pub makespan_s: f64,
}

/// List-schedule `g` given per-node durations (indexed by node id).
/// Nodes are issued in lowered order; each waits for its producers, then
/// takes the stream where it can *start* earliest (lowest index on ties)
/// — picking by free time alone would idle-block a stream behind a
/// dependency stall. Deterministic for a given graph and durations.
pub fn schedule(g: &ModelGraph, streams: usize, dur_s: &[f64]) -> Schedule {
    assert_eq!(dur_s.len(), g.len(), "one duration per node");
    let streams = streams.max(1).min(g.len().max(1));
    let mut free = vec![0.0f64; streams];
    let mut finish = vec![0.0f64; g.len()];
    let mut ops = Vec::with_capacity(g.len());
    let mut makespan = 0.0f64;
    for id in g.lowered_ids() {
        let i = id.index();
        let mut ready = 0.0f64;
        for inp in &g.node(id).inputs {
            ready = ready.max(finish[inp.index()]);
        }
        // Collectives are cross-device sync points: every rank (and so
        // every local stream) rendezvouses, so the collective starts after
        // ALL stream frontiers and advances them together. On one stream
        // this degenerates to the ordinary sequential placement, keeping
        // the bit-for-bit `streams = 1` guarantee.
        if matches!(g.node(id).op, Op::Comm(_)) {
            let mut start = ready;
            for &t in &free {
                start = start.max(t);
            }
            let end = start + dur_s[i];
            finish[i] = end;
            for t in free.iter_mut() {
                *t = end;
            }
            makespan = makespan.max(end);
            ops.push(ScheduledOp { id, stream: 0, start_s: start, finish_s: end });
            continue;
        }
        // On one stream `ready <= free[0]` always holds (producers ran
        // earlier on the same stream), so `start` accumulates exactly the
        // sequential sum `total += dur` of the legacy trace path.
        let mut stream = 0usize;
        let mut start = ready.max(free[0]);
        for (s, &t) in free.iter().enumerate().skip(1) {
            let candidate = ready.max(t);
            if candidate < start {
                stream = s;
                start = candidate;
            }
        }
        let end = start + dur_s[i];
        finish[i] = end;
        free[stream] = end;
        makespan = makespan.max(end);
        ops.push(ScheduledOp { id, stream, start_s: start, finish_s: end });
    }
    Schedule { ops, streams, makespan_s: makespan }
}

/// Dependency-only lower bound: the longest duration-weighted path. No
/// stream cap can beat it; `schedule` approaches it as streams grow.
pub fn critical_path_s(g: &ModelGraph, dur_s: &[f64]) -> f64 {
    assert_eq!(dur_s.len(), g.len());
    let mut finish = vec![0.0f64; g.len()];
    let mut longest = 0.0f64;
    for id in g.lowered_ids() {
        let i = id.index();
        let mut ready = 0.0f64;
        for inp in &g.node(id).inputs {
            ready = ready.max(finish[inp.index()]);
        }
        finish[i] = ready + dur_s[i];
        longest = longest.max(finish[i]);
    }
    longest
}

/// Predict whole-graph latency: per-node costs from `cost` (None when any
/// op is unsupported), aggregated as the `streams`-bounded makespan.
/// `streams = 1` is bit-identical to the sequential trace sum.
pub fn predict_graph_latency<F>(g: &ModelGraph, streams: usize, cost: F) -> Option<f64>
where
    F: Fn(&Op) -> Option<f64>,
{
    let mut dur = Vec::with_capacity(g.len());
    for n in g.nodes() {
        dur.push(cost(&n.op)?);
    }
    Some(schedule(g, streams, &dur).makespan_s)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::{DType, GemmOp, UtilKind, UtilOp};

    fn gemm() -> Op {
        Op::Gemm(GemmOp::mm(64, 64, 64, DType::F32))
    }

    fn chain(durs: &[f64]) -> (ModelGraph, Vec<f64>) {
        let trace: Vec<Op> = durs.iter().map(|_| gemm()).collect();
        (ModelGraph::from_trace(&trace), durs.to_vec())
    }

    #[test]
    fn one_stream_is_the_sequential_sum_bit_for_bit() {
        let durs = [0.1, 0.2, 0.3, 0.07, 1e-9];
        let (g, d) = chain(&durs);
        let mut total = 0.0f64;
        for x in &durs {
            total += x;
        }
        let s = schedule(&g, 1, &d);
        assert_eq!(s.makespan_s, total, "same additions in the same order");
        assert!(s.ops.iter().all(|o| o.stream == 0));
    }

    #[test]
    fn diamond_overlaps_on_two_streams() {
        // a(1) → {b(2), c(3)} → d(1): 2 streams run b ∥ c.
        let mut g = ModelGraph::new();
        let a = g.add_node(gemm(), &[]);
        let b = g.add_node(gemm(), &[a]);
        let c = g.add_node(gemm(), &[a]);
        g.add_node(gemm(), &[b, c]);
        let d = vec![1.0, 2.0, 3.0, 1.0];
        assert_eq!(schedule(&g, 1, &d).makespan_s, 7.0);
        let two = schedule(&g, 2, &d);
        assert_eq!(two.makespan_s, 5.0, "1 + max(2,3) + 1");
        assert_eq!(critical_path_s(&g, &d), 5.0);
        // Streams beyond the branch width change nothing.
        assert_eq!(schedule(&g, 8, &d).makespan_s, 5.0);
    }

    #[test]
    fn independent_roots_fan_out_across_streams() {
        let mut g = ModelGraph::new();
        for _ in 0..4 {
            g.add_node(gemm(), &[]);
        }
        let d = vec![1.0; 4];
        assert_eq!(schedule(&g, 1, &d).makespan_s, 4.0);
        assert_eq!(schedule(&g, 2, &d).makespan_s, 2.0);
        assert_eq!(schedule(&g, 4, &d).makespan_s, 1.0);
        assert_eq!(critical_path_s(&g, &d), 1.0);
    }

    #[test]
    fn dependent_node_does_not_idle_block_a_free_stream() {
        // a(10) → b(1); c(5) independent. Greedy earliest-*free* stream
        // placement would park b on the idle stream until t=10 and push c
        // behind a (makespan 15); placing by earliest *start* leaves the
        // second stream open for c (makespan 11).
        let mut g = ModelGraph::new();
        let a = g.add_node(gemm(), &[]);
        g.add_node(gemm(), &[a]);
        g.add_node(gemm(), &[]);
        let d = vec![10.0, 1.0, 5.0];
        assert_eq!(schedule(&g, 2, &d).makespan_s, 11.0);
    }

    #[test]
    fn makespan_bounded_by_work_and_critical_path() {
        let mut g = ModelGraph::new();
        let a = g.add_node(gemm(), &[]);
        let b = g.add_node(gemm(), &[]);
        let c = g.add_node(gemm(), &[a, b]);
        for _ in 0..3 {
            g.add_node(gemm(), &[c]);
        }
        let d = vec![0.5, 1.0, 0.25, 2.0, 0.1, 0.4];
        let total: f64 = d.iter().sum();
        for streams in 1..=6 {
            let m = schedule(&g, streams, &d).makespan_s;
            assert!(m <= total * (1.0 + 1e-12));
            assert!(m >= critical_path_s(&g, &d) * (1.0 - 1e-12));
        }
    }

    #[test]
    fn predict_latency_none_when_any_cost_missing() {
        let (g, d) = chain(&[1.0, 1.0]);
        let _ = d;
        assert_eq!(predict_graph_latency(&g, 1, |_| Some(1.0)), Some(2.0));
        assert_eq!(predict_graph_latency(&g, 1, |_| None), None);
        let u = Op::Util(UtilOp::new(UtilKind::Relu, 8, 8, DType::F32));
        let g2 = ModelGraph::from_trace(&[gemm(), u]);
        let only_gemm = |op: &Op| match op {
            Op::Gemm(_) => Some(1.0),
            _ => None,
        };
        assert_eq!(predict_graph_latency(&g2, 1, only_gemm), None);
    }

    #[test]
    fn collective_is_a_barrier_across_streams() {
        use crate::ops::CommOp;
        // a(1) ∥ b(4) on two streams, then an AllReduce fed only by a:
        // the collective still waits for *every* frontier (b included)
        // and both streams resume after it.
        let mut g = ModelGraph::new();
        let a = g.add_node(gemm(), &[]);
        g.add_node(gemm(), &[]);
        let ar = g.add_node(
            Op::Comm(CommOp::all_reduce(64 * 64, DType::F32, 2)),
            &[a],
        );
        g.add_node(gemm(), &[ar]);
        let d = vec![1.0, 4.0, 0.5, 1.0];
        let s = schedule(&g, 2, &d);
        assert_eq!(s.ops[2].start_s, 4.0, "barrier waits for the slow stream");
        assert_eq!(s.makespan_s, 5.5);
        // On one stream the collective is just another sequential op.
        assert_eq!(schedule(&g, 1, &d).makespan_s, 6.5);
    }

    #[test]
    fn empty_graph_schedules_to_zero() {
        let g = ModelGraph::new();
        assert_eq!(schedule(&g, 4, &[]).makespan_s, 0.0);
        assert_eq!(predict_graph_latency(&g, 1, |_| None), Some(0.0));
    }
}
