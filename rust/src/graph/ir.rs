//! The typed model-graph IR: nodes carry an [`Op`] plus explicit data
//! dependencies, and every graph lowers losslessly to a topologically
//! ordered `Vec<Op>` — the flat-trace view all pre-graph consumers keep
//! using. Graphs are append-only DAGs by construction: a node may only
//! reference already-inserted nodes, so insertion order is always a valid
//! topological order and [`ModelGraph::lower`] reproduces it exactly.
//! That invariant is what makes the `streams = 1` graph path
//! bit-identical to the legacy sequential-trace path.

use crate::ops::{CustomOp, Op};

/// Index of a node within one [`ModelGraph`].
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct NodeId(pub usize);

impl NodeId {
    pub fn index(self) -> usize {
        self.0
    }
}

impl std::fmt::Display for NodeId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "n{}", self.0)
    }
}

/// One graph node: the op and the producers it consumes. `causal` is the
/// mask annotation causal-mask propagation reads and writes: the op's
/// *shape* cannot express masking (an attention-scores BMM looks the same
/// masked or not), so the builder records it on the node and rewrite
/// passes carry it to the fused kernels that can exploit it. `kv_groups`
/// is the grouped-query annotation with the same rationale: the unfused
/// scores BMM is MHA-expanded (frameworks repeat-interleave the grouped
/// KV before the BMM), so only the builder knows that `kv_groups` query
/// heads share each KV lane — fusion reads it to emit kernels that
/// stream the *grouped* cache. 1 (the default) is plain MHA.
#[derive(Clone, Debug)]
pub struct Node {
    pub op: Op,
    pub inputs: Vec<NodeId>,
    pub causal: bool,
    pub kv_groups: usize,
}

/// Logical output-tensor shape of an op (batch × rows × cols).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TensorShape {
    pub batch: usize,
    pub rows: usize,
    pub cols: usize,
}

impl TensorShape {
    pub fn elems(&self) -> usize {
        self.batch * self.rows * self.cols
    }
}

/// Output shape metadata derived from the op itself — the vocabulary in
/// `ops.rs` fully determines it, so graphs never store shapes redundantly.
pub fn output_shape(op: &Op) -> TensorShape {
    match *op {
        Op::Gemm(g) => TensorShape { batch: g.batch, rows: g.m, cols: g.n },
        Op::Util(u) => TensorShape { batch: 1, rows: u.rows, cols: u.cols },
        Op::Custom(c) => match c {
            CustomOp::TritonMM { m, n, .. } => TensorShape { batch: 1, rows: m, cols: n },
            CustomOp::TritonVec { elems, .. } => {
                TensorShape { batch: 1, rows: 1, cols: elems }
            }
            CustomOp::FlashAttn { batch, heads, q_len, head_dim, .. }
            | CustomOp::CutlassAttn { batch, heads, q_len, head_dim, .. } => {
                TensorShape { batch: batch * heads, rows: q_len, cols: head_dim }
            }
        },
        // AllReduce keeps the per-rank tensor size; AllGather concatenates
        // one shard from every participant.
        Op::Comm(c) => match c.kind {
            crate::ops::CommKind::AllReduce => {
                TensorShape { batch: 1, rows: 1, cols: c.elems }
            }
            crate::ops::CommKind::AllGather => {
                TensorShape { batch: 1, rows: c.participants.max(1), cols: c.elems }
            }
        },
    }
}

#[derive(Clone, Debug, PartialEq, Eq, thiserror::Error)]
pub enum GraphError {
    #[error("node {node} consumes node {input}, which does not precede it")]
    ForwardEdge { node: usize, input: usize },
    #[error(
        "node {node} ({kind}) produces more elements than its input {input} supplies"
    )]
    ShapeMismatch { node: usize, kind: &'static str, input: usize },
    #[error("marked output {0} is not a node")]
    BadOutput(usize),
    #[error("node {node}: collective has no producer to synchronize")]
    DanglingComm { node: usize },
    #[error(
        "node {node}: row-sharded partial sum ({parts} parts) is never all-reduced"
    )]
    UnreducedShard { node: usize, parts: usize },
}

/// A DNN model as a dependency graph of simulator ops.
#[derive(Clone, Debug, Default)]
pub struct ModelGraph {
    nodes: Vec<Node>,
    outputs: Vec<NodeId>,
}

impl ModelGraph {
    pub fn new() -> ModelGraph {
        ModelGraph::default()
    }

    /// Append a node. Inputs must reference already-inserted nodes — the
    /// append-only discipline that keeps every graph acyclic and makes
    /// insertion order a valid schedule.
    pub fn add_node(&mut self, op: Op, inputs: &[NodeId]) -> NodeId {
        let id = NodeId(self.nodes.len());
        for inp in inputs {
            assert!(
                inp.0 < id.0,
                "graph input {} must precede node {} (append-only DAG)",
                inp.0,
                id.0
            );
        }
        self.nodes.push(Node { op, inputs: inputs.to_vec(), causal: false, kv_groups: 1 });
        id
    }

    /// Mark a node as a graph output (a root dead-node elimination must
    /// preserve). Without any marked output, every sink is presumed live.
    pub fn mark_output(&mut self, id: NodeId) {
        if !self.outputs.contains(&id) {
            self.outputs.push(id);
        }
    }

    /// Annotate a node as causally masked (attention scores under an
    /// autoregressive mask). Builders set this; causal-mask propagation
    /// spreads it through the attention pattern; fusion emits
    /// `causal: true` kernels from it.
    pub fn mark_causal(&mut self, id: NodeId) {
        self.nodes[id.0].causal = true;
    }

    pub fn is_causal(&self, id: NodeId) -> bool {
        self.nodes[id.0].causal
    }

    /// Annotate a node with its grouped-query structure: `groups` query
    /// heads share each KV lane (GQA). Builders set this on the attention
    /// scores BMM; fusion emits grouped fused kernels from it. Values
    /// ≤ 1 reset the node to plain MHA.
    pub fn mark_kv_groups(&mut self, id: NodeId, groups: usize) {
        self.nodes[id.0].kv_groups = groups.max(1);
    }

    /// Grouped-query annotation (1 = MHA, the default).
    pub fn kv_groups(&self, id: NodeId) -> usize {
        self.nodes[id.0].kv_groups
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    pub fn node(&self, id: NodeId) -> &Node {
        &self.nodes[id.0]
    }

    /// Nodes in id (= insertion = lowered) order.
    pub fn nodes(&self) -> &[Node] {
        &self.nodes
    }

    /// Explicitly marked outputs (may be empty).
    pub fn outputs(&self) -> &[NodeId] {
        &self.outputs
    }

    /// Nodes no other node consumes.
    pub fn sinks(&self) -> Vec<NodeId> {
        let cons = self.consumers();
        (0..self.nodes.len())
            .filter(|&i| cons[i].is_empty())
            .map(NodeId)
            .collect()
    }

    /// Per-node consumer lists (reverse adjacency).
    pub fn consumers(&self) -> Vec<Vec<NodeId>> {
        let mut out = vec![Vec::new(); self.nodes.len()];
        for (i, n) in self.nodes.iter().enumerate() {
            for inp in &n.inputs {
                out[inp.0].push(NodeId(i));
            }
        }
        out
    }

    /// Structural validation: every edge points backward (acyclicity), no
    /// utility node produces more elements than any of its inputs supplies
    /// (reductions and gated activations may consume *more*), marked
    /// outputs exist, and sharded subgraphs are consistent — collectives
    /// synchronize a real producer, and every row-sharded GEMM (a partial
    /// sum) is completed by an AllReduce over the same participant count.
    pub fn validate(&self) -> Result<(), GraphError> {
        let mut has_shards = false;
        for (i, n) in self.nodes.iter().enumerate() {
            for inp in &n.inputs {
                if inp.0 >= i {
                    return Err(GraphError::ForwardEdge { node: i, input: inp.0 });
                }
            }
            match n.op {
                Op::Util(u) => {
                    let need = output_shape(&n.op).elems();
                    for inp in &n.inputs {
                        let have = output_shape(&self.nodes[inp.0].op).elems();
                        if have < need {
                            return Err(GraphError::ShapeMismatch {
                                node: i,
                                kind: u.kind.name(),
                                input: inp.0,
                            });
                        }
                    }
                }
                Op::Comm(_) => {
                    if n.inputs.is_empty() {
                        return Err(GraphError::DanglingComm { node: i });
                    }
                }
                Op::Gemm(g) => {
                    has_shards |= g.shard.is_some();
                }
                _ => {}
            }
        }
        if has_shards {
            let cons = self.consumers();
            for (i, n) in self.nodes.iter().enumerate() {
                if let Op::Gemm(g) = n.op {
                    if let Some(s) = g.shard {
                        if s.dim == crate::ops::ShardDim::Row && s.parts > 1 {
                            let reduced = cons[i].iter().any(|&c| {
                                matches!(
                                    self.nodes[c.0].op,
                                    Op::Comm(cm) if cm.kind == crate::ops::CommKind::AllReduce
                                        && cm.participants == s.parts
                                )
                            });
                            if !reduced {
                                return Err(GraphError::UnreducedShard {
                                    node: i,
                                    parts: s.parts,
                                });
                            }
                        }
                    }
                }
            }
        }
        for o in &self.outputs {
            if o.0 >= self.nodes.len() {
                return Err(GraphError::BadOutput(o.0));
            }
        }
        Ok(())
    }

    /// Deterministic topological order. Append-only construction
    /// (`add_node` rejects forward edges) makes insertion order both
    /// topologically valid and the smallest-id-first such order — `0, 1,
    /// 2, …` is the lexicographic minimum over all permutations — so the
    /// canonical lowering is the identity order, computed in O(n). This
    /// sits on hot paths: every `trace()` call, every simulator rep of
    /// `run_graph_once`, every `submit_graphs` request.
    pub fn lowered_ids(&self) -> Vec<NodeId> {
        (0..self.nodes.len()).map(NodeId).collect()
    }

    /// The flat-trace view: ops in lowered order. Every pre-graph consumer
    /// (simulator runs, trace prediction, partitioning) reads this.
    pub fn lower(&self) -> Vec<Op> {
        self.lowered_ids().into_iter().map(|id| self.nodes[id.0].op).collect()
    }

    /// Structural identity hash: a stable 64-bit digest over every node
    /// (op, input edges, causal and grouped-query annotations) and the
    /// marked outputs, composing the same field-structured
    /// [`crate::util::prng::StableHasher`] that backs `Op::stable_hash`.
    /// Two graphs hash equal iff they are node-for-node identical (modulo
    /// the 64-bit collision bound), which is exactly the granularity the
    /// pass-result cache ([`crate::graph::PassResultCache`]) and the
    /// serving iteration memo need: a rewrite pass is a deterministic
    /// function of this structure, so equal hashes ⇒ equal rewrites.
    /// Process-stable (no `DefaultHasher` randomization), so hashes can
    /// be recorded and compared across runs.
    pub fn stable_hash(&self) -> u64 {
        use std::hash::{Hash, Hasher};
        let mut h = crate::util::prng::StableHasher::new();
        self.nodes.len().hash(&mut h);
        for n in &self.nodes {
            n.op.hash(&mut h);
            n.inputs.hash(&mut h);
            n.causal.hash(&mut h);
            n.kv_groups.hash(&mut h);
        }
        self.outputs.hash(&mut h);
        h.finish()
    }

    /// Wrap a flat trace as a pure chain graph (each op depends on its
    /// predecessor) — the adapter for callers that only have a `Vec<Op>`.
    pub fn from_trace(trace: &[Op]) -> ModelGraph {
        let mut g = ModelGraph::new();
        let mut prev: Option<NodeId> = None;
        for &op in trace {
            let inputs: Vec<NodeId> = prev.into_iter().collect();
            prev = Some(g.add_node(op, &inputs));
        }
        if let Some(p) = prev {
            g.mark_output(p);
        }
        g
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::{DType, GemmOp, UtilKind, UtilOp};

    fn gemm(m: usize, n: usize, k: usize) -> Op {
        Op::Gemm(GemmOp::mm(m, n, k, DType::F32))
    }

    fn util(kind: UtilKind, rows: usize, cols: usize) -> Op {
        Op::Util(UtilOp::new(kind, rows, cols, DType::F32))
    }

    #[test]
    fn stable_hash_tracks_structure_exactly() {
        let build = |mark: bool| {
            let mut g = ModelGraph::new();
            let a = g.add_node(gemm(64, 128, 32), &[]);
            let b = g.add_node(util(UtilKind::Gelu, 64, 128), &[a]);
            if mark {
                g.mark_causal(b);
            }
            g.mark_output(b);
            g
        };
        // Identical construction → identical hash, across instances.
        assert_eq!(build(false).stable_hash(), build(false).stable_hash());
        // Annotations are part of the structure (passes read them).
        assert_ne!(build(false).stable_hash(), build(true).stable_hash());
        // Ops, edges, and outputs all discriminate.
        let mut g2 = build(false);
        g2.add_node(gemm(64, 32, 128), &[NodeId(1)]);
        assert_ne!(build(false).stable_hash(), g2.stable_hash());
        let mut g3 = ModelGraph::new();
        let a = g3.add_node(gemm(64, 128, 32), &[]);
        let b = g3.add_node(util(UtilKind::Gelu, 64, 128), &[a, a]); // extra edge
        g3.mark_output(b);
        assert_ne!(build(false).stable_hash(), g3.stable_hash());
    }

    #[test]
    fn chain_round_trips_through_lowering() {
        let trace = vec![gemm(64, 128, 32), util(UtilKind::Gelu, 64, 128), gemm(64, 32, 128)];
        let g = ModelGraph::from_trace(&trace);
        assert_eq!(g.len(), 3);
        g.validate().unwrap();
        assert_eq!(g.lower(), trace, "lossless, order-preserving lowering");
        assert_eq!(g.outputs(), &[NodeId(2)]);
    }

    #[test]
    fn diamond_lowers_in_insertion_order() {
        // a → {b, c} → d: insertion order is the canonical lowering.
        let mut g = ModelGraph::new();
        let a = g.add_node(gemm(32, 32, 32), &[]);
        let b = g.add_node(util(UtilKind::Relu, 32, 32), &[a]);
        let c = g.add_node(util(UtilKind::Gelu, 32, 32), &[a]);
        let d = g.add_node(util(UtilKind::Add, 32, 32), &[b, c]);
        g.mark_output(d);
        g.validate().unwrap();
        assert_eq!(g.lowered_ids(), vec![a, b, c, d]);
        let cons = g.consumers();
        assert_eq!(cons[a.index()], vec![b, c]);
        assert_eq!(g.sinks(), vec![d]);
    }

    #[test]
    fn validate_rejects_undersized_elementwise_input() {
        let mut g = ModelGraph::new();
        let small = g.add_node(gemm(8, 8, 8), &[]);
        g.add_node(util(UtilKind::Add, 64, 64), &[small]);
        assert!(matches!(
            g.validate(),
            Err(GraphError::ShapeMismatch { node: 1, .. })
        ));
    }

    #[test]
    fn validate_accepts_reductions_and_gated_halving() {
        // SoftMax consumes exactly what it produces; a gated activation
        // consumes the doubled up+gate projection.
        let mut g = ModelGraph::new();
        let scores = g.add_node(Op::Gemm(GemmOp::bmm(4, 64, 64, 16, DType::F32)), &[]);
        g.add_node(util(UtilKind::Softmax, 4 * 64, 64), &[scores]);
        let upgate = g.add_node(gemm(64, 512, 128), &[]);
        g.add_node(util(UtilKind::Gelu, 64, 256), &[upgate]);
        g.validate().unwrap();
    }

    #[test]
    #[should_panic(expected = "append-only")]
    fn forward_edges_are_rejected_at_insertion() {
        let mut g = ModelGraph::new();
        g.add_node(gemm(8, 8, 8), &[NodeId(5)]);
    }

    #[test]
    fn empty_graph_lowers_to_empty_trace() {
        let g = ModelGraph::new();
        assert!(g.is_empty());
        assert!(g.lower().is_empty());
        g.validate().unwrap();
    }

    #[test]
    fn output_shapes_cover_all_op_families() {
        assert_eq!(output_shape(&gemm(3, 5, 7)).elems(), 15);
        assert_eq!(output_shape(&util(UtilKind::Relu, 4, 6)).elems(), 24);
        let fa = Op::Custom(CustomOp::FlashAttn {
            batch: 2,
            heads: 8,
            kv_heads: 8,
            q_len: 64,
            kv_len: 64,
            head_dim: 16,
            dtype: DType::Bf16,
            causal: false,
        });
        assert_eq!(output_shape(&fa).elems(), 2 * 8 * 64 * 16);
        // Decode-shaped attention produces one row per lane.
        let dec = Op::Custom(CustomOp::FlashAttn {
            batch: 2,
            heads: 8,
            kv_heads: 8,
            q_len: 1,
            kv_len: 777,
            head_dim: 16,
            dtype: DType::Bf16,
            causal: true,
        });
        assert_eq!(output_shape(&dec).elems(), 2 * 8 * 16);
    }

    #[test]
    fn validate_checks_shard_consistency() {
        use crate::ops::{CommOp, ShardDim};
        // Row-sharded GEMM without its AllReduce: a partial sum escapes.
        let mut g = ModelGraph::new();
        let part = g.add_node(
            Op::Gemm(GemmOp::linear(8, 8, 64, DType::F32).sharded(ShardDim::Row, 4)),
            &[],
        );
        assert!(matches!(
            g.validate(),
            Err(GraphError::UnreducedShard { node: 0, parts: 4 })
        ));
        // Completing it with a matching AllReduce makes the graph valid.
        g.add_node(Op::Comm(CommOp::all_reduce(64, DType::F32, 4)), &[part]);
        g.validate().unwrap();
        // Column shards produce full partial tensors — no reduce needed.
        let mut c = ModelGraph::new();
        c.add_node(
            Op::Gemm(GemmOp::linear(8, 64, 8, DType::F32).sharded(ShardDim::Col, 4)),
            &[],
        );
        c.validate().unwrap();
        // A collective with nothing to synchronize is malformed.
        let mut d = ModelGraph::new();
        d.add_node(Op::Comm(CommOp::all_reduce(64, DType::F32, 2)), &[]);
        assert!(matches!(d.validate(), Err(GraphError::DanglingComm { node: 0 })));
    }

    #[test]
    fn comm_output_shapes() {
        use crate::ops::CommOp;
        let ar = Op::Comm(CommOp::all_reduce(128, DType::F32, 4));
        let ag = Op::Comm(CommOp::all_gather(128, DType::F32, 4));
        assert_eq!(output_shape(&ar).elems(), 128);
        assert_eq!(output_shape(&ag).elems(), 512);
    }

    #[test]
    fn causal_marks_are_per_node_annotations() {
        let mut g = ModelGraph::new();
        let a = g.add_node(gemm(8, 8, 8), &[]);
        let b = g.add_node(util(UtilKind::Softmax, 8, 8), &[a]);
        assert!(!g.is_causal(a) && !g.is_causal(b));
        g.mark_causal(a);
        assert!(g.is_causal(a) && !g.is_causal(b));
        g.validate().unwrap();
    }
}
