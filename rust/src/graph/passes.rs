//! Graph rewrite passes: pattern-match subgraphs and replace them with
//! cheaper equivalents, tract/XLA style — match, build a patch, rebuild.
//!
//! Three concrete passes ship today:
//!
//! * [`CausalMaskPropagation`] — spreads the builder's causal-mask
//!   annotations across the whole unfused attention pattern (scores →
//!   softmax → context) and *infers* causality for decode-shaped
//!   patterns (`q_len == 1` reading a longer KV window is autoregressive
//!   by construction). Runs before fusion so the fused kernels inherit
//!   the mask.
//! * [`AttentionFusion`] — rewrites the unfused BMM→SoftMax→BMM attention
//!   subgraph the transformer builder emits into a fused
//!   `FlashAttn`/`CutlassAttn` kernel. Matches both prefill
//!   (`q_len == kv_len`) and decode-step (`q_len == 1, kv_len == t`)
//!   shapes, emits `causal: true` kernels wherever the mask annotation
//!   reaches the pattern, and is gated on device/dtype support (Table
//!   VI's "-" cells) and optionally on a cost model proving the fused
//!   kernel is no slower (`only_if_faster`).
//! * [`DeadNodeElimination`] — removes nodes that cannot reach a marked
//!   graph output.
//!
//! Every rewrite rebuilds the graph through `add_node`, so the
//! append-only/topological invariants of [`ModelGraph`] survive passes and
//! lowering stays deterministic.

use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::gpusim::custom;
use crate::gpusim::DeviceSpec;
use crate::ops::{CommOp, CustomOp, DType, GemmApi, GemmOp, Op, ShardDim, UtilKind, UtilOp};

use super::ir::{ModelGraph, Node, NodeId};

/// Rebuild `g` node by node: `emit` returns `None` to drop a node, or
/// `Some((op, inputs))` to re-add it — inputs named by *old* ids, which
/// must resolve to surviving nodes. Marked outputs are remapped (and
/// silently dropped if their node was); per-node causal and kv-group
/// annotations survive on every surviving node. Shared by every
/// structural pass so the remap/outputs invariants live in exactly one
/// place.
fn rebuild_graph(
    g: &mut ModelGraph,
    mut emit: impl FnMut(usize, &Node) -> Option<(Op, Vec<NodeId>)>,
) {
    let n = g.len();
    let mut out = ModelGraph::new();
    let mut remap: Vec<Option<NodeId>> = vec![None; n];
    for i in 0..n {
        let node = g.node(NodeId(i));
        let causal = node.causal;
        let kv_groups = node.kv_groups;
        let Some((op, srcs)) = emit(i, node) else { continue };
        let ins: Vec<NodeId> = srcs
            .iter()
            .map(|x| remap[x.index()].expect("emitted inputs must survive the rebuild"))
            .collect();
        let id = out.add_node(op, &ins);
        if causal {
            out.mark_causal(id);
        }
        if kv_groups > 1 {
            out.mark_kv_groups(id, kv_groups);
        }
        remap[i] = Some(id);
    }
    for &o in g.outputs() {
        if let Some(m) = remap[o.index()] {
            out.mark_output(m);
        }
    }
    *g = out;
}

/// One matched unfused-attention subgraph (paper Table II "BMM" rows):
///
/// ```text
/// scores = BMM(lanes, q, kv, d)    — consumed only by the softmax
/// probs  = SoftMax(lanes·q, kv)    — consumed only by the second BMM
/// ctx    = BMM(lanes, q, d, kv)
/// ```
///
/// Prefill emits `q == kv == seq`; a decode step emits `q == 1,
/// kv == cache length`. `lanes = batch·heads`.
#[derive(Clone, Copy, Debug)]
struct AttnMatch {
    scores: usize,
    softmax: usize,
    ctx: usize,
    lanes: usize,
    q_len: usize,
    kv_len: usize,
    head_dim: usize,
    dtype: DType,
}

/// Find every disjoint unfused-attention pattern, in softmax-id order.
/// Shared by [`CausalMaskPropagation`] (annotates the pattern) and
/// [`AttentionFusion`] (rewrites it) so the two passes can never disagree
/// about what "attention" looks like.
fn match_attention(g: &ModelGraph, cons: &[Vec<NodeId>]) -> Vec<AttnMatch> {
    let mut used: HashSet<usize> = HashSet::new();
    let mut out = Vec::new();
    for si in 0..g.len() {
        let s_node = g.node(NodeId(si));
        let Op::Util(u) = s_node.op else { continue };
        if u.kind != UtilKind::Softmax || s_node.inputs.len() != 1 {
            continue;
        }
        let b1 = s_node.inputs[0].index();
        let Op::Gemm(g1) = g.node(NodeId(b1)).op else { continue };
        if g1.api != GemmApi::Bmm {
            continue;
        }
        // Softmax rows/cols must be exactly the scores layout.
        if u.rows != g1.batch * g1.m || u.cols != g1.n || u.dtype != g1.dtype {
            continue;
        }
        // Scores feed only the softmax; probs feed only one consumer.
        if cons[b1].len() != 1 || cons[b1][0].index() != si || cons[si].len() != 1 {
            continue;
        }
        let b2 = cons[si][0].index();
        let Op::Gemm(g2) = g.node(NodeId(b2)).op else { continue };
        if g2.api != GemmApi::Bmm
            || g2.batch != g1.batch
            || g2.m != g1.m
            || g2.k != g1.n
            || g2.n != g1.k
            || g2.dtype != g1.dtype
        {
            continue;
        }
        if used.contains(&b1) || used.contains(&si) || used.contains(&b2) {
            continue;
        }
        used.extend([b1, si, b2]);
        out.push(AttnMatch {
            scores: b1,
            softmax: si,
            ctx: b2,
            lanes: g1.batch,
            q_len: g1.m,
            kv_len: g1.n,
            head_dim: g1.k,
            dtype: g1.dtype,
        });
    }
    out
}

/// Context shared by all passes: the target device (None = purely
/// structural rewriting, no hardware gate) and an optional per-op cost
/// model (used by cost-gated rewrites).
#[derive(Clone, Copy, Default)]
pub struct PassCtx<'a> {
    pub device: Option<&'a DeviceSpec>,
    pub cost: Option<&'a dyn Fn(&Op) -> Option<f64>>,
}

impl<'a> PassCtx<'a> {
    /// No device gate, no cost model.
    pub fn structural() -> PassCtx<'static> {
        PassCtx { device: None, cost: None }
    }

    pub fn for_device(device: &'a DeviceSpec) -> PassCtx<'a> {
        PassCtx { device: Some(device), cost: None }
    }

    pub fn with_cost(
        device: &'a DeviceSpec,
        cost: &'a dyn Fn(&Op) -> Option<f64>,
    ) -> PassCtx<'a> {
        PassCtx { device: Some(device), cost: Some(cost) }
    }
}

/// A graph rewrite pass. `run` mutates the graph in place and returns the
/// number of rewrites applied (0 = fixed point).
pub trait Pass {
    fn name(&self) -> &'static str;
    fn run(&self, g: &mut ModelGraph, ctx: &PassCtx<'_>) -> usize;
}

/// Ordered pass pipeline.
#[derive(Default)]
pub struct PassManager {
    passes: Vec<Box<dyn Pass>>,
}

impl PassManager {
    pub fn new() -> PassManager {
        PassManager { passes: Vec::new() }
    }

    pub fn with(mut self, pass: impl Pass + 'static) -> PassManager {
        self.passes.push(Box::new(pass));
        self
    }

    /// The standard pipeline: causal-mask propagation, attention fusion,
    /// then dead-node cleanup.
    pub fn standard() -> PassManager {
        PassManager::new()
            .with(CausalMaskPropagation)
            .with(AttentionFusion::default())
            .with(DeadNodeElimination)
    }

    /// Run every pass once, returning (pass name, rewrite count) pairs.
    pub fn run(&self, g: &mut ModelGraph, ctx: &PassCtx<'_>) -> Vec<(&'static str, usize)> {
        self.passes.iter().map(|p| (p.name(), p.run(g, ctx))).collect()
    }
}

/// Memoized pass results, keyed on (pass-pipeline tag, input-graph
/// structural hash). Rewrite passes are deterministic functions of graph
/// structure, so running the same pipeline on a structurally identical
/// graph is pure recomputation — the serving simulator hits exactly this
/// when `simulate_placed` re-runs [`TensorParallelPass`] on every
/// iteration of a decode-heavy trace whose batch signatures repeat.
///
/// Results are shared as `Arc<ModelGraph>` so a hit costs one refcount
/// bump instead of a clone + rewrite. Keys are 64-bit
/// [`ModelGraph::stable_hash`] digests rather than whole graphs: an
/// accidental collision between two *distinct* live iteration graphs
/// would require a 64-bit birthday within one replay's working set
/// (thousands of graphs — odds ≈ 10⁻¹²), and the hot-path property
/// tests cross-check key equality against structural equality on
/// randomized corpora. `Sync` (mutex-protected map + atomic counters),
/// so one instance serves all worker threads of a parallel sweep.
///
/// Bounded by wholesale clearing: when the map reaches `capacity` the
/// next insert empties it. Pass results are pure acceleration, so a
/// clear only costs recomputation; real working sets (distinct batch
/// signatures) sit far below any sane bound.
pub struct PassResultCache {
    capacity: usize,
    results: Mutex<HashMap<(u64, u64), Arc<ModelGraph>>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl PassResultCache {
    pub fn new(capacity: usize) -> PassResultCache {
        PassResultCache {
            capacity: capacity.max(1),
            results: Mutex::new(HashMap::new()),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    /// A default bound comfortably above any replay's distinct-signature
    /// working set.
    pub fn default_sized() -> PassResultCache {
        PassResultCache::new(1 << 12)
    }

    /// Tag for a pass configuration — fold in the pass name and every
    /// parameter that changes its output (e.g. the tensor-parallel
    /// degree). Two configurations with different tags never share
    /// results.
    pub fn config_tag<T: std::hash::Hash>(name: &str, params: &T) -> u64 {
        crate::util::prng::StableHasher::hash_of(&(name, params))
    }

    /// The rewritten form of `g` under the pass configuration `tag`:
    /// served from memory when this structure was rewritten before,
    /// computed by `rewrite` (and stored) otherwise.
    pub fn rewrite(
        &self,
        tag: u64,
        g: &ModelGraph,
        rewrite: impl FnOnce() -> ModelGraph,
    ) -> Arc<ModelGraph> {
        let key = (tag, g.stable_hash());
        if let Some(hit) = self.results.lock().unwrap().get(&key).cloned() {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return hit;
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        let out = Arc::new(rewrite());
        let mut map = self.results.lock().unwrap();
        if map.len() >= self.capacity {
            map.clear();
        }
        // A racing thread may have inserted meanwhile; both computed the
        // same deterministic rewrite, so either value is correct.
        map.entry(key).or_insert_with(|| out.clone());
        out
    }

    pub fn len(&self) -> usize {
        self.results.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }
}

/// Propagate causal-mask annotations across unfused attention patterns,
/// and infer them where structure proves them:
///
/// * any mark on the scores BMM, the softmax or the context BMM spreads
///   to all three nodes, so downstream rewrites can test whichever node
///   survives;
/// * a decode-shaped pattern (`q_len == 1` reading `kv_len > 1` cached
///   entries) is marked causal by construction — a single new query over
///   a longer key window only occurs in autoregressive generation, and
///   the mask removes nothing at `q == 1`, so the annotation is exact.
///
/// Purely an annotation pass: ops, edges and lowering are untouched.
/// Returns the number of newly marked nodes.
#[derive(Clone, Copy, Debug, Default)]
pub struct CausalMaskPropagation;

impl Pass for CausalMaskPropagation {
    fn name(&self) -> &'static str {
        "causal-mask-propagation"
    }

    fn run(&self, g: &mut ModelGraph, _ctx: &PassCtx<'_>) -> usize {
        let cons = g.consumers();
        let mut marked = 0usize;
        for m in match_attention(g, &cons) {
            let ids = [m.scores, m.softmax, m.ctx];
            let annotated = ids.iter().any(|&i| g.is_causal(NodeId(i)));
            let decode_shaped = m.q_len == 1 && m.kv_len > 1;
            if !annotated && !decode_shaped {
                continue;
            }
            for &i in &ids {
                if !g.is_causal(NodeId(i)) {
                    g.mark_causal(NodeId(i));
                    marked += 1;
                }
            }
        }
        marked
    }
}

/// Fuse the unfused attention core ([`AttnMatch`]) into one fused
/// attention kernel over the same `lanes = batch·heads` blocks (the
/// fused-kernel cost model depends only on the product, so the head split
/// needs no extra metadata). Both prefill (`q == kv`) and decode-step
/// (`q == 1`) shapes fuse; causal-mask annotations on the pattern become
/// `causal: true` on the emitted kernel. FlashAttn is preferred, CUTLASS
/// attention is the fallback; both are gated on the architecture/dtype
/// support table.
#[derive(Clone, Copy, Debug, Default)]
pub struct AttentionFusion {
    /// Rewrite only when `ctx.cost` proves the fused kernel is no slower
    /// than the three ops it replaces (requires a cost model in the ctx).
    pub only_if_faster: bool,
}

impl Pass for AttentionFusion {
    fn name(&self) -> &'static str {
        "attention-fusion"
    }

    fn run(&self, g: &mut ModelGraph, ctx: &PassCtx<'_>) -> usize {
        let cons = g.consumers();
        // ctx node id → (scores id, softmax id, fused op).
        let mut fused_at: HashMap<usize, (usize, usize, Op)> = HashMap::new();
        for m in match_attention(g, &cons) {
            let causal = [m.scores, m.softmax, m.ctx]
                .iter()
                .any(|&i| g.is_causal(NodeId(i)));
            // Grouped-query structure: the builder annotates the scores
            // BMM with how many query heads share each KV lane. The fused
            // kernel's cost model depends only on lane *products*, so the
            // grouping is encoded as `heads = groups, kv_heads = 1` over
            // `lanes / groups` batch entries — `batch·heads` query lanes
            // stay exactly `lanes`, while the KV cache shrinks to
            // `lanes / groups` distinct lanes. Annotations that do not
            // divide the lane count are ignored (defensive: a hand-built
            // graph could mislabel).
            let groups = [m.scores, m.softmax, m.ctx]
                .iter()
                .map(|&i| g.kv_groups(NodeId(i)))
                .max()
                .unwrap_or(1);
            let groups = if groups > 1 && m.lanes % groups == 0 { groups } else { 1 };
            let candidates = [
                CustomOp::FlashAttn {
                    batch: m.lanes / groups,
                    heads: groups,
                    kv_heads: 1,
                    q_len: m.q_len,
                    kv_len: m.kv_len,
                    head_dim: m.head_dim,
                    dtype: m.dtype,
                    causal,
                },
                CustomOp::CutlassAttn {
                    batch: m.lanes / groups,
                    heads: groups,
                    kv_heads: 1,
                    q_len: m.q_len,
                    kv_len: m.kv_len,
                    head_dim: m.head_dim,
                    dtype: m.dtype,
                    causal,
                },
            ];
            let mut chosen = None;
            for cand in candidates {
                if let Some(dev) = ctx.device {
                    if !custom::supported(dev, &cand) {
                        continue;
                    }
                }
                let fused = Op::Custom(cand);
                if self.only_if_faster {
                    let Some(cost) = ctx.cost else { continue };
                    let Some(fused_cost) = cost(&fused) else { continue };
                    let parts = [
                        g.node(NodeId(m.scores)).op,
                        g.node(NodeId(m.softmax)).op,
                        g.node(NodeId(m.ctx)).op,
                    ];
                    let mut unfused_cost = 0.0;
                    let mut priced = true;
                    for p in &parts {
                        match cost(p) {
                            Some(v) => unfused_cost += v,
                            None => {
                                priced = false;
                                break;
                            }
                        }
                    }
                    if !priced || fused_cost > unfused_cost {
                        continue;
                    }
                }
                chosen = Some(fused);
                break;
            }
            let Some(fused) = chosen else { continue };
            if causal {
                // The fused node is emitted at the ctx position; carry the
                // mask annotation onto it through the rebuild.
                g.mark_causal(NodeId(m.ctx));
            }
            fused_at.insert(m.ctx, (m.scores, m.softmax, fused));
        }
        if fused_at.is_empty() {
            return 0;
        }
        let count = fused_at.len();
        let used: HashSet<usize> = fused_at
            .iter()
            .flat_map(|(&b2, &(b1, si, _))| [b1, si, b2])
            .collect();

        // Rebuild: drop b1/softmax, emit the fused op at b2's position
        // with the union of the matched subgraph's external inputs. The
        // input snapshot lets the emitter read the *replaced* nodes'
        // edges while the rebuild walks the graph.
        let inputs_of: Vec<Vec<NodeId>> =
            g.nodes().iter().map(|nd| nd.inputs.clone()).collect();
        rebuild_graph(g, |i, node| {
            if used.contains(&i) && !fused_at.contains_key(&i) {
                return None; // b1 or softmax: replaced by the fused node
            }
            let Some(&(b1, si, fused)) = fused_at.get(&i) else {
                return Some((node.op, node.inputs.clone()));
            };
            let mut srcs: Vec<NodeId> = Vec::new();
            for &x in inputs_of[b1]
                .iter()
                .chain(inputs_of[si].iter())
                .chain(inputs_of[i].iter())
            {
                if x.index() == b1 || x.index() == si || srcs.contains(&x) {
                    continue;
                }
                srcs.push(x);
            }
            Some((fused, srcs))
        });
        count
    }
}

/// Remove nodes that cannot reach a marked output. A graph with no marked
/// outputs is left untouched — every sink is then presumed live, so there
/// is nothing provably dead.
#[derive(Clone, Copy, Debug, Default)]
pub struct DeadNodeElimination;

impl Pass for DeadNodeElimination {
    fn name(&self) -> &'static str {
        "dead-node-elimination"
    }

    fn run(&self, g: &mut ModelGraph, _ctx: &PassCtx<'_>) -> usize {
        if g.outputs().is_empty() {
            return 0;
        }
        let n = g.len();
        let mut live = vec![false; n];
        let mut stack: Vec<usize> = g.outputs().iter().map(|r| r.index()).collect();
        while let Some(i) = stack.pop() {
            if live[i] {
                continue;
            }
            live[i] = true;
            for inp in &g.node(NodeId(i)).inputs {
                stack.push(inp.index());
            }
        }
        let dead = live.iter().filter(|l| !**l).count();
        if dead == 0 {
            return 0;
        }
        rebuild_graph(g, |i, node| {
            live[i].then(|| (node.op, node.inputs.clone()))
        });
        dead
    }
}

/// Walk backwards from `down` through elementwise utility nodes only,
/// collecting the utils crossed; succeeds when the walk roots at exactly
/// one GEMM (the FFN up-projection pattern: `up → activation [→ gate
/// multiply] → down`). Reductions (LayerNorm etc.) abort the walk — they
/// separate FFN internals from residual plumbing.
fn ffn_chain(g: &ModelGraph, down: usize) -> Option<(usize, Vec<usize>)> {
    let mut utils = Vec::new();
    let mut gemms: Vec<usize> = Vec::new();
    let mut seen: HashSet<usize> = HashSet::new();
    let mut stack: Vec<usize> =
        g.node(NodeId(down)).inputs.iter().map(|x| x.index()).collect();
    while let Some(i) = stack.pop() {
        if !seen.insert(i) {
            continue;
        }
        match g.node(NodeId(i)).op {
            Op::Util(u) => {
                if u.kind.is_reduction() {
                    return None;
                }
                utils.push(i);
                stack.extend(g.node(NodeId(i)).inputs.iter().map(|x| x.index()));
            }
            Op::Gemm(_) => {
                if !gemms.contains(&i) {
                    gemms.push(i);
                }
            }
            _ => return None,
        }
    }
    if gemms.len() == 1 && !utils.is_empty() {
        Some((gemms[0], utils))
    } else {
        None
    }
}

/// Megatron-style tensor parallelism: split every attention and FFN GEMM
/// across `tp` ranks and insert the collectives that stitch the shards
/// back together. The rewritten graph describes **one rank's** work —
/// ranks are symmetric, so cluster latency is this rank's makespan with
/// the collectives priced at the full participant count.
///
/// Per attention pattern: the Q/K/V projections feeding the scores BMM
/// split column-wise (each rank computes `heads/tp` heads), both
/// attention BMMs and the softmax shrink to their head slice, and the
/// output projection splits row-wise — its partial sum is completed by
/// an inserted AllReduce. Per FFN: the up-projection splits column-wise,
/// the intermediate activation shrinks, and the down-projection splits
/// row-wise + AllReduce. Patterns whose dimensions don't divide by `tp`
/// are left untouched (and counted by nobody); `tp <= 1` is the
/// single-device identity — the graph is not rebuilt at all, preserving
/// the bit-for-bit `Placement::single()` guarantee.
///
/// Returns the number of GEMMs sharded.
#[derive(Clone, Copy, Debug)]
pub struct TensorParallelPass {
    pub tp: usize,
}

impl Pass for TensorParallelPass {
    fn name(&self) -> &'static str {
        "tensor-parallel"
    }

    fn run(&self, g: &mut ModelGraph, _ctx: &PassCtx<'_>) -> usize {
        let tp = self.tp;
        if tp <= 1 {
            return 0;
        }
        let cons = g.consumers();
        let mut replace: HashMap<usize, Op> = HashMap::new();
        let mut reduce_after: HashMap<usize, CommOp> = HashMap::new();
        let mut sharded = 0usize;

        // Attention: column-parallel Q/K/V, head-split BMMs + softmax,
        // row-parallel output projection + AllReduce.
        for m in match_attention(g, &cons) {
            let Op::Gemm(s1) = g.node(NodeId(m.scores)).op else { continue };
            let Op::Gemm(s2) = g.node(NodeId(m.ctx)).op else { continue };
            let Op::Util(sm) = g.node(NodeId(m.softmax)).op else { continue };
            if m.lanes % tp != 0 {
                continue;
            }
            let qkvs: Vec<(usize, GemmOp)> = g
                .node(NodeId(m.scores))
                .inputs
                .iter()
                .filter_map(|x| match g.node(*x).op {
                    Op::Gemm(q) if q.api == GemmApi::Linear && q.shard.is_none() => {
                        Some((x.index(), q))
                    }
                    _ => None,
                })
                .collect();
            let proj = cons[m.ctx].iter().find_map(|c| match g.node(*c).op {
                Op::Gemm(p) if p.api == GemmApi::Linear && p.shard.is_none() => {
                    Some((c.index(), p))
                }
                _ => None,
            });
            let Some((pi, p)) = proj else { continue };
            // Ragged serving batches share one QKV projection (and one
            // output projection) across per-sequence attention chains:
            // a producer already sharded by an earlier match is fine as
            // long as this match wants the identical shard.
            let consistent = |i: &usize, want: GemmOp| match replace.get(i) {
                None => true,
                Some(Op::Gemm(r)) => *r == want,
                _ => false,
            };
            if qkvs.is_empty()
                || qkvs.iter().any(|(_, q)| q.n % tp != 0)
                || p.k % tp != 0
                || replace.contains_key(&m.scores)
                || !consistent(&pi, p.sharded(ShardDim::Row, tp))
                || qkvs
                    .iter()
                    .any(|(qi, q)| !consistent(qi, q.sharded(ShardDim::Col, tp)))
            {
                continue;
            }
            for (qi, q) in qkvs {
                if replace.insert(qi, Op::Gemm(q.sharded(ShardDim::Col, tp))).is_none() {
                    sharded += 1;
                }
            }
            replace.insert(m.scores, Op::Gemm(GemmOp { batch: s1.batch / tp, ..s1 }));
            replace.insert(m.ctx, Op::Gemm(GemmOp { batch: s2.batch / tp, ..s2 }));
            sharded += 2;
            replace.insert(m.softmax, Op::Util(UtilOp { rows: sm.rows / tp, ..sm }));
            if replace.insert(pi, Op::Gemm(p.sharded(ShardDim::Row, tp))).is_none() {
                sharded += 1;
            }
            reduce_after
                .insert(pi, CommOp::all_reduce(p.batch * p.m * p.n, p.dtype, tp));
        }

        // FFN: column-parallel up, shrunk activation chain, row-parallel
        // down + AllReduce. `up.n == down.k` is the plain FFN; `2·down.k`
        // is the gated (up‖gate) projection.
        for di in 0..g.len() {
            if replace.contains_key(&di) {
                continue;
            }
            let Op::Gemm(d) = g.node(NodeId(di)).op else { continue };
            if d.api != GemmApi::Linear || d.shard.is_some() {
                continue;
            }
            let Some((ui, utils)) = ffn_chain(g, di) else { continue };
            if replace.contains_key(&ui) {
                continue;
            }
            let Op::Gemm(u) = g.node(NodeId(ui)).op else { continue };
            if u.api != GemmApi::Linear
                || u.shard.is_some()
                || !(u.n == d.k || u.n == 2 * d.k)
                || u.n % tp != 0
                || d.k % tp != 0
            {
                continue;
            }
            let chain_ok = utils.iter().all(|&x| match g.node(NodeId(x)).op {
                Op::Util(w) => w.cols % tp == 0 && !replace.contains_key(&x),
                _ => false,
            });
            if !chain_ok {
                continue;
            }
            replace.insert(ui, Op::Gemm(u.sharded(ShardDim::Col, tp)));
            replace.insert(di, Op::Gemm(d.sharded(ShardDim::Row, tp)));
            sharded += 2;
            for &x in &utils {
                if let Op::Util(w) = g.node(NodeId(x)).op {
                    replace.insert(x, Op::Util(UtilOp { cols: w.cols / tp, ..w }));
                }
            }
            reduce_after
                .insert(di, CommOp::all_reduce(d.batch * d.m * d.n, d.dtype, tp));
        }

        if sharded == 0 {
            return 0;
        }

        // Rebuild with collective insertion (rebuild_graph can only drop
        // or replace nodes, never add): each node re-emits under its
        // replacement op; a node carrying a pending AllReduce is followed
        // by the collective, and the remap points consumers at the
        // *reduced* tensor.
        let mut out = ModelGraph::new();
        let mut remap: Vec<NodeId> = Vec::with_capacity(g.len());
        for i in 0..g.len() {
            let node = g.node(NodeId(i));
            let op = replace.get(&i).copied().unwrap_or(node.op);
            let ins: Vec<NodeId> = node.inputs.iter().map(|x| remap[x.index()]).collect();
            let id = out.add_node(op, &ins);
            if node.causal {
                out.mark_causal(id);
            }
            if node.kv_groups > 1 {
                out.mark_kv_groups(id, node.kv_groups);
            }
            if let Some(c) = reduce_after.get(&i) {
                remap.push(out.add_node(Op::Comm(*c), &[id]));
            } else {
                remap.push(id);
            }
        }
        for &o in g.outputs() {
            out.mark_output(remap[o.index()]);
        }
        *g = out;
        sharded
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::device_by_name;
    use crate::models::zoo;
    use crate::ops::{DType, GemmOp, UtilOp};

    #[test]
    fn pass_result_cache_memoizes_per_structure_and_config() {
        let cache = PassResultCache::new(8);
        let cfg = zoo::gpt2_large();
        let g = cfg.graph(1, 64);
        let tag2 = PassResultCache::config_tag("tensor-parallel", &2usize);
        let tag4 = PassResultCache::config_tag("tensor-parallel", &4usize);
        assert_ne!(tag2, tag4, "parameters are part of the config tag");
        let shard = |tp: usize| {
            let mut rank = g.clone();
            TensorParallelPass { tp }.run(&mut rank, &PassCtx::structural());
            rank
        };
        let a = cache.rewrite(tag2, &g, || shard(2));
        let b = cache.rewrite(tag2, &g, || panic!("second lookup must hit"));
        assert!(Arc::ptr_eq(&a, &b), "hits share the stored rewrite");
        assert_eq!(cache.hits(), 1);
        assert_eq!(cache.misses(), 1);
        // A different configuration over the same structure recomputes …
        let c = cache.rewrite(tag4, &g, || shard(4));
        assert_ne!(a.stable_hash(), c.stable_hash());
        // … as does the same configuration over a different structure.
        let g2 = cfg.graph(1, 128);
        let d = cache.rewrite(tag2, &g2, || {
            let mut rank = g2.clone();
            TensorParallelPass { tp: 2 }.run(&mut rank, &PassCtx::structural());
            rank
        });
        assert_ne!(a.stable_hash(), d.stable_hash());
        assert_eq!(cache.len(), 3);
        // The memoized rewrite is the rewrite, node for node.
        assert_eq!(a.stable_hash(), shard(2).stable_hash());
    }

    #[test]
    fn pass_result_cache_bound_clears_instead_of_growing() {
        let cache = PassResultCache::new(2);
        let cfg = zoo::gpt2_large();
        let tag = PassResultCache::config_tag("noop", &0usize);
        for seq in [16usize, 32, 48, 64] {
            let g = cfg.graph(1, seq);
            cache.rewrite(tag, &g, || g.clone());
        }
        assert!(cache.len() <= 2, "bound must hold under churn");
    }

    fn fused_count(g: &ModelGraph) -> usize {
        g.nodes()
            .iter()
            .filter(|n| {
                matches!(
                    n.op,
                    Op::Custom(CustomOp::FlashAttn { .. } | CustomOp::CutlassAttn { .. })
                )
            })
            .count()
    }

    fn softmax_count(g: &ModelGraph) -> usize {
        g.nodes()
            .iter()
            .filter(|n| matches!(n.op, Op::Util(u) if u.kind == UtilKind::Softmax))
            .count()
    }

    #[test]
    fn fuses_one_subgraph_per_block_on_ampere() {
        let dev = device_by_name("a100").unwrap();
        for cfg in [zoo::gpt2_large(), zoo::qwen3_0_6b()] {
            let mut g = cfg.graph(1, 128);
            let before = g.len();
            let rewrites = AttentionFusion::default()
                .run(&mut g, &PassCtx::for_device(&dev));
            assert_eq!(rewrites, cfg.layers, "{}: one match per block", cfg.name);
            assert_eq!(fused_count(&g), cfg.layers);
            assert_eq!(softmax_count(&g), 0, "no unfused attention left");
            assert_eq!(g.len(), before - 2 * cfg.layers, "3 nodes became 1");
            g.validate().unwrap();
            // FlashAttn preferred on Ampere; decoder-only self-attention
            // carries the builder's causal mark onto the fused kernels.
            assert!(g
                .nodes()
                .iter()
                .any(|n| matches!(n.op, Op::Custom(CustomOp::FlashAttn { .. }))));
            assert!(
                g.nodes().iter().all(|n| match n.op {
                    Op::Custom(
                        CustomOp::FlashAttn { causal, q_len, kv_len, .. }
                        | CustomOp::CutlassAttn { causal, q_len, kv_len, .. },
                    ) => causal && q_len == 128 && kv_len == 128,
                    _ => true,
                }),
                "{}: prefill fusion must emit causal square kernels",
                cfg.name
            );
        }
    }

    #[test]
    fn decode_step_pattern_fuses_to_kv_shaped_kernel() {
        // Decode-shaped attention (q = 1, kv = cache length) must fuse
        // into a decode-shaped kernel, and the causal pass must infer the
        // mask without any builder annotation.
        let dt = DType::F32;
        let (lanes, kv, hd) = (16usize, 384usize, 64usize);
        let mut g = ModelGraph::new();
        let qkv = g.add_node(Op::Gemm(GemmOp::linear(1, 3 * lanes * hd, lanes * hd, dt)), &[]);
        let scores = g.add_node(Op::Gemm(GemmOp::bmm(lanes, 1, kv, hd, dt)), &[qkv]);
        let probs =
            g.add_node(Op::Util(UtilOp::new(UtilKind::Softmax, lanes, kv, dt)), &[scores]);
        let ctx_v = g.add_node(Op::Gemm(GemmOp::bmm(lanes, 1, hd, kv, dt)), &[probs, qkv]);
        let proj = g.add_node(Op::Gemm(GemmOp::linear(1, lanes * hd, lanes * hd, dt)), &[ctx_v]);
        g.mark_output(proj);
        let marked = CausalMaskPropagation.run(&mut g, &PassCtx::structural());
        assert_eq!(marked, 3, "decode shape inferred causal across the pattern");
        let rewrites = AttentionFusion::default().run(&mut g, &PassCtx::structural());
        assert_eq!(rewrites, 1);
        g.validate().unwrap();
        let fused = g
            .nodes()
            .iter()
            .find_map(|n| match n.op {
                Op::Custom(c @ CustomOp::FlashAttn { .. }) => Some(c),
                _ => None,
            })
            .expect("decode pattern fused");
        assert!(matches!(
            fused,
            CustomOp::FlashAttn { q_len: 1, kv_len: 384, causal: true, .. }
        ));
    }

    #[test]
    fn gqa_annotation_fuses_to_grouped_kernels() {
        // ISSUE GQA satellite: the builder's kv_groups annotation reaches
        // the fused kernel as a grouped (kv_heads < heads) shape whose KV
        // traffic is the grouped cache, not the MHA-expanded one.
        let cfg = zoo::qwen3_4b(); // 32 heads, 8 kv_heads → groups = 4
        let groups = cfg.heads / cfg.kv_heads;
        let mut g = cfg.decode_graph(1, 512);
        CausalMaskPropagation.run(&mut g, &PassCtx::structural());
        let rewrites = AttentionFusion::default().run(&mut g, &PassCtx::structural());
        assert_eq!(rewrites, cfg.layers);
        g.validate().unwrap();
        let mut grouped_io = 0.0;
        let mut seen = 0usize;
        for n in g.nodes() {
            if let Op::Custom(
                c @ (CustomOp::FlashAttn { batch, heads, kv_heads, .. }
                | CustomOp::CutlassAttn { batch, heads, kv_heads, .. }),
            ) = n.op
            {
                seen += 1;
                assert_eq!(batch * heads, cfg.heads, "query lanes preserved");
                assert_eq!(heads, groups, "group factor encoded in heads");
                assert_eq!(kv_heads, 1, "one KV lane per group");
                assert_eq!(batch * kv_heads, cfg.kv_heads, "grouped cache lanes");
                grouped_io += c.io_bytes();
                // The MHA-expanded equivalent streams more bytes.
                let mha = CustomOp::FlashAttn {
                    batch: batch * heads,
                    heads: 1,
                    kv_heads: 1,
                    q_len: 1,
                    kv_len: 512,
                    head_dim: cfg.head_dim(),
                    dtype: cfg.dtype,
                    causal: true,
                };
                assert!(c.io_bytes() < mha.io_bytes());
            }
        }
        assert_eq!(seen, cfg.layers);
        assert!(grouped_io > 0.0);
        // MHA models carry no annotation and keep the historical shape.
        let mha_cfg = zoo::gpt2_large();
        let mut mg = mha_cfg.graph(1, 64);
        AttentionFusion::default().run(&mut mg, &PassCtx::structural());
        for n in mg.nodes() {
            if let Op::Custom(CustomOp::FlashAttn { batch, heads, kv_heads, .. }) = n.op {
                assert_eq!((batch, heads, kv_heads), (mha_cfg.heads, 1, 1));
            }
        }
    }

    #[test]
    fn causal_propagation_spreads_builder_marks_and_is_idempotent() {
        let cfg = zoo::gpt2_large();
        let mut g = cfg.graph(1, 64);
        // The builder marks one scores BMM per decoder block; propagation
        // extends each mark to the softmax + context BMM.
        let marked = CausalMaskPropagation.run(&mut g, &PassCtx::structural());
        assert_eq!(marked, 2 * cfg.layers);
        assert_eq!(
            CausalMaskPropagation.run(&mut g, &PassCtx::structural()),
            0,
            "fixed point on the second run"
        );
        assert_eq!(g.lower(), cfg.trace(1, 64), "annotation-only pass");
        // Encoder self-attention stays unmasked: T5's encoder blocks gain
        // no causal marks, its decoder blocks do.
        let t5 = zoo::flan_t5_base();
        let mut tg = t5.graph(1, 64);
        let t5_marked = CausalMaskPropagation.run(&mut tg, &PassCtx::structural());
        assert_eq!(t5_marked, 2 * t5.layers, "decoder self-attention only");
    }

    #[test]
    fn enc_dec_fuses_self_and_cross_attention() {
        let dev = device_by_name("a100").unwrap();
        let cfg = zoo::flan_t5_base();
        let mut g = cfg.graph(1, 64);
        let rewrites =
            AttentionFusion::default().run(&mut g, &PassCtx::for_device(&dev));
        // Encoder blocks + decoder blocks + decoder cross-attention.
        assert_eq!(rewrites, cfg.enc_layers + 2 * cfg.layers);
        g.validate().unwrap();
        assert_eq!(g.lower().len(), g.len(), "lowering still covers every node");
    }

    #[test]
    fn turing_falls_back_to_cutlass_and_blackwell_declines() {
        let cfg = zoo::gpt2_large(); // F32 — runs on every device
        let t4 = device_by_name("t4").unwrap();
        let mut g = cfg.graph(1, 64);
        let rewrites = AttentionFusion::default().run(&mut g, &PassCtx::for_device(&t4));
        assert_eq!(rewrites, cfg.layers);
        assert!(
            g.nodes()
                .iter()
                .all(|n| !matches!(n.op, Op::Custom(CustomOp::FlashAttn { .. }))),
            "no FlashAttention-2 on Turing"
        );
        assert!(g
            .nodes()
            .iter()
            .any(|n| matches!(n.op, Op::Custom(CustomOp::CutlassAttn { .. }))));

        let b5070 = device_by_name("rtx5070").unwrap();
        let mut g2 = cfg.graph(1, 64);
        assert_eq!(
            AttentionFusion::default().run(&mut g2, &PassCtx::for_device(&b5070)),
            0,
            "no attention kernels on Blackwell"
        );
        assert_eq!(g2.lower(), cfg.trace(1, 64), "declined pass leaves graph intact");
    }

    #[test]
    fn cost_gate_requires_a_cost_model() {
        let dev = device_by_name("a100").unwrap();
        let mut g = zoo::gpt2_large().graph(1, 64);
        let pass = AttentionFusion { only_if_faster: true };
        assert_eq!(pass.run(&mut g, &PassCtx::for_device(&dev)), 0);
        // A cost model that prices the fused kernel cheaper admits it.
        let cost = |op: &Op| match op {
            Op::Custom(_) => Some(1.0),
            _ => Some(10.0),
        };
        let ctx = PassCtx::with_cost(&dev, &cost);
        assert_eq!(pass.run(&mut g, &ctx), zoo::gpt2_large().layers);
        // And one that prices it dearer rejects it.
        let mut g2 = zoo::gpt2_large().graph(1, 64);
        let dear = |op: &Op| match op {
            Op::Custom(_) => Some(1e9),
            _ => Some(1.0),
        };
        let ctx2 = PassCtx::with_cost(&dev, &dear);
        assert_eq!(pass.run(&mut g2, &ctx2), 0);
    }

    #[test]
    fn fusion_preserves_external_wiring() {
        // qkv → [scores → softmax → ctx] → proj becomes qkv → fused → proj.
        let dt = DType::F32;
        let mut g = ModelGraph::new();
        let qkv = g.add_node(Op::Gemm(GemmOp::linear(64, 192, 64, dt)), &[]);
        let scores = g.add_node(Op::Gemm(GemmOp::bmm(4, 64, 64, 16, dt)), &[qkv]);
        let probs =
            g.add_node(Op::Util(UtilOp::new(UtilKind::Softmax, 4 * 64, 64, dt)), &[scores]);
        let ctx_v = g.add_node(Op::Gemm(GemmOp::bmm(4, 64, 16, 64, dt)), &[probs, qkv]);
        let proj = g.add_node(Op::Gemm(GemmOp::linear(64, 64, 64, dt)), &[ctx_v]);
        g.mark_output(proj);
        assert_eq!(
            AttentionFusion::default().run(&mut g, &PassCtx::structural()),
            1
        );
        g.validate().unwrap();
        assert_eq!(g.len(), 3);
        let fused = &g.node(NodeId(1));
        assert!(matches!(fused.op, Op::Custom(CustomOp::FlashAttn { .. })));
        assert_eq!(fused.inputs, vec![NodeId(0)], "external input deduped to qkv");
        assert_eq!(g.node(NodeId(2)).inputs, vec![NodeId(1)], "consumer rewired");
        assert_eq!(g.outputs(), &[NodeId(2)]);
    }

    #[test]
    fn dce_removes_unreachable_nodes_only_with_marked_outputs() {
        let dt = DType::F32;
        let mut g = ModelGraph::new();
        let a = g.add_node(Op::Gemm(GemmOp::mm(32, 32, 32, dt)), &[]);
        let b = g.add_node(Op::Util(UtilOp::new(UtilKind::Relu, 32, 32, dt)), &[a]);
        g.add_node(Op::Gemm(GemmOp::mm(64, 64, 64, dt)), &[]); // orphan
        let mut unmarked = g.clone();
        assert_eq!(DeadNodeElimination.run(&mut unmarked, &PassCtx::structural()), 0);
        g.mark_output(b);
        assert_eq!(DeadNodeElimination.run(&mut g, &PassCtx::structural()), 1);
        assert_eq!(g.len(), 2);
        g.validate().unwrap();
        assert_eq!(g.outputs(), &[NodeId(1)]);
    }

    #[test]
    fn transformer_graph_has_no_dead_nodes() {
        let cfg = zoo::qwen3_0_6b();
        let mut g = cfg.graph(2, 128);
        let before = g.len();
        assert_eq!(DeadNodeElimination.run(&mut g, &PassCtx::structural()), 0);
        assert_eq!(g.len(), before);
    }

    #[test]
    fn tp1_is_the_identity() {
        let cfg = zoo::gpt2_large();
        let g0 = cfg.graph(1, 64);
        let mut g = g0.clone();
        assert_eq!(TensorParallelPass { tp: 1 }.run(&mut g, &PassCtx::structural()), 0);
        assert_eq!(g.len(), g0.len());
        assert_eq!(g.lower(), g0.lower(), "tp = 1 must not rebuild the graph");
    }

    #[test]
    fn tp2_shards_every_block_and_inserts_collectives() {
        for cfg in [zoo::gpt2_large(), zoo::qwen3_0_6b()] {
            let g0 = cfg.graph(1, 128);
            let mut g = g0.clone();
            let tp = 2usize;
            let n = TensorParallelPass { tp }.run(&mut g, &PassCtx::structural());
            // Per block: qkv + scores + ctx + proj + FFN up + FFN down.
            assert_eq!(n, 6 * cfg.layers, "{}", cfg.name);
            g.validate().unwrap();
            // Two AllReduces per block: after proj and after FFN down.
            let comms: Vec<CommOp> = g
                .nodes()
                .iter()
                .filter_map(|nd| match nd.op {
                    Op::Comm(c) => Some(c),
                    _ => None,
                })
                .collect();
            assert_eq!(comms.len(), 2 * cfg.layers, "{}", cfg.name);
            assert!(comms
                .iter()
                .all(|c| c.kind == crate::ops::CommKind::AllReduce && c.participants == tp));
            // Collective payload matches the shard math: each AllReduce
            // carries a full rows×hidden activation.
            assert!(comms.iter().all(|c| c.elems == 128 * cfg.hidden), "{}", cfg.name);
            // FLOP conservation: the rank graph plus its (tp−1) peers do
            // exactly the original GEMM work.
            let gemm_flops = |gr: &ModelGraph| -> f64 {
                gr.nodes()
                    .iter()
                    .filter_map(|nd| match nd.op {
                        Op::Gemm(gm) => Some(gm.flops()),
                        _ => None,
                    })
                    .sum()
            };
            let orig = gemm_flops(&g0);
            let rank = gemm_flops(&g);
            let unsharded: f64 = g0
                .nodes()
                .iter()
                .zip(g.nodes().iter().filter(|nd| !matches!(nd.op, Op::Comm(_))))
                .filter(|(a, b)| a.op == b.op)
                .filter_map(|(a, _)| match a.op {
                    Op::Gemm(gm) => Some(gm.flops()),
                    _ => None,
                })
                .sum();
            assert_eq!(
                (rank - unsharded) * tp as f64 + unsharded,
                orig,
                "{}: shard FLOPs must sum to the unsharded total",
                cfg.name
            );
        }
    }

    #[test]
    fn tp2_shards_ragged_mixed_batches_with_shared_projections() {
        // Serving iterations share one QKV / output projection across
        // per-sequence attention chains; every chain must still shard —
        // a half-sharded iteration would price one slot's BMMs at full
        // head count against a column-sharded QKV.
        use crate::models::SeqSlot;
        let cfg = zoo::gpt2_large();
        let slots = [SeqSlot::prefill(0, 64), SeqSlot::decode(32)];
        let mut g = cfg.mixed_batch_graph(&slots);
        let n = TensorParallelPass { tp: 2 }.run(&mut g, &PassCtx::structural());
        // Per block: qkv + proj + FFN up/down, plus (scores, ctx) per slot.
        assert_eq!(n, (4 + 2 * slots.len()) * cfg.layers);
        g.validate().unwrap();
        for nd in g.nodes() {
            if let Op::Gemm(gm) = nd.op {
                if gm.api == GemmApi::Bmm {
                    assert_eq!(gm.batch, cfg.heads / 2, "every slot runs the head slice");
                }
            }
        }
        let comms = g.nodes().iter().filter(|nd| matches!(nd.op, Op::Comm(_))).count();
        assert_eq!(comms, 2 * cfg.layers, "one AllReduce per proj and FFN down");
    }

    #[test]
    fn tp_composes_with_fusion_and_respects_divisibility() {
        // TP then fusion: the head-split attention still fuses, over
        // lanes/tp blocks.
        let cfg = zoo::gpt2_large();
        let mut g = cfg.graph(1, 64);
        TensorParallelPass { tp: 2 }.run(&mut g, &PassCtx::structural());
        let rewrites = AttentionFusion::default().run(&mut g, &PassCtx::structural());
        assert_eq!(rewrites, cfg.layers);
        g.validate().unwrap();
        for n in g.nodes() {
            if let Op::Custom(CustomOp::FlashAttn { batch, heads, .. }) = n.op {
                assert_eq!(batch * heads, cfg.heads / 2, "half the heads per rank");
            }
        }
        // A degree that does not divide the head count declines cleanly.
        let g0 = cfg.graph(1, 64);
        let mut g2 = g0.clone();
        let n = TensorParallelPass { tp: 7 }.run(&mut g2, &PassCtx::structural());
        // Attention (20 heads % 7 ≠ 0) is skipped; whether FFN shards
        // depends on divisibility, so just require a valid result.
        let _ = n;
        g2.validate().unwrap();
    }

    #[test]
    fn standard_pipeline_reports_per_pass_counts() {
        let dev = device_by_name("a100").unwrap();
        let cfg = zoo::qwen3_0_6b();
        let mut g = cfg.graph(1, 128);
        let report = PassManager::standard().run(&mut g, &PassCtx::for_device(&dev));
        assert_eq!(report.len(), 3);
        assert_eq!(report[0], ("causal-mask-propagation", 2 * cfg.layers));
        assert_eq!(report[1], ("attention-fusion", cfg.layers));
        assert_eq!(report[2].0, "dead-node-elimination");
        assert_eq!(report[2].1, 0, "fusion leaves no garbage behind");
        g.validate().unwrap();
    }
}
