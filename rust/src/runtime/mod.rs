//! # runtime — the PJRT execution layer (L2→L3 boundary)
//!
//! Load the AOT HLO-text artifacts produced by `python/compile/aot.py`,
//! compile them once on the PJRT CPU client, and execute them from the
//! Rust request path. Python never runs here: the batched PM2Lat GEMM
//! kernel and the NeuSight MLP arrive as HLO text under `artifacts/`
//! (`make artifacts`), and everything downstream is `Runtime::call`.
//!
//! Interchange is HLO *text* — jax ≥ 0.5 serialized protos use 64-bit
//! instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (see DESIGN.md / aot.py).

use std::collections::{BTreeMap, HashMap};
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

/// Typed host-side argument for an artifact call.
pub enum ArgValue<'a> {
    F32(&'a [f32], &'a [usize]),
    I32(&'a [i32], &'a [usize]),
    /// Rank-0 f32.
    ScalarF32(f32),
}

/// Parsed `artifacts/manifest.json`.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub feature_dim: usize,
    pub hidden_dim: usize,
    pub max_kernels: usize,
    pub n_k_points: usize,
    /// name → (file, arg shapes with dtype strings).
    pub artifacts: BTreeMap<String, (String, Vec<(Vec<usize>, String)>)>,
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Manifest> {
        let v = Json::parse(text).context("manifest.json parse")?;
        let num = |k: &str| -> Result<usize> {
            v.get(k).and_then(Json::as_usize).ok_or_else(|| anyhow!("manifest missing {k}"))
        };
        let mut artifacts = BTreeMap::new();
        let arts = v
            .get("artifacts")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("manifest missing artifacts"))?;
        for (name, entry) in arts {
            let file = entry
                .get("file")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("artifact {name} missing file"))?
                .to_string();
            let mut args = Vec::new();
            for spec in entry
                .get("args")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("artifact {name} missing args"))?
            {
                let pair = spec.as_arr().ok_or_else(|| anyhow!("bad arg spec"))?;
                let shape: Vec<usize> = pair[0]
                    .as_arr()
                    .ok_or_else(|| anyhow!("bad shape"))?
                    .iter()
                    .filter_map(Json::as_usize)
                    .collect();
                let dtype = pair[1].as_str().unwrap_or("float32").to_string();
                args.push((shape, dtype));
            }
            artifacts.insert(name.clone(), (file, args));
        }
        Ok(Manifest {
            feature_dim: num("feature_dim")?,
            hidden_dim: num("hidden_dim")?,
            max_kernels: num("max_kernels")?,
            n_k_points: num("n_k_points")?,
            artifacts,
        })
    }
}

/// Compile-once, execute-many PJRT runtime over the artifact directory.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    pub manifest: Manifest,
    exes: Mutex<HashMap<String, Arc<xla::PjRtLoadedExecutable>>>,
}

/// Locate the artifacts directory: $PM2LAT_ARTIFACTS, then ./artifacts,
/// then ancestors (so tests work from the crate root or target dirs).
pub fn default_artifacts_dir() -> Option<PathBuf> {
    if let Ok(p) = std::env::var("PM2LAT_ARTIFACTS") {
        let p = PathBuf::from(p);
        if p.join("manifest.json").exists() {
            return Some(p);
        }
    }
    let mut cur = std::env::current_dir().ok()?;
    loop {
        let cand = cur.join("artifacts");
        if cand.join("manifest.json").exists() {
            return Some(cand);
        }
        if !cur.pop() {
            return None;
        }
    }
}

impl Runtime {
    pub fn new(dir: &Path) -> Result<Runtime> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("reading {}/manifest.json — run `make artifacts`", dir.display()))?;
        let manifest = Manifest::parse(&text)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        Ok(Runtime { client, dir: dir.to_path_buf(), manifest, exes: Mutex::new(HashMap::new()) })
    }

    /// Open using the default artifact search path.
    pub fn open_default() -> Result<Runtime> {
        let dir = default_artifacts_dir()
            .ok_or_else(|| anyhow!("artifacts/ not found — run `make artifacts`"))?;
        Runtime::new(&dir)
    }

    pub fn artifact_names(&self) -> Vec<String> {
        self.manifest.artifacts.keys().cloned().collect()
    }

    /// Get (compiling + caching on first use) the executable for `name`.
    fn exe(&self, name: &str) -> Result<Arc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.exes.lock().unwrap().get(name) {
            return Ok(e.clone());
        }
        let (file, _) = self
            .manifest
            .artifacts
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact `{name}`"))?;
        let path = self.dir.join(file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parse HLO {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Arc::new(
            self.client
                .compile(&comp)
                .map_err(|e| anyhow!("compile {name}: {e:?}"))?,
        );
        self.exes.lock().unwrap().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Pre-compile an artifact (warm the cache explicitly).
    pub fn warm(&self, name: &str) -> Result<()> {
        self.exe(name).map(|_| ())
    }

    fn literal(arg: &ArgValue) -> Result<xla::Literal> {
        Ok(match arg {
            ArgValue::F32(data, shape) => {
                let n: usize = shape.iter().product();
                if n != data.len() {
                    bail!("arg shape {:?} != data len {}", shape, data.len());
                }
                let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(data)
                    .reshape(&dims)
                    .map_err(|e| anyhow!("reshape: {e:?}"))?
            }
            ArgValue::I32(data, shape) => {
                let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(data)
                    .reshape(&dims)
                    .map_err(|e| anyhow!("reshape: {e:?}"))?
            }
            ArgValue::ScalarF32(v) => xla::Literal::scalar(*v),
        })
    }

    /// Execute artifact `name`; returns every tuple element flattened to
    /// f32 vectors (all our artifact outputs are f32).
    pub fn call(&self, name: &str, args: &[ArgValue]) -> Result<Vec<Vec<f32>>> {
        let exe = self.exe(name)?;
        let expected = &self.manifest.artifacts[name].1;
        if args.len() != expected.len() {
            bail!("artifact {name} expects {} args, got {}", expected.len(), args.len());
        }
        let literals: Vec<xla::Literal> =
            args.iter().map(Self::literal).collect::<Result<_>>()?;
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("execute {name}: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch {name}: {e:?}"))?;
        let parts = result.to_tuple().map_err(|e| anyhow!("untuple: {e:?}"))?;
        parts
            .into_iter()
            .map(|lit| lit.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}")))
            .collect()
    }
}

/// Load `params_init.json` (the MLP init the Rust trainer starts from).
pub fn load_params_init(dir: &Path) -> Result<Vec<(Vec<usize>, Vec<f32>)>> {
    let text = std::fs::read_to_string(dir.join("params_init.json"))?;
    let v = Json::parse(&text).context("params_init.json")?;
    let obj = v.as_obj().ok_or_else(|| anyhow!("params_init not an object"))?;
    let mut out = Vec::new();
    for i in 0..obj.len() {
        let p = v
            .get(&format!("p{i}"))
            .ok_or_else(|| anyhow!("missing p{i}"))?;
        let shape: Vec<usize> = p
            .get("shape")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("p{i} missing shape"))?
            .iter()
            .filter_map(Json::as_usize)
            .collect();
        let data: Vec<f32> = p
            .get("data")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("p{i} missing data"))?
            .iter()
            .filter_map(|x| x.as_f64().map(|f| f as f32))
            .collect();
        out.push((shape, data));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn runtime() -> Runtime {
        Runtime::open_default().expect("run `make artifacts` first")
    }

    #[test]
    fn manifest_lists_all_entries() {
        let rt = runtime();
        let names = rt.artifact_names();
        assert!(names.iter().any(|n| n.starts_with("neusight_infer")));
        assert!(names.iter().any(|n| n.starts_with("neusight_train")));
        assert!(names.iter().any(|n| n.starts_with("pm2lat_batch_predict")));
        assert!(names.iter().any(|n| n.starts_with("pm2lat_gram")));
        assert_eq!(rt.manifest.feature_dim, 16);
        assert_eq!(rt.manifest.n_k_points, 9);
    }

    #[test]
    fn gram_artifact_plus_rust_solve_recovers_coefficients() {
        let rt = runtime();
        let n = 4096usize;
        let p = 8usize;
        let mut rng = crate::util::prng::Rng::new(3);
        let truth: Vec<f64> = (0..p).map(|_| rng.normal()).collect();
        let mut x = vec![0f32; n * p];
        let mut y = vec![0f32; n];
        for i in 0..n {
            let mut acc = 0.0;
            for j in 0..p {
                let v = rng.normal();
                x[i * p + j] = v as f32;
                acc += v * truth[j];
            }
            y[i] = acc as f32;
        }
        let out = rt
            .call(
                "pm2lat_gram_n4096_p8",
                &[ArgValue::F32(&x, &[n, p]), ArgValue::F32(&y, &[n])],
            )
            .unwrap();
        assert_eq!(out.len(), 2);
        let xtx: Vec<f64> = out[0].iter().map(|&v| v as f64).collect();
        let xty: Vec<f64> = out[1].iter().map(|&v| v as f64).collect();
        let coeffs =
            crate::util::stats::cholesky_solve(&xtx, &xty, p).expect("solve");
        for (got, want) in coeffs.iter().zip(&truth) {
            assert!((got - want).abs() < 1e-3, "{got} vs {want}");
        }
    }

    #[test]
    fn batch_predict_artifact_matches_eq12() {
        let rt = runtime();
        let nk = rt.manifest.max_kernels;
        let npts = rt.manifest.n_k_points;
        // Flat throughput rows → Eq 1 reduces to orgDur * K/8192 * scale.
        let table = vec![2.0f32; nk * npts];
        let base: Vec<f32> = (0..nk).map(|i| 1.0 + i as f32).collect();
        let b = 1024usize;
        let k_vals = vec![4096.0f32; b];
        let kids: Vec<i32> = (0..b).map(|i| (i % nk) as i32).collect();
        let scale = vec![2.0f32; b];
        let out = rt
            .call(
                "pm2lat_batch_predict_b1024",
                &[
                    ArgValue::F32(&table, &[nk, npts]),
                    ArgValue::F32(&base, &[nk]),
                    ArgValue::F32(&k_vals, &[b]),
                    ArgValue::I32(&kids, &[b]),
                    ArgValue::F32(&scale, &[b]),
                ],
            )
            .unwrap();
        for (i, &v) in out[0].iter().enumerate() {
            let want = (1.0 + (i % nk) as f32) * 0.5 * 2.0;
            assert!((v - want).abs() < 1e-4, "i={i}: {v} vs {want}");
        }
    }

    #[test]
    fn params_init_shapes_match_manifest() {
        let dir = default_artifacts_dir().unwrap();
        let params = load_params_init(&dir).unwrap();
        assert_eq!(params.len(), 6);
        let f = 16;
        let h = 128;
        assert_eq!(params[0].0, vec![f, h]);
        assert_eq!(params[4].0, vec![h, 1]);
        for (shape, data) in &params {
            assert_eq!(shape.iter().product::<usize>(), data.len());
        }
    }

    #[test]
    fn wrong_arg_count_rejected() {
        let rt = runtime();
        assert!(rt.call("pm2lat_gram_n4096_p8", &[]).is_err());
        assert!(rt.call("no_such_artifact", &[]).is_err());
    }
}
