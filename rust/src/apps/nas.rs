//! Application §IV-D2: NAS preprocessing — predict-and-cache latencies
//! for enormous configuration spaces. The paper's headline: PM2Lat at
//! 0.045 ms/prediction (CPU) vs NeuSight at 6.5 ms/prediction (GPU); the
//! 400M-configuration MatMul space takes ~5 hours vs ~30 days.

use std::collections::HashMap;
use std::time::Instant;

use crate::gpusim::Gpu;
use crate::ops::{DType, GemmOp};
use crate::pm2lat::GemmTable;
use crate::util::prng::Rng;

/// The paper's example NAS space: 14 feature-dimension choices, batch
/// 1..256, sequence 64..8192 — "the number of configurations for just one
/// MatMul layer exceeds 400 million possibilities".
pub const FEATURE_CHOICES: [usize; 14] =
    [128, 256, 384, 512, 640, 768, 1024, 1280, 1536, 2048, 2560, 3072, 4096, 5120];

pub fn space_size() -> u64 {
    // features_in × features_out × batch × seq values ≈ 4.07e8 — the
    // paper's ">400 million possibilities for just one MatMul layer".
    let b = 256u64;
    let s = 8192u64 - 64 + 1;
    14 * 14 * b * s
}

/// Sample `n` NAS MatMul configurations (M = batch·seq, N = out-features,
/// K = in-features).
pub fn sample_configs(n: usize, dtype: DType, seed: u64) -> Vec<GemmOp> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| {
            let f_in = *rng.choice(&FEATURE_CHOICES);
            let f_out = *rng.choice(&FEATURE_CHOICES);
            let batch = rng.int_range(1, 256) as usize;
            let seq = rng.log_uniform_int(64, 8192) as usize;
            GemmOp::linear((batch * seq).min(1 << 21), f_out, f_in, dtype)
        })
        .collect()
}

/// A latency cache: the precomputed lookup NAS uses at search time.
#[derive(Default)]
pub struct LatencyCache {
    map: HashMap<(usize, usize, usize, u8), f64>,
}

impl LatencyCache {
    fn key(op: &GemmOp) -> (usize, usize, usize, u8) {
        (op.m, op.n, op.k, matches!(op.dtype, DType::Bf16) as u8)
    }
    pub fn insert(&mut self, op: &GemmOp, latency: f64) {
        self.map.insert(Self::key(op), latency);
    }
    pub fn get(&self, op: &GemmOp) -> Option<f64> {
        self.map.get(&Self::key(op)).copied()
    }
    pub fn len(&self) -> usize {
        self.map.len()
    }
    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }
}

/// Timing report for a preprocessing run.
#[derive(Clone, Debug)]
pub struct SpeedReport {
    pub n_predictions: usize,
    pub total_s: f64,
    pub ms_per_prediction: f64,
    /// Extrapolated wall time for the full 400M-config space.
    pub full_space_hours: f64,
}

impl SpeedReport {
    pub fn from_run(n: usize, total_s: f64) -> SpeedReport {
        let ms = total_s * 1e3 / n as f64;
        SpeedReport {
            n_predictions: n,
            total_s,
            ms_per_prediction: ms,
            full_space_hours: ms * 4e8 / 1e3 / 3600.0,
        }
    }
}

/// Fill a cache with PM2Lat scalar-path predictions, timing the run.
pub fn preprocess_pm2lat(
    gpu: &Gpu,
    table: &GemmTable,
    configs: &[GemmOp],
    cache: &mut LatencyCache,
) -> SpeedReport {
    let t0 = Instant::now();
    for op in configs {
        if let Some(lat) = table.predict(gpu, op) {
            cache.insert(op, lat);
        }
    }
    SpeedReport::from_run(configs.len(), t0.elapsed().as_secs_f64())
}

/// Fill a cache through the prediction service (§IV-D2 at serving scale):
/// NAS is a *consumer of the coordinator*, not of raw `Pm2Lat` — one
/// submit round-trip rides the batched PJRT path, the parallel scalar
/// fallback, and the coordinator's own LRU (repeat configurations across
/// preprocessing rounds become cache hits).
pub fn preprocess_service(
    coord: &crate::coordinator::Coordinator<'_>,
    device: &str,
    configs: &[GemmOp],
    cache: &mut LatencyCache,
) -> anyhow::Result<SpeedReport> {
    use crate::coordinator::{PredictorKind, Request};
    use crate::ops::Op;
    let t0 = Instant::now();
    let requests: Vec<Request> = configs
        .iter()
        .map(|g| Request {
            device: device.to_string(),
            op: Op::Gemm(*g),
            kind: PredictorKind::Pm2LatBatched,
        })
        .collect();
    let results = coord.submit(&requests)?;
    for (g, r) in configs.iter().zip(&results) {
        if let Some(lat) = r {
            cache.insert(g, *lat);
        }
    }
    Ok(SpeedReport::from_run(configs.len(), t0.elapsed().as_secs_f64()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pm2lat::gemm_model;
    use crate::profiler::ProfileSpec;

    #[test]
    fn space_exceeds_400m() {
        assert!(space_size() > 4e8 as u64);
    }

    #[test]
    fn sampled_configs_in_domain() {
        let cfgs = sample_configs(100, DType::F32, 1);
        assert_eq!(cfgs.len(), 100);
        for c in &cfgs {
            assert!(FEATURE_CHOICES.contains(&c.k));
            assert!(FEATURE_CHOICES.contains(&c.n));
            assert!(c.m >= 64);
        }
    }

    #[test]
    fn cache_roundtrip_and_speed() {
        let mut gpu = Gpu::by_name("a100").unwrap();
        let table =
            gemm_model::collect(&mut gpu, DType::F32, &ProfileSpec::quick()).unwrap();
        gpu.reset();
        let configs = sample_configs(500, DType::F32, 2);
        let mut cache = LatencyCache::default();
        let report = preprocess_pm2lat(&gpu, &table, &configs, &mut cache);
        assert!(cache.len() > 450, "cache {} entries", cache.len());
        assert_eq!(cache.get(&configs[0]), cache.get(&configs[0]));
        // The paper's headline: well under a millisecond per prediction.
        assert!(
            report.ms_per_prediction < 1.0,
            "PM2Lat too slow: {} ms/pred",
            report.ms_per_prediction
        );
        assert!(report.full_space_hours < 120.0);
    }

    #[test]
    fn deterministic_sampling() {
        assert_eq!(sample_configs(10, DType::F32, 3), sample_configs(10, DType::F32, 3));
        assert_ne!(sample_configs(10, DType::F32, 3), sample_configs(10, DType::F32, 4));
    }
}
