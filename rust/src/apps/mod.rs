//! # apps — the two §IV-D applications
//!
//! [`partition`]: pipeline partitioning of Qwen3-4B across heterogeneous
//! edge devices; [`nas`]: NAS-preprocessing latency caching at scale.

pub mod nas;
pub mod partition;
