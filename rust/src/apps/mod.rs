//! # apps — the two §IV-D applications
//!
//! [`partition`]: pipeline partitioning of Qwen3-4B across heterogeneous
//! edge devices (block-range traces + memory feasibility + predicted
//! stage balance); [`nas`]: NAS-preprocessing latency caching at scale —
//! the §IV-D2 headline that PM2Lat's analytical predictions are cheap
//! enough to enumerate 400M-configuration search spaces. Both consume
//! the prediction *service* (`coordinator`), not raw `Pm2Lat`.

pub mod nas;
pub mod partition;
