//! Application §IV-D1: model partitioning for distributed inference
//! across two heterogeneous edge devices with pipeline parallelism.
//!
//! Qwen3-4B at batch 8 is split at one transformer-block boundary between
//! an RTX 3060M (stage 1, receives input) and an RTX 5070 (stage 2). The
//! predictor estimates per-stage latency for every cut point; the chosen
//! cut minimizes the pipeline bottleneck max(stage₁, stage₂) subject to
//! both stages fitting device memory. Ground truth comes from executing
//! each stage's trace on the simulated devices and a pipeline simulation
//! of 100 requests.

use crate::gpusim::{ExecError, Gpu};
use crate::models::runner;
use crate::models::TransformerConfig;
use crate::ops::Op;

/// Inter-stage activation transfer model (PCIe-class link).
pub const LINK_GBPS: f64 = 12.0;
pub const LINK_LATENCY_S: f64 = 150e-6;

/// A candidate plan: stage 1 = blocks [0, cut), stage 2 = [cut, L) + head.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Plan {
    pub cut: usize,
    pub stage1_s: f64,
    pub stage2_s: f64,
}

impl Plan {
    pub fn bottleneck_s(&self) -> f64 {
        self.stage1_s.max(self.stage2_s)
    }
}

/// Activation transfer time between stages for (batch, seq, hidden).
pub fn transfer_s(cfg: &TransformerConfig, batch: usize, seq: usize) -> f64 {
    let bytes = (batch * seq * cfg.hidden * cfg.dtype.bytes()) as f64;
    LINK_LATENCY_S + bytes / (LINK_GBPS * 1e9)
}

/// Memory feasibility of a cut on a device pair.
pub fn cut_fits(
    cfg: &TransformerConfig,
    cut: usize,
    batch: usize,
    seq: usize,
    dev1: &Gpu,
    dev2: &Gpu,
) -> bool {
    let act = cfg.activation_bytes(batch, seq) + 0.7e9;
    let w1 = cfg.block_range_weight_bytes(0, cut, false);
    let w2 = cfg.block_range_weight_bytes(cut, cfg.layers, true);
    dev1.check_memory(w1 + act).is_ok() && dev2.check_memory(w2 + act).is_ok()
}

/// Search the cut that minimizes the predicted bottleneck, using any
/// per-stage latency estimator (PM2Lat, NeuSight, or the oracle).
pub fn best_cut<F>(
    cfg: &TransformerConfig,
    batch: usize,
    seq: usize,
    dev1: &Gpu,
    dev2: &Gpu,
    mut estimate: F,
) -> Option<Plan>
where
    F: FnMut(&Gpu, &[Op]) -> Option<f64>,
{
    let mut best: Option<Plan> = None;
    for cut in 1..cfg.layers {
        if !cut_fits(cfg, cut, batch, seq, dev1, dev2) {
            continue;
        }
        let t1 = cfg.block_range_trace(batch, seq, 0, cut, false);
        let t2 = cfg.block_range_trace(batch, seq, cut, cfg.layers, true);
        let s1 = estimate(dev1, &t1)?;
        let s2 = estimate(dev2, &t2)? + transfer_s(cfg, batch, seq);
        let plan = Plan { cut, stage1_s: s1, stage2_s: s2 };
        if best
            .map(|b| plan.bottleneck_s() < b.bottleneck_s())
            .unwrap_or(true)
        {
            best = Some(plan);
        }
    }
    best
}

/// Measured per-stage times for a cut (ground truth on the simulators).
pub fn measure_cut(
    cfg: &TransformerConfig,
    cut: usize,
    batch: usize,
    seq: usize,
    dev1: &mut Gpu,
    dev2: &mut Gpu,
    reps: usize,
) -> Result<Plan, ExecError> {
    let t1 = cfg.block_range_trace(batch, seq, 0, cut, false);
    let t2 = cfg.block_range_trace(batch, seq, cut, cfg.layers, true);
    let mut s1 = 0.0;
    let mut s2 = 0.0;
    // Warm both devices.
    runner::run_trace_once(dev1, &t1)?;
    runner::run_trace_once(dev2, &t2)?;
    for _ in 0..reps {
        s1 += runner::run_trace_once(dev1, &t1)?;
        s2 += runner::run_trace_once(dev2, &t2)?;
    }
    Ok(Plan {
        cut,
        stage1_s: s1 / reps as f64,
        stage2_s: s2 / reps as f64 + transfer_s(cfg, batch, seq),
    })
}

/// Two-stage pipeline of `n_requests`: total completion time given the
/// measured stage times (fill + steady state paced by the bottleneck).
pub fn pipeline_completion_s(plan: &Plan, n_requests: usize) -> f64 {
    if n_requests == 0 {
        return 0.0;
    }
    plan.stage1_s + plan.stage2_s
        + (n_requests - 1) as f64 * plan.bottleneck_s()
}

/// Full §IV-D1 experiment output.
#[derive(Clone, Debug)]
pub struct PartitionResult {
    pub predictor: &'static str,
    pub chosen_cut: usize,
    pub predicted_bottleneck_s: f64,
    pub measured: Plan,
    pub completion_100_s: f64,
}

/// Run the experiment for one predictor's estimator.
pub fn run_experiment<F>(
    cfg: &TransformerConfig,
    batch: usize,
    seq: usize,
    dev1: &mut Gpu,
    dev2: &mut Gpu,
    predictor: &'static str,
    estimate: F,
) -> Option<PartitionResult>
where
    F: FnMut(&Gpu, &[Op]) -> Option<f64>,
{
    let plan = best_cut(cfg, batch, seq, dev1, dev2, estimate)?;
    let measured = measure_cut(cfg, plan.cut, batch, seq, dev1, dev2, 5).ok()?;
    Some(PartitionResult {
        predictor,
        chosen_cut: plan.cut,
        predicted_bottleneck_s: plan.bottleneck_s(),
        measured,
        completion_100_s: pipeline_completion_s(&measured, 100),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::zoo;

    #[test]
    fn pipeline_completion_formula() {
        let plan = Plan { cut: 10, stage1_s: 0.5, stage2_s: 0.3 };
        assert!((pipeline_completion_s(&plan, 1) - 0.8).abs() < 1e-12);
        assert!((pipeline_completion_s(&plan, 100) - (0.8 + 99.0 * 0.5)).abs() < 1e-9);
        assert_eq!(pipeline_completion_s(&plan, 0), 0.0);
    }

    #[test]
    fn memory_constrains_cut_range() {
        // Qwen3-4B on 3060M (6 GB): only small head-ends fit stage 1.
        let cfg = zoo::qwen3_4b();
        let d1 = Gpu::by_name("rtx3060m").unwrap();
        let d2 = Gpu::by_name("rtx5070").unwrap();
        assert!(!cut_fits(&cfg, cfg.layers - 1, 8, 512, &d1, &d2),
                "3060M cannot host nearly the whole 4B model");
        let any_fit = (1..cfg.layers).any(|c| cut_fits(&cfg, c, 8, 512, &d1, &d2));
        assert!(any_fit, "some cut must fit the 3060M+5070 pair");
    }

    #[test]
    fn oracle_partition_balances_stages() {
        // With the simulator itself as the estimator, the chosen cut's
        // measured stages should be within ~35% of each other (or pinned
        // at a memory-feasibility boundary).
        let cfg = zoo::qwen3_4b();
        let mut d1 = Gpu::by_name("rtx3060m").unwrap();
        let mut d2 = Gpu::by_name("rtx5070").unwrap();
        let plan = best_cut(&cfg, 8, 512, &d1, &d2, |gpu, trace| {
            let mut total = 0.0;
            for op in trace {
                total += gpu.model_latency(op, None, gpu.spec.max_freq_ghz).ok()?;
            }
            Some(total)
        })
        .unwrap();
        let measured = measure_cut(&cfg, plan.cut, 8, 512, &mut d1, &mut d2, 3).unwrap();
        assert!(plan.cut >= 1 && plan.cut < cfg.layers);
        assert!(measured.stage1_s > 0.0 && measured.stage2_s > 0.0);
    }

    #[test]
    fn transfer_time_positive_and_scales() {
        let cfg = zoo::qwen3_4b();
        let t1 = transfer_s(&cfg, 1, 512);
        let t8 = transfer_s(&cfg, 8, 512);
        assert!(t8 > t1 && t1 > LINK_LATENCY_S);
    }
}
