//! NeuSight training: Adam + SMAPE-on-latency loss, executed entirely
//! through the AOT `neusight_train_b512` artifact on PJRT — the L2 train
//! step compiled once, driven by the Rust loop. The *latency-target
//! relative loss* is kept faithful to the paper, inheriting its documented
//! imbalance (small-latency samples dominate; device bias).

use anyhow::{anyhow, Result};

use crate::runtime::{ArgValue, Runtime};
use crate::util::prng::Rng;

use super::dataset::Dataset;
use super::features::FEATURE_DIM;
use super::mlp::MlpParams;

pub const TRAIN_BATCH: usize = 512;

#[derive(Clone, Debug)]
pub struct TrainReport {
    pub epochs: usize,
    pub first_loss: f64,
    pub final_loss: f64,
    pub loss_curve: Vec<f64>,
}

/// Train the MLP on a dataset; returns trained params + report.
pub fn train(
    runtime: &Runtime,
    dataset: &Dataset,
    epochs: usize,
    lr: f32,
    seed: u64,
) -> Result<(MlpParams, TrainReport)> {
    if dataset.samples.is_empty() {
        return Err(anyhow!("empty dataset"));
    }
    let artifact = format!("neusight_train_b{TRAIN_BATCH}");
    runtime.warm(&artifact)?;
    let mut params = MlpParams::init_from_artifacts(runtime)?;
    let mut m: Vec<Vec<f32>> =
        params.tensors.iter().map(|(_, d)| vec![0.0; d.len()]).collect();
    let mut v = m.clone();
    let mut step = 0f32;
    let mut rng = Rng::new(seed);
    let mut order: Vec<usize> = (0..dataset.samples.len()).collect();
    let mut curve = Vec::with_capacity(epochs);
    let mut first_loss = None;
    for _epoch in 0..epochs {
        rng.shuffle(&mut order);
        let mut epoch_losses = Vec::new();
        for chunk in order.chunks(TRAIN_BATCH) {
            // Pad short batches by repeating samples (keeps shapes AOT-
            // compatible; repeated samples only reweight slightly).
            let mut x = vec![0f32; TRAIN_BATCH * FEATURE_DIM];
            let mut scale = vec![0f32; TRAIN_BATCH];
            let mut y = vec![0f32; TRAIN_BATCH];
            for i in 0..TRAIN_BATCH {
                let s = &dataset.samples[chunk[i % chunk.len()]];
                x[i * FEATURE_DIM..(i + 1) * FEATURE_DIM]
                    .copy_from_slice(&s.features);
                scale[i] = s.scale_s as f32;
                y[i] = s.latency_s as f32;
            }
            let mut args: Vec<ArgValue> = Vec::with_capacity(23);
            for (shape, data) in &params.tensors {
                args.push(ArgValue::F32(data, shape));
            }
            for (mi, (shape, _)) in m.iter().zip(&params.tensors) {
                args.push(ArgValue::F32(mi, shape));
            }
            for (vi, (shape, _)) in v.iter().zip(&params.tensors) {
                args.push(ArgValue::F32(vi, shape));
            }
            let batch_shape = [TRAIN_BATCH, FEATURE_DIM];
            let vec_shape = [TRAIN_BATCH];
            args.push(ArgValue::ScalarF32(step));
            args.push(ArgValue::F32(&x, &batch_shape));
            args.push(ArgValue::F32(&scale, &vec_shape));
            args.push(ArgValue::F32(&y, &vec_shape));
            args.push(ArgValue::ScalarF32(lr));
            let out = runtime.call(&artifact, &args)?;
            // out = (p×6, m×6, v×6, step, loss)
            for (i, t) in params.tensors.iter_mut().enumerate() {
                t.1 = out[i].clone();
            }
            for i in 0..6 {
                m[i] = out[6 + i].clone();
                v[i] = out[12 + i].clone();
            }
            step = out[18][0];
            let loss = out[19][0] as f64;
            if first_loss.is_none() {
                first_loss = Some(loss);
            }
            epoch_losses.push(loss);
        }
        curve.push(crate::util::stats::mean(&epoch_losses));
    }
    let report = TrainReport {
        epochs,
        first_loss: first_loss.unwrap_or(0.0),
        final_loss: *curve.last().unwrap_or(&0.0),
        loss_curve: curve,
    };
    Ok((params, report))
}

/// Serialize trained params to JSON (cacheable across runs).
pub fn params_to_json(params: &MlpParams) -> String {
    use crate::util::json::Json;
    let mut obj = Vec::new();
    for (i, (shape, data)) in params.tensors.iter().enumerate() {
        obj.push((
            format!("p{i}"),
            Json::obj(vec![
                ("shape", Json::Arr(shape.iter().map(|&d| Json::Num(d as f64)).collect())),
                ("data", Json::Arr(data.iter().map(|&x| Json::Num(x as f64)).collect())),
            ]),
        ));
    }
    Json::Obj(obj.into_iter().collect()).to_string()
}

pub fn params_from_json(text: &str) -> Result<MlpParams> {
    use crate::util::json::Json;
    let v = Json::parse(text)?;
    let obj = v.as_obj().ok_or_else(|| anyhow!("not an object"))?;
    let mut tensors = Vec::new();
    for i in 0..obj.len() {
        let p = v.get(&format!("p{i}")).ok_or_else(|| anyhow!("missing p{i}"))?;
        let shape: Vec<usize> = p
            .get("shape")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("bad shape"))?
            .iter()
            .filter_map(Json::as_usize)
            .collect();
        let data: Vec<f32> = p
            .get("data")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("bad data"))?
            .iter()
            .filter_map(|x| x.as_f64().map(|f| f as f32))
            .collect();
        tensors.push((shape, data));
    }
    Ok(MlpParams { tensors })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::neusight::dataset::Sample;

    fn synthetic_dataset(n: usize) -> Dataset {
        // Learnable structure: utilization is a sigmoid of two features.
        let mut rng = Rng::new(11);
        let mut d = Dataset::default();
        for _ in 0..n {
            let mut f = [0f32; FEATURE_DIM];
            for v in f.iter_mut() {
                *v = rng.normal() as f32 * 0.5;
            }
            let u = 1.0 / (1.0 + (-(0.9 * f[0] - 0.7 * f[5]) as f64).exp());
            let u = u.clamp(0.05, 0.98);
            let scale = 1e-4;
            d.samples.push(Sample {
                features: f,
                scale_s: scale,
                latency_s: scale / u,
            });
        }
        d
    }

    #[test]
    fn loss_decreases_via_pjrt_training() {
        let rt = Runtime::open_default().expect("make artifacts");
        let data = synthetic_dataset(1024);
        let (_params, report) = train(&rt, &data, 30, 3e-3, 42).unwrap();
        assert!(
            report.final_loss < report.first_loss * 0.6,
            "first {} final {}",
            report.first_loss,
            report.final_loss
        );
    }

    #[test]
    fn trained_model_beats_untrained() {
        let rt = Runtime::open_default().expect("make artifacts");
        let data = synthetic_dataset(1024);
        let (params, _) = train(&rt, &data, 30, 3e-3, 42).unwrap();
        let init = MlpParams::init_from_artifacts(&rt).unwrap();
        let mut err_trained = 0.0;
        let mut err_init = 0.0;
        for s in &data.samples[..200] {
            let ut = params.forward_host(&s.features) as f64;
            let ui = init.forward_host(&s.features) as f64;
            let true_u = s.scale_s / s.latency_s;
            err_trained += (ut - true_u).abs();
            err_init += (ui - true_u).abs();
        }
        assert!(err_trained < err_init * 0.7, "{err_trained} vs {err_init}");
    }

    #[test]
    fn params_json_roundtrip() {
        let rt = Runtime::open_default().expect("make artifacts");
        let params = MlpParams::init_from_artifacts(&rt).unwrap();
        let text = params_to_json(&params);
        let back = params_from_json(&text).unwrap();
        assert_eq!(params.tensors.len(), back.tensors.len());
        for (a, b) in params.tensors.iter().zip(&back.tensors) {
            assert_eq!(a.0, b.0);
            assert_eq!(a.1, b.1);
        }
    }

    #[test]
    fn empty_dataset_rejected() {
        let rt = Runtime::open_default().expect("make artifacts");
        assert!(train(&rt, &Dataset::default(), 1, 1e-3, 0).is_err());
    }
}
