//! NeuSight feature extraction: the shape/wave/device-spec feature vector
//! its utilization MLP consumes (paper §II / §III-B). Exactly the inputs
//! the paper criticizes: theoretical peak FLOPs, DRAM bandwidth, L2 size,
//! SM count, cores per SM, FLOP counts and wave estimates — and nothing
//! about which of the 13/96 kernel implementations actually runs.

use crate::gpusim::DeviceSpec;
use crate::ops::{DType, GemmOp, Op, UtilOp};

/// Must match the AOT-compiled MLP input width (manifest feature_dim).
pub const FEATURE_DIM: usize = 16;

/// Tile assumption used for wave estimation (from the tile dataset match;
/// NeuSight has no heuristic API access).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TileGuess {
    pub tile_m: usize,
    pub tile_n: usize,
}

impl Default for TileGuess {
    fn default() -> Self {
        TileGuess { tile_m: 128, tile_n: 128 }
    }
}

fn ln(x: f64) -> f32 {
    (x.max(1e-12)).ln() as f32
}

/// Feature vector for a GEMM op under a tile guess.
pub fn gemm_features(dev: &DeviceSpec, op: &GemmOp, tile: TileGuess) -> [f32; FEATURE_DIM] {
    let tiles = op.m.div_ceil(tile.tile_m) * op.n.div_ceil(tile.tile_n) * op.batch;
    // NeuSight's wave estimate: blocks over SMs (it cannot see occupancy
    // per implementation).
    let waves = tiles.div_ceil(dev.sm_count);
    let peak = dev.peak_tflops(op.dtype).unwrap_or(dev.fp32_tflops);
    [
        ln(op.m as f64) / 10.0,
        ln(op.n as f64) / 10.0,
        ln(op.k as f64) / 10.0,
        ln(op.batch as f64) / 6.0,
        ln(op.flops()) / 30.0,
        ln(op.io_bytes()) / 25.0,
        ln(waves as f64) / 8.0,
        tile.tile_m as f32 / 256.0,
        tile.tile_n as f32 / 256.0,
        ln(peak) / 6.0,
        ln(dev.dram_gbps) / 8.0,
        ln(dev.l2_mb) / 4.0,
        ln(dev.sm_count as f64) / 5.0,
        dev.cores_per_sm() as f32 / 160.0,
        if op.dtype == DType::Bf16 { 1.0 } else { 0.0 },
        0.0, // is_util
    ]
}

/// Feature vector for a utility op.
pub fn util_features(dev: &DeviceSpec, op: &UtilOp) -> [f32; FEATURE_DIM] {
    let elems = op.elems();
    let bytes = elems * op.dtype.bytes() as f64 * op.passes();
    let waves = (op.rows * op.cols).div_ceil(dev.sm_count * 1024);
    let peak = dev.peak_tflops(op.dtype).unwrap_or(dev.fp32_tflops);
    [
        ln(op.rows as f64) / 10.0,
        ln(op.cols as f64) / 10.0,
        0.0,
        if op.kind.is_reduction() { 1.0 } else { 0.0 },
        ln(elems * op.instrs_per_elem()) / 30.0,
        ln(bytes) / 25.0,
        ln(waves.max(1) as f64) / 8.0,
        0.0,
        0.0,
        ln(peak) / 6.0,
        ln(dev.dram_gbps) / 8.0,
        ln(dev.l2_mb) / 4.0,
        ln(dev.sm_count as f64) / 5.0,
        dev.cores_per_sm() as f32 / 160.0,
        if op.dtype == DType::Bf16 { 1.0 } else { 0.0 },
        1.0, // is_util
    ]
}

/// The "work at 100% utilization" scale the latency head divides by
/// (latency = scale / predicted_utilization).
pub fn scale_seconds(dev: &DeviceSpec, op: &Op) -> f64 {
    match op {
        Op::Gemm(g) => {
            let peak = dev.peak_tflops(g.dtype).unwrap_or(dev.fp32_tflops) * 1e12;
            g.flops() / peak
        }
        Op::Util(u) => {
            let bytes = u.elems() * u.dtype.bytes() as f64 * u.passes();
            bytes / dev.dram_bw()
        }
        Op::Custom(c) => {
            let peak =
                dev.peak_tflops(op.dtype()).unwrap_or(dev.fp32_tflops) * 1e12;
            c.flops() / peak
        }
        // Collectives move bytes over the interconnect, not DRAM, but the
        // DRAM scale is the closest "100% utilization" proxy NeuSight has.
        Op::Comm(c) => c.io_bytes() / dev.dram_bw(),
    }
}

pub fn features_for(dev: &DeviceSpec, op: &Op, tile: TileGuess) -> [f32; FEATURE_DIM] {
    match op {
        Op::Gemm(g) => gemm_features(dev, g, tile),
        Op::Util(u) => util_features(dev, u),
        Op::Custom(_) | Op::Comm(_) => {
            // NeuSight models neither custom kernels nor collectives (a
            // paper limitation); fall back to a neutral encoding.
            let mut f = [0f32; FEATURE_DIM];
            f[15] = 0.5;
            f
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::device_by_name;
    use crate::ops::UtilKind;

    #[test]
    fn features_are_finite_and_bounded() {
        let dev = device_by_name("a100").unwrap();
        let f = gemm_features(&dev, &GemmOp::mm(4096, 4096, 8192, DType::Bf16), TileGuess::default());
        for v in f {
            assert!(v.is_finite());
            assert!(v.abs() < 10.0, "feature too large: {v}");
        }
        assert_eq!(f[14], 1.0);
    }

    #[test]
    fn gemm_vs_util_flag() {
        let dev = device_by_name("t4").unwrap();
        let g = gemm_features(&dev, &GemmOp::mm(128, 128, 128, DType::F32), TileGuess::default());
        let u = util_features(&dev, &UtilOp::new(UtilKind::Relu, 128, 128, DType::F32));
        assert_eq!(g[15], 0.0);
        assert_eq!(u[15], 1.0);
    }

    #[test]
    fn scale_is_lower_bound_on_latency() {
        // scale = ideal time at 100% utilization — real executions are
        // never faster.
        let mut gpu = crate::gpusim::Gpu::by_name("rtx5070").unwrap();
        let op = Op::Gemm(GemmOp::mm(2048, 2048, 2048, DType::F32));
        let s = scale_seconds(&gpu.spec, &op);
        let meas = gpu.exec(&op).unwrap();
        assert!(meas.dur_s > s, "measured {} <= ideal {}", meas.dur_s, s);
    }

    #[test]
    fn tile_guess_changes_wave_feature() {
        let dev = device_by_name("l4").unwrap();
        let op = GemmOp::mm(4096, 4096, 512, DType::F32);
        let a = gemm_features(&dev, &op, TileGuess { tile_m: 64, tile_n: 64 });
        let b = gemm_features(&dev, &op, TileGuess { tile_m: 256, tile_n: 128 });
        assert_ne!(a[6], b[6]);
    }
}
