//! # neusight — the baseline (Lee et al., ASPLOS'25), reimplemented
//!
//! Tile-dataset "sieve" collection + wave features + an MLP utilization
//! predictor, trained and served through the L1/L2/L3 stack (Pallas
//! kernel → JAX Adam step → HLO artifacts → PJRT from Rust). Faithful to
//! the failure modes the paper documents (§III-B, §IV): dataset-matching
//! overhead, out-of-domain degradation, latency-target loss imbalance,
//! and blindness to the BF16 kernel-implementation dispersion.

pub mod dataset;
pub mod features;
pub mod mlp;
pub mod predictor;
pub mod train;

pub use predictor::{NeuSight, TrainConfig};
