//! The NeuSight utilization MLP, executed through the L1 Pallas kernel
//! via PJRT (`neusight_infer_*` artifacts). The training path keeps the
//! parameters host-side between steps; inference batches queries to
//! amortize executable launches. A pure-Rust forward mirror exists for
//! verification (it must agree with the artifact — the same guarantee the
//! pytest suite gives between the Pallas kernel and the jnp oracle).

use anyhow::{anyhow, Result};

use crate::runtime::{load_params_init, ArgValue, Runtime};

use super::features::FEATURE_DIM;

/// MLP parameters: (w1, b1, w2, b2, w3, b3) flattened f32 with shapes.
#[derive(Clone, Debug)]
pub struct MlpParams {
    pub tensors: Vec<(Vec<usize>, Vec<f32>)>,
}

impl MlpParams {
    pub fn init_from_artifacts(_runtime: &Runtime) -> Result<MlpParams> {
        let dir = crate::runtime::default_artifacts_dir()
            .ok_or_else(|| anyhow!("artifacts dir not found"))?;
        Ok(MlpParams { tensors: load_params_init(&dir)? })
    }

    pub fn hidden_dim(&self) -> usize {
        self.tensors[0].0[1]
    }

    /// Pure-Rust forward (verification mirror of the Pallas kernel).
    pub fn forward_host(&self, x: &[f32]) -> f32 {
        assert_eq!(x.len(), self.tensors[0].0[0]);
        let h = self.hidden_dim();
        let (w1, b1) = (&self.tensors[0].1, &self.tensors[1].1);
        let (w2, b2) = (&self.tensors[2].1, &self.tensors[3].1);
        let (w3, b3) = (&self.tensors[4].1, &self.tensors[5].1);
        let f = x.len();
        let mut h1 = vec![0f32; h];
        for j in 0..h {
            let mut acc = b1[j];
            for (i, &xi) in x.iter().enumerate() {
                acc += xi * w1[i * h + j];
            }
            h1[j] = acc.max(0.0);
        }
        let _ = f;
        let mut h2 = vec![0f32; h];
        for j in 0..h {
            let mut acc = b2[j];
            for (i, &hi) in h1.iter().enumerate() {
                acc += hi * w2[i * h + j];
            }
            h2[j] = acc.max(0.0);
        }
        let mut logit = b3[0];
        for (i, &hi) in h2.iter().enumerate() {
            logit += hi * w3[i];
        }
        1.0 / (1.0 + (-logit).exp())
    }
}

/// PJRT-backed batched inference session.
pub struct MlpSession<'rt> {
    runtime: &'rt Runtime,
    pub params: MlpParams,
}

impl<'rt> MlpSession<'rt> {
    pub fn new(runtime: &'rt Runtime, params: MlpParams) -> MlpSession<'rt> {
        MlpSession { runtime, params }
    }

    /// Predict utilization for a batch of feature vectors through the
    /// Pallas-kernel artifact, choosing the smallest batch size that fits.
    pub fn predict_util(&self, feats: &[[f32; FEATURE_DIM]]) -> Result<Vec<f64>> {
        let mut out = Vec::with_capacity(feats.len());
        let mut idx = 0;
        while idx < feats.len() {
            let remaining = feats.len() - idx;
            let b = if remaining > 128 { 1024 } else { 128 };
            let artifact = format!("neusight_infer_b{b}");
            let take = remaining.min(b);
            let mut x = vec![0f32; b * FEATURE_DIM];
            for (i, f) in feats[idx..idx + take].iter().enumerate() {
                x[i * FEATURE_DIM..(i + 1) * FEATURE_DIM].copy_from_slice(f);
            }
            let x_shape = [b, FEATURE_DIM];
            let mut args: Vec<ArgValue> = vec![ArgValue::F32(&x, &x_shape)];
            for (shape, data) in &self.params.tensors {
                args.push(ArgValue::F32(data, shape));
            }
            let result = self.runtime.call(&artifact, &args)?;
            out.extend(
                result[0][..take]
                    .iter()
                    .map(|&u| (u as f64).clamp(1e-4, 1.0)),
            );
            idx += take;
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn artifact_matches_host_mirror() {
        let rt = Runtime::open_default().expect("make artifacts");
        let params = MlpParams::init_from_artifacts(&rt).unwrap();
        let session = MlpSession::new(&rt, params.clone());
        let mut rng = crate::util::prng::Rng::new(3);
        let feats: Vec<[f32; FEATURE_DIM]> = (0..50)
            .map(|_| {
                let mut f = [0f32; FEATURE_DIM];
                for v in f.iter_mut() {
                    *v = rng.normal() as f32 * 0.5;
                }
                f
            })
            .collect();
        let via_pjrt = session.predict_util(&feats).unwrap();
        for (f, got) in feats.iter().zip(&via_pjrt) {
            let want = params.forward_host(f) as f64;
            assert!(
                (got - want).abs() < 1e-5,
                "pjrt {got} vs host {want}"
            );
        }
    }

    #[test]
    fn batches_larger_than_1024_chunk() {
        let rt = Runtime::open_default().expect("make artifacts");
        let params = MlpParams::init_from_artifacts(&rt).unwrap();
        let session = MlpSession::new(&rt, params);
        let feats = vec![[0.1f32; FEATURE_DIM]; 2500];
        let out = session.predict_util(&feats).unwrap();
        assert_eq!(out.len(), 2500);
        // All-equal inputs → all-equal outputs.
        assert!(out.windows(2).all(|w| (w[0] - w[1]).abs() < 1e-9));
    }

    #[test]
    fn utilization_in_unit_interval() {
        let rt = Runtime::open_default().expect("make artifacts");
        let params = MlpParams::init_from_artifacts(&rt).unwrap();
        let session = MlpSession::new(&rt, params);
        let feats = vec![[2.0f32; FEATURE_DIM]; 8];
        for u in session.predict_util(&feats).unwrap() {
            assert!(u > 0.0 && u <= 1.0);
        }
    }
}
