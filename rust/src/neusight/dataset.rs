//! NeuSight's precollected dataset: "sieve" sampling over a constrained
//! shape domain, mapping input shapes to tile configurations and measured
//! latencies (paper §III-B "Dataset Matching and Scalability Issues").
//! At prediction time the nearest entry in log-shape space supplies the
//! tile guess — the matching overhead and out-of-domain degradation the
//! paper criticizes are inherent to this design and faithfully kept.

use crate::gpusim::{heuristic, FreqMode, Gpu};
use crate::ops::{DType, GemmApi, GemmOp, Op, UtilKind, UtilOp};
use crate::profiler::{self, ProfileSpec};
use crate::util::prng::Rng;

use super::features::{self, TileGuess, FEATURE_DIM};

/// One training sample: features, work scale, measured latency.
#[derive(Clone, Debug)]
pub struct Sample {
    pub features: [f32; FEATURE_DIM],
    pub scale_s: f64,
    pub latency_s: f64,
}

/// One tile-dataset entry (shape → tile), for nearest matching.
#[derive(Clone, Debug)]
pub struct TileEntry {
    pub log_m: f64,
    pub log_n: f64,
    pub log_k: f64,
    pub tile: TileGuess,
}

/// The sieve's training domain — deliberately narrower than the paper's
/// evaluation domain (M, N ≤ 8192, K ≤ 20000), producing the
/// out-of-domain degradation of §III-B.
pub const SIEVE_MAX_MN: usize = 4096;
pub const SIEVE_MAX_K: usize = 4096;

/// Collected dataset for one dtype (across devices).
#[derive(Clone, Debug, Default)]
pub struct Dataset {
    pub samples: Vec<Sample>,
    pub tiles: Vec<TileEntry>,
}

impl Dataset {
    /// Nearest tile entry in log-shape space (linear scan — the matching
    /// overhead the paper measures is this scan).
    pub fn match_tile(&self, m: usize, n: usize, k: usize) -> TileGuess {
        let (lm, ln_, lk) = ((m as f64).ln(), (n as f64).ln(), (k as f64).ln());
        let mut best = TileGuess::default();
        let mut best_d = f64::MAX;
        for e in &self.tiles {
            let d = (e.log_m - lm).powi(2)
                + (e.log_n - ln_).powi(2)
                + (e.log_k - lk).powi(2);
            if d < best_d {
                best_d = d;
                best = e.tile;
            }
        }
        best
    }

    pub fn merge(&mut self, other: Dataset) {
        self.samples.extend(other.samples);
        self.tiles.extend(other.tiles);
    }
}

/// Sieve lattice: proportionally distributed points across the domain
/// (powers of two and their midpoints).
fn sieve_points(max: usize) -> Vec<usize> {
    let mut pts = Vec::new();
    let mut p = 64;
    while p <= max {
        pts.push(p);
        if p + p / 2 <= max {
            pts.push(p + p / 2);
        }
        p *= 2;
    }
    pts
}

/// Collect the NeuSight training dataset on one device. NeuSight profiles
/// at full boost with heavy back-to-back workloads — which is exactly why
/// it "captures thermal characteristics more effectively" (§IV-A): the
/// die is hot while it measures.
pub fn collect(gpu: &mut Gpu, dtype: DType, per_device: usize, spec: &ProfileSpec, seed: u64) -> Dataset {
    let mut out = Dataset::default();
    if !gpu.spec.supports(dtype) {
        return out;
    }
    gpu.set_freq(FreqMode::Boost);
    let mut rng = Rng::new(seed ^ crate::util::prng::hash64(gpu.spec.name.as_bytes()));
    let pts = sieve_points(SIEVE_MAX_MN);
    let kpts = sieve_points(SIEVE_MAX_K);
    // Warm the die like NeuSight's heavy profiling phase does.
    for _ in 0..30 {
        let _ = gpu.exec(&Op::Gemm(GemmOp::mm(2048, 2048, 2048, dtype)));
    }
    let mut n_gemm = 0;
    while n_gemm < per_device {
        let m = *rng.choice(&pts);
        let n = *rng.choice(&pts);
        let k = *rng.choice(&kpts);
        let api = *rng.choice(&[GemmApi::MatMul, GemmApi::Linear, GemmApi::Bmm]);
        let op = match api {
            GemmApi::Bmm => GemmOp::bmm(rng.int_range(1, 64) as usize, m.min(1024), n.min(1024), k.min(1024), dtype),
            GemmApi::Linear => GemmOp::linear(m, n, k, dtype),
            GemmApi::MatMul => GemmOp::mm(m, n, k, dtype),
        };
        let Ok(meas) = profiler::measure(gpu, &Op::Gemm(op), spec) else {
            continue;
        };
        // NeuSight records the tile configuration observed during its
        // collection runs (profiler metadata), keyed by shape.
        let tile = heuristic::algo_get_heuristic(&gpu.spec, &op)
            .and_then(|cfg| gpu.kernel(dtype, cfg.kernel_id))
            .map(|kern| TileGuess { tile_m: kern.tile_m, tile_n: kern.tile_n })
            .unwrap_or_default();
        out.tiles.push(TileEntry {
            log_m: (op.m as f64).ln(),
            log_n: (op.n as f64).ln(),
            log_k: (op.k as f64).ln(),
            tile,
        });
        out.samples.push(Sample {
            features: features::gemm_features(&gpu.spec, &op, tile),
            scale_s: features::scale_seconds(&gpu.spec, &Op::Gemm(op)),
            latency_s: meas.mean_s,
        });
        n_gemm += 1;
    }
    // Utility samples (half the GEMM count).
    let mut n_util = 0;
    while n_util < per_device / 2 {
        let kind = *rng.choice(UtilKind::all());
        let rows = rng.log_uniform_int(16, 8192) as usize;
        let cols = rng.log_uniform_int(16, 8192) as usize;
        if rows * cols < 1024 {
            continue;
        }
        let op = UtilOp::new(kind, rows, cols, dtype);
        let Ok(meas) = profiler::measure(gpu, &Op::Util(op), spec) else {
            continue;
        };
        out.samples.push(Sample {
            features: features::util_features(&gpu.spec, &op),
            scale_s: features::scale_seconds(&gpu.spec, &Op::Util(op)),
            latency_s: meas.mean_s,
        });
        n_util += 1;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_dataset() -> (Gpu, Dataset) {
        let mut gpu = Gpu::by_name("a100").unwrap();
        let d = collect(&mut gpu, DType::F32, 20, &ProfileSpec::quick(), 1);
        (gpu, d)
    }

    #[test]
    fn collects_requested_counts() {
        let (_, d) = small_dataset();
        assert_eq!(d.samples.len(), 30); // 20 gemm + 10 util
        assert_eq!(d.tiles.len(), 20);
        for s in &d.samples {
            assert!(s.latency_s > 0.0 && s.scale_s > 0.0);
            assert!(s.latency_s > s.scale_s, "latency below ideal");
        }
    }

    #[test]
    fn tile_match_returns_nearest() {
        let (_, d) = small_dataset();
        let e = &d.tiles[0];
        let got = d.match_tile(
            e.log_m.exp() as usize,
            e.log_n.exp() as usize,
            e.log_k.exp() as usize,
        );
        assert_eq!(got, e.tile);
    }

    #[test]
    fn t4_bf16_dataset_empty() {
        let mut gpu = Gpu::by_name("t4").unwrap();
        let d = collect(&mut gpu, DType::Bf16, 10, &ProfileSpec::quick(), 2);
        assert!(d.samples.is_empty());
    }

    #[test]
    fn sieve_points_cover_domain() {
        let pts = sieve_points(4096);
        assert!(pts.contains(&64) && pts.contains(&4096) && pts.contains(&96));
        assert!(pts.iter().all(|&p| p <= 4096));
    }

    #[test]
    fn deterministic_given_seed() {
        let mut g1 = Gpu::by_name("l4").unwrap();
        let mut g2 = Gpu::by_name("l4").unwrap();
        let a = collect(&mut g1, DType::F32, 5, &ProfileSpec::quick(), 7);
        let b = collect(&mut g2, DType::F32, 5, &ProfileSpec::quick(), 7);
        for (x, y) in a.samples.iter().zip(&b.samples) {
            assert_eq!(x.latency_s, y.latency_s);
        }
    }
}
