//! The NeuSight predictor facade: per-dtype trained MLP + tile dataset.
//! Prediction = dataset tile match → features → MLP utilization (through
//! PJRT) → latency = scale / utilization.

use anyhow::Result;
use std::path::Path;

use crate::gpusim::{DeviceSpec, Gpu};
use crate::ops::{DType, Op};
use crate::profiler::ProfileSpec;
use crate::runtime::Runtime;

use super::dataset::{self, Dataset};
use super::features::{self, TileGuess};
use super::mlp::MlpSession;
use super::train::{self, TrainReport};

/// Fully-trained NeuSight for one dtype.
pub struct NeuSight<'rt> {
    pub dtype: DType,
    pub dataset: Dataset,
    pub session: MlpSession<'rt>,
    pub report: Option<TrainReport>,
}

/// Training-time configuration.
#[derive(Clone, Copy, Debug)]
pub struct TrainConfig {
    pub per_device: usize,
    pub epochs: usize,
    pub lr: f32,
    pub seed: u64,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig { per_device: 200, epochs: 60, lr: 3e-3, seed: 2024 }
    }
}

impl<'rt> NeuSight<'rt> {
    /// Collect the sieve dataset across `gpus` and train the MLP
    /// (re-collected and re-trained per dtype, as the paper does for its
    /// comparison).
    pub fn train_on(
        runtime: &'rt Runtime,
        gpus: &mut [Gpu],
        dtype: DType,
        cfg: TrainConfig,
        spec: &ProfileSpec,
    ) -> Result<NeuSight<'rt>> {
        let mut data = Dataset::default();
        for gpu in gpus.iter_mut() {
            gpu.reset();
            data.merge(dataset::collect(gpu, dtype, cfg.per_device, spec, cfg.seed));
            gpu.reset();
        }
        let (params, report) = train::train(runtime, &data, cfg.epochs, cfg.lr, cfg.seed)?;
        Ok(NeuSight {
            dtype,
            dataset: data,
            session: MlpSession::new(runtime, params),
            report: Some(report),
        })
    }

    /// Load trained params from a cache file (skips re-training).
    pub fn from_cache(
        runtime: &'rt Runtime,
        gpus: &mut [Gpu],
        dtype: DType,
        cfg: TrainConfig,
        spec: &ProfileSpec,
        cache: &Path,
    ) -> Result<NeuSight<'rt>> {
        let mut data = Dataset::default();
        for gpu in gpus.iter_mut() {
            gpu.reset();
            data.merge(dataset::collect(gpu, dtype, cfg.per_device, spec, cfg.seed));
            gpu.reset();
        }
        let text = std::fs::read_to_string(cache)?;
        let params = train::params_from_json(&text)?;
        Ok(NeuSight { dtype, dataset: data, session: MlpSession::new(runtime, params), report: None })
    }

    /// Train, or load from cache when present (writes the cache after a
    /// fresh train).
    pub fn train_or_load(
        runtime: &'rt Runtime,
        gpus: &mut [Gpu],
        dtype: DType,
        cfg: TrainConfig,
        spec: &ProfileSpec,
        cache: &Path,
    ) -> Result<NeuSight<'rt>> {
        if cache.exists() {
            if let Ok(ns) = Self::from_cache(runtime, gpus, dtype, cfg, spec, cache) {
                return Ok(ns);
            }
        }
        let ns = Self::train_on(runtime, gpus, dtype, cfg, spec)?;
        if let Some(dir) = cache.parent() {
            let _ = std::fs::create_dir_all(dir);
        }
        let _ = std::fs::write(cache, train::params_to_json(&ns.session.params));
        Ok(ns)
    }

    fn tile_for(&self, op: &Op) -> TileGuess {
        match op {
            Op::Gemm(g) => self.dataset.match_tile(g.m, g.n, g.k),
            _ => TileGuess::default(),
        }
    }

    /// Predict latency for one op on a device.
    pub fn predict(&self, dev: &DeviceSpec, op: &Op) -> Result<Option<f64>> {
        Ok(self.predict_batch(dev, std::slice::from_ref(op))?.pop().flatten())
    }

    /// Batched prediction (amortizes the PJRT launch).
    pub fn predict_batch(&self, dev: &DeviceSpec, ops: &[Op]) -> Result<Vec<Option<f64>>> {
        let mut feats = Vec::with_capacity(ops.len());
        let mut scales = Vec::with_capacity(ops.len());
        let mut supported = Vec::with_capacity(ops.len());
        for op in ops {
            let ok = dev.supports(op.dtype());
            supported.push(ok);
            feats.push(features::features_for(dev, op, self.tile_for(op)));
            scales.push(features::scale_seconds(dev, op));
        }
        let utils = self.session.predict_util(&feats)?;
        Ok(supported
            .into_iter()
            .zip(utils)
            .zip(scales)
            .map(|((ok, u), s)| if ok { Some(s / u) } else { None })
            .collect())
    }

    /// Whole-model prediction (sequential kernel sum, like PM2Lat's).
    pub fn predict_trace(&self, dev: &DeviceSpec, trace: &[Op]) -> Result<Option<f64>> {
        let parts = self.predict_batch(dev, trace)?;
        let mut total = 0.0;
        for p in parts {
            match p {
                Some(t) => total += t,
                None => return Ok(None),
            }
        }
        Ok(Some(total))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::all_devices;
    use crate::ops::{GemmOp, UtilKind, UtilOp};
    use crate::profiler;
    use crate::util::stats::{mean, rel_err_pct};

    fn quick_neusight(runtime: &Runtime, dtype: DType) -> NeuSight<'_> {
        let mut gpus: Vec<Gpu> = all_devices().into_iter().map(Gpu::new).collect();
        let cfg = TrainConfig { per_device: 60, epochs: 25, lr: 3e-3, seed: 5 };
        NeuSight::train_on(runtime, &mut gpus, dtype, cfg, &ProfileSpec::quick()).unwrap()
    }

    #[test]
    fn training_reduces_loss_and_predicts_in_domain() {
        let rt = Runtime::open_default().expect("make artifacts");
        let ns = quick_neusight(&rt, DType::F32);
        let report = ns.report.as_ref().unwrap();
        assert!(report.final_loss < report.first_loss,
                "loss should improve: {report:?}");
        // In-domain FP32 predictions should be decent (paper Table II:
        // NeuSight FP32 errors 1.8–50%; assert a loose envelope).
        let mut gpu = Gpu::by_name("a100").unwrap();
        let mut errs = Vec::new();
        let mut rng = crate::util::prng::Rng::new(9);
        for _ in 0..25 {
            let m = rng.log_uniform_int(64, 4096) as usize;
            let n = rng.log_uniform_int(64, 4096) as usize;
            let k = rng.log_uniform_int(64, 4096) as usize;
            let op = Op::Gemm(GemmOp::mm(m, n, k, DType::F32));
            let pred = ns.predict(&gpu.spec, &op).unwrap().unwrap();
            let truth = profiler::measure(&mut gpu, &op, &ProfileSpec::quick())
                .unwrap()
                .mean_s;
            errs.push(rel_err_pct(pred, truth));
        }
        let e = mean(&errs);
        assert!(e < 60.0, "NS in-domain FP32 err {e}%");
        assert!(e > 1.0, "suspiciously perfect — check the baseline isn't cheating");
    }

    #[test]
    fn unsupported_dtype_gives_none() {
        let rt = Runtime::open_default().expect("make artifacts");
        let ns = quick_neusight(&rt, DType::Bf16);
        let t4 = crate::gpusim::device_by_name("t4").unwrap();
        let op = Op::Gemm(GemmOp::mm(256, 256, 256, DType::Bf16));
        assert!(ns.predict(&t4, &op).unwrap().is_none());
    }

    #[test]
    fn trace_prediction_sums() {
        let rt = Runtime::open_default().expect("make artifacts");
        let ns = quick_neusight(&rt, DType::F32);
        let dev = crate::gpusim::device_by_name("l4").unwrap();
        let ops = vec![
            Op::Gemm(GemmOp::linear(256, 1024, 512, DType::F32)),
            Op::Util(UtilOp::new(UtilKind::Gelu, 256, 1024, DType::F32)),
        ];
        let total = ns.predict_trace(&dev, &ops).unwrap().unwrap();
        let a = ns.predict(&dev, &ops[0]).unwrap().unwrap();
        let b = ns.predict(&dev, &ops[1]).unwrap().unwrap();
        assert!((total - (a + b)).abs() / total < 1e-9);
    }

    #[test]
    fn cache_roundtrip() {
        let rt = Runtime::open_default().expect("make artifacts");
        let dir = std::env::temp_dir().join("pm2lat_test_ns_cache");
        let cache = dir.join("ns_f32.json");
        let _ = std::fs::remove_file(&cache);
        let mut gpus: Vec<Gpu> = all_devices().into_iter().map(Gpu::new).collect();
        let cfg = TrainConfig { per_device: 30, epochs: 5, lr: 3e-3, seed: 6 };
        let a = NeuSight::train_or_load(&rt, &mut gpus, DType::F32, cfg, &ProfileSpec::quick(), &cache).unwrap();
        assert!(cache.exists());
        let b = NeuSight::train_or_load(&rt, &mut gpus, DType::F32, cfg, &ProfileSpec::quick(), &cache).unwrap();
        // Same cached params → identical predictions.
        let dev = crate::gpusim::device_by_name("rtx5070").unwrap();
        let op = Op::Gemm(GemmOp::mm(512, 512, 512, DType::F32));
        assert_eq!(
            a.predict(&dev, &op).unwrap(),
            b.predict(&dev, &op).unwrap()
        );
    }
}
