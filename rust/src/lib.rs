//! # PM2Lat — kernel-aware DNN latency prediction (paper reproduction)
//!
//! Three-layer reproduction of *PM2Lat: Highly Accurate and Generalized
//! Prediction of DNN Execution Latency on GPUs* (CS.PF 2026):
//!
//! - **L1/L2 (build-time Python)** — Pallas kernels + JAX graphs, AOT-lowered
//!   to HLO text under `artifacts/` (`make artifacts`).
//! - **L3 (this crate)** — everything at runtime: the simulated-GPU
//!   substrate ([`gpusim`]), the CUPTI/NCU-style [`profiler`], the paper's
//!   predictor ([`pm2lat`]), the NeuSight baseline ([`neusight`]) whose MLP
//!   runs through PJRT ([`runtime`]), the typed model-graph IR with
//!   causal-mask propagation, fusion passes and dependency-aware
//!   scheduling ([`graph`]), the transformer model zoo with prefill *and*
//!   autoregressive-decode graphs ([`models`]), the prediction service
//!   ([`coordinator`], including whole-generation serving), the
//!   continuous-batching serving simulator — paged KV cache, mixed
//!   prefill+decode iterations, cluster-level SLO curves ([`serving`]) —
//!   speculative decoding as a first-class workload ([`spec_decode`]),
//!   the zero-cost-when-off observability layer — structured tracing,
//!   Chrome-trace export, unified metrics ([`obs`]) — and the two
//!   applications from §IV-D ([`apps`]).
//!
//! See `README.md` for the quickstart and CLI tour, and
//! `docs/ARCHITECTURE.md` for the end-to-end dataflow (graph IR → passes
//! → scheduler → predictors → coordinator) and the design decisions
//! behind the service, graph and decode layers.
//!
//! The physical GPUs of the paper are replaced by `gpusim` per the
//! substitution table in DESIGN.md §1; everything downstream consumes only
//! latency observations + kernel metadata, exactly as the paper's method
//! does on hardware.

pub mod apps;
pub mod coordinator;
pub mod experiments;
pub mod gpusim;
pub mod graph;
pub mod models;
pub mod neusight;
pub mod obs;
pub mod ops;
pub mod pm2lat;
pub mod profiler;
pub mod runtime;
pub mod serving;
pub mod spec_decode;
pub mod util;

pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}
