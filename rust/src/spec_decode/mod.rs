//! # spec_decode — speculative decoding as a first-class workload (L2.5)
//!
//! Speculative decoding is the first workload in the stack where
//! *iteration cost and token progress decouple*: a cheap draft model
//! proposes `k` tokens per round, the target model scores all of them in
//! one `q = k + 1` verification pass
//! ([`crate::models::TransformerConfig::verify_graph`]), and the number
//! of tokens actually committed per round is a random variable — between
//! 1 (every draft token rejected; the verify pass still yields the
//! target's own next token) and `k + 1` (all accepted plus the bonus
//! token from the verification logits).
//!
//! This module holds the workload *description* and the acceptance
//! *mathematics*; the latency numbers come from the layers that consume
//! it:
//!
//! * [`SpecConfig`] pairs a draft and a target
//!   [`crate::models::TransformerConfig`] with the draft length `k` and
//!   an [`AcceptanceModel`].
//! * [`AcceptanceModel`] is the per-position acceptance probability α:
//!   the analytical closed form `E[τ] = Σ_{i=1..k} Π_{j<i} α_j`
//!   (`α(1−α^k)/(1−α)` in the uniform case) drives
//!   `Pm2Lat::predict_speculative`'s expected-latency curve, and the
//!   seeded Bernoulli sampler drives the serving simulator's
//!   discrete-event replay
//!   ([`crate::serving::simulate_speculative_hot`]), which must commit
//!   an *integer* number of tokens per round.
//! * [`SpeculativePrediction`] is the analytical latency curve: target
//!   prefill + draft prompt ingestion, then per-round draft steps and a
//!   verification pass, with the expected committed tokens per round.
//! * [`CrossoverPoint`] rows back `Pm2Lat::speculative_crossover`'s
//!   k-analysis: tokens/s per draft length against the plain-decode
//!   baseline, locating where speculation starts (or stops) paying.
//!
//! The serving integration prices mixed draft+verify iterations through
//! the existing ragged-batch machinery (verification is a rectangular
//! causal window — exactly a chunked-prefill slot shape) and rolls
//! rejected speculated KV back with the refcount-safe
//! [`crate::serving::KvPager::truncate`]. `k = 0` is the anchored
//! degenerate case everywhere: the verify graph is node-identical to the
//! decode graph, the predictor curve is bit-for-bit
//! `predict_generation`, and the simulator replay is bit-for-bit the
//! plain serving path (`tests/spec_decode.rs`).
//!
//! Observability: under [`crate::serving::simulate_speculative_traced`]
//! every verification emits a [`crate::obs::TraceEvent::SpecRound`]
//! (proposed `k`, accepted run τ, committed tokens), and the per-round
//! stream reproduces the report's aggregate counters exactly — summed
//! `proposed`/`accepted` equal `ServingReport::spec_draft_tokens` /
//! `spec_accepted_tokens`. The Chrome export renders rounds as instants
//! on the `draft` track next to the draft-share sub-spans, which is the
//! fastest way to *see* an acceptance-rate problem rather than infer it
//! from α̂.

use crate::models::TransformerConfig;
use crate::util::prng::{Rng, StableHasher};

/// Per-position draft-token acceptance probabilities. Position `i` is
/// the `i`-th speculated token of a round (0-based); a round commits
/// `τ + 1` tokens where `τ` is the length of the leading accepted run —
/// the `+ 1` is the verification pass's own token (the correction at the
/// first rejection, or the bonus token when everything is accepted).
#[derive(Clone, Debug, PartialEq)]
pub enum AcceptanceModel {
    /// Position-independent acceptance probability α ∈ [0, 1].
    Uniform(f64),
    /// Per-position probabilities; positions past the end reuse the last
    /// entry (an empty vector accepts nothing).
    PerPosition(Vec<f64>),
}

impl AcceptanceModel {
    /// Uniform α, clamped into [0, 1].
    pub fn uniform(alpha: f64) -> AcceptanceModel {
        AcceptanceModel::Uniform(alpha.clamp(0.0, 1.0))
    }

    /// Acceptance probability of draft position `pos` (0-based).
    pub fn accept_prob(&self, pos: usize) -> f64 {
        match self {
            AcceptanceModel::Uniform(a) => *a,
            AcceptanceModel::PerPosition(v) => match v.get(pos) {
                Some(&p) => p,
                None => v.last().copied().unwrap_or(0.0),
            },
        }
    }

    /// Expected leading accepted run length `E[τ]` over `k` draft
    /// tokens: `Σ_{i=1..k} Π_{j<i} α_j` — the uniform case collapses to
    /// the closed form `α(1−α^k)/(1−α)` (and to `k` as α → 1).
    pub fn expected_accepted(&self, k: usize) -> f64 {
        match self {
            AcceptanceModel::Uniform(a) => {
                let a = a.clamp(0.0, 1.0);
                if a >= 1.0 {
                    k as f64
                } else if a <= 0.0 {
                    0.0
                } else {
                    a * (1.0 - a.powi(k as i32)) / (1.0 - a)
                }
            }
            AcceptanceModel::PerPosition(_) => {
                let mut run = 1.0f64;
                let mut total = 0.0f64;
                for pos in 0..k {
                    run *= self.accept_prob(pos).clamp(0.0, 1.0);
                    total += run;
                }
                total
            }
        }
    }

    /// Expected tokens committed per round: `E[τ] + 1` (the verification
    /// pass always contributes one target token). Always ≥ 1 — a round
    /// can never stall.
    pub fn expected_tokens_per_round(&self, k: usize) -> f64 {
        self.expected_accepted(k) + 1.0
    }

    /// Seeded stochastic mode for the discrete-event simulator: sample
    /// the leading accepted run length `τ ∈ [0, k]` as sequential
    /// Bernoulli trials. Deterministic for a deterministic `rng`.
    pub fn sample(&self, rng: &mut Rng, k: usize) -> usize {
        let mut tau = 0usize;
        while tau < k && rng.uniform() < self.accept_prob(tau) {
            tau += 1;
        }
        tau
    }

    /// Stable 64-bit tag over the acceptance semantics (probability bit
    /// patterns), folded into iteration-memo scopes.
    pub fn tag(&self) -> u64 {
        match self {
            AcceptanceModel::Uniform(a) => StableHasher::hash_of(&(0u8, a.to_bits())),
            AcceptanceModel::PerPosition(v) => {
                let bits: Vec<u64> = v.iter().map(|p| p.to_bits()).collect();
                StableHasher::hash_of(&(1u8, bits))
            }
        }
    }
}

/// A draft/target pairing: the whole speculative-decoding workload
/// shape. `k = 0` is the degenerate no-speculation configuration — every
/// consumer reproduces its plain-decode path bit for bit.
#[derive(Clone, Debug)]
pub struct SpecConfig {
    pub draft: TransformerConfig,
    pub target: TransformerConfig,
    /// Draft tokens proposed per round.
    pub k: usize,
    pub acceptance: AcceptanceModel,
}

impl SpecConfig {
    /// Pair `draft` with `target`. Both must be decoder-only and share a
    /// vocabulary — speculation verifies draft *token ids* against the
    /// target distribution, which is meaningless across tokenizers.
    pub fn new(
        draft: TransformerConfig,
        target: TransformerConfig,
        k: usize,
        acceptance: AcceptanceModel,
    ) -> SpecConfig {
        assert_eq!(draft.enc_layers, 0, "speculative drafts are decoder-only");
        assert_eq!(target.enc_layers, 0, "speculative targets are decoder-only");
        assert_eq!(
            draft.vocab, target.vocab,
            "draft and target must share a vocabulary"
        );
        SpecConfig { draft, target, k, acceptance }
    }

    /// Expected tokens committed per verification round.
    pub fn expected_tokens_per_round(&self) -> f64 {
        self.acceptance.expected_tokens_per_round(self.k)
    }

    /// Stable tag over the speculation semantics (draft shape, `k`,
    /// acceptance), folded into [`crate::serving::IterScope`] so memo
    /// entries can never alias across k/acceptance configurations.
    pub fn scope_tag(&self) -> u64 {
        StableHasher::hash_of(&(
            self.draft.name,
            self.draft.layers,
            self.draft.hidden,
            self.draft.heads,
            self.draft.kv_heads,
            self.draft.ffn_hidden,
            self.draft.dtype,
            self.k,
            self.acceptance.tag(),
        ))
    }
}

/// A synthetic draft for targets without a published companion model: a
/// 4× shallower, 2× narrower copy of the target (same vocabulary, same
/// head geometry, same dtype). Roughly an order of magnitude cheaper per
/// decode step, which is the regime where speculation pays.
pub fn auto_draft(target: &TransformerConfig) -> TransformerConfig {
    let mut d = target.clone();
    d.name = "auto-draft";
    d.layers = (d.layers / 4).max(1);
    if d.heads % 2 == 0 && d.kv_heads % 2 == 0 && d.hidden % 2 == 0 && d.ffn_hidden % 2 == 0 {
        // Halving width and heads together preserves head_dim, so the
        // attention geometry stays valid.
        d.heads /= 2;
        d.kv_heads /= 2;
        d.hidden /= 2;
        d.ffn_hidden /= 2;
    }
    d.params_b = d.weight_params() / 1e9;
    d
}

/// One speculative round of the analytical latency curve.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SpecRound {
    /// Target KV window of the verification pass (`ctx + k + 1`).
    pub kv_len: usize,
    /// Σ of the `k` draft decode steps this round.
    pub draft_s: f64,
    /// The one `q = k + 1` target verification pass.
    pub verify_s: f64,
    /// Expected tokens committed (`E[τ] + 1`, clamped at the tail of the
    /// generation).
    pub tokens: f64,
}

impl SpecRound {
    pub fn total_s(&self) -> f64 {
        self.draft_s + self.verify_s
    }
}

/// The full speculative latency curve `Pm2Lat::predict_speculative`
/// answers: prefill (target + draft prompt ingestion), then one
/// [`SpecRound`] per expected verification round.
#[derive(Clone, Debug, PartialEq)]
pub struct SpeculativePrediction {
    /// Target prefill over the prompt.
    pub prefill_s: f64,
    /// Draft prompt ingestion (0 when `k = 0` — no draft runs at all).
    pub draft_prefill_s: f64,
    pub gen_len: usize,
    pub k: usize,
    pub rounds: Vec<SpecRound>,
}

impl SpeculativePrediction {
    /// End-to-end expected latency: prefill + every round.
    pub fn total_s(&self) -> f64 {
        self.prefill_s + self.draft_prefill_s + self.decode_s()
    }

    /// Expected decode-phase latency (draft steps + verification passes).
    pub fn decode_s(&self) -> f64 {
        self.rounds.iter().map(SpecRound::total_s).sum()
    }

    /// Expected time per output token over the decode phase — the
    /// speculative TPOT (0 when nothing is generated).
    pub fn time_per_output_token_s(&self) -> f64 {
        if self.gen_len == 0 {
            0.0
        } else {
            self.decode_s() / self.gen_len as f64
        }
    }

    /// Expected steady-state decode throughput (tokens/s).
    pub fn tokens_per_s(&self) -> f64 {
        let tpot = self.time_per_output_token_s();
        if tpot > 0.0 {
            1.0 / tpot
        } else {
            0.0
        }
    }

    /// Share of decode time spent in the draft model.
    pub fn draft_time_share(&self) -> f64 {
        let total = self.decode_s();
        if total > 0.0 {
            self.rounds.iter().map(|r| r.draft_s).sum::<f64>() / total
        } else {
            0.0
        }
    }
}

/// One row of the crossover-k analysis: decode throughput at a given
/// draft length, against the plain-decode baseline.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CrossoverPoint {
    pub k: usize,
    pub tokens_per_s: f64,
    /// `tokens_per_s / baseline` — > 1 means speculation pays at this k.
    pub speedup: f64,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::zoo;

    #[test]
    fn uniform_expected_accepted_matches_the_closed_form_and_edges() {
        let m = AcceptanceModel::uniform(0.8);
        // Σ_{i=1..4} 0.8^i = 0.8 + 0.64 + 0.512 + 0.4096.
        let expect = 0.8 + 0.64 + 0.512 + 0.4096;
        assert!((m.expected_accepted(4) - expect).abs() < 1e-12);
        assert_eq!(m.expected_accepted(0), 0.0);
        assert_eq!(AcceptanceModel::uniform(0.0).expected_accepted(7), 0.0);
        assert_eq!(AcceptanceModel::uniform(1.0).expected_accepted(7), 7.0);
        // Out-of-range inputs clamp instead of exploding the series.
        assert_eq!(AcceptanceModel::uniform(1.5).expected_accepted(3), 3.0);
        // tokens/round always includes the verification token.
        assert!((m.expected_tokens_per_round(4) - (expect + 1.0)).abs() < 1e-12);
        assert_eq!(AcceptanceModel::uniform(0.0).expected_tokens_per_round(4), 1.0);
    }

    #[test]
    fn per_position_model_matches_uniform_when_flat_and_extends_the_tail() {
        let flat = AcceptanceModel::PerPosition(vec![0.6; 5]);
        let uni = AcceptanceModel::uniform(0.6);
        for k in 0..=5 {
            assert!((flat.expected_accepted(k) - uni.expected_accepted(k)).abs() < 1e-12);
        }
        // Past-the-end positions reuse the last entry.
        let decay = AcceptanceModel::PerPosition(vec![0.9, 0.5]);
        assert_eq!(decay.accept_prob(0), 0.9);
        assert_eq!(decay.accept_prob(1), 0.5);
        assert_eq!(decay.accept_prob(7), 0.5);
        // E[τ] over k=3: 0.9 + 0.9·0.5 + 0.9·0.5·0.5.
        let expect = 0.9 + 0.45 + 0.225;
        assert!((decay.expected_accepted(3) - expect).abs() < 1e-12);
        assert_eq!(AcceptanceModel::PerPosition(vec![]).expected_accepted(4), 0.0);
    }

    #[test]
    fn sampler_is_deterministic_bounded_and_tracks_alpha() {
        let m = AcceptanceModel::uniform(0.8);
        let draw = |seed: u64| {
            let mut rng = Rng::new(seed);
            m.sample(&mut rng, 4)
        };
        assert_eq!(draw(42), draw(42), "seeded sampling is deterministic");
        // Empirical mean over many seeds approaches E[τ].
        let n = 4000;
        let mean = (0..n).map(|s| draw(s as u64) as f64).sum::<f64>() / n as f64;
        assert!((mean - m.expected_accepted(4)).abs() < 0.1, "mean {mean}");
        for s in 0..200 {
            assert!(draw(s) <= 4);
        }
        // Degenerate α: always-reject and always-accept are exact.
        let mut rng = Rng::new(7);
        assert_eq!(AcceptanceModel::uniform(0.0).sample(&mut rng, 4), 0);
        assert_eq!(AcceptanceModel::uniform(1.0).sample(&mut rng, 4), 4);
    }

    #[test]
    fn spec_config_validates_and_tags_discriminate() {
        let target = zoo::gpt2_large();
        let draft = auto_draft(&target);
        assert_eq!(draft.vocab, target.vocab, "auto draft keeps the vocabulary");
        assert_eq!(draft.head_dim(), target.head_dim(), "head geometry preserved");
        assert!(draft.weight_bytes() < target.weight_bytes() / 4.0);
        let s1 = SpecConfig::new(draft.clone(), target.clone(), 4, AcceptanceModel::uniform(0.8));
        let s2 = SpecConfig::new(draft.clone(), target.clone(), 5, AcceptanceModel::uniform(0.8));
        let s3 = SpecConfig::new(draft, target, 4, AcceptanceModel::uniform(0.7));
        assert_ne!(s1.scope_tag(), s2.scope_tag(), "k is part of the scope");
        assert_ne!(s1.scope_tag(), s3.scope_tag(), "acceptance is part of the scope");
        assert!((s1.expected_tokens_per_round() - 3.3616).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "share a vocabulary")]
    fn mismatched_vocabularies_are_rejected() {
        let mut draft = zoo::qwen3_0_6b();
        draft.vocab = 1000;
        SpecConfig::new(draft, zoo::qwen3_4b(), 4, AcceptanceModel::uniform(0.8));
    }
}
