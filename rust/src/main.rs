//! PM2Lat CLI — the leader entrypoint.
//!
//! ```text
//! pm2lat report devices                     # Table I
//! pm2lat predict --device a100 --model gpt2-large --batch 8 \
//!                [--streams 4] [--fuse]   # graph schedule + attention fusion
//! pm2lat layer --device l4 --dtype bf16 --m 1024 --n 1024 --k 4096
//! pm2lat experiments [--full]               # every table + figure
//! pm2lat nas --n 1000                       # §IV-D2 speed study
//! pm2lat partition                          # §IV-D1 case study
//! pm2lat serve-bench --n 50000 --threads 8  # service throughput A/B
//! ```

use anyhow::{anyhow, Result};

use pm2lat::coordinator::{
    ab_phases, build_service, mixed_workload, mixed_workload_dtyped, quick_neusight,
    timed_submit, to_batched, to_kind, AbReport, PredictorKind,
};
use pm2lat::experiments::{self, Scale};
use pm2lat::gpusim::Gpu;
use pm2lat::graph::{AttentionFusion, Pass, PassCtx};
use pm2lat::models::{runner, zoo};
use pm2lat::ops::{DType, GemmOp, Op};
use pm2lat::pm2lat::Pm2Lat;
use pm2lat::profiler::ProfileSpec;
use pm2lat::runtime::Runtime;
use pm2lat::util::cli::Args;

fn main() {
    let args = Args::parse_env();
    if let Err(e) = run(&args) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn run(args: &Args) -> Result<()> {
    match args.subcommand.as_deref() {
        Some("report") => {
            println!("{}", experiments::tables::table1());
            Ok(())
        }
        Some("layer") => layer(args),
        Some("predict") => predict_model(args),
        Some("experiments") => {
            let runtime = Runtime::open_default()?;
            if args.flag("full") {
                std::env::set_var("PM2LAT_FULL", "1");
            }
            let report = experiments::run_all(&runtime, Scale::from_env())?;
            println!("{report}");
            println!("\n(written to results/)");
            Ok(())
        }
        Some("nas") => {
            let runtime = Runtime::open_default()?;
            let mut lab = experiments::Lab::build(&runtime, Scale::from_env(), false)?;
            let n = args.opt_usize("n", 1000);
            println!("{}", experiments::apps_exp::nas_speed_experiment(&mut lab, n)?);
            Ok(())
        }
        Some("partition") => {
            let runtime = Runtime::open_default()?;
            let mut lab = experiments::Lab::build(&runtime, Scale::from_env(), false)?;
            println!("{}", experiments::apps_exp::partition_experiment(&mut lab)?);
            Ok(())
        }
        Some("serve-bench") => serve_bench(args),
        Some(cmd) => Err(anyhow!("unknown command `{cmd}` (try: report, layer, predict, experiments, nas, partition, serve-bench)")),
        None => {
            println!("pm2lat {} — kernel-aware DNN latency prediction", pm2lat::version());
            println!("commands: report | layer | predict | experiments | nas | partition | serve-bench");
            Ok(())
        }
    }
}

/// §IV-D2 at service scale: requests/sec on a multi-device mixed workload,
/// serial no-cache baseline vs the concurrent cache-accelerated service,
/// across the F32 scalar + batched-PJRT kinds, the BF16 tensor-core lane
/// and the NeuSight learned-baseline lane.
fn serve_bench(args: &Args) -> Result<()> {
    let runtime = Runtime::open_default()?;
    let n = args.opt_usize("n", 50_000);
    let unique = args.opt_usize("unique", n / 12 + 1);
    let batch = args.opt_usize("batch", 2_048);
    let threads = args.opt_usize("threads", pm2lat::util::pool::default_threads());
    let devices = ["a100", "t4", "l4"];
    let dev_names: Vec<String> = devices.iter().map(|s| s.to_string()).collect();
    let workload = mixed_workload(&dev_names, n, unique, 42);
    println!(
        "serve-bench: {n} requests ({unique} unique ops) over {} devices, batch {batch}",
        devices.len()
    );

    // Baseline: the seed's serving regime — one thread, no cache — vs the
    // concurrent, cache-accelerated service. Both carry F32 + BF16 tables
    // (T4 has no BF16 path and answers None deterministically).
    let dtypes = [DType::F32, DType::Bf16];
    let base = build_service(&runtime, 1, 0, &devices, &dtypes)?;
    let mut fast = build_service(&runtime, threads, 1 << 17, &devices, &dtypes)?;
    fast.register_neusight(quick_neusight(&runtime, DType::F32)?);
    let scalar = ab_phases(&base, &fast, &workload, batch)?;
    let batched = ab_phases(&base, &fast, &to_batched(&workload), batch)?;
    // Seed 42 mirrors the F32 workload shape for shape (the RNG stream is
    // dtype-independent), so the lanes compare like for like.
    let bf16_workload = mixed_workload_dtyped(&dev_names, n, unique, 42, DType::Bf16);
    let bf16 = ab_phases(&base, &fast, &bf16_workload, batch)?;

    print_ab("scalar kind (f32)", n, threads, &scalar);
    print_ab("batched (PJRT) kind (f32)", n, threads, &batched);
    print_ab("bf16 scalar kind", n, threads, &bf16);

    // NeuSight lane: the learned baseline's MLP through PJRT. Outputs are
    // not memoized, so the A/B of interest is repeat-pass determinism.
    let ns_reqs = to_kind(&workload, PredictorKind::NeuSight);
    let (t1, o1) = timed_submit(&fast, &ns_reqs, batch)?;
    let (t2, o2) = timed_submit(&fast, &ns_reqs, batch)?;
    println!("-- neusight kind (f32) --");
    println!("pass 1               : {:>10.0} req/s", n as f64 / t1);
    println!("pass 2               : {:>10.0} req/s (repeat passes identical: {})",
        n as f64 / t2,
        o1 == o2
    );

    println!("metrics: {}", fast.metrics.summary());
    if !scalar.identical || !batched.identical || !bf16.identical {
        return Err(anyhow!("cached/parallel results diverged from uncached baseline"));
    }
    if o1 != o2 {
        return Err(anyhow!("neusight lane nondeterministic across repeat passes"));
    }
    Ok(())
}

fn print_ab(title: &str, n: usize, threads: usize, r: &AbReport) {
    println!("-- {title} --");
    println!("serial, no cache      : {:>10.0} req/s", n as f64 / r.serial_s);
    println!(
        "cold cache, {threads} threads: {:>10.0} req/s ({:.1}x vs serial, phase hit rate {:.1}%)",
        n as f64 / r.cold_s,
        r.serial_s / r.cold_s,
        r.cold_hit_rate * 100.0
    );
    println!(
        "warm cache            : {:>10.0} req/s ({:.1}x vs serial, phase hit rate {:.1}%)",
        n as f64 / r.warm_s,
        r.serial_s / r.warm_s,
        r.warm_hit_rate * 100.0
    );
    println!("cached results bit-identical to uncached: {}", r.identical);
}

fn layer(args: &Args) -> Result<()> {
    let device = args.opt_or("device", "a100").to_string();
    let dtype = DType::parse(args.opt_or("dtype", "fp32"))
        .ok_or_else(|| anyhow!("bad dtype"))?;
    let m = args.opt_usize("m", 1024);
    let n = args.opt_usize("n", 1024);
    let k = args.opt_usize("k", 1024);
    let mut gpu = Gpu::by_name(&device).ok_or_else(|| anyhow!("unknown device"))?;
    let pl = Pm2Lat::build_dtypes(&mut gpu, &ProfileSpec::experiment(), &[dtype], false);
    gpu.reset();
    let op = Op::Gemm(GemmOp::mm(m, n, k, dtype));
    let pred = pl
        .predict(&gpu, &op)
        .ok_or_else(|| anyhow!("unsupported on this device"))?;
    let truth = pm2lat::profiler::measure(&mut gpu, &op, &ProfileSpec::experiment())?;
    println!(
        "MatMul {m}x{n}x{k} {dtype} on {device}: predicted {:.3} ms, measured {:.3} ms ({:+.1}%)",
        pred * 1e3,
        truth.mean_s * 1e3,
        pm2lat::util::stats::signed_rel_err_pct(pred, truth.mean_s)
    );
    Ok(())
}

fn predict_model(args: &Args) -> Result<()> {
    let device = args.opt_or("device", "a100").to_string();
    let model = args.opt_or("model", "gpt2-large").to_string();
    let batch = args.opt_usize("batch", 1);
    let seq = args.opt_usize("seq", 512);
    let streams = args.opt_usize("streams", 1).max(1);
    let fuse = args.flag("fuse");
    let cfg = zoo::by_name(&model).ok_or_else(|| anyhow!("unknown model"))?;
    let mut gpu = Gpu::by_name(&device).ok_or_else(|| anyhow!("unknown device"))?;
    // Fusion needs the custom-kernel profile to price fused attention.
    let pl = Pm2Lat::build_dtypes(&mut gpu, &ProfileSpec::experiment(), &[cfg.dtype], fuse);
    gpu.reset();
    let mut g = cfg.graph(batch, seq);
    if fuse {
        let cost = |op: &Op| pl.predict(&gpu, op);
        let ctx = PassCtx::with_cost(&gpu.spec, &cost);
        let rewrites = AttentionFusion { only_if_faster: true }.run(&mut g, &ctx);
        println!("fusion: rewrote {rewrites} attention subgraphs");
    }
    let pred = pl
        .predict_graph(&gpu, &g, streams)
        .ok_or_else(|| anyhow!("model unsupported on this device"))?;
    println!(
        "{model} BS={batch} seq={seq} on {device} (streams={streams}): predicted {:.1} ms",
        pred * 1e3
    );
    match gpu.check_memory(cfg.memory_bytes(batch, seq)) {
        Ok(()) => match runner::run_graph(&mut gpu, &g, 5, 25, streams) {
            Ok(run) => println!(
                "measured {:.1} ms → error {:+.1}%",
                run.mean_s * 1e3,
                pm2lat::util::stats::signed_rel_err_pct(pred, run.mean_s)
            ),
            Err(e) => println!("(measurement unavailable: {e})"),
        },
        Err(e) => println!("(measurement unavailable: {e})"),
    }
    Ok(())
}
